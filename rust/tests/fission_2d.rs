//! 2D architecture fission: the cross-layer guarantees of the tile
//! generalization, on deterministic workloads.
//!
//! The columns-mode byte-parity guard lives in `engine_parity.rs`; this
//! file pins the *win*: on a multi-tenant mix with shallow-K tenants, 2D
//! mode must beat column-only partitioning outright (the
//! `examples/fission_2d.rs` demo mix, quoted in `docs/fission.md`).

use mtsa::coordinator::scheduler::{AllocPolicy, DynamicScheduler, PartitionMode, SchedulerConfig};
use mtsa::coordinator::RunMetrics;
use mtsa::report;
use mtsa::workloads::dnng::{Dnn, Layer, WorkloadPool};
use mtsa::workloads::shapes::{LayerKind, LayerShape};

fn fc_chain(name: &str, layers: usize, sr: u64, k: u64, m: u64) -> Dnn {
    let layers = (0..layers)
        .map(|i| Layer::new(&format!("l{i}"), LayerKind::Fc, LayerShape::fc(sr, k, m)))
        .collect();
    Dnn::chain(name, layers)
}

/// The docs/fission.md demo mix: one deep-reduction tenant plus three
/// shallow wide tenants, batch arrival.
fn demo_mix() -> WorkloadPool {
    WorkloadPool::new(
        "fission-demo",
        vec![
            fc_chain("deep", 3, 4000, 512, 64),
            fc_chain("shallow-a", 3, 4000, 32, 512),
            fc_chain("shallow-b", 3, 4000, 32, 512),
            fc_chain("shallow-c", 3, 4000, 32, 512),
        ],
    )
}

#[test]
fn two_d_beats_columns_on_the_shallow_heavy_mix() {
    let pool = demo_mix();
    let columns = DynamicScheduler::new(SchedulerConfig::default()).run(&pool);
    let two_d = DynamicScheduler::new(SchedulerConfig {
        partition_mode: PartitionMode::TwoD,
        ..Default::default()
    })
    .run(&pool);

    // The headline claim: folding shallow tenants into short tiles beats
    // fighting over width with full-height slices — by a wide margin, not
    // an epsilon (the example measures ~45% on this mix).
    assert!(
        (two_d.makespan as f64) < 0.75 * columns.makespan as f64,
        "2D fission should beat columns by >25% on this mix: {} vs {}",
        two_d.makespan,
        columns.makespan
    );
    assert!(
        report::mean_completion(&two_d) < report::mean_completion(&columns),
        "2D mean completion {} !< columns {}",
        report::mean_completion(&two_d),
        report::mean_completion(&columns)
    );

    // Columns mode only ever allocates full-height slices.
    assert!(columns.dispatches.iter().all(|d| d.tile.row0 == 0 && d.tile.rows == 128));

    // 2D mode actually stacked tenants: some tile starts below row 0, and
    // the shallow tenants run on short tiles (rows < 128).
    assert!(
        two_d.dispatches.iter().any(|d| d.tile.row0 > 0),
        "2D run never stacked a tile below another"
    );
    for name in ["shallow-a", "shallow-b", "shallow-c"] {
        assert!(
            two_d
                .dispatches
                .iter()
                .filter(|d| d.dnn_name == name)
                .all(|d| d.tile.rows < 128),
            "{name} should run on short tiles in 2D mode"
        );
    }
    // The deep tenant still gets its full reduction depth.
    assert!(
        two_d
            .dispatches
            .iter()
            .filter(|d| d.dnn_name == "deep")
            .all(|d| d.tile.rows == 128),
        "the deep-K tenant must keep full-height tiles"
    );

    // Both modes run every layer exactly once.
    assert_eq!(columns.dispatches.len(), pool.total_layers());
    assert_eq!(two_d.dispatches.len(), pool.total_layers());
}

#[test]
fn equal_share_policy_caps_width_in_2d_mode() {
    // The paper-literal `equal` policy must keep its meaning under 2D
    // fission: with 4 tenants available at t = 0 the equal share is
    // 128/4 = 32 columns, so no first-round tile may be wider — while
    // demand-first `widest` takes 64-wide tiles on this mix.
    let pool = demo_mix();
    let first_round_max = |m: &RunMetrics| {
        m.dispatches.iter().filter(|d| d.t_start == 0).map(|d| d.tile.cols).max().unwrap()
    };
    let equal = DynamicScheduler::new(SchedulerConfig {
        partition_mode: PartitionMode::TwoD,
        alloc_policy: AllocPolicy::EqualShare,
        ..Default::default()
    })
    .run(&pool);
    let widest = DynamicScheduler::new(SchedulerConfig {
        partition_mode: PartitionMode::TwoD,
        ..Default::default()
    })
    .run(&pool);
    assert_eq!(first_round_max(&equal), 32, "equal share = cols / n_available");
    assert_eq!(first_round_max(&widest), 64, "widest carves demand-first");
    assert_ne!(
        equal.dispatches, widest.dispatches,
        "equal must actually differ from widest in 2D mode"
    );
}

#[test]
fn two_d_concurrency_is_visible_in_start_times() {
    // In 2D mode all four tenants start at t = 0 (three stacked beside
    // the deep one); in columns mode at most two fit side by side.
    let pool = demo_mix();
    let columns = DynamicScheduler::new(SchedulerConfig::default()).run(&pool);
    let two_d = DynamicScheduler::new(SchedulerConfig {
        partition_mode: PartitionMode::TwoD,
        ..Default::default()
    })
    .run(&pool);
    let starts_at_zero =
        |m: &mtsa::coordinator::RunMetrics| m.start.values().filter(|&&t| t == 0).count();
    assert_eq!(starts_at_zero(&two_d), 4, "2D fits the whole mix at t=0: {:?}", two_d.start);
    assert!(
        starts_at_zero(&columns) < 4,
        "columns cannot fit the whole mix at t=0: {:?}",
        columns.start
    );
}
