//! Heterogeneous co-tenancy end to end: a compute-bound tenant and a
//! memory-bound tenant sharing one machine (systolic array + vector
//! lane pool) versus the same pair on the array alone.
//!
//! The cycle counts asserted here are *exact* — every segment is checked
//! against the closed-form timing model on the tile/span the scheduler
//! actually recorded, and the lane segment is additionally pinned to a
//! hand-computed literal so a silent change to the vector timing (or to
//! intensity-aware placement) fails loudly with the arithmetic in view.
//!
//! Also home of the `vector_off_is_transparent` property: with no
//! `[vector]` section (or `enabled = false`) the heterogeneous machinery
//! must be invisible — bit-identical run metrics and sweep JSON with no
//! vector/lane keys — across randomized configurations.

use mtsa::config::schema::RunConfig;
use mtsa::coordinator::scheduler::{AllocPolicy, DynamicScheduler, SchedulerConfig};
use mtsa::report;
use mtsa::sim::dataflow::{layer_timing_vector, VectorUnit};
use mtsa::sim::partitioned::{tile_layer_timing, FeedPolicy, LaneSpan, Tile};
use mtsa::sweep::{run_sweep, SweepGrid};
use mtsa::util::prop::{self, ensure, ensure_eq};
use mtsa::workloads::dnng::{Dnn, Layer, WorkloadPool};
use mtsa::workloads::models;
use mtsa::workloads::shapes::{LayerKind, LayerShape, OpClass};

/// One compute-bound tenant (a 3×3 conv, high arithmetic intensity) and
/// one memory-bound tenant (an embedding lookup lowered as a skinny
/// GEMM) — the canonical pair heterogeneous placement exists for.
fn colocate_pool() -> WorkloadPool {
    let conv = Layer::new(
        "conv3x3",
        LayerKind::Conv,
        LayerShape::conv(1, 64, 56, 56, 128, 3, 3, 1, 1),
    );
    let embed = Layer::new("embed", LayerKind::Embedding, LayerShape::fc(32, 1024, 64));
    WorkloadPool::new(
        "colocate",
        vec![Dnn::chain("convnet", vec![conv]), Dnn::chain("embedder", vec![embed])],
    )
}

#[test]
fn lane_offload_beats_array_only_colocation() {
    let pool = colocate_pool();
    assert_eq!(pool.dnns[0].layers[0].op_class(), OpClass::ComputeBound);
    assert_eq!(pool.dnns[1].layers[0].op_class(), OpClass::MemoryBound);

    let cfg = SchedulerConfig::default();
    let vu = VectorUnit::new(128);
    let hetero_cfg = SchedulerConfig { vector: Some(vu), ..cfg.clone() };

    let array_only = DynamicScheduler::new(cfg.clone()).run(&pool);
    let hetero = DynamicScheduler::new(hetero_cfg).run(&pool);

    // --- heterogeneous run: the embedding goes to the lanes ---
    assert_eq!(hetero.vector_dispatches, 1);
    let lane_rec = hetero
        .dispatches
        .iter()
        .find(|d| d.lanes.is_some())
        .expect("the memory-bound layer runs on the vector engine");
    assert_eq!(lane_rec.dnn_name, "embedder");
    // Sole memory-bound ready layer on an idle pool: it takes every lane.
    assert_eq!(lane_rec.lanes, Some(LaneSpan::new(0, 128)));
    assert_eq!(lane_rec.t_start, 0);
    // Hand-pinned: macs = 32·1024·64 = 2_097_152; ideal words
    // = k·m + sr·k + sr·m = 65_536 + 32_768 + 2_048 = 100_352.
    // cycles = startup + max(⌈2_097_152/128⌉, ⌈100_352/128⌉)
    //        = 64 + max(16_384, 784) = 16_448.
    assert_eq!(lane_rec.duration(), 16_448);
    let embed_gemm = pool.dnns[1].layers[0].shape.gemm();
    assert_eq!(layer_timing_vector(&vu, 128, embed_gemm).cycles, 16_448);

    // With the embedding off the array, the conv owns the full machine.
    let conv_rec = hetero
        .dispatches
        .iter()
        .find(|d| d.lanes.is_none())
        .expect("the compute-bound layer stays on the array");
    assert_eq!(conv_rec.dnn_name, "convnet");
    assert_eq!(conv_rec.tile, Tile::full(cfg.geom));
    assert_eq!(conv_rec.t_start, 0);
    let conv_gemm = pool.dnns[0].layers[0].shape.gemm();
    let conv_full = tile_layer_timing(
        cfg.geom,
        conv_gemm,
        Tile::full(cfg.geom),
        FeedPolicy::Independent,
        &cfg.buffers,
    )
    .cycles;
    assert_eq!(conv_rec.duration(), conv_full);
    assert_eq!(hetero.makespan, conv_full.max(16_448));

    // Lane work is billed to the vector ledger, not the array's.
    assert_eq!(hetero.vector_activity.macs, 2_097_152);
    assert_eq!(hetero.total_activity.macs, conv_gemm.macs());

    // --- array-only run: both tenants split the columns ---
    assert_eq!(array_only.vector_dispatches, 0);
    assert_eq!(array_only.dispatches.len(), 2);
    let mut array_completion = 0u64;
    for d in &array_only.dispatches {
        assert!(d.lanes.is_none());
        // floor_pow2(128 cols / 2 ready) = a 64-wide slice each.
        assert_eq!((d.tile.rows, d.tile.cols), (128, 64));
        let gemm = if d.dnn_name == "convnet" { conv_gemm } else { embed_gemm };
        let expect =
            tile_layer_timing(cfg.geom, gemm, d.tile, FeedPolicy::Independent, &cfg.buffers)
                .cycles;
        assert_eq!(d.duration(), expect, "segment {} priced by the closed form", d.dnn_name);
        array_completion = array_completion.max(d.t_end);
    }
    assert_eq!(array_only.makespan, array_completion);

    // --- the measured co-location win ---
    // Folding the 128-wide conv into a 64-column slice doubles its
    // M-folds, while the embedding finishes early and strands its slice;
    // the lane pool absorbs the embedding at full width instead, so the
    // heterogeneous machine strictly beats array-only dynamic
    // partitioning on makespan for this pair.
    assert!(
        hetero.makespan < array_only.makespan,
        "hetero makespan {} must beat array-only {}",
        hetero.makespan,
        array_only.makespan,
    );
}

/// With lanes off, the heterogeneous machinery must be invisible:
/// a config with no `[vector]` section and one with `enabled = false`
/// produce bit-identical run metrics (the full dispatch log, not just
/// the makespan) and bit-identical sweep JSON that never mentions
/// vector lanes — across randomized scheduler configurations.
#[test]
fn vector_off_is_transparent() {
    prop::check("vector_off_is_transparent", 8, |rng| {
        let policy = ["widest", "equal"][rng.gen_range(2) as usize];
        let mode = ["columns", "2d"][rng.gen_range(2) as usize];
        let preempt = ["off", "arrival"][rng.gen_range(2) as usize];
        let feed = ["independent", "interleaved"][rng.gen_range(2) as usize];
        let dram = rng.gen_bool(0.5);
        let base_toml = format!(
            "[array]\nrows = 128\ncols = 128\n\n\
             [scheduler]\npolicy = \"{policy}\"\nfeed_model = \"{feed}\"\n\n\
             [partition]\nmode = \"{mode}\"\npreempt = \"{preempt}\"\n\n\
             [dram]\nenabled = {dram}\n",
        );
        let off_toml = format!("{base_toml}\n[vector]\nenabled = false\n");
        let absent = RunConfig::from_toml(&base_toml).map_err(|e| e.to_string())?;
        let off = RunConfig::from_toml(&off_toml).map_err(|e| e.to_string())?;
        ensure(absent.scheduler.vector.is_none(), "no [vector] section parses to None")?;
        ensure(off.scheduler.vector.is_none(), "enabled = false parses to None")?;

        let pool = models::by_spec("NCF,MelodyLSTM").map_err(|e| e.to_string())?;
        let ma = DynamicScheduler::new(absent.scheduler.clone()).run(&pool);
        let mb = DynamicScheduler::new(off.scheduler.clone()).run(&pool);
        ensure_eq(ma.makespan, mb.makespan, "makespan")?;
        ensure_eq(&ma.dispatches, &mb.dispatches, "dispatch log")?;
        ensure_eq(ma.vector_dispatches, 0, "no lane dispatches with lanes off")?;
        ensure(
            ma.dispatches.iter().all(|d| d.lanes.is_none()),
            "no record carries a lane span with lanes off",
        )?;

        // JSON surface: one sweep point under each parse, byte-identical,
        // and free of vector/lane keys.
        let grid = SweepGrid {
            mixes: vec!["NCF".to_string()],
            rates: vec![0.0],
            policies: vec![AllocPolicy::WidestToHeaviest],
            requests: 2,
            ..SweepGrid::default()
        };
        let rows_a = run_sweep(&grid, &absent.scheduler, 1).map_err(|e| e.to_string())?;
        let rows_b = run_sweep(&grid, &off.scheduler, 1).map_err(|e| e.to_string())?;
        let json_a = report::sweep_json(&grid, &rows_a).render();
        let json_b = report::sweep_json(&grid, &rows_b).render();
        ensure_eq(&json_a, &json_b, "sweep JSON bytes")?;
        ensure(!json_a.contains("vector"), "sweep JSON has no vector key")?;
        ensure(!json_a.contains("lanes"), "sweep JSON has no lanes key")?;
        Ok(())
    });
}
