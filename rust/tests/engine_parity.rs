//! Engine/legacy parity and Scheduler-trait contract tests.
//!
//! `legacy_dynamic_run` below is a verbatim port of the pre-refactor
//! `DynamicScheduler::run` — the fused batch loop that owned policy,
//! clock and metrics before the `sim_core` engine existed.  The golden
//! tests assert the engine-driven port reproduces it **bit-for-bit**
//! (makespan, every dispatch record, per-tenant p50/p95/p99 and miss
//! rates) on the paper's heavy and light mixes, across alloc policies,
//! feed models and the DRAM bound.
//!
//! The property tests then check the trait contract every `Scheduler`
//! implementation must satisfy: each layer executes exactly once, in
//! chain order, never before its DNN arrives — including a test-local
//! policy that exists nowhere in the library.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use mtsa::coordinator::baseline::SequentialBaseline;
use mtsa::coordinator::metrics::{DispatchRecord, RunMetrics};
use mtsa::coordinator::multi_array::MultiArrayBank;
use mtsa::coordinator::partition::{AllocId, PartitionManager};
use mtsa::coordinator::queue::TaskQueue;
use mtsa::coordinator::scenario::{Scenario, ScenarioSpec};
use mtsa::coordinator::scheduler::{
    AllocPolicy, DynamicScheduler, FeedModel, PartitionMode, PreemptMode, SchedulerConfig,
};
use mtsa::coordinator::static_part::StaticPartitioning;
use mtsa::sim::dram::DramConfig;
use mtsa::sim::partitioned::{tile_layer_timing, FeedPolicy, Tile};
use mtsa::sim_core::{Allocation, Engine, LayerExec, Scheduler, SystemState};
use mtsa::util::prop;
use mtsa::workloads::dnng::{DnnId, LayerId, WorkloadPool};
use mtsa::workloads::generator::{random_pool, ArrivalProcess, GeneratorCfg};
use mtsa::workloads::models;

// ---------------------------------------------------------------------
// The legacy scheduler, frozen: this is the exact pre-sim_core loop.
// ---------------------------------------------------------------------

fn floor_pow2(x: u64) -> u64 {
    1 << (63 - x.leading_zeros() as u64)
}

fn ceil_pow2(x: u64) -> u64 {
    x.next_power_of_two()
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Completion {
    t_end: u64,
    dnn: DnnId,
    layer: LayerId,
    alloc: AllocId,
    t_start: u64,
}

impl Ord for Completion {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.t_end, self.dnn, self.layer).cmp(&(other.t_end, other.dnn, other.layer))
    }
}
impl PartialOrd for Completion {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

fn legacy_layer_cycles(
    cfg: &SchedulerConfig,
    pool: &WorkloadPool,
    dnn: DnnId,
    layer: LayerId,
    tile: Tile,
    coresident: u64,
) -> u64 {
    let gemm = pool.dnns[dnn].layers[layer].shape.gemm();
    let policy = match cfg.feed_model {
        FeedModel::Independent => FeedPolicy::Independent,
        FeedModel::Interleaved => FeedPolicy::Interleaved {
            coresident: coresident.max(1),
            slot: coresident.saturating_sub(1),
        },
    };
    let t = tile_layer_timing(cfg.geom, gemm, tile, policy, &cfg.buffers);
    match &cfg.dram {
        Some(d) => d.bound_cycles(t.cycles, &t.activity),
        None => t.cycles,
    }
}

/// Pre-refactor `DynamicScheduler::run`, verbatim.
fn legacy_dynamic_run(cfg: &SchedulerConfig, pool: &WorkloadPool) -> RunMetrics {
    let mut queue = TaskQueue::new(pool);
    let mut pm = PartitionManager::new(cfg.geom);
    let mut metrics = RunMetrics::default();
    let mut events: BinaryHeap<Reverse<Completion>> = BinaryHeap::new();
    let mut now = 0u64;

    loop {
        // ---- dispatch phase at `now` -------------------------------
        let ready = queue.ready_at(now);
        if !ready.is_empty() {
            let n_avail = ready.len() as u64 + pm.allocated_count() as u64;
            let target =
                floor_pow2((cfg.geom.cols / n_avail).max(1)).clamp(cfg.min_width, cfg.geom.cols);

            let mut dispatched_any = false;
            for r in ready {
                let m_cols = pool.dnns[r.dnn].layers[r.layer].shape.gemm().m;
                let demand = ceil_pow2(m_cols).clamp(cfg.min_width, cfg.geom.cols);

                if pm.fully_free() && n_avail == 1 {
                    let (alloc, tile) = pm.allocate(cfg.geom.cols).expect("full array free");
                    queue.mark_running(r.dnn, r.layer);
                    let cycles = legacy_layer_cycles(cfg, pool, r.dnn, r.layer, tile, 1);
                    events.push(Reverse(Completion {
                        t_end: now + cycles,
                        dnn: r.dnn,
                        layer: r.layer,
                        alloc,
                        t_start: now,
                    }));
                    dispatched_any = true;
                    continue;
                }

                let widest = pm.widest_free().map(|s| s.width).unwrap_or(0);
                if widest < cfg.min_width {
                    continue;
                }
                let width = match cfg.alloc_policy {
                    AllocPolicy::EqualShare => demand.min(target).min(floor_pow2(widest)),
                    // The legacy loop predates the [mem] hierarchy;
                    // without it the mem-aware policy carves exactly like
                    // widest (pinned by the mem-disabled parity test).
                    AllocPolicy::WidestToHeaviest | AllocPolicy::MemAware => {
                        let width = demand.min(floor_pow2(widest));
                        let acceptable = (demand / cfg.patience_divisor).max(cfg.min_width);
                        if width >= acceptable {
                            width
                        } else if pm.allocated_count() == 0 && !dispatched_any {
                            floor_pow2(widest)
                        } else {
                            continue;
                        }
                    }
                };
                let Some((alloc, tile)) = pm.allocate(width) else { continue };
                queue.mark_running(r.dnn, r.layer);
                dispatched_any = true;

                let coresident = pm.allocated_count() as u64;
                let cycles = legacy_layer_cycles(cfg, pool, r.dnn, r.layer, tile, coresident);
                events.push(Reverse(Completion {
                    t_end: now + cycles,
                    dnn: r.dnn,
                    layer: r.layer,
                    alloc,
                    t_start: now,
                }));
            }
        }

        // ---- advance time ------------------------------------------
        let next_completion = events.peek().map(|Reverse(c)| c.t_end);
        let next_arrival = queue.next_arrival_after(now);
        match (next_completion, next_arrival) {
            (None, None) => break,
            (None, Some(t_arr)) => {
                now = t_arr;
            }
            (Some(t_done), t_arr) => {
                if let Some(t_arr) = t_arr {
                    if t_arr < t_done {
                        now = t_arr;
                        continue;
                    }
                }
                now = t_done;
                while let Some(Reverse(c)) = events.peek().copied() {
                    if c.t_end != now {
                        break;
                    }
                    events.pop();
                    let tile = pm.tile_of(c.alloc).expect("completion of live alloc");
                    pm.free(c.alloc);
                    queue.mark_done(c.dnn, c.layer);
                    let layer = &pool.dnns[c.dnn].layers[c.layer];
                    let timing = tile_layer_timing(
                        cfg.geom,
                        layer.shape.gemm(),
                        tile,
                        FeedPolicy::Independent,
                        &cfg.buffers,
                    );
                    metrics.record_dispatch(DispatchRecord {
                        dnn: c.dnn,
                        dnn_name: pool.dnns[c.dnn].name.clone(),
                        layer: c.layer,
                        layer_name: layer.name.clone(),
                        tile,
                        lanes: None,
                        t_start: c.t_start,
                        t_end: c.t_end,
                        activity: timing.activity,
                    });
                }
            }
        }
        if queue.all_done() && events.is_empty() {
            break;
        }
    }

    assert!(queue.all_done(), "legacy scheduler exited with pending layers");
    metrics
}

// ---------------------------------------------------------------------
// Golden tests: engine == legacy, bit for bit.
// ---------------------------------------------------------------------

fn assert_metrics_identical(legacy: &RunMetrics, engine: &RunMetrics, what: &str) {
    assert_eq!(legacy.makespan, engine.makespan, "{what}: makespan");
    assert_eq!(legacy.completion, engine.completion, "{what}: completion map");
    assert_eq!(legacy.start, engine.start, "{what}: start map");
    assert_eq!(legacy.total_activity, engine.total_activity, "{what}: activity");
    assert_eq!(legacy.dispatches.len(), engine.dispatches.len(), "{what}: dispatch count");
    for (i, (l, e)) in legacy.dispatches.iter().zip(&engine.dispatches).enumerate() {
        assert_eq!(l, e, "{what}: dispatch record #{i}");
    }
}

fn paper_mixes() -> Vec<(&'static str, WorkloadPool)> {
    vec![
        ("heavy", models::by_spec("heavy").unwrap()),
        ("light", models::by_spec("light").unwrap()),
    ]
}

#[test]
fn golden_engine_matches_legacy_on_paper_mixes() {
    for (name, pool) in paper_mixes() {
        let cfg = SchedulerConfig::default();
        let legacy = legacy_dynamic_run(&cfg, &pool);
        let engine = DynamicScheduler::new(cfg).run(&pool);
        assert_metrics_identical(&legacy, &engine, name);
    }
}

#[test]
fn golden_parity_across_config_axes() {
    let variants: Vec<(&str, SchedulerConfig)> = vec![
        (
            "equal-share",
            SchedulerConfig { alloc_policy: AllocPolicy::EqualShare, ..Default::default() },
        ),
        (
            "interleaved",
            SchedulerConfig { feed_model: FeedModel::Interleaved, ..Default::default() },
        ),
        ("dram-bound", SchedulerConfig { dram: Some(DramConfig::default()), ..Default::default() }),
        ("narrow-min", SchedulerConfig { min_width: 32, ..Default::default() }),
        ("impatient", SchedulerConfig { patience_divisor: 1, ..Default::default() }),
    ];
    for (name, pool) in paper_mixes() {
        for (vname, cfg) in &variants {
            let legacy = legacy_dynamic_run(cfg, &pool);
            let engine = DynamicScheduler::new(cfg.clone()).run(&pool);
            assert_metrics_identical(&legacy, &engine, &format!("{name}/{vname}"));
        }
    }
}

#[test]
fn golden_tenant_stats_on_arrival_driven_scenario() {
    // The serving-side view: p50/p95/p99 + miss rates from an
    // arrival-driven scenario must match exactly too.
    for (name, pool) in paper_mixes() {
        let cfg = SchedulerConfig::default();
        let spec = ScenarioSpec {
            name: format!("{name}-poisson"),
            arrival: ArrivalProcess::Poisson { mean_interarrival: 25_000.0 },
            requests: 16,
            seed: 0xFEED,
            qos_slack: Some(2.5),
        };
        let scenario = Scenario::generate(&pool.dnns, &spec, &cfg);
        let legacy = legacy_dynamic_run(&cfg, &scenario.pool);
        let (engine_obs, engine_outcome) =
            scenario.run(&mut DynamicScheduler::new(cfg.clone()), cfg.geom);
        assert_metrics_identical(&legacy, &engine_obs.metrics, name);
        let legacy_outcome = scenario.analyze(&legacy);
        assert_eq!(legacy_outcome.tenants, engine_outcome.tenants, "{name}: per-tenant stats");
        assert_eq!(legacy_outcome.overall, engine_outcome.overall, "{name}: overall stats");
    }
}

#[test]
fn golden_parity_on_random_arrival_pools() {
    prop::check("engine == legacy on random pools", 12, |rng| {
        let cfg = GeneratorCfg {
            num_dnns: rng.gen_range_inclusive(2, 6) as usize,
            layers_min: 1,
            layers_max: 8,
            mean_interarrival: *rng.choose(&[0.0, 10_000.0, 80_000.0]),
            dim_scale: 0.4 + rng.gen_f64(),
        };
        let pool = random_pool(rng, &cfg);
        let scfg = SchedulerConfig {
            alloc_policy: *rng.choose(&AllocPolicy::ALL),
            feed_model: *rng.choose(&FeedModel::ALL),
            ..Default::default()
        };
        let legacy = legacy_dynamic_run(&scfg, &pool);
        let engine = DynamicScheduler::new(scfg).run(&pool);
        prop::ensure_eq(legacy.makespan, engine.makespan, "makespan")?;
        prop::ensure_eq(&legacy.dispatches, &engine.dispatches, "dispatch log")
    });
}

// ---------------------------------------------------------------------
// Trait-contract property: ANY Scheduler executes every layer exactly
// once, in chain order, never before arrival.
// ---------------------------------------------------------------------

/// A policy that exists only in this test: earliest ready (dnn, layer)
/// takes the whole array, FIFO.  If the contract holds for this too, it
/// is a property of the engine + trait, not of any particular policy.
struct TestFifo(SchedulerConfig);

impl Scheduler for TestFifo {
    fn name(&self) -> &'static str {
        "test-fifo"
    }
    fn plan(&mut self, s: &SystemState<'_>) -> Vec<Allocation> {
        if !s.partitions.fully_free() {
            return Vec::new();
        }
        s.queue
            .ready_at(s.now)
            .iter()
            .min_by_key(|r| (r.dnn, r.layer))
            .map(|r| {
                vec![Allocation::array(r.dnn, r.layer, Tile::full(self.0.geom))]
            })
            .unwrap_or_default()
    }
    fn exec(
        &self,
        s: &SystemState<'_>,
        dnn: DnnId,
        layer: LayerId,
        tile: Tile,
        _coresident: u64,
    ) -> LayerExec {
        let gemm = s.pool.dnns[dnn].layers[layer].shape.gemm();
        let t =
            tile_layer_timing(self.0.geom, gemm, tile, FeedPolicy::Independent, &self.0.buffers);
        LayerExec { cycles: t.cycles, activity: t.activity }
    }
}

/// The contract every `Scheduler` implementation must satisfy on chain
/// pools: one dispatch per layer, in chain order, non-overlapping within
/// a DNN, never before the DNN's arrival.
fn check_contract(pool: &WorkloadPool, m: &RunMetrics, who: &str) -> Result<(), String> {
    prop::ensure_eq(m.dispatches.len(), pool.total_layers(), &format!("{who}: dispatch count"))?;
    for (di, dnn) in pool.dnns.iter().enumerate() {
        let mut recs: Vec<&DispatchRecord> =
            m.dispatches.iter().filter(|d| d.dnn == di).collect();
        prop::ensure_eq(recs.len(), dnn.layers.len(), &format!("{who}: layers of {}", dnn.name))?;
        recs.sort_by_key(|d| (d.t_start, d.layer));
        for (i, r) in recs.iter().enumerate() {
            prop::ensure_eq(r.layer, i, &format!("{who}: chain order of {}", dnn.name))?;
            prop::ensure(
                r.t_start >= dnn.arrival_cycles,
                &format!("{who}: {} layer {} started before arrival", dnn.name, r.layer),
            )?;
        }
        for w in recs.windows(2) {
            prop::ensure(
                w[0].t_end <= w[1].t_start,
                &format!("{who}: {} layers overlap", dnn.name),
            )?;
        }
    }
    Ok(())
}

#[test]
fn every_scheduler_runs_each_layer_once_in_chain_order() {
    prop::check("scheduler trait contract", 10, |rng| {
        let gcfg = GeneratorCfg {
            num_dnns: rng.gen_range_inclusive(2, 6) as usize,
            layers_min: 1,
            layers_max: 6,
            mean_interarrival: *rng.choose(&[0.0, 20_000.0]),
            dim_scale: 0.5 + rng.gen_f64() * 0.5,
        };
        let pool = random_pool(rng, &gcfg);
        let cfg = SchedulerConfig::default();

        check_contract(&pool, &DynamicScheduler::new(cfg.clone()).run(&pool), "dynamic")?;
        check_contract(&pool, &SequentialBaseline::new(cfg.clone()).run(&pool), "sequential")?;
        check_contract(&pool, &StaticPartitioning::new(cfg.clone()).run(&pool), "static")?;
        check_contract(&pool, &MultiArrayBank::split_of(&cfg, 2).run(&pool), "multi-array")?;
        check_contract(
            &pool,
            &Engine::execute(&pool, cfg.geom, &mut TestFifo(cfg.clone())),
            "test-fifo",
        )
    });
}

// ---------------------------------------------------------------------
// Cross-policy sanity on the shared engine.
// ---------------------------------------------------------------------

// ---------------------------------------------------------------------
// Shared-memory-hierarchy parity guard: with [mem] disabled (the
// default), every policy and every report must reproduce today's bytes.
// ---------------------------------------------------------------------

#[test]
fn mem_disabled_keeps_all_four_policies_bit_identical_to_legacy_era_runs() {
    // The legacy goldens above already pin the dynamic policy against the
    // frozen pre-engine loop; this pins the *shape* guarantees the mem
    // subsystem must not disturb when disabled: no mem stats collected,
    // the mem-aware tag degenerates to widest bit-for-bit, and sweep JSON
    // carries no mem fields and stays thread-count invariant.
    for (name, pool) in paper_mixes() {
        let cfg = SchedulerConfig::default();
        assert!(cfg.mem.is_none(), "contention must be opt-in");
        let widest = DynamicScheduler::new(cfg.clone()).run(&pool);
        assert!(widest.mem.is_empty(), "{name}: no [mem] => no mem stats");
        assert_eq!(widest.mem_total, Default::default());
        let aware = DynamicScheduler::new(SchedulerConfig {
            alloc_policy: AllocPolicy::MemAware,
            ..cfg.clone()
        })
        .run(&pool);
        assert_eq!(widest.makespan, aware.makespan, "{name}");
        assert_eq!(widest.dispatches, aware.dispatches, "{name}");

        let seq = SequentialBaseline::new(cfg.clone()).run(&pool);
        assert!(seq.mem.is_empty());
        let stat = StaticPartitioning::new(cfg.clone()).run(&pool);
        assert!(stat.mem.is_empty());
        let multi = MultiArrayBank::split_of(&cfg, 4).run(&pool);
        assert!(multi.mem.is_empty());
    }

    let grid = mtsa::sweep::SweepGrid {
        mixes: vec!["light".into()],
        rates: vec![0.0, 40_000.0],
        policies: vec![AllocPolicy::WidestToHeaviest],
        feeds: vec![FeedModel::Independent],
        geoms: vec![mtsa::sim::dataflow::ArrayGeometry::new(128, 128)],
        requests: 4,
        ..Default::default()
    };
    let base = SchedulerConfig::default();
    let a = mtsa::report::sweep_json(&grid, &mtsa::sweep::run_sweep(&grid, &base, 1).unwrap())
        .render();
    let b = mtsa::report::sweep_json(&grid, &mtsa::sweep::run_sweep(&grid, &base, 4).unwrap())
        .render();
    assert_eq!(a, b, "mem-disabled sweep must stay thread-count invariant");
    assert!(!a.contains("\"mem\""), "no [mem] => no mem keys in the JSON");
    assert!(!a.contains("\"bandwidths\""), "no contention axis => no grid-level mem keys");
}

#[test]
fn all_four_policies_run_the_heavy_mix_through_one_engine() {
    let cfg = SchedulerConfig::default();
    let pool = models::by_spec("heavy").unwrap();
    let layers = pool.total_layers();
    let runs = [
        Engine::execute(&pool, cfg.geom, &mut DynamicScheduler::new(cfg.clone())),
        Engine::execute(&pool, cfg.geom, &mut SequentialBaseline::new(cfg.clone())),
        Engine::execute(&pool, cfg.geom, &mut StaticPartitioning::new(cfg.clone())),
        MultiArrayBank::split_of(&cfg, 4).run(&pool),
    ];
    for m in &runs {
        assert_eq!(m.dispatches.len(), layers);
        assert!(m.makespan > 0);
    }
    // And the paper's ordering holds: dynamic <= sequential on the mixes.
    assert!(runs[0].makespan <= runs[1].makespan);
}

// ---------------------------------------------------------------------
// 2D-fission parity guard: the default `partition.mode = "columns"`
// must produce byte-identical runs and sweep JSON to the pre-2D system,
// and the new JSON keys may only appear when 2D mode is actually on.
// ---------------------------------------------------------------------

#[test]
fn columns_mode_is_default_and_byte_identical() {
    for (name, pool) in paper_mixes() {
        let def_cfg = SchedulerConfig::default();
        assert_eq!(def_cfg.partition_mode, PartitionMode::Columns, "columns must be the default");
        let def = DynamicScheduler::new(def_cfg.clone()).run(&pool);
        let explicit = DynamicScheduler::new(SchedulerConfig {
            partition_mode: PartitionMode::Columns,
            ..def_cfg.clone()
        })
        .run(&pool);
        assert_metrics_identical(&def, &explicit, name);
        // Every columns-mode tile is full-height — the 1D shape exactly.
        for d in &def.dispatches {
            assert_eq!(d.tile.row0, 0, "{name}: columns tiles start at row 0");
            assert_eq!(d.tile.rows, def_cfg.geom.rows, "{name}: columns tiles span all rows");
        }
    }
}

// ---------------------------------------------------------------------
// Preemption parity guard: `preempt = off` (the default) must produce
// byte-identical runs and sweep JSON to the non-preemptive system, and
// the preempt JSON keys may only appear when preemption is actually on.
// ---------------------------------------------------------------------

#[test]
fn preempt_off_is_default_and_byte_identical() {
    for (name, pool) in paper_mixes() {
        let def_cfg = SchedulerConfig::default();
        assert_eq!(def_cfg.preempt, PreemptMode::Off, "preemption must be opt-in");
        let def = DynamicScheduler::new(def_cfg.clone()).run(&pool);
        let explicit = DynamicScheduler::new(SchedulerConfig {
            preempt: PreemptMode::Off,
            ..def_cfg.clone()
        })
        .run(&pool);
        assert_metrics_identical(&def, &explicit, name);
        assert_eq!(def.preemptions, 0, "{name}: off => no preemptions");
        assert_eq!(def.replayed_folds, 0);
        assert_eq!(def.wasted_refill_cycles, 0);
        // ... and the legacy golden above already pins `def` against the
        // frozen pre-engine loop, so off == the pre-preemption system.
    }

    let grid = mtsa::sweep::SweepGrid {
        mixes: vec!["light".into()],
        rates: vec![0.0, 40_000.0],
        policies: vec![AllocPolicy::WidestToHeaviest],
        feeds: vec![FeedModel::Independent],
        requests: 4,
        ..Default::default()
    };
    let base = SchedulerConfig::default();
    let default_json =
        mtsa::report::sweep_json(&grid, &mtsa::sweep::run_sweep(&grid, &base, 2).unwrap())
            .render();
    let explicit = mtsa::sweep::SweepGrid { preempts: vec![PreemptMode::Off], ..grid.clone() };
    let explicit_json =
        mtsa::report::sweep_json(&explicit, &mtsa::sweep::run_sweep(&explicit, &base, 2).unwrap())
            .render();
    assert_eq!(default_json, explicit_json, "explicit preempt=off changed the sweep bytes");
    for key in ["\"preempt\"", "\"preempts\"", "\"preemptions\"", "\"wasted_refill_cycles\""] {
        assert!(!default_json.contains(key), "preempt-off sweep JSON leaked {key}");
    }
    // The keys DO appear once a preempting point runs.
    let with_pre = mtsa::sweep::SweepGrid {
        preempts: vec![PreemptMode::Off, PreemptMode::Arrival],
        ..grid.clone()
    };
    let json_pre =
        mtsa::report::sweep_json(&with_pre, &mtsa::sweep::run_sweep(&with_pre, &base, 2).unwrap())
            .render();
    for key in ["\"preempt\"", "\"preempts\"", "\"preemptions\"", "\"wasted_refill_cycles\""] {
        assert!(json_pre.contains(key), "preempting sweep JSON must carry {key}");
    }
    // ... and the preempting sweep stays thread-count invariant.
    let json_pre_8 =
        mtsa::report::sweep_json(&with_pre, &mtsa::sweep::run_sweep(&with_pre, &base, 8).unwrap())
            .render();
    assert_eq!(json_pre, json_pre_8, "preempting sweep must stay thread-count invariant");
}

#[test]
fn columns_mode_sweep_json_carries_no_2d_keys() {
    let grid = mtsa::sweep::SweepGrid {
        mixes: vec!["light".into()],
        rates: vec![0.0, 40_000.0],
        policies: vec![AllocPolicy::WidestToHeaviest],
        feeds: vec![FeedModel::Independent],
        requests: 4,
        ..Default::default()
    };
    let base = SchedulerConfig::default();
    let default_json =
        mtsa::report::sweep_json(&grid, &mtsa::sweep::run_sweep(&grid, &base, 2).unwrap())
            .render();
    // An explicit columns-only mode axis must not change a byte either.
    let explicit = mtsa::sweep::SweepGrid {
        modes: vec![PartitionMode::Columns],
        ..grid.clone()
    };
    let explicit_json =
        mtsa::report::sweep_json(&explicit, &mtsa::sweep::run_sweep(&explicit, &base, 2).unwrap())
            .render();
    assert_eq!(default_json, explicit_json, "explicit columns mode changed the sweep bytes");
    for key in ["\"partition_mode\"", "\"modes\"", "\"rows\""] {
        assert!(!default_json.contains(key), "columns-mode sweep JSON leaked {key}");
    }
    // The keys DO appear once a 2D point runs — guarding against the
    // opposite failure (silently dropping the new coordinates).
    let with_2d = mtsa::sweep::SweepGrid {
        modes: vec![PartitionMode::Columns, PartitionMode::TwoD],
        ..grid.clone()
    };
    let json_2d =
        mtsa::report::sweep_json(&with_2d, &mtsa::sweep::run_sweep(&with_2d, &base, 2).unwrap())
            .render();
    assert!(json_2d.contains("\"partition_mode\""));
    assert!(json_2d.contains("\"modes\""));
}

#[test]
fn bucket_queue_matches_binary_heap() {
    // The calendar/bucket event queue (PR 6 hot-path attack #2) must pop
    // the exact same event sequence as the seq-stamped `BinaryHeap`
    // reference — including FIFO order among *equal-key* duplicates,
    // which the engine relies on for stale-husk semantics.
    //
    // The generator respects the one contract the engine guarantees and
    // the bucket queue requires: no push at a time earlier than the last
    // popped event (simulated time never moves backwards).
    use mtsa::sim_core::queue::{BucketQueue, HeapQueue};
    use mtsa::sim_core::Event;

    fn random_event(rng: &mut mtsa::util::rng::Rng, low: u64) -> Event {
        let t = low + rng.gen_range_inclusive(0, 12);
        let dnn = rng.gen_range(4) as DnnId;
        let layer = rng.gen_range(3) as LayerId;
        let alloc = rng.gen_range(5) as AllocId;
        match rng.gen_range(6) {
            0 => Event::Arrival { t, dnn },
            1 => Event::LayerComplete { t, dnn, layer, alloc },
            2 => Event::Preempt { t, dnn, layer, alloc },
            3 => Event::Deadline { t, dnn },
            4 => Event::Repartition { t },
            _ => Event::MemRescale { t },
        }
    }

    prop::check("bucket queue == binary heap", 200, |rng| {
        let mut bucket = BucketQueue::new();
        let mut heap = HeapQueue::new();
        let mut low = 0u64; // time of the last popped event
        let mut live = 0usize;
        for step in 0..rng.gen_range_inclusive(20, 400) {
            prop::ensure_eq(
                bucket.next_time(),
                heap.next_time(),
                &format!("next_time before step {step}"),
            )?;
            if live == 0 || rng.gen_bool(0.6) {
                let ev = random_event(rng, low);
                bucket.push(ev);
                heap.push(ev);
                live += 1;
                // Same-cycle FIFO ties: re-push the identical event so
                // only insertion order can distinguish the copies.
                if rng.gen_bool(0.25) {
                    bucket.push(ev);
                    heap.push(ev);
                    live += 1;
                }
            } else {
                let a = bucket.pop();
                let b = heap.pop();
                prop::ensure_eq(a, b, &format!("pop at step {step}"))?;
                let ev = a.expect("live > 0 implies non-empty");
                prop::ensure(ev.time() >= low, "pops are time-monotonic")?;
                low = ev.time();
                live -= 1;
            }
        }
        // Full drain: both queues must empty in the identical order.
        loop {
            let a = bucket.pop();
            let b = heap.pop();
            prop::ensure_eq(a, b, "pop during final drain")?;
            if a.is_none() {
                break;
            }
        }
        prop::ensure_eq(bucket.next_time(), None, "bucket empty after drain")
    });
}
