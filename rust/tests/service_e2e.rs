//! Integration: the multi-tenant serving loop on the real PJRT datapath
//! (skipped when artifacts are absent; `make artifacts` builds them).

use std::path::PathBuf;
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use mtsa::coordinator::service::{GemmRequest, Service, ServiceHandle};
use mtsa::runtime::{Engine, Tensor};
use mtsa::util::rng::Rng;
use mtsa::verify;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn engine() -> Option<Arc<Engine>> {
    static ENGINE: OnceLock<Option<Arc<Engine>>> = OnceLock::new();
    ENGINE
        .get_or_init(|| artifacts_dir().map(|d| Arc::new(Engine::load(&d).expect("engine"))))
        .clone()
}

fn rand_tensor(rng: &mut Rng, shape: Vec<usize>) -> Tensor {
    let n = shape.iter().product();
    Tensor::new(shape, (0..n).map(|_| rng.gen_f32() - 0.5).collect())
}

#[test]
fn serve_group_matches_host_matmul() {
    let Some(eng) = engine() else { return };
    let service = Service::new(eng);
    let mut rng = Rng::new(1);
    // Three tenants, ragged shapes, K > 128 to exercise fold chaining.
    let reqs: Vec<GemmRequest> = [(100usize, 300usize, 40usize), (64, 129, 20), (17, 64, 30)]
        .iter()
        .enumerate()
        .map(|(t, &(sr, k, m))| GemmRequest {
            tenant: t,
            x: rand_tensor(&mut rng, vec![sr, k]),
            w: rand_tensor(&mut rng, vec![k, m]),
        })
        .collect();
    let results = service.serve_group(&reqs).unwrap();
    for (req, got) in reqs.iter().zip(&results) {
        let want = req.x.matmul(&req.w);
        assert!(
            got.max_abs_diff(&want) < 1e-2,
            "tenant {}: diff {}",
            req.tenant,
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn serve_group_rejects_oversize() {
    let Some(eng) = engine() else { return };
    let service = Service::new(eng);
    let mut rng = Rng::new(2);
    // sr > 128
    let bad = GemmRequest { tenant: 0, x: rand_tensor(&mut rng, vec![200, 8]), w: rand_tensor(&mut rng, vec![8, 8]) };
    assert!(service.serve_group(&[bad]).is_err());
    // total m > 128
    let mut wide = |t| GemmRequest {
        tenant: t,
        x: rand_tensor(&mut rng, vec![8, 8]),
        w: rand_tensor(&mut rng, vec![8, 70]),
    };
    let w0 = wide(0);
    let w1 = wide(1);
    assert!(service.serve_group(&[w0, w1]).is_err());
    // K mismatch
    let bad_k = GemmRequest { tenant: 0, x: rand_tensor(&mut rng, vec![8, 8]), w: rand_tensor(&mut rng, vec![9, 8]) };
    assert!(service.serve_group(&[bad_k]).is_err());
    // empty group is fine
    assert!(service.serve_group(&[]).unwrap().is_empty());
}

#[test]
fn threaded_handle_batches_and_answers() {
    let Some(eng) = engine() else { return };
    let service = Service::new(eng.clone());
    let handle = ServiceHandle::spawn(service, 4, Duration::from_millis(5));
    let mut rng = Rng::new(3);

    // Submit 8 concurrent requests; every response must be correct.
    let mut waits = Vec::new();
    let mut wants = Vec::new();
    for t in 0..8usize {
        let x = rand_tensor(&mut rng, vec![32, 64]);
        let w = rand_tensor(&mut rng, vec![64, 16]);
        wants.push(x.matmul(&w));
        waits.push(handle.submit(GemmRequest { tenant: t, x, w }));
    }
    for (i, rx) in waits.into_iter().enumerate() {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.tenant, i);
        assert!(resp.y.max_abs_diff(&wants[i]) < 1e-3, "tenant {i}");
    }
    // Dynamic batching must have grouped: fewer array steps than requests.
    assert!(eng.exec_count() >= 2, "at least two groups of four");
    handle.shutdown();
}

#[test]
fn verify_all_battery() {
    let Some(dir) = artifacts_dir() else { return };
    let n = verify::verify_all(&dir).unwrap();
    assert!(n >= 30, "expected a full battery, got {n} checks");
}
