//! Fleet serving-tier invariants: thread-count byte-identity of the
//! rendered JSON, chunk-size independence, and exactly-once request
//! conservation under randomized (including overloaded) configurations.

use mtsa::coordinator::scheduler::SchedulerConfig;
use mtsa::fleet::{run_fleet, FleetConfig, FleetPolicy, Placement};
use mtsa::report;
use mtsa::util::prop;
use mtsa::workloads::generator::{ArrivalProcess, Diurnal, ModelMix};

fn serving_cfg(requests: usize, seed: u64) -> FleetConfig {
    let sched = SchedulerConfig::default();
    FleetConfig {
        instances: FleetConfig::uniform(8, &sched, FleetPolicy::Dynamic),
        placement: Placement::LeastLoaded,
        random_k: 2,
        classes: FleetConfig::default_classes(25_000.0),
        slots: 6,
        queue_cap: 48,
        mix: ModelMix::new(&[("NCF", 3.0), ("MelodyLSTM", 2.0), ("AlexNet", 1.0)]),
        arrival: ArrivalProcess::Poisson { mean_interarrival: 25_000.0 },
        diurnal: Some(Diurnal { period: 8_000_000.0, amplitude: 0.6, phase: 0.0 }),
        requests,
        seed,
        chunk: 256,
        tables: None,
    }
}

/// The headline determinism contract: the rendered fleet JSON is
/// byte-identical at any worker-thread count.
#[test]
fn fleet_json_is_byte_identical_across_thread_counts() {
    let cfg = serving_cfg(1_500, 0xF1EE7);
    let base = report::fleet_json(&run_fleet(&cfg, 1).unwrap()).render();
    for threads in [4usize, 8] {
        let json = report::fleet_json(&run_fleet(&cfg, threads).unwrap()).render();
        assert_eq!(json, base, "thread count {threads} changed the report bytes");
    }
}

/// Placement and batching draws live in the router, not the workers: the
/// other placements are thread-stable too.
#[test]
fn every_placement_is_thread_stable() {
    for placement in [Placement::Affinity, Placement::RandomK] {
        let mut cfg = serving_cfg(400, 99);
        cfg.placement = placement;
        let a = report::fleet_json(&run_fleet(&cfg, 1).unwrap()).render();
        let b = report::fleet_json(&run_fleet(&cfg, 8).unwrap()).render();
        assert_eq!(a, b, "{placement:?}");
    }
}

/// Every generated request is accounted for exactly once — completed or
/// dropped with a reason — for random capacities, placements and seeds,
/// including overloaded fleets that must shed load.
#[test]
fn requests_are_conserved_exactly_once() {
    prop::check("fleet conservation", 12, |rng| {
        let sched = SchedulerConfig::default();
        let overload = rng.gen_bool(0.5);
        // Overloaded fleets get a single near-capacityless instance fed
        // back-to-back arrivals, so shedding is structurally forced.
        let n = if overload { 1 } else { rng.gen_range_inclusive(1, 4) as usize };
        let mean = if overload { 500.0 } else { 30_000.0 };
        let cfg = FleetConfig {
            instances: FleetConfig::uniform(n, &sched, FleetPolicy::Dynamic),
            placement: *rng.choose(&[
                Placement::LeastLoaded,
                Placement::Affinity,
                Placement::RandomK,
            ]),
            random_k: rng.gen_range_inclusive(1, 3) as usize,
            classes: FleetConfig::default_classes(mean),
            slots: if overload { 1 } else { rng.gen_range_inclusive(1, 4) as usize },
            queue_cap: if overload { 1 } else { rng.gen_range_inclusive(1, 8) as usize },
            mix: ModelMix::new(&[("NCF", 1.0), ("MelodyLSTM", 1.0)]),
            arrival: ArrivalProcess::Poisson { mean_interarrival: mean },
            diurnal: None,
            requests: rng.gen_range_inclusive(100, 200) as usize,
            seed: rng.gen_range_inclusive(0, u64::MAX - 1),
            chunk: 64,
            tables: None,
        };
        let r = run_fleet(&cfg, 2).map_err(|e| format!("run_fleet: {e}"))?;
        prop::ensure(r.conserved(), "generated != completed + dropped")?;
        prop::ensure_eq(r.generated, cfg.requests as u64, "generated count")?;
        let mut by_class = 0u64;
        for c in &r.classes {
            prop::ensure_eq(c.generated, c.completed + c.dropped, "per-class conservation")?;
            by_class += c.generated;
        }
        prop::ensure_eq(by_class, r.generated, "class totals cover the stream")?;
        if overload {
            prop::ensure(r.dropped > 0, "overloaded fleet must shed load")?;
        }
        Ok(())
    });
}

/// Peak memory is bounded by the chunk size, never the request count —
/// pinned by results being independent of how the stream is chunked.
#[test]
fn chunking_is_invisible_in_the_report() {
    let mut cfg = serving_cfg(600, 31);
    let base = report::fleet_json(&run_fleet(&cfg, 2).unwrap()).render();
    for chunk in [1usize, 7, 4096] {
        cfg.chunk = chunk;
        let json = report::fleet_json(&run_fleet(&cfg, 2).unwrap()).render();
        assert_eq!(json, base, "chunk {chunk} changed the report bytes");
    }
}
