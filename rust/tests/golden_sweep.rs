//! Golden-snapshot regression corpus: sweep JSON fixtures under
//! `tests/golden/`, byte-diffed against live output.
//!
//! These snapshots pin the *entire* observable surface of the sweep
//! pipeline — scenario generation, the event engine (all four hot-path
//! optimizations enabled), SLA statistics, and the deterministic JSON
//! renderer — across every axis: the full policy set (widest / equal /
//! mem-aware) × partition mode (columns / 2d) × preemption (off /
//! arrival) × shared memory (off / on).
//!
//! Lifecycle:
//! - missing fixture → the test *bootstraps* it (writes the live bytes
//!   and passes), so a fresh checkout is green and the first CI run
//!   self-seeds;
//! - `UPDATE_GOLDEN=1 cargo test --test golden_sweep` → rewrite all
//!   fixtures (do this only for an intended behavior change, and commit
//!   the diff);
//! - otherwise → byte-equality, with the first divergence reported.

use std::path::PathBuf;

use mtsa::coordinator::scheduler::{
    AllocPolicy, FeedModel, PartitionMode, PreemptMode, SchedulerConfig,
};
use mtsa::mem::ArbitrationMode;
use mtsa::report;
use mtsa::sweep::{run_sweep, SweepGrid};

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

/// Byte-compare the live sweep JSON for `grid` against `tests/golden/<name>.json`,
/// bootstrapping (or refreshing under `UPDATE_GOLDEN=1`) the fixture.
fn check_golden(name: &str, grid: &SweepGrid) {
    let rows = run_sweep(grid, &SchedulerConfig::default(), 2).expect("sweep runs");
    check_golden_bytes(name, report::sweep_json(grid, &rows).render());
}

/// The byte-diff half of [`check_golden`], for callers that render the
/// live JSON themselves (fleet axis, profile tables).
fn check_golden_bytes(name: &str, live: String) {
    let path = golden_dir().join(format!("{name}.json"));
    let update = std::env::var_os("UPDATE_GOLDEN").is_some();
    if update || !path.exists() {
        std::fs::create_dir_all(golden_dir()).expect("create tests/golden");
        std::fs::write(&path, &live).expect("write fixture");
        eprintln!(
            "golden: wrote {} ({} bytes){}",
            path.display(),
            live.len(),
            if update { "" } else { " [bootstrap — commit this file]" },
        );
        return;
    }
    let want = std::fs::read_to_string(&path).expect("read fixture");
    if live != want {
        let at = live
            .bytes()
            .zip(want.bytes())
            .position(|(a, b)| a != b)
            .unwrap_or_else(|| live.len().min(want.len()));
        let ctx = |s: &str| {
            let lo = at.saturating_sub(60);
            let hi = (at + 60).min(s.len());
            s.get(lo..hi).unwrap_or("<non-utf8 boundary>").to_string()
        };
        panic!(
            "golden snapshot `{name}` diverged at byte {at} \
             (live {} bytes, fixture {} bytes).\n  live:    …{}…\n  fixture: …{}…\n\
             If this change is intended, refresh with \
             `UPDATE_GOLDEN=1 cargo test --test golden_sweep` and commit.",
            live.len(),
            want.len(),
            ctx(&live),
            ctx(&want),
        );
    }
}

/// Small, fast base: one mix, batch arrivals, one feed.
fn base_grid() -> SweepGrid {
    SweepGrid {
        mixes: vec!["NCF".to_string()],
        rates: vec![0.0],
        policies: vec![
            AllocPolicy::WidestToHeaviest,
            AllocPolicy::EqualShare,
            AllocPolicy::MemAware,
        ],
        feeds: vec![FeedModel::Independent],
        requests: 3,
        ..SweepGrid::default()
    }
}

#[test]
fn golden_columns_all_policies() {
    check_golden("columns_policies", &base_grid());
}

#[test]
fn golden_2d_all_policies() {
    let grid = SweepGrid { modes: vec![PartitionMode::TwoD], ..base_grid() };
    check_golden("2d_policies", &grid);
}

#[test]
fn golden_preempt_axis() {
    let grid = SweepGrid {
        mixes: vec!["light".to_string()],
        rates: vec![30_000.0],
        policies: vec![AllocPolicy::WidestToHeaviest, AllocPolicy::EqualShare],
        preempts: vec![PreemptMode::Off, PreemptMode::Arrival],
        requests: 4,
        ..base_grid()
    };
    check_golden("preempt_axis", &grid);
}

#[test]
fn golden_mem_axis() {
    let grid = SweepGrid {
        policies: vec![AllocPolicy::WidestToHeaviest, AllocPolicy::MemAware],
        bandwidths: vec![8.0],
        arbitrations: vec![ArbitrationMode::FairShare],
        ..base_grid()
    };
    check_golden("mem_axis", &grid);
}

#[test]
fn golden_fleet_axis() {
    // The serving-tier corner: one (mix, rate) cell fanned across a
    // two-instance cluster, attached to the sweep JSON as its `fleet`
    // key (PR 7 added the axis; this pins its bytes).
    let grid = SweepGrid {
        mixes: vec!["NCF".to_string()],
        rates: vec![40_000.0],
        policies: vec![AllocPolicy::WidestToHeaviest],
        requests: 20,
        fleet: vec![2],
        ..base_grid()
    };
    let base = SchedulerConfig::default();
    let rows = run_sweep(&grid, &base, 2).expect("sweep runs");
    let fleet_rows = mtsa::sweep::run_fleet_axis(&grid, &base, 2).expect("fleet axis runs");
    assert_eq!(fleet_rows.len(), 1, "one non-batch cell x one cluster size");
    check_golden_bytes(
        "fleet_axis",
        report::sweep_json_with_fleet(&grid, &rows, &fleet_rows).render(),
    );
}

#[test]
fn golden_tables_axis() {
    // The profile-table corner: every point paired off/on against an
    // in-memory NCF table, pinning both the per-row `tables` key and the
    // table-driven 2D plans themselves.
    use mtsa::profiler::{ProfileStore, ProfileTable};
    use mtsa::sim::buffers::BufferConfig;
    use mtsa::sim::dataflow::ArrayGeometry;
    let geom = ArrayGeometry::new(128, 128);
    let dnn = (mtsa::workloads::models::by_name("NCF").expect("zoo model").build)();
    let table = ProfileTable::build("NCF", &dnn, geom, &BufferConfig::default());
    let grid = SweepGrid {
        policies: vec![AllocPolicy::WidestToHeaviest],
        modes: vec![PartitionMode::TwoD],
        tables: vec![false, true],
        tables_store: Some(std::sync::Arc::new(ProfileStore::from_tables(
            "golden",
            vec![table],
        ))),
        ..base_grid()
    };
    check_golden("tables_axis", &grid);
}

#[test]
fn golden_bursty_same_cycle() {
    // The coalescing corner: batch arrivals (rate 0) land every request
    // of a multi-model mix at the same cycle, so nearly every event
    // batch the engine drains is same-cycle-heavy — exactly the shape
    // the PR 9 coalesced drain + plan memo fast path serves.  Both
    // partition modes and arrival preemption keep the batch contents
    // diverse (arrivals, completions and preemptions colliding).
    let grid = SweepGrid {
        mixes: vec!["NCF,MelodyLSTM,NCF".to_string()],
        rates: vec![0.0],
        policies: vec![AllocPolicy::WidestToHeaviest, AllocPolicy::EqualShare],
        modes: vec![PartitionMode::Columns, PartitionMode::TwoD],
        preempts: vec![PreemptMode::Arrival],
        requests: 6,
        ..base_grid()
    };
    check_golden("bursty_same_cycle", &grid);
}

#[test]
fn golden_mem_preempt_2d_cross() {
    // The full cross on one policy: {columns, 2d} × {off, arrival} × mem
    // on — the interaction corner none of the single-axis fixtures pins.
    let grid = SweepGrid {
        mixes: vec!["light".to_string()],
        rates: vec![30_000.0],
        policies: vec![AllocPolicy::MemAware],
        modes: vec![PartitionMode::Columns, PartitionMode::TwoD],
        preempts: vec![PreemptMode::Off, PreemptMode::Arrival],
        bandwidths: vec![8.0],
        requests: 3,
        ..base_grid()
    };
    check_golden("mem_preempt_2d_cross", &grid);
}

#[test]
fn golden_lanes_axis() {
    // The heterogeneous corner (PR 10): every point paired lanes-off
    // (`0`, the pre-heterogeneous machine bit for bit) against a
    // 128-lane vector engine, pinning both the `lanes_axis` header, the
    // per-row `vector` summary, and the intensity-aware lane placement
    // itself (NCF's embeddings offload; everything else stays on the
    // array).
    let grid = SweepGrid {
        policies: vec![AllocPolicy::WidestToHeaviest, AllocPolicy::EqualShare],
        lanes: vec![0, 128],
        ..base_grid()
    };
    check_golden("lanes_axis", &grid);
}
