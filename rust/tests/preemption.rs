//! The pinned preemption win (`docs/preemption.md`, mirrored by
//! `examples/preemption_bursty.rs`): on a bursty light-over-heavy mix,
//! fold-boundary drain-and-reshape preemption strictly improves the
//! light tenant's p99 latency and deadline-miss rate over the
//! non-preemptive scheduler — at zero cost to the heavy tenant here,
//! because the heavy layer's width demand (M = 64) fits the half it
//! keeps after the reshape.
//!
//! The scenario: one heavy tenant (2 × fc [4000, 1024] × [1024, 64] —
//! 8 K-bands of 4319 cycles per layer on the 128×128 array) arrives at
//! t = 0 and takes the whole array; six light requests (fc [256, 128] ×
//! [128, 32], 543 isolated cycles) burst in at t = 3000..3500, mid-band
//! of the heavy tenant's first layer.  Deadlines are slack-relative at
//! 6× isolated latency (3258 cycles for a light request).
//!
//! Every number asserted here is derived from the closed-form timing
//! model by hand (and cross-checked by an independent reference
//! simulation of Algorithm 1 + the preemption rules).

use mtsa::coordinator::scenario::{Scenario, ScenarioSpec};
use mtsa::coordinator::scheduler::{DynamicScheduler, PreemptMode, SchedulerConfig};
use mtsa::workloads::dnng::{Dnn, Layer};
use mtsa::workloads::generator::ArrivalProcess;
use mtsa::workloads::shapes::{LayerKind, LayerShape};

fn fc_chain(name: &str, sr: u64, k: u64, m: u64, n_layers: usize) -> Dnn {
    let layers = (0..n_layers)
        .map(|i| Layer::new(&format!("l{i}"), LayerKind::Fc, LayerShape::fc(sr, k, m)))
        .collect();
    Dnn::chain(name, layers)
}

/// One heavy template plus six light templates: `requests = 7` with a
/// fixed trace round-robins each template exactly once, so the scenario
/// is one heavy request at t = 0 and a light burst at 3000..3500.
fn bursty_scenario(cfg: &SchedulerConfig) -> Scenario {
    let mut templates = vec![fc_chain("heavy", 4000, 1024, 64, 2)];
    for _ in 0..6 {
        templates.push(fc_chain("light", 256, 128, 32, 1));
    }
    let spec = ScenarioSpec {
        name: "bursty-light-over-heavy".to_string(),
        arrival: ArrivalProcess::Trace(vec![0, 3000, 3100, 3200, 3300, 3400, 3500]),
        requests: 7,
        seed: 1,
        qos_slack: Some(6.0),
    };
    Scenario::generate(&templates, &spec, cfg)
}

#[test]
fn preemption_wins_p99_and_miss_rate_on_the_bursty_mix() {
    let base = SchedulerConfig::default();
    let scenario = bursty_scenario(&base);
    // The slack-relative deadlines come out of the isolated latencies:
    // a light request has 543 isolated cycles => 3258 of budget.
    for r in scenario.requests.iter().filter(|r| r.tenant == "light") {
        assert_eq!(r.isolated_cycles, 543);
        assert_eq!(r.deadline, Some(r.arrival + 3258));
    }

    let (off_obs, off) = scenario.run(
        &mut DynamicScheduler::new(base.clone()),
        base.geom,
    );
    let pre_cfg = SchedulerConfig { preempt: PreemptMode::Arrival, ..base.clone() };
    let (pre_obs, pre) = scenario.run(&mut DynamicScheduler::new(pre_cfg.clone()), base.geom);

    let light = |o: &mtsa::coordinator::scenario::ScenarioOutcome| {
        o.tenants.iter().find(|t| t.tenant == "light").unwrap().clone()
    };
    let (l_off, l_pre) = (light(&off), light(&pre));

    // Head-of-line blocking without preemption: every light request
    // waits out the heavy tenant's whole first layer (34552 cycles) and
    // misses its deadline.
    assert_eq!(l_off.misses, 6, "all six light requests miss without preemption");
    assert!(l_off.p99_latency > 32_000.0, "p99 {:.0}", l_off.p99_latency);
    assert_eq!(off_obs.metrics.preemptions, 0);

    // With `preempt = arrival`: exactly one drain-and-reshape at the
    // heavy layer's first band boundary (cycle 4319); the heavy tenant
    // keeps 64 columns — all its M = 64 demand needs — and the burst
    // runs in the freed half.
    assert_eq!(pre_obs.metrics.preemptions, 1);
    assert_eq!(pre_obs.metrics.replayed_folds, 0, "band boundary: nothing replayed");
    assert_eq!(pre_obs.metrics.wasted_refill_cycles, 0);
    assert_eq!(l_pre.misses, 0, "every light request meets its deadline");
    assert!(
        l_pre.p99_latency < 3_000.0,
        "p99 {:.0} must collapse to burst-service latency",
        l_pre.p99_latency
    );
    assert!(
        l_pre.p99_latency * 10.0 < l_off.p99_latency,
        "pinned win: >10x p99 improvement ({:.0} vs {:.0})",
        l_pre.p99_latency,
        l_off.p99_latency
    );
    assert!(pre.miss_rate() < off.miss_rate());

    // The reshape is free for the heavy tenant on this mix: its layer-0
    // remainder runs the same 7 bands it had left, at the same per-band
    // cost, so both runs finish the heavy request at the same cycle —
    // and the makespan is identical.
    assert_eq!(
        pre_obs.metrics.completion["heavy#0"],
        off_obs.metrics.completion["heavy#0"]
    );
    assert_eq!(pre_obs.metrics.makespan, off_obs.metrics.makespan);

    // Exactly one extra (segment) record, visible as the 128 -> 64
    // reshape in the heavy tenant's partition trace.
    assert_eq!(
        pre_obs.metrics.dispatches.len(),
        off_obs.metrics.dispatches.len() + 1
    );
    assert_eq!(pre_obs.metrics.partition_trace("heavy#0")[..2], [128, 64]);

    // Deterministic: the preempting run reproduces itself bit for bit.
    let (again, _) = scenario.run(&mut DynamicScheduler::new(pre_cfg), base.geom);
    assert_eq!(again.metrics.dispatches, pre_obs.metrics.dispatches);
    assert_eq!(again.deadline_events, pre_obs.deadline_events);
}

#[test]
fn deadline_mode_also_wins_on_the_bursty_mix() {
    // `deadline` mode subsumes the arrival trigger, so the same scenario
    // improves at least as much; with no missed-deadline evictions in
    // play the outcome matches `arrival` exactly.
    let base = SchedulerConfig::default();
    let scenario = bursty_scenario(&base);
    let run = |preempt: PreemptMode| {
        let cfg = SchedulerConfig { preempt, ..base.clone() };
        scenario.run(&mut DynamicScheduler::new(cfg), base.geom)
    };
    let (ar_obs, ar) = run(PreemptMode::Arrival);
    let (dl_obs, dl) = run(PreemptMode::Deadline);
    assert_eq!(ar_obs.metrics.dispatches, dl_obs.metrics.dispatches);
    assert_eq!(ar.overall, dl.overall);
    assert_eq!(dl_obs.metrics.preemptions, 1);
}
