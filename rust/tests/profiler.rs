//! Offline fission profiler invariants, integration level: the
//! table-driven plan never projects worse than the online pow-2 ladder,
//! table bytes are thread-count invariant, and stale artifacts are
//! rejected by name.

use mtsa::profiler::{build_tables, write_artifacts, ProfileStore, ProfileTable};
use mtsa::sim::buffers::BufferConfig;
use mtsa::sim::dataflow::ArrayGeometry;
use mtsa::sim::partitioned::{tile_layer_timing, FeedPolicy, Tile};
use mtsa::util::prop;
use mtsa::workloads::dnng::{Dnn, Layer};
use mtsa::workloads::shapes::{GemmDims, LayerKind, LayerShape};

/// The best plan key (mirrors `plan_2d`: cycles, then fewest PEs) over a
/// set of tile shapes at the origin of a full free array.
fn best_over(
    geom: ArrayGeometry,
    gemm: GemmDims,
    bufs: &BufferConfig,
    shapes: impl Iterator<Item = (u64, u64)>,
) -> Option<(u64, u64)> {
    shapes
        .filter(|&(h, w)| h >= 1 && w >= 1 && h <= geom.rows && w <= geom.cols)
        .map(|(h, w)| {
            let tile = Tile::new(0, 0, h, w);
            let t = tile_layer_timing(geom, gemm, tile, FeedPolicy::Independent, bufs);
            (t.cycles, tile.pes())
        })
        .min()
}

/// The scheduler's online candidate set: pow-2 heights × pow-2 widths
/// (plus the full extents), what `plan_2d` tries without tables.
fn ladder(geom: ArrayGeometry) -> Vec<(u64, u64)> {
    let mut hs: Vec<u64> = (0..)
        .map(|i| 1u64 << i)
        .take_while(|&h| h <= geom.rows)
        .collect();
    hs.push(geom.rows);
    let mut ws: Vec<u64> = (0..)
        .map(|i| 1u64 << i)
        .take_while(|&w| w <= geom.cols)
        .collect();
    ws.push(geom.cols);
    hs.iter().flat_map(|&h| ws.iter().map(move |&w| (h, w))).collect()
}

/// Unioning the profiled candidates with the ladder can only improve the
/// projected per-layer completion — for random layers and geometries.
#[test]
fn table_candidates_never_worsen_the_projected_plan() {
    let bufs = BufferConfig::default();
    prop::check("tables vs ladder projection", 24, |rng| {
        let geom = ArrayGeometry::new(
            rng.gen_range_inclusive(16, 160),
            rng.gen_range_inclusive(16, 160),
        );
        let layers: Vec<Layer> = (0..rng.gen_range_inclusive(1, 3))
            .map(|i| {
                let shape = LayerShape::fc(
                    rng.gen_range_inclusive(64, 4_000),
                    rng.gen_range_inclusive(16, 2_048),
                    rng.gen_range_inclusive(16, 1_024),
                );
                Layer::new(&format!("l{i}"), LayerKind::Fc, shape)
            })
            .collect();
        let dnn = Dnn::chain("rand", layers);
        let table = ProfileTable::build("rand", &dnn, geom, &bufs);
        let store = ProfileStore::from_tables("<memory>", vec![table]);
        let (mut with_tables, mut ladder_only) = (0u64, 0u64);
        for l in &dnn.layers {
            let gemm = l.shape.gemm();
            let base = best_over(geom, gemm, &bufs, ladder(geom).into_iter())
                .expect("ladder is never empty");
            let shapes = ladder(geom).into_iter().chain(
                store.candidates(geom, gemm.k, gemm.m).iter().map(|c| (c.rows, c.cols)),
            );
            let union = best_over(geom, gemm, &bufs, shapes).expect("union is never empty");
            prop::ensure(
                union.0 <= base.0,
                &format!(
                    "union best {} > ladder best {} for {:?} on {}x{}",
                    union.0, base.0, gemm, geom.rows, geom.cols
                ),
            )?;
            with_tables += union.0;
            ladder_only += base.0;
        }
        prop::ensure(
            with_tables <= ladder_only,
            "projected completion with tables exceeds the ladder plan",
        )?;
        Ok(())
    });
}

/// `mtsa profile` output is a pure function of (models, geometries):
/// byte-identical JSON and CSV at any worker-thread count.
#[test]
fn table_bytes_are_thread_count_invariant() {
    let bufs = BufferConfig::default();
    let jobs: Vec<(String, ArrayGeometry)> = vec![
        ("NCF".into(), ArrayGeometry::new(128, 128)),
        ("NCF".into(), ArrayGeometry::new(96, 64)),
        ("MelodyLSTM".into(), ArrayGeometry::new(128, 128)),
        ("AlexNet".into(), ArrayGeometry::new(128, 128)),
    ];
    let base = build_tables(&jobs, &bufs, 1).unwrap();
    for threads in [2usize, 8] {
        let other = build_tables(&jobs, &bufs, threads).unwrap();
        assert_eq!(base.len(), other.len());
        for (a, b) in base.iter().zip(&other) {
            assert_eq!(
                a.to_json().render(),
                b.to_json().render(),
                "{} at {threads} threads",
                a.stem()
            );
            assert_eq!(a.report_csv(&bufs), b.report_csv(&bufs), "{}", a.stem());
        }
    }
}

/// A persisted table whose model has since changed (here: a tampered
/// hash standing in for a zoo edit) is rejected at load, naming the
/// model so the fix — re-running `mtsa profile` — is obvious.
#[test]
fn stale_tables_are_rejected_naming_the_model() {
    let dir = std::env::temp_dir().join(format!("mtsa-stale-int-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let bufs = BufferConfig::default();
    let jobs = vec![("NCF".to_string(), ArrayGeometry::new(128, 128))];
    let tables = build_tables(&jobs, &bufs, 1).unwrap();
    write_artifacts(&tables[0], &bufs, &dir).unwrap();
    assert!(ProfileStore::load(&dir).is_ok(), "fresh artifacts load cleanly");
    let path = dir.join("ncf_128x128.table.json");
    let tampered = std::fs::read_to_string(&path)
        .unwrap()
        .replace(&format!("\"hash\":\"{}\"", tables[0].hash), "\"hash\":\"deadbeefdeadbeef\"");
    std::fs::write(&path, tampered).unwrap();
    let err = ProfileStore::load(&dir).unwrap_err();
    assert!(err.contains("stale profile table"), "{err}");
    assert!(err.contains("NCF"), "names the model: {err}");
    assert!(err.contains("mtsa profile"), "says how to fix it: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}
