//! Integration: the paper's experiments hold their qualitative shape on
//! the actual zoo pools (the assertions behind EXPERIMENTS.md).

use mtsa::coordinator::scheduler::{AllocPolicy, SchedulerConfig};
use mtsa::coordinator::static_part::StaticPartitioning;
use mtsa::energy::EnergyModel;
use mtsa::report;
use mtsa::workloads::models::{heavy_pool, light_pool};

fn cfg() -> SchedulerConfig {
    SchedulerConfig::default()
}

#[test]
fn heavy_pool_dynamic_beats_sequential_makespan() {
    let g = report::run_group(&heavy_pool(), &cfg());
    assert!(
        g.dynamic.makespan < g.sequential.makespan,
        "dynamic {} !< sequential {}",
        g.dynamic.makespan,
        g.sequential.makespan
    );
    // And by a meaningful margin (paper direction; see EXPERIMENTS.md for
    // the magnitude discussion).
    let saving = report::saving_pct(g.sequential.makespan as f64, g.dynamic.makespan as f64);
    assert!(saving > 5.0, "heavy-pool makespan saving only {saving:.1}%");
}

#[test]
fn light_pool_dynamic_never_loses_makespan() {
    let g = report::run_group(&light_pool(), &cfg());
    assert!(g.dynamic.makespan <= g.sequential.makespan);
}

#[test]
fn equal_share_slashes_small_dnn_completion_times() {
    // The Fig. 9(a) shape: under the paper-literal policy, small DNNs
    // finish far earlier than in the sequential queue.
    let g = report::run_group_with_policy(&heavy_pool(), &cfg(), AllocPolicy::EqualShare);
    for small in ["NCF", "SA_CNN", "SA_LSTM"] {
        let seq = g.sequential.completion[small];
        let dynd = g.dynamic.completion[small];
        assert!(
            (dynd as f64) < 0.5 * seq as f64,
            "{small}: dynamic {dynd} not << sequential {seq}"
        );
    }
}

#[test]
fn fig9c_partition_ladder_shape() {
    // Widths land on the {16,32,64,128} ladder; narrow nets stay narrow;
    // stragglers' final layers claim merged wide partitions.
    let g = report::run_group_with_policy(&heavy_pool(), &cfg(), AllocPolicy::EqualShare);
    let ladder = [16u64, 32, 64, 128];
    for d in &g.dynamic.dispatches {
        assert!(ladder.contains(&d.tile.cols), "width {} off-ladder", d.tile.cols);
    }
    // NCF's narrow layers (M <= 128, mostly <= 64) never need the full array.
    assert!(g.dynamic.partition_widths("NCF").iter().all(|&w| w <= 64));
    // The last-finishing DNN's final layer runs on a merged wide partition.
    let (last_dnn, _) = g.dynamic.completion.iter().max_by_key(|(_, t)| **t).unwrap();
    let final_width = *g.dynamic.partition_trace(last_dnn).last().unwrap();
    assert!(final_width >= 64, "{last_dnn} final layer width {final_width}");
}

#[test]
fn fig9d_light_pool_shape() {
    let g = report::run_group_with_policy(&light_pool(), &cfg(), AllocPolicy::EqualShare);
    // All four RNNs complete; GoogleTranslate (the heavyweight) finishes last.
    let (last, _) = g.dynamic.completion.iter().max_by_key(|(_, t)| **t).unwrap();
    assert_eq!(last, "GoogleTranslate");
    // The small RNNs complete much earlier than the sequential queue.
    assert!(
        g.dynamic.completion["HandwritingLSTM"] < g.sequential.completion["HandwritingLSTM"]
    );
}

#[test]
fn fig9e_energy_bars_favor_partitioning() {
    // Per-DNN static-attribution bars (the paper's accounting): the mean
    // bar must improve under partitioning for the heavy pool with the
    // demand-aware policy.  (Under the paper-literal equal-share policy
    // the extra per-fold IFMap re-reads of narrow partitions outweigh the
    // static savings in our traffic-faithful model — quantified in
    // EXPERIMENTS.md §Gaps.)
    let model = EnergyModel::default_128();
    let g = report::run_group_with_policy(&heavy_pool(), &cfg(), AllocPolicy::WidestToHeaviest);
    let bars_seq = report::per_dnn_energy_bars(&g.sequential, &model);
    let bars_dyn = report::per_dnn_energy_bars(&g.dynamic, &model);
    let mean_seq: f64 = bars_seq.values().sum::<f64>() / bars_seq.len() as f64;
    let mean_dyn: f64 = bars_dyn.values().sum::<f64>() / bars_dyn.len() as f64;
    assert!(
        mean_dyn < mean_seq,
        "mean bar: dynamic {mean_dyn} !< sequential {mean_seq}"
    );
}

#[test]
fn total_energy_tracks_makespan_direction() {
    // With the widest policy (which wins makespan on the heavy pool), the
    // total-energy comparison must not regress by more than the extra
    // SRAM re-reads can explain (< 10%).
    let model = EnergyModel::default_128();
    let g = report::run_group(&heavy_pool(), &cfg());
    let es = report::total_energy(&g.sequential, &model).total_j();
    let ed = report::total_energy(&g.dynamic, &model).total_j();
    assert!(ed < es * 1.10, "dynamic energy {ed} vs sequential {es}");
}

#[test]
fn dynamic_beats_static_partitioning_on_both_pools() {
    // A1: merging + demand-aware assignment must beat a naive fixed split.
    for pool in [heavy_pool(), light_pool()] {
        let stat = StaticPartitioning::new(cfg()).run(&pool);
        let g = report::run_group(&pool, &cfg());
        assert!(
            g.dynamic.makespan < stat.makespan,
            "{}: dynamic {} !< static {}",
            pool.name,
            g.dynamic.makespan,
            stat.makespan
        );
    }
}

#[test]
fn utilization_improves_under_partitioning() {
    let g = report::run_group(&heavy_pool(), &cfg());
    assert!(g.dynamic.utilization(cfg().geom) > g.sequential.utilization(cfg().geom));
}

#[test]
fn dispatch_log_complete_and_consistent() {
    for pool in [heavy_pool(), light_pool()] {
        let g = report::run_group(&pool, &cfg());
        assert_eq!(g.dynamic.dispatches.len(), pool.total_layers());
        assert_eq!(g.sequential.dispatches.len(), pool.total_layers());
        // Activity totals are scheduler-invariant except for fold-count
        // dependent SRAM/DRAM terms; MACs must match exactly.
        assert_eq!(
            g.dynamic.total_activity.macs, g.sequential.total_activity.macs,
            "{}: MACs differ between schedulers",
            pool.name
        );
        assert_eq!(g.dynamic.total_activity.macs, pool.total_macs());
    }
}
