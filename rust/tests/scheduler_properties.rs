//! Property tests over the dynamic scheduler: structural invariants that
//! must hold for ANY workload (random pools, random arrivals, generated
//! arrival traces).

use std::collections::BTreeMap;

use mtsa::coordinator::baseline::SequentialBaseline;
use mtsa::coordinator::scheduler::{AllocPolicy, DynamicScheduler, FeedModel, SchedulerConfig};
use mtsa::report;
use mtsa::util::prop;
use mtsa::workloads::generator::{random_pool, ArrivalProcess, GeneratorCfg};

fn random_cfg(rng: &mut mtsa::util::rng::Rng) -> SchedulerConfig {
    SchedulerConfig {
        min_width: *rng.choose(&[8u64, 16, 32]),
        alloc_policy: *rng.choose(&[AllocPolicy::WidestToHeaviest, AllocPolicy::EqualShare]),
        feed_model: *rng.choose(&[FeedModel::Independent, FeedModel::Interleaved]),
        patience_divisor: rng.gen_range_inclusive(1, 8),
        ..SchedulerConfig::default()
    }
}

fn random_gen_cfg(rng: &mut mtsa::util::rng::Rng) -> GeneratorCfg {
    GeneratorCfg {
        num_dnns: rng.gen_range_inclusive(1, 8) as usize,
        layers_min: 1,
        layers_max: 10,
        mean_interarrival: if rng.gen_bool(0.5) { 20_000.0 } else { 0.0 },
        dim_scale: 0.3 + rng.gen_f64(),
    }
}

#[test]
fn every_layer_dispatched_exactly_once() {
    prop::check("completeness", 40, |rng| {
        let gcfg = random_gen_cfg(rng);
        let pool = random_pool(rng, &gcfg);
        let m = DynamicScheduler::new(random_cfg(rng)).run(&pool);
        prop::ensure_eq(m.dispatches.len(), pool.total_layers(), "dispatch count")?;
        let mut seen = BTreeMap::new();
        for d in &m.dispatches {
            *seen.entry((d.dnn, d.layer)).or_insert(0) += 1;
        }
        prop::ensure(seen.values().all(|&c| c == 1), "no duplicate dispatch")
    });
}

#[test]
fn no_spatial_overlap_at_any_time() {
    // Two concurrently-running layers must occupy disjoint column ranges.
    prop::check("spatial isolation", 30, |rng| {
        let gcfg = random_gen_cfg(rng);
        let pool = random_pool(rng, &gcfg);
        let m = DynamicScheduler::new(random_cfg(rng)).run(&pool);
        for (i, a) in m.dispatches.iter().enumerate() {
            for b in &m.dispatches[i + 1..] {
                let time_overlap = a.t_start < b.t_end && b.t_start < a.t_end;
                if time_overlap {
                    let cols_overlap =
                        a.slice.col0 < b.slice.end() && b.slice.col0 < a.slice.end();
                    prop::ensure(
                        !cols_overlap,
                        &format!(
                            "{}/{} and {}/{} overlap in time AND columns",
                            a.dnn_name, a.layer_name, b.dnn_name, b.layer_name
                        ),
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn chain_dependencies_respected() {
    prop::check("precedence", 30, |rng| {
        let gcfg = random_gen_cfg(rng);
        let pool = random_pool(rng, &gcfg);
        let m = DynamicScheduler::new(random_cfg(rng)).run(&pool);
        let mut end_of: BTreeMap<(usize, usize), u64> = BTreeMap::new();
        for d in &m.dispatches {
            end_of.insert((d.dnn, d.layer), d.t_end);
        }
        for d in &m.dispatches {
            for pred in pool.dnns[d.dnn].preds(d.layer) {
                prop::ensure(
                    end_of[&(d.dnn, pred)] <= d.t_start,
                    &format!("{}#{} started before predecessor {} ended", d.dnn, d.layer, pred),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn arrivals_and_width_bounds_respected() {
    prop::check("arrival+width bounds", 30, |rng| {
        let gcfg = random_gen_cfg(rng);
        let pool = random_pool(rng, &gcfg);
        let cfg = random_cfg(rng);
        let m = DynamicScheduler::new(cfg.clone()).run(&pool);
        for d in &m.dispatches {
            prop::ensure(
                d.t_start >= pool.dnns[d.dnn].arrival_cycles,
                "dispatch before arrival",
            )?;
            prop::ensure(d.slice.width >= cfg.min_width, "below min width")?;
            prop::ensure(d.slice.end() <= cfg.geom.cols, "slice beyond array")?;
            prop::ensure(d.t_end > d.t_start, "zero-duration dispatch")?;
        }
        Ok(())
    });
}

#[test]
fn makespan_at_least_critical_path() {
    // Makespan can never beat the longest chain run at full width.
    prop::check("critical-path lower bound", 20, |rng| {
        let gcfg = random_gen_cfg(rng);
        let pool = random_pool(rng, &gcfg);
        let cfg = SchedulerConfig::default();
        let m = DynamicScheduler::new(cfg.clone()).run(&pool);
        for dnn in &pool.dnns {
            let full_width: u64 = dnn
                .layers
                .iter()
                .map(|l| {
                    mtsa::sim::dataflow::baseline_layer_timing(
                        cfg.geom,
                        l.shape.gemm(),
                        &cfg.buffers,
                    )
                    .cycles
                })
                .sum();
            prop::ensure(
                m.makespan >= dnn.arrival_cycles + full_width,
                &format!("makespan {} < critical path of {}", m.makespan, dnn.name),
            )?;
        }
        Ok(())
    });
}

#[test]
fn arrival_traces_keep_dynamic_competitive_with_sequential() {
    // On generated arrival traces (the scenario engine's regime), dynamic
    // partitioning must never do materially worse than the sequential
    // baseline: the makespan stays inside the same 1.25x envelope the
    // batch-arrival property enforces — spreading arrivals only reduces
    // contention — and so does the mean completion cycle.  (The strict
    // win under contention is asserted on the zoo pools in
    // paper_experiments.rs.)
    prop::check("arrival-trace dynamic vs sequential", 12, |rng| {
        let n = rng.gen_range_inclusive(2, 6) as usize;
        let mut t = 0u64;
        let mut trace = Vec::with_capacity(n);
        for _ in 0..n {
            trace.push(t);
            t += rng.gen_range(60_000);
        }
        let arrivals = ArrivalProcess::Trace(trace).sample(rng, n);

        let gcfg = GeneratorCfg {
            num_dnns: n,
            layers_min: 2,
            layers_max: 7,
            mean_interarrival: 0.0,
            dim_scale: 0.4 + 0.6 * rng.gen_f64(),
        };
        let mut pool = random_pool(rng, &gcfg);
        for (dnn, &at) in pool.dnns.iter_mut().zip(&arrivals) {
            dnn.arrival_cycles = at;
        }

        let cfg = SchedulerConfig::default();
        let dyn_m = DynamicScheduler::new(cfg.clone()).run(&pool);
        let seq_m = SequentialBaseline::new(cfg).run(&pool);
        prop::ensure(
            dyn_m.makespan as f64 <= 1.25 * seq_m.makespan as f64,
            &format!("makespan: dynamic {} > 1.25x sequential {}", dyn_m.makespan, seq_m.makespan),
        )?;
        prop::ensure(
            report::mean_completion(&dyn_m) <= 1.25 * report::mean_completion(&seq_m),
            &format!(
                "mean completion: dynamic {:.0} > 1.25x sequential {:.0}",
                report::mean_completion(&dyn_m),
                report::mean_completion(&seq_m)
            ),
        )?;
        // Every DNN still respects its trace arrival.
        for d in &dyn_m.dispatches {
            prop::ensure(
                d.t_start >= pool.dnns[d.dnn].arrival_cycles,
                "dispatch before trace arrival",
            )?;
        }
        Ok(())
    });
}

#[test]
fn metrics_are_internally_consistent() {
    prop::check("metrics consistency", 30, |rng| {
        let gcfg = random_gen_cfg(rng);
        let pool = random_pool(rng, &gcfg);
        let m = DynamicScheduler::new(random_cfg(rng)).run(&pool);
        let max_end = m.dispatches.iter().map(|d| d.t_end).max().unwrap_or(0);
        prop::ensure_eq(m.makespan, max_end, "makespan == max t_end")?;
        for dnn in &pool.dnns {
            let done = m.completion[&dnn.name];
            let starts: Vec<u64> = m
                .dispatches
                .iter()
                .filter(|d| d.dnn_name == dnn.name)
                .map(|d| d.t_start)
                .collect();
            prop::ensure_eq(m.start[&dnn.name], *starts.iter().min().unwrap(), "start")?;
            prop::ensure(
                done
                    == m.dispatches
                        .iter()
                        .filter(|d| d.dnn_name == dnn.name)
                        .map(|d| d.t_end)
                        .max()
                        .unwrap(),
                "completion == max t_end of dnn",
            )?;
        }
        Ok(())
    });
}
