//! Property tests over the dynamic scheduler and the shared memory
//! hierarchy: structural invariants that must hold for ANY workload
//! (random pools, random arrivals, generated arrival traces, random
//! contention schedules).

use std::collections::BTreeMap;

use mtsa::coordinator::baseline::SequentialBaseline;
use mtsa::coordinator::partition::{AllocId, PartitionManager};
use mtsa::coordinator::scheduler::{
    AllocPolicy, DynamicScheduler, FeedModel, PartitionMode, PreemptMode, SchedulerConfig,
};
use mtsa::mem::{ArbitrationMode, BandwidthArbiter, MemConfig, MemUpdate};
use mtsa::report;
use mtsa::sim::dataflow::ArrayGeometry;
use mtsa::sim::dram::DramConfig;
use mtsa::util::prop;
use mtsa::workloads::dnng::WorkloadPool;
use mtsa::workloads::generator::{random_pool, ArrivalProcess, GeneratorCfg};

fn random_cfg(rng: &mut mtsa::util::rng::Rng) -> SchedulerConfig {
    SchedulerConfig {
        min_width: *rng.choose(&[8u64, 16, 32]),
        min_rows: *rng.choose(&[8u64, 16, 32]),
        partition_mode: *rng.choose(&[PartitionMode::Columns, PartitionMode::TwoD]),
        alloc_policy: *rng.choose(&[AllocPolicy::WidestToHeaviest, AllocPolicy::EqualShare]),
        feed_model: *rng.choose(&[FeedModel::Independent, FeedModel::Interleaved]),
        patience_divisor: rng.gen_range_inclusive(1, 8),
        ..SchedulerConfig::default()
    }
}

fn random_gen_cfg(rng: &mut mtsa::util::rng::Rng) -> GeneratorCfg {
    GeneratorCfg {
        num_dnns: rng.gen_range_inclusive(1, 8) as usize,
        layers_min: 1,
        layers_max: 10,
        mean_interarrival: if rng.gen_bool(0.5) { 20_000.0 } else { 0.0 },
        dim_scale: 0.3 + rng.gen_f64(),
    }
}

#[test]
fn every_layer_dispatched_exactly_once() {
    prop::check("completeness", 40, |rng| {
        let gcfg = random_gen_cfg(rng);
        let pool = random_pool(rng, &gcfg);
        let m = DynamicScheduler::new(random_cfg(rng)).run(&pool);
        prop::ensure_eq(m.dispatches.len(), pool.total_layers(), "dispatch count")?;
        let mut seen = BTreeMap::new();
        for d in &m.dispatches {
            *seen.entry((d.dnn, d.layer)).or_insert(0) += 1;
        }
        prop::ensure(seen.values().all(|&c| c == 1), "no duplicate dispatch")
    });
}

#[test]
fn no_spatial_overlap_at_any_time() {
    // Two concurrently-running layers must occupy disjoint PE rectangles
    // (disjoint columns in columns mode; 2D mode may instead separate
    // them by row band).
    prop::check("spatial isolation", 30, |rng| {
        let gcfg = random_gen_cfg(rng);
        let pool = random_pool(rng, &gcfg);
        let m = DynamicScheduler::new(random_cfg(rng)).run(&pool);
        for (i, a) in m.dispatches.iter().enumerate() {
            for b in &m.dispatches[i + 1..] {
                let time_overlap = a.t_start < b.t_end && b.t_start < a.t_end;
                if time_overlap {
                    prop::ensure(
                        !a.tile.overlaps(&b.tile),
                        &format!(
                            "{}/{} and {}/{} overlap in time AND PEs",
                            a.dnn_name, a.layer_name, b.dnn_name, b.layer_name
                        ),
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn chain_dependencies_respected() {
    prop::check("precedence", 30, |rng| {
        let gcfg = random_gen_cfg(rng);
        let pool = random_pool(rng, &gcfg);
        let m = DynamicScheduler::new(random_cfg(rng)).run(&pool);
        let mut end_of: BTreeMap<(usize, usize), u64> = BTreeMap::new();
        for d in &m.dispatches {
            end_of.insert((d.dnn, d.layer), d.t_end);
        }
        for d in &m.dispatches {
            for pred in pool.dnns[d.dnn].preds(d.layer) {
                prop::ensure(
                    end_of[&(d.dnn, pred)] <= d.t_start,
                    &format!("{}#{} started before predecessor {} ended", d.dnn, d.layer, pred),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn arrivals_and_width_bounds_respected() {
    prop::check("arrival+width bounds", 30, |rng| {
        let gcfg = random_gen_cfg(rng);
        let pool = random_pool(rng, &gcfg);
        let cfg = random_cfg(rng);
        let m = DynamicScheduler::new(cfg.clone()).run(&pool);
        for d in &m.dispatches {
            prop::ensure(
                d.t_start >= pool.dnns[d.dnn].arrival_cycles,
                "dispatch before arrival",
            )?;
            prop::ensure(d.tile.cols >= cfg.min_width, "below min width")?;
            prop::ensure(d.tile.col_end() <= cfg.geom.cols, "tile beyond array cols")?;
            prop::ensure(d.tile.row_end() <= cfg.geom.rows, "tile beyond array rows")?;
            if cfg.partition_mode == PartitionMode::Columns {
                prop::ensure(
                    d.tile.row0 == 0 && d.tile.rows == cfg.geom.rows,
                    "columns mode must stay full height",
                )?;
            } else {
                prop::ensure(d.tile.rows >= cfg.min_rows, "below min rows")?;
            }
            prop::ensure(d.t_end > d.t_start, "zero-duration dispatch")?;
        }
        Ok(())
    });
}

#[test]
fn makespan_at_least_critical_path() {
    // Makespan can never beat the longest chain run at full width.
    prop::check("critical-path lower bound", 20, |rng| {
        let gcfg = random_gen_cfg(rng);
        let pool = random_pool(rng, &gcfg);
        let cfg = SchedulerConfig::default();
        let m = DynamicScheduler::new(cfg.clone()).run(&pool);
        for dnn in &pool.dnns {
            let full_width: u64 = dnn
                .layers
                .iter()
                .map(|l| {
                    mtsa::sim::dataflow::baseline_layer_timing(
                        cfg.geom,
                        l.shape.gemm(),
                        &cfg.buffers,
                    )
                    .cycles
                })
                .sum();
            prop::ensure(
                m.makespan >= dnn.arrival_cycles + full_width,
                &format!("makespan {} < critical path of {}", m.makespan, dnn.name),
            )?;
        }
        Ok(())
    });
}

#[test]
fn arrival_traces_keep_dynamic_competitive_with_sequential() {
    // On generated arrival traces (the scenario engine's regime), dynamic
    // partitioning must never do materially worse than the sequential
    // baseline: the makespan stays inside the same 1.25x envelope the
    // batch-arrival property enforces — spreading arrivals only reduces
    // contention — and so does the mean completion cycle.  (The strict
    // win under contention is asserted on the zoo pools in
    // paper_experiments.rs.)
    prop::check("arrival-trace dynamic vs sequential", 12, |rng| {
        let n = rng.gen_range_inclusive(2, 6) as usize;
        let mut t = 0u64;
        let mut trace = Vec::with_capacity(n);
        for _ in 0..n {
            trace.push(t);
            t += rng.gen_range(60_000);
        }
        let arrivals = ArrivalProcess::Trace(trace).sample(rng, n);

        let gcfg = GeneratorCfg {
            num_dnns: n,
            layers_min: 2,
            layers_max: 7,
            mean_interarrival: 0.0,
            dim_scale: 0.4 + 0.6 * rng.gen_f64(),
        };
        let mut pool = random_pool(rng, &gcfg);
        for (dnn, &at) in pool.dnns.iter_mut().zip(&arrivals) {
            dnn.arrival_cycles = at;
        }

        let cfg = SchedulerConfig::default();
        let dyn_m = DynamicScheduler::new(cfg.clone()).run(&pool);
        let seq_m = SequentialBaseline::new(cfg).run(&pool);
        prop::ensure(
            dyn_m.makespan as f64 <= 1.25 * seq_m.makespan as f64,
            &format!("makespan: dynamic {} > 1.25x sequential {}", dyn_m.makespan, seq_m.makespan),
        )?;
        prop::ensure(
            report::mean_completion(&dyn_m) <= 1.25 * report::mean_completion(&seq_m),
            &format!(
                "mean completion: dynamic {:.0} > 1.25x sequential {:.0}",
                report::mean_completion(&dyn_m),
                report::mean_completion(&seq_m)
            ),
        )?;
        // Every DNN still respects its trace arrival.
        for d in &dyn_m.dispatches {
            prop::ensure(
                d.t_start >= pool.dnns[d.dnn].arrival_cycles,
                "dispatch before trace arrival",
            )?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Shared memory hierarchy (rust/src/mem): arbiter + engine properties.
// ---------------------------------------------------------------------

fn mem_cfg(rng: &mut mtsa::util::rng::Rng) -> MemConfig {
    MemConfig {
        dram: DramConfig {
            words_per_cycle: *rng.choose(&[1.0, 4.0, 16.0, 64.0]),
            burst_latency: *rng.choose(&[0u64, 20, 100]),
        },
        arbitration: *rng.choose(&ArbitrationMode::ALL),
        banks: *rng.choose(&[1u64, 4, 8, 32]),
    }
}

#[test]
fn sharing_never_beats_the_isolated_bound() {
    // Property (a): a tenant's completion under the shared hierarchy is
    // >= its completion running the same workload alone (full array, all
    // banks, whole interface) — contention can only slow you down.
    prop::check("shared completion >= isolated completion", 12, |rng| {
        let gcfg = GeneratorCfg {
            num_dnns: rng.gen_range_inclusive(2, 5) as usize,
            layers_min: 1,
            layers_max: 5,
            mean_interarrival: *rng.choose(&[0.0, 20_000.0]),
            dim_scale: 0.3 + 0.5 * rng.gen_f64(),
        };
        let pool = random_pool(rng, &gcfg);
        let cfg = SchedulerConfig { mem: Some(mem_cfg(rng)), ..Default::default() };
        let shared = DynamicScheduler::new(cfg.clone()).run(&pool);
        for dnn in &pool.dnns {
            let solo_pool = WorkloadPool::new("solo", vec![dnn.clone()]);
            let solo = DynamicScheduler::new(cfg.clone()).run(&solo_pool);
            prop::ensure(
                shared.completion[&dnn.name] >= solo.completion[&dnn.name],
                &format!(
                    "{}: shared {} < isolated {}",
                    dnn.name, shared.completion[&dnn.name], solo.completion[&dnn.name]
                ),
            )?;
        }
        Ok(())
    });
}

#[test]
fn arbiter_conserves_words_across_rescales() {
    // Property (b): however often the co-runner set changes (admissions,
    // retirements, early releases — each rescaling every in-flight
    // transfer), the words the arbiter delivers equal the words admitted.
    prop::check("arbiter word conservation", 25, |rng| {
        let dram = DramConfig {
            words_per_cycle: 0.5 + 10.0 * rng.gen_f64(),
            burst_latency: rng.gen_range(50),
        };
        let mode = *rng.choose(&ArbitrationMode::ALL);
        let mut arb = BandwidthArbiter::new(dram, mode);
        let n = rng.gen_range_inclusive(2, 8) as usize;
        let mut admitted_words = 0u64;

        // Engine-style event loop; kind: 0 = admit, 1 = complete, 2 =
        // rescale.  Admissions are events too, so arbiter time only moves
        // forward.
        let mut events: Vec<(u64, u8, usize)> = Vec::new();
        fn absorb(events: &mut Vec<(u64, u8, usize)>, upd: &MemUpdate) {
            for &(id, t) in &upd.reposts {
                events.push((t, 1, id));
            }
            if let Some(t) = upd.next_release {
                events.push((t, 2, 0));
            }
        }
        let mut flights: Vec<(u64, u64, u64, u64)> = Vec::new(); // (t, width, compute, words)
        let mut t_admit = 0u64;
        for _ in 0..n {
            t_admit += rng.gen_range(500);
            let words = rng.gen_range(20_000);
            let compute = 1 + rng.gen_range(10_000);
            admitted_words += words;
            flights.push((t_admit, *rng.choose(&[16u64, 32, 64, 128]), compute, words));
        }
        for (id, &(t, ..)) in flights.iter().enumerate() {
            events.push((t, 0, id));
        }
        let mut retired = 0usize;
        while !events.is_empty() {
            events.sort_unstable();
            let (t, kind, id) = events.remove(0);
            let upd = match kind {
                0 => {
                    let (_, width, compute, words) = flights[id];
                    arb.admit(t, id, id, width, compute, words)
                }
                1 => {
                    if arb.is_stale(id, t) {
                        continue;
                    }
                    let (rep, u) = arb.retire(t, id);
                    prop::ensure_eq(rep.t_end, t, "retire at the predicted cycle")?;
                    retired += 1;
                    u
                }
                _ => arb.rescale(t),
            };
            absorb(&mut events, &upd);
        }
        prop::ensure_eq(retired, n, "every flight retires")?;
        prop::ensure_eq(arb.in_flight(), 0, "arbiter drained")?;
        prop::ensure(
            (arb.consumed_words() - admitted_words as f64).abs() < 1e-6 * (1.0 + admitted_words as f64),
            &format!("conserved {} vs admitted {}", arb.consumed_words(), admitted_words),
        )
    });
}

#[test]
fn mem_aware_sweep_json_is_thread_count_invariant() {
    // Property (c): the determinism contract survives the contention
    // axis and the mem-aware policy — fixed seed => byte-identical JSON.
    let grid = mtsa::sweep::SweepGrid {
        mixes: vec!["light".into()],
        rates: vec![0.0, 30_000.0],
        policies: vec![AllocPolicy::MemAware],
        feeds: vec![FeedModel::Independent],
        geoms: vec![ArrayGeometry::new(128, 128)],
        requests: 4,
        bandwidths: vec![8.0, 64.0],
        arbitrations: vec![ArbitrationMode::FairShare, ArbitrationMode::WeightedByColumns],
        seed: 0xBEEF,
        ..Default::default()
    };
    let base = SchedulerConfig::default();
    let a = report::sweep_json(&grid, &mtsa::sweep::run_sweep(&grid, &base, 1).unwrap()).render();
    let b = report::sweep_json(&grid, &mtsa::sweep::run_sweep(&grid, &base, 4).unwrap()).render();
    let c = report::sweep_json(&grid, &mtsa::sweep::run_sweep(&grid, &base, 8).unwrap()).render();
    assert_eq!(a, b, "1 vs 4 workers changed the mem-aware report bytes");
    assert_eq!(a, c, "1 vs 8 workers changed the mem-aware report bytes");
    assert!(a.contains("\"mem\""), "contention points must carry mem stats");
}

#[test]
fn preemption_never_loses_work() {
    // Fold-boundary preemption invariants, for ANY workload and either
    // preempting mode, in both partition modes:
    //  - every layer still completes exactly once (the extra records are
    //    segments: dispatches - layers == preemptions);
    //  - work is conserved — each layer's MACs split exactly across its
    //    segments (completed K-bands) plus its final record (the
    //    remainder re-bills replayed folds, never double-billing MACs);
    //  - chain order holds across segments (layer i+1 starts after layer
    //    i's last segment ends) and no two time-overlapping records
    //    share PEs (reshape conserves spatial isolation);
    //  - the makespan still respects every DNN's critical path.
    prop::check("preemption work conservation", 25, |rng| {
        let gcfg = GeneratorCfg {
            num_dnns: rng.gen_range_inclusive(2, 6) as usize,
            layers_min: 1,
            layers_max: 6,
            mean_interarrival: *rng.choose(&[5_000.0, 20_000.0, 60_000.0]),
            dim_scale: 0.4 + rng.gen_f64() * 0.8,
        };
        let pool = random_pool(rng, &gcfg);
        let cfg = SchedulerConfig {
            preempt: *rng.choose(&[PreemptMode::Arrival, PreemptMode::Deadline]),
            partition_mode: *rng.choose(&[PartitionMode::Columns, PartitionMode::TwoD]),
            ..SchedulerConfig::default()
        };
        let m = DynamicScheduler::new(cfg).run(&pool);

        prop::ensure_eq(
            m.dispatches.len(),
            pool.total_layers() + m.preemptions as usize,
            "records == layers + preempted segments",
        )?;
        for (di, dnn) in pool.dnns.iter().enumerate() {
            prop::ensure_eq(
                m.completion.get(&dnn.name).is_some(),
                true,
                "every DNN completes",
            )?;
            let mut last_end = dnn.arrival_cycles;
            for (li, layer) in dnn.layers.iter().enumerate() {
                let recs: Vec<_> = m
                    .dispatches
                    .iter()
                    .filter(|d| d.dnn == di && d.layer == li)
                    .collect();
                prop::ensure(!recs.is_empty(), "layer has at least one record")?;
                let macs: u64 = recs.iter().map(|d| d.activity.macs).sum();
                prop::ensure_eq(macs, layer.shape.gemm().macs(), "MAC conservation")?;
                let start = recs.iter().map(|d| d.t_start).min().unwrap();
                let end = recs.iter().map(|d| d.t_end).max().unwrap();
                prop::ensure(start >= last_end, "chain order across segments")?;
                last_end = end;
            }
        }
        // Reshaped tiles still never share PEs with a co-running record.
        for (i, a) in m.dispatches.iter().enumerate() {
            for b in &m.dispatches[i + 1..] {
                if a.t_start < b.t_end && b.t_start < a.t_end {
                    prop::ensure(
                        !a.tile.overlaps(&b.tile),
                        &format!(
                            "{}/{} and {}/{} overlap in time AND PEs after a reshape",
                            a.dnn_name, a.layer_name, b.dnn_name, b.layer_name
                        ),
                    )?;
                }
            }
        }
        // Preemption adds overhead, never time travel.
        for dnn in &pool.dnns {
            let full_width: u64 = dnn
                .layers
                .iter()
                .map(|l| {
                    mtsa::sim::dataflow::baseline_layer_timing(
                        SchedulerConfig::default().geom,
                        l.shape.gemm(),
                        &SchedulerConfig::default().buffers,
                    )
                    .cycles
                })
                .sum();
            prop::ensure(
                m.makespan >= dnn.arrival_cycles + full_width,
                "critical-path lower bound survives preemption",
            )?;
        }
        Ok(())
    });
}

#[test]
fn metrics_are_internally_consistent() {
    prop::check("metrics consistency", 30, |rng| {
        let gcfg = random_gen_cfg(rng);
        let pool = random_pool(rng, &gcfg);
        let m = DynamicScheduler::new(random_cfg(rng)).run(&pool);
        let max_end = m.dispatches.iter().map(|d| d.t_end).max().unwrap_or(0);
        prop::ensure_eq(m.makespan, max_end, "makespan == max t_end")?;
        for dnn in &pool.dnns {
            let done = m.completion[&dnn.name];
            let starts: Vec<u64> = m
                .dispatches
                .iter()
                .filter(|d| d.dnn_name == dnn.name)
                .map(|d| d.t_start)
                .collect();
            prop::ensure_eq(m.start[&dnn.name], *starts.iter().min().unwrap(), "start")?;
            prop::ensure(
                done
                    == m.dispatches
                        .iter()
                        .filter(|d| d.dnn_name == dnn.name)
                        .map(|d| d.t_end)
                        .max()
                        .unwrap(),
                "completion == max t_end of dnn",
            )?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// 2D partition manager (rust/src/coordinator/partition.rs): the 1D
// random alloc/free property suite, ported to rectangular tiles.
// ---------------------------------------------------------------------

#[test]
fn partition_manager_2d_random_ops_preserve_invariants() {
    prop::check("2d partition manager invariants", 150, |rng| {
        let geom = ArrayGeometry::new(
            *rng.choose(&[16u64, 64, 128]),
            *rng.choose(&[16u64, 64, 128, 256]),
        );
        let mut pm = PartitionManager::new(geom);
        let mut live: Vec<AllocId> = Vec::new();
        for _ in 0..64 {
            if live.is_empty() || rng.gen_bool(0.55) {
                let h = rng.gen_range_inclusive(1, (geom.rows / 2).max(1));
                let w = rng.gen_range_inclusive(1, (geom.cols / 2).max(1));
                // Mix the two allocation paths: best-fit 2D and the
                // full-height columns carve.
                let got = if rng.gen_bool(0.7) {
                    pm.allocate_tile(h, w)
                } else {
                    pm.allocate(w)
                };
                if let Some((id, t)) = got {
                    prop::ensure_eq(t.cols, w, "allocated width")?;
                    live.push(id);
                }
            } else {
                let i = rng.gen_range(live.len() as u64) as usize;
                pm.free(live.swap_remove(i));
            }
            // Tiling, disjointness, canonical merge.
            pm.check_invariants()?;
            // PE-count conservation across every alloc/free interleaving.
            let alloc_pes: u64 = live.iter().map(|&id| pm.tile_of(id).unwrap().pes()).sum();
            prop::ensure_eq(alloc_pes + pm.free_pes(), geom.pes(), "PE conservation")?;
        }
        for id in live {
            pm.free(id);
            pm.check_invariants()?;
        }
        prop::ensure(pm.fully_free(), "all freed => fully free")
    });
}

#[test]
fn two_d_mode_executes_every_layer_once_like_columns() {
    // Whatever tile shapes the 2D planner picks, the engine contract is
    // unchanged: every layer exactly once, and the 2D makespan stays
    // within the same envelope vs the sequential baseline that the
    // columns-mode properties enforce.
    prop::check("2d engine contract", 10, |rng| {
        let gcfg = GeneratorCfg {
            num_dnns: rng.gen_range_inclusive(2, 6) as usize,
            layers_min: 1,
            layers_max: 6,
            mean_interarrival: *rng.choose(&[0.0, 20_000.0]),
            dim_scale: 0.4 + rng.gen_f64() * 0.8,
        };
        let pool = random_pool(rng, &gcfg);
        let cfg = SchedulerConfig {
            partition_mode: PartitionMode::TwoD,
            ..SchedulerConfig::default()
        };
        let m = DynamicScheduler::new(cfg).run(&pool);
        prop::ensure_eq(m.dispatches.len(), pool.total_layers(), "dispatch count")?;
        let seq = SequentialBaseline::new(SchedulerConfig::default()).run(&pool);
        // Slightly looser envelope than the columns property: 2D tiles
        // additionally trade K-fold count and row skew, so individual
        // placements can be marginally worse while the mix still wins.
        prop::ensure(
            m.makespan as f64 <= 1.35 * seq.makespan as f64,
            &format!("2d makespan {} > 1.35x sequential {}", m.makespan, seq.makespan),
        )
    });
}

#[test]
fn timing_cache_is_transparent() {
    // The memoized timing model (PR 6 hot-path attack #1) must be
    // observationally identical to the uncached computation for every
    // (geometry, gemm, tile, buffer share, interleave) key — both on the
    // first call (miss path) and on an immediate repeat (hit path).
    use mtsa::sim::buffers::BufferConfig;
    use mtsa::sim::dataflow::{
        layer_timing_tile_with_share, layer_timing_tile_with_share_uncached, timing_cache_enabled,
    };
    use mtsa::sim::partitioned::Tile;
    use mtsa::workloads::shapes::GemmDims;

    assert!(
        timing_cache_enabled(),
        "run this test without MTSA_NO_TIMING_CACHE: it exercises the memo"
    );
    prop::check("timing memo == uncached", 300, |rng| {
        let geom = ArrayGeometry::new(
            *rng.choose(&[16u64, 32, 64, 128]),
            *rng.choose(&[16u64, 32, 64, 128, 256]),
        );
        let rows = rng.gen_range_inclusive(1, geom.rows);
        let cols = rng.gen_range_inclusive(1, geom.cols);
        let tile = Tile::new(
            rng.gen_range_inclusive(0, geom.rows - rows),
            rng.gen_range_inclusive(0, geom.cols - cols),
            rows,
            cols,
        );
        let gemm = GemmDims {
            sr: rng.gen_range_inclusive(1, 4096),
            k: rng.gen_range_inclusive(1, 2048),
            m: rng.gen_range_inclusive(1, 2048),
        };
        // Mostly realistic shares (what the scheduler hands out), plus
        // the occasional full-array config to vary the key's buffer arm.
        let share = if rng.gen_bool(0.8) {
            BufferConfig::default().share(tile.cols.max(1), geom.cols)
        } else {
            BufferConfig::default()
        };
        let interleave = if rng.gen_bool(0.5) {
            let parties = rng.gen_range_inclusive(1, 4);
            Some((parties, rng.gen_range_inclusive(0, parties - 1)))
        } else {
            None
        };
        let miss = layer_timing_tile_with_share(geom, gemm, tile, &share, interleave);
        let hit = layer_timing_tile_with_share(geom, gemm, tile, &share, interleave);
        let raw = layer_timing_tile_with_share_uncached(geom, gemm, tile, &share, interleave);
        prop::ensure_eq(miss, raw, "memoized (miss path) == uncached")?;
        prop::ensure_eq(hit, raw, "memoized (hit path) == uncached")
    });
}

#[test]
fn plan_cache_is_transparent() {
    // The epoch-tagged plan memo and the dispatch arenas (the PR 9
    // planner campaign) must be observationally invisible: cache+arena on
    // must equal both off for random configs — across partition modes,
    // preempting modes, and table-driven candidate pricing.
    use std::sync::Arc;

    use mtsa::profiler::{ProfileStore, ProfileTable};

    prop::check("plan cache/arena on == off", 12, |rng| {
        let gcfg = GeneratorCfg {
            num_dnns: rng.gen_range_inclusive(2, 6) as usize,
            layers_min: 1,
            layers_max: 6,
            mean_interarrival: *rng.choose(&[0.0, 20_000.0]),
            dim_scale: 0.4 + rng.gen_f64() * 0.8,
        };
        let pool = random_pool(rng, &gcfg);
        let mut cfg = random_cfg(rng);
        cfg.preempt =
            *rng.choose(&[PreemptMode::Off, PreemptMode::Arrival, PreemptMode::Deadline]);
        if rng.gen_bool(0.5) {
            let tables: Vec<ProfileTable> = pool
                .dnns
                .iter()
                .map(|d| ProfileTable::build(&d.name, d, cfg.geom, &cfg.buffers))
                .collect();
            cfg.tables = Some(Arc::new(ProfileStore::from_tables("<prop>", tables)));
        }
        let base = DynamicScheduler::new(cfg.clone())
            .with_plan_cache(false)
            .with_plan_arena(false)
            .run(&pool);
        let tuned = DynamicScheduler::new(cfg.clone())
            .with_plan_cache(true)
            .with_plan_arena(true)
            .run(&pool);
        let cache_only = DynamicScheduler::new(cfg)
            .with_plan_cache(true)
            .with_plan_arena(false)
            .run(&pool);
        prop::ensure_eq(base.makespan, tuned.makespan, "makespan (cache+arena)")?;
        prop::ensure_eq(base.makespan, cache_only.makespan, "makespan (cache only)")?;
        prop::ensure_eq(base.dispatches.len(), tuned.dispatches.len(), "record count")?;
        prop::ensure(base.dispatches == tuned.dispatches, "dispatch stream (cache+arena)")?;
        prop::ensure(base.dispatches == cache_only.dispatches, "dispatch stream (cache only)")
    });
}

#[test]
fn coalescing_preserves_fifo() {
    // The engine's same-cycle batch drain rides on pop_batch_into: for
    // BOTH queue backends, the batch must replay the exact sequence an
    // un-coalesced pop loop would produce at that cycle — including the
    // FIFO order of key-equal events.
    use mtsa::sim_core::queue::{BucketQueue, HeapQueue};
    use mtsa::sim_core::Event;

    prop::check("batched drain == un-coalesced pop order", 100, |rng| {
        // Few distinct cycles => dense same-cycle collisions.
        let n = rng.gen_range_inclusive(1, 48) as usize;
        let mut events = Vec::with_capacity(n);
        for _ in 0..n {
            let t = rng.gen_range(4);
            let dnn = rng.gen_range(3) as usize;
            let layer = rng.gen_range(3) as usize;
            events.push(match rng.gen_range(4) {
                0 => Event::Arrival { t, dnn },
                1 => Event::LayerComplete { t, dnn, layer, alloc: 0 },
                2 => Event::Preempt { t, dnn, layer, alloc: 0 },
                _ => Event::Deadline { t, dnn },
            });
        }
        let mut heap_batched = HeapQueue::new();
        let mut heap_popped = HeapQueue::new();
        let mut bucket_batched = BucketQueue::new();
        let mut bucket_popped = BucketQueue::new();
        for &ev in &events {
            heap_batched.push(ev);
            heap_popped.push(ev);
            bucket_batched.push(ev);
            bucket_popped.push(ev);
        }
        let mut batch = Vec::new();
        loop {
            batch.clear();
            let Some(t) = heap_batched.pop_batch_into(&mut batch) else { break };
            let mut reference = Vec::new();
            while heap_popped.next_time() == Some(t) {
                reference.push(heap_popped.pop().unwrap());
            }
            prop::ensure(batch == reference, "heap: batch == pop sequence")?;
        }
        prop::ensure(heap_popped.pop().is_none(), "heap reference drained")?;
        loop {
            batch.clear();
            let Some(t) = bucket_batched.pop_batch_into(&mut batch) else { break };
            let mut reference = Vec::new();
            while bucket_popped.next_time() == Some(t) {
                reference.push(bucket_popped.pop().unwrap());
            }
            prop::ensure(batch == reference, "bucket: batch == pop sequence")?;
        }
        prop::ensure(bucket_popped.pop().is_none(), "bucket reference drained")
    });
}
