//! Integration: PJRT engine × AOT artifacts — the end-to-end numerics
//! contract between `python/compile/` and `rust/src/runtime/`.
//!
//! Requires `make artifacts`.  Tests are skipped (not failed) when the
//! artifacts directory is absent so `cargo test` works pre-AOT; the Makefile
//! `test` target always builds artifacts first.

use std::path::PathBuf;
use std::sync::OnceLock;

use mtsa::runtime::{pack_step, packing, Engine, Tensor, TenantTile};
use mtsa::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

/// One engine per test process: PJRT client construction + 8 compiles is
/// ~seconds; sharing it keeps the suite fast.
fn engine() -> Option<&'static Engine> {
    static ENGINE: OnceLock<Option<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| artifacts_dir().map(|d| Engine::load(&d).expect("engine load")))
        .as_ref()
}

fn rand_tensor(rng: &mut Rng, shape: Vec<usize>) -> Tensor {
    let n = shape.iter().product();
    Tensor::new(shape, (0..n).map(|_| rng.gen_f32() - 0.5).collect())
}

#[test]
fn engine_loads_all_manifest_artifacts() {
    let Some(eng) = engine() else { return };
    let names = eng.artifact_names();
    for expected in [
        "pws_p1", "pws_p2", "pws_p4", "pws_p8",
        "pws_fused_p4", "gemm_baseline", "drain_relu", "drain_none",
    ] {
        assert!(names.contains(&expected), "missing artifact {expected}");
    }
    assert_eq!(eng.manifest().array_c, 128);
}

#[test]
fn gemm_baseline_matches_cpu_matmul() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(10);
    let x = rand_tensor(&mut rng, vec![128, 128]);
    let w = rand_tensor(&mut rng, vec![128, 128]);
    let acc = rand_tensor(&mut rng, vec![128, 128]);

    let y = eng.execute("gemm_baseline", &[x.clone(), w.clone(), acc.clone()]).unwrap();

    let mut want = x.matmul(&w);
    for (o, a) in want.data_mut().iter_mut().zip(acc.data()) {
        *o += a;
    }
    assert!(y.max_abs_diff(&want) < 1e-3, "diff {}", y.max_abs_diff(&want));
}

#[test]
fn pws_p4_matches_packed_oracle() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(20);
    // Four tenants with ragged stream rows and K depths, 32 columns each.
    let tiles: Vec<TenantTile> = (0..4)
        .map(|t| TenantTile {
            tenant: t,
            x: rand_tensor(&mut rng, vec![100 + t, 96 + 8 * t]),
            w: rand_tensor(&mut rng, vec![96 + 8 * t, 32]),
        })
        .collect();
    let step = pack_step(&tiles, 128, 128, 128, 4).unwrap();
    let acc = rand_tensor(&mut rng, vec![128, 128]);

    let y = eng
        .execute("pws_p4", &[step.x.clone(), step.w.clone(), step.mask.clone(), acc.clone()])
        .unwrap();

    let want = packing::packed_step_oracle(&step, &acc);
    assert!(y.max_abs_diff(&want) < 1e-3, "diff {}", y.max_abs_diff(&want));

    // And per-tenant unpack equals each tenant's own GEMM (acc=0 region check
    // done in unit tests; here acc was random so compare against oracle slices).
    for i in 0..4 {
        let got = step.unpack(&y, i);
        let oracle_slice = step.unpack(&want, i);
        assert!(got.max_abs_diff(&oracle_slice) < 1e-3, "tenant {i}");
    }
}

#[test]
fn pws_variants_agree_on_shared_case() {
    // The same 2-tenant case run through pws_p2, pws_p4 (2 lanes idle) and
    // pws_p8 (6 lanes idle) must produce identical tenant results.
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(30);
    let tiles: Vec<TenantTile> = (0..2)
        .map(|t| TenantTile {
            tenant: t,
            x: rand_tensor(&mut rng, vec![64, 128]),
            w: rand_tensor(&mut rng, vec![128, 48]),
        })
        .collect();
    let acc = Tensor::zeros(vec![128, 128]);

    let mut results = Vec::new();
    for p in [2usize, 4, 8] {
        let step = pack_step(&tiles, 128, 128, 128, p).unwrap();
        let y = eng
            .execute(
                &format!("pws_p{p}"),
                &[step.x.clone(), step.w.clone(), step.mask.clone(), acc.clone()],
            )
            .unwrap();
        results.push((step.unpack(&y, 0), step.unpack(&y, 1)));
    }
    for i in 1..results.len() {
        assert!(results[0].0.max_abs_diff(&results[i].0) < 1e-4);
        assert!(results[0].1.max_abs_diff(&results[i].1) < 1e-4);
    }
}

#[test]
fn fold_chaining_through_acc_matches_monolithic() {
    // K = 256 split into two 128-folds chained through acc — what the
    // coordinator does for layers deeper than the array.
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(40);
    let x_full = rand_tensor(&mut rng, vec![128, 256]);
    let w_full = rand_tensor(&mut rng, vec![256, 128]);

    let slice_x = |k0: usize| {
        let mut t = Tensor::zeros(vec![128, 128]);
        for r in 0..128 {
            for k in 0..128 {
                t.set2(r, k, x_full.at2(r, k0 + k));
            }
        }
        t
    };
    let slice_w = |k0: usize| {
        let mut t = Tensor::zeros(vec![128, 128]);
        for k in 0..128 {
            for c in 0..128 {
                t.set2(k, c, w_full.at2(k0 + k, c));
            }
        }
        t
    };

    let acc0 = Tensor::zeros(vec![128, 128]);
    let y1 = eng.execute("gemm_baseline", &[slice_x(0), slice_w(0), acc0]).unwrap();
    let y2 = eng.execute("gemm_baseline", &[slice_x(128), slice_w(128), y1]).unwrap();

    let want = x_full.matmul(&w_full);
    assert!(y2.max_abs_diff(&want) < 1e-2, "diff {}", y2.max_abs_diff(&want));
}

#[test]
fn drain_relu_clamps_negatives() {
    let Some(eng) = engine() else { return };
    let y = Tensor::from_fn(vec![128, 128], |i| if i % 2 == 0 { -1.0 } else { 2.0 });
    let bias = Tensor::zeros(vec![128]);
    let out = eng.execute("drain_relu", &[y, bias]).unwrap();
    for (i, &v) in out.data().iter().enumerate() {
        let want = if i % 2 == 0 { 0.0 } else { 2.0 };
        assert_eq!(v, want, "at {i}");
    }
}

#[test]
fn fused_step_equals_pws_plus_drain() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(50);
    let tiles: Vec<TenantTile> = (0..4)
        .map(|t| TenantTile {
            tenant: t,
            x: rand_tensor(&mut rng, vec![128, 128]),
            w: rand_tensor(&mut rng, vec![128, 32]),
        })
        .collect();
    let step = pack_step(&tiles, 128, 128, 128, 4).unwrap();
    let acc = Tensor::zeros(vec![128, 128]);
    let bias = rand_tensor(&mut rng, vec![128]);

    let fused = eng
        .execute(
            "pws_fused_p4",
            &[step.x.clone(), step.w.clone(), step.mask.clone(), acc.clone(), bias.clone()],
        )
        .unwrap();

    let partial = eng
        .execute("pws_p4", &[step.x.clone(), step.w.clone(), step.mask.clone(), acc])
        .unwrap();
    let unfused = eng.execute("drain_relu", &[partial, bias]).unwrap();

    assert!(fused.max_abs_diff(&unfused) < 1e-4);
}

#[test]
fn engine_rejects_wrong_shapes_and_names() {
    let Some(eng) = engine() else { return };
    let bad = Tensor::zeros(vec![2, 2]);
    assert!(eng.execute("gemm_baseline", &[bad.clone(), bad.clone(), bad.clone()]).is_err());
    let ok = Tensor::zeros(vec![128, 128]);
    assert!(eng.execute("gemm_baseline", &[ok.clone()]).is_err(), "arity check");
    assert!(eng.execute("no_such_artifact", &[ok]).is_err());
}
