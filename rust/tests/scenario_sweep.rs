//! Integration: the scenario engine + parallel sweep runner.
//!
//! The load-bearing contract is reproducibility: a sweep is a pure
//! function of (grid, base config), so a fixed seed must produce
//! byte-identical JSON regardless of how many worker threads ran it or
//! how the OS scheduled them.

use mtsa::coordinator::scheduler::{AllocPolicy, FeedModel, SchedulerConfig};
use mtsa::report;
use mtsa::sim::dataflow::ArrayGeometry;
use mtsa::sweep::{expand, run_sweep, SweepGrid};
use mtsa::util::json::Json;

fn small_grid() -> SweepGrid {
    SweepGrid {
        mixes: vec!["light".to_string()],
        rates: vec![0.0, 30_000.0],
        policies: vec![AllocPolicy::WidestToHeaviest, AllocPolicy::EqualShare],
        feeds: vec![FeedModel::Independent],
        geoms: vec![ArrayGeometry::new(128, 128)],
        requests: 5,
        qos_slack: 3.0,
        bursty: None,
        seed: 0xDECAF,
        ..SweepGrid::default()
    }
}

#[test]
fn fixed_seed_reproduces_byte_identical_json() {
    let base = SchedulerConfig::default();
    let grid = small_grid();
    // Different thread counts, same bytes.
    let a = report::sweep_json(&grid, &run_sweep(&grid, &base, 1).unwrap()).render();
    let b = report::sweep_json(&grid, &run_sweep(&grid, &base, 3).unwrap()).render();
    let c = report::sweep_json(&grid, &run_sweep(&grid, &base, 8).unwrap()).render();
    assert_eq!(a, b, "1 vs 3 worker threads changed the report bytes");
    assert_eq!(a, c, "1 vs 8 worker threads changed the report bytes");
    // And the bytes are valid JSON with the full grid.
    let parsed = Json::parse(&a).unwrap();
    assert_eq!(parsed.get("points").unwrap().as_arr().unwrap().len(), 4);
    assert_eq!(parsed.get("seed").unwrap().as_str(), Some("912559"));
}

#[test]
fn different_seed_changes_arrival_driven_points() {
    let base = SchedulerConfig::default();
    let grid = small_grid();
    let other = SweepGrid { seed: 1, ..small_grid() };
    let a = report::sweep_json(&grid, &run_sweep(&grid, &base, 2).unwrap()).render();
    let b = report::sweep_json(&other, &run_sweep(&other, &base, 2).unwrap()).render();
    assert_ne!(a, b, "seed must flow into the arrival traces");
}

#[test]
fn default_grid_meets_the_24_point_floor() {
    let grid = SweepGrid::default();
    assert!(expand(&grid, &SchedulerConfig::default()).len() >= 24);
}

#[test]
fn sla_report_fields_are_coherent() {
    let base = SchedulerConfig::default();
    let rows = run_sweep(&small_grid(), &base, 4).unwrap();
    assert_eq!(rows.len(), 4);
    for row in &rows {
        let o = &row.outcome.overall;
        assert_eq!(o.requests, 5);
        assert!(o.p50_latency > 0.0);
        assert!(o.p50_latency <= o.p95_latency && o.p95_latency <= o.p99_latency);
        assert!(o.p99_latency <= o.max_latency);
        assert!((0.0..=1.0).contains(&row.outcome.miss_rate()));
        assert!(o.deadlines == o.requests, "slack > 0 puts a deadline on every request");
        // Per-tenant rows partition the requests.
        assert_eq!(row.outcome.tenants.iter().map(|t| t.requests).sum::<usize>(), 5);
        // Batch points start everything at t=0; arrival-driven points
        // cannot finish earlier than the batch's busiest schedule allows.
        assert!(row.makespan > 0 && row.seq_makespan > 0);
    }

    // Dynamic partitioning's downside stays tightly bounded (same 1.25x
    // envelope the scheduler property tests enforce; the strict win on the
    // canonical Table-1 pools is asserted in paper_experiments.rs).
    let batch_widest = &rows[0];
    assert_eq!(batch_widest.point.mean_interarrival, 0.0);
    assert!(
        batch_widest.makespan as f64 <= 1.25 * batch_widest.seq_makespan as f64,
        "dynamic {} >> sequential {} on the batch light mix",
        batch_widest.makespan,
        batch_widest.seq_makespan
    );
}
