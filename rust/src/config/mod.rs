//! Configuration system: a small TOML-subset parser ([`toml`]) and the
//! typed accelerator/scheduler schema ([`schema`]) the CLI consumes.

pub mod schema;
pub mod toml;

pub use schema::RunConfig;
pub use toml::TomlDoc;
