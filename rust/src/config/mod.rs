//! Configuration system: a small TOML-subset parser ([`toml`]) and the
//! typed accelerator/scheduler/scenario schema ([`schema`]) the CLI
//! consumes.  The scenario keys (`[scenario]`: arrival process, request
//! count, QoS slack) are documented in `docs/scenarios.md`.

pub mod schema;
pub mod toml;

pub use schema::{ArrivalKind, RunConfig, ScenarioDefaults};
pub use toml::TomlDoc;
