//! Minimal TOML-subset parser (offline build: no `serde`/`toml` crates).
//!
//! Supported grammar — everything the config schema needs:
//!
//! ```toml
//! # comment
//! top_key = 1
//! [section]
//! int = 128
//! float = 0.5
//! string = "hello"
//! boolean = true
//! ```
//!
//! Unsupported (rejected loudly): arrays, inline tables, dotted keys,
//! multi-line strings, dates.

use std::collections::BTreeMap;
use std::fmt;

/// A scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Str(String),
    Bool(bool),
}

impl TomlValue {
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse error with line number.
#[derive(Debug, Clone, PartialEq)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// A parsed document: `section -> key -> value`; top-level keys live in
/// the `""` section.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line_no = ln + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| TomlError { line: line_no, msg: "unterminated [section]".into() })?
                    .trim();
                if name.is_empty() || !is_bare_key(name) {
                    return Err(TomlError { line: line_no, msg: format!("bad section name {name:?}") });
                }
                section = name.to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| TomlError { line: line_no, msg: "expected key = value".into() })?;
            let key = key.trim();
            if !is_bare_key(key) {
                return Err(TomlError { line: line_no, msg: format!("bad key {key:?}") });
            }
            let value = parse_value(value.trim(), line_no)?;
            let prev = doc.sections.entry(section.clone()).or_default().insert(key.into(), value);
            if prev.is_some() {
                return Err(TomlError { line: line_no, msg: format!("duplicate key {key:?}") });
            }
        }
        Ok(doc)
    }

    /// Look up `section.key` (empty section = top level).
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    /// Section names (excluding the implicit top level).
    pub fn section_names(&self) -> Vec<&str> {
        self.sections.keys().filter(|k| !k.is_empty()).map(String::as_str).collect()
    }

    /// Keys of a section.
    pub fn keys(&self, section: &str) -> Vec<&str> {
        self.sections
            .get(section)
            .map(|s| s.keys().map(String::as_str).collect())
            .unwrap_or_default()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string is preserved.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn is_bare_key(s: &str) -> bool {
    !s.is_empty() && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
}

fn parse_value(v: &str, line: usize) -> Result<TomlValue, TomlError> {
    if v == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if v == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = v.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| TomlError { line, msg: "unterminated string".into() })?;
        if inner.contains('"') {
            return Err(TomlError { line, msg: "embedded quote unsupported".into() });
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    let clean = v.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(TomlError { line, msg: format!("cannot parse value {v:?}") })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let doc = TomlDoc::parse(
            r#"
            # accelerator geometry
            seed = 42
            [array]
            rows = 128
            cols = 128          # TPU-like
            clock_ghz = 0.7
            [scheduler]
            policy = "widest"
            merge = true
            min_width = 16
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "seed").unwrap().as_u64(), Some(42));
        assert_eq!(doc.get("array", "rows").unwrap().as_u64(), Some(128));
        assert_eq!(doc.get("array", "clock_ghz").unwrap().as_f64(), Some(0.7));
        assert_eq!(doc.get("scheduler", "policy").unwrap().as_str(), Some("widest"));
        assert_eq!(doc.get("scheduler", "merge").unwrap().as_bool(), Some(true));
        assert_eq!(doc.section_names(), vec!["array", "scheduler"]);
    }

    #[test]
    fn underscored_ints() {
        let doc = TomlDoc::parse("big = 1_000_000").unwrap();
        assert_eq!(doc.get("", "big").unwrap().as_u64(), Some(1_000_000));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse(r##"name = "a#b""##).unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "[unterminated",
            "novalue",
            "k = ",
            "k = 'single'",
            "k = \"open",
            "[]\nk = 1",
            "dup = 1\ndup = 2",
        ] {
            assert!(TomlDoc::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn value_type_accessors() {
        assert_eq!(TomlValue::Int(5).as_f64(), Some(5.0));
        assert_eq!(TomlValue::Int(-1).as_u64(), None);
        assert_eq!(TomlValue::Bool(true).as_str(), None);
    }
}
