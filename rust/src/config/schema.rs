//! Typed run configuration: TOML file → `SchedulerConfig` + energy model
//! + workload selection, with validation and full-default fallback.
//!
//! Example (`configs/tpu128.toml`):
//!
//! ```toml
//! [array]
//! rows = 128
//! cols = 128
//!
//! [buffers]
//! weight_kib = 6144
//! ifmap_kib = 12288
//! ofmap_kib = 6144
//! dtype_bytes = 1
//!
//! [scheduler]
//! policy = "widest"        # widest | equal
//! feed_model = "independent"  # independent | interleaved
//! min_width = 16
//! patience_divisor = 4
//!
//! [partition]              # 2D architecture fission, see docs/fission.md
//! mode = "columns"         # columns (paper) | 2d (rectangular tiles)
//! min_rows = 16            # shortest tile 2d mode will create
//! preempt = "off"          # off | arrival | deadline — fold-boundary
//!                          # drain-and-reshape, see docs/preemption.md
//! # tables = "profiles/"   # optional `mtsa profile` output dir: 2d mode
//!                          # unions the profiled shapes with its ladder
//!                          # (see docs/profiling.md)
//!
//! [dram]
//! enabled = false
//! words_per_cycle = 64.0
//! burst_latency = 100
//!
//! [mem]                    # shared memory hierarchy, see docs/memory.md
//! enabled = false          # subsumes [dram]; the two are exclusive
//! words_per_cycle = 64.0
//! burst_latency = 100
//! arbitration = "fair"     # fair | weighted | priority
//! banks = 8
//!
//! [vector]                 # SIMD lane pool, see docs/heterogeneous.md
//! enabled = false          # off = pre-heterogeneous model, byte for byte
//! lanes = 128              # default: cols
//! ops_per_lane = 1
//! words_per_lane = 1
//! startup = 64             # per-layer dispatch/drain overhead (cycles)
//!
//! [scenario]              # arrival/QoS defaults, see docs/scenarios.md
//! arrival = "poisson"     # batch | poisson | bursty
//! mean_interarrival = 50000.0
//! burst_size = 4
//! burst_within = 1000.0
//! requests = 12
//! seed = 42
//! qos_slack = 3.0         # deadline = arrival + slack x isolated latency; 0 = best-effort
//! ```

use anyhow::{bail, Context, Result};

use super::toml::TomlDoc;
use crate::coordinator::scheduler::{
    AllocPolicy, FeedModel, PartitionMode, PreemptMode, SchedulerConfig,
};
use crate::mem::{ArbitrationMode, MemConfig};
use crate::util::UnknownTag;
use crate::energy::components::{EnergyModel, Precision};
use crate::fleet::{FleetPolicy, Placement};
use crate::sim::dataflow::{ArrayGeometry, VectorUnit, DEFAULT_VECTOR_STARTUP};
use crate::sim::dram::DramConfig;
use crate::workloads::generator::ArrivalProcess;

/// Arrival-process family selected by `[scenario] arrival`.
///
/// Fixed-trace arrivals ([`ArrivalProcess::Trace`]) have no TOML spelling
/// (the config subset has no arrays); build them through the library API.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ArrivalKind {
    /// Everything at t=0 (the paper's Table-1 setup).
    #[default]
    Batch,
    Poisson,
    Bursty,
}

impl ArrivalKind {
    /// Every variant, in tag order.
    pub const ALL: [ArrivalKind; 3] = [ArrivalKind::Batch, ArrivalKind::Poisson, ArrivalKind::Bursty];
    /// The tags of [`ArrivalKind::ALL`], in the same order.
    pub const TAGS: [&'static str; 3] = ["batch", "poisson", "bursty"];

    /// Stable config name (round-trips through [`std::str::FromStr`]).
    pub fn tag(self) -> &'static str {
        match self {
            ArrivalKind::Batch => Self::TAGS[0],
            ArrivalKind::Poisson => Self::TAGS[1],
            ArrivalKind::Bursty => Self::TAGS[2],
        }
    }
}

impl std::str::FromStr for ArrivalKind {
    type Err = UnknownTag;

    fn from_str(s: &str) -> Result<ArrivalKind, UnknownTag> {
        ArrivalKind::ALL.into_iter().find(|k| k.tag() == s).ok_or_else(|| UnknownTag {
            what: "arrival kind",
            got: s.to_string(),
            valid: &ArrivalKind::TAGS,
        })
    }
}

/// `[scenario]` — arrival + QoS defaults for the scenario engine and
/// `mtsa sweep` (CLI flags override these; see `docs/scenarios.md`).
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioDefaults {
    pub arrival: ArrivalKind,
    /// Poisson mean gap / bursty mean OFF gap, in cycles.
    pub mean_interarrival: f64,
    /// Requests per burst (bursty only).
    pub burst_size: u64,
    /// Intra-burst spacing in cycles (bursty only).
    pub burst_within: f64,
    /// DNN instances per scenario.
    pub requests: u64,
    pub seed: u64,
    /// Deadline slack factor; 0 = best-effort (no deadlines).
    pub qos_slack: f64,
}

impl Default for ScenarioDefaults {
    fn default() -> Self {
        ScenarioDefaults {
            arrival: ArrivalKind::Batch,
            mean_interarrival: 50_000.0,
            burst_size: 4,
            burst_within: 1_000.0,
            requests: 12,
            seed: 42,
            qos_slack: 3.0,
        }
    }
}

impl ScenarioDefaults {
    /// The configured arrival process.
    pub fn arrival_process(&self) -> ArrivalProcess {
        match self.arrival {
            ArrivalKind::Batch => ArrivalProcess::Batch,
            ArrivalKind::Poisson => {
                ArrivalProcess::Poisson { mean_interarrival: self.mean_interarrival }
            }
            ArrivalKind::Bursty => ArrivalProcess::Bursty {
                burst_size: self.burst_size as usize,
                within_gap: self.burst_within,
                between_gap: self.mean_interarrival,
            },
        }
    }
}

/// `[fleet]` — cluster-tier defaults for `mtsa fleet` (CLI flags
/// override these; see `docs/fleet.md`).  Per-instance geometry/buffers
/// come from the same `[array]`/`[buffers]`/`[mem]` sections every
/// instance of a homogeneous fleet shares; heterogeneous fleets are
/// built through the library API.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetDefaults {
    /// Accelerator instances in the fleet.
    pub instances: u64,
    /// Per-instance scheduling policy (`dynamic`, `sequential`,
    /// `static`, `multi-array[:N]`).
    pub policy: FleetPolicy,
    /// Router placement (`least-loaded`, `affinity`, `random-k`).
    pub placement: Placement,
    /// Candidate count for `random-k`.
    pub random_k: u64,
    /// Concurrent tenant slots per instance.
    pub slots: u64,
    /// Admission queue depth per instance.
    pub queue_cap: u64,
    /// Requests per fleet run.
    pub requests: u64,
    pub seed: u64,
    /// Diurnal "day" length in cycles; 0 = one day spanning the whole
    /// trace (`requests × mean_interarrival`).
    pub diurnal_period: f64,
    /// Diurnal swing in `[0, 1)`; 0 disables the modulation.
    pub diurnal_amplitude: f64,
    /// `mtsa profile` output dir the router prices isolated-run horizons
    /// from (loaded per `mtsa fleet` invocation; `None` = compute live).
    pub tables: Option<String>,
}

impl Default for FleetDefaults {
    fn default() -> Self {
        FleetDefaults {
            instances: 8,
            policy: FleetPolicy::Dynamic,
            placement: Placement::LeastLoaded,
            random_k: 2,
            slots: 8,
            queue_cap: 64,
            requests: 1_000_000,
            seed: 42,
            diurnal_period: 0.0,
            diurnal_amplitude: 0.6,
            tables: None,
        }
    }
}

/// Fully-resolved run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub scheduler: SchedulerConfig,
    pub precision: Precision,
    pub scenario: ScenarioDefaults,
    pub fleet: FleetDefaults,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            scheduler: SchedulerConfig::default(),
            precision: Precision::Int8,
            scenario: ScenarioDefaults::default(),
            fleet: FleetDefaults::default(),
        }
    }
}

impl RunConfig {
    /// Parse from TOML text; missing sections/keys keep defaults.
    pub fn from_toml(text: &str) -> Result<RunConfig> {
        let doc = TomlDoc::parse(text).context("parsing config")?;
        let mut cfg = RunConfig::default();

        let known = [
            "array", "buffers", "scheduler", "partition", "dram", "mem", "vector", "energy",
            "scenario", "fleet",
        ];
        for s in doc.section_names() {
            if !known.contains(&s) {
                bail!("unknown config section [{s}] (known: {known:?})");
            }
        }

        let u64_of = |sec: &str, key: &str| -> Option<u64> {
            doc.get(sec, key).and_then(|v| v.as_u64())
        };
        let f64_of = |sec: &str, key: &str| -> Option<f64> {
            doc.get(sec, key).and_then(|v| v.as_f64())
        };

        let rows = u64_of("array", "rows").unwrap_or(cfg.scheduler.geom.rows);
        let cols = u64_of("array", "cols").unwrap_or(cfg.scheduler.geom.cols);
        cfg.scheduler.geom = ArrayGeometry::try_new(rows, cols)
            .map_err(|e| anyhow::anyhow!("in [array]: {e}"))?;

        let b = &mut cfg.scheduler.buffers;
        if let Some(k) = u64_of("buffers", "weight_kib") {
            b.weight_bytes = k * 1024;
        }
        if let Some(k) = u64_of("buffers", "ifmap_kib") {
            b.ifmap_bytes = k * 1024;
        }
        if let Some(k) = u64_of("buffers", "ofmap_kib") {
            b.ofmap_bytes = k * 1024;
        }
        if let Some(d) = u64_of("buffers", "dtype_bytes") {
            if ![1, 2, 4].contains(&d) {
                bail!("dtype_bytes must be 1, 2 or 4");
            }
            b.dtype_bytes = d;
            cfg.precision = match d {
                1 => Precision::Int8,
                2 => Precision::Fp16,
                _ => Precision::Fp32,
            };
        }

        if let Some(p) = doc.get("scheduler", "policy").and_then(|v| v.as_str()) {
            cfg.scheduler.alloc_policy =
                p.parse::<AllocPolicy>().context("in [scheduler] policy")?;
        }
        if let Some(f) = doc.get("scheduler", "feed_model").and_then(|v| v.as_str()) {
            cfg.scheduler.feed_model =
                f.parse::<FeedModel>().context("in [scheduler] feed_model")?;
        }
        if let Some(w) = u64_of("scheduler", "min_width") {
            if w == 0 || w > cols {
                bail!("min_width must be in 1..=cols");
            }
            cfg.scheduler.min_width = w;
        }
        if let Some(p) = u64_of("scheduler", "patience_divisor") {
            if p == 0 {
                bail!("patience_divisor must be >= 1");
            }
            cfg.scheduler.patience_divisor = p;
        }

        if let Some(m) = doc.get("partition", "mode").and_then(|v| v.as_str()) {
            cfg.scheduler.partition_mode =
                m.parse::<PartitionMode>().context("in [partition] mode")?;
        }
        if let Some(r) = u64_of("partition", "min_rows") {
            if r == 0 || r > rows {
                bail!("min_rows must be in 1..=rows");
            }
            cfg.scheduler.min_rows = r;
        }
        if let Some(p) = doc.get("partition", "preempt").and_then(|v| v.as_str()) {
            cfg.scheduler.preempt = p.parse::<PreemptMode>().context("in [partition] preempt")?;
        }
        if let Some(dir) = doc.get("partition", "tables").and_then(|v| v.as_str()) {
            cfg.scheduler.tables = Some(
                crate::profiler::ProfileStore::load_arc(dir)
                    .map_err(anyhow::Error::msg)
                    .context("in [partition] tables")?,
            );
        }

        if doc.get("dram", "enabled").and_then(|v| v.as_bool()).unwrap_or(false) {
            let mut d = DramConfig::default();
            if let Some(w) = f64_of("dram", "words_per_cycle") {
                if w <= 0.0 {
                    bail!("dram.words_per_cycle must be positive");
                }
                d.words_per_cycle = w;
            }
            if let Some(l) = u64_of("dram", "burst_latency") {
                d.burst_latency = l;
            }
            cfg.scheduler.dram = Some(d);
        }

        if doc.get("mem", "enabled").and_then(|v| v.as_bool()).unwrap_or(false) {
            if cfg.scheduler.dram.is_some() {
                bail!(
                    "[mem] and [dram] are mutually exclusive: the shared memory hierarchy \
                     subsumes the isolated DRAM bound (see docs/memory.md)"
                );
            }
            let mut m = MemConfig::default();
            if let Some(w) = f64_of("mem", "words_per_cycle") {
                if w <= 0.0 {
                    bail!("mem.words_per_cycle must be positive");
                }
                m.dram.words_per_cycle = w;
            }
            if let Some(l) = u64_of("mem", "burst_latency") {
                m.dram.burst_latency = l;
            }
            if let Some(a) = doc.get("mem", "arbitration").and_then(|v| v.as_str()) {
                m.arbitration = a.parse::<ArbitrationMode>().context("in [mem] arbitration")?;
            }
            if let Some(b) = u64_of("mem", "banks") {
                if b == 0 {
                    bail!("mem.banks must be >= 1");
                }
                m.banks = b;
            }
            cfg.scheduler.mem = Some(m);
        }

        if doc.get("vector", "enabled").and_then(|v| v.as_bool()).unwrap_or(false) {
            let lanes = u64_of("vector", "lanes").unwrap_or(cols);
            let ops = u64_of("vector", "ops_per_lane").unwrap_or(1);
            let words = u64_of("vector", "words_per_lane").unwrap_or(1);
            let startup = u64_of("vector", "startup").unwrap_or(DEFAULT_VECTOR_STARTUP);
            cfg.scheduler.vector = Some(
                VectorUnit::try_new(lanes, ops, words, startup)
                    .map_err(|e| anyhow::anyhow!("in [vector]: {e}"))?,
            );
        }

        let sc = &mut cfg.scenario;
        if let Some(a) = doc.get("scenario", "arrival").and_then(|v| v.as_str()) {
            sc.arrival = a.parse::<ArrivalKind>().context("in [scenario] arrival")?;
        }
        if let Some(m) = f64_of("scenario", "mean_interarrival") {
            if m <= 0.0 {
                bail!("scenario.mean_interarrival must be positive");
            }
            sc.mean_interarrival = m;
        }
        if let Some(b) = u64_of("scenario", "burst_size") {
            if b == 0 {
                bail!("scenario.burst_size must be >= 1");
            }
            sc.burst_size = b;
        }
        if let Some(w) = f64_of("scenario", "burst_within") {
            if w < 0.0 {
                bail!("scenario.burst_within must be >= 0");
            }
            sc.burst_within = w;
        }
        if let Some(r) = u64_of("scenario", "requests") {
            if r == 0 {
                bail!("scenario.requests must be >= 1");
            }
            sc.requests = r;
        }
        if let Some(s) = u64_of("scenario", "seed") {
            sc.seed = s;
        }
        if let Some(q) = f64_of("scenario", "qos_slack") {
            if q < 0.0 {
                bail!("scenario.qos_slack must be >= 0 (0 disables deadlines)");
            }
            sc.qos_slack = q;
        }

        let fl = &mut cfg.fleet;
        if let Some(n) = u64_of("fleet", "instances") {
            if n == 0 {
                bail!("fleet.instances must be >= 1");
            }
            fl.instances = n;
        }
        if let Some(p) = doc.get("fleet", "policy").and_then(|v| v.as_str()) {
            fl.policy = p
                .parse::<FleetPolicy>()
                .map_err(|e| anyhow::anyhow!("in [fleet] policy: {e}"))?;
        }
        if let Some(p) = doc.get("fleet", "placement").and_then(|v| v.as_str()) {
            fl.placement = p.parse::<Placement>().context("in [fleet] placement")?;
        }
        if let Some(k) = u64_of("fleet", "random_k") {
            if k == 0 {
                bail!("fleet.random_k must be >= 1");
            }
            fl.random_k = k;
        }
        if let Some(s) = u64_of("fleet", "slots") {
            if s == 0 {
                bail!("fleet.slots must be >= 1");
            }
            fl.slots = s;
        }
        if let Some(q) = u64_of("fleet", "queue_cap") {
            if q == 0 {
                bail!("fleet.queue_cap must be >= 1");
            }
            fl.queue_cap = q;
        }
        if let Some(r) = u64_of("fleet", "requests") {
            if r == 0 {
                bail!("fleet.requests must be >= 1");
            }
            fl.requests = r;
        }
        if let Some(s) = u64_of("fleet", "seed") {
            fl.seed = s;
        }
        if let Some(p) = f64_of("fleet", "diurnal_period") {
            if p < 0.0 {
                bail!("fleet.diurnal_period must be >= 0 (0 = auto)");
            }
            fl.diurnal_period = p;
        }
        if let Some(a) = f64_of("fleet", "diurnal_amplitude") {
            if !(0.0..1.0).contains(&a) {
                bail!("fleet.diurnal_amplitude must be in [0, 1)");
            }
            fl.diurnal_amplitude = a;
        }
        if let Some(dir) = doc.get("fleet", "tables").and_then(|v| v.as_str()) {
            // Kept as a path: `mtsa fleet` loads (and coverage-checks) the
            // store per invocation, so a config can reference a tables dir
            // that is rebuilt between runs.
            fl.tables = Some(dir.to_string());
        }

        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &std::path::Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml(&text)
    }

    /// The energy model matching this configuration.
    pub fn energy_model(&self) -> EnergyModel {
        EnergyModel::build(self.scheduler.geom, &self.scheduler.buffers, self.precision)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_without_file() {
        let cfg = RunConfig::from_toml("").unwrap();
        assert_eq!(cfg.scheduler.geom.cols, 128);
        assert_eq!(cfg.scheduler.min_width, 16);
        assert!(cfg.scheduler.dram.is_none());
    }

    #[test]
    fn full_round_trip() {
        let cfg = RunConfig::from_toml(
            r#"
            [array]
            rows = 64
            cols = 64
            [buffers]
            weight_kib = 1024
            dtype_bytes = 2
            [scheduler]
            policy = "equal"
            feed_model = "interleaved"
            min_width = 8
            patience_divisor = 2
            [dram]
            enabled = true
            words_per_cycle = 32.0
            burst_latency = 50
            "#,
        )
        .unwrap();
        assert_eq!(cfg.scheduler.geom, ArrayGeometry::new(64, 64));
        assert_eq!(cfg.scheduler.buffers.weight_bytes, 1024 * 1024);
        assert_eq!(cfg.precision, Precision::Fp16);
        assert_eq!(cfg.scheduler.alloc_policy, AllocPolicy::EqualShare);
        assert_eq!(cfg.scheduler.feed_model, FeedModel::Interleaved);
        assert_eq!(cfg.scheduler.min_width, 8);
        let d = cfg.scheduler.dram.unwrap();
        assert_eq!(d.words_per_cycle, 32.0);
        assert_eq!(d.burst_latency, 50);
    }

    #[test]
    fn partition_section_round_trip() {
        let cfg = RunConfig::from_toml(
            r#"
            [partition]
            mode = "2d"
            min_rows = 32
            preempt = "arrival"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.scheduler.partition_mode, PartitionMode::TwoD);
        assert_eq!(cfg.scheduler.min_rows, 32);
        assert_eq!(cfg.scheduler.preempt, PreemptMode::Arrival);
        let dl = RunConfig::from_toml("[partition]\npreempt = \"deadline\"").unwrap();
        assert_eq!(dl.scheduler.preempt, PreemptMode::Deadline);
        assert_eq!(
            RunConfig::from_toml("").unwrap().scheduler.preempt,
            PreemptMode::Off,
            "preemption is strictly opt-in"
        );
        // Default: the paper's columns mode, min_rows = rows/8.
        let def = RunConfig::from_toml("").unwrap();
        assert_eq!(def.scheduler.partition_mode, PartitionMode::Columns);
        assert_eq!(def.scheduler.min_rows, 16);
        let explicit = RunConfig::from_toml("[partition]\nmode = \"columns\"").unwrap();
        assert_eq!(explicit.scheduler.partition_mode, PartitionMode::Columns);
    }

    #[test]
    fn partition_tables_load_from_a_profile_dir() {
        use crate::profiler::{build_tables, write_artifacts};
        use crate::sim::dataflow::ArrayGeometry;
        let dir = std::env::temp_dir().join(format!("mtsa-cfg-prof-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let bufs = crate::sim::buffers::BufferConfig::default();
        let tables =
            build_tables(&[("NCF".into(), ArrayGeometry::new(128, 128))], &bufs, 1).unwrap();
        write_artifacts(&tables[0], &bufs, &dir).unwrap();
        let toml = format!("[partition]\nmode = \"2d\"\ntables = {:?}", dir.display().to_string());
        let cfg = RunConfig::from_toml(&toml).unwrap();
        let store = cfg.scheduler.tables.expect("tables loaded");
        assert!(store.has_geometry(ArrayGeometry::new(128, 128)));
        let _ = std::fs::remove_dir_all(&dir);
        // A missing dir is rejected at parse time, naming the knob.
        let e = RunConfig::from_toml("[partition]\ntables = \"/nonexistent-mtsa-tables\"")
            .unwrap_err();
        assert!(format!("{e:#}").contains("[partition] tables"), "{e:#}");
        // Unset keeps the scheduler table-free (byte-stability contract).
        assert!(RunConfig::from_toml("").unwrap().scheduler.tables.is_none());
    }

    #[test]
    fn mem_section_round_trip() {
        let cfg = RunConfig::from_toml(
            r#"
            [mem]
            enabled = true
            words_per_cycle = 32.0
            burst_latency = 40
            arbitration = "weighted"
            banks = 16
            "#,
        )
        .unwrap();
        let m = cfg.scheduler.mem.unwrap();
        assert_eq!(m.dram.words_per_cycle, 32.0);
        assert_eq!(m.dram.burst_latency, 40);
        assert_eq!(m.arbitration, ArbitrationMode::WeightedByColumns);
        assert_eq!(m.banks, 16);
        assert!(cfg.scheduler.dram.is_none());

        // Disabled (the default): no mem system, bit-for-bit today's runs.
        let off = RunConfig::from_toml("[mem]\nenabled = false\nbanks = 4").unwrap();
        assert!(off.scheduler.mem.is_none());
        assert!(RunConfig::from_toml("").unwrap().scheduler.mem.is_none());
    }

    #[test]
    fn vector_section_round_trip() {
        let cfg = RunConfig::from_toml(
            r#"
            [vector]
            enabled = true
            lanes = 256
            ops_per_lane = 4
            words_per_lane = 2
            startup = 32
            "#,
        )
        .unwrap();
        let v = cfg.scheduler.vector.unwrap();
        assert_eq!(v.lanes, 256);
        assert_eq!(v.ops_per_lane, 4);
        assert_eq!(v.words_per_lane, 2);
        assert_eq!(v.startup, 32);

        // Lane count defaults to the array's column count.
        let d = RunConfig::from_toml("[array]\ncols = 64\n[vector]\nenabled = true").unwrap();
        assert_eq!(
            d.scheduler.vector.unwrap(),
            VectorUnit::try_new(64, 1, 1, DEFAULT_VECTOR_STARTUP).unwrap()
        );

        // Disabled (the default): no lane pool, bit-for-bit today's runs.
        let off = RunConfig::from_toml("[vector]\nenabled = false\nlanes = 64").unwrap();
        assert!(off.scheduler.vector.is_none());
        assert!(RunConfig::from_toml("").unwrap().scheduler.vector.is_none());
    }

    #[test]
    fn vector_error_names_the_offending_value() {
        let e = RunConfig::from_toml("[vector]\nenabled = true\nlanes = 0").unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("[vector]") && msg.contains("`lanes = 0`"), "{msg}");
        let e = RunConfig::from_toml("[vector]\nenabled = true\nops_per_lane = 0").unwrap_err();
        assert!(format!("{e:#}").contains("`ops_per_lane = 0`"), "{e:#}");
    }

    #[test]
    fn mem_and_dram_are_mutually_exclusive() {
        let e = RunConfig::from_toml(
            "[dram]\nenabled = true\n[mem]\nenabled = true",
        )
        .unwrap_err();
        assert!(e.to_string().contains("mutually exclusive"), "{e}");
    }

    #[test]
    fn rejects_bad_values() {
        for bad in [
            "[array]\nrows = 0",
            "[scheduler]\npolicy = \"nope\"",
            "[scheduler]\nmin_width = 0",
            "[partition]\nmode = \"diagonal\"",
            "[partition]\nmin_rows = 0",
            "[partition]\nmin_rows = 256",
            "[partition]\npreempt = \"sometimes\"",
            "[scheduler]\npatience_divisor = 0",
            "[buffers]\ndtype_bytes = 3",
            "[typo]\nx = 1",
            "[dram]\nenabled = true\nwords_per_cycle = -1.0",
            "[mem]\nenabled = true\nwords_per_cycle = -2.0",
            "[mem]\nenabled = true\nbanks = 0",
            "[mem]\nenabled = true\narbitration = \"psychic\"",
            "[vector]\nenabled = true\nlanes = 0",
            "[vector]\nenabled = true\nops_per_lane = 0",
            "[vector]\nenabled = true\nwords_per_lane = 0",
            "[scenario]\narrival = \"fractal\"",
            "[scenario]\nmean_interarrival = 0",
            "[scenario]\nburst_size = 0",
            "[scenario]\nrequests = 0",
            "[scenario]\nqos_slack = -1.0",
            "[fleet]\ninstances = 0",
            "[fleet]\npolicy = \"roundrobin\"",
            "[fleet]\npolicy = \"multi-array:0\"",
            "[fleet]\nplacement = \"psychic\"",
            "[fleet]\nrandom_k = 0",
            "[fleet]\nslots = 0",
            "[fleet]\nqueue_cap = 0",
            "[fleet]\nrequests = 0",
            "[fleet]\ndiurnal_period = -1.0",
            "[fleet]\ndiurnal_amplitude = 1.0",
        ] {
            assert!(RunConfig::from_toml(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn fleet_section_round_trip() {
        let cfg = RunConfig::from_toml(
            r#"
            [fleet]
            instances = 16
            policy = "multi-array:2"
            placement = "affinity"
            random_k = 3
            slots = 6
            queue_cap = 128
            requests = 5000
            seed = 9
            diurnal_period = 1e9
            diurnal_amplitude = 0.4
            tables = "profiles"
            "#,
        )
        .unwrap();
        let fl = &cfg.fleet;
        assert_eq!(fl.tables.as_deref(), Some("profiles"));
        assert_eq!(fl.instances, 16);
        assert_eq!(fl.policy, FleetPolicy::MultiArray(2));
        assert_eq!(fl.placement, Placement::Affinity);
        assert_eq!(fl.random_k, 3);
        assert_eq!(fl.slots, 6);
        assert_eq!(fl.queue_cap, 128);
        assert_eq!(fl.requests, 5000);
        assert_eq!(fl.seed, 9);
        assert_eq!(fl.diurnal_period, 1e9);
        assert_eq!(fl.diurnal_amplitude, 0.4);
        // Absent section keeps the serving-scale defaults.
        assert_eq!(RunConfig::from_toml("").unwrap().fleet, FleetDefaults::default());
    }

    #[test]
    fn scenario_section_round_trip() {
        let cfg = RunConfig::from_toml(
            r#"
            [scenario]
            arrival = "bursty"
            mean_interarrival = 80000.0
            burst_size = 6
            burst_within = 250.0
            requests = 20
            seed = 7
            qos_slack = 1.5
            "#,
        )
        .unwrap();
        let sc = &cfg.scenario;
        assert_eq!(sc.arrival, ArrivalKind::Bursty);
        assert_eq!(sc.requests, 20);
        assert_eq!(sc.seed, 7);
        assert_eq!(sc.qos_slack, 1.5);
        assert_eq!(
            sc.arrival_process(),
            ArrivalProcess::Bursty { burst_size: 6, within_gap: 250.0, between_gap: 80_000.0 }
        );
    }

    #[test]
    fn scenario_defaults_without_section() {
        let cfg = RunConfig::from_toml("").unwrap();
        assert_eq!(cfg.scenario, ScenarioDefaults::default());
        assert_eq!(cfg.scenario.arrival_process(), ArrivalProcess::Batch);
        let poisson = RunConfig::from_toml("[scenario]\narrival = \"poisson\"").unwrap();
        assert_eq!(
            poisson.scenario.arrival_process(),
            ArrivalProcess::Poisson { mean_interarrival: 50_000.0 }
        );
    }

    #[test]
    fn arrival_kind_tags_round_trip() {
        for k in ArrivalKind::ALL {
            assert_eq!(k.tag().parse::<ArrivalKind>().unwrap(), k);
        }
        let e = "fractal".parse::<ArrivalKind>().unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("batch") && msg.contains("poisson") && msg.contains("bursty"), "{msg}");
    }

    #[test]
    fn bad_geometry_error_names_the_offending_value() {
        let e = RunConfig::from_toml("[array]\nrows = 0\ncols = 8").unwrap_err();
        assert!(e.to_string().contains("0x8"), "{e}");
        let e = RunConfig::from_toml("[array]\ncols = 0").unwrap_err();
        assert!(e.to_string().contains("128x0"), "{e}");
    }

    #[test]
    fn energy_model_follows_geometry() {
        let cfg = RunConfig::from_toml("[array]\nrows = 32\ncols = 32").unwrap();
        assert_eq!(cfg.energy_model().geom.pes(), 1024);
    }
}
