//! Typed run configuration: TOML file → `SchedulerConfig` + energy model
//! + workload selection, with validation and full-default fallback.
//!
//! Example (`configs/tpu128.toml`):
//!
//! ```toml
//! [array]
//! rows = 128
//! cols = 128
//!
//! [buffers]
//! weight_kib = 6144
//! ifmap_kib = 12288
//! ofmap_kib = 6144
//! dtype_bytes = 1
//!
//! [scheduler]
//! policy = "widest"        # widest | equal
//! feed_model = "independent"  # independent | interleaved
//! min_width = 16
//! patience_divisor = 4
//!
//! [dram]
//! enabled = false
//! words_per_cycle = 64.0
//! burst_latency = 100
//! ```

use anyhow::{bail, Context, Result};

use super::toml::TomlDoc;
use crate::coordinator::scheduler::{AllocPolicy, FeedModel, SchedulerConfig};
use crate::energy::components::{EnergyModel, Precision};
use crate::sim::dataflow::ArrayGeometry;
use crate::sim::dram::DramConfig;

/// Fully-resolved run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub scheduler: SchedulerConfig,
    pub precision: Precision,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig { scheduler: SchedulerConfig::default(), precision: Precision::Int8 }
    }
}

impl RunConfig {
    /// Parse from TOML text; missing sections/keys keep defaults.
    pub fn from_toml(text: &str) -> Result<RunConfig> {
        let doc = TomlDoc::parse(text).context("parsing config")?;
        let mut cfg = RunConfig::default();

        let known = ["array", "buffers", "scheduler", "dram", "energy"];
        for s in doc.section_names() {
            if !known.contains(&s) {
                bail!("unknown config section [{s}] (known: {known:?})");
            }
        }

        let u64_of = |sec: &str, key: &str| -> Option<u64> {
            doc.get(sec, key).and_then(|v| v.as_u64())
        };
        let f64_of = |sec: &str, key: &str| -> Option<f64> {
            doc.get(sec, key).and_then(|v| v.as_f64())
        };

        let rows = u64_of("array", "rows").unwrap_or(cfg.scheduler.geom.rows);
        let cols = u64_of("array", "cols").unwrap_or(cfg.scheduler.geom.cols);
        if rows == 0 || cols == 0 {
            bail!("array dims must be positive");
        }
        cfg.scheduler.geom = ArrayGeometry::new(rows, cols);

        let b = &mut cfg.scheduler.buffers;
        if let Some(k) = u64_of("buffers", "weight_kib") {
            b.weight_bytes = k * 1024;
        }
        if let Some(k) = u64_of("buffers", "ifmap_kib") {
            b.ifmap_bytes = k * 1024;
        }
        if let Some(k) = u64_of("buffers", "ofmap_kib") {
            b.ofmap_bytes = k * 1024;
        }
        if let Some(d) = u64_of("buffers", "dtype_bytes") {
            if ![1, 2, 4].contains(&d) {
                bail!("dtype_bytes must be 1, 2 or 4");
            }
            b.dtype_bytes = d;
            cfg.precision = match d {
                1 => Precision::Int8,
                2 => Precision::Fp16,
                _ => Precision::Fp32,
            };
        }

        if let Some(p) = doc.get("scheduler", "policy").and_then(|v| v.as_str()) {
            cfg.scheduler.alloc_policy = match p {
                "widest" => AllocPolicy::WidestToHeaviest,
                "equal" => AllocPolicy::EqualShare,
                _ => bail!("unknown scheduler.policy {p:?} (widest|equal)"),
            };
        }
        if let Some(f) = doc.get("scheduler", "feed_model").and_then(|v| v.as_str()) {
            cfg.scheduler.feed_model = match f {
                "independent" => FeedModel::Independent,
                "interleaved" => FeedModel::Interleaved,
                _ => bail!("unknown scheduler.feed_model {f:?}"),
            };
        }
        if let Some(w) = u64_of("scheduler", "min_width") {
            if w == 0 || w > cols {
                bail!("min_width must be in 1..=cols");
            }
            cfg.scheduler.min_width = w;
        }
        if let Some(p) = u64_of("scheduler", "patience_divisor") {
            if p == 0 {
                bail!("patience_divisor must be >= 1");
            }
            cfg.scheduler.patience_divisor = p;
        }

        if doc.get("dram", "enabled").and_then(|v| v.as_bool()).unwrap_or(false) {
            let mut d = DramConfig::default();
            if let Some(w) = f64_of("dram", "words_per_cycle") {
                if w <= 0.0 {
                    bail!("dram.words_per_cycle must be positive");
                }
                d.words_per_cycle = w;
            }
            if let Some(l) = u64_of("dram", "burst_latency") {
                d.burst_latency = l;
            }
            cfg.scheduler.dram = Some(d);
        }

        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &std::path::Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml(&text)
    }

    /// The energy model matching this configuration.
    pub fn energy_model(&self) -> EnergyModel {
        EnergyModel::build(self.scheduler.geom, &self.scheduler.buffers, self.precision)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_without_file() {
        let cfg = RunConfig::from_toml("").unwrap();
        assert_eq!(cfg.scheduler.geom.cols, 128);
        assert_eq!(cfg.scheduler.min_width, 16);
        assert!(cfg.scheduler.dram.is_none());
    }

    #[test]
    fn full_round_trip() {
        let cfg = RunConfig::from_toml(
            r#"
            [array]
            rows = 64
            cols = 64
            [buffers]
            weight_kib = 1024
            dtype_bytes = 2
            [scheduler]
            policy = "equal"
            feed_model = "interleaved"
            min_width = 8
            patience_divisor = 2
            [dram]
            enabled = true
            words_per_cycle = 32.0
            burst_latency = 50
            "#,
        )
        .unwrap();
        assert_eq!(cfg.scheduler.geom, ArrayGeometry::new(64, 64));
        assert_eq!(cfg.scheduler.buffers.weight_bytes, 1024 * 1024);
        assert_eq!(cfg.precision, Precision::Fp16);
        assert_eq!(cfg.scheduler.alloc_policy, AllocPolicy::EqualShare);
        assert_eq!(cfg.scheduler.feed_model, FeedModel::Interleaved);
        assert_eq!(cfg.scheduler.min_width, 8);
        let d = cfg.scheduler.dram.unwrap();
        assert_eq!(d.words_per_cycle, 32.0);
        assert_eq!(d.burst_latency, 50);
    }

    #[test]
    fn rejects_bad_values() {
        for bad in [
            "[array]\nrows = 0",
            "[scheduler]\npolicy = \"nope\"",
            "[scheduler]\nmin_width = 0",
            "[scheduler]\npatience_divisor = 0",
            "[buffers]\ndtype_bytes = 3",
            "[typo]\nx = 1",
            "[dram]\nenabled = true\nwords_per_cycle = -1.0",
        ] {
            assert!(RunConfig::from_toml(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn energy_model_follows_geometry() {
        let cfg = RunConfig::from_toml("[array]\nrows = 32\ncols = 32").unwrap();
        assert_eq!(cfg.energy_model().geom.pes(), 1024);
    }
}
