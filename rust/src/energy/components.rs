//! 45 nm component energy table — the Accelergy component library of the
//! paper's toolchain, anchored to standard published numbers:
//!
//! - int8 MAC ≈ 0.2 pJ, fp16 ≈ 1.0 pJ, fp32 ≈ 3.0 pJ (Horowitz ISSCC'14,
//!   add+mul);
//! - pipeline/load register write ≈ 0.06 pJ/byte;
//! - DRAM ≈ 160 pJ per byte (LPDDR-class at 45 nm-era interfaces);
//! - SRAM buffers from the CACTI-lite curves ([`super::cacti`]);
//! - idle PE leakage + clock ≈ 50% of its active MAC energy per cycle (45 nm
//!   leakage plus the always-running clock tree; measured accelerators are
//!   idle-heavy — the TPU v1 paper reports 28 W idle vs 40 W busy, i.e.
//!   ~70% — so 50% at the PE granularity is mid-range).

use super::cacti::SramSpec;
use crate::sim::activity::Activity;
use crate::sim::buffers::BufferConfig;
use crate::sim::dataflow::ArrayGeometry;

/// Arithmetic precision of the PE datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Int8,
    Fp16,
    Fp32,
}

impl Precision {
    /// MAC energy in pJ (multiply + accumulate).
    pub fn mac_pj(&self) -> f64 {
        match self {
            Precision::Int8 => 0.2,
            Precision::Fp16 => 1.0,
            Precision::Fp32 => 3.0,
        }
    }

    pub fn bytes(&self) -> u64 {
        match self {
            Precision::Int8 => 1,
            Precision::Fp16 => 2,
            Precision::Fp32 => 4,
        }
    }
}

/// Per-event (pJ) and per-cycle (W) energy of every modeled component.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComponentEnergy {
    pub mac_pj: f64,
    pub lr_write_pj: f64,
    pub weight_sram_pj: f64,
    pub ifmap_sram_pj: f64,
    pub ofmap_sram_pj: f64,
    pub dram_pj_per_word: f64,
    /// Leakage+clock of one *idle* PE per cycle, pJ.
    pub pe_idle_pj_per_cycle: f64,
    /// SRAM leakage power of all three buffers, W.
    pub sram_leakage_w: f64,
    /// Control/sequencer overhead per cycle, pJ.
    pub control_pj_per_cycle: f64,
}

/// The assembled energy model for one accelerator configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyModel {
    pub geom: ArrayGeometry,
    pub precision: Precision,
    pub clock_ghz: f64,
    pub components: ComponentEnergy,
}

impl EnergyModel {
    /// Build the 45 nm model for an array + buffer configuration.
    pub fn build(geom: ArrayGeometry, bufs: &BufferConfig, precision: Precision) -> EnergyModel {
        let word = precision.bytes();
        // Bank the buffers by array edge: one bank per 2 columns/rows, the
        // natural layout for edge-fed buffers.
        let weight = SramSpec::new(bufs.weight_bytes.max(1024), word, (geom.cols / 2).max(1));
        let ifmap = SramSpec::new(bufs.ifmap_bytes.max(1024), word, (geom.rows / 2).max(1));
        // Drain holds f32 partials regardless of datapath precision.
        let ofmap = SramSpec::new(bufs.ofmap_bytes.max(1024), word.max(4), (geom.cols / 2).max(1));

        let mac_pj = precision.mac_pj();
        let components = ComponentEnergy {
            mac_pj,
            lr_write_pj: 0.06 * word as f64,
            weight_sram_pj: weight.access_pj(),
            ifmap_sram_pj: ifmap.access_pj(),
            ofmap_sram_pj: ofmap.access_pj(),
            dram_pj_per_word: 160.0 * word as f64,
            pe_idle_pj_per_cycle: 0.5 * mac_pj,
            sram_leakage_w: weight.leakage_w() + ifmap.leakage_w() + ofmap.leakage_w(),
            control_pj_per_cycle: 2.0,
        };
        EnergyModel { geom, precision, clock_ghz: 0.7, components }
    }

    /// Default TPU-like 128×128 int8 model.
    pub fn default_128() -> EnergyModel {
        EnergyModel::build(ArrayGeometry::new(128, 128), &BufferConfig::default(), Precision::Int8)
    }

    /// Whole-array static power as joules per cycle (all PEs idle): the
    /// rate used for per-DNN static attribution (Fig. 9(e)(f) accounting).
    pub fn static_rate_j_per_cycle(&self) -> f64 {
        let c = &self.components;
        1e-12 * (self.geom.pes() as f64 * c.pe_idle_pj_per_cycle + c.control_pj_per_cycle)
            + c.sram_leakage_w / (self.clock_ghz * 1e9)
    }

    /// Dynamic energy of an activity record, in joules.
    pub fn dynamic_j(&self, a: &Activity) -> f64 {
        let c = &self.components;
        1e-12
            * (a.macs as f64 * c.mac_pj
                + a.pe_lr_writes as f64 * c.lr_write_pj
                + (a.weight_sram_reads + a.weight_sram_writes) as f64 * c.weight_sram_pj
                + (a.ifmap_sram_reads + a.ifmap_sram_writes) as f64 * c.ifmap_sram_pj
                + (a.ofmap_sram_reads + a.ofmap_sram_writes) as f64 * c.ofmap_sram_pj
                + a.dram_accesses() as f64 * c.dram_pj_per_word)
    }

    /// Idle-leakage energy of memory-stall residency, in joules:
    /// `stall_col_cycles` column-cycles of PEs held by a partition but
    /// starved by the DRAM interface (see
    /// [`MemStats::stall_col_cycles`](crate::mem::MemStats)), each
    /// burning a column of idle PEs.  This is *attribution*, not new
    /// energy: stalls stretch residency and the makespan, so the
    /// whole-run [`EnergyModel::static_j`] term already contains it —
    /// this prices the share a specific tenant's stalls caused.
    pub fn stall_j(&self, stall_col_cycles: u64) -> f64 {
        1e-12
            * (stall_col_cycles.saturating_mul(self.geom.rows)) as f64
            * self.components.pe_idle_pj_per_cycle
    }

    /// Static/idle energy over a span of cycles, in joules.
    ///
    /// `busy_pe_cycles` = Σ MACs: a PE doing a MAC burns `mac_pj` (already
    /// counted as dynamic); every *other* PE-cycle burns the idle
    /// leakage+clock energy.  SRAM leakage and control run for the whole
    /// span — this is the term makespan reduction saves, i.e. the paper's
    /// multi-tenant energy win.
    pub fn static_j(&self, span_cycles: u64, busy_pe_cycles: u64) -> f64 {
        let total_pe_cycles = span_cycles.saturating_mul(self.geom.pes());
        let idle_pe_cycles = total_pe_cycles.saturating_sub(busy_pe_cycles) as f64;
        let c = &self.components;
        let idle_j = 1e-12 * idle_pe_cycles * c.pe_idle_pj_per_cycle;
        let control_j = 1e-12 * span_cycles as f64 * c.control_pj_per_cycle;
        let seconds = span_cycles as f64 / (self.clock_ghz * 1e9);
        idle_j + control_j + c.sram_leakage_w * seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_table() {
        assert_eq!(Precision::Int8.bytes(), 1);
        assert_eq!(Precision::Fp32.bytes(), 4);
        assert!(Precision::Fp32.mac_pj() > Precision::Fp16.mac_pj());
        assert!(Precision::Fp16.mac_pj() > Precision::Int8.mac_pj());
    }

    #[test]
    fn hierarchy_ratios_sane() {
        // DRAM >> SRAM >> MAC — the ordering all dataflow papers rely on.
        let m = EnergyModel::default_128();
        let c = m.components;
        assert!(c.dram_pj_per_word > 10.0 * c.ifmap_sram_pj, "DRAM {} vs SRAM {}", c.dram_pj_per_word, c.ifmap_sram_pj);
        assert!(c.ifmap_sram_pj > c.mac_pj, "SRAM {} vs MAC {}", c.ifmap_sram_pj, c.mac_pj);
        assert!(c.mac_pj > c.lr_write_pj);
    }

    #[test]
    fn dynamic_energy_additive() {
        let m = EnergyModel::default_128();
        let a = Activity { macs: 1000, ..Default::default() };
        let b = Activity { dram_reads: 10, ..Default::default() };
        let mut ab = a;
        ab.add(&b);
        let sum = m.dynamic_j(&a) + m.dynamic_j(&b);
        assert!((m.dynamic_j(&ab) - sum).abs() < 1e-18);
    }

    #[test]
    fn static_energy_shrinks_with_busy_pes() {
        let m = EnergyModel::default_128();
        let span = 1_000_000;
        let idle_all = m.static_j(span, 0);
        let busy_half = m.static_j(span, span * m.geom.pes() / 2);
        let busy_all = m.static_j(span, span * m.geom.pes());
        assert!(idle_all > busy_half && busy_half > busy_all);
        // With every PE busy, only control + SRAM leakage remain.
        assert!(busy_all > 0.0);
    }

    #[test]
    fn stall_energy_scales_with_held_columns() {
        let m = EnergyModel::default_128();
        let one_col = m.stall_j(1_000);
        let four_col = m.stall_j(4_000);
        assert!(one_col > 0.0);
        assert!((four_col / one_col - 4.0).abs() < 1e-9);
        // A full-width stall for S cycles equals S cycles of the PE-idle
        // share of the whole-array static rate.
        let s = 10_000u64;
        let full = m.stall_j(s * m.geom.cols);
        let idle_all = 1e-12 * (s * m.geom.pes()) as f64 * m.components.pe_idle_pj_per_cycle;
        assert!((full - idle_all).abs() < 1e-15);
    }

    #[test]
    fn makespan_reduction_saves_static_energy() {
        // Same work (busy cycles), shorter span -> less static energy.
        let m = EnergyModel::default_128();
        let busy = 500_000 * 128; // some busy PE-cycles
        let long = m.static_j(2_000_000, busy);
        let short = m.static_j(1_000_000, busy);
        assert!(short < long * 0.6);
    }
}
