//! Area model at 45 nm — the other half of Accelergy's output.
//!
//! Anchors (published 45 nm synthesis numbers): an int8 MAC + pipeline
//! registers ≈ 1 700 µm²; SRAM ≈ 0.35 mm² per Mbit for large mats
//! (density ~2.9 Mbit/mm² at 45 nm with peripheral overhead); control ≈
//! 5% of the PE array.  Per-PE overhead of the paper's proposal — one
//! tri-state gate + the `Mul_En` control wire — is ≈ 5 µm²/PE, i.e.
//! ~0.3% of a PE: the "no expensive hardware costs" claim, quantified.

use super::components::Precision;
use crate::sim::buffers::BufferConfig;
use crate::sim::dataflow::ArrayGeometry;

/// Area breakdown in mm².
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AreaBreakdown {
    pub pe_array_mm2: f64,
    pub sram_mm2: f64,
    pub control_mm2: f64,
    /// The paper's added tri-state gates, totalled.
    pub mul_en_gates_mm2: f64,
}

impl AreaBreakdown {
    pub fn total_mm2(&self) -> f64 {
        self.pe_array_mm2 + self.sram_mm2 + self.control_mm2 + self.mul_en_gates_mm2
    }

    /// Fractional overhead of the proposal's hardware change.
    pub fn mul_en_overhead_fraction(&self) -> f64 {
        self.mul_en_gates_mm2 / self.total_mm2()
    }
}

/// PE area in µm² by datapath precision (MAC + LR + pipeline regs).
fn pe_um2(p: Precision) -> f64 {
    match p {
        Precision::Int8 => 1_700.0,
        Precision::Fp16 => 5_500.0,
        Precision::Fp32 => 14_000.0,
    }
}

const SRAM_MM2_PER_MBIT: f64 = 0.35;
const MUL_EN_GATE_UM2: f64 = 5.0;

/// Estimate the accelerator's area.
pub fn estimate(geom: ArrayGeometry, bufs: &BufferConfig, precision: Precision) -> AreaBreakdown {
    let pes = geom.pes() as f64;
    let pe_array_mm2 = pes * pe_um2(precision) * 1e-6;
    let sram_bits = 8.0 * (bufs.weight_bytes + bufs.ifmap_bytes + bufs.ofmap_bytes) as f64;
    let sram_mm2 = sram_bits / 1e6 * SRAM_MM2_PER_MBIT;
    AreaBreakdown {
        pe_array_mm2,
        sram_mm2,
        control_mm2: 0.05 * pe_array_mm2,
        mul_en_gates_mm2: pes * MUL_EN_GATE_UM2 * 1e-6,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tpu_like_config_plausible() {
        // 128x128 int8 + 24 MiB SRAM at 45 nm: tens of mm², SRAM-dominated.
        let a = estimate(ArrayGeometry::new(128, 128), &BufferConfig::default(), Precision::Int8);
        assert!((20.0..150.0).contains(&a.total_mm2()), "{}", a.total_mm2());
        assert!(a.sram_mm2 > a.pe_array_mm2);
    }

    #[test]
    fn mul_en_overhead_is_negligible() {
        // The paper's §1 claim ("a slight hardware modification"): < 0.5%.
        let a = estimate(ArrayGeometry::new(128, 128), &BufferConfig::default(), Precision::Int8);
        assert!(a.mul_en_overhead_fraction() < 0.005, "{}", a.mul_en_overhead_fraction());
    }

    #[test]
    fn precision_scales_pe_area() {
        let geom = ArrayGeometry::new(64, 64);
        let b = BufferConfig::default();
        let int8 = estimate(geom, &b, Precision::Int8);
        let fp32 = estimate(geom, &b, Precision::Fp32);
        assert!(fp32.pe_array_mm2 > 5.0 * int8.pe_array_mm2);
        assert_eq!(int8.sram_mm2, fp32.sram_mm2);
    }

    #[test]
    fn breakdown_sums() {
        let a = estimate(ArrayGeometry::new(32, 32), &BufferConfig::default(), Precision::Int8);
        let sum = a.pe_array_mm2 + a.sram_mm2 + a.control_mm2 + a.mul_en_gates_mm2;
        assert!((a.total_mm2() - sum).abs() < 1e-12);
    }
}
