//! Accelergy-equivalent energy estimation (paper Fig. 8, §4.2).
//!
//! The paper feeds Scale-Sim component activities into Accelergy (with
//! Cacti and Aladdin plug-ins) at 45 nm.  We rebuild the same pipeline:
//!
//! - [`cacti`] — a CACTI-P-lite analytic SRAM model: per-access energy and
//!   leakage as functions of capacity and word width at 45 nm;
//! - [`components`] — the 45 nm component table (MAC, registers, DRAM,
//!   clock/control) from the standard literature numbers (Horowitz,
//!   ISSCC'14; Eyeriss ratios), with the SRAM entries filled by `cacti`;
//! - [`estimator`] — `E = Σ_c activity(c)·e_dyn(c) + cycles·P_static`,
//!   with per-DNN and per-component breakdowns;
//! - [`area`] — the 45 nm area side of Accelergy's output, including the
//!   quantified (negligible) cost of the paper's added Mul_En gates.

pub mod area;
pub mod cacti;
pub mod components;
pub mod estimator;

pub use components::{ComponentEnergy, EnergyModel};
pub use estimator::{EnergyBreakdown, Estimator};
