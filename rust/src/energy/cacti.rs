//! CACTI-P-lite: analytic SRAM access-energy and leakage model at 45 nm.
//!
//! CACTI's detailed circuit model reduces, for the purposes of an
//! architecture-level estimator, to well-known scaling laws:
//!
//! - dynamic energy per access grows ~√capacity (bitline/wordline length
//!   scales with the side of the mat) and linearly with word width;
//! - leakage power grows linearly with capacity.
//!
//! We anchor the curves to published 45 nm reference points (Eyeriss /
//! Horowitz ISSCC'14): an 8 KiB scratchpad costs ~5 pJ per 16-bit access;
//! a 64-bit register ~0.1 pJ; large SRAM leaks ~10 µW per KiB at 45 nm.
//! Absolute joules are less important than *ratios* (DRAM ≈ 100–200× a
//! small SRAM access, SRAM ≈ 5–25× a MAC), which set the shape of the
//! paper's Fig. 9(e)(f).

/// An SRAM buffer instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SramSpec {
    pub capacity_bytes: u64,
    /// Access word width in bytes.
    pub word_bytes: u64,
    /// Number of banks (accesses hit one bank; leakage sums over all).
    pub banks: u64,
}

/// 45 nm anchor: pJ per access of an 8 KiB, 2-byte-word, single-bank mat.
const ANCHOR_PJ: f64 = 5.0;
const ANCHOR_BYTES: f64 = 8.0 * 1024.0;
const ANCHOR_WORD: f64 = 2.0;

/// 45 nm leakage: µW per KiB.  CACTI-P at 45 nm puts large low-ports SRAM
/// leakage at 30–80 µW/KiB depending on cell flavor; 40 is mid-range.
const LEAK_UW_PER_KIB: f64 = 40.0;

impl SramSpec {
    pub fn new(capacity_bytes: u64, word_bytes: u64, banks: u64) -> SramSpec {
        assert!(capacity_bytes > 0 && word_bytes > 0 && banks > 0);
        SramSpec { capacity_bytes, word_bytes, banks }
    }

    /// Dynamic energy per access in pJ.
    ///
    /// `e = ANCHOR · sqrt(bank_capacity / 8KiB) · (word / 2B)`
    pub fn access_pj(&self) -> f64 {
        let bank_bytes = self.capacity_bytes as f64 / self.banks as f64;
        ANCHOR_PJ * (bank_bytes / ANCHOR_BYTES).sqrt() * (self.word_bytes as f64 / ANCHOR_WORD)
    }

    /// Leakage power in watts (all banks).
    pub fn leakage_w(&self) -> f64 {
        LEAK_UW_PER_KIB * 1e-6 * (self.capacity_bytes as f64 / 1024.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchor_point() {
        let s = SramSpec::new(8 * 1024, 2, 1);
        assert!((s.access_pj() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn sqrt_capacity_scaling() {
        let small = SramSpec::new(8 * 1024, 2, 1);
        let big = SramSpec::new(32 * 1024, 2, 1);
        assert!((big.access_pj() / small.access_pj() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn banking_reduces_access_energy() {
        let mono = SramSpec::new(1 << 20, 2, 1);
        let banked = SramSpec::new(1 << 20, 2, 16);
        assert!((mono.access_pj() / banked.access_pj() - 4.0).abs() < 1e-9);
        // ...but not leakage.
        assert!((mono.leakage_w() - banked.leakage_w()).abs() < 1e-15);
    }

    #[test]
    fn word_width_linear() {
        let narrow = SramSpec::new(8 * 1024, 1, 1);
        let wide = SramSpec::new(8 * 1024, 4, 1);
        assert!((wide.access_pj() / narrow.access_pj() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn leakage_linear_in_capacity() {
        let s = SramSpec::new(1024 * 1024, 2, 4);
        assert!((s.leakage_w() - 40.0e-6 * 1024.0).abs() < 1e-10);
    }

    #[test]
    fn plausible_45nm_magnitudes() {
        // A 12 MiB feed buffer: access should land in the tens-of-pJ range
        // (banked), leakage ~0.1 W.
        let s = SramSpec::new(12 << 20, 1, 64);
        assert!((1.0..60.0).contains(&s.access_pj()), "{}", s.access_pj());
        assert!((0.2..1.2).contains(&s.leakage_w()), "{}", s.leakage_w());
    }
}
