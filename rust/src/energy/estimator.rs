//! The estimator: activity × component energy → per-DNN and per-component
//! joules, with the dynamic/static split that drives the paper's Fig. 9(e)(f).

use std::collections::BTreeMap;

use super::components::EnergyModel;
use crate::sim::activity::Activity;

/// Energy totals for one run (one workload pool on one scheduler).
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyBreakdown {
    /// Joules by component class.
    pub dynamic_by_component: BTreeMap<&'static str, f64>,
    /// Static/idle joules over the makespan.
    pub static_j: f64,
    /// Per-DNN dynamic joules (name → J).
    pub per_dnn_dynamic_j: BTreeMap<String, f64>,
    /// Makespan used for the static term (cycles).
    pub span_cycles: u64,
}

impl EnergyBreakdown {
    pub fn dynamic_j(&self) -> f64 {
        self.dynamic_by_component.values().sum()
    }

    pub fn total_j(&self) -> f64 {
        self.dynamic_j() + self.static_j
    }
}

/// Accumulating estimator: feed it per-layer activities tagged by DNN,
/// close it with the makespan.
#[derive(Debug, Clone)]
pub struct Estimator {
    model: EnergyModel,
    total: Activity,
    per_dnn: BTreeMap<String, Activity>,
}

impl Estimator {
    pub fn new(model: EnergyModel) -> Estimator {
        Estimator { model, total: Activity::default(), per_dnn: BTreeMap::new() }
    }

    pub fn model(&self) -> &EnergyModel {
        &self.model
    }

    /// Record one layer's activity under its DNN name.
    pub fn record(&mut self, dnn: &str, activity: &Activity) {
        self.total.add(activity);
        self.per_dnn.entry(dnn.to_string()).or_default().add(activity);
    }

    /// Close the run: the makespan (cycles) sets the static term.
    pub fn finish(&self, span_cycles: u64) -> EnergyBreakdown {
        let m = &self.model;
        let c = &m.components;
        let a = &self.total;
        let pj = |x: f64| x * 1e-12;
        let mut dynamic_by_component = BTreeMap::new();
        dynamic_by_component.insert("mac", pj(a.macs as f64 * c.mac_pj));
        dynamic_by_component.insert("pe_lr", pj(a.pe_lr_writes as f64 * c.lr_write_pj));
        dynamic_by_component.insert(
            "weight_sram",
            pj((a.weight_sram_reads + a.weight_sram_writes) as f64 * c.weight_sram_pj),
        );
        dynamic_by_component.insert(
            "ifmap_sram",
            pj((a.ifmap_sram_reads + a.ifmap_sram_writes) as f64 * c.ifmap_sram_pj),
        );
        dynamic_by_component.insert(
            "ofmap_sram",
            pj((a.ofmap_sram_reads + a.ofmap_sram_writes) as f64 * c.ofmap_sram_pj),
        );
        dynamic_by_component.insert("dram", pj(a.dram_accesses() as f64 * c.dram_pj_per_word));

        let per_dnn_dynamic_j =
            self.per_dnn.iter().map(|(k, v)| (k.clone(), m.dynamic_j(v))).collect();

        EnergyBreakdown {
            dynamic_by_component,
            static_j: m.static_j(span_cycles, a.macs),
            per_dnn_dynamic_j,
            span_cycles,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::components::EnergyModel;

    fn act(macs: u64, dram: u64) -> Activity {
        Activity { macs, dram_reads: dram, ..Default::default() }
    }

    #[test]
    fn breakdown_sums_to_model_dynamic() {
        let m = EnergyModel::default_128();
        let mut est = Estimator::new(m);
        est.record("a", &act(1_000_000, 5_000));
        est.record("b", &act(2_000_000, 0));
        let bd = est.finish(10_000_000);
        let mut total = Activity::default();
        total.add(&act(1_000_000, 5_000));
        total.add(&act(2_000_000, 0));
        assert!((bd.dynamic_j() - m.dynamic_j(&total)).abs() < 1e-15);
        assert_eq!(bd.per_dnn_dynamic_j.len(), 2);
        // "a" has half the MACs but 5000 DRAM words at 160 pJ/word — the
        // memory hierarchy dominates, as it must in any Accelergy-like model.
        assert!(bd.per_dnn_dynamic_j["a"] > bd.per_dnn_dynamic_j["b"]);
    }

    #[test]
    fn same_work_shorter_span_less_total_energy() {
        // The paper's core energy claim: identical dynamic work, but the
        // multi-tenant run's shorter makespan cuts the static share.
        let m = EnergyModel::default_128();
        let mut est = Estimator::new(m);
        est.record("x", &act(50_000_000, 100_000));
        let sequential = est.finish(20_000_000);
        let partitioned = est.finish(9_000_000);
        assert!((sequential.dynamic_j() - partitioned.dynamic_j()).abs() < 1e-15);
        assert!(partitioned.total_j() < sequential.total_j());
    }

    #[test]
    fn per_dnn_tags_accumulate() {
        let m = EnergyModel::default_128();
        let mut est = Estimator::new(m);
        est.record("net", &act(10, 0));
        est.record("net", &act(20, 0));
        let bd = est.finish(100);
        assert_eq!(bd.per_dnn_dynamic_j.len(), 1);
        let want = m.dynamic_j(&act(30, 0));
        assert!((bd.per_dnn_dynamic_j["net"] - want).abs() < 1e-18);
    }

    #[test]
    fn span_recorded() {
        let m = EnergyModel::default_128();
        let est = Estimator::new(m);
        assert_eq!(est.finish(12345).span_cycles, 12345);
    }
}
