//! The DRAM bandwidth arbiter — processor-sharing of the off-chip
//! interface among concurrently executing partitions.
//!
//! Every in-flight layer is a *flight*: a fixed compute finish time (the
//! policy's `exec` price) overlapped with a transfer obligation (its DRAM
//! words, double-buffered against compute — the same `max(compute,
//! transfer)` semantics as the isolated
//! [`DramConfig::bound_cycles`](crate::sim::dram::DramConfig::bound_cycles),
//! except the interface is now *shared*).  Whenever the co-runner set
//! changes — a dispatch, a retirement, or a transfer draining before its
//! compute — remaining transfer work is rescaled under the new shares and
//! every affected completion is re-predicted; the engine re-posts those
//! [`LayerComplete`](crate::sim_core::Event::LayerComplete) events and
//! drops the stale ones.
//!
//! Three arbitration modes: [`ArbitrationMode::FairShare`] (equal split
//! among transfer-active flights), [`ArbitrationMode::WeightedByColumns`]
//! (split proportional to partition width — wide tenants paid for their
//! bandwidth in silicon) and [`ArbitrationMode::StrictPriority`]
//! (earliest-dispatched flight takes the whole interface; later flights
//! starve until it drains — FIFO DMA).
//!
//! Everything is deterministic: flights live in a `BTreeMap`, shares are
//! pure functions of the live set, and the only state is advanced at
//! engine event boundaries.

use std::collections::BTreeMap;
use std::str::FromStr;

use crate::coordinator::partition::AllocId;
use crate::sim::dram::DramConfig;
use crate::util::UnknownTag;
use crate::workloads::dnng::DnnId;

/// How the DRAM interface is split among transfer-active flights.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ArbitrationMode {
    /// Equal share per transfer-active flight.
    #[default]
    FairShare,
    /// Share proportional to partition width (columns held).
    WeightedByColumns,
    /// Earliest-dispatched flight takes the whole interface.
    StrictPriority,
}

impl ArbitrationMode {
    /// Every variant, in tag order.
    pub const ALL: [ArbitrationMode; 3] = [
        ArbitrationMode::FairShare,
        ArbitrationMode::WeightedByColumns,
        ArbitrationMode::StrictPriority,
    ];
    /// The tags of [`ArbitrationMode::ALL`], in the same order.
    pub const TAGS: [&'static str; 3] = ["fair", "weighted", "priority"];

    /// Stable config/CLI/report name (round-trips through [`FromStr`]).
    pub fn tag(self) -> &'static str {
        match self {
            ArbitrationMode::FairShare => Self::TAGS[0],
            ArbitrationMode::WeightedByColumns => Self::TAGS[1],
            ArbitrationMode::StrictPriority => Self::TAGS[2],
        }
    }
}

impl FromStr for ArbitrationMode {
    type Err = UnknownTag;

    fn from_str(s: &str) -> Result<ArbitrationMode, UnknownTag> {
        ArbitrationMode::ALL.into_iter().find(|m| m.tag() == s).ok_or_else(|| UnknownTag {
            what: "arbitration mode",
            got: s.to_string(),
            valid: &ArbitrationMode::TAGS,
        })
    }
}

/// Sentinel "no completion predictable" (a starved strict-priority
/// flight); no event is posted until a rescale gives it bandwidth.
const STARVED: u64 = u64::MAX;

/// One in-flight layer's transfer obligation.
#[derive(Debug, Clone)]
struct Flight {
    dnn: DnnId,
    width: u64,
    /// Admission order (strict-priority key).
    seq: u64,
    t_start: u64,
    /// Compute path finishes here regardless of contention.
    compute_end: u64,
    /// Per-burst setup latency still to elapse (rate-independent).
    burst_left: u64,
    /// DRAM words still to move.
    words_left: f64,
    words_total: u64,
    /// Currently predicted completion cycle (the one live event).
    predicted_end: u64,
}

impl Flight {
    fn transfer_active(&self) -> bool {
        self.burst_left > 0 || self.words_left > 0.0
    }
}

/// Event-queue corrections after a co-runner-set change: completions to
/// re-post and (optionally) the next cycle at which a transfer drains
/// *before* its compute — an early bandwidth release the engine turns
/// into a [`MemRescale`](crate::sim_core::Event::MemRescale) event.
#[derive(Debug, Clone, Default)]
pub struct MemUpdate {
    /// `(alloc, new completion cycle)` — re-post these `LayerComplete`s.
    pub reposts: Vec<(AllocId, u64)>,
    /// Earliest early-release cycle, strictly in the future.
    pub next_release: Option<u64>,
}

/// What one retired flight contributed (the raw material of
/// [`MemStats`](super::MemStats)).
#[derive(Debug, Clone, Copy)]
pub struct FlightReport {
    pub dnn: DnnId,
    pub width: u64,
    pub t_start: u64,
    pub t_end: u64,
    /// The compute-path cycles the policy priced (stall = residency
    /// beyond this).
    pub compute_cycles: u64,
    /// DRAM words this flight moved.
    pub words: u64,
}

/// The shared-interface arbiter.  Owned by the engine's
/// [`MemSystem`](super::MemSystem); usable standalone in tests.
#[derive(Debug, Clone)]
pub struct BandwidthArbiter {
    dram: DramConfig,
    mode: ArbitrationMode,
    flights: BTreeMap<AllocId, Flight>,
    now: u64,
    seq: u64,
    /// Σ rate×dt actually delivered — the conservation ledger: once every
    /// flight retires this equals the sum of admitted words exactly.
    consumed_words: f64,
}

impl BandwidthArbiter {
    pub fn new(dram: DramConfig, mode: ArbitrationMode) -> BandwidthArbiter {
        assert!(dram.words_per_cycle > 0.0);
        BandwidthArbiter {
            dram,
            mode,
            flights: BTreeMap::new(),
            now: 0,
            seq: 0,
            consumed_words: 0.0,
        }
    }

    pub fn in_flight(&self) -> usize {
        self.flights.len()
    }

    /// Words delivered so far (see the conservation property test).
    pub fn consumed_words(&self) -> f64 {
        self.consumed_words
    }

    /// The currently predicted completion of a live flight (`None` for
    /// unknown flights *and* for starved ones with no prediction).
    pub fn predicted_end(&self, id: AllocId) -> Option<u64> {
        self.flights.get(&id).map(|f| f.predicted_end).filter(|&t| t != STARVED)
    }

    /// True when a `LayerComplete { t, alloc: id }` event no longer
    /// matches the flight's live prediction (superseded by a rescale, or
    /// the flight already retired).
    pub fn is_stale(&self, id: AllocId, t: u64) -> bool {
        match self.flights.get(&id) {
            Some(f) => f.predicted_end != t,
            None => true,
        }
    }

    /// Per-flight transfer rates (words/cycle) under the current set.
    fn rates(&self) -> BTreeMap<AllocId, f64> {
        let mut out: BTreeMap<AllocId, f64> = self.flights.keys().map(|&id| (id, 0.0)).collect();
        let active: Vec<(AllocId, &Flight)> = self
            .flights
            .iter()
            .filter(|(_, f)| f.transfer_active())
            .map(|(&id, f)| (id, f))
            .collect();
        if active.is_empty() {
            return out;
        }
        let b = self.dram.words_per_cycle;
        match self.mode {
            ArbitrationMode::FairShare => {
                let share = b / active.len() as f64;
                for (id, _) in &active {
                    out.insert(*id, share);
                }
            }
            ArbitrationMode::WeightedByColumns => {
                let total: u64 = active.iter().map(|(_, f)| f.width).sum();
                for (id, f) in &active {
                    out.insert(*id, b * f.width as f64 / total as f64);
                }
            }
            ArbitrationMode::StrictPriority => {
                let first = active
                    .iter()
                    .min_by_key(|(id, f)| (f.seq, *id))
                    .map(|(id, _)| *id)
                    .expect("non-empty active set");
                out.insert(first, b);
            }
        }
        out
    }

    /// Progress every transfer from the last update to `now` at the
    /// current shares, crediting the conservation ledger.  Burst latency
    /// elapses first (it is setup time, not bandwidth).
    pub fn advance(&mut self, now: u64) {
        debug_assert!(now >= self.now, "arbiter time went backwards");
        let dt = now - self.now;
        if dt > 0 && !self.flights.is_empty() {
            let rates = self.rates();
            for (id, f) in self.flights.iter_mut() {
                let lat = f.burst_left.min(dt);
                f.burst_left -= lat;
                let span = (dt - lat) as f64;
                let rate = rates[id];
                if span > 0.0 && rate > 0.0 && f.words_left > 0.0 {
                    let moved = (rate * span).min(f.words_left);
                    f.words_left -= moved;
                    self.consumed_words += moved;
                }
            }
        }
        self.now = now;
    }

    /// Cycles until flight `f`'s transfer drains at `rate` (`None` =
    /// starved, never under the current shares).
    fn transfer_eta(f: &Flight, rate: f64) -> Option<u64> {
        if !f.transfer_active() {
            return Some(0);
        }
        if rate <= 0.0 {
            return None;
        }
        Some(f.burst_left + (f.words_left / rate).ceil() as u64)
    }

    /// Re-predict every completion from `self.now` under the current
    /// shares.  Call after any co-runner-set change (and after
    /// [`BandwidthArbiter::advance`]).
    pub fn reschedule(&mut self) -> MemUpdate {
        let rates = self.rates();
        let now = self.now;
        let mut upd = MemUpdate::default();
        for (id, f) in self.flights.iter_mut() {
            let end = match Self::transfer_eta(f, rates[id]) {
                None => STARVED,
                Some(eta) => {
                    let t_xfer = now + eta;
                    if eta > 0 && t_xfer < f.compute_end {
                        // Transfer drains before compute: bandwidth frees
                        // early — the set changes again at t_xfer.
                        upd.next_release = Some(match upd.next_release {
                            Some(c) => c.min(t_xfer),
                            None => t_xfer,
                        });
                    }
                    t_xfer.max(f.compute_end)
                }
            };
            if end != f.predicted_end {
                f.predicted_end = end;
                if end != STARVED {
                    upd.reposts.push((*id, end));
                }
            }
        }
        upd
    }

    /// Admit a dispatched layer at `now`: `compute_cycles` from the
    /// policy's `exec`, `words` its (banked) DRAM traffic.  The returned
    /// update includes the new flight's own completion.
    pub fn admit(
        &mut self,
        now: u64,
        id: AllocId,
        dnn: DnnId,
        width: u64,
        compute_cycles: u64,
        words: u64,
    ) -> MemUpdate {
        self.advance(now);
        let seq = self.seq;
        self.seq += 1;
        let prev = self.flights.insert(
            id,
            Flight {
                dnn,
                width,
                seq,
                t_start: now,
                compute_end: now + compute_cycles.max(1),
                burst_left: if words > 0 { self.dram.burst_latency } else { 0 },
                words_left: words as f64,
                words_total: words,
                // Repaired by the reschedule below (guaranteed to differ,
                // so the new flight always lands in `reposts`).
                predicted_end: 0,
            },
        );
        assert!(prev.is_none(), "double admit of allocation {id}");
        self.reschedule()
    }

    /// Retire flight `id` at `now` (which must be its live prediction —
    /// the engine checks [`BandwidthArbiter::is_stale`] first).  The
    /// survivors' shares grow; their corrections come back in the update.
    pub fn retire(&mut self, now: u64, id: AllocId) -> (FlightReport, MemUpdate) {
        self.advance(now);
        let f = self.flights.remove(&id).unwrap_or_else(|| panic!("retire of unknown flight {id}"));
        debug_assert_eq!(f.predicted_end, now, "retire at a stale prediction");
        // Sub-word float residue at the boundary cycle goes to the ledger
        // so conservation stays exact.
        self.consumed_words += f.words_left;
        let report = FlightReport {
            dnn: f.dnn,
            width: f.width,
            t_start: f.t_start,
            t_end: now,
            compute_cycles: f.compute_end - f.t_start,
            words: f.words_total,
        };
        (report, self.reschedule())
    }

    /// A rescale decision point (an early bandwidth release fired):
    /// advance and re-predict.  Idempotent — firing a stale rescale is a
    /// no-op.
    pub fn rescale(&mut self, now: u64) -> MemUpdate {
        self.advance(now);
        self.reschedule()
    }

    /// Remove flight `id` at `now`, *before* its predicted completion —
    /// a fold-boundary preemption drained its layer segment early.  The
    /// report covers only what actually happened: words moved so far and
    /// the compute cycles consumed by `now`.  Words never moved are NOT
    /// credited to the conservation ledger (the resumed remainder
    /// re-admits its own traffic as a fresh flight); survivors' shares
    /// grow and their corrections come back in the update.
    pub fn preempt(&mut self, now: u64, id: AllocId) -> (FlightReport, MemUpdate) {
        self.advance(now);
        let f = self
            .flights
            .remove(&id)
            .unwrap_or_else(|| panic!("preempt of unknown flight {id}"));
        let moved = f.words_total.saturating_sub(f.words_left.ceil() as u64);
        let report = FlightReport {
            dnn: f.dnn,
            width: f.width,
            t_start: f.t_start,
            t_end: now,
            compute_cycles: f.compute_end.min(now) - f.t_start,
            words: moved,
        };
        (report, self.reschedule())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dram(wpc: f64, burst: u64) -> DramConfig {
        DramConfig { words_per_cycle: wpc, burst_latency: burst }
    }

    /// Drive an arbiter to completion: honor reposts/releases like the
    /// engine does, returning each flight's final completion cycle.
    fn drain(arb: &mut BandwidthArbiter, upds: Vec<MemUpdate>) -> BTreeMap<AllocId, u64> {
        fn absorb(events: &mut Vec<(u64, Option<AllocId>)>, upd: &MemUpdate) {
            for &(id, t) in &upd.reposts {
                events.push((t, Some(id)));
            }
            if let Some(t) = upd.next_release {
                events.push((t, None));
            }
        }
        let mut done = BTreeMap::new();
        // (t, Some = completion of alloc, None = rescale)
        let mut events: Vec<(u64, Option<AllocId>)> = Vec::new();
        for upd in &upds {
            absorb(&mut events, upd);
        }
        while !events.is_empty() {
            events.sort_by_key(|&(t, id)| (t, id.is_some() as u8, id));
            let (t, id) = events.remove(0);
            let upd = match id {
                Some(id) => {
                    if arb.is_stale(id, t) {
                        continue;
                    }
                    let (rep, u) = arb.retire(t, id);
                    done.insert(id, rep.t_end);
                    u
                }
                None => arb.rescale(t),
            };
            absorb(&mut events, &upd);
        }
        done
    }

    #[test]
    fn lone_flight_matches_isolated_bound() {
        // One tenant with the whole interface: completion is exactly
        // max(compute, burst + ceil(words / B)) — the isolated bound.
        let mut arb = BandwidthArbiter::new(dram(10.0, 5), ArbitrationMode::FairShare);
        let upd = arb.admit(0, 0, 0, 128, 100, 2000);
        let done = drain(&mut arb, vec![upd]);
        assert_eq!(done[&0], 5 + 200);
        assert!((arb.consumed_words() - 2000.0).abs() < 1e-6);
    }

    #[test]
    fn compute_bound_flight_ignores_interface() {
        let mut arb = BandwidthArbiter::new(dram(10.0, 5), ArbitrationMode::FairShare);
        let upd = arb.admit(0, 0, 0, 128, 1000, 50); // transfer 10 cycles + burst
        let done = drain(&mut arb, vec![upd]);
        assert_eq!(done[&0], 1000);
    }

    #[test]
    fn zero_traffic_flight_costs_no_burst() {
        let mut arb = BandwidthArbiter::new(dram(10.0, 100), ArbitrationMode::FairShare);
        let upd = arb.admit(0, 0, 0, 128, 40, 0);
        let done = drain(&mut arb, vec![upd]);
        assert_eq!(done[&0], 40);
    }

    #[test]
    fn fair_share_halves_two_equal_flights() {
        let mut arb = BandwidthArbiter::new(dram(10.0, 0), ArbitrationMode::FairShare);
        let u0 = arb.admit(0, 0, 0, 64, 10, 1000);
        assert_eq!(u0.reposts, vec![(0, 100)]);
        let u1 = arb.admit(0, 1, 1, 64, 10, 1000);
        // Both now see half the interface: 200 cycles each.
        let done = drain(&mut arb, vec![u0, u1]);
        assert_eq!(done[&0], 200);
        assert_eq!(done[&1], 200);
        assert!((arb.consumed_words() - 2000.0).abs() < 1e-6);
    }

    #[test]
    fn weighted_mode_favors_wide_partitions() {
        let mut arb = BandwidthArbiter::new(dram(10.0, 0), ArbitrationMode::WeightedByColumns);
        let u0 = arb.admit(0, 0, 0, 96, 10, 900); // 3/4 of the columns
        let u1 = arb.admit(0, 1, 1, 32, 10, 900); // 1/4
        let done = drain(&mut arb, vec![u0, u1]);
        // Wide: 900 words at 7.5 w/c = 120 cycles; narrow then drains the
        // remainder at full rate.
        assert_eq!(done[&0], 120);
        assert!(done[&1] > done[&0]);
        assert!((arb.consumed_words() - 1800.0).abs() < 1e-6);
    }

    #[test]
    fn strict_priority_serializes_transfers() {
        let mut arb = BandwidthArbiter::new(dram(10.0, 0), ArbitrationMode::StrictPriority);
        let u0 = arb.admit(0, 0, 0, 64, 10, 1000);
        let u1 = arb.admit(0, 1, 1, 64, 10, 1000);
        // Flight 1 is starved: no event posted for it yet.
        assert!(arb.predicted_end(1).is_none());
        let done = drain(&mut arb, vec![u0, u1]);
        assert_eq!(done[&0], 100, "priority holder sees the full interface");
        assert_eq!(done[&1], 200, "loser drains after the holder retires");
    }

    #[test]
    fn early_release_speeds_up_the_survivor() {
        // Flight 0: tiny transfer, long compute — its transfer drains
        // early and flight 1 must speed up mid-flight via the release
        // rescale, NOT wait for flight 0's completion.
        let mut arb = BandwidthArbiter::new(dram(10.0, 0), ArbitrationMode::FairShare);
        let u0 = arb.admit(0, 0, 0, 64, 1000, 100);
        let u1 = arb.admit(0, 1, 1, 64, 10, 1000);
        assert!(u1.next_release.is_some(), "flight 0's transfer drains before its compute");
        let done = drain(&mut arb, vec![u0, u1]);
        assert_eq!(done[&0], 1000);
        // Shared until t=20 (flight 0 moves 100 words at 5 w/c), then
        // full rate: 1000 - 20*5 = 900 words at 10 w/c => done at 110.
        assert_eq!(done[&1], 110);
        assert!((arb.consumed_words() - 1100.0).abs() < 1e-6);
    }

    #[test]
    fn preempted_flight_frees_its_share_early() {
        // Two equal flights split 10 w/c; preempting flight 0 at t=50
        // hands the whole interface to flight 1 mid-transfer.
        let mut arb = BandwidthArbiter::new(dram(10.0, 0), ArbitrationMode::FairShare);
        let u0 = arb.admit(0, 0, 0, 64, 10, 1000);
        let u1 = arb.admit(0, 1, 1, 64, 10, 1000);
        let (rep, upd) = arb.preempt(50, 0);
        assert_eq!(rep.t_end, 50);
        assert_eq!(rep.words, 250, "5 w/c for 50 cycles");
        assert_eq!(rep.compute_cycles, 10, "compute path had finished");
        assert_eq!(arb.in_flight(), 1);
        // Survivor: 250 words moved by t=50, 750 left at 10 w/c => 125.
        assert_eq!(upd.reposts, vec![(1, 125)]);
        let done = drain(&mut arb, vec![u0, u1, upd]);
        assert_eq!(done[&1], 125);
        // The ledger holds only what crossed the interface.
        assert!((arb.consumed_words() - (250.0 + 1000.0)).abs() < 1e-6);
    }

    #[test]
    fn stale_predictions_are_detected() {
        let mut arb = BandwidthArbiter::new(dram(10.0, 0), ArbitrationMode::FairShare);
        arb.admit(0, 0, 0, 64, 10, 1000); // predicted 100
        assert!(!arb.is_stale(0, 100));
        arb.admit(0, 1, 1, 64, 10, 1000); // both re-predicted to 200
        assert!(arb.is_stale(0, 100), "old prediction superseded");
        assert!(!arb.is_stale(0, 200));
        assert!(arb.is_stale(7, 0), "unknown flight is stale");
    }

    #[test]
    fn tags_round_trip() {
        for m in ArbitrationMode::ALL {
            assert_eq!(m.tag().parse::<ArbitrationMode>().unwrap(), m);
        }
        let e = "psychic".parse::<ArbitrationMode>().unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("fair") && msg.contains("weighted") && msg.contains("priority"), "{msg}");
    }
}
