//! The shared memory hierarchy — cross-tenant DRAM bandwidth arbitration
//! and banked global-buffer allocation, wired into the discrete-event
//! engine as a first-class resource.
//!
//! Before this module, the DRAM model priced each tenant's layer in
//! isolation ([`DramConfig::bound_cycles`](crate::sim::dram::DramConfig))
//! — co-running DNNs magically each saw the full interface, so memory
//! interference (the dominant multi-tenant effect per MoCA, arXiv
//! 2305.05843) was invisible to every policy and every sweep.  Enabled
//! via the `[mem]` config section (or
//! [`SchedulerConfig::mem`](crate::coordinator::scheduler::SchedulerConfig)),
//! the engine instead simulates:
//!
//! - [`BandwidthArbiter`] — processor-sharing of the DRAM interface among
//!   concurrently executing partitions (fair-share, weighted-by-columns,
//!   strict-priority).  At every event where the co-runner set changes,
//!   in-flight layers' remaining transfer work is rescaled and their
//!   completions re-posted.
//! - [`BankAllocator`] — the global buffer split into integral banks
//!   granted to partitions alongside their columns, replacing the
//!   proportional `BufferConfig::share` fiction: refetch traffic follows
//!   the banks a tenant actually owns.
//! - [`MemStats`] / [`MemFeedback`] — per-tenant stall cycles, achieved
//!   words/cycle and refetch bytes, flowing through the
//!   [`Observer`](crate::sim_core::Observer) into
//!   [`RunMetrics`](crate::coordinator::metrics::RunMetrics), the report
//!   tables/JSON and the energy estimator; the live feedback view is what
//!   the `mem-aware` policy throttles on.
//!
//! With `[mem]` disabled (the default) nothing here is instantiated and
//! every execution path reproduces today's outputs bit-for-bit
//! (`rust/tests/engine_parity.rs`).  See `docs/memory.md` for the
//! narrative and a worked example.

pub mod arbiter;
pub mod banks;
pub mod stats;

pub use arbiter::{ArbitrationMode, BandwidthArbiter, FlightReport, MemUpdate};
pub use banks::BankAllocator;
pub use stats::{MemFeedback, MemStats};

use std::collections::BTreeMap;

use crate::coordinator::partition::AllocId;
use crate::sim::activity::Activity;
use crate::sim::buffers::BufferConfig;
use crate::sim::dataflow::{layer_timing_tile_with_share, ArrayGeometry};
use crate::sim::dram::DramConfig;
use crate::sim::partitioned::Tile;
use crate::workloads::dnng::DnnId;
use crate::workloads::shapes::GemmDims;

/// `[mem]` — the shared memory-hierarchy configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemConfig {
    /// The shared DRAM interface (aggregate words/cycle + per-burst
    /// latency — the same parameters as the isolated `[dram]` bound,
    /// which this subsumes).
    pub dram: DramConfig,
    pub arbitration: ArbitrationMode,
    /// Global-buffer banks the [`BankAllocator`] hands out.
    pub banks: u64,
}

impl Default for MemConfig {
    fn default() -> Self {
        MemConfig { dram: DramConfig::default(), arbitration: ArbitrationMode::FairShare, banks: 8 }
    }
}

/// Everything the engine needs to instantiate the shared memory system
/// for one run — supplied by the policy via
/// [`Scheduler::mem_spec`](crate::sim_core::Scheduler::mem_spec), so
/// every entry point (`mtsa run`, scenarios, sweeps, `Engine::execute`)
/// gets contention through the one engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemSpec {
    pub cfg: MemConfig,
    pub geom: ArrayGeometry,
    /// Whole-array buffer capacity the banks split.
    pub buffers: BufferConfig,
}

/// The DRAM words a layer would move with *unbounded* SRAM: weights in
/// once, IFMap streamed once, OFMap out once.  Everything beyond this is
/// refetch traffic.
pub fn ideal_words(gemm: GemmDims) -> u64 {
    gemm.ideal_words()
}

/// Per-flight bookkeeping the arbiter does not own.
#[derive(Debug, Clone, Copy)]
struct FlightMeta {
    refetch_words: u64,
    /// Intrinsically memory-bound (transfer need beats compute even at
    /// the full interface) — feeds [`MemFeedback::inflight_bound`].
    bound: bool,
}

/// The engine-owned memory system: arbiter + bank allocator + stats.
///
/// Lifecycle per dispatched layer: [`MemSystem::admit`] grants banks,
/// re-prices the layer's DRAM traffic under the *banked* share (the
/// activity the observer bills), and registers the transfer with the
/// arbiter; [`MemSystem::retire`] at the (possibly rescaled) completion
/// releases the banks and emits the layer's [`MemStats`].
#[derive(Debug, Clone)]
pub struct MemSystem {
    spec: MemSpec,
    arbiter: BandwidthArbiter,
    banks: BankAllocator,
    feedback: MemFeedback,
    meta: BTreeMap<AllocId, FlightMeta>,
}

impl MemSystem {
    pub fn new(spec: MemSpec) -> MemSystem {
        MemSystem {
            arbiter: BandwidthArbiter::new(spec.cfg.dram, spec.cfg.arbitration),
            banks: BankAllocator::new(spec.cfg.banks.max(1), spec.geom.pes()),
            feedback: MemFeedback::default(),
            meta: BTreeMap::new(),
            spec,
        }
    }

    pub fn spec(&self) -> &MemSpec {
        &self.spec
    }

    /// The live feedback view policies read through
    /// [`SystemState::mem`](crate::sim_core::SystemState).
    pub fn feedback(&self) -> &MemFeedback {
        &self.feedback
    }

    /// Admit a dispatched layer: grant banks, price its DRAM traffic
    /// under the banked share, register the transfer.  Returns the
    /// banked [`Activity`] (what the observer should bill) and the
    /// event-queue corrections (which include the new flight's own
    /// completion).
    pub fn admit(
        &mut self,
        now: u64,
        alloc: AllocId,
        dnn: DnnId,
        gemm: GemmDims,
        tile: Tile,
        compute_cycles: u64,
    ) -> (Activity, MemUpdate) {
        let got = self.banks.grant(alloc, tile.pes());
        let share = self.banks.share_of(got, &self.spec.buffers);
        let t = layer_timing_tile_with_share(self.spec.geom, gemm, tile, &share, None);
        let words = t.activity.dram_accesses();
        let refetch = words.saturating_sub(ideal_words(gemm));
        let bound = self.spec.cfg.dram.transfer_cycles(&t.activity) > compute_cycles;
        if bound {
            *self.feedback.inflight_bound.entry(dnn).or_insert(0) += 1;
        }
        self.meta.insert(alloc, FlightMeta { refetch_words: refetch, bound });
        // The arbiter weights shares in column-equivalents (tile PEs /
        // array rows — exactly the column span for full-height tiles),
        // which also keeps `stall_col_cycles` in the units the energy
        // model bills.
        let width = (tile.pes() / self.spec.geom.rows).max(1);
        let upd = self.arbiter.admit(now, alloc, dnn, width, compute_cycles, words);
        (t.activity, upd)
    }

    /// Admit a layer dispatched onto the *vector lanes*: lane flows are
    /// first-class arbiter citizens, competing for the same DRAM
    /// interface as every array partition.  Lanes stream operands
    /// directly (no tiled refetch, no banked SRAM working set), so the
    /// transfer is exactly [`ideal_words`] and no banks are granted; the
    /// arbiter weight is one column-equivalent — a lane group occupies
    /// one drain port's worth of the interface, matching the width-1
    /// share the narrowest array slice gets.
    pub fn admit_vector(
        &mut self,
        now: u64,
        alloc: AllocId,
        dnn: DnnId,
        gemm: GemmDims,
        compute_cycles: u64,
        activity: Activity,
    ) -> (Activity, MemUpdate) {
        let words = ideal_words(gemm);
        let bound = self.spec.cfg.dram.transfer_cycles(&activity) > compute_cycles;
        if bound {
            *self.feedback.inflight_bound.entry(dnn).or_insert(0) += 1;
        }
        self.meta.insert(alloc, FlightMeta { refetch_words: 0, bound });
        let upd = self.arbiter.admit(now, alloc, dnn, 1, compute_cycles, words);
        (activity, upd)
    }

    /// True when a `LayerComplete { t, alloc }` event was superseded by a
    /// rescale (or the flight already retired) and must be skipped.
    pub fn is_stale(&self, alloc: AllocId, t: u64) -> bool {
        self.arbiter.is_stale(alloc, t)
    }

    /// Retire a flight at its completion cycle: release banks, emit its
    /// stats, and return the survivors' corrections.
    pub fn retire(&mut self, now: u64, alloc: AllocId) -> (MemStats, MemUpdate) {
        let (rep, upd) = self.arbiter.retire(now, alloc);
        let stats = self.close_flight(alloc, &rep, u64::MAX);
        (stats, upd)
    }

    /// Early-retire a flight at a fold-boundary preemption: the drained
    /// segment's banks release and its stats cover only the words it
    /// actually moved (refetch attribution is clamped accordingly — the
    /// resumed remainder re-admits the rest as a fresh flight).
    pub fn preempt(&mut self, now: u64, alloc: AllocId) -> (MemStats, MemUpdate) {
        let (rep, upd) = self.arbiter.preempt(now, alloc);
        let stats = self.close_flight(alloc, &rep, rep.words);
        (stats, upd)
    }

    /// Shared retire/preempt bookkeeping: banks, stats, bound counter,
    /// per-tenant feedback.  `refetch_cap` clamps the refetch attribution
    /// for partially-moved flights.
    fn close_flight(&mut self, alloc: AllocId, rep: &FlightReport, refetch_cap: u64) -> MemStats {
        let meta = self.meta.remove(&alloc).expect("close of unadmitted flight");
        self.banks.release(alloc);
        let busy = rep.t_end - rep.t_start;
        let stall = busy.saturating_sub(rep.compute_cycles);
        let stats = MemStats {
            layers: 1,
            stall_cycles: stall,
            stall_col_cycles: stall.saturating_mul(rep.width),
            busy_cycles: busy,
            xfer_words: rep.words,
            refetch_words: meta.refetch_words.min(refetch_cap),
        };
        if meta.bound {
            let c = self
                .feedback
                .inflight_bound
                .get_mut(&rep.dnn)
                .expect("bound flight retired without an inflight_bound entry");
            *c -= 1;
            if *c == 0 {
                self.feedback.inflight_bound.remove(&rep.dnn);
            }
        }
        self.feedback.per_dnn.entry(rep.dnn).or_default().add(&stats);
        stats
    }

    /// An early bandwidth release fired: rescale the survivors.
    pub fn rescale(&mut self, now: u64) -> MemUpdate {
        self.arbiter.rescale(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(wpc: f64, banks: u64) -> MemSpec {
        MemSpec {
            cfg: MemConfig {
                dram: DramConfig { words_per_cycle: wpc, burst_latency: 0 },
                arbitration: ArbitrationMode::FairShare,
                banks,
            },
            geom: ArrayGeometry::new(128, 128),
            buffers: BufferConfig::default(),
        }
    }

    #[test]
    fn admit_prices_banked_traffic_and_retire_reports_stall() {
        let mut mem = MemSystem::new(spec(1.0, 8));
        let gemm = GemmDims { sr: 512, k: 128, m: 64 };
        let tile = Tile::new(0, 0, 128, 64);
        let (activity, upd) = mem.admit(0, 0, 0, gemm, tile, 1000);
        let words = activity.dram_accesses();
        assert!(words >= ideal_words(gemm));
        // Strongly memory-bound at 1 word/cycle.
        assert_eq!(mem.feedback().inflight_bound.get(&0), Some(&1));
        let (_, t_end) = upd.reposts.iter().find(|&&(a, _)| a == 0).copied().unwrap();
        assert_eq!(t_end, words, "transfer-bound completion at words / 1.0 w/c");
        let (stats, _) = mem.retire(t_end, 0);
        assert_eq!(stats.busy_cycles, t_end);
        assert_eq!(stats.stall_cycles, t_end - 1000);
        assert_eq!(stats.stall_col_cycles, (t_end - 1000) * 64);
        assert_eq!(stats.xfer_words, words);
        assert!(mem.feedback().inflight_bound.is_empty());
        assert_eq!(mem.feedback().tenant(0).unwrap().layers, 1);
    }

    #[test]
    fn fewer_banks_mean_more_refetch_words() {
        // A tenant admitted after the pool is drained gets no banks at
        // all and pays in IFMap refetches — traffic the proportional
        // `BufferConfig::share` fiction would never show.
        let gemm = GemmDims { sr: 4000, k: 512, m: 256 }; // fm = 4 on 64 cols
        let tile = Tile::new(0, 0, 128, 64);
        let mut rich = MemSystem::new(spec(64.0, 8));
        let (a_rich, _) = rich.admit(0, 0, 0, gemm, tile, 1_000_000);
        let mut poor = MemSystem::new(spec(64.0, 2));
        // A full-width tenant exhausts the two banks first.
        let (_, _) = poor.admit(0, 7, 7, gemm, Tile::new(0, 0, 128, 128), 1_000_000);
        let (a_poor, _) = poor.admit(0, 0, 0, gemm, tile, 1_000_000);
        assert!(
            a_poor.dram_accesses() > a_rich.dram_accesses(),
            "starved banks must inflate traffic: {} vs {}",
            a_poor.dram_accesses(),
            a_rich.dram_accesses()
        );
        // And the surplus is exactly what `refetch_words` accounts.
        let ideal = ideal_words(gemm);
        assert!(a_poor.dram_accesses() - ideal > a_rich.dram_accesses() - ideal);
    }

    #[test]
    fn preempt_releases_banks_and_bound_tracking_early() {
        let mut mem = MemSystem::new(spec(1.0, 8));
        let gemm = GemmDims { sr: 512, k: 128, m: 64 };
        let (activity, _) = mem.admit(0, 0, 0, gemm, Tile::new(0, 0, 128, 64), 1000);
        assert_eq!(mem.feedback().inflight_bound.get(&0), Some(&1));
        let (stats, _) = mem.preempt(500, 0);
        assert_eq!(stats.busy_cycles, 500);
        assert!(stats.xfer_words <= activity.dram_accesses(), "only moved words are billed");
        assert!(stats.xfer_words >= 499, "1 w/c for 500 cycles minus burst setup");
        assert!(mem.feedback().inflight_bound.is_empty(), "bound tracking released");
        // The remainder can re-admit under the same alloc id.
        let (_, upd) = mem.admit(500, 0, 0, gemm, Tile::new(0, 0, 128, 32), 1000);
        assert!(upd.reposts.iter().any(|&(a, _)| a == 0));
    }

    #[test]
    fn compute_bound_layer_has_no_stall() {
        let mut mem = MemSystem::new(spec(1_000_000.0, 8));
        let gemm = GemmDims { sr: 64, k: 64, m: 64 };
        let (_, upd) = mem.admit(0, 0, 0, gemm, Tile::new(0, 0, 128, 64), 50_000);
        let (_, t_end) = upd.reposts.iter().find(|&&(a, _)| a == 0).copied().unwrap();
        assert_eq!(t_end, 50_000);
        let (stats, _) = mem.retire(t_end, 0);
        assert_eq!(stats.stall_cycles, 0);
        assert!(mem.feedback().inflight_bound.is_empty(), "not memory-bound");
    }

    #[test]
    fn ideal_words_formula() {
        let g = GemmDims { sr: 10, k: 20, m: 30 };
        assert_eq!(ideal_words(g), 20 * 30 + 10 * 20 + 10 * 30);
    }
}
