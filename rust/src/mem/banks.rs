//! The bank allocator — integral global-buffer banks granted to
//! partitions alongside their PEs.
//!
//! The paper shares "parts of each storage element" with the PE columns;
//! [`BufferConfig::share`](crate::sim::buffers::BufferConfig::share)
//! models that as an exact proportional split, which no banked SRAM can
//! deliver.  This allocator splits each buffer into `total` equal banks
//! and hands out *whole* banks: a partition asks for the count
//! proportional to its **tile footprint** (PEs held — under 2D fission a
//! half-height tile earns half the banks of a full column slice of the
//! same width; for full-height tiles this reduces exactly to the old
//! column-span grant), gets at least one, and is capped by what the pool
//! still holds — so a late tenant under heavy co-residency really does
//! run with less SRAM than its share suggests, and its refetch traffic
//! (and therefore its DRAM interference) follows the banks it actually
//! owns.

use std::collections::BTreeMap;

use crate::coordinator::partition::AllocId;
use crate::sim::buffers::BufferConfig;

/// Grants whole buffer banks to live allocations.
#[derive(Debug, Clone)]
pub struct BankAllocator {
    total: u64,
    /// Total PEs the banks are split over (the whole array).
    pes: u64,
    free: u64,
    granted: BTreeMap<AllocId, u64>,
}

impl BankAllocator {
    /// An allocator of `total` banks over an array of `pes` PEs.
    pub fn new(total: u64, pes: u64) -> BankAllocator {
        assert!(total >= 1 && pes >= 1);
        BankAllocator { total, pes, free: total, granted: BTreeMap::new() }
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn free_banks(&self) -> u64 {
        self.free
    }

    /// Banks currently held by allocation `id` (0 if unknown).
    pub fn granted(&self, id: AllocId) -> u64 {
        self.granted.get(&id).copied().unwrap_or(0)
    }

    /// Grant banks to a partition holding `tile_pes` PEs: the
    /// proportional count (at least one), capped by the free pool.
    /// Returns the grant — a grant of 0 means the pool was exhausted and
    /// the tenant runs with the minimal (one-word) share.
    pub fn grant(&mut self, id: AllocId, tile_pes: u64) -> u64 {
        assert!(tile_pes >= 1 && !self.granted.contains_key(&id), "double grant for {id}");
        let want = (self.total * tile_pes / self.pes).max(1);
        let got = want.min(self.free);
        self.free -= got;
        self.granted.insert(id, got);
        got
    }

    /// Release the banks of allocation `id` back to the pool.
    pub fn release(&mut self, id: AllocId) -> u64 {
        let got = self.granted.remove(&id).unwrap_or_else(|| panic!("release of unknown grant {id}"));
        self.free += got;
        got
    }

    /// The absolute SRAM capacity `got` banks of `bufs` carry (every
    /// buffer banked the same way, min one dtype word — mirrors
    /// [`BufferConfig::share`]).
    pub fn share_of(&self, got: u64, bufs: &BufferConfig) -> BufferConfig {
        let scale = |b: u64| (b * got / self.total).max(bufs.dtype_bytes);
        BufferConfig {
            weight_bytes: scale(bufs.weight_bytes),
            ifmap_bytes: scale(bufs.ifmap_bytes),
            ofmap_bytes: scale(bufs.ofmap_bytes),
            dtype_bytes: bufs.dtype_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// PE footprint of a full-height slice `width` columns wide on the
    /// default 128-row array.
    fn cols_pes(width: u64) -> u64 {
        width * 128
    }

    #[test]
    fn proportional_grants_and_release() {
        let mut b = BankAllocator::new(8, cols_pes(128));
        assert_eq!(b.grant(0, cols_pes(64)), 4);
        assert_eq!(b.grant(1, cols_pes(32)), 2);
        assert_eq!(b.free_banks(), 2);
        assert_eq!(b.granted(0), 4);
        assert_eq!(b.release(0), 4);
        assert_eq!(b.free_banks(), 6);
        assert_eq!(b.granted(0), 0);
    }

    #[test]
    fn footprint_grants_follow_tile_height() {
        // A half-height tile earns half the banks of the full column
        // slice at the same width — the 2D generalization.
        let mut b = BankAllocator::new(8, cols_pes(128));
        assert_eq!(b.grant(0, 64 * 64), 2, "64x64 quadrant = quarter of the array");
        assert_eq!(b.grant(1, 64 * 128), 4, "full-height 64 cols = half");
    }

    #[test]
    fn narrow_partition_still_gets_one_bank() {
        let mut b = BankAllocator::new(8, cols_pes(128));
        assert_eq!(b.grant(0, 1), 1);
    }

    #[test]
    fn exhausted_pool_grants_zero() {
        let mut b = BankAllocator::new(2, cols_pes(128));
        assert_eq!(b.grant(0, cols_pes(128)), 2);
        assert_eq!(b.grant(1, cols_pes(64)), 0, "pool exhausted: late tenant starved");
        b.release(0);
        assert_eq!(b.free_banks(), 2);
    }

    #[test]
    #[should_panic(expected = "unknown grant")]
    fn double_release_panics() {
        let mut b = BankAllocator::new(4, cols_pes(128));
        b.grant(0, cols_pes(32));
        b.release(0);
        b.release(0);
    }

    #[test]
    fn share_scales_with_banks() {
        let b = BankAllocator::new(4, cols_pes(128));
        let bufs = BufferConfig { weight_bytes: 400, ifmap_bytes: 800, ofmap_bytes: 1200, dtype_bytes: 1 };
        let half = b.share_of(2, &bufs);
        assert_eq!(half.weight_bytes, 200);
        assert_eq!(half.ifmap_bytes, 400);
        assert_eq!(half.ofmap_bytes, 600);
        let full = b.share_of(4, &bufs);
        assert_eq!(full, bufs);
        // A zero-bank grant leaves the one-word minimum.
        let none = b.share_of(0, &bufs);
        assert_eq!(none.weight_bytes, 1);
    }

    #[test]
    fn one_bank_per_column_matches_proportional_share() {
        // With `banks == cols` the integral grant reproduces the exact
        // proportional split — the fiction is the limit of fine banking.
        let mut b = BankAllocator::new(128, cols_pes(128));
        let bufs = BufferConfig::default();
        let got = b.grant(0, cols_pes(32));
        assert_eq!(got, 32);
        assert_eq!(b.share_of(got, &bufs), bufs.share(32, 128));
    }
}
