//! Per-tenant memory-system statistics and the live feedback view the
//! `mem-aware` policy decides over.

use std::collections::BTreeMap;

use crate::workloads::dnng::DnnId;

/// Accumulated memory-hierarchy statistics for one tenant (or one layer,
/// or a whole run — the struct is additive via [`MemStats::add`]).
///
/// All counts come from the [`BandwidthArbiter`](super::BandwidthArbiter)
/// and [`BankAllocator`](super::BankAllocator): `stall_cycles` is time a
/// layer was resident beyond its compute need (waiting on the shared DRAM
/// interface), `xfer_words` the DRAM words actually moved (banked
/// refetches included), and `refetch_words` the words beyond the
/// single-pass ideal — the traffic a bigger bank grant would have
/// eliminated.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemStats {
    /// Layers accumulated into this record.
    pub layers: u64,
    /// Cycles spent resident beyond the compute need (DRAM stall).
    pub stall_cycles: u64,
    /// Stall cycles weighted by partition width (column-cycles of PEs
    /// held but starved) — the idle-leakage term the energy model prices
    /// via [`EnergyModel::stall_j`](crate::energy::components::EnergyModel::stall_j).
    pub stall_col_cycles: u64,
    /// Total cycles layers were resident (dispatch → completion).
    pub busy_cycles: u64,
    /// DRAM words moved (reads + writes, refetches included).
    pub xfer_words: u64,
    /// Words beyond the single-pass ideal (weights once, IFMap once,
    /// OFMap out once) — refetch traffic caused by the banks actually
    /// owned.
    pub refetch_words: u64,
}

impl MemStats {
    /// Element-wise accumulate.
    pub fn add(&mut self, other: &MemStats) {
        self.layers += other.layers;
        self.stall_cycles += other.stall_cycles;
        self.stall_col_cycles += other.stall_col_cycles;
        self.busy_cycles += other.busy_cycles;
        self.xfer_words += other.xfer_words;
        self.refetch_words += other.refetch_words;
    }

    /// Mean DRAM words delivered per resident cycle (0.0 when idle) —
    /// the *achieved* bandwidth, to compare against the interface's
    /// `words_per_cycle`.
    pub fn achieved_words_per_cycle(&self) -> f64 {
        if self.busy_cycles == 0 {
            0.0
        } else {
            self.xfer_words as f64 / self.busy_cycles as f64
        }
    }

    /// Fraction of residency spent stalled on memory (0.0 when idle).
    pub fn stall_fraction(&self) -> f64 {
        if self.busy_cycles == 0 {
            0.0
        } else {
            self.stall_cycles as f64 / self.busy_cycles as f64
        }
    }
}

/// Live arbiter feedback exposed to policies through
/// [`SystemState::mem`](crate::sim_core::SystemState) — what the
/// `mem-aware` policy reads to detect memory-bound tenants.
#[derive(Debug, Clone, Default)]
pub struct MemFeedback {
    /// Per-DNN accumulated stats over *finished* layers.
    pub per_dnn: BTreeMap<DnnId, MemStats>,
    /// Count of in-flight layers per DNN that are intrinsically
    /// memory-bound (transfer need exceeds compute need even at full
    /// interface bandwidth).
    pub inflight_bound: BTreeMap<DnnId, usize>,
}

impl MemFeedback {
    /// Accumulated stats of one tenant's finished layers.
    pub fn tenant(&self, dnn: DnnId) -> Option<&MemStats> {
        self.per_dnn.get(&dnn)
    }

    /// Memory-bound layers currently in flight for tenants *other* than
    /// `dnn` — the signal the `mem-aware` policy throttles on.
    pub fn bound_inflight_excluding(&self, dnn: DnnId) -> usize {
        self.inflight_bound.iter().filter(|&(&d, _)| d != dnn).map(|(_, &c)| c).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_accumulate() {
        let mut a = MemStats {
            layers: 1,
            stall_cycles: 100,
            stall_col_cycles: 3200,
            busy_cycles: 400,
            xfer_words: 800,
            refetch_words: 50,
        };
        let b = MemStats { layers: 2, busy_cycles: 100, xfer_words: 200, ..Default::default() };
        a.add(&b);
        assert_eq!(a.layers, 3);
        assert_eq!(a.busy_cycles, 500);
        assert_eq!(a.xfer_words, 1000);
        assert!((a.achieved_words_per_cycle() - 2.0).abs() < 1e-12);
        assert!((a.stall_fraction() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_have_zero_rates() {
        let s = MemStats::default();
        assert_eq!(s.achieved_words_per_cycle(), 0.0);
        assert_eq!(s.stall_fraction(), 0.0);
    }

    #[test]
    fn feedback_excludes_own_tenant() {
        let mut fb = MemFeedback::default();
        fb.inflight_bound.insert(0, 2);
        fb.inflight_bound.insert(1, 1);
        assert_eq!(fb.bound_inflight_excluding(0), 1);
        assert_eq!(fb.bound_inflight_excluding(1), 2);
        assert_eq!(fb.bound_inflight_excluding(9), 3);
        assert!(fb.tenant(0).is_none());
    }
}
