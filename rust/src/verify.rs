//! Cross-model verification: the register-level functional simulator, the
//! CPU oracle, and the AOT-compiled PJRT artifacts must all agree on the
//! partitioned weight-stationary computation.
//!
//! This is the repo's deepest consistency check — it ties the *timing*
//! model's hardware semantics (L3 `sim::array`, the Fig. 7 PE) to the
//! *functional* datapath (L1 Pallas kernel via PJRT) through the shared
//! packing layer.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::runtime::packing::{pack_step, packed_step_oracle, TenantTile};
use crate::runtime::{Engine, Tensor};
use crate::sim::array::{simulate_step, StepTile};
use crate::util::rng::Rng;

fn rand_tensor(rng: &mut Rng, shape: Vec<usize>) -> Tensor {
    let n = shape.iter().product();
    Tensor::new(shape, (0..n).map(|_| rng.gen_f32() - 0.5).collect())
}

/// One randomized cross-check of `num_p` tenants on the artifact geometry.
///
/// Asserts (a) functional sim == oracle, (b) PJRT artifact == oracle, for
/// every tenant's output slice.  Returns the number of comparisons.
pub fn cross_check(engine: &Engine, rng: &mut Rng, num_p: usize) -> Result<usize> {
    let m = engine.manifest();
    let (s, k, c) = (m.array_s, m.array_k, m.array_c);
    let width = c / num_p;

    // Random ragged tiles, one per tenant.  Stream and depth are capped so
    // the register-level sim (O(rows·cols·cycles)) stays fast; the PJRT
    // artifact still runs at its full fixed geometry via zero padding.
    let sim_rows = 48usize.min(k);
    let tiles: Vec<TenantTile> = (0..num_p)
        .map(|t| {
            let sr = 1 + rng.gen_range(48.min(s as u64)) as usize;
            let kd = 1 + rng.gen_range(sim_rows as u64) as usize;
            let wc = 1 + rng.gen_range(width as u64) as usize;
            TenantTile {
                tenant: t,
                x: rand_tensor(rng, vec![sr, kd]),
                w: rand_tensor(rng, vec![kd, wc]),
            }
        })
        .collect();

    let step = pack_step(&tiles, s, k, c, num_p)?;
    let acc = Tensor::zeros(vec![s, c]);

    // (1) PJRT artifact.
    let pjrt = engine.execute(
        &format!("pws_p{num_p}"),
        &[step.x.clone(), step.w.clone(), step.mask.clone(), acc.clone()],
    )?;
    // (2) CPU oracle.
    let oracle = packed_step_oracle(&step, &acc);
    ensure!(
        pjrt.max_abs_diff(&oracle) < 1e-3,
        "PJRT vs oracle diff {}",
        pjrt.max_abs_diff(&oracle)
    );

    // (3) Functional register-level sim (on the same column layout, with
    // interleaved shared wires — the honest hardware model).
    let mut col0 = 0usize;
    let sim_tiles: Vec<StepTile> = tiles
        .iter()
        .map(|t| {
            let st = StepTile { x: t.x.clone(), w: t.w.clone(), col0 };
            col0 += t.w.shape()[1];
            st
        })
        .collect();
    let r = simulate_step(sim_rows, c, &sim_tiles, true, None);

    let mut checks = 1usize; // the PJRT-vs-oracle check above
    for (i, tile) in tiles.iter().enumerate() {
        let want = tile.x.matmul(&tile.w);
        ensure!(
            r.outputs[i].max_abs_diff(&want) < 1e-3,
            "functional sim vs matmul diff {} (tenant {i})",
            r.outputs[i].max_abs_diff(&want)
        );
        let got = step.unpack(&pjrt, i);
        ensure!(
            got.max_abs_diff(&want) < 1e-3,
            "PJRT slice vs matmul diff {} (tenant {i})",
            got.max_abs_diff(&want)
        );
        checks += 2;
    }
    Ok(checks)
}

/// Run the full verification battery against an artifacts directory.
pub fn verify_all(artifacts_dir: &Path) -> Result<usize> {
    let engine = Engine::load(artifacts_dir).context("loading artifacts")?;
    let mut rng = Rng::new(0xEC0_FFEE);
    let mut total = 0usize;
    for num_p in [1usize, 2, 4] {
        for round in 0..3 {
            total += cross_check(&engine, &mut rng, num_p)
                .with_context(|| format!("cross_check p={num_p} round={round}"))?;
        }
    }
    Ok(total)
}
