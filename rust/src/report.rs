//! Experiment drivers shared by the benches, the CLI and the integration
//! tests — one implementation of every Fig. 9 series so the numbers in
//! `cargo bench`, `mtsa run` and `EXPERIMENTS.md` cannot drift apart.

use std::collections::BTreeMap;

use crate::coordinator::baseline::SequentialBaseline;
use crate::coordinator::scheduler::{AllocPolicy, DynamicScheduler, SchedulerConfig};
use crate::coordinator::RunMetrics;
use crate::energy::{EnergyBreakdown, EnergyModel, Estimator};
use crate::workloads::dnng::WorkloadPool;

/// Results of running one pool under both the baseline and the dynamic
/// partitioning scheduler.
#[derive(Debug, Clone)]
pub struct GroupResults {
    pub pool_name: String,
    pub dynamic: RunMetrics,
    pub sequential: RunMetrics,
    pub cfg: SchedulerConfig,
}

/// Run a pool under sequential + dynamic scheduling.
pub fn run_group(pool: &WorkloadPool, cfg: &SchedulerConfig) -> GroupResults {
    GroupResults {
        pool_name: pool.name.clone(),
        dynamic: DynamicScheduler::new(cfg.clone()).run(pool),
        sequential: SequentialBaseline::new(cfg.clone()).run(pool),
        cfg: cfg.clone(),
    }
}

/// Run with an explicit allocation policy (for the policy ablation).
pub fn run_group_with_policy(
    pool: &WorkloadPool,
    cfg: &SchedulerConfig,
    policy: AllocPolicy,
) -> GroupResults {
    let cfg = SchedulerConfig { alloc_policy: policy, ..cfg.clone() };
    run_group(pool, &cfg)
}

/// Total-energy breakdown of a run (dynamic activity + makespan static).
pub fn total_energy(m: &RunMetrics, model: &EnergyModel) -> EnergyBreakdown {
    let mut est = Estimator::new(*model);
    for d in &m.dispatches {
        est.record(&d.dnn_name, &d.activity);
    }
    est.finish(m.makespan)
}

/// Per-DNN energy bars — the accounting of the paper's Fig. 9(e)(f):
/// each DNN's bar is its own dynamic energy plus the array static energy
/// attributed to its residency, weighted by the fraction of the array it
/// occupied (`width/cols`).  Under the sequential baseline every layer
/// occupies the full array, so a DNN is billed the whole static power for
/// its whole execution window; under partitioning, co-residents split it.
pub fn per_dnn_energy_bars(m: &RunMetrics, model: &EnergyModel) -> BTreeMap<String, f64> {
    let rate = model.static_rate_j_per_cycle();
    let cols = model.geom.cols as f64;
    let mut bars: BTreeMap<String, f64> = BTreeMap::new();
    let mut est = Estimator::new(*model);
    for d in &m.dispatches {
        est.record(&d.dnn_name, &d.activity);
        *bars.entry(d.dnn_name.clone()).or_default() +=
            rate * d.duration() as f64 * (d.slice.width as f64 / cols);
    }
    let bd = est.finish(m.makespan);
    for (name, dyn_j) in bd.per_dnn_dynamic_j {
        *bars.entry(name).or_default() += dyn_j;
    }
    bars
}

/// Percentage saving of `new` vs `base` (positive = improvement).
pub fn saving_pct(base: f64, new: f64) -> f64 {
    if base == 0.0 {
        0.0
    } else {
        100.0 * (1.0 - new / base)
    }
}

/// Mean completion cycle over DNNs.
pub fn mean_completion(m: &RunMetrics) -> f64 {
    if m.completion.is_empty() {
        return 0.0;
    }
    m.completion.values().sum::<u64>() as f64 / m.completion.len() as f64
}

/// Headline summary of one group (the H1 row of DESIGN.md §7).
#[derive(Debug, Clone)]
pub struct Headline {
    pub pool: String,
    pub makespan_saving_pct: f64,
    pub mean_completion_saving_pct: f64,
    pub total_energy_saving_pct: f64,
    pub mean_bar_energy_saving_pct: f64,
    pub dyn_utilization: f64,
    pub seq_utilization: f64,
}

/// Compute the headline metrics for a group result.
pub fn headline(g: &GroupResults, model: &EnergyModel) -> Headline {
    let e_dyn = total_energy(&g.dynamic, model);
    let e_seq = total_energy(&g.sequential, model);
    let bars_dyn = per_dnn_energy_bars(&g.dynamic, model);
    let bars_seq = per_dnn_energy_bars(&g.sequential, model);
    let mean = |b: &BTreeMap<String, f64>| b.values().sum::<f64>() / b.len().max(1) as f64;
    Headline {
        pool: g.pool_name.clone(),
        makespan_saving_pct: saving_pct(g.sequential.makespan as f64, g.dynamic.makespan as f64),
        mean_completion_saving_pct: saving_pct(
            mean_completion(&g.sequential),
            mean_completion(&g.dynamic),
        ),
        total_energy_saving_pct: saving_pct(e_seq.total_j(), e_dyn.total_j()),
        mean_bar_energy_saving_pct: saving_pct(mean(&bars_seq), mean(&bars_dyn)),
        dyn_utilization: g.dynamic.utilization(g.cfg.geom),
        seq_utilization: g.sequential.utilization(g.cfg.geom),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::dnng::{Dnn, Layer};
    use crate::workloads::shapes::{LayerKind, LayerShape};

    fn small_pool() -> WorkloadPool {
        let mk = |name: &str, m: u64, n_layers: usize| {
            let layers = (0..n_layers)
                .map(|i| Layer::new(&format!("l{i}"), LayerKind::Fc, LayerShape::fc(64, 128, m)))
                .collect();
            Dnn::chain(name, layers)
        };
        WorkloadPool::new("small", vec![mk("a", 64, 3), mk("b", 32, 2), mk("c", 16, 2)])
    }

    #[test]
    fn group_runs_both_schedulers() {
        let g = run_group(&small_pool(), &SchedulerConfig::default());
        assert_eq!(g.dynamic.dispatches.len(), 7);
        assert_eq!(g.sequential.dispatches.len(), 7);
        assert!(g.dynamic.makespan <= g.sequential.makespan);
    }

    #[test]
    fn bars_cover_every_dnn() {
        let g = run_group(&small_pool(), &SchedulerConfig::default());
        let model = EnergyModel::default_128();
        let bars = per_dnn_energy_bars(&g.dynamic, &model);
        assert_eq!(bars.len(), 3);
        assert!(bars.values().all(|&v| v > 0.0));
    }

    #[test]
    fn shared_static_attribution_smaller_than_exclusive() {
        // Under partitioning, a narrow-width DNN is billed a width fraction
        // of the static power, so its bar must not exceed its sequential bar
        // by more than its (possibly longer) runtime would explain.
        let g = run_group(&small_pool(), &SchedulerConfig::default());
        let model = EnergyModel::default_128();
        let bars_dyn = per_dnn_energy_bars(&g.dynamic, &model);
        let bars_seq = per_dnn_energy_bars(&g.sequential, &model);
        let sum_dyn: f64 = bars_dyn.values().sum();
        let sum_seq: f64 = bars_seq.values().sum();
        // All layers here have m <= 64 (width-insensitive), so the shared
        // accounting must strictly win in aggregate.
        assert!(sum_dyn < sum_seq, "dyn {sum_dyn} vs seq {sum_seq}");
    }

    #[test]
    fn saving_pct_signs() {
        assert!((saving_pct(100.0, 50.0) - 50.0).abs() < 1e-12);
        assert!(saving_pct(100.0, 120.0) < 0.0);
        assert_eq!(saving_pct(0.0, 5.0), 0.0);
    }

    #[test]
    fn headline_is_consistent() {
        let g = run_group(&small_pool(), &SchedulerConfig::default());
        let model = EnergyModel::default_128();
        let h = headline(&g, &model);
        assert!(h.makespan_saving_pct >= 0.0);
        assert!(h.dyn_utilization >= h.seq_utilization);
    }
}
