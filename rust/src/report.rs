//! Experiment drivers shared by the benches, the CLI and the integration
//! tests — one implementation of every Fig. 9 series so the numbers in
//! `cargo bench`, `mtsa run` and `EXPERIMENTS.md` cannot drift apart —
//! plus the JSON/table renderers for the scenario sweep
//! ([`sweep_table`], [`sweep_json`]).

use std::collections::BTreeMap;

use crate::coordinator::baseline::SequentialBaseline;
use crate::coordinator::metrics::TenantStats;
use crate::coordinator::scheduler::{
    AllocPolicy, DynamicScheduler, PartitionMode, PreemptMode, SchedulerConfig,
};
use crate::coordinator::RunMetrics;
use crate::energy::{EnergyBreakdown, EnergyModel, Estimator};
use crate::fleet::FleetReport;
use crate::mem::MemStats;
use crate::sweep::{FleetAxisRow, SweepGrid, SweepRow};
use crate::util::json::Json;
use crate::util::tablefmt::Table;
use crate::workloads::dnng::WorkloadPool;

/// Results of running one pool under both the baseline and the dynamic
/// partitioning scheduler.
#[derive(Debug, Clone)]
pub struct GroupResults {
    pub pool_name: String,
    pub dynamic: RunMetrics,
    pub sequential: RunMetrics,
    pub cfg: SchedulerConfig,
}

/// Run a pool under sequential + dynamic scheduling — both policies on
/// the one shared engine (the `run` wrappers are `Engine::execute`),
/// metrics collected by the same observer.
pub fn run_group(pool: &WorkloadPool, cfg: &SchedulerConfig) -> GroupResults {
    GroupResults {
        pool_name: pool.name.clone(),
        dynamic: DynamicScheduler::new(cfg.clone()).run(pool),
        sequential: SequentialBaseline::new(cfg.clone()).run(pool),
        cfg: cfg.clone(),
    }
}

/// Run with an explicit allocation policy (for the policy ablation).
pub fn run_group_with_policy(
    pool: &WorkloadPool,
    cfg: &SchedulerConfig,
    policy: AllocPolicy,
) -> GroupResults {
    let cfg = SchedulerConfig { alloc_policy: policy, ..cfg.clone() };
    run_group(pool, &cfg)
}

/// Total-energy breakdown of a run (dynamic activity + makespan static).
pub fn total_energy(m: &RunMetrics, model: &EnergyModel) -> EnergyBreakdown {
    let mut est = Estimator::new(*model);
    for d in &m.dispatches {
        est.record(&d.dnn_name, &d.activity);
    }
    est.finish(m.makespan)
}

/// Per-DNN energy bars — the accounting of the paper's Fig. 9(e)(f):
/// each DNN's bar is its own dynamic energy plus the array static energy
/// attributed to its residency, weighted by the fraction of the array it
/// occupied (tile PEs / array PEs — exactly `width/cols` for the
/// full-height tiles of columns mode).  Under the sequential baseline
/// every layer occupies the full array, so a DNN is billed the whole
/// static power for its whole execution window; under partitioning,
/// co-residents split it.
pub fn per_dnn_energy_bars(m: &RunMetrics, model: &EnergyModel) -> BTreeMap<String, f64> {
    let rate = model.static_rate_j_per_cycle();
    let pes = model.geom.pes() as f64;
    let mut bars: BTreeMap<String, f64> = BTreeMap::new();
    let mut est = Estimator::new(*model);
    for d in &m.dispatches {
        est.record(&d.dnn_name, &d.activity);
        *bars.entry(d.dnn_name.clone()).or_default() +=
            rate * d.duration() as f64 * (d.tile.pes() as f64 / pes);
    }
    let bd = est.finish(m.makespan);
    for (name, dyn_j) in bd.per_dnn_dynamic_j {
        *bars.entry(name).or_default() += dyn_j;
    }
    bars
}

/// Percentage saving of `new` vs `base` (positive = improvement).
pub fn saving_pct(base: f64, new: f64) -> f64 {
    if base == 0.0 {
        0.0
    } else {
        100.0 * (1.0 - new / base)
    }
}

/// Mean completion cycle over DNNs.
pub fn mean_completion(m: &RunMetrics) -> f64 {
    if m.completion.is_empty() {
        return 0.0;
    }
    m.completion.values().sum::<u64>() as f64 / m.completion.len() as f64
}

/// Headline summary of one group (the H1 row of DESIGN.md §7).
#[derive(Debug, Clone)]
pub struct Headline {
    pub pool: String,
    pub makespan_saving_pct: f64,
    pub mean_completion_saving_pct: f64,
    pub total_energy_saving_pct: f64,
    pub mean_bar_energy_saving_pct: f64,
    pub dyn_utilization: f64,
    pub seq_utilization: f64,
}

/// Compute the headline metrics for a group result.
pub fn headline(g: &GroupResults, model: &EnergyModel) -> Headline {
    let e_dyn = total_energy(&g.dynamic, model);
    let e_seq = total_energy(&g.sequential, model);
    let bars_dyn = per_dnn_energy_bars(&g.dynamic, model);
    let bars_seq = per_dnn_energy_bars(&g.sequential, model);
    let mean = |b: &BTreeMap<String, f64>| b.values().sum::<f64>() / b.len().max(1) as f64;
    Headline {
        pool: g.pool_name.clone(),
        makespan_saving_pct: saving_pct(g.sequential.makespan as f64, g.dynamic.makespan as f64),
        mean_completion_saving_pct: saving_pct(
            mean_completion(&g.sequential),
            mean_completion(&g.dynamic),
        ),
        total_energy_saving_pct: saving_pct(e_seq.total_j(), e_dyn.total_j()),
        mean_bar_energy_saving_pct: saving_pct(mean(&bars_seq), mean(&bars_dyn)),
        dyn_utilization: g.dynamic.utilization(g.cfg.geom),
        seq_utilization: g.sequential.utilization(g.cfg.geom),
    }
}

/// Per-tenant memory-hierarchy table (`mtsa run` with `[mem]` enabled):
/// DRAM words moved, achieved bandwidth, stall cycles/fraction, refetch
/// words, and the idle-leakage energy the stalls held live
/// ([`EnergyModel::stall_j`]).  Refetch *energy* needs no extra row: the
/// banked activity already flows through the estimator's DRAM term.
pub fn mem_table(m: &RunMetrics, model: &EnergyModel) -> Table {
    let mut t = Table::new(&[
        "tenant",
        "xfer words",
        "achieved w/c",
        "stall cycles",
        "stall",
        "refetch words",
        "stall energy (mJ)",
    ]);
    let mut push = |name: &str, s: &MemStats| {
        t.row(&[
            name.to_string(),
            s.xfer_words.to_string(),
            format!("{:.2}", s.achieved_words_per_cycle()),
            s.stall_cycles.to_string(),
            format!("{:.1}%", 100.0 * s.stall_fraction()),
            s.refetch_words.to_string(),
            format!("{:.3}", model.stall_j(s.stall_col_cycles) * 1e3),
        ]);
    };
    for (name, s) in &m.mem {
        push(name, s);
    }
    push("== total ==", &m.mem_total);
    t
}

// ---------------------------------------------------------------------
// Scenario-sweep rendering (`mtsa sweep`)
// ---------------------------------------------------------------------

/// One point's arrival-axis label: `batch`, `1/<gap>` (Poisson) or
/// `burst<size>/<gap>` (ON-OFF).
fn arrival_label(grid: &SweepGrid, mean_interarrival: f64) -> String {
    if mean_interarrival <= 0.0 {
        "batch".to_string()
    } else if let Some((burst_size, _)) = grid.bursty {
        format!("burst{burst_size}/{mean_interarrival:.0}")
    } else {
        format!("1/{mean_interarrival:.0}")
    }
}

/// One point's geometry label: the bare side for square arrays, `HxW`
/// otherwise (the same spelling `--geoms` parses).
fn geom_label(geom: crate::sim::dataflow::ArrayGeometry) -> String {
    if geom.rows == geom.cols {
        geom.cols.to_string()
    } else {
        format!("{}x{}", geom.rows, geom.cols)
    }
}

/// The human-readable sweep report: one row per grid point.  When any
/// point ran under the shared memory hierarchy, four contention columns
/// (interface bandwidth, arbitration, stall fraction, achieved
/// words/cycle) are appended; points without `[mem]` show `-`.  A `mode`
/// column appears only when some point ran 2D fission, and three
/// preemption columns (mode, count, wasted refill cycles) only when some
/// point ran with preemption on — so column-only non-preemptive sweeps
/// render exactly as before.  A `tables` column appears only when the
/// grid has a profile-table axis, and two lane columns (count, vector
/// dispatches) only when some point ran with a vector engine.
pub fn sweep_table(grid: &SweepGrid, rows: &[SweepRow]) -> Table {
    let with_mem = rows.iter().any(|r| r.mem.is_some());
    let with_mode = rows.iter().any(|r| r.point.mode == PartitionMode::TwoD);
    let with_preempt = rows.iter().any(|r| r.point.preempt != PreemptMode::Off);
    let with_tables = !grid.tables.is_empty();
    let with_vector = rows.iter().any(|r| r.vector.is_some());
    let mut headers = vec![
        "mix", "arrival", "policy", "feed", "cols", "makespan", "vs seq", "util", "p50 lat",
        "p99 lat", "miss",
    ];
    if with_mode {
        headers.insert(5, "mode");
    }
    if with_tables {
        headers.insert(if with_mode { 6 } else { 5 }, "tables");
    }
    if with_preempt {
        headers.extend(["preempt", "npre", "wasted"]);
    }
    if with_mem {
        headers.extend(["bw", "arb", "stall", "wpc"]);
    }
    if with_vector {
        headers.extend(["lanes", "vdisp"]);
    }
    let mut t = Table::new(&headers);
    for r in rows {
        let mut cells = vec![
            r.point.mix.clone(),
            arrival_label(grid, r.point.mean_interarrival),
            r.point.policy.tag().to_string(),
            r.point.feed.tag().to_string(),
            geom_label(r.point.geom),
            r.makespan.to_string(),
            format!("{:+.1}%", saving_pct(r.seq_makespan as f64, r.makespan as f64)),
            format!("{:.1}%", 100.0 * r.utilization),
            format!("{:.0}", r.outcome.overall.p50_latency),
            format!("{:.0}", r.outcome.overall.p99_latency),
            format!("{:.1}%", 100.0 * r.outcome.miss_rate()),
        ];
        if with_mode {
            cells.insert(5, r.point.mode.tag().to_string());
        }
        if with_tables {
            cells.insert(
                if with_mode { 6 } else { 5 },
                if r.point.tables { "on" } else { "off" }.to_string(),
            );
        }
        if with_preempt {
            cells.extend([
                r.point.preempt.tag().to_string(),
                r.preemptions.to_string(),
                r.wasted_refill_cycles.to_string(),
            ]);
        }
        if with_mem {
            match &r.mem {
                Some(m) => cells.extend([
                    format!("{:.0}", m.words_per_cycle),
                    m.arbitration.tag().to_string(),
                    format!("{:.1}%", 100.0 * m.stats.stall_fraction()),
                    format!("{:.2}", m.stats.achieved_words_per_cycle()),
                ]),
                None => cells.extend(["-".into(), "-".into(), "-".into(), "-".into()]),
            }
        }
        if with_vector {
            match &r.vector {
                Some(v) => cells.extend([v.lanes.to_string(), v.dispatches.to_string()]),
                None => cells.extend(["-".into(), "-".into()]),
            }
        }
        t.row(&cells);
    }
    t
}

fn mem_stats_json(s: &MemStats) -> Json {
    let mut o = BTreeMap::new();
    o.insert("layers".to_string(), Json::Num(s.layers as f64));
    o.insert("stall_cycles".to_string(), Json::Num(s.stall_cycles as f64));
    o.insert("busy_cycles".to_string(), Json::Num(s.busy_cycles as f64));
    o.insert("stall_fraction".to_string(), Json::Num(s.stall_fraction()));
    o.insert("xfer_words".to_string(), Json::Num(s.xfer_words as f64));
    o.insert("refetch_words".to_string(), Json::Num(s.refetch_words as f64));
    o.insert("achieved_words_per_cycle".to_string(), Json::Num(s.achieved_words_per_cycle()));
    Json::Obj(o)
}

fn tenant_stats_json(s: &TenantStats) -> Json {
    let mut o = BTreeMap::new();
    o.insert("requests".to_string(), Json::Num(s.requests as f64));
    o.insert("mean_latency".to_string(), Json::Num(s.mean_latency));
    o.insert("p50_latency".to_string(), Json::Num(s.p50_latency));
    o.insert("p95_latency".to_string(), Json::Num(s.p95_latency));
    o.insert("p99_latency".to_string(), Json::Num(s.p99_latency));
    o.insert("max_latency".to_string(), Json::Num(s.max_latency));
    o.insert("deadlines".to_string(), Json::Num(s.deadlines as f64));
    o.insert("misses".to_string(), Json::Num(s.misses as f64));
    o.insert("miss_rate".to_string(), Json::Num(s.miss_rate()));
    Json::Obj(o)
}

/// The machine-readable sweep report.  Deterministic: a fixed grid seed
/// renders byte-identically regardless of worker-thread count (see
/// `util::json` and `rust/tests/scenario_sweep.rs`).
pub fn sweep_json(grid: &SweepGrid, rows: &[SweepRow]) -> Json {
    let mut points = Vec::with_capacity(rows.len());
    for r in rows {
        let mut o = BTreeMap::new();
        o.insert("mix".to_string(), Json::Str(r.point.mix.clone()));
        o.insert("mean_interarrival".to_string(), Json::Num(r.point.mean_interarrival));
        o.insert("policy".to_string(), Json::Str(r.point.policy.tag().to_string()));
        o.insert("feed".to_string(), Json::Str(r.point.feed.tag().to_string()));
        o.insert("cols".to_string(), Json::Num(r.point.geom.cols as f64));
        // New-geometry keys are strictly opt-in: `rows` only for
        // non-square arrays, `partition_mode` only for 2D points — a
        // columns-mode square-geometry sweep renders byte-identically to
        // the pre-2D report.
        if r.point.geom.rows != r.point.geom.cols {
            o.insert("rows".to_string(), Json::Num(r.point.geom.rows as f64));
        }
        if r.point.mode == PartitionMode::TwoD {
            o.insert(
                "partition_mode".to_string(),
                Json::Str(r.point.mode.tag().to_string()),
            );
        }
        // The tables key is strictly opt-in on the grid axis: a sweep
        // without `--tables` emits nothing, keeping goldens byte-stable.
        if !grid.tables.is_empty() {
            o.insert("tables".to_string(), Json::Bool(r.point.tables));
        }
        // Preemption keys are strictly opt-in: a `preempt = off` point
        // emits none of them, keeping non-preemptive sweeps byte-stable.
        if r.point.preempt != PreemptMode::Off {
            o.insert("preempt".to_string(), Json::Str(r.point.preempt.tag().to_string()));
            o.insert("preemptions".to_string(), Json::Num(r.preemptions as f64));
            o.insert(
                "wasted_refill_cycles".to_string(),
                Json::Num(r.wasted_refill_cycles as f64),
            );
        }
        // Seeds are u64; emitted as strings so they stay exact beyond 2^53.
        o.insert("scenario_seed".to_string(), Json::Str(r.point.scenario_seed.to_string()));
        o.insert("requests".to_string(), Json::Num(r.requests as f64));
        o.insert("makespan".to_string(), Json::Num(r.makespan as f64));
        o.insert("seq_makespan".to_string(), Json::Num(r.seq_makespan as f64));
        o.insert(
            "makespan_saving_pct".to_string(),
            Json::Num(saving_pct(r.seq_makespan as f64, r.makespan as f64)),
        );
        o.insert("utilization".to_string(), Json::Num(r.utilization));
        o.insert("seq_utilization".to_string(), Json::Num(r.seq_utilization));
        o.insert(
            "occupancy".to_string(),
            Json::Arr(r.occupancy.iter().map(|&v| Json::Num(v)).collect()),
        );
        // Only emitted for points that ran under [mem] — a sweep without
        // the contention axis renders byte-identically to before.
        if let Some(m) = &r.mem {
            let mut mo = BTreeMap::new();
            mo.insert("words_per_cycle".to_string(), Json::Num(m.words_per_cycle));
            mo.insert("arbitration".to_string(), Json::Str(m.arbitration.tag().to_string()));
            mo.insert("total".to_string(), mem_stats_json(&m.stats));
            o.insert("mem".to_string(), Json::Obj(mo));
        }
        // Only emitted for points that ran with a vector engine — a sweep
        // without the lanes axis (and no [vector] config) renders
        // byte-identically to before.
        if let Some(v) = &r.vector {
            let mut vo = BTreeMap::new();
            vo.insert("lanes".to_string(), Json::Num(v.lanes as f64));
            vo.insert("dispatches".to_string(), Json::Num(v.dispatches as f64));
            o.insert("vector".to_string(), Json::Obj(vo));
        }
        o.insert("overall".to_string(), tenant_stats_json(&r.outcome.overall));
        o.insert("seq_overall".to_string(), tenant_stats_json(&r.seq_outcome.overall));
        o.insert(
            "tenants".to_string(),
            Json::Obj(
                r.outcome
                    .tenants
                    .iter()
                    .map(|t| (t.tenant.clone(), tenant_stats_json(t)))
                    .collect(),
            ),
        );
        points.push(Json::Obj(o));
    }
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Num(1.0));
    top.insert("seed".to_string(), Json::Str(grid.seed.to_string()));
    top.insert("requests".to_string(), Json::Num(grid.requests as f64));
    top.insert("qos_slack".to_string(), Json::Num(grid.qos_slack));
    // The arrival family for the non-zero rates (zero rates are batch).
    match grid.bursty {
        Some((burst_size, burst_within)) => {
            top.insert("arrival".to_string(), Json::Str("bursty".to_string()));
            top.insert("burst_size".to_string(), Json::Num(burst_size as f64));
            top.insert("burst_within".to_string(), Json::Num(burst_within));
        }
        None => {
            top.insert("arrival".to_string(), Json::Str("poisson".to_string()));
        }
    }
    if grid.modes.contains(&PartitionMode::TwoD) {
        top.insert(
            "modes".to_string(),
            Json::Arr(
                grid.modes.iter().map(|m| Json::Str(m.tag().to_string())).collect(),
            ),
        );
    }
    if grid.preempts.iter().any(|p| *p != PreemptMode::Off) {
        top.insert(
            "preempts".to_string(),
            Json::Arr(
                grid.preempts.iter().map(|p| Json::Str(p.tag().to_string())).collect(),
            ),
        );
    }
    if !grid.tables.is_empty() {
        top.insert(
            "tables_axis".to_string(),
            Json::Arr(grid.tables.iter().map(|&t| Json::Bool(t)).collect()),
        );
        if let Some(store) = &grid.tables_store {
            top.insert("tables_origin".to_string(), Json::Str(store.origin.clone()));
        }
    }
    if !grid.lanes.is_empty() {
        top.insert(
            "lanes_axis".to_string(),
            Json::Arr(grid.lanes.iter().map(|&l| Json::Num(l as f64)).collect()),
        );
    }
    if !grid.bandwidths.is_empty() {
        top.insert(
            "bandwidths".to_string(),
            Json::Arr(grid.bandwidths.iter().map(|&b| Json::Num(b)).collect()),
        );
        top.insert(
            "arbitrations".to_string(),
            Json::Arr(
                grid.effective_arbitrations()
                    .into_iter()
                    .map(|a| Json::Str(a.tag().to_string()))
                    .collect(),
            ),
        );
    }
    top.insert("points".to_string(), Json::Arr(points));
    Json::Obj(top)
}

/// Render the per-class SLO table of a fleet run (`mtsa fleet`).
pub fn fleet_table(r: &FleetReport) -> Table {
    let mut t = Table::new(&[
        "class", "share", "gen", "done", "drop", "slo%", "p50", "p95", "p99", "queue", "service",
    ]);
    for c in &r.classes {
        t.row(&[
            c.class.tag().to_string(),
            format!("{:.2}", c.share),
            c.generated.to_string(),
            c.completed.to_string(),
            c.dropped.to_string(),
            format!("{:.1}%", c.attainment * 100.0),
            c.p50.to_string(),
            c.p95.to_string(),
            c.p99.to_string(),
            format!("{:.0}", c.mean_queue_cycles),
            format!("{:.0}", c.mean_service_cycles),
        ]);
    }
    t
}

/// Render the per-instance table of a fleet run.
pub fn fleet_instance_table(r: &FleetReport) -> Table {
    let mut t = Table::new(&[
        "instance", "policy", "admitted", "done", "dropped", "preempt", "util", "energy_j",
    ]);
    for i in &r.instances {
        t.row(&[
            i.name.clone(),
            i.policy.clone(),
            i.admitted_batches.to_string(),
            i.completed_batches.to_string(),
            i.dropped_batches.to_string(),
            i.preemptions.to_string(),
            format!("{:.1}%", i.utilization * 100.0),
            format!("{:.3}", i.energy_j),
        ]);
    }
    t
}

/// One fleet run as a JSON object (shared by `mtsa fleet --json` and the
/// sweep's fleet axis).  Deterministic: BTreeMap key order, seeds as
/// strings, and the `slack` key strictly opt-in per class.
pub fn fleet_point_json(r: &FleetReport) -> Json {
    let mut classes = Vec::with_capacity(r.classes.len());
    for c in &r.classes {
        let mut o = BTreeMap::new();
        o.insert("class".to_string(), Json::Str(c.class.tag().to_string()));
        o.insert("share".to_string(), Json::Num(c.share));
        // Deadline-free classes emit no slack key at all.
        if let Some(s) = c.slack {
            o.insert("slack".to_string(), Json::Num(s));
        }
        o.insert("generated".to_string(), Json::Num(c.generated as f64));
        o.insert("completed".to_string(), Json::Num(c.completed as f64));
        o.insert("dropped".to_string(), Json::Num(c.dropped as f64));
        o.insert("slo_ok".to_string(), Json::Num(c.slo_ok as f64));
        o.insert("attainment".to_string(), Json::Num(c.attainment));
        o.insert("p50_cycles".to_string(), Json::Num(c.p50 as f64));
        o.insert("p95_cycles".to_string(), Json::Num(c.p95 as f64));
        o.insert("p99_cycles".to_string(), Json::Num(c.p99 as f64));
        o.insert("mean_queue_cycles".to_string(), Json::Num(c.mean_queue_cycles));
        o.insert("mean_service_cycles".to_string(), Json::Num(c.mean_service_cycles));
        classes.push(Json::Obj(o));
    }
    let mut instances = Vec::with_capacity(r.instances.len());
    for i in &r.instances {
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(i.name.clone()));
        o.insert("policy".to_string(), Json::Str(i.policy.clone()));
        o.insert("admitted_batches".to_string(), Json::Num(i.admitted_batches as f64));
        o.insert("completed_batches".to_string(), Json::Num(i.completed_batches as f64));
        o.insert("dropped_batches".to_string(), Json::Num(i.dropped_batches as f64));
        o.insert("preemptions".to_string(), Json::Num(i.preemptions as f64));
        o.insert("makespan".to_string(), Json::Num(i.makespan as f64));
        o.insert("utilization".to_string(), Json::Num(i.utilization));
        o.insert("energy_j".to_string(), Json::Num(i.energy_j));
        o.insert("events".to_string(), Json::Num(i.events as f64));
        instances.push(Json::Obj(o));
    }
    let mut o = BTreeMap::new();
    o.insert("schema".to_string(), Json::Num(1.0));
    o.insert("seed".to_string(), Json::Str(r.seed.to_string()));
    o.insert("generated".to_string(), Json::Num(r.generated as f64));
    o.insert("completed".to_string(), Json::Num(r.completed as f64));
    o.insert("dropped".to_string(), Json::Num(r.dropped as f64));
    o.insert("batches".to_string(), Json::Num(r.batches as f64));
    o.insert("makespan".to_string(), Json::Num(r.makespan as f64));
    o.insert("utilization".to_string(), Json::Num(r.utilization));
    o.insert("energy_j".to_string(), Json::Num(r.energy_j));
    o.insert("cost_j_per_query".to_string(), Json::Num(r.cost_j_per_query));
    o.insert("events".to_string(), Json::Num(r.events as f64));
    o.insert("classes".to_string(), Json::Arr(classes));
    o.insert("instances".to_string(), Json::Arr(instances));
    Json::Obj(o)
}

/// Top-level JSON of `mtsa fleet --json` (one fleet run).
pub fn fleet_json(r: &FleetReport) -> Json {
    fleet_point_json(r)
}

/// Sweep JSON with the fleet axis attached (see
/// [`sweep::run_fleet_axis`](crate::sweep::run_fleet_axis)).  With an
/// empty axis this renders byte-identically to [`sweep_json`], so
/// existing goldens are untouched.
pub fn sweep_json_with_fleet(
    grid: &SweepGrid,
    rows: &[SweepRow],
    fleet_rows: &[FleetAxisRow],
) -> Json {
    let mut json = sweep_json(grid, rows);
    if fleet_rows.is_empty() {
        return json;
    }
    let points: Vec<Json> = fleet_rows
        .iter()
        .map(|fr| {
            let mut o = BTreeMap::new();
            o.insert("instances".to_string(), Json::Num(fr.instances as f64));
            o.insert("mix".to_string(), Json::Str(fr.mix.clone()));
            o.insert("mean_interarrival".to_string(), Json::Num(fr.mean_interarrival));
            o.insert("scenario_seed".to_string(), Json::Str(fr.scenario_seed.to_string()));
            o.insert("result".to_string(), fleet_point_json(&fr.report));
            Json::Obj(o)
        })
        .collect();
    if let Json::Obj(top) = &mut json {
        top.insert("fleet".to_string(), Json::Arr(points));
    }
    json
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::dnng::{Dnn, Layer};
    use crate::workloads::shapes::{LayerKind, LayerShape};

    fn small_pool() -> WorkloadPool {
        let mk = |name: &str, m: u64, n_layers: usize| {
            let layers = (0..n_layers)
                .map(|i| Layer::new(&format!("l{i}"), LayerKind::Fc, LayerShape::fc(64, 128, m)))
                .collect();
            Dnn::chain(name, layers)
        };
        WorkloadPool::new("small", vec![mk("a", 64, 3), mk("b", 32, 2), mk("c", 16, 2)])
    }

    #[test]
    fn group_runs_both_schedulers() {
        let g = run_group(&small_pool(), &SchedulerConfig::default());
        assert_eq!(g.dynamic.dispatches.len(), 7);
        assert_eq!(g.sequential.dispatches.len(), 7);
        assert!(g.dynamic.makespan <= g.sequential.makespan);
    }

    #[test]
    fn bars_cover_every_dnn() {
        let g = run_group(&small_pool(), &SchedulerConfig::default());
        let model = EnergyModel::default_128();
        let bars = per_dnn_energy_bars(&g.dynamic, &model);
        assert_eq!(bars.len(), 3);
        assert!(bars.values().all(|&v| v > 0.0));
    }

    #[test]
    fn shared_static_attribution_smaller_than_exclusive() {
        // Under partitioning, a narrow-width DNN is billed a width fraction
        // of the static power, so its bar must not exceed its sequential bar
        // by more than its (possibly longer) runtime would explain.
        let g = run_group(&small_pool(), &SchedulerConfig::default());
        let model = EnergyModel::default_128();
        let bars_dyn = per_dnn_energy_bars(&g.dynamic, &model);
        let bars_seq = per_dnn_energy_bars(&g.sequential, &model);
        let sum_dyn: f64 = bars_dyn.values().sum();
        let sum_seq: f64 = bars_seq.values().sum();
        // All layers here have m <= 64 (width-insensitive), so the shared
        // accounting must strictly win in aggregate.
        assert!(sum_dyn < sum_seq, "dyn {sum_dyn} vs seq {sum_seq}");
    }

    #[test]
    fn mem_table_renders_tenants_and_total() {
        let mut m = RunMetrics::default();
        m.record_mem(
            "a",
            &MemStats {
                layers: 1,
                stall_cycles: 50,
                stall_col_cycles: 3200,
                busy_cycles: 200,
                xfer_words: 1000,
                refetch_words: 10,
            },
        );
        let text = mem_table(&m, &EnergyModel::default_128()).render();
        assert!(text.contains("== total =="), "{text}");
        assert!(text.contains("1000"), "{text}");
        assert!(text.contains("25.0%"), "stall fraction 50/200: {text}");
    }

    #[test]
    fn saving_pct_signs() {
        assert!((saving_pct(100.0, 50.0) - 50.0).abs() < 1e-12);
        assert!(saving_pct(100.0, 120.0) < 0.0);
        assert_eq!(saving_pct(0.0, 5.0), 0.0);
    }

    #[test]
    fn headline_is_consistent() {
        let g = run_group(&small_pool(), &SchedulerConfig::default());
        let model = EnergyModel::default_128();
        let h = headline(&g, &model);
        assert!(h.makespan_saving_pct >= 0.0);
        assert!(h.dyn_utilization >= h.seq_utilization);
    }

    fn tiny_fleet_report() -> FleetReport {
        use crate::coordinator::scheduler::SchedulerConfig;
        use crate::fleet::{run_fleet, FleetConfig, FleetPolicy, Placement};
        use crate::workloads::generator::{ArrivalProcess, ModelMix};
        let sched = SchedulerConfig::default();
        let cfg = FleetConfig {
            instances: FleetConfig::uniform(2, &sched, FleetPolicy::Dynamic),
            placement: Placement::LeastLoaded,
            random_k: 2,
            classes: FleetConfig::default_classes(40_000.0),
            slots: 4,
            queue_cap: 16,
            mix: ModelMix::new(&[("NCF", 1.0)]),
            arrival: ArrivalProcess::Poisson { mean_interarrival: 40_000.0 },
            diurnal: None,
            requests: 40,
            seed: 11,
            chunk: 64,
            tables: None,
        };
        run_fleet(&cfg, 2).unwrap()
    }

    #[test]
    fn fleet_tables_render_every_class_and_instance() {
        let r = tiny_fleet_report();
        let text = fleet_table(&r).render();
        for tag in ["latency-critical", "best-effort", "batch"] {
            assert!(text.contains(tag), "{text}");
        }
        let itext = fleet_instance_table(&r).render();
        assert!(itext.contains("acc0") && itext.contains("acc1"), "{itext}");
        assert!(itext.contains("dynamic"), "{itext}");
    }

    #[test]
    fn fleet_json_shape_and_slack_opt_in() {
        let r = tiny_fleet_report();
        let rendered = fleet_json(&r).render();
        assert!(rendered.contains("\"schema\":1"), "{rendered}");
        assert!(rendered.contains("\"seed\":\"11\""), "{rendered}");
        assert!(rendered.contains("\"cost_j_per_query\""), "{rendered}");
        // The batch class has no deadline, so exactly two classes carry
        // a slack key (latency-critical + best-effort).
        assert_eq!(rendered.matches("\"slack\"").count(), 2, "{rendered}");
        assert_eq!(rendered.matches("\"class\"").count(), 3, "{rendered}");
    }

    #[test]
    fn sweep_json_with_empty_fleet_axis_is_byte_identical() {
        let grid = SweepGrid::default();
        let a = sweep_json(&grid, &[]).render();
        let b = sweep_json_with_fleet(&grid, &[], &[]).render();
        assert_eq!(a, b);
    }

    #[test]
    fn sweep_lane_keys_are_strictly_opt_in() {
        // No lanes axis: not a byte of the header mentions lanes.
        let plain = sweep_json(&SweepGrid::default(), &[]).render();
        assert!(!plain.contains("lanes"), "{plain}");
        assert!(!plain.contains("vector"), "{plain}");
        // Axis on: the header names the swept lane counts.
        let grid = SweepGrid { lanes: vec![0, 128], ..Default::default() };
        let on = sweep_json(&grid, &[]).render();
        assert!(on.contains("\"lanes_axis\":[0,128]"), "{on}");
    }

    #[test]
    fn sweep_tables_keys_are_strictly_opt_in() {
        // No tables axis: not a byte of the header mentions tables.
        let plain = sweep_json(&SweepGrid::default(), &[]).render();
        assert!(!plain.contains("tables"), "{plain}");
        // Axis on: the header names it, plus the store's origin when
        // one is loaded.
        let grid = SweepGrid { tables: vec![false, true], ..Default::default() };
        let on = sweep_json(&grid, &[]).render();
        assert!(on.contains("\"tables_axis\":[false,true]"), "{on}");
        assert!(!on.contains("tables_origin"), "no store loaded: {on}");
    }
}
