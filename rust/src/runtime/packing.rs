//! Packing co-resident tenants into the shared array operands.
//!
//! The rust mirror of `python/compile/model.pack_tenants`: given the weight
//! and feed tiles of the layers currently resident in the array's vertical
//! partitions, build the fixed-shape operands of a `pws_p{P}` artifact —
//! packed weights `[K, C]`, per-tenant feed streams `[P, S, K]`, and the
//! float `Mul_En` mask plane `[P, C]` — plus the unpacking metadata to slice
//! each tenant's OFMap columns back out of the drained `[S, C]` block.

use anyhow::{bail, Result};

use super::tensor::Tensor;

/// One tenant's tile for a single array step.
#[derive(Debug, Clone)]
pub struct TenantTile {
    /// Caller-meaningful tenant id (carried through to the unpack info).
    pub tenant: usize,
    /// Feed-stream tile `[s_rows, k_depth]` (s_rows ≤ S, k_depth ≤ K).
    pub x: Tensor,
    /// Stationary weight tile `[k_depth, cols]` (cols = partition width used).
    pub w: Tensor,
}

/// Where one tenant's results live in the drained `[S, C]` block.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantSlot {
    pub tenant: usize,
    /// Valid output rows: `0..s_rows`.
    pub s_rows: usize,
    /// Column range `[col0, col0 + cols)`.
    pub col0: usize,
    pub cols: usize,
}

/// Fixed-shape artifact operands plus unpack metadata.
#[derive(Debug, Clone)]
pub struct PackedStep {
    /// Artifact partition count (`pws_p{num_p}`); ≥ number of tiles.
    pub num_p: usize,
    /// `[num_p, S, K]`
    pub x: Tensor,
    /// `[K, C]`
    pub w: Tensor,
    /// `[num_p, C]` float one-hot Mul_En plane.
    pub mask: Tensor,
    pub slots: Vec<TenantSlot>,
}

impl PackedStep {
    /// Slice one tenant's `[s_rows, cols]` result out of a drained `[S, C]` block.
    pub fn unpack(&self, drained: &Tensor, slot_idx: usize) -> Tensor {
        let slot = &self.slots[slot_idx];
        let c_total = drained.shape()[1];
        let mut out = Tensor::zeros(vec![slot.s_rows, slot.cols]);
        for r in 0..slot.s_rows {
            let src = &drained.data()[r * c_total + slot.col0..r * c_total + slot.col0 + slot.cols];
            out.data_mut()[r * slot.cols..(r + 1) * slot.cols].copy_from_slice(src);
        }
        out
    }
}

/// Pick the smallest available artifact partition count ≥ `n`.
///
/// `available` must be sorted ascending (see `Manifest::pws_partition_counts`).
pub fn pick_variant(available: &[usize], n: usize) -> Option<usize> {
    available.iter().copied().find(|&p| p >= n)
}

/// Pack tenant tiles into the operands of a `pws_p{num_p}` step.
///
/// * `array_s`, `array_k`, `array_c` — fixed artifact geometry;
/// * `num_p` — artifact partition count (≥ tiles.len(); unused partition
///   lanes are zero and own no columns).
///
/// Tiles are laid out left-to-right in the order given — the same order the
/// coordinator assigned partitions — and padded with zeros up to the fixed
/// shapes (zero padding is exact for a GEMM: it contributes nothing).
pub fn pack_step(
    tiles: &[TenantTile],
    array_s: usize,
    array_k: usize,
    array_c: usize,
    num_p: usize,
) -> Result<PackedStep> {
    if tiles.is_empty() {
        bail!("pack_step: no tiles");
    }
    if tiles.len() > num_p {
        bail!("pack_step: {} tiles > {} partition lanes", tiles.len(), num_p);
    }
    let total_cols: usize = tiles.iter().map(|t| t.w.shape()[1]).sum();
    if total_cols > array_c {
        bail!("pack_step: tiles span {total_cols} columns > array width {array_c}");
    }

    let mut x = Tensor::zeros(vec![num_p, array_s, array_k]);
    let mut w = Tensor::zeros(vec![array_k, array_c]);
    let mut mask = Tensor::zeros(vec![num_p, array_c]);
    let mut slots = Vec::with_capacity(tiles.len());

    let mut col0 = 0usize;
    for (p, tile) in tiles.iter().enumerate() {
        let (s_rows, k_depth) = (tile.x.shape()[0], tile.x.shape()[1]);
        let (k_depth2, cols) = (tile.w.shape()[0], tile.w.shape()[1]);
        if s_rows > array_s || k_depth > array_k {
            bail!(
                "pack_step: tile {p} stream [{s_rows},{k_depth}] exceeds array step [{array_s},{array_k}]"
            );
        }
        if k_depth2 != k_depth {
            bail!("pack_step: tile {p} K mismatch: x has {k_depth}, w has {k_depth2}");
        }

        // Feed stream into lane p, zero-padded to [S, K] — row-contiguous
        // copies (this is the serving hot path; see EXPERIMENTS.md §Perf).
        {
            let lane = &mut x.data_mut()[p * array_s * array_k..(p + 1) * array_s * array_k];
            for r in 0..s_rows {
                lane[r * array_k..r * array_k + k_depth]
                    .copy_from_slice(&tile.x.data()[r * k_depth..(r + 1) * k_depth]);
            }
        }
        // Weights into columns [col0, col0+cols), zero-padded rows.
        {
            let wdat = w.data_mut();
            for kk in 0..k_depth {
                wdat[kk * array_c + col0..kk * array_c + col0 + cols]
                    .copy_from_slice(&tile.w.data()[kk * cols..(kk + 1) * cols]);
            }
        }
        // Mul_En plane: lane p owns its column range.
        mask.data_mut()[p * array_c + col0..p * array_c + col0 + cols].fill(1.0);

        slots.push(TenantSlot { tenant: tile.tenant, s_rows, col0, cols });
        col0 += cols;
    }

    Ok(PackedStep { num_p, x, w, mask, slots })
}

/// CPU oracle for a packed step: what the artifact must compute.
///
/// `y[s, c] = acc[s, c] + Σ_k Σ_p x[p, s, k] · w[k, c] · mask[p, c]`
pub fn packed_step_oracle(step: &PackedStep, acc: &Tensor) -> Tensor {
    let (num_p, s, k) = (step.x.shape()[0], step.x.shape()[1], step.x.shape()[2]);
    let c = step.w.shape()[1];
    assert_eq!(acc.shape(), &[s, c]);
    let mut out = acc.clone();
    for p in 0..num_p {
        for si in 0..s {
            for kk in 0..k {
                let xv = step.x.at3(p, si, kk);
                if xv == 0.0 {
                    continue;
                }
                for ci in 0..c {
                    let m = step.mask.at2(p, ci);
                    if m != 0.0 {
                        let v = out.at2(si, ci) + xv * step.w.at2(kk, ci) * m;
                        out.set2(si, ci, v);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_tensor(rng: &mut Rng, shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.gen_f32() - 0.5).collect())
    }

    #[test]
    fn pick_variant_smallest_fit() {
        let avail = [1, 2, 4, 8];
        assert_eq!(pick_variant(&avail, 1), Some(1));
        assert_eq!(pick_variant(&avail, 2), Some(2));
        assert_eq!(pick_variant(&avail, 3), Some(4));
        assert_eq!(pick_variant(&avail, 8), Some(8));
        assert_eq!(pick_variant(&avail, 9), None);
    }

    #[test]
    fn layout_matches_python_pack_tenants() {
        let mut rng = Rng::new(1);
        let t0 = TenantTile { tenant: 10, x: rand_tensor(&mut rng, vec![4, 8]), w: rand_tensor(&mut rng, vec![8, 6]) };
        let t1 = TenantTile { tenant: 11, x: rand_tensor(&mut rng, vec![3, 8]), w: rand_tensor(&mut rng, vec![8, 10]) };
        let step = pack_step(&[t0.clone(), t1.clone()], 8, 8, 32, 2).unwrap();

        // Column layout: tenant0 cols 0..6, tenant1 cols 6..16, rest unowned.
        assert_eq!(step.slots[0], TenantSlot { tenant: 10, s_rows: 4, col0: 0, cols: 6 });
        assert_eq!(step.slots[1], TenantSlot { tenant: 11, s_rows: 3, col0: 6, cols: 10 });
        for c in 0..6 {
            assert_eq!(step.mask.at2(0, c), 1.0);
            assert_eq!(step.mask.at2(1, c), 0.0);
            assert_eq!(step.w.at2(3, c), t0.w.at2(3, c));
        }
        for c in 6..16 {
            assert_eq!(step.mask.at2(1, c), 1.0);
            assert_eq!(step.w.at2(3, c), t1.w.at2(3, c - 6));
        }
        for c in 16..32 {
            assert_eq!(step.mask.at2(0, c) + step.mask.at2(1, c), 0.0);
        }
        // Feed lanes zero-padded.
        assert_eq!(step.x.at3(0, 2, 3), t0.x.at2(2, 3));
        assert_eq!(step.x.at3(1, 2, 3), t1.x.at2(2, 3));
        assert_eq!(step.x.at3(1, 3, 0), 0.0, "row 3 of a 3-row stream is padding");
    }

    #[test]
    fn oracle_recovers_per_tenant_gemm() {
        let mut rng = Rng::new(2);
        let tiles: Vec<TenantTile> = (0..3)
            .map(|t| TenantTile {
                tenant: t,
                x: rand_tensor(&mut rng, vec![5, 7]),
                w: rand_tensor(&mut rng, vec![7, 4]),
            })
            .collect();
        let step = pack_step(&tiles, 8, 8, 16, 4).unwrap();
        let acc = Tensor::zeros(vec![8, 16]);
        let drained = packed_step_oracle(&step, &acc);
        for (i, tile) in tiles.iter().enumerate() {
            let got = step.unpack(&drained, i);
            let want = tile.x.matmul(&tile.w);
            assert!(got.max_abs_diff(&want) < 1e-5, "tenant {i}");
        }
    }

    #[test]
    fn isolation_under_oracle() {
        // Changing tenant 1's stream must not affect tenant 0's columns.
        let mut rng = Rng::new(3);
        let t0 = TenantTile { tenant: 0, x: rand_tensor(&mut rng, vec![4, 4]), w: rand_tensor(&mut rng, vec![4, 4]) };
        let mut t1 = TenantTile { tenant: 1, x: rand_tensor(&mut rng, vec![4, 4]), w: rand_tensor(&mut rng, vec![4, 4]) };
        let acc = Tensor::zeros(vec![4, 16]);
        let step_a = pack_step(&[t0.clone(), t1.clone()], 4, 4, 16, 2).unwrap();
        let before = step_a.unpack(&packed_step_oracle(&step_a, &acc), 0);
        t1.x = rand_tensor(&mut rng, vec![4, 4]);
        let step_b = pack_step(&[t0, t1], 4, 4, 16, 2).unwrap();
        let after = step_b.unpack(&packed_step_oracle(&step_b, &acc), 0);
        assert_eq!(before, after);
    }

    #[test]
    fn rejects_overflow_and_mismatch() {
        let mut rng = Rng::new(4);
        let big = TenantTile { tenant: 0, x: rand_tensor(&mut rng, vec![2, 4]), w: rand_tensor(&mut rng, vec![4, 20]) };
        assert!(pack_step(&[big.clone(), big.clone()], 4, 4, 32, 2).is_err());

        let bad_k = TenantTile { tenant: 0, x: rand_tensor(&mut rng, vec![2, 4]), w: rand_tensor(&mut rng, vec![5, 2]) };
        assert!(pack_step(&[bad_k], 4, 8, 32, 1).is_err());

        let too_many = TenantTile { tenant: 0, x: rand_tensor(&mut rng, vec![1, 1]), w: rand_tensor(&mut rng, vec![1, 1]) };
        assert!(pack_step(&[too_many.clone(), too_many], 4, 4, 32, 1).is_err());

        assert!(pack_step(&[], 4, 4, 32, 1).is_err());
    }
}
