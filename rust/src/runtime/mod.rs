//! PJRT runtime — the functional datapath of the accelerator.
//!
//! Loads the AOT artifacts produced by `python/compile/aot.py`
//! (`artifacts/*.hlo.txt` + `manifest.json`), compiles them once on the PJRT
//! CPU client, and executes them from the coordinator's hot path.  Python
//! never runs here; the rust binary is self-contained after
//! `make artifacts`.
//!
//! - [`manifest`] — parses/validates `manifest.json` (artifact signatures)
//! - [`tensor`] — host-side f32 tensor with shape checking
//! - `engine` — PJRT client + compiled-executable cache (behind the `pjrt`
//!   feature: it needs the `xla` crate and a PJRT install, neither of which
//!   exists in the offline build; see `Cargo.toml`)
//! - [`packing`] — packs co-resident tenants' weight tiles into the shared
//!   array operands (the rust mirror of `model.pack_tenants`)

#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;
pub mod packing;
pub mod tensor;

#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use manifest::{ArtifactSpec, Manifest};
pub use packing::{pack_step, PackedStep, TenantTile};
pub use tensor::Tensor;
