//! Host-side f32 tensor with shape checking — the interchange type between
//! the coordinator and the PJRT engine.

use std::fmt;

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build from shape and data; panics on element-count mismatch.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(
            n,
            data.len(),
            "shape {:?} implies {} elements, got {}",
            shape,
            n,
            data.len()
        );
        Tensor { shape, data }
    }

    /// All-zeros tensor.
    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Fill from a function of the flat index.
    pub fn from_fn(shape: Vec<usize>, f: impl Fn(usize) -> f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape, data: (0..n).map(f).collect() }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// 2-D element access (row-major). Panics unless rank 2.
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        assert_eq!(self.rank(), 2, "at2 on rank-{} tensor", self.rank());
        self.data[r * self.shape[1] + c]
    }

    /// 2-D element write. Panics unless rank 2.
    pub fn set2(&mut self, r: usize, c: usize, v: f32) {
        assert_eq!(self.rank(), 2);
        let cols = self.shape[1];
        self.data[r * cols + c] = v;
    }

    /// 3-D element access. Panics unless rank 3.
    pub fn at3(&self, a: usize, b: usize, c: usize) -> f32 {
        assert_eq!(self.rank(), 3);
        self.data[(a * self.shape[1] + b) * self.shape[2] + c]
    }

    /// 3-D element write. Panics unless rank 3.
    pub fn set3(&mut self, a: usize, b: usize, c: usize, v: f32) {
        assert_eq!(self.rank(), 3);
        let (s1, s2) = (self.shape[1], self.shape[2]);
        self.data[(a * s1 + b) * s2 + c] = v;
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, shape: Vec<usize>) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {:?}", self.shape, shape);
        self.shape = shape;
        self
    }

    /// Maximum absolute difference against another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch in comparison");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Row-major matmul oracle (used by verify/tests; not the hot path).
    pub fn matmul(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(rhs.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (rhs.shape[0], rhs.shape[1]);
        assert_eq!(k, k2, "matmul inner dim mismatch {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let a = self.data[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                let rrow = &rhs.data[kk * n..(kk + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * rrow[j];
                }
            }
        }
        Tensor::new(vec![m, n], out)
    }
}

impl fmt::Display for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::from_fn(vec![2, 3], |i| i as f32);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.at2(0, 0), 0.0);
        assert_eq!(t.at2(1, 2), 5.0);
    }

    #[test]
    #[should_panic(expected = "implies")]
    fn shape_data_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn set_and_get_round_trip() {
        let mut t = Tensor::zeros(vec![3, 4]);
        t.set2(2, 1, 7.5);
        assert_eq!(t.at2(2, 1), 7.5);
        let mut t3 = Tensor::zeros(vec![2, 3, 4]);
        t3.set3(1, 2, 3, -1.0);
        assert_eq!(t3.at3(1, 2, 3), -1.0);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_fn(vec![2, 6], |i| i as f32).reshape(vec![3, 4]);
        assert_eq!(t.shape(), &[3, 4]);
        assert_eq!(t.at2(2, 3), 11.0);
    }

    #[test]
    fn matmul_known() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(vec![2, 2], vec![1.0; 4]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Tensor::from_fn(vec![3, 3], |i| (i * 7 % 5) as f32);
        let eye = Tensor::from_fn(vec![3, 3], |i| if i % 4 == 0 { 1.0 } else { 0.0 });
        assert_eq!(a.matmul(&eye), a);
    }

    #[test]
    fn max_abs_diff_basics() {
        let a = Tensor::new(vec![2], vec![1.0, 2.0]);
        let b = Tensor::new(vec![2], vec![1.5, 1.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
        assert_eq!(a.max_abs_diff(&a), 0.0);
    }
}
