//! PJRT engine: compiled-executable cache over the `xla` crate.
//!
//! One [`Engine`] per process.  At construction it parses the manifest,
//! loads every HLO-text artifact (`HloModuleProto::from_text_file` — text is
//! the interchange format, see `python/compile/aot.py`), compiles each on
//! the PJRT CPU client **once**, and serves `execute` calls from the cache.
//! Execution takes and returns host [`Tensor`]s; shape checking happens
//! against the manifest signature before anything touches PJRT.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::Tensor;
use crate::log_info;

/// A compiled artifact plus its manifest signature.
struct LoadedArtifact {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT client + compiled executables, keyed by artifact name.
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    artifacts: HashMap<String, LoadedArtifact>,
    manifest: Manifest,
    /// Cumulative number of `execute` calls (hot-path metric).
    exec_count: std::sync::atomic::AtomicU64,
}

// SAFETY: the `xla` crate wraps raw pointers without declaring thread
// safety, but the underlying PJRT C API contract is explicitly thread-safe:
// `PjRtClient` and `PjRtLoadedExecutable` support concurrent `Compile`/
// `Execute` calls from multiple threads (XLA runs a multi-threaded runtime
// underneath).  `Engine` only exposes `&self` methods whose per-call state
// (input literals, output buffers) is function-local, and `exec_count` is
// atomic.  Mutation of the artifact map never happens after construction.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Load every artifact in `dir` and compile it on the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Engine> {
        let t0 = Instant::now();
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut artifacts = HashMap::new();
        for spec in &manifest.artifacts {
            let path = dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {}", spec.name))?;
            artifacts.insert(spec.name.clone(), LoadedArtifact { spec: spec.clone(), exe });
        }
        log_info!(
            "runtime",
            "loaded {} artifacts from {} in {:.2?} (platform: {})",
            artifacts.len(),
            dir.display(),
            t0.elapsed(),
            client.platform_name()
        );
        Ok(Engine { client, artifacts, manifest, exec_count: std::sync::atomic::AtomicU64::new(0) })
    }

    /// The manifest the engine was loaded from.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Names of loaded artifacts (sorted).
    pub fn artifact_names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.artifacts.keys().map(String::as_str).collect();
        names.sort_unstable();
        names
    }

    /// Number of `execute` calls served so far.
    pub fn exec_count(&self) -> u64 {
        self.exec_count.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Execute artifact `name` with `inputs`; returns the single output.
    ///
    /// Inputs are shape-checked against the manifest signature.  All
    /// artifacts in schema 1 return a 1-tuple (lowered with
    /// `return_tuple=True`), unwrapped here with `to_tuple1`.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Tensor> {
        let art = self
            .artifacts
            .get(name)
            .with_context(|| format!("unknown artifact '{name}' (have: {:?})", self.artifact_names()))?;
        if inputs.len() != art.spec.input_shapes.len() {
            bail!(
                "artifact {name}: expected {} inputs, got {}",
                art.spec.input_shapes.len(),
                inputs.len()
            );
        }
        for (i, (t, want)) in inputs.iter().zip(&art.spec.input_shapes).enumerate() {
            if t.shape() != want.as_slice() {
                bail!(
                    "artifact {name}: input #{i} shape {:?} != manifest {:?}",
                    t.shape(),
                    want
                );
            }
        }

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(
                        t.data().as_ptr() as *const u8,
                        t.data().len() * std::mem::size_of::<f32>(),
                    )
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    t.shape(),
                    bytes,
                )
                .context("building input literal")
            })
            .collect::<Result<_>>()?;

        let result = art
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing artifact {name}"))?;
        let out_literal = result[0][0]
            .to_literal_sync()
            .context("fetching output literal")?
            .to_tuple1()
            .context("unwrapping 1-tuple output")?;

        let shape = out_literal.array_shape().context("output shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = out_literal.to_vec::<f32>().context("output data")?;
        self.exec_count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(Tensor::new(dims, data))
    }
}

#[cfg(test)]
mod tests {
    // Engine tests that need built artifacts live in rust/tests/runtime_pjrt.rs;
    // here we only cover the error path that needs no artifacts.
    use super::*;

    #[test]
    fn missing_dir_is_an_error() {
        let Err(err) = Engine::load(Path::new("/nonexistent/mtsa-artifacts")) else {
            panic!("expected error for missing dir");
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("manifest.json"), "unexpected error: {msg}");
    }
}
