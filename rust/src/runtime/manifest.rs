//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the rust engine.  Parsed with the in-tree JSON parser and validated
//! eagerly so a stale or hand-edited artifacts directory fails loudly at
//! engine construction, not mid-serve.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Signature of one AOT artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    /// File name relative to the artifacts directory.
    pub file: String,
    /// Input shapes in call order (dtype is always f32 in schema 1).
    pub input_shapes: Vec<Vec<usize>>,
    pub num_outputs: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Array-step geometry the artifacts were lowered for.
    pub array_s: usize,
    pub array_k: usize,
    pub array_c: usize,
    pub artifacts: Vec<ArtifactSpec>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let doc = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        let schema = doc
            .get("schema")
            .and_then(Json::as_u64)
            .context("manifest missing 'schema'")?;
        if schema != 1 {
            bail!("unsupported manifest schema {schema} (expected 1)");
        }

        let array = doc.get("array").context("manifest missing 'array'")?;
        let dim = |k: &str| -> Result<usize> {
            Ok(array
                .get(k)
                .and_then(Json::as_u64)
                .with_context(|| format!("array.{k} missing"))? as usize)
        };

        let mut artifacts = Vec::new();
        for (i, a) in doc
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing 'artifacts'")?
            .iter()
            .enumerate()
        {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .with_context(|| format!("artifact #{i} missing name"))?
                .to_string();
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .with_context(|| format!("artifact {name} missing file"))?
                .to_string();
            let mut input_shapes = Vec::new();
            for inp in a
                .get("inputs")
                .and_then(Json::as_arr)
                .with_context(|| format!("artifact {name} missing inputs"))?
            {
                let dtype = inp.get("dtype").and_then(Json::as_str).unwrap_or("?");
                if dtype != "float32" {
                    bail!("artifact {name}: dtype {dtype} unsupported (schema 1 is f32-only)");
                }
                let shape: Option<Vec<usize>> = inp
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|dims| dims.iter().filter_map(|d| d.as_u64().map(|v| v as usize)).collect());
                let shape = shape.with_context(|| format!("artifact {name}: bad shape"))?;
                input_shapes.push(shape);
            }
            let num_outputs = a
                .get("num_outputs")
                .and_then(Json::as_u64)
                .with_context(|| format!("artifact {name} missing num_outputs"))?
                as usize;
            artifacts.push(ArtifactSpec { name, file, input_shapes, num_outputs });
        }
        if artifacts.is_empty() {
            bail!("manifest lists no artifacts");
        }

        Ok(Manifest {
            array_s: dim("s")?,
            array_k: dim("k")?,
            array_c: dim("c")?,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    /// Look up an artifact by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// The partition counts for which a `pws_p{n}` artifact exists,
    /// ascending.  The engine picks the smallest variant ≥ the live count.
    pub fn pws_partition_counts(&self) -> Vec<usize> {
        let mut counts: Vec<usize> = self
            .artifacts
            .iter()
            .filter_map(|a| a.name.strip_prefix("pws_p").and_then(|s| s.parse().ok()))
            .collect();
        counts.sort_unstable();
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, text: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("mtsa-manifest-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    const GOOD: &str = r#"{
      "schema": 1,
      "array": {"s": 128, "k": 128, "c": 128},
      "artifacts": [
        {"name": "pws_p2", "file": "pws_p2.hlo.txt",
         "inputs": [{"shape": [2,128,128], "dtype": "float32"},
                    {"shape": [128,128], "dtype": "float32"},
                    {"shape": [2,128], "dtype": "float32"},
                    {"shape": [128,128], "dtype": "float32"}],
         "num_outputs": 1},
        {"name": "pws_p8", "file": "pws_p8.hlo.txt",
         "inputs": [{"shape": [8,128,128], "dtype": "float32"}],
         "num_outputs": 1}
      ]
    }"#;

    #[test]
    fn parses_good_manifest() {
        let d = tmpdir("good");
        write_manifest(&d, GOOD);
        let m = Manifest::load(&d).unwrap();
        assert_eq!((m.array_s, m.array_k, m.array_c), (128, 128, 128));
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("pws_p2").unwrap();
        assert_eq!(a.input_shapes[0], vec![2, 128, 128]);
        assert_eq!(a.num_outputs, 1);
        assert_eq!(m.pws_partition_counts(), vec![2, 8]);
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn rejects_wrong_schema() {
        let d = tmpdir("schema");
        write_manifest(&d, &GOOD.replace("\"schema\": 1", "\"schema\": 9"));
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn rejects_non_f32() {
        let d = tmpdir("dtype");
        write_manifest(&d, &GOOD.replace("float32", "bfloat16"));
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn rejects_missing_file() {
        let d = tmpdir("missing");
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn rejects_empty_artifacts() {
        let d = tmpdir("empty");
        write_manifest(
            &d,
            r#"{"schema": 1, "array": {"s":1,"k":1,"c":1}, "artifacts": []}"#,
        );
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // `make artifacts` not run yet; covered by integration tests
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!((m.array_s, m.array_k, m.array_c), (128, 128, 128));
        assert!(m.pws_partition_counts().contains(&1));
        for a in &m.artifacts {
            assert!(dir.join(&a.file).exists(), "missing {}", a.file);
        }
    }
}
