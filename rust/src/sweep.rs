//! Parallel scenario sweeps — fan a (workload mix × arrival rate ×
//! allocation policy × feed model × array geometry) grid across OS
//! threads and collect per-point SLA metrics.
//!
//! Each grid point is a pure function of its [`SweepGrid`] coordinates and
//! the seed: a scenario is instantiated ([`crate::coordinator::scenario`]),
//! run on the shared discrete-event engine ([`crate::sim_core::Engine`],
//! via [`Scenario::run`]) under both the dynamic partitioning policy and
//! the sequential baseline, and scored against its deadlines.  The sweep
//! owns no time loop of its own.  Purity is what makes the
//! fan-out trivial — workers pull point indices from an atomic counter and
//! write results into their own slots, so the report is byte-identical for
//! a fixed seed regardless of thread count (asserted by
//! `rust/tests/scenario_sweep.rs`).
//!
//! Arrival traces are shared across the policy/feed/geometry axes of the
//! same (mix, rate) cell: every contender schedules the *same* request
//! stream, so differences in the report are attributable to the scheduler,
//! not sampling noise.
//!
//! Entry points: [`run_sweep`] (library / `mtsa sweep` / the `sweep` bench)
//! and the renderers in [`crate::report`] (`sweep_table`, `sweep_json`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::thread;

use anyhow::{Context, Result};

use crate::coordinator::baseline::SequentialBaseline;
use crate::coordinator::scenario::{Scenario, ScenarioOutcome, ScenarioSpec};
use crate::coordinator::scheduler::{
    AllocPolicy, DynamicScheduler, FeedModel, PartitionMode, PreemptMode, SchedulerConfig,
};
use crate::mem::{ArbitrationMode, MemConfig, MemStats};
use crate::sim::dataflow::{ArrayGeometry, VectorUnit};
use crate::workloads::dnng::Dnn;
use crate::workloads::generator::ArrivalProcess;
use crate::workloads::models;

/// Number of windows in each point's occupancy timeline.
pub const OCCUPANCY_BUCKETS: usize = 8;

/// The sweep grid: the cross product of every axis.
#[derive(Debug, Clone)]
pub struct SweepGrid {
    /// Workload mixes: `"heavy"`, `"light"`, or comma-separated zoo model
    /// names (same specs as `mtsa run`).
    pub mixes: Vec<String>,
    /// Mean inter-arrival gaps in cycles; `0` = batch (everything at t=0,
    /// the paper's setup).
    pub rates: Vec<f64>,
    pub policies: Vec<AllocPolicy>,
    pub feeds: Vec<FeedModel>,
    /// Array geometries (`HxW`, or `N` = square); empty = inherit the
    /// base config's geometry.
    pub geoms: Vec<ArrayGeometry>,
    /// Partition-mode axis (`columns` / `2d`); empty = inherit the base
    /// config's mode (so the report carries no mode fields and stays
    /// byte-identical to the pre-2D sweep).
    pub modes: Vec<PartitionMode>,
    /// Preemption axis (`off` / `arrival` / `deadline`, the dynamic
    /// policy's fold-boundary drain-and-reshape); empty = inherit the
    /// base config's mode (report carries no preempt fields and stays
    /// byte-identical to the non-preemptive sweep).
    pub preempts: Vec<PreemptMode>,
    /// Requests per scenario (DNN instances round-robined over the mix).
    pub requests: usize,
    /// Deadline slack factor; `0` disables deadlines.
    pub qos_slack: f64,
    /// Bursty arrivals: `Some((burst_size, within_gap))` turns each
    /// non-zero rate into an ON-OFF process with that rate as the mean OFF
    /// gap; `None` (default) uses Poisson.
    pub bursty: Option<(usize, f64)>,
    /// Shared-memory contention axis: DRAM interface bandwidths
    /// (words/cycle) to sweep.  Empty (default) = no `[mem]` hierarchy
    /// (points inherit the base config, normally isolated DRAM) and the
    /// report carries no mem fields — today's bytes exactly.
    pub bandwidths: Vec<f64>,
    /// Arbitration modes crossed with [`SweepGrid::bandwidths`]; empty
    /// defaults to fair-share when a bandwidth axis is present.
    pub arbitrations: Vec<ArbitrationMode>,
    /// Fleet axis: cluster sizes to run each (mix, non-batch rate) cell
    /// through the serving tier ([`crate::fleet`]).  Empty (default) =
    /// no fleet points and the sweep JSON carries no `fleet` key —
    /// today's bytes exactly.
    pub fleet: Vec<usize>,
    /// Profile-table axis (`mtsa sweep --tables <dir>`): each entry runs
    /// every point with offline fission tables off (`false`) or on
    /// (`true`, consulting [`SweepGrid::tables_store`]).  Empty (default)
    /// = inherit the base config's tables and the report carries no
    /// `tables` fields — today's bytes exactly.
    pub tables: Vec<bool>,
    /// The [`crate::profiler::ProfileStore`] the `tables = true` points
    /// consult; falls back to the base config's store when `None`.
    pub tables_store: Option<std::sync::Arc<crate::profiler::ProfileStore>>,
    /// Heterogeneous-compute axis (`mtsa sweep --lanes`): vector-engine
    /// lane counts to run each point under (`0` = explicitly no lanes).
    /// Empty (default) = inherit the base config's `[vector]` setting and
    /// the report carries no lane fields — today's bytes exactly.
    pub lanes: Vec<u64>,
    pub seed: u64,
}

impl Default for SweepGrid {
    /// The default 24-point grid: {heavy, light} × {batch, 20k, 100k
    /// cycles} × {widest, equal} × {independent, interleaved} on the base
    /// geometry.
    fn default() -> Self {
        SweepGrid {
            mixes: vec!["heavy".to_string(), "light".to_string()],
            rates: vec![0.0, 20_000.0, 100_000.0],
            policies: vec![AllocPolicy::WidestToHeaviest, AllocPolicy::EqualShare],
            feeds: vec![FeedModel::Independent, FeedModel::Interleaved],
            geoms: Vec::new(),
            modes: Vec::new(),
            preempts: Vec::new(),
            requests: 12,
            qos_slack: 3.0,
            bursty: None,
            bandwidths: Vec::new(),
            arbitrations: Vec::new(),
            fleet: Vec::new(),
            tables: Vec::new(),
            tables_store: None,
            lanes: Vec::new(),
            seed: 42,
        }
    }
}

impl SweepGrid {
    /// The arbitration modes the bandwidth axis actually runs under —
    /// empty `arbitrations` defaults to fair-share.  Shared by
    /// [`expand`] and the JSON header (`report::sweep_json`) so the
    /// report can never misstate what the points ran.
    pub fn effective_arbitrations(&self) -> Vec<ArbitrationMode> {
        if self.arbitrations.is_empty() {
            vec![ArbitrationMode::FairShare]
        } else {
            self.arbitrations.clone()
        }
    }
}

/// One grid coordinate (pre-resolved, ready to run).
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub index: usize,
    pub mix: String,
    pub mean_interarrival: f64,
    pub policy: AllocPolicy,
    pub feed: FeedModel,
    pub geom: ArrayGeometry,
    /// Partition mode this point runs under (the base config's when the
    /// grid has no mode axis).
    pub mode: PartitionMode,
    /// Preemption mode this point runs under (the base config's when the
    /// grid has no preempt axis).
    pub preempt: PreemptMode,
    /// `(interface words/cycle, arbitration)` when this point runs under
    /// the shared memory hierarchy; `None` inherits the base config.
    pub mem: Option<(f64, ArbitrationMode)>,
    /// Whether this point's dynamic scheduler consults the offline
    /// profile tables (the base config's setting when the grid has no
    /// tables axis).
    pub tables: bool,
    /// Vector-engine lane count this point runs under: `Some(0)` forces
    /// the array-only model, `Some(n)` an `n`-lane engine at default
    /// rates, `None` inherits the base config's `[vector]` setting.
    pub lanes: Option<u64>,
    /// Scenario seed — shared across policy/feed/geometry/mode/mem so
    /// every contender in a (mix, rate) cell sees the same arrival trace.
    pub scenario_seed: u64,
}

/// One finished grid point.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub point: SweepPoint,
    pub requests: usize,
    pub makespan: u64,
    pub seq_makespan: u64,
    /// MAC-based PE utilization of the dynamic run.
    pub utilization: f64,
    pub seq_utilization: f64,
    /// Dynamic-run SLA outcome (per-tenant + overall).
    pub outcome: ScenarioOutcome,
    /// Sequential-baseline SLA outcome (the comparison column).
    pub seq_outcome: ScenarioOutcome,
    /// Time-sliced occupancy of the dynamic run ([`OCCUPANCY_BUCKETS`]
    /// windows over the makespan).
    pub occupancy: Vec<f64>,
    /// Memory-hierarchy summary of the dynamic run; `Some` exactly when
    /// the point ran with `[mem]` enabled.
    pub mem: Option<MemSummary>,
    /// Fold-boundary preemptions the dynamic run took (0 with `preempt`
    /// off — the counters only reach the report when the axis is on).
    pub preemptions: u64,
    /// Cycles the dynamic run spent on replayed folds.
    pub wasted_refill_cycles: u64,
    /// Lane-pool summary of the dynamic run; `Some` exactly when the
    /// point ran with a vector engine configured.
    pub vector: Option<VectorSummary>,
}

/// Vector-engine summary of one grid point's dynamic run.
#[derive(Debug, Clone)]
pub struct VectorSummary {
    /// Lane count the point's vector engine had.
    pub lanes: u64,
    /// Layer segments the dynamic run placed on lanes.
    pub dispatches: u64,
}

/// Shared-memory summary of one grid point's dynamic run.
#[derive(Debug, Clone)]
pub struct MemSummary {
    /// Interface bandwidth this point ran under (words/cycle).
    pub words_per_cycle: f64,
    pub arbitration: ArbitrationMode,
    /// All tenants pooled ([`RunMetrics::mem_total`](crate::coordinator::metrics::RunMetrics)).
    pub stats: MemStats,
}

/// Expand a grid into its points (row-major over mix, rate, policy, feed,
/// geometry, partition mode, mem, preempt, tables, lanes — the JSON/table
/// row order).
pub fn expand(grid: &SweepGrid, base: &SchedulerConfig) -> Vec<SweepPoint> {
    let geoms: Vec<ArrayGeometry> =
        if grid.geoms.is_empty() { vec![base.geom] } else { grid.geoms.clone() };
    let modes: Vec<PartitionMode> =
        if grid.modes.is_empty() { vec![base.partition_mode] } else { grid.modes.clone() };
    let preempts: Vec<PreemptMode> =
        if grid.preempts.is_empty() { vec![base.preempt] } else { grid.preempts.clone() };
    // The contention axis: no bandwidths = one inherit-the-base point.
    let mems: Vec<Option<(f64, ArbitrationMode)>> = if grid.bandwidths.is_empty() {
        vec![None]
    } else {
        let arbs = grid.effective_arbitrations();
        grid.bandwidths
            .iter()
            .flat_map(|&bw| arbs.iter().map(move |&arb| Some((bw, arb))))
            .collect()
    };
    let tabs: Vec<bool> =
        if grid.tables.is_empty() { vec![base.tables.is_some()] } else { grid.tables.clone() };
    // The heterogeneous axis: no lane counts = one inherit-the-base point.
    let lane_axis: Vec<Option<u64>> = if grid.lanes.is_empty() {
        vec![None]
    } else {
        grid.lanes.iter().map(|&l| Some(l)).collect()
    };
    let mut points = Vec::new();
    for (mi, mix) in grid.mixes.iter().enumerate() {
        for (ri, &rate) in grid.rates.iter().enumerate() {
            let scenario_seed = grid
                .seed
                .wrapping_add((mi as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
                .wrapping_add((ri as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03));
            for &policy in &grid.policies {
                for &feed in &grid.feeds {
                    for &geom in &geoms {
                        for &mode in &modes {
                            for &mem in &mems {
                                for &preempt in &preempts {
                                    for &tables in &tabs {
                                        for &lanes in &lane_axis {
                                            points.push(SweepPoint {
                                                index: points.len(),
                                                mix: mix.clone(),
                                                mean_interarrival: rate,
                                                policy,
                                                feed,
                                                geom,
                                                mode,
                                                preempt,
                                                mem,
                                                tables,
                                                lanes,
                                                scenario_seed,
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    points
}

/// The arrival process for one grid point.
fn arrival_for(grid: &SweepGrid, rate: f64) -> ArrivalProcess {
    if rate <= 0.0 {
        ArrivalProcess::Batch
    } else if let Some((burst_size, within_gap)) = grid.bursty {
        ArrivalProcess::Bursty { burst_size, within_gap, between_gap: rate }
    } else {
        ArrivalProcess::Poisson { mean_interarrival: rate }
    }
}

/// Run a single grid point (pure: no shared state).  Both contenders are
/// [`Scheduler`](crate::sim_core::Scheduler) policies driven through
/// [`Scenario::run`] — i.e. the one shared engine — so adding a policy
/// axis is "construct another `impl Scheduler`", nothing more.
fn run_point(
    point: &SweepPoint,
    grid: &SweepGrid,
    base: &SchedulerConfig,
    templates: &[Dnn],
) -> SweepRow {
    let geom = point.geom;
    let mut cfg = SchedulerConfig {
        geom,
        min_width: (geom.cols / 8).max(1).min(base.min_width.max(1)),
        min_rows: (geom.rows / 8).max(1).min(base.min_rows.max(1)),
        partition_mode: point.mode,
        preempt: point.preempt,
        feed_model: point.feed,
        alloc_policy: point.policy,
        ..base.clone()
    };
    if let Some((bw, arb)) = point.mem {
        // The contention axis: this point runs under the shared memory
        // hierarchy, which subsumes any isolated [dram] bound — whose
        // interface parameters (burst latency) it inherits, exactly like
        // `mtsa run --mem`.
        let base_mem = base.mem.unwrap_or(MemConfig {
            dram: base.dram.unwrap_or_default(),
            ..MemConfig::default()
        });
        cfg.mem = Some(MemConfig {
            dram: crate::sim::dram::DramConfig { words_per_cycle: bw, ..base_mem.dram },
            arbitration: arb,
            banks: base_mem.banks,
        });
        cfg.dram = None;
    }
    cfg.tables = if point.tables {
        grid.tables_store.clone().or_else(|| base.tables.clone())
    } else {
        None
    };
    if let Some(l) = point.lanes {
        cfg.vector = if l == 0 { None } else { Some(VectorUnit::new(l)) };
    }
    let spec = ScenarioSpec {
        name: format!("{}@{}", point.mix, point.mean_interarrival),
        arrival: arrival_for(grid, point.mean_interarrival),
        requests: grid.requests,
        seed: point.scenario_seed,
        qos_slack: (grid.qos_slack > 0.0).then_some(grid.qos_slack),
    };
    let scenario = Scenario::generate(templates, &spec, &cfg);
    let (dyn_obs, outcome) = scenario.run(&mut DynamicScheduler::new(cfg.clone()), geom);
    let (seq_obs, seq_outcome) = scenario.run(&mut SequentialBaseline::new(cfg.clone()), geom);
    let (dynamic, sequential) = (dyn_obs.metrics, seq_obs.metrics);
    let mem = cfg.mem.map(|m| MemSummary {
        words_per_cycle: m.dram.words_per_cycle,
        arbitration: m.arbitration,
        stats: dynamic.mem_total,
    });
    let vector = cfg
        .vector
        .map(|v| VectorSummary { lanes: v.lanes, dispatches: dynamic.vector_dispatches });
    SweepRow {
        point: point.clone(),
        requests: grid.requests,
        makespan: dynamic.makespan,
        seq_makespan: sequential.makespan,
        utilization: dynamic.utilization(cfg.geom),
        seq_utilization: sequential.utilization(cfg.geom),
        preemptions: dynamic.preemptions,
        wasted_refill_cycles: dynamic.wasted_refill_cycles,
        outcome,
        seq_outcome,
        occupancy: dynamic.occupancy_timeline(geom, OCCUPANCY_BUCKETS),
        mem,
        vector,
    }
}

/// Run the whole grid across `threads` workers; rows come back in grid
/// order regardless of scheduling.
pub fn run_sweep(
    grid: &SweepGrid,
    base: &SchedulerConfig,
    threads: usize,
) -> Result<Vec<SweepRow>> {
    // Resolve every mix up front so workers are infallible.
    let mut mix_templates: Vec<(String, Vec<Dnn>)> = Vec::new();
    for mix in &grid.mixes {
        let pool = models::by_spec(mix)
            .map_err(anyhow::Error::msg)
            .with_context(|| format!("resolving workload mix {mix:?}"))?;
        mix_templates.push((mix.clone(), pool.dnns));
    }

    let points = expand(grid, base);
    if points.iter().any(|p| p.tables)
        && grid.tables_store.is_none()
        && base.tables.is_none()
    {
        anyhow::bail!(
            "sweep tables axis is on but no profile tables are loaded — \
             pass `--tables <dir>` or set `[partition] tables`"
        );
    }
    let point_templates: Vec<&[Dnn]> = points
        .iter()
        .map(|p| {
            mix_templates
                .iter()
                .find(|(m, _)| *m == p.mix)
                .map(|(_, t)| t.as_slice())
                .expect("mix resolved above")
        })
        .collect();
    let threads = threads.max(1).min(points.len().max(1));
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<SweepRow>>> =
        points.iter().map(|_| Mutex::new(None)).collect();

    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                let Some(point) = points.get(i) else { break };
                let row = run_point(point, grid, base, point_templates[i]);
                *slots[i].lock().expect("sweep slot poisoned") = Some(row);
            });
        }
    });

    Ok(slots
        .into_iter()
        .map(|slot| slot.into_inner().expect("slot lock").expect("worker filled every slot"))
        .collect())
}

/// One finished fleet-axis point ([`SweepGrid::fleet`]).
#[derive(Debug, Clone)]
pub struct FleetAxisRow {
    /// Cluster size this point ran at.
    pub instances: usize,
    pub mix: String,
    pub mean_interarrival: f64,
    /// Same per-(mix, rate)-cell derivation as [`expand`], so a fleet
    /// point shares its arrival seed with the single-array points of the
    /// same cell.
    pub scenario_seed: u64,
    pub report: crate::fleet::FleetReport,
}

/// Run the grid's fleet axis: every (mix, non-batch rate) cell through a
/// uniform dynamic-partitioned cluster of each size in
/// [`SweepGrid::fleet`].  Batch-arrival cells are skipped — "everything
/// at t=0" is not a serving workload.  `threads` parallelizes instances
/// inside each fleet run; the rows are byte-stable for any value.
pub fn run_fleet_axis(
    grid: &SweepGrid,
    base: &SchedulerConfig,
    threads: usize,
) -> Result<Vec<FleetAxisRow>> {
    use crate::fleet::{run_fleet, FleetConfig, FleetPolicy, Placement};
    use crate::workloads::generator::ModelMix;

    let mut rows = Vec::new();
    if grid.fleet.is_empty() {
        return Ok(rows);
    }
    for (mi, mix) in grid.mixes.iter().enumerate() {
        let pool = models::by_spec(mix)
            .map_err(anyhow::Error::msg)
            .with_context(|| format!("resolving fleet mix {mix:?}"))?;
        let weights: Vec<(&str, f64)> =
            pool.dnns.iter().map(|d| (d.name.as_str(), 1.0)).collect();
        for (ri, &rate) in grid.rates.iter().enumerate() {
            if rate <= 0.0 {
                continue;
            }
            let scenario_seed = grid
                .seed
                .wrapping_add((mi as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
                .wrapping_add((ri as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03));
            for &n in &grid.fleet {
                let mut classes = FleetConfig::default_classes(rate);
                if grid.qos_slack > 0.0 {
                    classes[0].slack = Some(grid.qos_slack);
                }
                let cfg = FleetConfig {
                    instances: FleetConfig::uniform(n, base, FleetPolicy::Dynamic),
                    placement: Placement::LeastLoaded,
                    random_k: 2,
                    classes,
                    slots: 8,
                    queue_cap: 64,
                    mix: ModelMix::new(&weights),
                    arrival: arrival_for(grid, rate),
                    diurnal: None,
                    requests: grid.requests,
                    seed: scenario_seed,
                    chunk: 4096,
                    tables: None,
                };
                let report = run_fleet(&cfg, threads)
                    .with_context(|| format!("fleet axis point {mix}@{rate}x{n}"))?;
                rows.push(FleetAxisRow {
                    instances: n,
                    mix: mix.clone(),
                    mean_interarrival: rate,
                    scenario_seed,
                    report,
                });
            }
        }
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_has_24_points() {
        let grid = SweepGrid::default();
        let points = expand(&grid, &SchedulerConfig::default());
        assert_eq!(points.len(), 24);
        // Indices are dense and ordered.
        for (i, p) in points.iter().enumerate() {
            assert_eq!(p.index, i);
        }
        // Geometry and mode inherited from the base config.
        assert!(points.iter().all(|p| p.geom == ArrayGeometry::new(128, 128)));
        assert!(points.iter().all(|p| p.mode == PartitionMode::Columns));
    }

    #[test]
    fn scenario_seed_shared_within_mix_rate_cell() {
        let grid = SweepGrid::default();
        let points = expand(&grid, &SchedulerConfig::default());
        for a in &points {
            for b in &points {
                let same_cell = a.mix == b.mix && a.mean_interarrival == b.mean_interarrival;
                assert_eq!(
                    same_cell,
                    a.scenario_seed == b.scenario_seed,
                    "seed sharing must follow (mix, rate) cells exactly"
                );
            }
        }
    }

    #[test]
    fn geometry_axis_expands() {
        let grid = SweepGrid {
            mixes: vec!["light".into()],
            rates: vec![0.0],
            policies: vec![AllocPolicy::WidestToHeaviest],
            feeds: vec![FeedModel::Independent],
            geoms: vec![ArrayGeometry::new(64, 64), ArrayGeometry::new(64, 256)],
            ..Default::default()
        };
        let points = expand(&grid, &SchedulerConfig::default());
        assert_eq!(points.len(), 2);
        assert_eq!(points[0].geom, ArrayGeometry::new(64, 64));
        assert_eq!(points[1].geom, ArrayGeometry::new(64, 256), "HxW geometries expand too");
    }

    #[test]
    fn mode_axis_expands() {
        let grid = SweepGrid {
            mixes: vec!["light".into()],
            rates: vec![0.0],
            policies: vec![AllocPolicy::WidestToHeaviest],
            feeds: vec![FeedModel::Independent],
            modes: vec![PartitionMode::Columns, PartitionMode::TwoD],
            ..Default::default()
        };
        let points = expand(&grid, &SchedulerConfig::default());
        assert_eq!(points.len(), 2);
        assert_eq!(points[0].mode, PartitionMode::Columns);
        assert_eq!(points[1].mode, PartitionMode::TwoD);
    }

    #[test]
    fn preempt_axis_expands_and_default_inherits_off() {
        let grid = SweepGrid {
            mixes: vec!["light".into()],
            rates: vec![0.0],
            policies: vec![AllocPolicy::WidestToHeaviest],
            feeds: vec![FeedModel::Independent],
            preempts: vec![PreemptMode::Off, PreemptMode::Arrival, PreemptMode::Deadline],
            ..Default::default()
        };
        let points = expand(&grid, &SchedulerConfig::default());
        assert_eq!(points.len(), 3);
        assert_eq!(points[0].preempt, PreemptMode::Off);
        assert_eq!(points[1].preempt, PreemptMode::Arrival);
        assert_eq!(points[2].preempt, PreemptMode::Deadline);
        let plain = expand(&SweepGrid::default(), &SchedulerConfig::default());
        assert!(plain.iter().all(|p| p.preempt == PreemptMode::Off));
    }

    #[test]
    fn bandwidth_axis_crosses_with_arbitration() {
        let grid = SweepGrid {
            mixes: vec!["light".into()],
            rates: vec![0.0],
            policies: vec![AllocPolicy::WidestToHeaviest],
            feeds: vec![FeedModel::Independent],
            geoms: vec![ArrayGeometry::new(128, 128)],
            bandwidths: vec![8.0, 64.0],
            arbitrations: vec![ArbitrationMode::FairShare, ArbitrationMode::StrictPriority],
            ..Default::default()
        };
        let points = expand(&grid, &SchedulerConfig::default());
        assert_eq!(points.len(), 4);
        assert_eq!(points[0].mem, Some((8.0, ArbitrationMode::FairShare)));
        assert_eq!(points[3].mem, Some((64.0, ArbitrationMode::StrictPriority)));
        // No bandwidth axis: the mem coordinate stays inherited.
        let plain = expand(&SweepGrid::default(), &SchedulerConfig::default());
        assert!(plain.iter().all(|p| p.mem.is_none()));
    }

    #[test]
    fn mem_points_report_contention() {
        let grid = SweepGrid {
            mixes: vec!["NCF".into()],
            rates: vec![0.0],
            policies: vec![AllocPolicy::WidestToHeaviest, AllocPolicy::MemAware],
            feeds: vec![FeedModel::Independent],
            geoms: vec![ArrayGeometry::new(128, 128)],
            requests: 4,
            bandwidths: vec![4.0],
            ..Default::default()
        };
        let rows = run_sweep(&grid, &SchedulerConfig::default(), 2).unwrap();
        assert_eq!(rows.len(), 2);
        for row in &rows {
            let mem = row.mem.as_ref().expect("bandwidth axis => mem summary");
            assert_eq!(mem.words_per_cycle, 4.0);
            assert!(mem.stats.layers > 0);
            assert!(mem.stats.xfer_words > 0);
            assert!(
                mem.stats.achieved_words_per_cycle() <= 4.0 + 1e-9,
                "cannot beat the interface: {}",
                mem.stats.achieved_words_per_cycle()
            );
        }
    }

    #[test]
    fn unknown_mix_is_an_error() {
        let grid = SweepGrid { mixes: vec!["nope".into()], ..Default::default() };
        assert!(run_sweep(&grid, &SchedulerConfig::default(), 1).is_err());
    }

    #[test]
    fn small_sweep_runs_and_orders_rows() {
        let grid = SweepGrid {
            mixes: vec!["light".into()],
            rates: vec![0.0, 50_000.0],
            policies: vec![AllocPolicy::WidestToHeaviest],
            feeds: vec![FeedModel::Independent],
            geoms: vec![ArrayGeometry::new(128, 128)],
            requests: 4,
            ..Default::default()
        };
        let rows = run_sweep(&grid, &SchedulerConfig::default(), 2).unwrap();
        assert_eq!(rows.len(), 2);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.point.index, i);
            assert!(row.makespan > 0);
            assert!(row.seq_makespan >= row.makespan / 2, "sanity");
            assert_eq!(row.occupancy.len(), OCCUPANCY_BUCKETS);
            assert!(row.occupancy.iter().all(|&o| (0.0..=1.0 + 1e-9).contains(&o)));
            assert_eq!(row.outcome.overall.requests, 4);
            assert!((0.0..=1.0).contains(&row.outcome.miss_rate()));
        }
    }

    #[test]
    fn tables_axis_expands_and_requires_a_store() {
        let grid = SweepGrid {
            mixes: vec!["light".into()],
            rates: vec![0.0],
            policies: vec![AllocPolicy::WidestToHeaviest],
            feeds: vec![FeedModel::Independent],
            tables: vec![false, true],
            ..Default::default()
        };
        let base = SchedulerConfig::default();
        let points = expand(&grid, &base);
        assert_eq!(points.len(), 2);
        assert!(!points[0].tables);
        assert!(points[1].tables);
        // No tables axis: the coordinate inherits the base config (off).
        let plain = expand(&SweepGrid::default(), &base);
        assert!(plain.iter().all(|p| !p.tables));
        // Turning the axis on with no store loaded anywhere is an error,
        // not 24 silently table-less points.
        let err = run_sweep(&grid, &base, 1).unwrap_err();
        assert!(format!("{err}").contains("--tables"), "{err}");
    }

    #[test]
    fn tables_axis_pairs_rows_and_keeps_2d_plans_sound() {
        use crate::profiler::{ProfileStore, ProfileTable};
        use crate::sim::buffers::BufferConfig;
        let geom = ArrayGeometry::new(128, 128);
        let bufs = BufferConfig::default();
        let dnn = (models::by_name("NCF").unwrap().build)();
        let table = ProfileTable::build("NCF", &dnn, geom, &bufs);
        let grid = SweepGrid {
            mixes: vec!["NCF".into()],
            rates: vec![0.0],
            policies: vec![AllocPolicy::WidestToHeaviest],
            feeds: vec![FeedModel::Independent],
            modes: vec![PartitionMode::TwoD],
            requests: 4,
            tables: vec![false, true],
            tables_store: Some(std::sync::Arc::new(ProfileStore::from_tables(
                "test",
                vec![table],
            ))),
            ..Default::default()
        };
        let rows = run_sweep(&grid, &SchedulerConfig::default(), 2).unwrap();
        assert_eq!(rows.len(), 2, "off/on pair per cell");
        assert!(!rows[0].point.tables);
        assert!(rows[1].point.tables);
        for row in &rows {
            assert!(row.makespan > 0);
            assert_eq!(row.outcome.overall.requests, 4);
        }
    }

    #[test]
    fn lanes_axis_expands_and_places_memory_bound_layers() {
        let grid = SweepGrid {
            mixes: vec!["NCF".into()],
            rates: vec![0.0],
            policies: vec![AllocPolicy::WidestToHeaviest],
            feeds: vec![FeedModel::Independent],
            requests: 4,
            lanes: vec![0, 128],
            ..Default::default()
        };
        let base = SchedulerConfig::default();
        let points = expand(&grid, &base);
        assert_eq!(points.len(), 2);
        assert_eq!(points[0].lanes, Some(0));
        assert_eq!(points[1].lanes, Some(128));
        // No lanes axis: the coordinate inherits the base config (off).
        let plain = expand(&SweepGrid::default(), &base);
        assert!(plain.iter().all(|p| p.lanes.is_none()));
        let rows = run_sweep(&grid, &base, 2).unwrap();
        assert!(rows[0].vector.is_none(), "lanes = 0 forces the array-only model");
        let v = rows[1].vector.as_ref().expect("lanes axis => vector summary");
        assert_eq!(v.lanes, 128);
        assert!(v.dispatches > 0, "NCF's embeddings are memory-bound and must land on lanes");
        assert!(rows[1].makespan > 0);
    }

    #[test]
    fn fleet_axis_skips_batch_cells_and_is_thread_stable() {
        let grid = SweepGrid {
            mixes: vec!["NCF".to_string()],
            rates: vec![0.0, 40_000.0],
            requests: 30,
            fleet: vec![2],
            ..Default::default()
        };
        let base = SchedulerConfig::default();
        let a = run_fleet_axis(&grid, &base, 1).unwrap();
        let b = run_fleet_axis(&grid, &base, 4).unwrap();
        // The batch (rate 0) cell is skipped: one point remains.
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].instances, 2);
        assert_eq!(a[0].report.generated, 30);
        assert_eq!(a[0].report.completed, b[0].report.completed);
        assert_eq!(a[0].report.makespan, b[0].report.makespan);
        // Fleet points share the cell's arrival seed with expand().
        let points = expand(&grid, &base);
        let cell = points.iter().find(|p| p.mean_interarrival > 0.0).unwrap();
        assert_eq!(a[0].scenario_seed, cell.scenario_seed);
    }
}
