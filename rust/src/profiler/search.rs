//! The offline fission search: enumerate the useful tile shapes of one
//! lowered GEMM on one array geometry.
//!
//! The closed-form timing of a tile at the array origin is *linear in the
//! stream length*: for a `[Sr, K] × [K, M]` GEMM on a `rows × cols` tile
//! placed at `(row0, col0)`,
//!
//! ```text
//! cycles = FM·K + FK·M + FK·FM·(row0 + Sr + H + col0 − 1)
//!        = a + b·(Sr + row0 + col0)
//! a      = FM·K + FK·M + FK·FM·(H − 1)      (H = physical array rows)
//! b      = FK·FM
//! ```
//!
//! so a candidate is fully described by `(rows, cols, a, b)` and stays
//! valid for *any* batch size (fleet batching multiplies `N`, hence `Sr`,
//! leaving `FK`/`FM` untouched) and any placement offset.  The search
//! space collapses accordingly: only tile heights that change `FK` and
//! widths that change `FM` matter, and the minimal height per `FK` (resp.
//! width per `FM`) dominates every taller/wider tile with the same fold
//! count.  That is `O(√K · √M)` shapes instead of `rows × cols`.
//!
//! The equality `cycles == a + b·(sr + row0 + col0)` against the real
//! pricing function [`layer_timing_tile_with_share`] is pinned by
//! `tests::candidates_match_closed_form_pricing` — the table never
//! disagrees with what the scheduler would compute online.
//!
//! [`layer_timing_tile_with_share`]: crate::sim::dataflow::layer_timing_tile_with_share

use crate::sim::dataflow::ArrayGeometry;
use crate::util::ceil_div;

/// Candidates kept per layer after ranking.  The scheduler unions the
/// table with its pow-2 ladder at plan time, so the cap trades table size
/// against coverage of small free rectangles — the ladder backstops
/// whatever the cap drops.
pub const CANDIDATE_CAP: usize = 64;

/// One profiled tile shape: a `rows × cols` tile whose origin-placed
/// cycle count is `a + b·sr` (see the module doc for the offset form).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileCandidate {
    pub rows: u64,
    pub cols: u64,
    /// Stream-independent cycle intercept.
    pub a: u64,
    /// Cycles per stream row (`FK·FM`).
    pub b: u64,
}

impl TileCandidate {
    /// Cycles of this shape placed at `(row0, col0)` for stream length
    /// `sr` — the exact closed form, reusable without re-deriving folds.
    pub fn cycles(&self, sr: u64, row0: u64, col0: u64) -> u64 {
        self.a.saturating_add(self.b.saturating_mul(sr.saturating_add(row0).saturating_add(col0)))
    }
}

/// Distinct values of `min(⌈dim/f⌉, cap)` for `f = 1, 2, …`, descending —
/// the only tile extents that change the fold count along one axis.
/// Classic divisor-jump enumeration: `O(√dim)` values, no scan.
fn fold_extents(dim: u64, cap: u64) -> Vec<u64> {
    debug_assert!(dim > 0 && cap > 0);
    let mut out = Vec::new();
    let mut f = 1u64;
    loop {
        let v = ceil_div(dim, f).min(cap);
        out.push(v);
        if v == 1 {
            break;
        }
        // Smallest f' with ⌈dim/f'⌉ ≤ v − 1.
        f = ceil_div(dim, v - 1);
    }
    out
}

/// Enumerate the candidate tile shapes of a `[*, K] × [K, M]` GEMM on
/// `geom`: every (minimal-height per `FK`) × (minimal-width per `FM`)
/// pair, ranked by origin-placed cycles at reference stream length
/// `ref_sr` and capped at [`CANDIDATE_CAP`].  The result is sorted by
/// `(rows, cols)` — a deterministic storage order independent of the
/// ranking's tie behaviour.
pub fn enumerate_candidates(geom: ArrayGeometry, k: u64, m: u64, ref_sr: u64) -> Vec<TileCandidate> {
    assert!(k > 0 && m > 0, "degenerate GEMM [{k} x {m}]");
    let heights = fold_extents(k, geom.rows);
    let widths = fold_extents(m, geom.cols);
    let mut cands = Vec::with_capacity(heights.len() * widths.len());
    for &h in &heights {
        let fk = ceil_div(k, h);
        for &w in &widths {
            let fm = ceil_div(m, w);
            let b = fk * fm;
            let a = fm * k + fk * m + b * (geom.rows - 1);
            cands.push(TileCandidate { rows: h, cols: w, a, b });
        }
    }
    // Keep the shapes that price fastest at the profiled batch size
    // (ties: fewest PEs, then smallest dims — all integer, fully
    // deterministic).  Larger tiles are never slower than smaller ones,
    // so this keeps a usable spread of footprints, not just one winner.
    cands.sort_by_key(|c| (c.cycles(ref_sr, 0, 0), c.rows * c.cols, c.rows, c.cols));
    cands.truncate(CANDIDATE_CAP);
    cands.sort_by_key(|c| (c.rows, c.cols, c.a, c.b));
    cands
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::buffers::BufferConfig;
    use crate::sim::dataflow::layer_timing_tile_with_share;
    use crate::sim::partitioned::Tile;
    use crate::util::prop;
    use crate::workloads::shapes::GemmDims;

    #[test]
    fn fold_extents_are_distinct_and_descending() {
        assert_eq!(fold_extents(10, 128), vec![10, 5, 4, 3, 2, 1]);
        assert_eq!(fold_extents(1, 128), vec![1]);
        // Values above the cap collapse to it exactly once.
        assert_eq!(fold_extents(10, 4), vec![4, 3, 2, 1]);
        prop::check("fold extents distinct + cover every fold count", 50, |rng| {
            let dim = rng.gen_range_inclusive(1, 10_000);
            let cap = rng.gen_range_inclusive(1, 256);
            let ext = fold_extents(dim, cap);
            for w in ext.windows(2) {
                prop::ensure(w[0] > w[1], "descending distinct")?;
            }
            // Minimality: shrinking any extent by one changes the fold count.
            for &v in &ext {
                if v > 1 {
                    prop::ensure(ceil_div(dim, v - 1) > ceil_div(dim, v), "minimal per fold count")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn candidates_match_closed_form_pricing() {
        // (rows, cols, a, b) must reproduce the real pricing function for
        // any placement and any batch-scaled stream length.
        prop::check("candidate a + b·sr == layer_timing_tile_with_share", 60, |rng| {
            let geom = ArrayGeometry::new(
                rng.gen_range_inclusive(1, 160),
                rng.gen_range_inclusive(1, 160),
            );
            let k = rng.gen_range_inclusive(1, 2048);
            let m = rng.gen_range_inclusive(1, 2048);
            let sr = rng.gen_range_inclusive(1, 8000);
            for c in enumerate_candidates(geom, k, m, sr) {
                let row0 = rng.gen_range_inclusive(0, geom.rows - c.rows);
                let col0 = rng.gen_range_inclusive(0, geom.cols - c.cols);
                let tile = Tile::new(row0, col0, c.rows, c.cols);
                let share = BufferConfig::default().share(tile.pes(), geom.pes());
                let t = layer_timing_tile_with_share(geom, GemmDims { sr, k, m }, tile, &share, None);
                prop::ensure_eq(c.cycles(sr, row0, col0), t.cycles, "cycles")?;
            }
            Ok(())
        });
    }

    #[test]
    fn candidates_include_exact_fit_shapes() {
        // 1152 on 96 rows divides exactly: the non-pow-2 height 96 must be
        // offered (the shape the pow-2 ladder can never reach).
        let geom = ArrayGeometry::new(96, 128);
        let cands = enumerate_candidates(geom, 1152, 384, 4000);
        assert!(cands.iter().any(|c| c.rows == 96), "{cands:?}");
        assert!(cands.iter().any(|c| c.cols == 96));
        // And each candidate's extents are minimal for their fold count.
        for c in &cands {
            let fk = ceil_div(1152, c.rows);
            assert_eq!(c.b % fk, 0);
            assert_eq!(c.rows, ceil_div(1152, fk).min(geom.rows));
        }
    }

    #[test]
    fn candidate_count_is_capped_and_sorted() {
        let geom = ArrayGeometry::new(128, 128);
        let cands = enumerate_candidates(geom, 8192, 8192, 3025);
        assert!(cands.len() <= CANDIDATE_CAP);
        assert!(!cands.is_empty());
        for w in cands.windows(2) {
            assert!((w[0].rows, w[0].cols) < (w[1].rows, w[1].cols), "sorted, distinct shapes");
        }
    }

    #[test]
    fn bigger_tiles_never_price_slower() {
        let geom = ArrayGeometry::new(128, 128);
        let cands = enumerate_candidates(geom, 1024, 512, 1000);
        let full = cands.iter().max_by_key(|c| c.rows * c.cols).unwrap();
        for c in &cands {
            assert!(c.cycles(1000, 0, 0) >= full.cycles(1000, 0, 0));
        }
    }
}
