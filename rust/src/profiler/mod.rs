//! Offline fission profiler — the `mtsa profile` subsystem.
//!
//! Planaria (MICRO'20) profiles each layer's optimal fission *offline*
//! and schedules from the resulting tables; this module does the same for
//! the closed-form weight-stationary model.  For every (model, geometry)
//! pair it exhaustively searches tile shapes × bank grants per layer
//! using the analytic pricing (`layer_timing_tile_with_share` — no
//! simulation), and persists:
//!
//! - a compact summary table ([`ProfileTable`], `*.table.json`) the
//!   schedulers consult at plan time, and
//! - a comprehensive per-candidate report (`*.report.csv`) with the
//!   bank-grant sensitivity sweep (cycles, refetch words, stall proxy,
//!   energy).
//!
//! Consumers:
//!
//! - the dynamic policy's `2d` mode ([`SchedulerConfig::tables`]) unions
//!   the table's exact-fit shapes with its online pow-2 ladder — never
//!   worse than the ladder, and byte-identical to it when unset;
//! - the fleet router ([`FleetConfig::tables`]) reads isolated-run
//!   horizon estimates from the table totals (`iso_a + batch·iso_b`)
//!   instead of re-summing per-layer baselines — exactly equal by
//!   construction, so fleet output bytes do not change.
//!
//! Tables are versioned and carry a content hash of (model, geometry,
//! layer GEMMs); [`ProfileStore::load`] rejects stale tables with an
//! error naming the model.
//!
//! [`SchedulerConfig::tables`]: crate::coordinator::scheduler::SchedulerConfig
//! [`FleetConfig::tables`]: crate::fleet::FleetConfig

pub mod search;
pub mod table;

pub use search::{enumerate_candidates, TileCandidate, CANDIDATE_CAP};
pub use table::{
    content_hash, isolated_cycles, LayerProfile, ProfileStore, ProfileTable, GRANT_LEVELS,
    PROFILE_SCHEMA,
};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::sim::buffers::BufferConfig;
use crate::sim::dataflow::ArrayGeometry;
use crate::workloads::models;

/// Build profile tables for `model × geometry` jobs on up to `threads`
/// workers.  Table construction is pure per job and results are returned
/// in job order, so the output (and any file written from it) is
/// byte-identical at every thread count — the same claim-by-atomic-index
/// pattern as the sweep runner.
pub fn build_tables(
    jobs: &[(String, ArrayGeometry)],
    bufs: &BufferConfig,
    threads: usize,
) -> Result<Vec<ProfileTable>, String> {
    // Resolve names up front so a typo fails before any work.
    let mut resolved = Vec::with_capacity(jobs.len());
    for (name, geom) in jobs {
        let entry = models::by_name(name)
            .ok_or_else(|| format!("unknown model {name:?} (see `mtsa zoo`)"))?;
        resolved.push((entry, *geom));
    }
    let slots: Mutex<Vec<Option<ProfileTable>>> = Mutex::new(vec![None; resolved.len()]);
    let next = AtomicUsize::new(0);
    let workers = threads.clamp(1, resolved.len().max(1));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= resolved.len() {
                    break;
                }
                let (entry, geom) = resolved[i];
                let table = ProfileTable::build(entry.name, &(entry.build)(), geom, bufs);
                slots.lock().unwrap()[i] = Some(table);
            });
        }
    });
    Ok(slots.into_inner().unwrap().into_iter().map(|t| t.expect("worker filled slot")).collect())
}

/// Write a table's two artifacts under `dir`; returns the summary-table
/// file name.
pub fn write_artifacts(
    table: &ProfileTable,
    bufs: &BufferConfig,
    dir: &std::path::Path,
) -> Result<String, String> {
    std::fs::create_dir_all(dir)
        .map_err(|e| format!("cannot create profile dir {}: {e}", dir.display()))?;
    let stem = table.stem();
    let json_path = dir.join(format!("{stem}.table.json"));
    std::fs::write(&json_path, table.to_json().render() + "\n")
        .map_err(|e| format!("cannot write {}: {e}", json_path.display()))?;
    let csv_path = dir.join(format!("{stem}.report.csv"));
    std::fs::write(&csv_path, table.report_csv(bufs))
        .map_err(|e| format!("cannot write {}: {e}", csv_path.display()))?;
    Ok(format!("{stem}.table.json"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_tables_rejects_unknown_models_by_name() {
        let jobs = vec![("Nonesuch".to_string(), ArrayGeometry::new(128, 128))];
        let err = build_tables(&jobs, &BufferConfig::default(), 2).unwrap_err();
        assert!(err.contains("Nonesuch"), "{err}");
    }

    #[test]
    fn build_tables_is_thread_count_invariant() {
        let jobs: Vec<(String, ArrayGeometry)> = ["NCF", "MelodyLSTM", "AlexNet"]
            .iter()
            .flat_map(|m| {
                [ArrayGeometry::new(128, 128), ArrayGeometry::new(96, 64)]
                    .map(|g| (m.to_string(), g))
            })
            .collect();
        let bufs = BufferConfig::default();
        let one = build_tables(&jobs, &bufs, 1).unwrap();
        let four = build_tables(&jobs, &bufs, 4).unwrap();
        assert_eq!(one.len(), jobs.len());
        let render = |ts: &[ProfileTable]| -> Vec<String> {
            ts.iter().map(|t| t.to_json().render()).collect()
        };
        assert_eq!(render(&one), render(&four));
    }

    #[test]
    fn artifacts_round_trip_through_the_store() {
        let dir = std::env::temp_dir().join(format!("mtsa-prof-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let bufs = BufferConfig::default();
        let tables =
            build_tables(&[("NCF".into(), ArrayGeometry::new(128, 128))], &bufs, 1).unwrap();
        write_artifacts(&tables[0], &bufs, &dir).unwrap();
        let store = ProfileStore::load(&dir).unwrap();
        assert_eq!(store.tables().len(), 1);
        assert_eq!(store.tables()[0], tables[0]);
        // Tampering with the stored hash is caught at load, naming the model.
        let path = dir.join(format!("{}.table.json", tables[0].stem()));
        let tampered = std::fs::read_to_string(&path)
            .unwrap()
            .replace(&tables[0].hash, "0000000000000000");
        std::fs::write(&path, tampered).unwrap();
        let err = ProfileStore::load(&dir).unwrap_err();
        assert!(err.contains("stale profile table"), "{err}");
        assert!(err.contains("NCF"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
