//! Profile tables: the persisted artifacts of `mtsa profile` and their
//! loader.
//!
//! Per (model, geometry) pair the profiler emits two files under the
//! `--out` directory:
//!
//! - `<model>_<rows>x<cols>.table.json` — the compact summary
//!   ([`ProfileTable`]): per-layer candidate shapes `(rows, cols, a, b)`,
//!   the batch-1 optimum, and the isolated-run totals `(iso_a, iso_b)`
//!   with `isolated(batch) = iso_a + batch·iso_b` (exact — see
//!   [`isolated_cycles`]);
//! - `<model>_<rows>x<cols>.report.csv` — the comprehensive per-layer
//!   report: every candidate × bank-grant level with cycles, DRAM words,
//!   refetch words beyond the compulsory traffic, a stall-cycle proxy
//!   (refetch words at a 1 word/cycle interface), and dynamic energy.
//!
//! Every table carries a content hash over (schema, model name, geometry,
//! per-layer GEMM dims).  [`ProfileStore::load`] recomputes the hash from
//! the *live* zoo model and rejects stale tables with an error naming the
//! model, so a zoo edit can never silently pair with old tables.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::Arc;

use crate::energy::components::{EnergyModel, Precision};
use crate::sim::buffers::BufferConfig;
use crate::sim::dataflow::{
    baseline_layer_timing, layer_timing_tile_with_share, layer_timing_vector, ArrayGeometry,
    VectorUnit,
};
use crate::sim::partitioned::Tile;
use crate::util::ceil_div;
use crate::util::json::Json;
use crate::workloads::dnng::Dnn;
use crate::workloads::models;
use crate::workloads::shapes::GemmDims;

use super::search::{enumerate_candidates, TileCandidate};

/// Artifact schema version (bumped on any layout change; loaders reject
/// other versions).
pub const PROFILE_SCHEMA: u64 = 1;

/// Bank-grant levels (percent of the proportional SRAM share) the
/// comprehensive report sweeps — the MoCA-style sensitivity axis.
pub const GRANT_LEVELS: &[u64] = &[100, 75, 50, 25];

/// The profile of one layer: its lowered GEMM and the ranked candidate
/// tile shapes (see [`super::search`]).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerProfile {
    pub name: String,
    pub sr: u64,
    pub k: u64,
    pub m: u64,
    /// Candidate shapes, sorted by `(rows, cols)`.
    pub candidates: Vec<TileCandidate>,
    /// Batch-1 optimum among the candidates (origin placement).
    pub best_rows: u64,
    pub best_cols: u64,
    pub best_cycles: u64,
    /// Full-array single-tenant cycles at batch 1, for reference.
    pub baseline_cycles: u64,
}

impl LayerProfile {
    /// The profiled GEMM, reassembled.
    pub fn gemm(&self) -> GemmDims {
        GemmDims { sr: self.sr, k: self.k, m: self.m }
    }

    /// Cycles this layer would take on `lanes` lanes of the vector engine
    /// `vu` — the lane closed form priced from the profiled GEMM, so
    /// offline tables can compare array candidates against a heterogeneous
    /// machine's lanes without re-deriving shapes.  Purely additive: no
    /// table artifact (JSON or CSV) changes.
    pub fn vector_cycles(&self, vu: &VectorUnit, lanes: u64) -> u64 {
        layer_timing_vector(vu, lanes, self.gemm()).cycles
    }
}

/// The compact summary table for one (model, geometry) pair.
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileTable {
    pub model: String,
    pub geom: ArrayGeometry,
    /// Content hash over (schema, model, geometry, layer GEMMs).
    pub hash: String,
    pub layers: Vec<LayerProfile>,
    /// Isolated-run intercept: `Σ_l FM·K + FK·M + FK·FM·(H−1)` on the full
    /// array.
    pub iso_a: u64,
    /// Isolated-run slope per batched request: `Σ_l FK·FM·Sr₁`.
    pub iso_b: u64,
}

/// FNV-1a 64-bit over a canonical description of (model, geometry, layer
/// GEMMs) — stable across platforms, rendered as 16 hex chars.
pub fn content_hash(model: &str, geom: ArrayGeometry, gemms: &[GemmDims]) -> String {
    let mut text = format!("mtsa-profile-v{PROFILE_SCHEMA}|{model}|{}x{}", geom.rows, geom.cols);
    for g in gemms {
        let _ = write!(text, "|{},{},{}", g.sr, g.k, g.m);
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in text.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!("{h:016x}")
}

/// Single-tenant whole-model cycles at batch multiplier `batch` — the one
/// pricing path for isolated-run horizon estimates (the fleet router's
/// `iso_cache` misses and the table totals both come through here, so
/// they can never drift apart).
pub fn isolated_cycles(geom: ArrayGeometry, bufs: &BufferConfig, dnn: &Dnn, batch: u64) -> u64 {
    let mut cycles = 0u64;
    for l in &dnn.layers {
        let mut shape = l.shape;
        shape.n *= batch;
        cycles = cycles.saturating_add(baseline_layer_timing(geom, shape.gemm(), bufs).cycles);
    }
    cycles
}

impl ProfileTable {
    /// Profile one model on one geometry: enumerate candidates per layer,
    /// pick the batch-1 optimum, and fold the isolated-run totals.
    pub fn build(model: &str, dnn: &Dnn, geom: ArrayGeometry, bufs: &BufferConfig) -> ProfileTable {
        let mut layers = Vec::with_capacity(dnn.layers.len());
        let mut gemms = Vec::with_capacity(dnn.layers.len());
        let (mut iso_a, mut iso_b) = (0u64, 0u64);
        for l in &dnn.layers {
            let g = l.shape.gemm();
            gemms.push(g);
            let candidates = enumerate_candidates(geom, g.k, g.m, g.sr);
            let best = candidates
                .iter()
                .min_by_key(|c| (c.cycles(g.sr, 0, 0), c.rows * c.cols, c.rows, c.cols))
                .copied()
                .expect("enumerate_candidates is never empty");
            // Full-array fold counts give the isolated-run linearization
            // cycles(batch) = A + B·batch (FK/FM are batch-independent:
            // batching scales N, hence Sr, not K or M).
            let fk = ceil_div(g.k, geom.rows);
            let fm = ceil_div(g.m, geom.cols);
            iso_a = iso_a
                .saturating_add(fm * g.k + fk * g.m + fk * fm * (geom.rows - 1));
            iso_b = iso_b.saturating_add(fk * fm * g.sr);
            layers.push(LayerProfile {
                name: l.name.clone(),
                sr: g.sr,
                k: g.k,
                m: g.m,
                candidates,
                best_rows: best.rows,
                best_cols: best.cols,
                best_cycles: best.cycles(g.sr, 0, 0),
                baseline_cycles: baseline_layer_timing(geom, g, bufs).cycles,
            });
        }
        ProfileTable {
            model: model.to_string(),
            geom,
            hash: content_hash(model, geom, &gemms),
            layers,
            iso_a,
            iso_b,
        }
    }

    /// `isolated_cycles` from the table totals alone (no per-layer work).
    pub fn isolated(&self, batch: u64) -> u64 {
        self.iso_a.saturating_add(self.iso_b.saturating_mul(batch))
    }

    /// Basename stem of this table's artifacts
    /// (`<model>_<rows>x<cols>`, model lowercased).
    pub fn stem(&self) -> String {
        format!("{}_{}x{}", self.model.to_lowercase(), self.geom.rows, self.geom.cols)
    }

    /// Serialize the summary table (deterministic bytes: sorted object
    /// keys, fixed number formatting).
    pub fn to_json(&self) -> Json {
        let num = |v: u64| Json::Num(v as f64);
        let layers = self
            .layers
            .iter()
            .map(|l| {
                let cands = l
                    .candidates
                    .iter()
                    .map(|c| Json::Arr(vec![num(c.rows), num(c.cols), num(c.a), num(c.b)]))
                    .collect();
                Json::Obj(BTreeMap::from([
                    ("name".into(), Json::Str(l.name.clone())),
                    ("sr".into(), num(l.sr)),
                    ("k".into(), num(l.k)),
                    ("m".into(), num(l.m)),
                    ("best_rows".into(), num(l.best_rows)),
                    ("best_cols".into(), num(l.best_cols)),
                    ("best_cycles".into(), num(l.best_cycles)),
                    ("baseline_cycles".into(), num(l.baseline_cycles)),
                    ("candidates".into(), Json::Arr(cands)),
                ]))
            })
            .collect();
        Json::Obj(BTreeMap::from([
            ("schema".into(), num(PROFILE_SCHEMA)),
            ("model".into(), Json::Str(self.model.clone())),
            ("rows".into(), num(self.geom.rows)),
            ("cols".into(), num(self.geom.cols)),
            ("hash".into(), Json::Str(self.hash.clone())),
            ("iso_a".into(), num(self.iso_a)),
            ("iso_b".into(), num(self.iso_b)),
            ("layers".into(), Json::Arr(layers)),
        ]))
    }

    /// Parse a summary table; errors name the missing/ill-typed field.
    pub fn from_json(doc: &Json) -> Result<ProfileTable, String> {
        fn field_u64(doc: &Json, key: &str) -> Result<u64, String> {
            doc.get(key)
                .and_then(|v| v.as_u64())
                .ok_or_else(|| format!("profile table missing integer field {key:?}"))
        }
        fn field_str<'a>(doc: &'a Json, key: &str) -> Result<&'a str, String> {
            doc.get(key)
                .and_then(|v| v.as_str())
                .ok_or_else(|| format!("profile table missing string field {key:?}"))
        }
        let schema = field_u64(doc, "schema")?;
        if schema != PROFILE_SCHEMA {
            return Err(format!(
                "profile table schema {schema} unsupported (expected {PROFILE_SCHEMA})"
            ));
        }
        let model = field_str(doc, "model")?.to_string();
        let geom = ArrayGeometry::try_new(field_u64(doc, "rows")?, field_u64(doc, "cols")?)?;
        let hash = field_str(doc, "hash")?.to_string();
        let mut layers = Vec::new();
        for l in doc
            .get("layers")
            .and_then(|v| v.as_arr())
            .ok_or("profile table missing \"layers\" array")?
        {
            let mut candidates = Vec::new();
            for c in l
                .get("candidates")
                .and_then(|v| v.as_arr())
                .ok_or("profile layer missing \"candidates\" array")?
            {
                let quad = c.as_arr().filter(|q| q.len() == 4).ok_or("candidate must be [rows, cols, a, b]")?;
                let at = |i: usize| {
                    quad[i].as_u64().ok_or_else(|| format!("candidate field {i} not an integer"))
                };
                let (rows, cols) = (at(0)?, at(1)?);
                if rows == 0 || cols == 0 {
                    return Err("candidate with zero extent".into());
                }
                candidates.push(TileCandidate { rows, cols, a: at(2)?, b: at(3)? });
            }
            layers.push(LayerProfile {
                name: field_str(l, "name")?.to_string(),
                sr: field_u64(l, "sr")?,
                k: field_u64(l, "k")?,
                m: field_u64(l, "m")?,
                candidates,
                best_rows: field_u64(l, "best_rows")?,
                best_cols: field_u64(l, "best_cols")?,
                best_cycles: field_u64(l, "best_cycles")?,
                baseline_cycles: field_u64(l, "baseline_cycles")?,
            });
        }
        Ok(ProfileTable {
            model,
            geom,
            hash,
            layers,
            iso_a: field_u64(doc, "iso_a")?,
            iso_b: field_u64(doc, "iso_b")?,
        })
    }

    /// The comprehensive per-layer report: every candidate × bank-grant
    /// level, priced by the real timing/energy models.  `refetch_words`
    /// is DRAM traffic beyond the compulsory (weights + one IFMap pass +
    /// one OFMap write); `stall_cycles` prices it at a 1 word/cycle DRAM
    /// interface — an upper-bound proxy, not a simulated stall.
    pub fn report_csv(&self, bufs: &BufferConfig) -> String {
        let energy = EnergyModel::build(self.geom, bufs, Precision::Int8);
        let mut out = String::from(
            "model,geom,layer,sr,k,m,rows,cols,grant_pct,cycles,dram_words,refetch_words,stall_cycles,energy_j\n",
        );
        for l in &self.layers {
            let gemm = GemmDims { sr: l.sr, k: l.k, m: l.m };
            let compulsory = l.k * l.m + l.sr * l.k + l.sr * l.m;
            for c in &l.candidates {
                let tile = Tile::new(0, 0, c.rows, c.cols);
                let share = bufs.share(tile.pes(), self.geom.pes());
                for &pct in GRANT_LEVELS {
                    let granted = BufferConfig {
                        weight_bytes: (share.weight_bytes * pct / 100).max(share.dtype_bytes),
                        ifmap_bytes: (share.ifmap_bytes * pct / 100).max(share.dtype_bytes),
                        ofmap_bytes: (share.ofmap_bytes * pct / 100).max(share.dtype_bytes),
                        dtype_bytes: share.dtype_bytes,
                    };
                    let t = layer_timing_tile_with_share(self.geom, gemm, tile, &granted, None);
                    let dram = t.activity.dram_accesses();
                    let refetch = dram.saturating_sub(compulsory);
                    let _ = writeln!(
                        out,
                        "{},{}x{},{},{},{},{},{},{},{},{},{},{},{},{:.6e}",
                        self.model,
                        self.geom.rows,
                        self.geom.cols,
                        l.name,
                        l.sr,
                        l.k,
                        l.m,
                        c.rows,
                        c.cols,
                        pct,
                        t.cycles,
                        dram,
                        refetch,
                        refetch,
                        energy.dynamic_j(&t.activity),
                    );
                }
            }
        }
        out
    }
}

/// A directory of validated [`ProfileTable`]s with merged lookups —
/// what the schedulers consult at plan time.  Wrapped in an [`Arc`] by
/// its consumers ([`SchedulerConfig`](crate::coordinator::scheduler::SchedulerConfig),
/// [`FleetConfig`](crate::fleet::FleetConfig)); all lookups are
/// read-only.
#[derive(Debug)]
pub struct ProfileStore {
    /// Where the tables came from (a directory, or `"<memory>"`).
    pub origin: String,
    tables: Vec<ProfileTable>,
    /// `(geom.rows, geom.cols, k, m)` → merged candidates.
    by_shape: BTreeMap<(u64, u64, u64, u64), Vec<TileCandidate>>,
    /// `(geom.rows, geom.cols, model lowercased)` → `(iso_a, iso_b)`.
    totals: BTreeMap<(u64, u64, String), (u64, u64)>,
}

impl ProfileStore {
    /// Index a set of already-validated tables (the in-memory path used
    /// by examples and tests; no zoo check — the caller built them).
    pub fn from_tables(origin: &str, tables: Vec<ProfileTable>) -> ProfileStore {
        let mut by_shape: BTreeMap<(u64, u64, u64, u64), Vec<TileCandidate>> = BTreeMap::new();
        let mut totals = BTreeMap::new();
        for t in &tables {
            totals.insert(
                (t.geom.rows, t.geom.cols, t.model.to_lowercase()),
                (t.iso_a, t.iso_b),
            );
            for l in &t.layers {
                let merged = by_shape.entry((t.geom.rows, t.geom.cols, l.k, l.m)).or_default();
                for c in &l.candidates {
                    if !merged.contains(c) {
                        merged.push(*c);
                    }
                }
                merged.sort_by_key(|c| (c.rows, c.cols, c.a, c.b));
            }
        }
        ProfileStore { origin: origin.to_string(), tables, by_shape, totals }
    }

    /// Load every `*.table.json` under `dir`, verifying each table's
    /// content hash against the live zoo: a table whose model was edited
    /// (or renamed away) since profiling is rejected, naming the model.
    pub fn load(dir: &std::path::Path) -> Result<ProfileStore, String> {
        let entries = std::fs::read_dir(dir)
            .map_err(|e| format!("cannot read profile tables dir {}: {e}", dir.display()))?;
        let mut files: Vec<std::path::PathBuf> = entries
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.ends_with(".table.json")))
            .collect();
        files.sort();
        if files.is_empty() {
            return Err(format!("no *.table.json files in {} (run `mtsa profile` first)", dir.display()));
        }
        let mut tables = Vec::with_capacity(files.len());
        for path in files {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
            let doc = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
            let table = ProfileTable::from_json(&doc).map_err(|e| format!("{}: {e}", path.display()))?;
            let entry = models::by_name(&table.model).ok_or_else(|| {
                format!(
                    "{}: profile table names unknown model {:?} (see `mtsa zoo`)",
                    path.display(),
                    table.model
                )
            })?;
            let live = (entry.build)();
            let gemms: Vec<GemmDims> = live.layers.iter().map(|l| l.shape.gemm()).collect();
            let expect = content_hash(&table.model, table.geom, &gemms);
            if expect != table.hash {
                return Err(format!(
                    "{}: stale profile table for model {:?}: content hash {} != current {} \
                     (the model changed since profiling; re-run `mtsa profile`)",
                    path.display(),
                    table.model,
                    table.hash,
                    expect
                ));
            }
            tables.push(table);
        }
        Ok(ProfileStore::from_tables(&dir.display().to_string(), tables))
    }

    /// Convenience: [`ProfileStore::load`] wrapped for config knobs.
    pub fn load_arc(dir: &str) -> Result<Arc<ProfileStore>, String> {
        ProfileStore::load(std::path::Path::new(dir)).map(Arc::new)
    }

    /// Candidate shapes for a `[*, k] × [k, m]` GEMM on `geom` (empty
    /// when the geometry or shape was never profiled — callers fall back
    /// to their online ladder).
    pub fn candidates(&self, geom: ArrayGeometry, k: u64, m: u64) -> &[TileCandidate] {
        self.by_shape.get(&(geom.rows, geom.cols, k, m)).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Isolated-run totals `(iso_a, iso_b)` for a model on `geom`
    /// (case-insensitive model lookup, like the zoo's).
    pub fn totals(&self, geom: ArrayGeometry, model: &str) -> Option<(u64, u64)> {
        self.totals.get(&(geom.rows, geom.cols, model.to_lowercase())).copied()
    }

    /// Whether any table covers `geom`.
    pub fn has_geometry(&self, geom: ArrayGeometry) -> bool {
        self.tables.iter().any(|t| t.geom == geom)
    }

    pub fn tables(&self) -> &[ProfileTable] {
        &self.tables
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn ncf_table(geom: ArrayGeometry) -> ProfileTable {
        let dnn = (models::by_name("NCF").unwrap().build)();
        ProfileTable::build("NCF", &dnn, geom, &BufferConfig::default())
    }

    #[test]
    fn layer_profile_prices_the_vector_closed_form() {
        // NCF's embeddings are the canonical lane customers: the profile's
        // vector pricing must be exactly the dataflow closed form on the
        // reassembled GEMM.
        let t = ncf_table(ArrayGeometry::new(128, 128));
        let vu = VectorUnit::new(128);
        for l in &t.layers {
            assert_eq!(
                l.vector_cycles(&vu, 128),
                layer_timing_vector(&vu, 128, l.gemm()).cycles,
                "layer {}",
                l.name,
            );
            assert!(l.vector_cycles(&vu, 128) > vu.startup);
        }
    }

    #[test]
    fn totals_equal_the_isolated_loop_exactly() {
        // The table's (iso_a, iso_b) linearization must reproduce the
        // per-layer baseline sum for every model and any batch size —
        // the property that lets the fleet router swap loops for tables
        // without changing a byte.
        let bufs = BufferConfig::default();
        let mut rng = Rng::new(99);
        for geom in [ArrayGeometry::new(128, 128), ArrayGeometry::new(96, 64)] {
            for e in models::ZOO {
                let dnn = (e.build)();
                let t = ProfileTable::build(e.name, &dnn, geom, &bufs);
                for _ in 0..4 {
                    let batch = rng.gen_range_inclusive(1, 64);
                    assert_eq!(
                        t.isolated(batch),
                        isolated_cycles(geom, &bufs, &dnn, batch),
                        "{} batch {batch} on {}x{}",
                        e.name,
                        geom.rows,
                        geom.cols
                    );
                }
            }
        }
    }

    #[test]
    fn json_round_trips_byte_identically() {
        let t = ncf_table(ArrayGeometry::new(128, 128));
        let rendered = t.to_json().render();
        let back = ProfileTable::from_json(&Json::parse(&rendered).unwrap()).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.to_json().render(), rendered);
    }

    #[test]
    fn best_never_loses_to_full_array_baseline() {
        // The profiled optimum searches a superset of the full-array
        // shape whenever the array fits one, so best ≤ baseline there;
        // it is exactly the baseline when K and M overfill the array.
        for e in models::ZOO {
            let dnn = (e.build)();
            let t = ProfileTable::build(e.name, &dnn, ArrayGeometry::new(128, 128), &BufferConfig::default());
            for l in &t.layers {
                assert!(
                    l.best_cycles <= l.baseline_cycles,
                    "{}/{}: best {} > baseline {}",
                    e.name,
                    l.name,
                    l.best_cycles,
                    l.baseline_cycles
                );
            }
        }
    }

    #[test]
    fn content_hash_tracks_model_and_geometry() {
        let g = |sr, k, m| GemmDims { sr, k, m };
        let base = content_hash("NCF", ArrayGeometry::new(128, 128), &[g(1, 2, 3)]);
        assert_eq!(base.len(), 16);
        assert_ne!(base, content_hash("GNMT", ArrayGeometry::new(128, 128), &[g(1, 2, 3)]));
        assert_ne!(base, content_hash("NCF", ArrayGeometry::new(64, 128), &[g(1, 2, 3)]));
        assert_ne!(base, content_hash("NCF", ArrayGeometry::new(128, 128), &[g(1, 2, 4)]));
        assert_eq!(base, content_hash("NCF", ArrayGeometry::new(128, 128), &[g(1, 2, 3)]));
    }

    #[test]
    fn store_lookups_merge_and_miss_cleanly() {
        let geom = ArrayGeometry::new(128, 128);
        let store = ProfileStore::from_tables("<memory>", vec![ncf_table(geom)]);
        assert!(store.has_geometry(geom));
        assert!(!store.has_geometry(ArrayGeometry::new(64, 64)));
        assert!(store.totals(geom, "ncf").is_some());
        assert!(store.totals(geom, "NCF").is_some());
        assert!(store.totals(geom, "GNMT").is_none());
        let l = &store.tables()[0].layers[0];
        assert!(!store.candidates(geom, l.k, l.m).is_empty());
        assert!(store.candidates(geom, 7, 11).is_empty());
        assert!(store.candidates(ArrayGeometry::new(64, 64), l.k, l.m).is_empty());
    }

    #[test]
    fn report_csv_shape_and_determinism() {
        let t = ncf_table(ArrayGeometry::new(128, 128));
        let bufs = BufferConfig::default();
        let csv = t.report_csv(&bufs);
        assert_eq!(csv, t.report_csv(&bufs), "deterministic bytes");
        let lines: Vec<&str> = csv.lines().collect();
        assert!(lines[0].starts_with("model,geom,layer"));
        let expect: usize =
            t.layers.iter().map(|l| l.candidates.len() * GRANT_LEVELS.len()).sum();
        assert_eq!(lines.len(), 1 + expect);
        // Starving the grant only ever adds refetch traffic.
        prop::check("grant monotonicity within a candidate row group", 1, |_| {
            for group in lines[1..].chunks(GRANT_LEVELS.len()) {
                let refetch: Vec<u64> = group
                    .iter()
                    .map(|l| l.split(',').nth(11).unwrap().parse().unwrap())
                    .collect();
                for w in refetch.windows(2) {
                    prop::ensure(w[0] <= w[1], "refetch grows as the grant shrinks")?;
                }
            }
            Ok(())
        });
    }
}
