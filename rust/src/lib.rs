//! `mtsa` — Multi-Tenant Systolic-Array accelerator with dynamic resource
//! partitioning.
//!
//! A from-scratch reproduction of *Dynamic Resource Partitioning for
//! Multi-Tenant Systolic Array Based DNN Accelerator* (Reshadi & Gregg,
//! PDP 2023) as a three-layer rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the paper's contribution: the dynamic
//!   partitioning coordinator ([`coordinator`]) as policies plugged into
//!   the shared discrete-event engine ([`sim_core`]), plus every substrate
//!   the evaluation depends on: a Scale-Sim-equivalent cycle model ([`sim`]),
//!   an Accelergy-equivalent energy estimator ([`energy`]), the 12-network
//!   workload zoo ([`workloads`]), the arrival-driven scenario engine and
//!   parallel sweep runner ([`coordinator::scenario`], [`sweep`]), and the
//!   PJRT runtime ([`runtime`]) that executes the AOT-compiled
//!   partitioned-weight-stationary computation (behind the `pjrt` feature;
//!   everything else builds offline with no accelerator hardware).
//! - **L2 (jax, build time)** — `python/compile/model.py`.
//! - **L1 (pallas, build time)** — `python/compile/kernels/`.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every figure of the paper to a bench target.

pub mod util;

pub mod runtime;

pub mod workloads;

pub mod sim;

pub mod energy;

pub mod mem;

pub mod sim_core;

pub mod coordinator;

pub mod fleet;

pub mod profiler;

pub mod report;

pub mod sweep;

pub mod config;

pub mod cli;

pub mod benchkit;

#[cfg(feature = "pjrt")]
pub mod verify;
