//! Tiny typed argument parser: `command [positionals] [--flag[=| ]value]`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ParsedArgs {
    /// Subcommand (first non-flag token).
    pub command: String,
    /// Positional arguments after the subcommand.
    pub positionals: Vec<String>,
    /// `--key value` / `--key=value` options.
    options: BTreeMap<String, String>,
    /// Bare `--switch` flags.
    switches: Vec<String>,
}

impl ParsedArgs {
    /// Parse argv (without the program name).
    pub fn parse(argv: &[String]) -> Result<ParsedArgs> {
        let mut out = ParsedArgs::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(flag) = tok.strip_prefix("--") {
                if flag.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = flag.split_once('=') {
                    out.insert_option(k, v)?;
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.insert_option(flag, v)?;
                } else {
                    out.switches.push(flag.to_string());
                }
            } else if out.command.is_empty() {
                out.command = tok.clone();
            } else {
                out.positionals.push(tok.clone());
            }
        }
        Ok(out)
    }

    fn insert_option(&mut self, k: &str, v: &str) -> Result<()> {
        if self.options.insert(k.to_string(), v.to_string()).is_some() {
            bail!("duplicate option --{k}");
        }
        Ok(())
    }

    /// String option.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Integer option with default.
    pub fn opt_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// Bare switch presence.
    pub fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    /// Error out on unknown options (call after reading all known ones).
    pub fn ensure_known(&self, opts: &[&str], switches: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !opts.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {opts:?})");
            }
        }
        for s in &self.switches {
            if !switches.contains(&s.as_str()) {
                bail!("unknown switch --{s} (known: {switches:?})");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_command_positionals_options_switches() {
        let p = ParsedArgs::parse(&argv("run heavy --policy widest --seed=7 --verbose")).unwrap();
        assert_eq!(p.command, "run");
        assert_eq!(p.positionals, vec!["heavy"]);
        assert_eq!(p.opt("policy"), Some("widest"));
        assert_eq!(p.opt_u64("seed", 0).unwrap(), 7);
        assert!(p.has("verbose"));
        assert!(!p.has("quiet"));
    }

    #[test]
    fn option_value_styles_equivalent() {
        let a = ParsedArgs::parse(&argv("x --k v")).unwrap();
        let b = ParsedArgs::parse(&argv("x --k=v")).unwrap();
        assert_eq!(a.opt("k"), b.opt("k"));
    }

    #[test]
    fn rejects_duplicates_and_bad_ints() {
        assert!(ParsedArgs::parse(&argv("x --a 1 --a 2")).is_err());
        let p = ParsedArgs::parse(&argv("x --n abc")).unwrap();
        assert!(p.opt_u64("n", 0).is_err());
    }

    #[test]
    fn ensure_known_catches_typos() {
        let p = ParsedArgs::parse(&argv("run --plicy widest")).unwrap();
        assert!(p.ensure_known(&["policy"], &[]).is_err());
        let p = ParsedArgs::parse(&argv("run --policy widest")).unwrap();
        assert!(p.ensure_known(&["policy"], &[]).is_ok());
    }

    #[test]
    fn trailing_switch_before_positional() {
        // `--flag` followed by a non-flag is consumed as its value.
        let p = ParsedArgs::parse(&argv("run --seq heavy")).unwrap();
        assert_eq!(p.opt("seq"), Some("heavy"));
        // To pass a bare switch last, use `--seq` at the end.
        let p = ParsedArgs::parse(&argv("run heavy --seq")).unwrap();
        assert!(p.has("seq"));
        assert_eq!(p.positionals, vec!["heavy"]);
    }
}
