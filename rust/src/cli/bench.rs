//! `mtsa bench` — the recorded perf trajectory.
//!
//! Each growth PR extends a trajectory of `BENCH_<n>.json` files at the
//! repository root: `mtsa bench --record` measures the engine hot path on
//! this host and writes the current PR's file; `--check` compares the
//! fresh measurement against a committed baseline and fails on a >15%
//! events/sec regression.  A baseline is only *gating* when its
//! `provenance` field is `"measured"` — a file whose numbers were
//! projected on a host without a toolchain records the trajectory shape
//! but must not fail builds on other hardware.  `docs/benchmarks.md` is
//! the narrative version of this contract.
//!
//! Scenarios (kept stable across PRs so the trajectory stays comparable):
//! - `engine_run_heavy` — `DynamicScheduler::run` over the heavy pool;
//!   `events_per_sec` counts engine events (arrivals + completed layers +
//!   preemptions) retired per wall-clock second.  This is the gated
//!   number.
//! - `timing_model` — one `slice_layer_timing` call (the sweep grid's
//!   inner loop; a cache hit when the timing memo is enabled).
//! - `sweep_point_light` — one full sweep point (scenario generation +
//!   dynamic/sequential runs + SLA stats); `points_per_sec` is the
//!   sweep-grid throughput unit.
//! - `fleet_events_per_sec` — a small serving-tier run ([`crate::fleet`]):
//!   streaming generation + routing + batched multi-instance simulation;
//!   `events_per_sec` counts engine events retired across the cluster per
//!   wall-clock second.  Informational (not gated).
//! - `profiler_tables_per_sec` — the offline fission profiler
//!   ([`crate::profiler::build_tables`]): the exhaustive closed-form tile
//!   search over two zoo models on the base geometry; `tables_per_sec` is
//!   the `mtsa profile` throughput unit.  Informational (not gated).
//! - `planner_plans_per_sec` — one `DynamicScheduler::plan` decision over
//!   the heavy pool's ready queue (a memo replay when the plan cache is
//!   enabled — the planner campaign's steady-state cost).  Informational
//!   (not gated).
//! - `coalesce_burst` — `DynamicScheduler::run` over a pool of same-cycle
//!   arrival bursts, the shape the event-coalescing fast path batches
//!   into single plan passes.  Informational (not gated).
//! - `vector_layers_per_sec` — heterogeneous co-tenancy: a dynamic run
//!   with a 128-lane vector engine over a memory-bound pool; counts the
//!   layer segments the planner offloads to lanes per wall-clock second.
//!   Informational (not gated).

use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::args::ParsedArgs;
use crate::benchkit::{Bench, BenchOpts};
use crate::coordinator::partition::{alloc_index_enabled, PartitionManager};
use crate::coordinator::queue::TaskQueue;
use crate::coordinator::scheduler::{
    plan_arena_enabled, plan_cache_enabled, AllocPolicy, DynamicScheduler, FeedModel,
    SchedulerConfig,
};
use crate::fleet::{run_fleet, FleetConfig, FleetPolicy, Placement};
use crate::sim::buffers::BufferConfig;
use crate::sim::dataflow::{timing_cache_enabled, ArrayGeometry};
use crate::sim::partitioned::{slice_layer_timing, FeedPolicy, PartitionSlice};
use crate::sim_core::queue::bucket_queue_enabled;
use crate::sim_core::{event_coalesce_enabled, obs_ring_enabled, Scheduler, SystemState};
use crate::sweep::{run_sweep, SweepGrid};
use crate::util::json::Json;
use crate::workloads::dnng::{Dnn, Layer, WorkloadPool};
use crate::workloads::generator::{ArrivalProcess, Diurnal, ModelMix};
use crate::workloads::models::heavy_pool;
use crate::workloads::shapes::{GemmDims, LayerKind, LayerShape};

/// Layout version of the `BENCH_*.json` files.
pub const BENCH_SCHEMA: u64 = 1;

/// Maximum tolerated fractional events/sec regression vs a *measured*
/// baseline before `--check` fails the build.
pub const REGRESSION_TOLERANCE: f64 = 0.15;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Measured {
    events_per_run: u64,
    events_per_sec: f64,
    engine_wall_s_per_run: f64,
    timing_ns_per_call: f64,
    sweep_points: usize,
    sweep_requests: usize,
    sweep_wall_s: f64,
    sweep_points_per_sec: f64,
    fleet_requests: usize,
    fleet_events: u64,
    fleet_wall_s: f64,
    fleet_events_per_sec: f64,
    profile_tables: usize,
    profile_wall_s: f64,
    profile_tables_per_sec: f64,
    plan_ns_per_call: f64,
    plans_per_sec: f64,
    burst_events_per_run: u64,
    burst_wall_s_per_run: f64,
    burst_events_per_sec: f64,
    vector_layers_per_run: u64,
    vector_wall_s_per_run: f64,
    vector_layers_per_sec: f64,
}

fn measure(quick: bool, threads: usize) -> Result<Measured> {
    let opts = if quick {
        BenchOpts {
            warmup: Duration::from_millis(10),
            measure: Duration::from_millis(100),
            min_iters: 2,
            max_iters: 1_000,
        }
    } else {
        BenchOpts {
            warmup: Duration::from_millis(100),
            measure: Duration::from_secs(1),
            min_iters: 3,
            max_iters: 100_000,
        }
    };
    let mut b = Bench::new("mtsa bench").with_opts(opts);

    // Inner-loop cost model (a memo hit when the timing cache is on).
    let geom = ArrayGeometry::new(128, 128);
    let bufs = BufferConfig::default();
    let gemm = GemmDims { sr: 3025, k: 1152, m: 384 };
    let timing = b.measure("slice_layer_timing (conv layer)", || {
        std::hint::black_box(slice_layer_timing(
            geom,
            std::hint::black_box(gemm),
            PartitionSlice::new(32, 32),
            FeedPolicy::Independent,
            &bufs,
        ));
    });

    // End-to-end engine run; the event count comes from the metrics of
    // one (deterministic) run, the wall time from the timed repeats.
    let pool = heavy_pool();
    let sched = DynamicScheduler::new(SchedulerConfig::default());
    let m = sched.run(&pool);
    let events_per_run = pool.dnns.len() as u64 + m.dispatches.len() as u64 + m.preemptions;
    let engine = b.measure("DynamicScheduler::run (heavy pool)", || {
        std::hint::black_box(sched.run(&pool));
    });
    let engine_wall_s = engine.mean / 1e9;

    // One sweep point, end to end.
    let grid = SweepGrid {
        mixes: vec!["light".to_string()],
        rates: vec![20_000.0],
        policies: vec![AllocPolicy::WidestToHeaviest],
        feeds: vec![FeedModel::Independent],
        requests: if quick { 4 } else { 8 },
        ..SweepGrid::default()
    };
    let t0 = Instant::now();
    let rows = run_sweep(&grid, &SchedulerConfig::default(), threads)?;
    let sweep_wall_s = t0.elapsed().as_secs_f64();

    // One small serving-tier run, end to end (generation + routing +
    // batched multi-instance simulation).
    let fleet_cfg = FleetConfig {
        instances: FleetConfig::uniform(4, &SchedulerConfig::default(), FleetPolicy::Dynamic),
        placement: Placement::LeastLoaded,
        random_k: 2,
        classes: FleetConfig::default_classes(30_000.0),
        slots: 8,
        queue_cap: 64,
        mix: ModelMix::new(&[("NCF", 2.0), ("MelodyLSTM", 1.0)]),
        arrival: ArrivalProcess::Poisson { mean_interarrival: 30_000.0 },
        diurnal: Some(Diurnal { period: 10_000_000.0, amplitude: 0.5, phase: 0.0 }),
        requests: if quick { 300 } else { 2_000 },
        seed: 42,
        chunk: 1024,
        tables: None,
    };
    let t0 = Instant::now();
    let fleet = run_fleet(&fleet_cfg, threads)?;
    let fleet_wall_s = t0.elapsed().as_secs_f64();

    // The offline fission profiler: exhaustive closed-form tile search
    // over two zoo models on the base geometry (`mtsa profile`).
    let profile_jobs = vec![
        ("NCF".to_string(), geom),
        ("MelodyLSTM".to_string(), geom),
    ];
    let t0 = Instant::now();
    let profile_tables = crate::profiler::build_tables(&profile_jobs, &bufs, threads)
        .map_err(anyhow::Error::msg)?
        .len();
    let profile_wall_s = t0.elapsed().as_secs_f64();

    // The planner hot path in isolation: one plan() decision over the
    // heavy pool's initial ready queue.  With the plan cache on this is
    // the steady-state memo replay; with MTSA_NO_PLAN_CACHE it is a full
    // candidate search + pricing pass.
    let plan_queue = TaskQueue::new(&pool);
    let plan_pm = PartitionManager::new(SchedulerConfig::default().geom);
    let plan_progress = std::collections::BTreeMap::new();
    let plan_state = SystemState {
        now: 0,
        pool: &pool,
        queue: &plan_queue,
        partitions: &plan_pm,
        lanes: None,
        mem: None,
        progress: &plan_progress,
    };
    let mut planner = DynamicScheduler::new(SchedulerConfig::default());
    let plan = b.measure("DynamicScheduler::plan (heavy ready queue)", || {
        std::hint::black_box(planner.plan(&plan_state));
    });

    // Same-cycle arrival bursts: the shape the event-coalescing fast
    // path turns into one batch drain + one plan pass per burst cycle.
    let burst_pool = {
        let mut dnns = Vec::new();
        for burst in 0..4u64 {
            for i in 0..8 {
                let layers = (0..3)
                    .map(|l| {
                        Layer::new(&format!("l{l}"), LayerKind::Fc, LayerShape::fc(32, 64, 64))
                    })
                    .collect();
                dnns.push(
                    Dnn::chain(&format!("b{burst}-{i}"), layers).arriving_at(burst * 50_000),
                );
            }
        }
        WorkloadPool::new("bursts", dnns)
    };
    let burst_sched = DynamicScheduler::new(SchedulerConfig::default());
    let bm = burst_sched.run(&burst_pool);
    let burst_events_per_run =
        burst_pool.dnns.len() as u64 + bm.dispatches.len() as u64 + bm.preemptions;
    let burst = b.measure("coalesce_burst (8-wide same-cycle arrivals)", || {
        std::hint::black_box(burst_sched.run(&burst_pool));
    });
    let burst_wall_s = burst.mean / 1e9;

    // Heterogeneous co-tenancy: a dynamic run with a 128-lane vector
    // engine over a memory-bound pool; the planner offloads the
    // embedding/recurrent layers to lanes while FC stages keep the array.
    let vec_pool = crate::workloads::models::by_spec("NCF,MelodyLSTM")
        .map_err(anyhow::Error::msg)?;
    let vec_sched = DynamicScheduler::new(SchedulerConfig {
        vector: Some(crate::sim::dataflow::VectorUnit::new(128)),
        ..SchedulerConfig::default()
    });
    let vm = vec_sched.run(&vec_pool);
    let vector_layers_per_run = vm.vector_dispatches;
    let vector = b.measure("vector co-tenancy (NCF+MelodyLSTM, 128 lanes)", || {
        std::hint::black_box(vec_sched.run(&vec_pool));
    });
    let vector_wall_s = vector.mean / 1e9;
    b.finish();

    Ok(Measured {
        events_per_run,
        events_per_sec: events_per_run as f64 / engine_wall_s,
        engine_wall_s_per_run: engine_wall_s,
        timing_ns_per_call: timing.mean,
        sweep_points: rows.len(),
        sweep_requests: grid.requests,
        sweep_wall_s,
        sweep_points_per_sec: rows.len() as f64 / sweep_wall_s,
        fleet_requests: fleet_cfg.requests,
        fleet_events: fleet.events,
        fleet_wall_s,
        fleet_events_per_sec: fleet.events as f64 / fleet_wall_s,
        profile_tables,
        profile_wall_s,
        profile_tables_per_sec: profile_tables as f64 / profile_wall_s.max(1e-9),
        plan_ns_per_call: plan.mean,
        plans_per_sec: 1e9 / plan.mean.max(1e-9),
        burst_events_per_run,
        burst_wall_s_per_run: burst_wall_s,
        burst_events_per_sec: burst_events_per_run as f64 / burst_wall_s.max(1e-12),
        vector_layers_per_run,
        vector_wall_s_per_run: vector_wall_s,
        vector_layers_per_sec: vector_layers_per_run as f64 / vector_wall_s.max(1e-12),
    })
}

fn record_json(m: &Measured) -> Json {
    obj(vec![
        ("schema", Json::Num(BENCH_SCHEMA as f64)),
        ("pr", Json::Num(10.0)),
        ("provenance", Json::Str("measured".into())),
        ("tolerance_pct", Json::Num(100.0 * REGRESSION_TOLERANCE)),
        (
            "features",
            obj(vec![
                ("timing_cache", Json::Bool(timing_cache_enabled())),
                ("bucket_queue", Json::Bool(bucket_queue_enabled())),
                ("alloc_index", Json::Bool(alloc_index_enabled())),
                ("obs_ring", Json::Bool(obs_ring_enabled())),
                ("plan_cache", Json::Bool(plan_cache_enabled())),
                ("event_coalesce", Json::Bool(event_coalesce_enabled())),
                ("plan_arena", Json::Bool(plan_arena_enabled())),
            ]),
        ),
        (
            "scenarios",
            obj(vec![
                (
                    "engine_run_heavy",
                    obj(vec![
                        ("events_per_run", Json::Num(m.events_per_run as f64)),
                        ("events_per_sec", Json::Num(m.events_per_sec)),
                        ("wall_s_per_run", Json::Num(m.engine_wall_s_per_run)),
                    ]),
                ),
                (
                    "timing_model",
                    obj(vec![("ns_per_call", Json::Num(m.timing_ns_per_call))]),
                ),
                (
                    "sweep_point_light",
                    obj(vec![
                        ("points", Json::Num(m.sweep_points as f64)),
                        ("requests", Json::Num(m.sweep_requests as f64)),
                        ("wall_s", Json::Num(m.sweep_wall_s)),
                        ("points_per_sec", Json::Num(m.sweep_points_per_sec)),
                    ]),
                ),
                (
                    "fleet_events_per_sec",
                    obj(vec![
                        ("requests", Json::Num(m.fleet_requests as f64)),
                        ("events", Json::Num(m.fleet_events as f64)),
                        ("wall_s", Json::Num(m.fleet_wall_s)),
                        ("events_per_sec", Json::Num(m.fleet_events_per_sec)),
                    ]),
                ),
                (
                    "profiler_tables_per_sec",
                    obj(vec![
                        ("tables", Json::Num(m.profile_tables as f64)),
                        ("wall_s", Json::Num(m.profile_wall_s)),
                        ("tables_per_sec", Json::Num(m.profile_tables_per_sec)),
                    ]),
                ),
                (
                    "planner_plans_per_sec",
                    obj(vec![
                        ("ns_per_plan", Json::Num(m.plan_ns_per_call)),
                        ("plans_per_sec", Json::Num(m.plans_per_sec)),
                    ]),
                ),
                (
                    "coalesce_burst",
                    obj(vec![
                        ("events_per_run", Json::Num(m.burst_events_per_run as f64)),
                        ("wall_s_per_run", Json::Num(m.burst_wall_s_per_run)),
                        ("events_per_sec", Json::Num(m.burst_events_per_sec)),
                    ]),
                ),
                (
                    "vector_layers_per_sec",
                    obj(vec![
                        ("layers_per_run", Json::Num(m.vector_layers_per_run as f64)),
                        ("wall_s_per_run", Json::Num(m.vector_wall_s_per_run)),
                        ("layers_per_sec", Json::Num(m.vector_layers_per_sec)),
                    ]),
                ),
            ]),
        ),
    ])
}

/// `--record` reruns must not lose history: a prior output file's
/// `pre_pr` block (the before-this-PR snapshot) is carried forward
/// verbatim into the fresh record.
fn carry_forward_pre_pr(out: &str, fresh: Json) -> Json {
    let prior = std::fs::read_to_string(out)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .and_then(|j| j.get("pre_pr").cloned());
    match (prior, fresh) {
        (Some(p), Json::Obj(mut map)) => {
            map.insert("pre_pr".to_string(), p);
            Json::Obj(map)
        }
        (_, fresh) => fresh,
    }
}

/// The one-line warning `--check` prints when the committed baseline
/// carries provenance `"projected"` — the trajectory file was written on
/// a host without a toolchain, so its numbers never gate.  Returns `None`
/// for any other provenance (the generic not-measured note covers those).
fn projected_baseline_warning(baseline_path: &str, provenance: &str) -> Option<String> {
    (provenance == "projected").then(|| {
        format!(
            "warning: baseline {baseline_path} has provenance \"projected\" (numbers derived \
             without measurement) — the regression gate is DISARMED; run `mtsa bench --record` \
             on a measuring host to arm it"
        )
    })
}

/// Gate a fresh measurement against a committed baseline file.  Returns
/// `Ok(true)` when the baseline actually gated (provenance `"measured"`),
/// `Ok(false)` when it was informational only.
fn check_against(baseline_path: &str, m: &Measured) -> Result<bool> {
    let text = std::fs::read_to_string(baseline_path)
        .with_context(|| format!("reading baseline {baseline_path}"))?;
    let base = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing baseline {baseline_path}: {e}"))?;
    let provenance = base.get("provenance").and_then(Json::as_str).unwrap_or("unknown");
    let base_eps = base
        .get("scenarios")
        .and_then(|s| s.get("engine_run_heavy"))
        .and_then(|s| s.get("events_per_sec"))
        .and_then(Json::as_f64);
    match (provenance, base_eps) {
        ("measured", Some(eps)) if eps > 0.0 => {
            let floor = eps * (1.0 - REGRESSION_TOLERANCE);
            if m.events_per_sec < floor {
                bail!(
                    "events/sec regression: measured {:.0} vs baseline {:.0} \
                     (floor {:.0}, tolerance {:.0}%) — see docs/benchmarks.md",
                    m.events_per_sec,
                    eps,
                    floor,
                    100.0 * REGRESSION_TOLERANCE,
                );
            }
            println!(
                "check: events/sec {:.0} vs measured baseline {:.0} (floor {:.0}) — ok",
                m.events_per_sec, eps, floor
            );
            Ok(true)
        }
        _ => {
            match projected_baseline_warning(baseline_path, provenance) {
                Some(w) => println!("{w}"),
                None => println!(
                    "check: baseline {baseline_path} has provenance {provenance:?} \
                     (not \"measured\") — informational only, not gating"
                ),
            }
            Ok(false)
        }
    }
}

pub fn cmd_bench(args: &ParsedArgs) -> Result<()> {
    args.ensure_known(&["out", "baseline", "threads"], &["record", "check", "quick"])?;
    let quick = args.has("quick");
    let threads = args.opt_u64("threads", 1)?.max(1) as usize;

    let m = measure(quick, threads)?;
    println!(
        "engine: {} events/run, {:.0} events/sec ({:.3}s/run); sweep: {:.2} points/sec",
        m.events_per_run, m.events_per_sec, m.engine_wall_s_per_run, m.sweep_points_per_sec
    );

    if args.has("check") {
        let baseline = args.opt("baseline").unwrap_or("BENCH_10.json");
        check_against(baseline, &m)?;
    }

    if args.has("record") {
        let out = args.opt("out").unwrap_or("BENCH_10.json");
        let json = carry_forward_pre_pr(out, record_json(&m)).render();
        std::fs::write(out, &json).with_context(|| format!("writing {out}"))?;
        println!("wrote {out} ({} bytes, provenance \"measured\")", json.len());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("mtsa-bench-{}-{name}", std::process::id()))
    }

    /// A placeholder measurement for the `check_against` tests — only
    /// `events_per_sec` participates in gating.
    fn fake_measured(events_per_sec: f64) -> Measured {
        Measured {
            events_per_run: 100,
            events_per_sec,
            engine_wall_s_per_run: 1.0,
            timing_ns_per_call: 1.0,
            sweep_points: 1,
            sweep_requests: 4,
            sweep_wall_s: 1.0,
            sweep_points_per_sec: 1.0,
            fleet_requests: 300,
            fleet_events: 1,
            fleet_wall_s: 1.0,
            fleet_events_per_sec: 1.0,
            profile_tables: 2,
            profile_wall_s: 1.0,
            profile_tables_per_sec: 2.0,
            plan_ns_per_call: 1.0,
            plans_per_sec: 1e9,
            burst_events_per_run: 1,
            burst_wall_s_per_run: 1.0,
            burst_events_per_sec: 1.0,
            vector_layers_per_run: 1,
            vector_wall_s_per_run: 1.0,
            vector_layers_per_sec: 1.0,
        }
    }

    #[test]
    fn record_writes_parseable_trajectory_file() {
        let out = tmp("record.json");
        let args = ParsedArgs::parse(&[
            "bench".into(),
            "--quick".into(),
            "--record".into(),
            "--out".into(),
            out.to_string_lossy().into_owned(),
        ])
        .unwrap();
        cmd_bench(&args).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(parsed.get("provenance").and_then(Json::as_str), Some("measured"));
        let eng = parsed.get("scenarios").unwrap().get("engine_run_heavy").unwrap();
        assert!(eng.get("events_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(eng.get("events_per_run").unwrap().as_u64().unwrap() > 0);
        let sweep = parsed.get("scenarios").unwrap().get("sweep_point_light").unwrap();
        assert!(sweep.get("points_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(parsed.get("pr").and_then(Json::as_u64), Some(10));
        let fleet = parsed.get("scenarios").unwrap().get("fleet_events_per_sec").unwrap();
        assert!(fleet.get("events_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(fleet.get("events").unwrap().as_u64().unwrap() > 0);
        let prof = parsed.get("scenarios").unwrap().get("profiler_tables_per_sec").unwrap();
        assert_eq!(prof.get("tables").unwrap().as_u64(), Some(2));
        assert!(prof.get("tables_per_sec").unwrap().as_f64().unwrap() > 0.0);
        let plan = parsed.get("scenarios").unwrap().get("planner_plans_per_sec").unwrap();
        assert!(plan.get("plans_per_sec").unwrap().as_f64().unwrap() > 0.0);
        let burst = parsed.get("scenarios").unwrap().get("coalesce_burst").unwrap();
        assert!(burst.get("events_per_run").unwrap().as_u64().unwrap() >= 32);
        assert!(burst.get("events_per_sec").unwrap().as_f64().unwrap() > 0.0);
        let vector = parsed.get("scenarios").unwrap().get("vector_layers_per_sec").unwrap();
        assert!(
            vector.get("layers_per_run").unwrap().as_u64().unwrap() > 0,
            "NCF+MelodyLSTM must offload at least one memory-bound layer"
        );
        assert!(vector.get("layers_per_sec").unwrap().as_f64().unwrap() > 0.0);
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn record_rerun_preserves_prior_pre_pr_block() {
        // The satellite bugfix: rerunning `--record` on an existing file
        // must carry the before-this-PR snapshot forward, not drop it.
        let out = tmp("prepr.json");
        std::fs::write(
            &out,
            r#"{"pr":7,"pre_pr":{"engine_run_heavy":{"events_per_sec":123.0}},"scenarios":{}}"#,
        )
        .unwrap();
        let args = ParsedArgs::parse(&[
            "bench".into(),
            "--quick".into(),
            "--record".into(),
            "--out".into(),
            out.to_string_lossy().into_owned(),
        ])
        .unwrap();
        cmd_bench(&args).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        let kept = parsed
            .get("pre_pr")
            .and_then(|p| p.get("engine_run_heavy"))
            .and_then(|e| e.get("events_per_sec"))
            .and_then(Json::as_f64);
        assert_eq!(kept, Some(123.0));
        // The fresh measurement is still there alongside the history.
        assert!(parsed.get("scenarios").unwrap().get("engine_run_heavy").is_some());
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn carry_forward_is_identity_without_prior_file() {
        let fresh = obj(vec![("pr", Json::Num(7.0))]);
        let kept = carry_forward_pre_pr("/nonexistent/BENCH_7.json", fresh.clone());
        assert_eq!(kept.render(), fresh.render());
    }

    #[test]
    fn projected_warning_names_baseline_and_arm_command() {
        // The satellite contract: one explicit line naming the baseline
        // file and how to arm the gate.
        let w = projected_baseline_warning("BENCH_10.json", "projected").unwrap();
        assert!(w.starts_with("warning:"), "{w}");
        assert!(w.contains("BENCH_10.json"), "{w}");
        assert!(w.contains("mtsa bench --record"), "{w}");
        assert!(!w.contains('\n'), "one line: {w}");
        assert!(projected_baseline_warning("BENCH_10.json", "measured").is_none());
        assert!(projected_baseline_warning("BENCH_10.json", "unknown").is_none());
    }

    #[test]
    fn check_does_not_gate_on_projected_baseline() {
        // A projected baseline (no toolchain on the recording host) must
        // never fail a build, whatever its numbers claim.
        let base = tmp("projected.json");
        std::fs::write(
            &base,
            r#"{"provenance":"projected","scenarios":{"engine_run_heavy":{"events_per_sec":1e18}}}"#,
        )
        .unwrap();
        let m = fake_measured(1.0);
        assert!(!check_against(base.to_str().unwrap(), &m).unwrap());
        let _ = std::fs::remove_file(&base);
    }

    #[test]
    fn check_gates_on_measured_baseline() {
        let base = tmp("measured.json");
        std::fs::write(
            &base,
            r#"{"provenance":"measured","scenarios":{"engine_run_heavy":{"events_per_sec":1000.0}}}"#,
        )
        .unwrap();
        let mut m = fake_measured(900.0); // within 15%
        assert!(check_against(base.to_str().unwrap(), &m).unwrap());
        m.events_per_sec = 800.0; // >15% below
        let err = check_against(base.to_str().unwrap(), &m).unwrap_err();
        assert!(err.to_string().contains("regression"), "got: {err:#}");
        let _ = std::fs::remove_file(&base);
    }

    #[test]
    fn missing_baseline_is_an_error() {
        let m = fake_measured(1.0);
        assert!(check_against("/nonexistent/BENCH_6.json", &m).is_err());
    }
}
