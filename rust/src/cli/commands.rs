//! Subcommand implementations.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::args::ParsedArgs;
use crate::config::RunConfig;
use crate::coordinator::scheduler::AllocPolicy;
use crate::coordinator::static_part::StaticPartitioning;
use crate::report;
use crate::util::stats::fmt_si;
use crate::util::tablefmt::Table;
use crate::workloads::dnng::WorkloadPool;
use crate::workloads::models;

pub const USAGE: &str = "\
mtsa — multi-tenant systolic-array accelerator (Reshadi & Gregg, PDP'23)

USAGE:
  mtsa zoo                               print the Table-1 workload zoo
  mtsa run <heavy|light|model,...>       run dynamic vs sequential
       [--config <file>] [--policy widest|equal] [--static] [--detail]
  mtsa trace <heavy|light|model,...>     write Scale-Sim/Accelergy CSVs
       [--config <file>] [--out <dir>]
  mtsa area [--config <file>]            45nm area breakdown (Accelergy-style)
  mtsa verify [--artifacts <dir>]        PJRT vs functional-sim numerics
  mtsa help                              this message
";

/// Dispatch a parsed command line.
pub fn dispatch(args: &ParsedArgs) -> Result<()> {
    match args.command.as_str() {
        "zoo" => cmd_zoo(args),
        "run" => cmd_run(args),
        "trace" => cmd_trace(args),
        "area" => cmd_area(args),
        "verify" => cmd_verify(args),
        "help" | "" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn cmd_zoo(args: &ParsedArgs) -> Result<()> {
    args.ensure_known(&[], &[])?;
    let mut t = Table::new(&["model", "domain", "group", "layers", "GMACs", "Opr (G)"]);
    for e in models::ZOO {
        let dnn = (e.build)();
        t.row(&[
            e.name.to_string(),
            e.domain.to_string(),
            e.group.tag().to_string(),
            dnn.layers.len().to_string(),
            format!("{:.2}", dnn.total_macs() as f64 / 1e9),
            format!("{:.2}", dnn.total_opr() as f64 / 1e9),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// Resolve a pool spec: "heavy", "light", or comma-separated model names.
pub fn resolve_pool(spec: &str) -> Result<WorkloadPool> {
    match spec {
        "heavy" => Ok(models::heavy_pool()),
        "light" => Ok(models::light_pool()),
        list => {
            let mut dnns = Vec::new();
            for name in list.split(',') {
                let e = models::by_name(name.trim())
                    .with_context(|| format!("unknown model {name:?} (see `mtsa zoo`)"))?;
                dnns.push((e.build)());
            }
            if dnns.is_empty() {
                bail!("empty pool spec");
            }
            Ok(WorkloadPool::new(spec, dnns))
        }
    }
}

fn load_config(args: &ParsedArgs) -> Result<RunConfig> {
    match args.opt("config") {
        Some(p) => RunConfig::from_file(Path::new(p)),
        None => Ok(RunConfig::default()),
    }
}

fn cmd_run(args: &ParsedArgs) -> Result<()> {
    args.ensure_known(&["config", "policy"], &["static", "detail"])?;
    let spec = args.positionals.first().map(String::as_str).unwrap_or("heavy");
    let pool = resolve_pool(spec)?;
    let mut cfg = load_config(args)?;
    if let Some(p) = args.opt("policy") {
        cfg.scheduler.alloc_policy = match p {
            "widest" => AllocPolicy::WidestToHeaviest,
            "equal" => AllocPolicy::EqualShare,
            _ => bail!("--policy must be widest|equal"),
        };
    }
    let model = cfg.energy_model();
    let g = report::run_group(&pool, &cfg.scheduler);
    let h = report::headline(&g, &model);

    println!("pool: {}  ({} DNNs, {} layers, {} MACs)", pool.name, pool.dnns.len(), pool.total_layers(), fmt_si(pool.total_macs() as f64));
    let mut t = Table::new(&["metric", "sequential", "dynamic", "saving"]);
    t.row(&[
        "makespan (cycles)".into(),
        g.sequential.makespan.to_string(),
        g.dynamic.makespan.to_string(),
        format!("{:+.1}%", h.makespan_saving_pct),
    ]);
    t.row(&[
        "mean completion (cycles)".into(),
        format!("{:.0}", report::mean_completion(&g.sequential)),
        format!("{:.0}", report::mean_completion(&g.dynamic)),
        format!("{:+.1}%", h.mean_completion_saving_pct),
    ]);
    let es = report::total_energy(&g.sequential, &model);
    let ed = report::total_energy(&g.dynamic, &model);
    t.row(&[
        "total energy (mJ)".into(),
        format!("{:.2}", es.total_j() * 1e3),
        format!("{:.2}", ed.total_j() * 1e3),
        format!("{:+.1}%", h.total_energy_saving_pct),
    ]);
    t.row(&[
        "mean per-DNN energy bar".into(),
        "-".into(),
        "-".into(),
        format!("{:+.1}%", h.mean_bar_energy_saving_pct),
    ]);
    t.row(&[
        "PE utilization".into(),
        format!("{:.1}%", 100.0 * h.seq_utilization),
        format!("{:.1}%", 100.0 * h.dyn_utilization),
        "".into(),
    ]);
    println!("{}", t.render());

    if args.has("static") {
        let stat = StaticPartitioning::new(cfg.scheduler.clone()).run(&pool);
        println!(
            "static equal partitioning: makespan {} ({:+.1}% vs sequential)",
            stat.makespan,
            report::saving_pct(g.sequential.makespan as f64, stat.makespan as f64)
        );
    }

    if args.has("detail") {
        let mut t = Table::new(&["DNN", "arrive", "start", "done", "partition widths"]);
        for (name, done) in &g.dynamic.completion {
            let arrive = pool.dnns.iter().find(|d| &d.name == name).map(|d| d.arrival_cycles).unwrap_or(0);
            t.row(&[
                name.clone(),
                arrive.to_string(),
                g.dynamic.start[name].to_string(),
                done.to_string(),
                format!("{:?}", g.dynamic.partition_widths(name)),
            ]);
        }
        println!("{}", t.render());
    }
    Ok(())
}

fn cmd_trace(args: &ParsedArgs) -> Result<()> {
    args.ensure_known(&["config", "out"], &[])?;
    let spec = args.positionals.first().map(String::as_str).unwrap_or("heavy");
    let pool = resolve_pool(spec)?;
    let cfg = load_config(args)?;
    let out = PathBuf::from(args.opt("out").unwrap_or("traces"));
    std::fs::create_dir_all(&out).with_context(|| format!("creating {}", out.display()))?;

    let g = report::run_group(&pool, &cfg.scheduler);
    let safe = spec.replace([',', ' '], "_");
    for (tag, m) in [("dynamic", &g.dynamic), ("sequential", &g.sequential)] {
        let compute = out.join(format!("{safe}_{tag}_compute_report.csv"));
        std::fs::write(&compute, crate::sim::trace::compute_report_csv(m, cfg.scheduler.geom))?;
        let activity = out.join(format!("{safe}_{tag}_activity_log.csv"));
        std::fs::write(&activity, crate::sim::trace::activity_log_csv(m))?;
        println!("wrote {} and {}", compute.display(), activity.display());
    }
    Ok(())
}

fn cmd_area(args: &ParsedArgs) -> Result<()> {
    args.ensure_known(&["config"], &[])?;
    let cfg = load_config(args)?;
    let a = crate::energy::area::estimate(cfg.scheduler.geom, &cfg.scheduler.buffers, cfg.precision);
    let mut t = Table::new(&["component", "area (mm2)", "share"]);
    let total = a.total_mm2();
    for (name, v) in [
        ("PE array", a.pe_array_mm2),
        ("SRAM buffers", a.sram_mm2),
        ("control", a.control_mm2),
        ("Mul_En tri-state gates (the paper's addition)", a.mul_en_gates_mm2),
    ] {
        t.row(&[name.to_string(), format!("{v:.3}"), format!("{:.2}%", 100.0 * v / total)]);
    }
    t.row(&["== total ==".into(), format!("{total:.3}"), "100%".into()]);
    println!("{}", t.render());
    println!("Mul_En overhead: {:.3}% of die — the paper's 'slight hardware modification', quantified.",
        100.0 * a.mul_en_overhead_fraction());
    Ok(())
}

fn cmd_verify(args: &ParsedArgs) -> Result<()> {
    args.ensure_known(&["artifacts"], &[])?;
    let dir = args
        .opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    let n = crate::verify::verify_all(&dir)?;
    println!("verify: {n} cross-checks passed (functional sim == PJRT artifacts == oracle)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_pool_specs() {
        assert_eq!(resolve_pool("heavy").unwrap().dnns.len(), 8);
        assert_eq!(resolve_pool("light").unwrap().dnns.len(), 4);
        let custom = resolve_pool("NCF, AlexNet").unwrap();
        assert_eq!(custom.dnns.len(), 2);
        assert!(resolve_pool("nope").is_err());
        assert!(resolve_pool("").is_err());
    }

    #[test]
    fn dispatch_unknown_command_errors() {
        let args = ParsedArgs::parse(&["frobnicate".to_string()]).unwrap();
        assert!(dispatch(&args).is_err());
    }

    #[test]
    fn zoo_runs() {
        let args = ParsedArgs::parse(&["zoo".to_string()]).unwrap();
        dispatch(&args).unwrap();
    }

    #[test]
    fn area_command_runs() {
        let args = ParsedArgs::parse(&["area".to_string()]).unwrap();
        dispatch(&args).unwrap();
    }

    #[test]
    fn trace_command_writes_csvs() {
        let out = std::env::temp_dir().join(format!("mtsa-trace-{}", std::process::id()));
        let args = ParsedArgs::parse(&[
            "trace".into(),
            "NCF".into(),
            "--out".into(),
            out.to_string_lossy().into_owned(),
        ])
        .unwrap();
        dispatch(&args).unwrap();
        assert!(out.join("NCF_dynamic_compute_report.csv").exists());
        assert!(out.join("NCF_sequential_activity_log.csv").exists());
        let _ = std::fs::remove_dir_all(&out);
    }

    #[test]
    fn run_small_custom_pool() {
        let args =
            ParsedArgs::parse(&["run".into(), "NCF,HandwritingLSTM".into(), "--detail".into()])
                .unwrap();
        dispatch(&args).unwrap();
    }
}
