//! Subcommand implementations.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::args::ParsedArgs;
use crate::config::{ArrivalKind, RunConfig};
use crate::coordinator::scheduler::{AllocPolicy, FeedModel, PartitionMode, PreemptMode};
use crate::coordinator::static_part::StaticPartitioning;
use crate::mem::{ArbitrationMode, MemConfig};
use crate::report;
use crate::sim::dataflow::ArrayGeometry;
use crate::sweep::{run_sweep, SweepGrid};
use crate::util::stats::fmt_si;
use crate::util::tablefmt::Table;
use crate::workloads::dnng::WorkloadPool;
use crate::workloads::models;

pub const USAGE: &str = "\
mtsa — multi-tenant systolic-array accelerator (Reshadi & Gregg, PDP'23)

USAGE:
  mtsa zoo                               print the Table-1 workload zoo
  mtsa run <heavy|light|model,...>       run dynamic vs sequential
       [--config <file>] [--policy widest|equal|mem-aware] [--mem]
       [--mode columns|2d] [--preempt off|arrival|deadline]
       [--lanes N] [--static] [--detail]
  mtsa sweep                             parallel scenario sweep (SLA report)
       [--config <file>] [--mixes heavy,light] [--rates 0,20000,100000]
       [--policies widest,equal,mem-aware] [--feeds independent,interleaved]
       [--geoms 128,64x256] [--modes columns,2d]
       [--preempts off,arrival,deadline]
       [--bandwidths 8,32,128] [--arbitrations fair,weighted,priority]
       [--requests 12] [--slack 3.0] [--burst <size>]
       [--fleet 4,8] [--tables <dir>] [--lanes 0,128] [--seed 42]
       [--threads N] [--json <file>]
  mtsa fleet                             serve a request stream on a cluster
       [--config <file>] [--instances 8] [--requests 1000000]
       [--mix heavy|light|model,...] [--mean <cycles>]
       [--policy dynamic|sequential|static|multi-array[:N]]
       [--placement least-loaded|affinity|random-k] [--slots 8] [--queue 64]
       [--amplitude 0.6] [--period <cycles>] [--seed 42]
       [--tables <dir>] [--threads N] [--json <file>]
  mtsa profile                           offline fission profiler (tables)
       [--config <file>] [--models all|name,...] [--geoms 128,96x64]
       [--out profiles] [--threads N]
  mtsa trace <heavy|light|model,...>     write Scale-Sim/Accelergy CSVs
       [--config <file>] [--out <dir>]
  mtsa area [--config <file>]            45nm area breakdown (Accelergy-style)
  mtsa verify [--artifacts <dir>]        PJRT vs functional-sim numerics
  mtsa bench                             engine hot-path perf (BENCH_*.json)
       [--record] [--check] [--quick] [--out <file>] [--baseline <file>]
       [--threads N]
  mtsa help                              this message
";

/// Dispatch a parsed command line.
pub fn dispatch(args: &ParsedArgs) -> Result<()> {
    match args.command.as_str() {
        "zoo" => cmd_zoo(args),
        "run" => cmd_run(args),
        "sweep" => cmd_sweep(args),
        "fleet" => cmd_fleet(args),
        "profile" => cmd_profile(args),
        "trace" => cmd_trace(args),
        "area" => cmd_area(args),
        "verify" => cmd_verify(args),
        "bench" => super::bench::cmd_bench(args),
        "help" | "" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn cmd_zoo(args: &ParsedArgs) -> Result<()> {
    args.ensure_known(&[], &[])?;
    let mut t = Table::new(&["model", "domain", "group", "layers", "GMACs", "Opr (G)"]);
    for e in models::ZOO {
        let dnn = (e.build)();
        t.row(&[
            e.name.to_string(),
            e.domain.to_string(),
            e.group.tag().to_string(),
            dnn.layers.len().to_string(),
            format!("{:.2}", dnn.total_macs() as f64 / 1e9),
            format!("{:.2}", dnn.total_opr() as f64 / 1e9),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// Resolve a pool spec: "heavy", "light", or comma-separated model names.
pub fn resolve_pool(spec: &str) -> Result<WorkloadPool> {
    models::by_spec(spec).map_err(anyhow::Error::msg)
}

fn load_config(args: &ParsedArgs) -> Result<RunConfig> {
    match args.opt("config") {
        Some(p) => RunConfig::from_file(Path::new(p)),
        None => Ok(RunConfig::default()),
    }
}

fn cmd_run(args: &ParsedArgs) -> Result<()> {
    args.ensure_known(
        &["config", "policy", "mode", "preempt", "lanes"],
        &["static", "detail", "mem"],
    )?;
    let spec = args.positionals.first().map(String::as_str).unwrap_or("heavy");
    let pool = resolve_pool(spec)?;
    let mut cfg = load_config(args)?;
    if let Some(p) = args.opt("policy") {
        cfg.scheduler.alloc_policy =
            p.parse::<AllocPolicy>().map_err(|e| anyhow!("--policy: {e}"))?;
    }
    if let Some(m) = args.opt("mode") {
        cfg.scheduler.partition_mode =
            m.parse::<PartitionMode>().map_err(|e| anyhow!("--mode: {e}"))?;
    }
    if let Some(p) = args.opt("preempt") {
        cfg.scheduler.preempt = p.parse::<PreemptMode>().map_err(|e| anyhow!("--preempt: {e}"))?;
    }
    if let Some(l) = args.opt("lanes") {
        // Heterogeneous shorthand: an l-lane vector engine at default
        // rates ([vector] config section for the full knobs); 0 = off.
        let l: u64 = l.parse().map_err(|_| anyhow!("--lanes expects an integer, got {l:?}"))?;
        cfg.scheduler.vector =
            if l == 0 { None } else { Some(crate::sim::dataflow::VectorUnit::new(l)) };
    }
    if args.has("mem") && cfg.scheduler.mem.is_none() {
        // Shorthand: shared memory hierarchy at defaults ([mem] config
        // section for the full knobs).  Subsumes the [dram] bound —
        // keeping its configured interface parameters, since [mem]
        // shares the same words/cycle + burst model.
        cfg.scheduler.mem = Some(MemConfig {
            dram: cfg.scheduler.dram.take().unwrap_or_default(),
            ..MemConfig::default()
        });
    }
    let model = cfg.energy_model();
    let g = report::run_group(&pool, &cfg.scheduler);
    let h = report::headline(&g, &model);

    println!("pool: {}  ({} DNNs, {} layers, {} MACs)", pool.name, pool.dnns.len(), pool.total_layers(), fmt_si(pool.total_macs() as f64));
    let mut t = Table::new(&["metric", "sequential", "dynamic", "saving"]);
    t.row(&[
        "makespan (cycles)".into(),
        g.sequential.makespan.to_string(),
        g.dynamic.makespan.to_string(),
        format!("{:+.1}%", h.makespan_saving_pct),
    ]);
    t.row(&[
        "mean completion (cycles)".into(),
        format!("{:.0}", report::mean_completion(&g.sequential)),
        format!("{:.0}", report::mean_completion(&g.dynamic)),
        format!("{:+.1}%", h.mean_completion_saving_pct),
    ]);
    let es = report::total_energy(&g.sequential, &model);
    let ed = report::total_energy(&g.dynamic, &model);
    t.row(&[
        "total energy (mJ)".into(),
        format!("{:.2}", es.total_j() * 1e3),
        format!("{:.2}", ed.total_j() * 1e3),
        format!("{:+.1}%", h.total_energy_saving_pct),
    ]);
    t.row(&[
        "mean per-DNN energy bar".into(),
        "-".into(),
        "-".into(),
        format!("{:+.1}%", h.mean_bar_energy_saving_pct),
    ]);
    t.row(&[
        "PE utilization".into(),
        format!("{:.1}%", 100.0 * h.seq_utilization),
        format!("{:.1}%", 100.0 * h.dyn_utilization),
        "".into(),
    ]);
    println!("{}", t.render());

    if cfg.scheduler.preempt != PreemptMode::Off {
        println!(
            "preemption ({}): {} fold-boundary preemption(s), {} fold(s) replayed, \
             {} wasted refill cycle(s)",
            cfg.scheduler.preempt.tag(),
            g.dynamic.preemptions,
            g.dynamic.replayed_folds,
            g.dynamic.wasted_refill_cycles,
        );
    }

    if let Some(v) = cfg.scheduler.vector {
        println!(
            "vector engine ({} lanes): {} memory-bound layer segment(s) offloaded",
            v.lanes, g.dynamic.vector_dispatches,
        );
    }

    if cfg.scheduler.mem.is_some() {
        println!("shared memory hierarchy (dynamic run):");
        println!("{}", report::mem_table(&g.dynamic, &model).render());
    }

    if args.has("static") {
        let stat = StaticPartitioning::new(cfg.scheduler.clone()).run(&pool);
        println!(
            "static equal partitioning: makespan {} ({:+.1}% vs sequential)",
            stat.makespan,
            report::saving_pct(g.sequential.makespan as f64, stat.makespan as f64)
        );
    }

    if args.has("detail") {
        let mut t = Table::new(&["DNN", "arrive", "start", "done", "partition widths"]);
        for (name, done) in &g.dynamic.completion {
            let arrive = pool.dnns.iter().find(|d| &d.name == name).map(|d| d.arrival_cycles).unwrap_or(0);
            t.row(&[
                name.clone(),
                arrive.to_string(),
                g.dynamic.start[name].to_string(),
                done.to_string(),
                format!("{:?}", g.dynamic.partition_widths(name)),
            ]);
        }
        println!("{}", t.render());
    }
    Ok(())
}

/// Parse a comma-separated list via each item's [`std::str::FromStr`]
/// (tagged enums like [`AllocPolicy`]/[`FeedModel`] report the valid
/// variants in their error).
fn parse_list<T: std::str::FromStr>(raw: &str, what: &str) -> Result<Vec<T>>
where
    T::Err: std::fmt::Display,
{
    let mut out = Vec::new();
    for item in raw.split(',') {
        let item = item.trim();
        out.push(item.parse::<T>().map_err(|e| anyhow!("bad {what} value {item:?}: {e}"))?);
    }
    if out.is_empty() {
        bail!("--{what} must list at least one value");
    }
    Ok(out)
}

fn cmd_sweep(args: &ParsedArgs) -> Result<()> {
    args.ensure_known(
        &[
            "config", "mixes", "rates", "policies", "feeds", "geoms", "modes", "preempts",
            "bandwidths", "arbitrations", "requests", "slack", "burst", "burst-within", "fleet",
            "tables", "lanes", "seed", "threads", "json",
        ],
        &[],
    )?;
    let cfg = load_config(args)?;

    // Grid defaults <- [scenario] config section <- CLI flags.
    let mut grid = SweepGrid {
        requests: cfg.scenario.requests as usize,
        qos_slack: cfg.scenario.qos_slack,
        seed: cfg.scenario.seed,
        ..SweepGrid::default()
    };
    // A configured arrival process replaces the default rate axis: the
    // sweep then runs batch + the configured rate, bursty if configured.
    match cfg.scenario.arrival {
        ArrivalKind::Batch => {}
        ArrivalKind::Poisson => grid.rates = vec![0.0, cfg.scenario.mean_interarrival],
        ArrivalKind::Bursty => {
            grid.rates = vec![0.0, cfg.scenario.mean_interarrival];
            grid.bursty =
                Some((cfg.scenario.burst_size as usize, cfg.scenario.burst_within));
        }
    }
    if let Some(v) = args.opt("mixes") {
        grid.mixes = parse_list::<String>(v, "mixes")?;
    }
    if let Some(v) = args.opt("rates") {
        grid.rates = parse_list::<f64>(v, "rates")?;
        if grid.rates.iter().any(|r| !r.is_finite() || *r < 0.0) {
            bail!("--rates values must be finite and >= 0, got {:?}", grid.rates);
        }
    }
    if let Some(v) = args.opt("policies") {
        grid.policies = parse_list::<AllocPolicy>(v, "policies")?;
    }
    if let Some(v) = args.opt("feeds") {
        grid.feeds = parse_list::<FeedModel>(v, "feeds")?;
    }
    if let Some(v) = args.opt("geoms") {
        grid.geoms = parse_list::<ArrayGeometry>(v, "geoms")?;
        if grid.geoms.iter().any(|g| g.rows < 8 || g.cols < 8) {
            bail!("--geoms dimensions must be >= 8, got {:?}", grid.geoms);
        }
    }
    if let Some(v) = args.opt("modes") {
        grid.modes = parse_list::<PartitionMode>(v, "modes")?;
    }
    if let Some(v) = args.opt("preempts") {
        grid.preempts = parse_list::<PreemptMode>(v, "preempts")?;
    }
    if let Some(v) = args.opt("bandwidths") {
        grid.bandwidths = parse_list::<f64>(v, "bandwidths")?;
        if grid.bandwidths.iter().any(|b| !b.is_finite() || *b <= 0.0) {
            bail!("--bandwidths values must be finite and > 0, got {:?}", grid.bandwidths);
        }
    }
    if let Some(v) = args.opt("arbitrations") {
        grid.arbitrations = parse_list::<ArbitrationMode>(v, "arbitrations")?;
        if grid.bandwidths.is_empty() {
            bail!("--arbitrations requires --bandwidths (the contention axis)");
        }
    }
    if let Some(v) = args.opt("fleet") {
        grid.fleet = parse_list::<usize>(v, "fleet")?;
        if grid.fleet.iter().any(|&n| n == 0) {
            bail!("--fleet cluster sizes must be >= 1, got {:?}", grid.fleet);
        }
    }
    if let Some(dir) = args.opt("tables") {
        // Profiled-vs-ladder comparison axis: every point runs once with
        // the tables off and once consulting them.
        grid.tables_store = Some(
            crate::profiler::ProfileStore::load_arc(dir)
                .map_err(anyhow::Error::msg)
                .with_context(|| format!("--tables {dir}"))?,
        );
        grid.tables = vec![false, true];
    }
    if let Some(v) = args.opt("lanes") {
        // Heterogeneous-compute axis: vector-engine lane counts per point
        // (0 = array-only, for off/on pairs in one sweep).
        grid.lanes = parse_list::<u64>(v, "lanes")?;
    }
    grid.requests = args.opt_u64("requests", grid.requests as u64)?.max(1) as usize;
    grid.seed = args.opt_u64("seed", grid.seed)?;
    if let Some(v) = args.opt("slack") {
        grid.qos_slack = v
            .parse::<f64>()
            .ok()
            .filter(|s| *s >= 0.0)
            .with_context(|| format!("--slack expects a non-negative number, got {v:?}"))?;
    }
    let within_flag = args
        .opt("burst-within")
        .map(|w| {
            w.parse::<f64>()
                .ok()
                .filter(|w| *w >= 0.0)
                .with_context(|| format!("--burst-within expects cycles, got {w:?}"))
        })
        .transpose()?;
    if let Some(size) = args.opt("burst") {
        let size = size
            .parse::<usize>()
            .ok()
            .filter(|b| *b >= 1)
            .with_context(|| format!("--burst expects a positive integer, got {size:?}"))?;
        grid.bursty = Some((size, within_flag.unwrap_or(cfg.scenario.burst_within)));
    } else if let Some(within) = within_flag {
        match &mut grid.bursty {
            Some((_, w)) => *w = within,
            None => bail!("--burst-within requires --burst (or arrival = \"bursty\" in the config)"),
        }
    }

    let threads = match args.opt_u64("threads", 0)? {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        n => n as usize,
    };

    let rows = run_sweep(&grid, &cfg.scheduler, threads)?;
    println!(
        "sweep: {} points ({} mixes x {} rates x {} policies x {} feeds x {} geoms), \
         {} requests each, {} threads",
        rows.len(),
        grid.mixes.len(),
        grid.rates.len(),
        grid.policies.len(),
        grid.feeds.len(),
        if grid.geoms.is_empty() { 1 } else { grid.geoms.len() },
        grid.requests,
        threads,
    );
    println!("{}", report::sweep_table(&grid, &rows).render());

    let fleet_rows = crate::sweep::run_fleet_axis(&grid, &cfg.scheduler, threads)?;
    for fr in &fleet_rows {
        println!(
            "fleet {}x @ {}@{:.0}: util {:.1}%, {}/{} served, {:.4} J/query",
            fr.instances,
            fr.mix,
            fr.mean_interarrival,
            fr.report.utilization * 100.0,
            fr.report.completed,
            fr.report.generated,
            fr.report.cost_j_per_query,
        );
    }

    let json = report::sweep_json_with_fleet(&grid, &rows, &fleet_rows).render();
    match args.opt("json") {
        Some(path) => {
            std::fs::write(path, &json).with_context(|| format!("writing {path}"))?;
            println!("wrote {path} ({} bytes; same seed => identical bytes)", json.len());
        }
        None => println!("{json}"),
    }
    Ok(())
}

fn cmd_fleet(args: &ParsedArgs) -> Result<()> {
    use crate::fleet::{run_fleet, FleetConfig, FleetPolicy, Placement};
    use crate::workloads::generator::{ArrivalProcess, Diurnal, ModelMix};

    args.ensure_known(
        &[
            "config", "instances", "requests", "mix", "mean", "policy", "placement", "slots",
            "queue", "amplitude", "period", "seed", "tables", "threads", "json",
        ],
        &[],
    )?;
    let cfg = load_config(args)?;
    let d = &cfg.fleet;

    let instances = args.opt_u64("instances", d.instances)?.max(1) as usize;
    let requests = args.opt_u64("requests", d.requests)?.max(1) as usize;
    let slots = args.opt_u64("slots", d.slots)?.max(1) as usize;
    let queue_cap = args.opt_u64("queue", d.queue_cap)?.max(1) as usize;
    let seed = args.opt_u64("seed", d.seed)?;
    let policy = match args.opt("policy") {
        Some(v) => v.parse::<FleetPolicy>().map_err(|e| anyhow!("--policy: {e}"))?,
        None => d.policy,
    };
    let placement = match args.opt("placement") {
        Some(v) => v.parse::<Placement>().map_err(|e| anyhow!("--placement: {e}"))?,
        None => d.placement,
    };
    let mean = match args.opt("mean") {
        Some(v) => v
            .parse::<f64>()
            .ok()
            .filter(|m| m.is_finite() && *m > 0.0)
            .with_context(|| format!("--mean expects cycles > 0, got {v:?}"))?,
        None => cfg.scenario.mean_interarrival,
    };
    let amplitude = match args.opt("amplitude") {
        Some(v) => v
            .parse::<f64>()
            .ok()
            .filter(|a| (0.0..1.0).contains(a))
            .with_context(|| format!("--amplitude expects a value in [0, 1), got {v:?}"))?,
        None => d.diurnal_amplitude,
    };
    let period = match args.opt("period") {
        Some(v) => v
            .parse::<f64>()
            .ok()
            .filter(|p| p.is_finite() && *p >= 0.0)
            .with_context(|| format!("--period expects cycles >= 0, got {v:?}"))?,
        None => d.diurnal_period,
    };

    let spec = args.opt("mix").unwrap_or("light");
    let pool = resolve_pool(spec)?;
    let weights: Vec<(&str, f64)> = pool.dnns.iter().map(|m| (m.name.as_str(), 1.0)).collect();

    // Batch "everything at t=0" is not a serving workload: the fleet
    // always streams, Poisson by default, bursty when configured.
    let arrival = match cfg.scenario.arrival {
        ArrivalKind::Bursty => ArrivalProcess::Bursty {
            burst_size: cfg.scenario.burst_size as usize,
            within_gap: cfg.scenario.burst_within,
            between_gap: mean,
        },
        _ => ArrivalProcess::Poisson { mean_interarrival: mean },
    };
    // Period 0 = one diurnal day spanning the whole trace.
    let diurnal = (amplitude > 0.0).then(|| Diurnal {
        period: if period > 0.0 { period } else { requests as f64 * mean },
        amplitude,
        phase: 0.0,
    });
    let mut classes = FleetConfig::default_classes(mean);
    if cfg.scenario.qos_slack > 0.0 {
        classes[0].slack = Some(cfg.scenario.qos_slack);
    }

    // `--tables <dir>` / `[fleet] tables`: router horizon estimates come
    // from the profiled totals (coverage-checked by the driver).
    let tables = match args.opt("tables").map(str::to_string).or_else(|| d.tables.clone()) {
        Some(dir) => Some(
            crate::profiler::ProfileStore::load_arc(&dir)
                .map_err(anyhow::Error::msg)
                .with_context(|| format!("loading fleet tables from {dir}"))?,
        ),
        None => None,
    };

    let fleet_cfg = FleetConfig {
        instances: FleetConfig::uniform(instances, &cfg.scheduler, policy),
        placement,
        random_k: d.random_k.max(1) as usize,
        classes,
        slots,
        queue_cap,
        mix: ModelMix::new(&weights),
        arrival,
        diurnal,
        requests,
        seed,
        chunk: 8192,
        tables,
    };

    let threads = match args.opt_u64("threads", 0)? {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        n => n as usize,
    };
    let r = run_fleet(&fleet_cfg, threads)?;

    println!(
        "fleet: {} x {} ({}), {} requests ({} batches) over {} cycles, {} threads",
        instances,
        policy.label(),
        spec,
        fmt_si(r.generated as f64),
        r.batches,
        fmt_si(r.makespan as f64),
        threads,
    );
    println!(
        "served {} / dropped {} | fleet util {:.1}% | {:.3} J total, {:.6} J/query",
        r.completed,
        r.dropped,
        r.utilization * 100.0,
        r.energy_j,
        r.cost_j_per_query,
    );
    println!("{}", report::fleet_table(&r).render());
    println!("{}", report::fleet_instance_table(&r).render());

    if let Some(path) = args.opt("json") {
        let json = report::fleet_json(&r).render();
        std::fs::write(path, &json).with_context(|| format!("writing {path}"))?;
        println!("wrote {path} ({} bytes; same seed => identical bytes)", json.len());
    }
    Ok(())
}

/// `mtsa profile` — build offline fission tables: exhaustively search
/// tile shapes per layer (closed-form pricing, no simulation) for each
/// requested (model, geometry) pair and persist the summary table +
/// per-candidate report under `--out`.
fn cmd_profile(args: &ParsedArgs) -> Result<()> {
    args.ensure_known(&["config", "models", "geoms", "out", "threads"], &[])?;
    let cfg = load_config(args)?;
    let names: Vec<String> = match args.opt("models").unwrap_or("all") {
        "all" => models::ZOO.iter().map(|e| e.name.to_string()).collect(),
        list => parse_list::<String>(list, "models")?,
    };
    let geoms: Vec<ArrayGeometry> = match args.opt("geoms") {
        Some(v) => parse_list::<ArrayGeometry>(v, "geoms")?,
        None => vec![cfg.scheduler.geom],
    };
    let out = PathBuf::from(args.opt("out").unwrap_or("profiles"));
    let threads = match args.opt_u64("threads", 0)? {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        n => n as usize,
    };

    let jobs: Vec<(String, ArrayGeometry)> = names
        .iter()
        .flat_map(|n| geoms.iter().map(move |&g| (n.clone(), g)))
        .collect();
    let t0 = std::time::Instant::now();
    let tables = crate::profiler::build_tables(&jobs, &cfg.scheduler.buffers, threads)
        .map_err(anyhow::Error::msg)?;
    let wall_s = t0.elapsed().as_secs_f64();

    let mut t = Table::new(&["model", "geom", "layers", "hash", "table"]);
    for table in &tables {
        let file = crate::profiler::write_artifacts(table, &cfg.scheduler.buffers, &out)
            .map_err(anyhow::Error::msg)?;
        t.row(&[
            table.model.clone(),
            format!("{}x{}", table.geom.rows, table.geom.cols),
            table.layers.len().to_string(),
            table.hash.clone(),
            file,
        ]);
    }
    println!(
        "profiled {} (model, geometry) pairs in {:.2}s ({:.1} tables/s, {} threads) -> {}",
        tables.len(),
        wall_s,
        tables.len() as f64 / wall_s.max(1e-9),
        threads,
        out.display(),
    );
    println!("{}", t.render());
    println!("use with: [partition] tables / [fleet] tables, or --tables {}", out.display());
    Ok(())
}

fn cmd_trace(args: &ParsedArgs) -> Result<()> {
    args.ensure_known(&["config", "out"], &[])?;
    let spec = args.positionals.first().map(String::as_str).unwrap_or("heavy");
    let pool = resolve_pool(spec)?;
    let cfg = load_config(args)?;
    let out = PathBuf::from(args.opt("out").unwrap_or("traces"));
    std::fs::create_dir_all(&out).with_context(|| format!("creating {}", out.display()))?;

    let g = report::run_group(&pool, &cfg.scheduler);
    let safe = spec.replace([',', ' '], "_");
    for (tag, m) in [("dynamic", &g.dynamic), ("sequential", &g.sequential)] {
        let compute = out.join(format!("{safe}_{tag}_compute_report.csv"));
        std::fs::write(&compute, crate::sim::trace::compute_report_csv(m))?;
        let activity = out.join(format!("{safe}_{tag}_activity_log.csv"));
        std::fs::write(&activity, crate::sim::trace::activity_log_csv(m))?;
        println!("wrote {} and {}", compute.display(), activity.display());
    }
    Ok(())
}

fn cmd_area(args: &ParsedArgs) -> Result<()> {
    args.ensure_known(&["config"], &[])?;
    let cfg = load_config(args)?;
    let a = crate::energy::area::estimate(cfg.scheduler.geom, &cfg.scheduler.buffers, cfg.precision);
    let mut t = Table::new(&["component", "area (mm2)", "share"]);
    let total = a.total_mm2();
    for (name, v) in [
        ("PE array", a.pe_array_mm2),
        ("SRAM buffers", a.sram_mm2),
        ("control", a.control_mm2),
        ("Mul_En tri-state gates (the paper's addition)", a.mul_en_gates_mm2),
    ] {
        t.row(&[name.to_string(), format!("{v:.3}"), format!("{:.2}%", 100.0 * v / total)]);
    }
    t.row(&["== total ==".into(), format!("{total:.3}"), "100%".into()]);
    println!("{}", t.render());
    println!("Mul_En overhead: {:.3}% of die — the paper's 'slight hardware modification', quantified.",
        100.0 * a.mul_en_overhead_fraction());
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_verify(args: &ParsedArgs) -> Result<()> {
    args.ensure_known(&["artifacts"], &[])?;
    let dir = args
        .opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    let n = crate::verify::verify_all(&dir)?;
    println!("verify: {n} cross-checks passed (functional sim == PJRT artifacts == oracle)");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_verify(_args: &ParsedArgs) -> Result<()> {
    bail!(
        "`mtsa verify` exercises the PJRT datapath, which this binary was built without; \
         rebuild with `--features pjrt` on a host with XLA/PJRT (see README)"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_pool_specs() {
        assert_eq!(resolve_pool("heavy").unwrap().dnns.len(), 8);
        assert_eq!(resolve_pool("light").unwrap().dnns.len(), 4);
        let custom = resolve_pool("NCF, AlexNet").unwrap();
        assert_eq!(custom.dnns.len(), 2);
        assert!(resolve_pool("nope").is_err());
        assert!(resolve_pool("").is_err());
    }

    #[test]
    fn dispatch_unknown_command_errors() {
        let args = ParsedArgs::parse(&["frobnicate".to_string()]).unwrap();
        assert!(dispatch(&args).is_err());
    }

    #[test]
    fn zoo_runs() {
        let args = ParsedArgs::parse(&["zoo".to_string()]).unwrap();
        dispatch(&args).unwrap();
    }

    #[test]
    fn area_command_runs() {
        let args = ParsedArgs::parse(&["area".to_string()]).unwrap();
        dispatch(&args).unwrap();
    }

    #[test]
    fn trace_command_writes_csvs() {
        let out = std::env::temp_dir().join(format!("mtsa-trace-{}", std::process::id()));
        let args = ParsedArgs::parse(&[
            "trace".into(),
            "NCF".into(),
            "--out".into(),
            out.to_string_lossy().into_owned(),
        ])
        .unwrap();
        dispatch(&args).unwrap();
        assert!(out.join("NCF_dynamic_compute_report.csv").exists());
        assert!(out.join("NCF_sequential_activity_log.csv").exists());
        let _ = std::fs::remove_dir_all(&out);
    }

    #[test]
    fn run_small_custom_pool() {
        let args =
            ParsedArgs::parse(&["run".into(), "NCF,HandwritingLSTM".into(), "--detail".into()])
                .unwrap();
        dispatch(&args).unwrap();
    }

    #[test]
    fn sweep_small_grid_writes_json() {
        let out = std::env::temp_dir().join(format!("mtsa-sweep-{}.json", std::process::id()));
        let args = ParsedArgs::parse(&[
            "sweep".into(),
            "--mixes".into(),
            "NCF".into(),
            "--rates".into(),
            "0,40000".into(),
            "--policies".into(),
            "widest".into(),
            "--feeds".into(),
            "independent".into(),
            "--requests".into(),
            "4".into(),
            "--threads".into(),
            "2".into(),
            "--json".into(),
            out.to_string_lossy().into_owned(),
        ])
        .unwrap();
        dispatch(&args).unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(parsed.get("points").unwrap().as_arr().unwrap().len(), 2);
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn sweep_rejects_bad_flags() {
        for bad in [
            vec!["sweep".to_string(), "--rates".into(), "-5".into()],
            vec!["sweep".to_string(), "--policies".into(), "greedy".into()],
            vec!["sweep".to_string(), "--feeds".into(), "psychic".into()],
            vec!["sweep".to_string(), "--mixes".into(), "NotAModel".into()],
            vec!["sweep".to_string(), "--bandwidths".into(), "0".into()],
            vec!["sweep".to_string(), "--geoms".into(), "64x".into()],
            vec!["sweep".to_string(), "--geoms".into(), "4".into()],
            vec!["sweep".to_string(), "--modes".into(), "diagonal".into()],
            vec!["sweep".to_string(), "--preempts".into(), "sometimes".into()],
            vec!["run".to_string(), "NCF".into(), "--mode".into(), "psychic".into()],
            vec!["run".to_string(), "NCF".into(), "--preempt".into(), "sometimes".into()],
            vec!["sweep".to_string(), "--arbitrations".into(), "fair".into()],
            vec![
                "sweep".to_string(),
                "--bandwidths".into(),
                "8".into(),
                "--arbitrations".into(),
                "psychic".into(),
            ],
        ] {
            let args = ParsedArgs::parse(&bad).unwrap();
            assert!(dispatch(&args).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn run_with_2d_mode() {
        let args = ParsedArgs::parse(&[
            "run".into(),
            "NCF,HandwritingLSTM".into(),
            "--mode".into(),
            "2d".into(),
        ])
        .unwrap();
        dispatch(&args).unwrap();
    }

    #[test]
    fn sweep_mode_axis_and_hxw_geoms_emit_json() {
        let out = std::env::temp_dir().join(format!("mtsa-2dsweep-{}.json", std::process::id()));
        let args = ParsedArgs::parse(&[
            "sweep".into(),
            "--mixes".into(),
            "NCF".into(),
            "--rates".into(),
            "0".into(),
            "--policies".into(),
            "widest".into(),
            "--feeds".into(),
            "independent".into(),
            "--geoms".into(),
            "128,64x128".into(),
            "--modes".into(),
            "columns,2d".into(),
            "--requests".into(),
            "3".into(),
            "--threads".into(),
            "2".into(),
            "--json".into(),
            out.to_string_lossy().into_owned(),
        ])
        .unwrap();
        dispatch(&args).unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        let points = parsed.get("points").unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 4, "geoms x modes");
        // 2d points carry the mode key; columns points do not.
        let with_mode =
            points.iter().filter(|p| p.get("partition_mode").is_some()).count();
        assert_eq!(with_mode, 2);
        // Non-square geometries carry a rows key.
        let with_rows = points.iter().filter(|p| p.get("rows").is_some()).count();
        assert_eq!(with_rows, 2);
        assert!(parsed.get("modes").is_some());
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn run_with_preempt_flag() {
        let args = ParsedArgs::parse(&[
            "run".into(),
            "NCF,HandwritingLSTM".into(),
            "--preempt".into(),
            "arrival".into(),
        ])
        .unwrap();
        dispatch(&args).unwrap();
    }

    #[test]
    fn sweep_preempt_axis_emits_json_keys_only_when_on() {
        let out = std::env::temp_dir().join(format!("mtsa-presweep-{}.json", std::process::id()));
        let args = ParsedArgs::parse(&[
            "sweep".into(),
            "--mixes".into(),
            "light".into(),
            "--rates".into(),
            "30000".into(),
            "--policies".into(),
            "widest".into(),
            "--feeds".into(),
            "independent".into(),
            "--preempts".into(),
            "off,arrival".into(),
            "--requests".into(),
            "4".into(),
            "--threads".into(),
            "2".into(),
            "--json".into(),
            out.to_string_lossy().into_owned(),
        ])
        .unwrap();
        dispatch(&args).unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        let points = parsed.get("points").unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 2);
        let with_keys = points.iter().filter(|p| p.get("preempt").is_some()).count();
        assert_eq!(with_keys, 1, "only the arrival point carries preempt keys");
        assert!(parsed.get("preempts").is_some());
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn run_with_mem_prints_contention_table() {
        let args =
            ParsedArgs::parse(&["run".into(), "NCF".into(), "--mem".into()]).unwrap();
        dispatch(&args).unwrap();
    }

    #[test]
    fn sweep_contention_grid_emits_mem_json() {
        let out = std::env::temp_dir().join(format!("mtsa-memsweep-{}.json", std::process::id()));
        let args = ParsedArgs::parse(&[
            "sweep".into(),
            "--mixes".into(),
            "NCF".into(),
            "--rates".into(),
            "0".into(),
            "--policies".into(),
            "widest,mem-aware".into(),
            "--feeds".into(),
            "independent".into(),
            "--bandwidths".into(),
            "8,64".into(),
            "--arbitrations".into(),
            "fair,priority".into(),
            "--requests".into(),
            "3".into(),
            "--threads".into(),
            "2".into(),
            "--json".into(),
            out.to_string_lossy().into_owned(),
        ])
        .unwrap();
        dispatch(&args).unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        let points = parsed.get("points").unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 2 * 2 * 2, "policies x bandwidths x arbitrations");
        assert!(points.iter().all(|p| p.get("mem").is_some()));
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn profile_writes_tables_the_sweep_can_consume() {
        let dir = std::env::temp_dir().join(format!("mtsa-profcli-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let args = ParsedArgs::parse(&[
            "profile".into(),
            "--models".into(),
            "NCF".into(),
            "--out".into(),
            dir.to_string_lossy().into_owned(),
            "--threads".into(),
            "2".into(),
        ])
        .unwrap();
        dispatch(&args).unwrap();
        assert!(dir.join("ncf_128x128.table.json").is_file());
        assert!(dir.join("ncf_128x128.report.csv").is_file());
        // The written directory round-trips through the sweep flag.
        let out = std::env::temp_dir().join(format!("mtsa-profcli-{}.json", std::process::id()));
        let sweep = ParsedArgs::parse(&[
            "sweep".into(),
            "--mixes".into(),
            "NCF".into(),
            "--rates".into(),
            "0".into(),
            "--policies".into(),
            "widest".into(),
            "--feeds".into(),
            "independent".into(),
            "--modes".into(),
            "2d".into(),
            "--requests".into(),
            "3".into(),
            "--tables".into(),
            dir.to_string_lossy().into_owned(),
            "--threads".into(),
            "2".into(),
            "--json".into(),
            out.to_string_lossy().into_owned(),
        ])
        .unwrap();
        dispatch(&sweep).unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        let points = parsed.get("points").unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 2, "off/on pair");
        assert!(text.contains("\"tables_axis\":[false,true]"), "{text}");
        let _ = std::fs::remove_file(&out);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
