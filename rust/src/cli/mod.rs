//! Command-line interface (offline build: no `clap`) — a small typed
//! argument parser ([`args`]) plus the subcommand implementations
//! ([`commands`]).
//!
//! Subcommands map onto the paper + the serving extension: `zoo`
//! (Table 1), `run` (the Fig. 9 dynamic-vs-sequential comparison),
//! `sweep` (arrival-driven scenario grid with SLA metrics, see
//! `docs/scenarios.md`), `trace` (Scale-Sim/Accelergy-style CSVs,
//! Fig. 8 toolchain), `area` (the Mul_En overhead of §3.2), and `verify`
//! (PJRT cross-checks, `pjrt` feature).

pub mod args;
pub mod bench;
pub mod commands;

pub use args::ParsedArgs;

/// CLI entry: parse argv and dispatch.  Returns a process exit code.
pub fn main_with(argv: &[String]) -> i32 {
    let parsed = match ParsedArgs::parse(argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{}", commands::USAGE);
            return 2;
        }
    };
    match commands::dispatch(&parsed) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}
