//! Partitioned weight-stationary dataflow timing (paper §3.4) — the layer
//! timing the coordinator uses when a layer runs inside a partition.
//!
//! The paper partitions the array along **columns only**: a partition is a
//! contiguous vertical slice `[col0, col0 + width)` spanning every row.
//! This module generalizes that to rectangular **2D fission**
//! (Planaria-style): a [`Tile`] owns rows `[row0, row0 + rows)` ×
//! columns `[col0, col0 + cols)` and behaves as an independent
//! `rows × cols` sub-accelerator except for the partitioned-dataflow
//! effects:
//!
//! - **feed traversal skew** — feed data passes through `col0` foreign
//!   columns (Mul_En low) before reaching the tile (+`col0` cycles/fold);
//! - **load-chain skew** — weights ripple down the column shift chain
//!   through `row0` foreign rows before reaching the tile's band
//!   (+`row0` cycles/fold on the load step);
//! - **fold count** — a `[Sr,K]×[K,M]` GEMM takes `FK = ⌈K/rows⌉ ×
//!   FM = ⌈M/cols⌉` folds, so 2D fission trades fold count against
//!   width/height (see `docs/fission.md`);
//! - **feed-bus policy** — [`FeedPolicy::Independent`] gives every
//!   partition a private feed stream (the paper's model; partitions are
//!   fully concurrent).  [`FeedPolicy::Interleaved`] time-slices the
//!   physical row wires among co-resident tenants, multiplying stream time
//!   by the tenant count (the conservative physical model; see
//!   `sim::array` for its register-level derivation).  The ablation bench
//!   `ablation_feedbus` quantifies the gap, and `docs/feed-models.md` is
//!   the canonical discussion of when each model is the right one.
//!
//! [`PartitionSlice`] is kept as the full-height special case: a
//! `PartitionSlice { col0, width }` is exactly `Tile { row0: 0, col0,
//! rows: H, cols: width }` (see [`PartitionSlice::tile`]), and
//! [`slice_layer_timing`] prices it bit-identically to the pre-2D model.

use super::buffers::BufferConfig;
use super::dataflow::{layer_timing_tile, ArrayGeometry, LayerTiming};
use crate::workloads::shapes::GemmDims;

/// A rectangular tile of the array: rows `[row0, row0 + rows)` ×
/// columns `[col0, col0 + cols)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Tile {
    pub row0: u64,
    pub col0: u64,
    pub rows: u64,
    pub cols: u64,
}

impl Tile {
    pub fn new(row0: u64, col0: u64, rows: u64, cols: u64) -> Tile {
        assert!(rows > 0 && cols > 0);
        Tile { row0, col0, rows, cols }
    }

    /// The whole array as one tile.
    pub fn full(geom: ArrayGeometry) -> Tile {
        Tile { row0: 0, col0: 0, rows: geom.rows, cols: geom.cols }
    }

    /// The full-height tile of a vertical column slice — the paper's
    /// partition shape, and what every `columns`-mode policy allocates.
    pub fn full_height(geom: ArrayGeometry, col0: u64, width: u64) -> Tile {
        Tile::new(0, col0, geom.rows, width)
    }

    pub fn row_end(&self) -> u64 {
        self.row0 + self.rows
    }

    pub fn col_end(&self) -> u64 {
        self.col0 + self.cols
    }

    /// PEs this tile owns.
    pub fn pes(&self) -> u64 {
        self.rows * self.cols
    }

    /// True when the tile spans every row (a column slice).
    pub fn is_full_height(&self, geom: ArrayGeometry) -> bool {
        self.row0 == 0 && self.rows == geom.rows
    }

    /// True when `inner` lies entirely inside this tile.
    pub fn contains(&self, inner: &Tile) -> bool {
        self.row0 <= inner.row0
            && inner.row_end() <= self.row_end()
            && self.col0 <= inner.col0
            && inner.col_end() <= self.col_end()
    }

    /// True when the two tiles share at least one PE.
    pub fn overlaps(&self, other: &Tile) -> bool {
        self.row0 < other.row_end()
            && other.row0 < self.row_end()
            && self.col0 < other.col_end()
            && other.col0 < self.col_end()
    }

    /// True when the two tiles' row bands intersect (they share feed
    /// wires even if their columns are disjoint).
    pub fn overlaps_rows(&self, other: &Tile) -> bool {
        self.row0 < other.row_end() && other.row0 < self.row_end()
    }

    /// The union of two tiles when they share a full edge (same row band
    /// and adjacent columns, or same column band and adjacent rows);
    /// `None` when the union would not be a rectangle.
    pub fn merged_with(&self, other: &Tile) -> Option<Tile> {
        if self.row0 == other.row0
            && self.rows == other.rows
            && (self.col_end() == other.col0 || other.col_end() == self.col0)
        {
            return Some(Tile::new(
                self.row0,
                self.col0.min(other.col0),
                self.rows,
                self.cols + other.cols,
            ));
        }
        if self.col0 == other.col0
            && self.cols == other.cols
            && (self.row_end() == other.row0 || other.row_end() == self.row0)
        {
            return Some(Tile::new(
                self.row0.min(other.row0),
                self.col0,
                self.rows + other.rows,
                self.cols,
            ));
        }
        None
    }
}

/// A vertical (full-height) partition of the array — the paper's shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PartitionSlice {
    pub col0: u64,
    pub width: u64,
}

impl PartitionSlice {
    pub fn new(col0: u64, width: u64) -> PartitionSlice {
        assert!(width > 0);
        PartitionSlice { col0, width }
    }

    /// Full-array slice.
    pub fn full(geom: ArrayGeometry) -> PartitionSlice {
        PartitionSlice { col0: 0, width: geom.cols }
    }

    pub fn end(&self) -> u64 {
        self.col0 + self.width
    }

    /// The full-height [`Tile`] this slice denotes on `geom`.
    pub fn tile(self, geom: ArrayGeometry) -> Tile {
        Tile::full_height(geom, self.col0, self.width)
    }

    /// True if `other` is immediately adjacent (mergeable).
    pub fn adjacent(&self, other: &PartitionSlice) -> bool {
        self.end() == other.col0 || other.end() == self.col0
    }

    /// Merge with an adjacent slice.
    pub fn merge(&self, other: &PartitionSlice) -> PartitionSlice {
        assert!(self.adjacent(other), "merging non-adjacent slices");
        PartitionSlice { col0: self.col0.min(other.col0), width: self.width + other.width }
    }
}

/// A contiguous group of vector lanes `[lane0, lane0 + lanes)` — the 1D
/// partition shape of the second resource pool
/// ([`LaneManager`](crate::coordinator::partition::LaneManager)).  Kept a
/// distinct type from [`PartitionSlice`] so lane spans and array column
/// slices can never be confused at a call site.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct LaneSpan {
    pub lane0: u64,
    pub lanes: u64,
}

impl LaneSpan {
    pub fn new(lane0: u64, lanes: u64) -> LaneSpan {
        assert!(lanes > 0);
        LaneSpan { lane0, lanes }
    }

    pub fn end(&self) -> u64 {
        self.lane0 + self.lanes
    }

    /// The degenerate 1-row [`Tile`] this span occupies on the lane
    /// pool's internal geometry — how the lane allocator stores it, and
    /// the tile recorded on lane dispatches.
    pub fn as_tile(&self) -> Tile {
        Tile::new(0, self.lane0, 1, self.lanes)
    }

    /// The span a 1-row allocator tile denotes.
    pub fn from_tile(tile: Tile) -> LaneSpan {
        assert!(tile.row0 == 0 && tile.rows == 1, "lane tile must be 1 row high: {tile:?}");
        LaneSpan { lane0: tile.col0, lanes: tile.cols }
    }
}

/// Feed-bus sharing model for co-resident partitions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeedPolicy {
    /// Private feed stream per partition — the paper's model (default).
    Independent,
    /// Row wires time-sliced among `coresident` tenants; `slot` is this
    /// partition's position in the round-robin.
    Interleaved { coresident: u64, slot: u64 },
}

impl Default for FeedPolicy {
    fn default() -> Self {
        FeedPolicy::Independent
    }
}

/// Time one layer on a rectangular tile under the given feed policy.
pub fn tile_layer_timing(
    geom: ArrayGeometry,
    gemm: GemmDims,
    tile: Tile,
    policy: FeedPolicy,
    bufs: &BufferConfig,
) -> LayerTiming {
    let interleave = match policy {
        FeedPolicy::Independent => None,
        FeedPolicy::Interleaved { coresident, slot } => {
            assert!(coresident >= 1 && slot < coresident);
            Some((coresident, slot))
        }
    };
    layer_timing_tile(geom, gemm, tile, bufs, interleave)
}

/// Time one layer on a full-height partition slice — the paper's model,
/// bit-identical to pricing the corresponding [`Tile`].
pub fn slice_layer_timing(
    geom: ArrayGeometry,
    gemm: GemmDims,
    slice: PartitionSlice,
    policy: FeedPolicy,
    bufs: &BufferConfig,
) -> LayerTiming {
    tile_layer_timing(geom, gemm, slice.tile(geom), policy, bufs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    const GEOM: ArrayGeometry = ArrayGeometry { rows: 128, cols: 128 };

    fn bufs() -> BufferConfig {
        BufferConfig::default()
    }

    #[test]
    fn slice_merge_algebra() {
        let a = PartitionSlice::new(0, 32);
        let b = PartitionSlice::new(32, 32);
        let c = PartitionSlice::new(96, 32);
        assert!(a.adjacent(&b));
        assert!(b.adjacent(&a));
        assert!(!a.adjacent(&c));
        let m = a.merge(&b);
        assert_eq!(m, PartitionSlice::new(0, 64));
        assert_eq!(b.merge(&a), m);
    }

    #[test]
    #[should_panic(expected = "non-adjacent")]
    fn merge_rejects_gap() {
        PartitionSlice::new(0, 16).merge(&PartitionSlice::new(32, 16));
    }

    #[test]
    fn tile_geometry_helpers() {
        let t = Tile::new(32, 64, 16, 8);
        assert_eq!(t.row_end(), 48);
        assert_eq!(t.col_end(), 72);
        assert_eq!(t.pes(), 128);
        assert!(!t.is_full_height(GEOM));
        assert!(Tile::full(GEOM).is_full_height(GEOM));
        assert_eq!(PartitionSlice::new(64, 8).tile(GEOM), Tile::new(0, 64, 128, 8));
        assert!(Tile::full(GEOM).contains(&t));
        assert!(!t.contains(&Tile::full(GEOM)));
        assert!(t.overlaps(&Tile::new(40, 70, 20, 20)));
        assert!(!t.overlaps(&Tile::new(48, 64, 16, 8)), "edge-adjacent is not overlap");
        assert!(t.overlaps_rows(&Tile::new(40, 0, 8, 4)));
        assert!(!t.overlaps_rows(&Tile::new(48, 64, 8, 8)));
    }

    #[test]
    fn lane_span_tile_round_trip() {
        let s = LaneSpan::new(64, 32);
        assert_eq!(s.end(), 96);
        assert_eq!(s.as_tile(), Tile::new(0, 64, 1, 32));
        assert_eq!(LaneSpan::from_tile(s.as_tile()), s);
    }

    #[test]
    #[should_panic(expected = "1 row high")]
    fn lane_span_rejects_tall_tile() {
        let _ = LaneSpan::from_tile(Tile::new(0, 0, 2, 8));
    }

    #[test]
    fn tile_merge_algebra() {
        let a = Tile::new(0, 0, 64, 32);
        let b = Tile::new(0, 32, 64, 32);
        let c = Tile::new(64, 0, 64, 32);
        let d = Tile::new(64, 32, 64, 32);
        // Horizontal merge: same row band, adjacent columns.
        assert_eq!(a.merged_with(&b), Some(Tile::new(0, 0, 64, 64)));
        assert_eq!(b.merged_with(&a), Some(Tile::new(0, 0, 64, 64)));
        // Vertical merge: same column band, adjacent rows.
        assert_eq!(a.merged_with(&c), Some(Tile::new(0, 0, 128, 32)));
        // Diagonal neighbours do not merge into a rectangle.
        assert_eq!(a.merged_with(&d), None);
        // Adjacent but mismatched band: no merge.
        assert_eq!(a.merged_with(&Tile::new(0, 32, 32, 32)), None);
        assert_eq!(a.merged_with(&Tile::new(64, 0, 64, 16)), None);
    }

    #[test]
    fn independent_equals_full_array_when_whole() {
        let g = GemmDims { sr: 3025, k: 363, m: 96 };
        let full = slice_layer_timing(GEOM, g, PartitionSlice::full(GEOM), FeedPolicy::Independent, &bufs());
        let direct = super::super::dataflow::baseline_layer_timing(GEOM, g, &bufs());
        assert_eq!(full, direct);
    }

    #[test]
    fn full_height_tile_prices_like_its_slice() {
        // The parity rail of the 2D generalization: every column slice and
        // its Tile form are the same timing, bit for bit, under both feed
        // policies.
        prop::check("tile == slice when full height", 100, |rng| {
            let g = GemmDims {
                sr: rng.gen_range_inclusive(1, 5000),
                k: rng.gen_range_inclusive(1, 1024),
                m: rng.gen_range_inclusive(1, 1024),
            };
            let width = *rng.choose(&[8u64, 16, 32, 64, 128]);
            let col0 = rng.gen_range_inclusive(0, 128 - width);
            let slice = PartitionSlice::new(col0, width);
            let policy = if rng.gen_bool(0.5) {
                FeedPolicy::Independent
            } else {
                let p = rng.gen_range_inclusive(2, 8);
                FeedPolicy::Interleaved { coresident: p, slot: rng.gen_range(p) }
            };
            let a = slice_layer_timing(GEOM, g, slice, policy, &bufs());
            let b = tile_layer_timing(GEOM, g, slice.tile(GEOM), policy, &bufs());
            prop::ensure_eq(a, b, "slice vs tile")
        });
    }

    #[test]
    fn row_offset_adds_load_chain_skew() {
        // Two identical tiles, one at the top and one 32 rows down: the
        // lower tile pays +row0 load cycles per fold, nothing else.
        let g = GemmDims { sr: 100, k: 32, m: 32 };
        let top = tile_layer_timing(GEOM, g, Tile::new(0, 0, 32, 32), FeedPolicy::Independent, &bufs());
        let low = tile_layer_timing(GEOM, g, Tile::new(32, 0, 32, 32), FeedPolicy::Independent, &bufs());
        assert_eq!((top.fk, top.fm), (1, 1));
        assert_eq!(low.cycles - top.cycles, 32);
        assert_eq!(low.activity, top.activity);
    }

    #[test]
    fn shorter_tile_multiplies_k_folds() {
        // Halving the tile height doubles FK for a K-deep layer; the
        // cycles grow accordingly (fold overheads are paid FK x FM times).
        let g = GemmDims { sr: 500, k: 128, m: 32 };
        let full = tile_layer_timing(GEOM, g, Tile::new(0, 0, 128, 32), FeedPolicy::Independent, &bufs());
        let half = tile_layer_timing(GEOM, g, Tile::new(0, 0, 64, 32), FeedPolicy::Independent, &bufs());
        assert_eq!(full.fk, 1);
        assert_eq!(half.fk, 2);
        assert!(half.cycles > full.cycles);
    }

    #[test]
    fn shallow_layer_wastes_nothing_on_short_tile() {
        // A layer with k = 32 runs in the same cycles on a 32-row tile
        // (at row0 = 0) as on the full height — the core 2D-fission
        // utilization argument, dual to the narrow-M case below.
        let g = GemmDims { sr: 500, k: 32, m: 64 };
        let full = tile_layer_timing(GEOM, g, Tile::new(0, 0, 128, 64), FeedPolicy::Independent, &bufs());
        let short = tile_layer_timing(GEOM, g, Tile::new(0, 0, 32, 64), FeedPolicy::Independent, &bufs());
        assert_eq!(full.cycles, short.cycles);
        // And utilization of the tile is 4x better.
        let u_full = full.utilization(128 * 64);
        let u_short = short.utilization(32 * 64);
        assert!((u_short / u_full - 4.0).abs() < 1e-9);
    }

    #[test]
    fn interleaved_never_faster_than_independent() {
        prop::check("interleaved >= independent", 100, |rng| {
            let g = GemmDims {
                sr: rng.gen_range_inclusive(1, 5000),
                k: rng.gen_range_inclusive(1, 1024),
                m: rng.gen_range_inclusive(1, 1024),
            };
            let width = *rng.choose(&[16u64, 32, 64, 128]);
            let col0 = rng.gen_range_inclusive(0, (128 - width) / 16) * 16;
            let slice = PartitionSlice::new(col0, width);
            let p = rng.gen_range_inclusive(2, 8);
            let slot = rng.gen_range(p);
            let ind = slice_layer_timing(GEOM, g, slice, FeedPolicy::Independent, &bufs());
            let il = slice_layer_timing(
                GEOM,
                g,
                slice,
                FeedPolicy::Interleaved { coresident: p, slot },
                &bufs(),
            );
            prop::ensure(il.cycles >= ind.cycles, "interleaved slower-or-equal")?;
            prop::ensure_eq(il.activity, ind.activity, "activity identical")
        });
    }

    #[test]
    fn narrower_partitions_monotone_slower() {
        // For a fixed layer, cycles must not decrease as width shrinks.
        let g = GemmDims { sr: 784, k: 1152, m: 256 };
        let mut last = 0u64;
        for width in [128u64, 64, 32, 16, 8] {
            let t = slice_layer_timing(GEOM, g, PartitionSlice::new(0, width), FeedPolicy::Independent, &bufs());
            assert!(t.cycles >= last, "width {width}: {} < {last}", t.cycles);
            last = t.cycles;
        }
    }

    #[test]
    fn narrow_layer_wastes_nothing_on_narrow_partition() {
        // A layer with m = 16 runs in the same cycles on a 16-wide
        // partition (at col0 = 0) as on the full array — the core
        // utilization argument of the paper.
        let g = GemmDims { sr: 500, k: 128, m: 16 };
        let full = slice_layer_timing(GEOM, g, PartitionSlice::full(GEOM), FeedPolicy::Independent, &bufs());
        let narrow = slice_layer_timing(GEOM, g, PartitionSlice::new(0, 16), FeedPolicy::Independent, &bufs());
        assert_eq!(full.cycles, narrow.cycles);
        // And utilization of the slice is 8x better.
        let u_full = full.utilization(GEOM.pes());
        let u_narrow = narrow.utilization(128 * 16);
        assert!((u_narrow / u_full - 8.0).abs() < 1e-9);
    }

    #[test]
    fn activity_independent_of_offset() {
        let g = GemmDims { sr: 100, k: 64, m: 32 };
        let a = slice_layer_timing(GEOM, g, PartitionSlice::new(0, 32), FeedPolicy::Independent, &bufs());
        let b = slice_layer_timing(GEOM, g, PartitionSlice::new(96, 32), FeedPolicy::Independent, &bufs());
        assert_eq!(a.activity, b.activity);
        assert!(b.cycles > a.cycles);
    }
}
