//! Partitioned weight-stationary dataflow timing (paper §3.4) — the layer
//! timing the coordinator uses when a layer runs inside a vertical
//! partition.
//!
//! A partition is a contiguous column slice `[col0, col0 + width)`.  It
//! behaves as an independent `H × width` sub-accelerator except for the
//! partitioned-dataflow effects:
//!
//! - **traversal skew** — feed data passes through `col0` foreign columns
//!   (Mul_En low) before reaching the partition (+`col0` cycles/fold);
//! - **feed-bus policy** — [`FeedPolicy::Independent`] gives every
//!   partition a private feed stream (the paper's model; partitions are
//!   fully concurrent).  [`FeedPolicy::Interleaved`] time-slices the
//!   physical row wires among co-resident tenants, multiplying stream time
//!   by the tenant count (the conservative physical model; see
//!   `sim::array` for its register-level derivation).  The ablation bench
//!   `ablation_feedbus` quantifies the gap, and `docs/feed-models.md` is
//!   the canonical discussion of when each model is the right one.

use super::buffers::BufferConfig;
use super::dataflow::{layer_timing_at, ArrayGeometry, LayerTiming};
use crate::workloads::shapes::GemmDims;

/// A vertical partition of the array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PartitionSlice {
    pub col0: u64,
    pub width: u64,
}

impl PartitionSlice {
    pub fn new(col0: u64, width: u64) -> PartitionSlice {
        assert!(width > 0);
        PartitionSlice { col0, width }
    }

    /// Full-array slice.
    pub fn full(geom: ArrayGeometry) -> PartitionSlice {
        PartitionSlice { col0: 0, width: geom.cols }
    }

    pub fn end(&self) -> u64 {
        self.col0 + self.width
    }

    /// True if `other` is immediately adjacent (mergeable).
    pub fn adjacent(&self, other: &PartitionSlice) -> bool {
        self.end() == other.col0 || other.end() == self.col0
    }

    /// Merge with an adjacent slice.
    pub fn merge(&self, other: &PartitionSlice) -> PartitionSlice {
        assert!(self.adjacent(other), "merging non-adjacent slices");
        PartitionSlice { col0: self.col0.min(other.col0), width: self.width + other.width }
    }
}

/// Feed-bus sharing model for co-resident partitions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeedPolicy {
    /// Private feed stream per partition — the paper's model (default).
    Independent,
    /// Row wires time-sliced among `coresident` tenants; `slot` is this
    /// partition's position in the round-robin.
    Interleaved { coresident: u64, slot: u64 },
}

impl Default for FeedPolicy {
    fn default() -> Self {
        FeedPolicy::Independent
    }
}

/// Time one layer on a partition slice under the given feed policy.
pub fn slice_layer_timing(
    geom: ArrayGeometry,
    gemm: GemmDims,
    slice: PartitionSlice,
    policy: FeedPolicy,
    bufs: &BufferConfig,
) -> LayerTiming {
    let interleave = match policy {
        FeedPolicy::Independent => None,
        FeedPolicy::Interleaved { coresident, slot } => {
            assert!(coresident >= 1 && slot < coresident);
            Some((coresident, slot))
        }
    };
    layer_timing_at(geom, gemm, slice.col0, slice.width, bufs, interleave)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    const GEOM: ArrayGeometry = ArrayGeometry { rows: 128, cols: 128 };

    fn bufs() -> BufferConfig {
        BufferConfig::default()
    }

    #[test]
    fn slice_merge_algebra() {
        let a = PartitionSlice::new(0, 32);
        let b = PartitionSlice::new(32, 32);
        let c = PartitionSlice::new(96, 32);
        assert!(a.adjacent(&b));
        assert!(b.adjacent(&a));
        assert!(!a.adjacent(&c));
        let m = a.merge(&b);
        assert_eq!(m, PartitionSlice::new(0, 64));
        assert_eq!(b.merge(&a), m);
    }

    #[test]
    #[should_panic(expected = "non-adjacent")]
    fn merge_rejects_gap() {
        PartitionSlice::new(0, 16).merge(&PartitionSlice::new(32, 16));
    }

    #[test]
    fn independent_equals_full_array_when_whole() {
        let g = GemmDims { sr: 3025, k: 363, m: 96 };
        let full = slice_layer_timing(GEOM, g, PartitionSlice::full(GEOM), FeedPolicy::Independent, &bufs());
        let direct = super::super::dataflow::baseline_layer_timing(GEOM, g, &bufs());
        assert_eq!(full, direct);
    }

    #[test]
    fn interleaved_never_faster_than_independent() {
        prop::check("interleaved >= independent", 100, |rng| {
            let g = GemmDims {
                sr: rng.gen_range_inclusive(1, 5000),
                k: rng.gen_range_inclusive(1, 1024),
                m: rng.gen_range_inclusive(1, 1024),
            };
            let width = *rng.choose(&[16u64, 32, 64, 128]);
            let col0 = rng.gen_range_inclusive(0, (128 - width) / 16) * 16;
            let slice = PartitionSlice::new(col0, width);
            let p = rng.gen_range_inclusive(2, 8);
            let slot = rng.gen_range(p);
            let ind = slice_layer_timing(GEOM, g, slice, FeedPolicy::Independent, &bufs());
            let il = slice_layer_timing(
                GEOM,
                g,
                slice,
                FeedPolicy::Interleaved { coresident: p, slot },
                &bufs(),
            );
            prop::ensure(il.cycles >= ind.cycles, "interleaved slower-or-equal")?;
            prop::ensure_eq(il.activity, ind.activity, "activity identical")
        });
    }

    #[test]
    fn narrower_partitions_monotone_slower() {
        // For a fixed layer, cycles must not decrease as width shrinks.
        let g = GemmDims { sr: 784, k: 1152, m: 256 };
        let mut last = 0u64;
        for width in [128u64, 64, 32, 16, 8] {
            let t = slice_layer_timing(GEOM, g, PartitionSlice::new(0, width), FeedPolicy::Independent, &bufs());
            assert!(t.cycles >= last, "width {width}: {} < {last}", t.cycles);
            last = t.cycles;
        }
    }

    #[test]
    fn narrow_layer_wastes_nothing_on_narrow_partition() {
        // A layer with m = 16 runs in the same cycles on a 16-wide
        // partition (at col0 = 0) as on the full array — the core
        // utilization argument of the paper.
        let g = GemmDims { sr: 500, k: 128, m: 16 };
        let full = slice_layer_timing(GEOM, g, PartitionSlice::full(GEOM), FeedPolicy::Independent, &bufs());
        let narrow = slice_layer_timing(GEOM, g, PartitionSlice::new(0, 16), FeedPolicy::Independent, &bufs());
        assert_eq!(full.cycles, narrow.cycles);
        // And utilization of the slice is 8x better.
        let u_full = full.utilization(GEOM.pes());
        let u_narrow = narrow.utilization(128 * 16);
        assert!((u_narrow / u_full - 8.0).abs() < 1e-9);
    }

    #[test]
    fn activity_independent_of_offset() {
        let g = GemmDims { sr: 100, k: 64, m: 32 };
        let a = slice_layer_timing(GEOM, g, PartitionSlice::new(0, 32), FeedPolicy::Independent, &bufs());
        let b = slice_layer_timing(GEOM, g, PartitionSlice::new(96, 32), FeedPolicy::Independent, &bufs());
        assert_eq!(a.activity, b.activity);
        assert!(b.cycles > a.cycles);
    }
}
