//! On-chip SRAM buffer model — the *load* (weight), *feed* (IFMap) and
//! *drain* (OFMap) buffers of Fig. 3.
//!
//! Capacity determines DRAM refetch behaviour: a layer whose IFMap fits in
//! the feed-buffer share streams it from DRAM once and re-reads it from
//! SRAM on every column fold; otherwise every column fold re-fetches from
//! DRAM.  Under partitioning, each partition owns a proportional share of
//! every buffer (the paper allocates "parts of each storage element" with
//! the PEs).

/// Buffer sizing (per the whole array), TPU-like defaults.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BufferConfig {
    /// Load (weight) buffer bytes.
    pub weight_bytes: u64,
    /// Feed (IFMap) buffer bytes.
    pub ifmap_bytes: u64,
    /// Drain (OFMap) buffer bytes.
    pub ofmap_bytes: u64,
    /// Element width in bytes (int8 = 1, bf16 = 2, f32 = 4).
    pub dtype_bytes: u64,
}

impl Default for BufferConfig {
    fn default() -> Self {
        // TPUv3-ish SRAM split scaled to a single 128x128 core: 24 MiB
        // unified on-chip storage, split 1/2 feed, 1/4 weights, 1/4 drain.
        BufferConfig {
            weight_bytes: 6 << 20,
            ifmap_bytes: 12 << 20,
            ofmap_bytes: 6 << 20,
            dtype_bytes: 1, // int8 inference, as the paper's 45nm design point
        }
    }
}

impl BufferConfig {
    /// The buffer share of a partition covering `width` of `total_cols`
    /// columns (proportional allocation, min one dtype word).
    pub fn share(&self, width: u64, total_cols: u64) -> BufferConfig {
        assert!(width > 0 && width <= total_cols);
        let scale = |b: u64| (b * width / total_cols).max(self.dtype_bytes);
        BufferConfig {
            weight_bytes: scale(self.weight_bytes),
            ifmap_bytes: scale(self.ifmap_bytes),
            ofmap_bytes: scale(self.ofmap_bytes),
            dtype_bytes: self.dtype_bytes,
        }
    }

    /// How many DRAM passes the IFMap needs given `fm` column folds:
    /// 1 if the whole streamed IFMap (`sr·k` words) fits the feed share,
    /// else one pass per fold.
    pub fn ifmap_dram_passes(&self, sr: u64, k: u64, fm: u64) -> u64 {
        if sr.saturating_mul(k).saturating_mul(self.dtype_bytes) <= self.ifmap_bytes {
            1
        } else {
            fm
        }
    }

    /// Whether the layer's full weight tile (`k·m` words) fits the load
    /// share (it is streamed once either way — weights are single-use in
    /// WS — but a miss forces fold-grained fills, adding fill *events*).
    pub fn weight_fits(&self, k: u64, m: u64) -> bool {
        k.saturating_mul(m).saturating_mul(self.dtype_bytes) <= self.weight_bytes
    }

    /// Whether an OFMap partial-sum working set (`sr·m` words, f32 partials
    /// = 4x dtype for int8) fits the drain share; a miss spills partials to
    /// DRAM on every K-fold.
    pub fn ofmap_fits(&self, sr: u64, m: u64) -> bool {
        let partial_bytes = self.dtype_bytes.max(4);
        sr.saturating_mul(m).saturating_mul(partial_bytes) <= self.ofmap_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn share_is_proportional() {
        let b = BufferConfig { weight_bytes: 1000, ifmap_bytes: 2000, ofmap_bytes: 4000, dtype_bytes: 1 };
        let s = b.share(32, 128);
        assert_eq!(s.weight_bytes, 250);
        assert_eq!(s.ifmap_bytes, 500);
        assert_eq!(s.ofmap_bytes, 1000);
        let full = b.share(128, 128);
        assert_eq!(full, b);
    }

    #[test]
    fn share_never_zero() {
        let b = BufferConfig { weight_bytes: 10, ifmap_bytes: 10, ofmap_bytes: 10, dtype_bytes: 4 };
        let s = b.share(1, 128);
        assert!(s.weight_bytes >= 4);
    }

    #[test]
    fn ifmap_passes() {
        let b = BufferConfig { ifmap_bytes: 100, dtype_bytes: 1, ..Default::default() };
        assert_eq!(b.ifmap_dram_passes(10, 5, 7), 1); // 50 <= 100
        assert_eq!(b.ifmap_dram_passes(30, 5, 7), 7); // 150 > 100
    }

    #[test]
    fn fits_checks() {
        let b = BufferConfig { weight_bytes: 64, ofmap_bytes: 64, dtype_bytes: 1, ..Default::default() };
        assert!(b.weight_fits(8, 8));
        assert!(!b.weight_fits(9, 8));
        // f32 partials: 4 bytes each regardless of int8 dtype.
        assert!(b.ofmap_fits(4, 4));
        assert!(!b.ofmap_fits(5, 4));
    }
}
