//! Off-chip DRAM traffic and bandwidth-stall model.
//!
//! The analytic timing in [`super::dataflow`] assumes SRAM-fed folds; when
//! the DRAM traffic a layer generates exceeds what the interface can
//! deliver within the layer's compute cycles, the layer is memory-bound
//! and stalls for the difference.  This mirrors Scale-Sim's bandwidth mode
//! (`interface_bandwidth`), folded into a post-pass.

use super::activity::Activity;

/// DRAM interface model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DramConfig {
    /// Words (elements) transferable per array cycle, aggregate R+W.
    pub words_per_cycle: f64,
    /// Fixed per-burst latency charged once per layer (cycles).
    pub burst_latency: u64,
}

impl Default for DramConfig {
    fn default() -> Self {
        // ~700 MHz array clock vs HBM-class interface feeding one core:
        // 64 words/cycle aggregate for int8.
        DramConfig { words_per_cycle: 64.0, burst_latency: 100 }
    }
}

impl DramConfig {
    /// Cycles needed to move a layer's DRAM traffic.  A layer that
    /// touches DRAM not at all costs nothing — in particular no
    /// `burst_latency`, which is a per-burst setup cost and a layer with
    /// zero traffic issues zero bursts.
    pub fn transfer_cycles(&self, activity: &Activity) -> u64 {
        let words = activity.dram_accesses();
        if words == 0 {
            return 0;
        }
        (words as f64 / self.words_per_cycle).ceil() as u64 + self.burst_latency
    }

    /// Effective layer cycles: compute overlapped with (double-buffered)
    /// DRAM transfer — the slower of the two paths dominates.
    pub fn bound_cycles(&self, compute_cycles: u64, activity: &Activity) -> u64 {
        compute_cycles.max(self.transfer_cycles(activity))
    }

    /// True when the layer is memory-bound under this interface.
    pub fn memory_bound(&self, compute_cycles: u64, activity: &Activity) -> bool {
        self.transfer_cycles(activity) > compute_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn act(reads: u64, writes: u64) -> Activity {
        Activity { dram_reads: reads, dram_writes: writes, ..Default::default() }
    }

    #[test]
    fn transfer_cycles_scale_with_traffic() {
        let d = DramConfig { words_per_cycle: 10.0, burst_latency: 5 };
        assert_eq!(d.transfer_cycles(&act(100, 0)), 15);
        assert_eq!(d.transfer_cycles(&act(95, 6)), 16); // ceil(101/10)+5
    }

    #[test]
    fn zero_traffic_layer_costs_no_transfer_cycles() {
        // Regression: burst latency is per burst, and zero traffic issues
        // zero bursts — an SRAM-resident layer must not stall on DRAM.
        let d = DramConfig { words_per_cycle: 10.0, burst_latency: 100 };
        let a = act(0, 0);
        assert_eq!(d.transfer_cycles(&a), 0);
        assert_eq!(d.bound_cycles(5000, &a), 5000);
        assert!(!d.memory_bound(5000, &a));
        // One word still pays the burst setup.
        assert_eq!(d.transfer_cycles(&act(1, 0)), 101);
    }

    #[test]
    fn compute_bound_layer_unaffected() {
        let d = DramConfig { words_per_cycle: 100.0, burst_latency: 0 };
        let a = act(1000, 0);
        assert_eq!(d.bound_cycles(5000, &a), 5000);
        assert!(!d.memory_bound(5000, &a));
    }

    #[test]
    fn memory_bound_layer_stalls() {
        let d = DramConfig { words_per_cycle: 1.0, burst_latency: 0 };
        let a = act(10_000, 0);
        assert_eq!(d.bound_cycles(5000, &a), 10_000);
        assert!(d.memory_bound(5000, &a));
    }
}
