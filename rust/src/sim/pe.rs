//! The processing element — Fig. 3 (baseline) and Fig. 7 (proposed).
//!
//! Each PE holds a load register (LR) and a MAC unit.  Two control ports:
//!
//! - `load` — Load mode (`load=1`): the Y-dimension inter-PE wire carries
//!   weight values downward into the LRs (weights and partial sums share
//!   the vertical wire, which is why load and calculate are separate
//!   steps).  Calculate mode (`load=0`): the same wire carries partial
//!   sums downward.
//! - `mul_en` — the paper's added tri-state gate between multiplier and
//!   adder.  When 0, the multiplier is disconnected: the PE passes the feed
//!   value right and the partial sum down *unchanged*, which is what lets
//!   foreign tenants' feed data traverse a partition without corrupting it.

/// Inputs sampled by a PE in one cycle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PeInputs {
    /// Feed data arriving from the left neighbour (X dimension).
    pub fd: f32,
    /// Reused data arriving from above (Y dimension): weight in Load mode,
    /// partial sum in Calculate mode.
    pub rd: f32,
    /// Control: Load (true) vs Calculate (false).
    pub load: bool,
    /// Control: multiplier enable (the Fig. 7 tri-state gate).
    pub mul_en: bool,
}

/// Outputs driven by a PE at the end of a cycle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PeOutputs {
    /// Feed data forwarded to the right neighbour.
    pub fd_out: f32,
    /// Generated data to the neighbour below: forwarded weight in Load
    /// mode, partial sum in Calculate mode.
    pub gd: f32,
}

/// One processing element (registers survive across cycles).
#[derive(Debug, Clone, Default)]
pub struct Pe {
    /// Load register (the stationary weight).
    lr: f32,
    /// Feed-forward register (X pipeline).
    fd_reg: f32,
    /// Vertical-output register (Y pipeline: weight passthrough or psum).
    gd_reg: f32,
}

impl Pe {
    pub fn new() -> Pe {
        Pe::default()
    }

    /// The stationary value currently held.
    pub fn weight(&self) -> f32 {
        self.lr
    }

    /// Advance one cycle: sample `inputs`, update registers, drive outputs.
    ///
    /// Load mode: `rd` shifts into the LR and the *previous* LR content is
    /// forwarded down (a shift-register column, so `h` cycles load `h`
    /// rows).  Calculate mode: `gd = rd + fd·lr` when `mul_en`, else the
    /// partial sum passes through untouched (`gd = rd`) — the tri-state
    /// gate disconnects the multiplier, it does not zero the wire.
    pub fn step(&mut self, inputs: PeInputs) -> PeOutputs {
        let out = PeOutputs { fd_out: self.fd_reg, gd: self.gd_reg };
        if inputs.load {
            // Weight shift: new value in, old value forwarded down next cycle.
            self.gd_reg = self.lr;
            self.lr = inputs.rd;
            self.fd_reg = inputs.fd; // feed pipeline still advances
        } else {
            self.fd_reg = inputs.fd;
            self.gd_reg = if inputs.mul_en { inputs.rd + inputs.fd * self.lr } else { inputs.rd };
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn calc(fd: f32, rd: f32, mul_en: bool) -> PeInputs {
        PeInputs { fd, rd, load: false, mul_en }
    }

    #[test]
    fn load_mode_shifts_weights_down() {
        let mut pe = Pe::new();
        // Load 3.0 then 5.0: LR ends with 5.0, and 3.0 is forwarded down.
        pe.step(PeInputs { fd: 0.0, rd: 3.0, load: true, mul_en: false });
        assert_eq!(pe.weight(), 3.0);
        pe.step(PeInputs { fd: 0.0, rd: 5.0, load: true, mul_en: false });
        assert_eq!(pe.weight(), 5.0);
        // The gd register now carries the displaced 3.0 (visible next step).
        let out = pe.step(calc(0.0, 0.0, false));
        assert_eq!(out.gd, 3.0);
    }

    #[test]
    fn calculate_mode_macs_when_enabled() {
        let mut pe = Pe::new();
        pe.step(PeInputs { fd: 0.0, rd: 2.0, load: true, mul_en: false }); // LR = 2
        pe.step(calc(3.0, 10.0, true)); // gd_reg = 10 + 3*2 = 16
        let out = pe.step(calc(0.0, 0.0, true));
        assert_eq!(out.gd, 16.0);
    }

    #[test]
    fn mul_en_low_passes_psum_through_unchanged() {
        // The Fig. 7 property: with Mul_En=0 the partial sum is NOT zeroed,
        // it flows through while the foreign feed value is ignored.
        let mut pe = Pe::new();
        pe.step(PeInputs { fd: 0.0, rd: 7.0, load: true, mul_en: false }); // LR = 7
        pe.step(calc(100.0, 42.0, false)); // foreign data: gd_reg = 42 untouched
        let out = pe.step(calc(0.0, 0.0, false));
        assert_eq!(out.gd, 42.0);
    }

    #[test]
    fn feed_data_always_propagates_right() {
        // Feed forwards regardless of mul_en — foreign partitions see the
        // data pass through (one cycle of X-pipeline latency).
        let mut pe = Pe::new();
        pe.step(calc(9.0, 0.0, false));
        let out = pe.step(calc(1.0, 0.0, false));
        assert_eq!(out.fd_out, 9.0);
        let out = pe.step(calc(0.0, 0.0, true));
        assert_eq!(out.fd_out, 1.0);
    }

    #[test]
    fn outputs_are_registered_one_cycle() {
        // Outputs reflect the *previous* cycle's computation (registered).
        let mut pe = Pe::new();
        pe.step(PeInputs { fd: 0.0, rd: 4.0, load: true, mul_en: false });
        let out = pe.step(calc(5.0, 1.0, true)); // computes 1 + 5*4 = 21 into reg
        assert_ne!(out.gd, 21.0, "must not combinationally bypass");
        let out = pe.step(calc(0.0, 0.0, true));
        assert_eq!(out.gd, 21.0);
    }
}
