//! Component-activity counters — the Scale-Sim→Accelergy logfile of the
//! paper's Fig. 8, as a struct instead of a CSV (a CSV emitter is provided
//! for the trace path).
//!
//! Every timing routine fills one of these; the energy estimator multiplies
//! by per-component access energies.  Counts are *events*, not bytes —
//! word width is applied by the energy model.

/// Per-component activity counts for some simulated interval.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Activity {
    /// MAC operations executed (Mul_En high).
    pub macs: u64,
    /// PE load-register writes (weight loads).
    pub pe_lr_writes: u64,
    /// Load (weight) SRAM buffer reads.
    pub weight_sram_reads: u64,
    /// Feed (IFMap) SRAM buffer reads.
    pub ifmap_sram_reads: u64,
    /// Feed (IFMap) SRAM buffer writes (fills from DRAM).
    pub ifmap_sram_writes: u64,
    /// Drain (OFMap) SRAM buffer writes.
    pub ofmap_sram_writes: u64,
    /// Drain (OFMap) SRAM buffer reads (partial-sum accumulation).
    pub ofmap_sram_reads: u64,
    /// Weight SRAM buffer writes (fills from DRAM).
    pub weight_sram_writes: u64,
    /// DRAM words read (weights + ifmap fills).
    pub dram_reads: u64,
    /// DRAM words written (ofmap spills + final results).
    pub dram_writes: u64,
}

impl Activity {
    /// Element-wise accumulate.
    pub fn add(&mut self, other: &Activity) {
        self.macs += other.macs;
        self.pe_lr_writes += other.pe_lr_writes;
        self.weight_sram_reads += other.weight_sram_reads;
        self.ifmap_sram_reads += other.ifmap_sram_reads;
        self.ifmap_sram_writes += other.ifmap_sram_writes;
        self.ofmap_sram_writes += other.ofmap_sram_writes;
        self.ofmap_sram_reads += other.ofmap_sram_reads;
        self.weight_sram_writes += other.weight_sram_writes;
        self.dram_reads += other.dram_reads;
        self.dram_writes += other.dram_writes;
    }

    /// Total SRAM accesses (reads + writes, all three buffers).
    pub fn sram_accesses(&self) -> u64 {
        self.weight_sram_reads
            + self.weight_sram_writes
            + self.ifmap_sram_reads
            + self.ifmap_sram_writes
            + self.ofmap_sram_reads
            + self.ofmap_sram_writes
    }

    /// Total DRAM accesses.
    pub fn dram_accesses(&self) -> u64 {
        self.dram_reads + self.dram_writes
    }

    /// Accelergy-style CSV line (see [`csv_header`]).
    pub fn csv_line(&self, tag: &str) -> String {
        format!(
            "{tag},{},{},{},{},{},{},{},{},{},{}",
            self.macs,
            self.pe_lr_writes,
            self.weight_sram_reads,
            self.weight_sram_writes,
            self.ifmap_sram_reads,
            self.ifmap_sram_writes,
            self.ofmap_sram_reads,
            self.ofmap_sram_writes,
            self.dram_reads,
            self.dram_writes
        )
    }
}

/// Header matching [`Activity::csv_line`].
pub fn csv_header() -> &'static str {
    "tag,macs,pe_lr_writes,weight_sram_reads,weight_sram_writes,\
     ifmap_sram_reads,ifmap_sram_writes,ofmap_sram_reads,ofmap_sram_writes,\
     dram_reads,dram_writes"
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_accumulates_all_fields() {
        let mut a = Activity { macs: 1, pe_lr_writes: 2, weight_sram_reads: 3, ..Default::default() };
        let b = Activity { macs: 10, dram_writes: 5, ..Default::default() };
        a.add(&b);
        assert_eq!(a.macs, 11);
        assert_eq!(a.pe_lr_writes, 2);
        assert_eq!(a.dram_writes, 5);
    }

    #[test]
    fn totals() {
        let a = Activity {
            weight_sram_reads: 1,
            weight_sram_writes: 2,
            ifmap_sram_reads: 4,
            ifmap_sram_writes: 8,
            ofmap_sram_reads: 16,
            ofmap_sram_writes: 32,
            dram_reads: 64,
            dram_writes: 128,
            ..Default::default()
        };
        assert_eq!(a.sram_accesses(), 63);
        assert_eq!(a.dram_accesses(), 192);
    }

    #[test]
    fn csv_round_trip_field_count() {
        let line = Activity::default().csv_line("x");
        assert_eq!(line.split(',').count(), csv_header().split(',').count());
    }
}
