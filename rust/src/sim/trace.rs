//! Trace emitters — the Scale-Sim-style per-layer cycle report and the
//! Accelergy-style component-activity logfile of the paper's Fig. 8
//! toolchain, as CSV.  `mtsa trace <pool>` writes both.

use std::fmt::Write as _;

use crate::coordinator::RunMetrics;
use crate::sim::activity::csv_header;

/// Scale-Sim-style compute report: one row per layer dispatch.
///
/// Columns mirror Scale-Sim's `COMPUTE_REPORT.csv` (layer id, start/end
/// cycle, total cycles, utilization %) extended with the partition
/// geometry this system adds.
pub fn compute_report_csv(m: &RunMetrics) -> String {
    let mut out = String::from(
        "dnn,layer,layer_name,row0,col0,rows,cols,start_cycle,end_cycle,total_cycles,macs,pe_utilization_pct\n",
    );
    for d in &m.dispatches {
        let tile_pes = d.tile.pes();
        let util = if d.duration() > 0 {
            100.0 * d.activity.macs as f64 / (d.duration() as f64 * tile_pes as f64)
        } else {
            0.0
        };
        let _ = writeln!(
            out,
            "{},{},{},{},{},{},{},{},{},{},{},{:.2}",
            d.dnn_name,
            d.layer,
            d.layer_name,
            d.tile.row0,
            d.tile.col0,
            d.tile.rows,
            d.tile.cols,
            d.t_start,
            d.t_end,
            d.duration(),
            d.activity.macs,
            util
        );
    }
    out
}

/// Accelergy-style activity log: one row per layer dispatch, the
/// component-access counts the energy estimator consumes (Fig. 8's
/// "component activity" interchange file).
pub fn activity_log_csv(m: &RunMetrics) -> String {
    let mut out = String::from(csv_header());
    out.push('\n');
    for d in &m.dispatches {
        out.push_str(&d.activity.csv_line(&format!("{}/{}", d.dnn_name, d.layer_name)));
        out.push('\n');
    }
    // Aggregate row, as Accelergy's summary expects.
    out.push_str(&m.total_activity.csv_line("TOTAL"));
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::SchedulerConfig;
    use crate::coordinator::DynamicScheduler;
    use crate::workloads::dnng::{Dnn, Layer, WorkloadPool};
    use crate::workloads::shapes::{LayerKind, LayerShape};

    fn run() -> RunMetrics {
        let pool = WorkloadPool::new(
            "t",
            vec![Dnn::chain(
                "net",
                vec![
                    Layer::new("a", LayerKind::Fc, LayerShape::fc(64, 256, 64)),
                    Layer::new("b", LayerKind::Fc, LayerShape::fc(64, 64, 32)),
                ],
            )],
        );
        DynamicScheduler::new(SchedulerConfig::default()).run(&pool)
    }

    #[test]
    fn compute_report_has_row_per_dispatch() {
        let m = run();
        let csv = compute_report_csv(&m);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 1 + m.dispatches.len());
        assert!(lines[0].starts_with("dnn,layer,"));
        assert!(lines[1].contains("net,0,a,"));
        // Utilization parses and is within (0, 100].
        let util: f64 = lines[1].rsplit(',').next().unwrap().parse().unwrap();
        assert!(util > 0.0 && util <= 100.0);
    }

    #[test]
    fn activity_log_has_total_row() {
        let m = run();
        let csv = activity_log_csv(&m);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 1 + m.dispatches.len() + 1);
        let total = lines.last().unwrap();
        assert!(total.starts_with("TOTAL,"));
        // MAC column of TOTAL equals the metrics aggregate.
        let macs: u64 = total.split(',').nth(1).unwrap().parse().unwrap();
        assert_eq!(macs, m.total_activity.macs);
    }

    #[test]
    fn csv_is_machine_parseable() {
        let m = run();
        for csv in [compute_report_csv(&m), activity_log_csv(&m)] {
            let mut lines = csv.lines();
            let ncols = lines.next().unwrap().split(',').count();
            for line in lines {
                assert_eq!(line.split(',').count(), ncols, "ragged row: {line}");
            }
        }
    }
}
