//! Alternative systolic dataflows — *input stationary* (IS) and *output
//! stationary* (OS) — the two other basic mappings the paper's §2
//! preliminaries describe.  Built as comparators: the
//! `dataflow_comparison` bench shows why the paper (like the TPU) builds
//! on weight stationary, and where the alternatives would win.
//!
//! Both models use the same analytic style as [`super::dataflow`]
//! (fold-counting with pipeline-fill skew, derived from the same
//! register-level array assumptions) and fill the same [`Activity`]
//! counters so the energy model applies unchanged.
//!
//! **IS** — the roles of weights and inputs swap (paper: "the
//! input-stationary approach is similar to weight-stationary, but the
//! role of weights and inputs is swapped"): IFMap tiles `[Sr, K]` are
//! pinned in the load registers (Sr on columns, K on rows) and weight
//! rows stream through; outputs drain down columns.  Folds:
//! `⌈K/H⌉ × ⌈Sr/W⌉`, stream length `M`.
//!
//! **OS** — each PE accumulates one output element `[Sr × M]` in place;
//! inputs and weights stream in from the two edges (`K` cycles), then
//! outputs drain through the column wires (`h` cycles per fold).  Folds:
//! `⌈Sr/H⌉ × ⌈M/W⌉`, stream length `K`, plus an explicit drain phase —
//! the separate drain stage the paper's §1 mentions.

use super::activity::Activity;
use super::buffers::BufferConfig;
use super::dataflow::{ArrayGeometry, LayerTiming};
use crate::util::ceil_div;
use crate::workloads::shapes::GemmDims;

/// Input-stationary timing for one layer on the full array.
pub fn input_stationary_timing(
    geom: ArrayGeometry,
    gemm: GemmDims,
    bufs: &BufferConfig,
) -> LayerTiming {
    let GemmDims { sr, k, m } = gemm;
    assert!(sr > 0 && k > 0 && m > 0);
    // IFMap stationary: K rows x Sr columns resident; weights stream M rows.
    let fk = ceil_div(k, geom.rows);
    let fs = ceil_div(sr, geom.cols);
    // Per fold: load h_i rows of the ifmap tile, stream M weight rows
    // through (pipeline fill H + drain across w_j columns).
    // Closed form mirrors dataflow::layer_timing_at with Sr <-> M swapped.
    let per_fold_base = m + geom.rows - 1;
    let cycles = fs * k + fk * sr + fk * fs * per_fold_base;

    let ifmap_passes = bufs.ifmap_dram_passes(sr, k, 1);
    let activity = Activity {
        macs: sr * k * m,
        pe_lr_writes: k * sr,        // the ifmap is what gets pinned
        weight_sram_reads: k * m * fs, // weights re-stream per Sr fold
        weight_sram_writes: k * m,
        ifmap_sram_reads: sr * k,
        ifmap_sram_writes: sr * k * ifmap_passes,
        ofmap_sram_writes: sr * m * fk,
        ofmap_sram_reads: sr * m * (fk - 1),
        dram_reads: k * m + sr * k * ifmap_passes,
        dram_writes: sr * m,
    };
    LayerTiming { cycles, fk, fm: fs, activity }
}

/// Output-stationary timing for one layer on the full array.
pub fn output_stationary_timing(
    geom: ArrayGeometry,
    gemm: GemmDims,
    bufs: &BufferConfig,
) -> LayerTiming {
    let GemmDims { sr, k, m } = gemm;
    assert!(sr > 0 && k > 0 && m > 0);
    // Each PE owns one (sr, m) output element; stream K products, then
    // drain the fold's outputs down the columns (h_i cycles).
    let fs = ceil_div(sr, geom.rows);
    let fm = ceil_div(m, geom.cols);
    // Per fold (h_i, w_j): skew-in (h_i + w_j - 2) + K stream + h_i drain.
    // Closed form: Σ h_i = sr (once per fm), Σ w_j = m (once per fs):
    //   cycles = Σ_ij [2 h_i + w_j + K - 2]
    //          = 2·fm·sr + fs·m + fs·fm·(k - 2)   (saturating for k < 2)
    let cycles = 2 * fm * sr + fs * m + fs * fm * k.saturating_sub(2).max(1);

    let ifmap_passes = bufs.ifmap_dram_passes(sr, k, fm);
    let activity = Activity {
        macs: sr * k * m,
        pe_lr_writes: 0, // nothing pinned; accumulators live in the PE
        weight_sram_reads: k * m * fs, // weights re-stream per Sr fold
        weight_sram_writes: k * m,
        ifmap_sram_reads: sr * k * fm, // ifmap re-streams per M fold
        ifmap_sram_writes: sr * k * ifmap_passes,
        // OS writes each output exactly once: no partial-sum traffic.
        ofmap_sram_writes: sr * m,
        ofmap_sram_reads: 0,
        dram_reads: k * m + sr * k * ifmap_passes,
        dram_writes: sr * m,
    };
    LayerTiming { cycles, fk: fs, fm, activity }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::dataflow::baseline_layer_timing;

    const GEOM: ArrayGeometry = ArrayGeometry { rows: 128, cols: 128 };

    fn bufs() -> BufferConfig {
        BufferConfig::default()
    }

    #[test]
    fn macs_identical_across_dataflows() {
        let g = GemmDims { sr: 3025, k: 363, m: 96 };
        let ws = baseline_layer_timing(GEOM, g, &bufs());
        let is = input_stationary_timing(GEOM, g, &bufs());
        let os = output_stationary_timing(GEOM, g, &bufs());
        assert_eq!(ws.activity.macs, is.activity.macs);
        assert_eq!(ws.activity.macs, os.activity.macs);
    }

    #[test]
    fn os_has_no_partial_sum_traffic() {
        let g = GemmDims { sr: 1000, k: 2048, m: 512 };
        let os = output_stationary_timing(GEOM, g, &bufs());
        assert_eq!(os.activity.ofmap_sram_reads, 0);
        assert_eq!(os.activity.ofmap_sram_writes, g.sr * g.m);
        // WS with FK = 16 folds pays 15 read-modify-write passes.
        let ws = baseline_layer_timing(GEOM, g, &bufs());
        assert!(ws.activity.ofmap_sram_reads > 0);
    }

    #[test]
    fn ws_wins_convs_is_wins_batch1_fc() {
        // Convolution (long stream, narrow M): WS pins the small weight
        // tile once and amortizes the fill over 3025 stream rows; IS folds
        // the 3025-row ifmap into 24 column tiles and re-fills per tile.
        let conv = GemmDims { sr: 3025, k: 363, m: 96 }; // AlexNet conv1
        let ws = baseline_layer_timing(GEOM, conv, &bufs());
        let is = input_stationary_timing(GEOM, conv, &bufs());
        assert!(ws.cycles < is.cycles / 2, "WS {} vs IS {}", ws.cycles, is.cycles);

        // FC at batch 1 (Sr = 1): the WS weakness the zoo exposes (AlexNet
        // fc6-8 dominate its runtime).  IS pins the single ifmap column and
        // streams every weight row through in one pass per K-fold — fewer
        // fills, fewer cycles.  This is exactly the Herald/Planaria
        // motivation for heterogeneous dataflows.
        let fc = GemmDims { sr: 1, k: 4096, m: 4096 };
        let ws = baseline_layer_timing(GEOM, fc, &bufs());
        let is = input_stationary_timing(GEOM, fc, &bufs());
        assert!(is.cycles < ws.cycles, "IS {} vs WS {}", is.cycles, ws.cycles);
    }

    #[test]
    fn os_competitive_on_deep_reductions() {
        // Deep K, modest outputs: OS streams K once per output tile with no
        // psum spills; WS pays FK load+drain overheads.
        let deep = GemmDims { sr: 128, k: 16384, m: 128 };
        let ws = baseline_layer_timing(GEOM, deep, &bufs());
        let os = output_stationary_timing(GEOM, deep, &bufs());
        assert!(os.cycles < ws.cycles, "OS {} vs WS {}", os.cycles, ws.cycles);
    }

    #[test]
    fn cycle_counts_positive_and_bounded() {
        for g in [
            GemmDims { sr: 1, k: 1, m: 1 },
            GemmDims { sr: 7, k: 129, m: 129 },
            GemmDims { sr: 4096, k: 4096, m: 4096 },
        ] {
            for t in [
                input_stationary_timing(GEOM, g, &bufs()),
                output_stationary_timing(GEOM, g, &bufs()),
            ] {
                assert!(t.cycles > 0);
                // Sanity roofline: cycles >= macs / PEs.
                assert!(t.cycles >= g.macs() / GEOM.pes());
            }
        }
    }
}
