//! Scale-Sim-equivalent systolic-array simulator.
//!
//! Two models of the same hardware, cross-validated against each other:
//!
//! - **Functional** ([`pe`], [`array`]) — a register-level cycle simulation
//!   of the weight-stationary array with the paper's modified PE (load
//!   register + `Mul_En` tri-state gate, Fig. 7).  Executes real numerics
//!   cycle by cycle, including multi-tenant feed interleaving on shared row
//!   wires.  Ground truth for both numerics and cycle counts on small
//!   arrays.
//! - **Analytic** ([`dataflow`], [`partitioned`]) — closed-form fold/skew
//!   equations (the Scale-Sim approach) used by the coordinator for full
//!   128×128 runs.  Tests assert the analytic equations reproduce the
//!   functional simulator's cycle counts exactly.
//!
//! Supporting substrates: [`buffers`] (SRAM capacity/double-buffer model and
//! access counting), [`dram`] (off-chip traffic), [`activity`] (the
//! component-activity log consumed by the energy estimator — the
//! Scale-Sim→Accelergy logfile of the paper's Fig. 8).

pub mod activity;
pub mod alt_dataflows;
pub mod array;
pub mod buffers;
pub mod dataflow;
pub mod dram;
pub mod partitioned;
pub mod pe;
pub mod trace;

pub use activity::Activity;
pub use dataflow::{ArrayGeometry, LayerTiming};
pub use partitioned::{FeedPolicy, PartitionSlice, Tile};
