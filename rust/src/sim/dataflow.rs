//! Analytic weight-stationary timing — the Scale-Sim-equivalent closed
//! forms, derived from (and tested against) the functional simulator in
//! [`super::array`].
//!
//! A GEMM `[Sr, K] × [K, M]` maps onto an `H × W` array as
//! `FK = ⌈K/H⌉ × FM = ⌈M/W⌉` folds.  Per fold `(i, j)` with used rows
//! `h_i` and used columns `w_j`:
//!
//! - **load**: `h_i` cycles (weights ripple down the column shift chain);
//! - **feed+drain**: the last partial sum for stream row `Sr-1` leaves the
//!   drain port of column `col0 + w_j - 1` after
//!   `Sr + H + col0 + w_j - 1` cycles (psums traverse the *full* physical
//!   column height `H`, plus one drain-pipe stage) — see
//!   `array::tests::single_tile_cycle_count_formula` for the exact match.
//!
//! Folds execute back-to-back with no load/compute overlap (the Y wires
//! are shared between weights and partial sums, Fig. 3, so a fold's load
//! cannot start until the previous drain finishes — the paper's motivation
//! for separate load/calculate steps).

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};
use std::sync::OnceLock;

use super::activity::Activity;
use super::buffers::BufferConfig;
use super::partitioned::Tile;
use crate::util::ceil_div;
use crate::workloads::shapes::GemmDims;

/// Physical array geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArrayGeometry {
    /// PE rows (`H`, the K dimension).
    pub rows: u64,
    /// PE columns (`W`, the M/partitioned dimension).
    pub cols: u64,
}

impl ArrayGeometry {
    pub fn new(rows: u64, cols: u64) -> ArrayGeometry {
        ArrayGeometry::try_new(rows, cols).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Like [`ArrayGeometry::new`], but surfaces bad dimensions as an
    /// error naming the offending value — the config/CLI entry points
    /// route through this so a zero dimension in a TOML file is a
    /// reported config error, not an abort.
    pub fn try_new(rows: u64, cols: u64) -> Result<ArrayGeometry, String> {
        if rows == 0 || cols == 0 {
            return Err(format!(
                "array geometry {rows}x{cols} is invalid: both dimensions must be positive"
            ));
        }
        Ok(ArrayGeometry { rows, cols })
    }

    pub fn pes(&self) -> u64 {
        self.rows * self.cols
    }
}

/// Parse `"HxW"` (e.g. `64x256`) or a bare side `"N"` (= `NxN`) — the
/// CLI/config spelling of a geometry (`mtsa sweep --geoms 64x256,128`).
impl std::str::FromStr for ArrayGeometry {
    type Err = String;

    fn from_str(s: &str) -> Result<ArrayGeometry, String> {
        fn dim(d: &str) -> Result<u64, String> {
            match d.trim().parse::<u64>() {
                Ok(v) if v > 0 => Ok(v),
                _ => Err(format!(
                    "bad array dimension {d:?} (expected a positive integer, e.g. 128 or 64x256)"
                )),
            }
        }
        match s.split_once(|c| c == 'x' || c == 'X') {
            Some((h, w)) => Ok(ArrayGeometry { rows: dim(h)?, cols: dim(w)? }),
            None => {
                let n = dim(s)?;
                Ok(ArrayGeometry { rows: n, cols: n })
            }
        }
    }
}

/// A SIMD vector engine paired with the array — the systolic-vector
/// architecture (PAPERS.md, arXiv 2206.03060).  Lanes execute
/// memory-bound layers (LSTM steps, embeddings, skinny projections) that
/// waste array PEs no matter how they are tiled; the coordinator
/// partitions them as a second, 1D allocation pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VectorUnit {
    /// Total lanes.  Zero is rejected by [`VectorUnit::try_new`]; "no
    /// vector engine at all" is [`Machine::vector`]` = None`.
    pub lanes: u64,
    /// MAC-equivalent operations each lane retires per cycle.
    pub ops_per_lane: u64,
    /// DRAM words each lane can stream per cycle (the lanes' aggregate
    /// streaming bandwidth is `lanes × words_per_lane`).
    pub words_per_lane: u64,
    /// Fixed per-layer dispatch/drain overhead in cycles — lanes have no
    /// fold structure, but issuing a kernel still costs a pipeline fill.
    pub startup: u64,
}

/// Default per-layer vector dispatch overhead (cycles).
pub const DEFAULT_VECTOR_STARTUP: u64 = 64;

impl VectorUnit {
    /// A vector engine with `lanes` lanes and default rates (1 op and
    /// 1 word per lane per cycle, [`DEFAULT_VECTOR_STARTUP`] overhead).
    pub fn new(lanes: u64) -> VectorUnit {
        VectorUnit::try_new(lanes, 1, 1, DEFAULT_VECTOR_STARTUP).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Like [`VectorUnit::new`] but surfaces bad parameters as an error
    /// naming the offending key and value — the `[vector]` config section
    /// routes through this, mirroring [`ArrayGeometry::try_new`].
    pub fn try_new(
        lanes: u64,
        ops_per_lane: u64,
        words_per_lane: u64,
        startup: u64,
    ) -> Result<VectorUnit, String> {
        if lanes == 0 {
            return Err("vector config `lanes = 0` is invalid: a vector engine needs at least one lane (omit the [vector] section to model none)".to_string());
        }
        if ops_per_lane == 0 {
            return Err("vector config `ops_per_lane = 0` is invalid: each lane must retire at least one op per cycle".to_string());
        }
        if words_per_lane == 0 {
            return Err("vector config `words_per_lane = 0` is invalid: each lane must stream at least one word per cycle".to_string());
        }
        Ok(VectorUnit { lanes, ops_per_lane, words_per_lane, startup })
    }
}

/// The whole machine: one systolic array plus an optional vector engine.
/// `vector = None` (equivalently `vector_lanes() == 0`) is exactly the
/// pre-heterogeneous resource model — every code path conditioned on it
/// reproduces today's outputs byte for byte.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Machine {
    pub geom: ArrayGeometry,
    pub vector: Option<VectorUnit>,
}

impl Machine {
    /// The classic single-resource machine.
    pub fn array_only(geom: ArrayGeometry) -> Machine {
        Machine { geom, vector: None }
    }

    /// Array + `lanes`-lane vector engine at default rates.
    pub fn with_lanes(geom: ArrayGeometry, lanes: u64) -> Machine {
        Machine { geom, vector: Some(VectorUnit::new(lanes)) }
    }

    /// Lane count of the vector engine, `0` when there is none.
    pub fn vector_lanes(&self) -> u64 {
        self.vector.map_or(0, |v| v.lanes)
    }
}

/// Time a layer on `lanes` lanes of the vector engine `vu` — the vector
/// analogue of the tile closed form.  Lanes have no fold structure: the
/// GEMM's MACs divide across `lanes × ops_per_lane` and its ideal DRAM
/// stream across `lanes × words_per_lane`, compute and streaming overlap
/// (double-buffered operand queues), and a fixed `startup` covers kernel
/// issue and pipeline drain:
///
/// ```text
/// cycles = startup + max( ⌈MACs / (lanes·ops_per_lane)⌉,
///                         ⌈words / (lanes·words_per_lane)⌉ )
/// ```
///
/// All integer, so the result is exact and platform-independent.  The
/// activity bills the MACs and the ideal DRAM traffic; lanes stream
/// operands directly and never refetch, so every SRAM counter is zero.
pub fn layer_timing_vector(vu: &VectorUnit, lanes: u64, gemm: GemmDims) -> LayerTiming {
    let GemmDims { sr, k, m } = gemm;
    assert!(sr > 0 && k > 0 && m > 0);
    assert!(
        lanes > 0 && lanes <= vu.lanes,
        "lane span {lanes} out of range for a {}-lane vector engine",
        vu.lanes
    );
    let compute = ceil_div(gemm.macs(), lanes * vu.ops_per_lane);
    let stream = ceil_div(gemm.ideal_words(), lanes * vu.words_per_lane);
    let activity = Activity {
        macs: gemm.macs(),
        dram_reads: k * m + sr * k,
        dram_writes: sr * m,
        ..Activity::default()
    };
    LayerTiming { cycles: vu.startup + compute.max(stream), fk: 1, fm: 1, activity }
}

/// The compute-only half of [`layer_timing_vector`] — what a lane layer
/// costs when the shared memory system ([`crate::mem`]) owns the
/// streaming side (the arbiter re-prices the transfer under contention,
/// so baking the isolated stream bound in here would double-count it).
pub fn vector_compute_cycles(vu: &VectorUnit, lanes: u64, gemm: GemmDims) -> u64 {
    assert!(lanes > 0 && lanes <= vu.lanes);
    vu.startup + ceil_div(gemm.macs(), lanes * vu.ops_per_lane)
}

/// Result of timing one layer on (a slice of) the array.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerTiming {
    /// Total cycles (load + feed + drain over all folds).
    pub cycles: u64,
    /// K folds.
    pub fk: u64,
    /// M folds.
    pub fm: u64,
    /// Component activity for the energy model.
    pub activity: Activity,
}

impl LayerTiming {
    /// PE-seconds utilization of the slice: MACs / (cycles × slice PEs).
    pub fn utilization(&self, slice_pes: u64) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.activity.macs as f64 / (self.cycles as f64 * slice_pes as f64)
    }
}

/// Single-tenant stream cycles for one fold: the tile starts at column
/// `col0` and spans `w` columns on an `h`-row-high array.
#[inline]
pub fn stream_cycles(sr: u64, array_rows: u64, col0: u64, w: u64) -> u64 {
    sr + array_rows + col0 + w - 1
}

/// Interleaved (shared-wire) stream cycles with `p` co-resident tenants:
/// slot `slot` of `p`, derived from the functional model
/// (`array::tests::interleaved_cycle_count_formula`).
#[inline]
pub fn stream_cycles_interleaved(p: u64, slot: u64, sr: u64, array_rows: u64, col0: u64, w: u64) -> u64 {
    debug_assert!(slot < p);
    p * (sr - 1 + array_rows - 1) + slot + col0 + w - 1 + p + 1
}

/// Iterate fold dimensions `(h_i, w_j)` of a `[K, M]` weight on `H×W`.
pub fn folds(k: u64, m: u64, rows: u64, cols: u64) -> impl Iterator<Item = (u64, u64)> {
    let fk = ceil_div(k, rows);
    let fm = ceil_div(m, cols);
    (0..fk).flat_map(move |i| {
        let h = (k - i * rows).min(rows);
        (0..fm).map(move |j| (h, (m - j * cols).min(cols)))
    })
}

/// Time a layer on the full array, single tenant (the baseline datapath).
pub fn baseline_layer_timing(geom: ArrayGeometry, gemm: GemmDims, bufs: &BufferConfig) -> LayerTiming {
    layer_timing_at(geom, gemm, 0, geom.cols, bufs, None)
}

/// Progress of a partially executed layer at a fold boundary, under the
/// independent feed model.  Fold order is K-band-major (all M-folds of
/// band `i` before band `i + 1`), matching [`folds`].
///
/// A preemption can only take effect here: the fold in flight must drain
/// its partial sums before the tile can be reshaped.  Work is credited at
/// *K-band* granularity — a complete band has accumulated its psum
/// contribution for every output column, so the remainder is exactly the
/// GEMM `[Sr, K - bands_done·rows] × [K - bands_done·rows, M]` and can
/// resume on any tile.  M-folds of a trailing *partial* band have no
/// complete band to fold their psums into and are replayed by the
/// remainder (`replayed_folds` / the `cycles - band_prefix_cycles` gap is
/// the preemption's wasted refill; see `docs/preemption.md`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FoldBoundary {
    /// Complete K-bands (fold-grid rows) finished by the boundary.
    pub bands_done: u64,
    /// M-folds completed inside the trailing partial band — work the
    /// resumed remainder replays.
    pub replayed_folds: u64,
    /// Cycles from the segment's start to the boundary.
    pub cycles: u64,
    /// Cycles from the segment's start to the end of the last complete
    /// band (`cycles - band_prefix_cycles` is the wasted replayed work).
    pub band_prefix_cycles: u64,
}

/// The earliest fold boundary at or after `elapsed` cycles into a layer
/// running `gemm` on `tile` (independent feed model).
///
/// Returns `None` when that boundary is the layer's own completion (or
/// `elapsed` is already past it) — nothing is gained by preempting there.
/// O(FK): per-band arithmetic, no per-fold loop (verified against the
/// explicit fold scan by `tests::fold_boundary_matches_fold_scan`).
pub fn next_fold_boundary(
    geom: ArrayGeometry,
    gemm: GemmDims,
    tile: Tile,
    elapsed: u64,
) -> Option<FoldBoundary> {
    let GemmDims { sr, k, m } = gemm;
    assert!(sr > 0 && k > 0 && m > 0);
    let fk = ceil_div(k, tile.rows);
    let fm = ceil_div(m, tile.cols);
    let w_last = m - (fm - 1) * tile.cols;
    // Per-fold duration: load (row0 skew + h) plus stream (see the module
    // doc) = base + h + w.
    let base = tile.row0 + sr + geom.rows + tile.col0 - 1;
    let mut t = 0u64;
    for i in 0..fk {
        let h = (k - i * tile.rows).min(tile.rows);
        let d_full = base + h + tile.cols;
        let d_last = base + h + w_last;
        let band = (fm - 1) * d_full + d_last;
        if elapsed >= t + band {
            t += band;
            continue;
        }
        let into = elapsed - t;
        if into == 0 {
            // Exactly on the band edge: band i-1's boundary, no replay.
            return Some(FoldBoundary {
                bands_done: i,
                replayed_folds: 0,
                cycles: t,
                band_prefix_cycles: t,
            });
        }
        if fm >= 2 && into <= (fm - 1) * d_full {
            // Mid-band: finish the fold in flight; its band stays partial.
            let j = ceil_div(into, d_full);
            return Some(FoldBoundary {
                bands_done: i,
                replayed_folds: j,
                cycles: t + j * d_full,
                band_prefix_cycles: t,
            });
        }
        // The fold in flight completes the band.
        if i + 1 == fk {
            return None; // ... and the band completes the layer
        }
        return Some(FoldBoundary {
            bands_done: i + 1,
            replayed_folds: 0,
            cycles: t + band,
            band_prefix_cycles: t + band,
        });
    }
    None // elapsed is at or past the layer's completion
}

/// Shared core: time a layer on columns `[col0, col0+width)` of the array.
///
/// `interleave`: `Some((p, slot))` applies the shared-feed-wire penalty of
/// `p` co-resident tenants; `None` is the independent-feed model (the
/// paper's).
pub fn layer_timing_at(
    geom: ArrayGeometry,
    gemm: GemmDims,
    col0: u64,
    width: u64,
    bufs: &BufferConfig,
    interleave: Option<(u64, u64)>,
) -> LayerTiming {
    assert!(
        width > 0 && col0 + width <= geom.cols,
        "slice [{col0}, {}) out of range for a {}-column array",
        col0 + width,
        geom.cols
    );
    layer_timing_tile(geom, gemm, Tile::full_height(geom, col0, width), bufs, interleave)
}

/// Like [`layer_timing_at`], but with an *explicit* buffer share instead
/// of the proportional `width/cols` split: `share` is the absolute SRAM
/// capacity this slice actually owns.  This is the entry point of the
/// banked memory hierarchy ([`crate::mem`]) — the
/// [`BankAllocator`](crate::mem::BankAllocator) grants integral banks, so
/// a tenant's refetch traffic follows the banks it holds, not the
/// proportional fiction.
pub fn layer_timing_with_share(
    geom: ArrayGeometry,
    gemm: GemmDims,
    col0: u64,
    width: u64,
    share: &BufferConfig,
    interleave: Option<(u64, u64)>,
) -> LayerTiming {
    assert!(
        width > 0 && col0 + width <= geom.cols,
        "slice [{col0}, {}) out of range for a {}-column array",
        col0 + width,
        geom.cols
    );
    layer_timing_tile_with_share(geom, gemm, Tile::full_height(geom, col0, width), share, interleave)
}

/// Time a layer on a rectangular [`Tile`] with the proportional buffer
/// share of its PE footprint.  Full-height tiles reproduce
/// [`layer_timing_at`] bit for bit (`rows·width / rows·cols` and
/// `width / cols` floor to the same share, and `row0 = 0` adds nothing).
pub fn layer_timing_tile(
    geom: ArrayGeometry,
    gemm: GemmDims,
    tile: Tile,
    bufs: &BufferConfig,
    interleave: Option<(u64, u64)>,
) -> LayerTiming {
    layer_timing_tile_with_share(geom, gemm, tile, &bufs.share(tile.pes(), geom.pes()), interleave)
}

/// Cache key of the memoized timing core: every input of
/// [`layer_timing_tile_with_share`], flattened to plain integers.  The
/// function is pure in exactly these fields, so key equality implies
/// result equality (pinned by `timing_cache_is_transparent` in
/// `rust/tests/scheduler_properties.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct TimingKey {
    geom: (u64, u64),
    gemm: (u64, u64, u64),
    tile: (u64, u64, u64, u64),
    share: (u64, u64, u64, u64),
    /// `(1 + p, slot)` for the interleaved feed, `(0, 0)` for independent.
    interleave: (u64, u64),
}

impl TimingKey {
    fn new(
        geom: ArrayGeometry,
        gemm: GemmDims,
        tile: Tile,
        share: &BufferConfig,
        interleave: Option<(u64, u64)>,
    ) -> TimingKey {
        TimingKey {
            geom: (geom.rows, geom.cols),
            gemm: (gemm.sr, gemm.k, gemm.m),
            tile: (tile.row0, tile.col0, tile.rows, tile.cols),
            share: (share.weight_bytes, share.ifmap_bytes, share.ofmap_bytes, share.dtype_bytes),
            interleave: match interleave {
                None => (0, 0),
                Some((p, slot)) => (1 + p, slot),
            },
        }
    }
}

/// Multiply-xor integer hasher (fx-style) — the key is a dozen small
/// integers, so the default SipHash would dominate the lookup cost.
#[derive(Default)]
struct TimingHasher {
    hash: u64,
}

impl Hasher for TimingHasher {
    fn finish(&self) -> u64 {
        self.hash
    }
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.write_u64(b as u64);
        }
    }
    fn write_u64(&mut self, v: u64) {
        self.hash = (self.hash.rotate_left(5) ^ v).wrapping_mul(0x517C_C1B7_2722_0A95);
    }
}

/// Entries above which a thread's timing cache is reset — a backstop
/// against unbounded growth in pathological never-repeating workloads;
/// real sweeps revisit a few thousand (layer, tile, share) combinations.
const TIMING_CACHE_CAP: usize = 1 << 20;

type TimingCache = HashMap<TimingKey, LayerTiming, BuildHasherDefault<TimingHasher>>;

thread_local! {
    static TIMING_CACHE: RefCell<TimingCache> = RefCell::new(HashMap::default());
    /// Uncached timing computations this thread has performed — the
    /// observable half of the memo hand-off protocol below (a warmed
    /// thread replaying known keys performs none).
    static UNCACHED_CALLS: Cell<u64> = Cell::new(0);
}

/// Uncached timing computations performed by the *calling thread* so far.
/// Fresh OS threads start at zero, so a fleet worker warmed from a
/// [`TimingSnapshot`] can prove its chunk was fully memo-served.
pub fn timing_uncached_calls() -> u64 {
    UNCACHED_CALLS.with(|c| c.get())
}

/// A portable copy of a thread's timing memo.
///
/// The fleet driver respawns its worker pool at every chunk barrier, and
/// each fresh OS thread starts with a cold thread-local [`TimingCache`] —
/// so without help, every wave re-prices the same (layer, tile, share)
/// shapes from scratch.  Workers export a snapshot when a wave ends and
/// re-warm from the merged snapshot when the next wave starts; the memo
/// is a pure-function cache, so sharing it cannot change any simulated
/// byte.
#[derive(Debug, Clone, Default)]
pub struct TimingSnapshot {
    map: TimingCache,
}

impl TimingSnapshot {
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Absorb `other`.  Keys are the full input tuple of a pure function,
    /// so colliding entries carry equal values — which side wins is
    /// immaterial.
    pub fn merge(&mut self, other: TimingSnapshot) {
        if self.map.is_empty() {
            self.map = other.map;
        } else {
            self.map.extend(other.map);
        }
    }
}

/// Export a copy of the calling thread's timing memo.
pub fn timing_cache_snapshot() -> TimingSnapshot {
    TIMING_CACHE.with(|c| TimingSnapshot { map: c.borrow().clone() })
}

/// Pre-warm the calling thread's timing memo from `snap`.  A no-op when
/// the memo is disabled (`MTSA_NO_TIMING_CACHE`) or warming would blow
/// the [`TIMING_CACHE_CAP`] backstop.
pub fn timing_cache_warm(snap: &TimingSnapshot) {
    if !timing_cache_enabled() || snap.map.is_empty() {
        return;
    }
    TIMING_CACHE.with(|c| {
        let mut cache = c.borrow_mut();
        if cache.len() + snap.map.len() >= TIMING_CACHE_CAP {
            return;
        }
        for (k, v) in &snap.map {
            cache.insert(*k, *v);
        }
    });
}

/// Whether the layer-timing memo is on.  Set `MTSA_NO_TIMING_CACHE` (to
/// any value) to opt out and compute every call from scratch — the
/// results are identical either way; the switch exists for A/B timing and
/// for bisecting, not correctness.
pub fn timing_cache_enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| std::env::var_os("MTSA_NO_TIMING_CACHE").is_none())
}

/// The general timing core: a layer on rows `[row0, row0+rows)` ×
/// columns `[col0, col0+cols)` with an explicit buffer share.
///
/// Memoized: the result is a pure function of the arguments, and the
/// scheduler's planning loops (`plan_2d` candidate ladders, checkpoint
/// pricing, the sweep grid's repeated scenarios) revisit the same few
/// thousand keys constantly.  Each OS thread keeps its own cache, so the
/// parallel sweep stays lock-free and byte-deterministic.  Opt out with
/// `MTSA_NO_TIMING_CACHE` (see [`timing_cache_enabled`]).
pub fn layer_timing_tile_with_share(
    geom: ArrayGeometry,
    gemm: GemmDims,
    tile: Tile,
    share: &BufferConfig,
    interleave: Option<(u64, u64)>,
) -> LayerTiming {
    if !timing_cache_enabled() {
        return layer_timing_tile_with_share_uncached(geom, gemm, tile, share, interleave);
    }
    let key = TimingKey::new(geom, gemm, tile, share, interleave);
    TIMING_CACHE.with(|cache| {
        if let Some(hit) = cache.borrow().get(&key) {
            return *hit;
        }
        let t = layer_timing_tile_with_share_uncached(geom, gemm, tile, share, interleave);
        let mut cache = cache.borrow_mut();
        if cache.len() >= TIMING_CACHE_CAP {
            cache.clear();
        }
        cache.insert(key, t);
        t
    })
}

/// The uncached computation behind [`layer_timing_tile_with_share`] —
/// public so the transparency property test (and any A/B harness) can
/// compare against the memo directly.
pub fn layer_timing_tile_with_share_uncached(
    geom: ArrayGeometry,
    gemm: GemmDims,
    tile: Tile,
    share: &BufferConfig,
    interleave: Option<(u64, u64)>,
) -> LayerTiming {
    UNCACHED_CALLS.with(|c| c.set(c.get() + 1));
    assert!(
        tile.col_end() <= geom.cols && tile.row_end() <= geom.rows,
        "tile {tile:?} out of range for a {}x{} array",
        geom.rows,
        geom.cols
    );
    let GemmDims { sr, k, m } = gemm;
    assert!(sr > 0 && k > 0 && m > 0);
    let fk = ceil_div(k, tile.rows);
    let fm = ceil_div(m, tile.cols);

    // Closed form of `Σ_folds [(row0 + h_i) + stream(...)]` — the
    // scheduler calls this for every candidate dispatch, and a fold loop
    // is O(FK·FM) (AlexNet fc6 on a 16-wide slice = 18 432 folds).  The
    // load step pays `row0` extra cycles per fold (weights ripple through
    // the `row0` foreign rows above the tile's band), and the drain still
    // traverses the full physical column height `H`.  Using
    // Σ_i h_i = K, Σ_j w_j = M and the per-fold stream equations:
    //
    //   independent:  Σ = FM·K + FK·M + FK·FM·(row0 + Sr + H + col0 − 1)
    //   interleaved:  Σ = FM·K + FK·M + FK·FM·(row0 + p·(Sr + H − 2) + slot + col0 + p)
    //
    // Verified against the explicit fold loop by
    // `tests::closed_form_matches_fold_loop`.
    let per_fold_base = match interleave {
        None => tile.row0 + sr + geom.rows + tile.col0 - 1,
        Some((p, slot)) => {
            debug_assert!(slot < p);
            tile.row0 + p * (sr + geom.rows - 2) + slot + tile.col0 + p
        }
    };
    let cycles = fm * k + fk * m + fk * fm * per_fold_base;

    // Activity counts (per the DESIGN.md §4 accounting).
    let ifmap_passes = share.ifmap_dram_passes(sr, k, fm);
    let ofmap_spills = if share.ofmap_fits(sr, m) { 0 } else { fk.saturating_sub(1) };
    let activity = Activity {
        macs: sr * k * m,
        pe_lr_writes: k * m,
        weight_sram_reads: k * m,
        weight_sram_writes: k * m, // filled from DRAM once (single-use)
        ifmap_sram_reads: sr * k * fm,
        ifmap_sram_writes: sr * k * ifmap_passes,
        ofmap_sram_writes: sr * m * fk,
        ofmap_sram_reads: sr * m * (fk - 1),
        dram_reads: k * m + sr * k * ifmap_passes + sr * m * ofmap_spills,
        dram_writes: sr * m + sr * m * ofmap_spills,
    };

    LayerTiming { cycles, fk, fm, activity }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Tensor;
    use crate::sim::array::{simulate_step, StepTile};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand_tensor(rng: &mut Rng, shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.gen_f32() - 0.5).collect())
    }

    #[test]
    fn folds_cover_exact_dims() {
        let fs: Vec<_> = folds(10, 7, 4, 3).collect();
        // FK = 3 (4,4,2), FM = 3 (3,3,1)
        assert_eq!(fs.len(), 9);
        let sum_h: u64 = fs.iter().step_by(3).map(|(h, _)| h).sum();
        assert_eq!(sum_h, 10);
        let sum_w: u64 = fs[..3].iter().map(|(_, w)| w).sum();
        assert_eq!(sum_w, 7);
    }

    #[test]
    fn analytic_matches_functional_single_fold() {
        prop::check("analytic == functional (single fold, single tenant)", 60, |rng| {
            let rows = rng.gen_range_inclusive(1, 8);
            let cols = rng.gen_range_inclusive(1, 8);
            let k = rng.gen_range_inclusive(1, rows);
            let w = rng.gen_range_inclusive(1, cols);
            let col0 = rng.gen_range_inclusive(0, cols - w);
            let sr = rng.gen_range_inclusive(1, 20);
            let x = rand_tensor(rng, vec![sr as usize, k as usize]);
            let wt = rand_tensor(rng, vec![k as usize, w as usize]);
            let r = simulate_step(
                rows as usize,
                cols as usize,
                &[StepTile { x, w: wt, col0: col0 as usize }],
                true,
                None,
            );
            let geom = ArrayGeometry::new(rows, cols);
            let t = layer_timing_at(geom, GemmDims { sr, k, m: w }, col0, w, &BufferConfig::default(), None);
            prop::ensure_eq(t.cycles, r.total_cycles(), "cycles")?;
            prop::ensure_eq(t.activity.macs, r.macs, "macs")
        });
    }

    #[test]
    fn analytic_matches_functional_interleaved() {
        prop::check("analytic == functional (interleaved, worst slot)", 40, |rng| {
            let rows = rng.gen_range_inclusive(1, 6);
            let p = rng.gen_range_inclusive(2, 4);
            // p equal-width tiles with the same stream length and K so the
            // worst slot is the last one (deterministic max).
            let w = rng.gen_range_inclusive(1, 3);
            let cols = p * w;
            let k = rng.gen_range_inclusive(1, rows);
            let sr = rng.gen_range_inclusive(2, 12);
            let tiles: Vec<StepTile> = (0..p)
                .map(|i| StepTile {
                    x: rand_tensor(rng, vec![sr as usize, k as usize]),
                    w: rand_tensor(rng, vec![k as usize, w as usize]),
                    col0: (i * w) as usize,
                })
                .collect();
            let r = simulate_step(rows as usize, cols as usize, &tiles, true, None);
            let geom = ArrayGeometry::new(rows, cols);
            // Tile p-1 (last slot, rightmost columns) finishes last.
            let t = layer_timing_at(
                geom,
                GemmDims { sr, k, m: w },
                (p - 1) * w,
                w,
                &BufferConfig::default(),
                Some((p, p - 1)),
            );
            prop::ensure_eq(t.cycles, k + r.stream_cycles, "load+stream cycles")
        });
    }

    #[test]
    fn closed_form_matches_fold_loop() {
        // The O(1) closed form in layer_timing_at must equal the explicit
        // per-fold sum for any shape, slice, and feed policy.
        prop::check("closed form == fold loop", 200, |rng| {
            let geom = ArrayGeometry::new(
                rng.gen_range_inclusive(1, 128),
                rng.gen_range_inclusive(1, 128),
            );
            let width = rng.gen_range_inclusive(1, geom.cols);
            let col0 = rng.gen_range_inclusive(0, geom.cols - width);
            let gemm = GemmDims {
                sr: rng.gen_range_inclusive(1, 5000),
                k: rng.gen_range_inclusive(1, 8192),
                m: rng.gen_range_inclusive(1, 8192),
            };
            let interleave = if rng.gen_bool(0.5) {
                let p = rng.gen_range_inclusive(2, 8);
                Some((p, rng.gen_range(p)))
            } else {
                None
            };
            let t = layer_timing_at(geom, gemm, col0, width, &BufferConfig::default(), interleave);
            let mut loop_cycles = 0u64;
            for (h, w) in folds(gemm.k, gemm.m, geom.rows, width) {
                loop_cycles += h + match interleave {
                    None => stream_cycles(gemm.sr, geom.rows, col0, w),
                    Some((p, slot)) => {
                        stream_cycles_interleaved(p, slot, gemm.sr, geom.rows, col0, w)
                    }
                };
            }
            prop::ensure_eq(t.cycles, loop_cycles, "cycles")
        });
    }

    #[test]
    fn tile_closed_form_matches_fold_loop() {
        // The 2D closed form (row0 load-chain skew + height-based FK)
        // must equal the explicit per-fold sum for any tile placement.
        prop::check("tile closed form == fold loop", 200, |rng| {
            let geom = ArrayGeometry::new(
                rng.gen_range_inclusive(1, 128),
                rng.gen_range_inclusive(1, 128),
            );
            let height = rng.gen_range_inclusive(1, geom.rows);
            let row0 = rng.gen_range_inclusive(0, geom.rows - height);
            let width = rng.gen_range_inclusive(1, geom.cols);
            let col0 = rng.gen_range_inclusive(0, geom.cols - width);
            let gemm = GemmDims {
                sr: rng.gen_range_inclusive(1, 5000),
                k: rng.gen_range_inclusive(1, 8192),
                m: rng.gen_range_inclusive(1, 8192),
            };
            let interleave = if rng.gen_bool(0.5) {
                let p = rng.gen_range_inclusive(2, 8);
                Some((p, rng.gen_range(p)))
            } else {
                None
            };
            let tile = Tile::new(row0, col0, height, width);
            let t = layer_timing_tile(geom, gemm, tile, &BufferConfig::default(), interleave);
            let mut loop_cycles = 0u64;
            for (h, w) in folds(gemm.k, gemm.m, height, width) {
                loop_cycles += row0
                    + h
                    + match interleave {
                        None => stream_cycles(gemm.sr, geom.rows, col0, w),
                        Some((p, slot)) => {
                            stream_cycles_interleaved(p, slot, gemm.sr, geom.rows, col0, w)
                        }
                    };
            }
            prop::ensure_eq(t.cycles, loop_cycles, "cycles")
        });
    }

    #[test]
    fn fold_boundary_matches_fold_scan() {
        // The O(FK) per-band arithmetic must agree with an explicit scan
        // over the fold durations for any tile, shape and elapsed time.
        prop::check("next_fold_boundary == fold scan", 150, |rng| {
            let geom = ArrayGeometry::new(
                rng.gen_range_inclusive(1, 64),
                rng.gen_range_inclusive(1, 64),
            );
            let rows = rng.gen_range_inclusive(1, geom.rows);
            let row0 = rng.gen_range_inclusive(0, geom.rows - rows);
            let cols = rng.gen_range_inclusive(1, geom.cols);
            let col0 = rng.gen_range_inclusive(0, geom.cols - cols);
            let tile = Tile::new(row0, col0, rows, cols);
            let gemm = GemmDims {
                sr: rng.gen_range_inclusive(1, 2000),
                k: rng.gen_range_inclusive(1, 300),
                m: rng.gen_range_inclusive(1, 300),
            };
            let fm = ceil_div(gemm.m, cols);
            let durations: Vec<u64> = folds(gemm.k, gemm.m, rows, cols)
                .map(|(h, w)| row0 + h + stream_cycles(gemm.sr, geom.rows, col0, w))
                .collect();
            let total: u64 = durations.iter().sum();
            let elapsed = rng.gen_range(total + 3);
            // Reference: the smallest fold-end >= elapsed.
            let mut t = 0u64;
            let mut n_folds = durations.len();
            for (n, d) in durations.iter().enumerate() {
                if t >= elapsed {
                    n_folds = n;
                    break;
                }
                t += d;
            }
            let fm_us = fm as usize;
            let expect = if elapsed >= total || n_folds == durations.len() {
                None
            } else {
                let prefix: u64 = durations[..n_folds / fm_us * fm_us].iter().sum();
                Some(FoldBoundary {
                    bands_done: (n_folds / fm_us) as u64,
                    replayed_folds: (n_folds % fm_us) as u64,
                    cycles: t,
                    band_prefix_cycles: prefix,
                })
            };
            prop::ensure_eq(next_fold_boundary(geom, gemm, tile, elapsed), expect, "boundary")
        });
    }

    #[test]
    fn warmed_thread_replays_timings_without_uncached_calls() {
        // The fleet's chunk-barrier hand-off in miniature: wave 1 runs on
        // a fresh OS thread (cold memo), computes a set of shapes, and
        // exports its memo; wave 2 runs on ANOTHER fresh thread, re-warms
        // from the snapshot, and must serve the same shapes without a
        // single uncached computation.
        if !timing_cache_enabled() {
            return; // opted out via MTSA_NO_TIMING_CACHE: nothing to share
        }
        let geom = ArrayGeometry::new(64, 64);
        let bufs = BufferConfig::default();
        let shapes: Vec<GemmDims> = (1..6)
            .map(|i| GemmDims { sr: 8 * i, k: 32 * i, m: 16 * i })
            .collect();
        let (snap, cold, timings) = std::thread::scope(|s| {
            s.spawn(|| {
                let timings: Vec<LayerTiming> = shapes
                    .iter()
                    .map(|&g| layer_timing_tile(geom, g, Tile::full(geom), &bufs, None))
                    .collect();
                (timing_cache_snapshot(), timing_uncached_calls(), timings)
            })
            .join()
            .unwrap()
        });
        assert!(cold >= shapes.len() as u64, "wave 1 started cold");
        assert!(snap.len() >= shapes.len());
        let (warm_calls, replayed) = std::thread::scope(|s| {
            s.spawn(|| {
                timing_cache_warm(&snap);
                let replayed: Vec<LayerTiming> = shapes
                    .iter()
                    .map(|&g| layer_timing_tile(geom, g, Tile::full(geom), &bufs, None))
                    .collect();
                (timing_uncached_calls(), replayed)
            })
            .join()
            .unwrap()
        });
        assert_eq!(warm_calls, 0, "wave 2 must be fully memo-served");
        assert_eq!(replayed, timings, "memo hand-off must not change results");
    }

    #[test]
    fn fold_boundary_pinned_values() {
        // The preemption example's heavy layer: [4000, 1024] x [1024, 64]
        // on the full 128x128 array — 8 K-bands of one 4319-cycle fold.
        let geom = ArrayGeometry::new(128, 128);
        let g = GemmDims { sr: 4000, k: 1024, m: 64 };
        let tile = Tile::full(geom);
        let band = 128 + 4000 + 128 + 64 - 1; // load + stream
        assert_eq!(band, 4319);
        let fb = next_fold_boundary(geom, g, tile, 3000).unwrap();
        let want =
            FoldBoundary { bands_done: 1, replayed_folds: 0, cycles: 4319, band_prefix_cycles: 4319 };
        assert_eq!(fb, want);
        // Landing exactly on a boundary preempts there, with no replay.
        let fb = next_fold_boundary(geom, g, tile, 2 * 4319).unwrap();
        assert_eq!((fb.bands_done, fb.cycles), (2, 2 * 4319));
        // Inside the last band (or past the end) there is nothing to gain.
        assert_eq!(next_fold_boundary(geom, g, tile, 7 * 4319 + 1), None);
        assert_eq!(next_fold_boundary(geom, g, tile, 8 * 4319), None);
        assert_eq!(next_fold_boundary(geom, g, tile, u64::MAX), None);
    }

    #[test]
    fn fold_boundary_counts_replayed_partial_band_folds() {
        // m = 300 on 128 columns: fm = 3 (128, 128, 44).  Mid-band
        // boundaries credit no K rows but count the folds to replay.
        let geom = ArrayGeometry::new(128, 128);
        let g = GemmDims { sr: 100, k: 256, m: 300 };
        let tile = Tile::full(geom);
        let d_full = 128 + 100 + 128 + 128 - 1; // 483
        let fb = next_fold_boundary(geom, g, tile, 1).unwrap();
        let want =
            FoldBoundary { bands_done: 0, replayed_folds: 1, cycles: d_full, band_prefix_cycles: 0 };
        assert_eq!(fb, want);
        let fb = next_fold_boundary(geom, g, tile, d_full + 1).unwrap();
        assert_eq!((fb.bands_done, fb.replayed_folds), (0, 2));
        assert_eq!(fb.cycles - fb.band_prefix_cycles, 2 * d_full, "wasted = replayed folds");
    }

    #[test]
    fn geometry_try_new_names_the_offending_value() {
        assert_eq!(ArrayGeometry::try_new(64, 32), Ok(ArrayGeometry { rows: 64, cols: 32 }));
        let e = ArrayGeometry::try_new(0, 8).unwrap_err();
        assert!(e.contains("0x8"), "{e}");
        assert!(ArrayGeometry::try_new(8, 0).unwrap_err().contains("8x0"));
    }

    #[test]
    #[should_panic(expected = "0x8")]
    fn geometry_new_panic_names_the_offending_value() {
        let _ = ArrayGeometry::new(0, 8);
    }

    #[test]
    fn geometry_parses_hxw_and_bare_side() {
        assert_eq!("128".parse::<ArrayGeometry>().unwrap(), ArrayGeometry::new(128, 128));
        assert_eq!("64x256".parse::<ArrayGeometry>().unwrap(), ArrayGeometry::new(64, 256));
        assert_eq!("64X256".parse::<ArrayGeometry>().unwrap(), ArrayGeometry::new(64, 256));
        assert_eq!(" 32 x 8 ".parse::<ArrayGeometry>().unwrap(), ArrayGeometry::new(32, 8));
        for bad in ["", "x", "0", "0x8", "8x0", "8x", "x8", "12y34", "-4", "8x8x8"] {
            assert!(bad.parse::<ArrayGeometry>().is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn timing_memo_repeat_calls_match_uncached() {
        let geom = ArrayGeometry::new(128, 128);
        let g = GemmDims { sr: 3025, k: 1152, m: 384 };
        let tile = Tile::new(16, 32, 64, 64);
        let share = BufferConfig::default().share(tile.pes(), geom.pes());
        let first = layer_timing_tile_with_share(geom, g, tile, &share, None);
        let hit = layer_timing_tile_with_share(geom, g, tile, &share, None);
        let uncached = layer_timing_tile_with_share_uncached(geom, g, tile, &share, None);
        assert_eq!(first, hit);
        assert_eq!(first, uncached);
        // The interleave tag keeps `None` distinct from every `Some`.
        let il = layer_timing_tile_with_share(geom, g, tile, &share, Some((2, 1)));
        assert_ne!(first.cycles, il.cycles);
        assert_eq!(il, layer_timing_tile_with_share_uncached(geom, g, tile, &share, Some((2, 1))));
    }

    #[test]
    fn explicit_share_matches_proportional_share() {
        let geom = ArrayGeometry::new(128, 128);
        let g = GemmDims { sr: 3025, k: 363, m: 96 };
        let bufs = BufferConfig::default();
        let a = layer_timing_at(geom, g, 0, 32, &bufs, None);
        let b = layer_timing_with_share(geom, g, 0, 32, &bufs.share(32, 128), None);
        assert_eq!(a, b);
        // A starved explicit share inflates refetch traffic but never
        // changes the compute cycles (bufs only shape the activity).
        let starved = BufferConfig { weight_bytes: 1, ifmap_bytes: 1, ofmap_bytes: 1, dtype_bytes: 1 };
        let c = layer_timing_with_share(geom, g, 0, 32, &starved, None);
        assert_eq!(c.cycles, a.cycles);
        assert!(c.activity.dram_accesses() >= a.activity.dram_accesses());
    }

    #[test]
    fn multi_fold_cycles_sum() {
        // K = 2H, M = 2W: 4 folds, each full-size.
        let geom = ArrayGeometry::new(4, 4);
        let g = GemmDims { sr: 10, k: 8, m: 8 };
        let t = baseline_layer_timing(geom, g, &BufferConfig::default());
        assert_eq!((t.fk, t.fm), (2, 2));
        let per_fold = 4 + stream_cycles(10, 4, 0, 4);
        assert_eq!(t.cycles, 4 * per_fold);
    }

    #[test]
    fn narrower_slice_takes_longer() {
        let geom = ArrayGeometry::new(128, 128);
        let g = GemmDims { sr: 1000, k: 256, m: 128 };
        let full = baseline_layer_timing(geom, g, &BufferConfig::default());
        let half = layer_timing_at(geom, g, 0, 64, &BufferConfig::default(), None);
        assert!(half.cycles > full.cycles);
        // But by less than 2x: fold overheads amortize.
        assert!(half.cycles < 2 * full.cycles + 1000);
    }

    #[test]
    fn offset_adds_traversal_skew() {
        let geom = ArrayGeometry::new(8, 32);
        let g = GemmDims { sr: 100, k: 8, m: 8 };
        let at0 = layer_timing_at(geom, g, 0, 8, &BufferConfig::default(), None);
        let at24 = layer_timing_at(geom, g, 24, 8, &BufferConfig::default(), None);
        assert_eq!(at24.cycles - at0.cycles, 24);
    }

    #[test]
    fn utilization_bounded() {
        let geom = ArrayGeometry::new(128, 128);
        let g = GemmDims { sr: 10_000, k: 128, m: 128 };
        let t = baseline_layer_timing(geom, g, &BufferConfig::default());
        let u = t.utilization(geom.pes());
        assert!(u > 0.9, "long streams should approach full utilization, got {u}");
        assert!(u <= 1.0);
    }

    #[test]
    fn activity_scaling_with_folds() {
        let geom = ArrayGeometry::new(4, 4);
        let g = GemmDims { sr: 10, k: 8, m: 8 };
        let t = baseline_layer_timing(geom, g, &BufferConfig::default());
        assert_eq!(t.activity.macs, 10 * 8 * 8);
        assert_eq!(t.activity.pe_lr_writes, 8 * 8);
        assert_eq!(t.activity.ifmap_sram_reads, 10 * 8 * 2); // FM = 2
        assert_eq!(t.activity.ofmap_sram_writes, 10 * 8 * 2); // FK = 2
        assert_eq!(t.activity.ofmap_sram_reads, 10 * 8); // FK-1 accumulation
    }

    #[test]
    fn vector_unit_try_new_names_the_offending_value() {
        assert!(VectorUnit::try_new(256, 1, 1, 64).is_ok());
        let e = VectorUnit::try_new(0, 1, 1, 64).unwrap_err();
        assert!(e.contains("lanes = 0"), "{e}");
        assert!(VectorUnit::try_new(8, 0, 1, 0).unwrap_err().contains("ops_per_lane = 0"));
        assert!(VectorUnit::try_new(8, 1, 0, 0).unwrap_err().contains("words_per_lane = 0"));
    }

    #[test]
    fn machine_lane_accessors() {
        let geom = ArrayGeometry::new(128, 128);
        assert_eq!(Machine::array_only(geom).vector_lanes(), 0);
        let m = Machine::with_lanes(geom, 256);
        assert_eq!(m.vector_lanes(), 256);
        assert_eq!(m.vector.unwrap().startup, DEFAULT_VECTOR_STARTUP);
    }

    #[test]
    fn vector_timing_closed_form_pinned() {
        // GNMT-ish LSTM step: [50, 1536] x [1536, 4096] on 256 lanes.
        let vu = VectorUnit::new(256);
        let g = GemmDims { sr: 50, k: 1536, m: 4096 };
        let t = layer_timing_vector(&vu, 256, g);
        let macs = 50 * 1536 * 4096u64;
        let words = 1536 * 4096 + 50 * 1536 + 50 * 4096u64;
        assert_eq!(t.cycles, 64 + ceil_div(macs, 256).max(ceil_div(words, 256)));
        assert_eq!((t.fk, t.fm), (1, 1));
        assert_eq!(t.activity.macs, macs);
        assert_eq!(t.activity.dram_accesses(), words);
        assert_eq!(t.activity.sram_accesses(), 0, "lanes stream directly, no SRAM traffic");
        // This layer is compute-limited on equal rates; a narrower span
        // is priced proportionally slower.
        let half = layer_timing_vector(&vu, 128, g);
        assert!(half.cycles > t.cycles);
        assert_eq!(vector_compute_cycles(&vu, 256, g), 64 + ceil_div(macs, 256));
    }

    #[test]
    fn vector_timing_stream_bound_when_words_dominate() {
        // An embedding-style lookup: almost no re-use, the stream term
        // wins and words_per_lane (not ops_per_lane) sets the cycles.
        let vu = VectorUnit::try_new(64, 4, 1, 0).unwrap();
        let g = GemmDims { sr: 1, k: 100_000, m: 8 };
        let t = layer_timing_vector(&vu, 64, g);
        assert_eq!(t.cycles, ceil_div(g.ideal_words(), 64));
        assert!(ceil_div(g.macs(), 64 * 4) < t.cycles);
    }

    #[test]
    #[should_panic(expected = "lane span 512 out of range")]
    fn vector_timing_rejects_oversized_span() {
        let vu = VectorUnit::new(256);
        let _ = layer_timing_vector(&vu, 512, GemmDims { sr: 1, k: 1, m: 1 });
    }
}
