//! Functional (register-level) simulation of the partitioned
//! weight-stationary array — ground truth for numerics *and* cycle counts.
//!
//! Implements exactly the transfer function of [`super::pe::Pe`],
//! vectorized over the array, plus the multi-tenant feed interleaving of
//! the partitioned dataflow:
//!
//! - **Load step** (paper step ①): weights shift down the Y wires into the
//!   load registers, one row per cycle, all columns in parallel.
//! - **Feed/calculate step** (step ②): feed values move right one column
//!   per cycle; each value carries its tenant tag (physically: the Mul_En
//!   control stream that accompanies the data).  A PE multiplies only when
//!   the tag matches its column's owner; otherwise the value passes
//!   through and the partial sum below is untouched (Fig. 7 semantics).
//! - **Drain step** (step ③): partial sums exit the bottom of each column
//!   into the drain buffer, which accumulates across K-folds.
//!
//! When `P` tenants share the array, the row wires carry their streams
//! time-sliced (slot `p` on cycles `t ≡ p (mod P)`), and the partial-sum
//! path has a matching `P`-deep delay per row so products stay aligned
//! with their stream row.  `P = 1` reduces to the textbook WS array.  The
//! simulator asserts tag alignment at every MAC — a timing bug in the
//! model itself would abort, not silently corrupt.

use std::collections::VecDeque;

use crate::runtime::Tensor;

/// One tenant tile placed on the array for a step.
#[derive(Debug, Clone)]
pub struct StepTile {
    /// Feed stream `[sr, k_depth]`.
    pub x: Tensor,
    /// Stationary weights `[k_depth, width]`.
    pub w: Tensor,
    /// First column of the tile's partition.
    pub col0: usize,
}

/// Result of simulating one array step.
#[derive(Debug, Clone)]
pub struct StepResult {
    /// Per-tile OFMap `[sr, width]` (drain-buffer contents).
    pub outputs: Vec<Tensor>,
    /// Cycles spent in the load step.
    pub load_cycles: u64,
    /// Cycles spent in feed+drain (last output collected).
    pub stream_cycles: u64,
    /// MAC operations actually performed (Mul_En high).
    pub macs: u64,
}

impl StepResult {
    pub fn total_cycles(&self) -> u64 {
        self.load_cycles + self.stream_cycles
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
struct FeedSlot {
    value: f32,
    /// Tile index; usize::MAX = bubble.
    tenant: usize,
    /// Stream row the value belongs to.
    s: usize,
    valid: bool,
}

const BUBBLE: FeedSlot = FeedSlot { value: 0.0, tenant: usize::MAX, s: 0, valid: false };

#[derive(Debug, Clone, Copy, PartialEq)]
struct PsumSlot {
    value: f32,
    tenant: usize,
    s: usize,
    valid: bool,
}

const PSUM_BUBBLE: PsumSlot = PsumSlot { value: 0.0, tenant: usize::MAX, s: 0, valid: false };

/// Simulate one partitioned weight-stationary step.
///
/// * `rows`, `cols` — array geometry (`H × W`).
/// * `tiles` — co-resident tenant tiles (disjoint column ranges).
/// * `interleave` — `true`: tenants share the physical row wires
///   time-sliced (the honest hardware model); `false`: each tenant gets a
///   private feed port (the paper's independent-partition model — streams
///   run concurrently, foreign traversal still applies via `col0` skew).
/// * `acc` — optional previous-fold drain-buffer contents to accumulate
///   into (one `[sr, width]` tensor per tile).
pub fn simulate_step(
    rows: usize,
    cols: usize,
    tiles: &[StepTile],
    interleave: bool,
    acc: Option<&[Tensor]>,
) -> StepResult {
    validate_tiles(rows, cols, tiles);
    if interleave {
        simulate_shared_wires(rows, cols, tiles, acc)
    } else {
        // Independent feed ports: each tile streams concurrently on its own
        // (virtual) wires; cycle count is the max over tiles, numerics are
        // per-tile exact.  Model each tile as a P=1 shared-wire run that
        // still pays its column-offset traversal skew.
        let mut outputs = Vec::with_capacity(tiles.len());
        let mut load_cycles = 0u64;
        let mut stream_cycles = 0u64;
        let mut macs = 0u64;
        for (i, tile) in tiles.iter().enumerate() {
            let sub_acc = acc.map(|a| std::slice::from_ref(&a[i]));
            let r = simulate_shared_wires(rows, cols, std::slice::from_ref(tile), sub_acc);
            load_cycles = load_cycles.max(r.load_cycles);
            stream_cycles = stream_cycles.max(r.stream_cycles);
            macs += r.macs;
            outputs.extend(r.outputs);
        }
        StepResult { outputs, load_cycles, stream_cycles, macs }
    }
}

fn validate_tiles(rows: usize, cols: usize, tiles: &[StepTile]) {
    assert!(!tiles.is_empty(), "no tiles");
    let mut ranges: Vec<(usize, usize)> = Vec::new();
    for (i, t) in tiles.iter().enumerate() {
        assert_eq!(t.x.rank(), 2, "tile {i} x rank");
        assert_eq!(t.w.rank(), 2, "tile {i} w rank");
        let (_, k) = (t.x.shape()[0], t.x.shape()[1]);
        let (kw, width) = (t.w.shape()[0], t.w.shape()[1]);
        assert_eq!(k, kw, "tile {i} K mismatch");
        assert!(k <= rows, "tile {i} K {k} > array rows {rows}");
        assert!(t.col0 + width <= cols, "tile {i} overflows array width");
        ranges.push((t.col0, t.col0 + width));
    }
    ranges.sort_unstable();
    for w in ranges.windows(2) {
        assert!(w[0].1 <= w[1].0, "tile column ranges overlap");
    }
}

fn simulate_shared_wires(
    rows: usize,
    cols: usize,
    tiles: &[StepTile],
    acc: Option<&[Tensor]>,
) -> StepResult {
    let num_p = tiles.len();

    // ---- Load step ① ------------------------------------------------
    // Column c's weight vector shifts down from the load buffer; all
    // columns in parallel, h_max cycles for the deepest tile.
    let h_max = tiles.iter().map(|t| t.w.shape()[0]).max().unwrap();
    let mut lr = vec![vec![0.0f32; cols]; rows];
    // Column ownership map (usize::MAX = unowned).
    let mut owner = vec![usize::MAX; cols];
    for (p, t) in tiles.iter().enumerate() {
        let (kd, width) = (t.w.shape()[0], t.w.shape()[1]);
        for c in 0..width {
            owner[t.col0 + c] = p;
        }
        for k in 0..kd {
            for c in 0..width {
                lr[k][t.col0 + c] = t.w.at2(k, c);
            }
        }
    }
    // Shifting h_max rows down a column register chain takes h_max cycles
    // (one injection per cycle per column); we model the end state directly
    // and account the cycles — the shift itself is value-exact because the
    // chain is a pure delay line (see pe::tests::load_mode_shifts_weights_down).
    let load_cycles = h_max as u64;

    // ---- Feed/calculate step ② + drain ③ -----------------------------
    // fd[k][c]: the feed slot currently latched at PE (k, c).
    let mut fd = vec![vec![BUBBLE; cols]; rows];
    // Psum delay pipes: pipe[k][c] connects row k-1 -> row k with depth P.
    // pipe[0] is the zero-injection stage (depth 1 conceptually; handled
    // inline).  pipe[rows] is the drain port.
    let mut pipes: Vec<Vec<VecDeque<PsumSlot>>> = (0..=rows)
        .map(|_| (0..cols).map(|_| VecDeque::from(vec![PSUM_BUBBLE; num_p])).collect())
        .collect();

    let mut outputs: Vec<Tensor> = tiles
        .iter()
        .enumerate()
        .map(|(i, t)| match acc {
            Some(a) => {
                assert_eq!(a[i].shape(), &[t.x.shape()[0], t.w.shape()[1]], "acc shape tile {i}");
                a[i].clone()
            }
            None => Tensor::zeros(vec![t.x.shape()[0], t.w.shape()[1]]),
        })
        .collect();

    let expected: u64 = tiles.iter().map(|t| (t.x.shape()[0] * t.w.shape()[1]) as u64).sum();
    let mut collected = 0u64;
    let mut macs = 0u64;
    let mut last_collect_cycle = 0u64;

    // Safety cap: generous upper bound on the schedule length.
    let sr_max = tiles.iter().map(|t| t.x.shape()[0]).max().unwrap();
    let cap = (num_p as u64) * ((sr_max + rows) as u64 + 4) + (cols as u64) + 16;

    for t in 0..cap {
        if collected == expected {
            break;
        }
        // (1) Advance the feed pipeline: shift right, inject at column 0.
        for k in 0..rows {
            for c in (1..cols).rev() {
                fd[k][c] = fd[k][c - 1];
            }
            fd[k][0] = inject(tiles, num_p, k, t);
        }
        // (2) Each PE computes; psum slots advance one pipe stage.
        for k in 0..rows {
            for c in 0..cols {
                // Incoming psum: row 0 gets a zero tagged like its feed;
                // deeper rows pop the delay pipe from above.
                let incoming = if k == 0 {
                    let f = fd[0][c];
                    PsumSlot { value: 0.0, tenant: f.tenant, s: f.s, valid: f.valid }
                } else {
                    pipes[k][c].pop_front().unwrap()
                };
                let f = fd[k][c];
                let mul_en = f.valid && owner[c] == f.tenant;
                let out = if mul_en {
                    // Alignment self-check: the psum slot must belong to the
                    // same (tenant, stream row) as the feed value.
                    assert!(
                        incoming.valid && incoming.tenant == f.tenant && incoming.s == f.s,
                        "psum/feed misalignment at PE[{k}][{c}] cycle {t}: \
                         psum ({},{}) vs feed ({},{})",
                        incoming.tenant,
                        incoming.s,
                        f.tenant,
                        f.s
                    );
                    macs += 1;
                    PsumSlot { value: incoming.value + f.value * lr[k][c], ..incoming }
                } else {
                    incoming // Mul_En=0: pass through unchanged (Fig. 7)
                };
                // Push below: rows beyond the tile's K depth hold zero
                // weights, so letting every psum traverse all `rows` rows is
                // value-exact; the *timing* consequence (full-height drain)
                // matches the fixed-depth physical column.
                pipes[k + 1][c].push_back(out);
            }
        }
        // (3) Drain: collect matching slots at the bottom of each column.
        for c in 0..cols {
            let slot = pipes[rows][c].pop_front().unwrap();
            if slot.valid && slot.tenant != usize::MAX && owner[c] == slot.tenant {
                let tile = &tiles[slot.tenant];
                let local_c = c - tile.col0;
                let prev = outputs[slot.tenant].at2(slot.s, local_c);
                outputs[slot.tenant].set2(slot.s, local_c, prev + slot.value);
                collected += 1;
                last_collect_cycle = t;
            }
        }
    }
    assert_eq!(collected, expected, "functional sim did not drain all outputs within {cap} cycles");

    StepResult { outputs, load_cycles, stream_cycles: last_collect_cycle + 1, macs }
}

/// Feed injection at PE[k][0] on cycle `t`: slot `p = t mod P` carries
/// element `x[p][s][k]` with `s = (t - p)/P - k` when in range.
fn inject(tiles: &[StepTile], num_p: usize, k: usize, t: u64) -> FeedSlot {
    let p = (t % num_p as u64) as usize;
    let base = (t / num_p as u64) as i64;
    let s = base - k as i64;
    let tile = &tiles[p];
    let (sr, kd) = (tile.x.shape()[0], tile.x.shape()[1]);
    if k < kd && s >= 0 && (s as usize) < sr {
        FeedSlot { value: tile.x.at2(s as usize, k), tenant: p, s: s as usize, valid: true }
    } else {
        BUBBLE
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_tensor(rng: &mut Rng, shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.gen_f32() - 0.5).collect())
    }

    #[test]
    fn single_tile_matches_matmul() {
        let mut rng = Rng::new(1);
        let x = rand_tensor(&mut rng, vec![6, 4]);
        let w = rand_tensor(&mut rng, vec![4, 5]);
        let want = x.matmul(&w);
        for interleave in [false, true] {
            let r = simulate_step(4, 8, &[StepTile { x: x.clone(), w: w.clone(), col0: 0 }], interleave, None);
            assert!(r.outputs[0].max_abs_diff(&want) < 1e-5);
            assert_eq!(r.macs, 6 * 4 * 5);
        }
    }

    #[test]
    fn single_tile_cycle_count_formula() {
        // P=1, tile at col0: stream = Sr + h + col0 + w - 2, load = h.
        for (sr, k, w, col0, rows, cols) in
            [(6usize, 4usize, 5usize, 0usize, 4usize, 8usize), (3, 2, 2, 3, 2, 8), (10, 8, 8, 0, 8, 8), (1, 1, 1, 0, 1, 1)]
        {
            let mut rng = Rng::new(7);
            let x = rand_tensor(&mut rng, vec![sr, k]);
            let wt = rand_tensor(&mut rng, vec![k, w]);
            let r = simulate_step(rows, cols, &[StepTile { x, w: wt, col0 }], true, None);
            assert_eq!(r.load_cycles, k as u64, "load for k={k}");
            // Psum traverses the FULL array height (rows), not just the
            // tile's k rows — the physical column has fixed depth.  The
            // drain port adds one more pipe stage (P = 1 here).
            let want = (sr + rows + col0 + w - 1) as u64;
            assert_eq!(r.stream_cycles, want, "stream for sr={sr} k={k} w={w} col0={col0} rows={rows}");
        }
    }

    #[test]
    fn two_tenants_isolated_and_correct() {
        let mut rng = Rng::new(2);
        let t0 = StepTile { x: rand_tensor(&mut rng, vec![5, 3]), w: rand_tensor(&mut rng, vec![3, 2]), col0: 0 };
        let t1 = StepTile { x: rand_tensor(&mut rng, vec![4, 3]), w: rand_tensor(&mut rng, vec![3, 4]), col0: 2 };
        for interleave in [false, true] {
            let r = simulate_step(3, 6, &[t0.clone(), t1.clone()], interleave, None);
            assert!(r.outputs[0].max_abs_diff(&t0.x.matmul(&t0.w)) < 1e-5);
            assert!(r.outputs[1].max_abs_diff(&t1.x.matmul(&t1.w)) < 1e-5);
        }
    }

    #[test]
    fn foreign_traversal_does_not_corrupt() {
        // Tenant 1 sits to the RIGHT of tenant 0, so tenant 1's stream
        // passes through tenant 0's columns with Mul_En=0.  Perturbing
        // tenant 1's data must leave tenant 0's output bit-identical.
        let mut rng = Rng::new(3);
        let t0 = StepTile { x: rand_tensor(&mut rng, vec![4, 2]), w: rand_tensor(&mut rng, vec![2, 2]), col0: 0 };
        let t1a = StepTile { x: rand_tensor(&mut rng, vec![4, 2]), w: rand_tensor(&mut rng, vec![2, 2]), col0: 2 };
        let mut t1b = t1a.clone();
        t1b.x = rand_tensor(&mut rng, vec![4, 2]);
        let ra = simulate_step(2, 4, &[t0.clone(), t1a], true, None);
        let rb = simulate_step(2, 4, &[t0, t1b], true, None);
        assert_eq!(ra.outputs[0], rb.outputs[0]);
        assert_ne!(ra.outputs[1], rb.outputs[1]);
    }

    #[test]
    fn interleaving_slows_streams_by_p() {
        // Shared wires serialize the feeds: stream time scales ~P vs the
        // independent-port model.
        let mut rng = Rng::new(4);
        let mk = |col0, rng: &mut Rng| StepTile {
            x: rand_tensor(rng, vec![60, 4]),
            w: rand_tensor(rng, vec![4, 4]),
            col0,
        };
        let tiles = vec![mk(0, &mut rng), mk(4, &mut rng), mk(8, &mut rng), mk(12, &mut rng)];
        let shared = simulate_step(4, 16, &tiles, true, None);
        let indep = simulate_step(4, 16, &tiles, false, None);
        assert!(
            shared.stream_cycles > 3 * indep.stream_cycles,
            "shared {} vs indep {}",
            shared.stream_cycles,
            indep.stream_cycles
        );
        // Numerics identical either way.
        for (a, b) in shared.outputs.iter().zip(&indep.outputs) {
            assert!(a.max_abs_diff(b) < 1e-5);
        }
    }

    #[test]
    fn interleaved_cycle_count_formula() {
        // P tenants, tile p at slot p: row rows-1 emits (s, c) at
        // P*(s + rows - 1) + p + c, and the drain pipe adds P more cycles;
        // stream cycles = max_p [P*(sr_p-1+rows-1) + p + col0_p + w_p - 1]
        // + P + 1.
        let mut rng = Rng::new(5);
        let tiles = vec![
            StepTile { x: rand_tensor(&mut rng, vec![7, 3]), w: rand_tensor(&mut rng, vec![3, 2]), col0: 0 },
            StepTile { x: rand_tensor(&mut rng, vec![5, 3]), w: rand_tensor(&mut rng, vec![3, 3]), col0: 2 },
            StepTile { x: rand_tensor(&mut rng, vec![9, 2]), w: rand_tensor(&mut rng, vec![2, 2]), col0: 5 },
        ];
        let rows = 3usize;
        let p_n = tiles.len() as u64;
        let r = simulate_step(rows, 8, &tiles, true, None);
        let want = tiles
            .iter()
            .enumerate()
            .map(|(p, t)| {
                p_n * (t.x.shape()[0] as u64 - 1 + rows as u64 - 1)
                    + p as u64
                    + (t.col0 + t.w.shape()[1] - 1) as u64
            })
            .max()
            .unwrap()
            + p_n
            + 1;
        assert_eq!(r.stream_cycles, want);
    }

    #[test]
    fn acc_accumulates_across_folds() {
        // Two K-folds of a K=6 GEMM on a 3-row array, chained through acc.
        let mut rng = Rng::new(6);
        let x = rand_tensor(&mut rng, vec![5, 6]);
        let w = rand_tensor(&mut rng, vec![6, 4]);
        let slice2 = |t: &Tensor, k0: usize, kn: usize, cols: usize| {
            Tensor::from_fn(vec![t.shape()[0], kn], |i| {
                let r = i / kn;
                let c = i % kn;
                let _ = cols;
                t.at2(r, k0 + c)
            })
        };
        let x0 = slice2(&x, 0, 3, 6);
        let x1 = slice2(&x, 3, 3, 6);
        let w0 = Tensor::from_fn(vec![3, 4], |i| w.at2(i / 4, i % 4));
        let w1 = Tensor::from_fn(vec![3, 4], |i| w.at2(3 + i / 4, i % 4));
        let r0 = simulate_step(3, 4, &[StepTile { x: x0, w: w0, col0: 0 }], true, None);
        let r1 = simulate_step(3, 4, &[StepTile { x: x1, w: w1, col0: 0 }], true, Some(&r0.outputs));
        assert!(r1.outputs[0].max_abs_diff(&x.matmul(&w)) < 1e-5);
    }

    #[test]
    #[should_panic(expected = "overlap")]
    fn overlapping_tiles_rejected() {
        let mut rng = Rng::new(8);
        let a = StepTile { x: rand_tensor(&mut rng, vec![2, 2]), w: rand_tensor(&mut rng, vec![2, 3]), col0: 0 };
        let b = StepTile { x: rand_tensor(&mut rng, vec![2, 2]), w: rand_tensor(&mut rng, vec![2, 3]), col0: 2 };
        simulate_step(2, 8, &[a, b], true, None);
    }
}

#[cfg(test)]
mod horizontal_partitioning {
    //! Why the paper partitions only vertically (§3.2): the Y-dimension
    //! wires carry partial sums downward and *add* along the way, so two
    //! tenants stacked vertically in the same columns are summed
    //! inseparably at the drain port — there is one accumulation chain
    //! per column and no architectural way to split it.
    use super::*;
    use crate::util::rng::Rng;

    fn rand_tensor(rng: &mut Rng, shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.gen_f32() - 0.5).collect())
    }

    #[test]
    fn vertical_stacking_sums_tenants_inseparably() {
        // Tenant A occupies rows 0..2, tenant B rows 2..4 of the same
        // columns.  Feeding both streams yields exactly xA@wA + xB@wB at
        // the bottom — neither tenant's result is recoverable.
        let mut rng = Rng::new(42);
        let (xa, wa) = (rand_tensor(&mut rng, vec![5, 2]), rand_tensor(&mut rng, vec![2, 3]));
        let (xb, wb) = (rand_tensor(&mut rng, vec![5, 2]), rand_tensor(&mut rng, vec![2, 3]));

        // Stacked occupancy = one fused tile with concatenated K.
        let x_cat = Tensor::from_fn(vec![5, 4], |i| {
            let (r, c) = (i / 4, i % 4);
            if c < 2 { xa.at2(r, c) } else { xb.at2(r, c - 2) }
        });
        let w_cat = Tensor::from_fn(vec![4, 3], |i| {
            let (r, c) = (i / 3, i % 3);
            if r < 2 { wa.at2(r, c) } else { wb.at2(r - 2, c) }
        });
        let r = simulate_step(4, 3, &[StepTile { x: x_cat, w: w_cat, col0: 0 }], true, None);

        // The drain holds the SUM of both tenants' GEMMs...
        let mut want_sum = xa.matmul(&wa);
        let b_out = xb.matmul(&wb);
        for (o, b) in want_sum.data_mut().iter_mut().zip(b_out.data()) {
            *o += b;
        }
        assert!(r.outputs[0].max_abs_diff(&want_sum) < 1e-5);
        // ...and is NOT either tenant's own result.
        assert!(r.outputs[0].max_abs_diff(&xa.matmul(&wa)) > 0.1);
        assert!(r.outputs[0].max_abs_diff(&b_out) > 0.1);
    }
}
