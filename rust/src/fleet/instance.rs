//! One accelerator of the fleet: an owned [`Engine`] + scheduler pair
//! with an admission queue, tenant-slot management, and streaming
//! accounting.
//!
//! The driver feeds each instance its (router-fixed) admission sequence
//! and advances it wave-by-wave to a cycle horizon; between waves the
//! router never consults instance state, so instances are free to run on
//! any worker thread.  Slot recycling ([`Engine::release`]) keeps the
//! engine's pool bounded by the live-tenant cap however many requests
//! stream through.

use std::collections::VecDeque;

use crate::energy::components::{EnergyModel, Precision};
use crate::energy::Estimator;
use crate::sim_core::{Engine, Scheduler};
use crate::workloads::dnng::{DnnId, WorkloadPool};

use super::metrics::{ClassAccum, FleetObserver, InstanceReport};
use super::router::{Assignment, BatchInfo};
use super::InstanceConfig;

/// A batch waiting to enter its instance.
#[derive(Debug)]
struct Queued {
    t: u64,
    dnn: crate::workloads::dnng::Dnn,
    batch: BatchInfo,
}

/// One fleet member: engine + policy + queues + tallies.
pub struct Instance {
    pub name: String,
    policy_label: String,
    engine: Engine,
    sched: Box<dyn Scheduler + Send>,
    obs: FleetObserver,
    /// Admissions delivered by the driver, time-ordered, not yet offered
    /// to the engine.
    incoming: VecDeque<Queued>,
    /// Admitted-but-waiting batches (all tenant slots busy).
    waiting: VecDeque<Queued>,
    /// Live tenants: engine id → batch bookkeeping.
    live: Vec<(DnnId, BatchInfo)>,
    slots: usize,
    queue_cap: usize,
    pes: u64,
    energy_model: EnergyModel,
    /// Per-class tallies, merged fleet-wide at the end.
    pub accum: [ClassAccum; 3],
    pub admitted_batches: u64,
    pub completed_batches: u64,
    pub dropped_batches: u64,
}

impl Instance {
    pub fn new(cfg: &InstanceConfig, slots: usize, queue_cap: usize) -> Instance {
        let mut sched = cfg.policy.build(&cfg.sched);
        // An empty pool is valid: every tenant arrives via admit().
        let mut engine = Engine::new(&WorkloadPool::new(&cfg.name, vec![]), cfg.sched.geom);
        engine.start(&mut *sched);
        let precision = match cfg.sched.buffers.dtype_bytes {
            1 => Precision::Int8,
            2 => Precision::Fp16,
            _ => Precision::Fp32,
        };
        let energy_model = EnergyModel::build(cfg.sched.geom, &cfg.sched.buffers, precision);
        Instance {
            name: cfg.name.clone(),
            policy_label: cfg.policy.label(),
            engine,
            sched,
            obs: FleetObserver::default(),
            incoming: VecDeque::new(),
            waiting: VecDeque::new(),
            live: Vec::new(),
            slots: slots.max(1),
            queue_cap: queue_cap.max(1),
            pes: cfg.sched.geom.rows * cfg.sched.geom.cols,
            energy_model,
            accum: Default::default(),
            admitted_batches: 0,
            completed_batches: 0,
            dropped_batches: 0,
        }
    }

    /// Accept one routed batch (driver thread, between waves).  Admission
    /// times must arrive nondecreasing — the router guarantees it.
    /// `incoming` is a staging area bounded by the driver's chunk size;
    /// the admission-queue cap is enforced at *simulated* time (see
    /// [`Instance::run_until`]) so drop behavior cannot depend on how
    /// the stream is chunked.
    pub fn deliver(&mut self, a: Assignment) {
        debug_assert!(
            self.incoming.back().map_or(true, |q| q.t <= a.t),
            "router emissions must be time-monotone per instance"
        );
        self.incoming.push_back(Queued { t: a.t, dnn: a.dnn, batch: a.batch });
    }

    /// Queue overflow: every member of the batch is dropped with reason
    /// `queue_full`, counted against its class's SLO attainment.
    fn drop_batch(&mut self, q: Queued) {
        self.accum[q.batch.class.index()].dropped += q.batch.members.len() as u64;
        self.dropped_batches += 1;
    }

    /// Admit `q` into a free tenant slot at `t` (or the engine frontier,
    /// whichever is later) and arm its tightest member deadline.
    fn admit_now(&mut self, q: Queued) {
        let t = q.t.max(self.engine.now());
        let id = self.engine.admit(q.dnn, t);
        if let Some(d) = q.batch.engine_deadline {
            self.engine.push_deadline(id, d.max(t));
        }
        self.live.push((id, q.batch));
        self.admitted_batches += 1;
    }

    /// Reap finished tenants: record their members' latencies, release
    /// the engine slot, and backfill from the waiting queue.
    fn reap(&mut self) {
        let mut i = 0;
        while i < self.live.len() {
            if self.engine.dnn_done(self.live[i].0) {
                let (id, batch) = self.live.swap_remove(i);
                let (first, done) = self.obs.take_done(id);
                self.finish_batch(batch, first, done);
                self.engine.release(id, &mut *self.sched);
            } else {
                i += 1;
            }
        }
        while self.live.len() < self.slots {
            let Some(q) = self.waiting.pop_front() else { break };
            self.admit_now(q);
        }
    }

    fn finish_batch(&mut self, batch: BatchInfo, first: u64, done: u64) {
        let acc = &mut self.accum[batch.class.index()];
        for &(arrival, deadline) in &batch.members {
            acc.completed += 1;
            acc.latency.record(done.saturating_sub(arrival));
            acc.queue_cycles += u128::from(first.saturating_sub(arrival));
            acc.service_cycles += u128::from(done.saturating_sub(first));
            if deadline.map_or(true, |d| done <= d) {
                acc.slo_ok += 1;
            }
        }
        self.completed_batches += 1;
    }

    /// Advance the instance to cycle `horizon`: interleave queued
    /// admissions with engine steps in time order, reaping completed
    /// tenants as slots free up.  `u64::MAX` drains everything.
    pub fn run_until(&mut self, horizon: u64) {
        loop {
            // Admissions waiting on a free slot gate later arrivals too
            // (FIFO admission): only pull from `incoming` when the slot
            // queue is empty or capacity exists.
            if self.live.len() < self.slots && self.waiting.is_empty() {
                if let Some(q) = self.incoming.front() {
                    let ta = q.t.max(self.engine.now());
                    let admit_first = match self.engine.next_event_time() {
                        Some(te) => ta <= te && ta <= horizon,
                        None => ta <= horizon,
                    };
                    if admit_first {
                        let q = self.incoming.pop_front().expect("peeked");
                        self.admit_now(q);
                        // Coalesce same-cycle admissions: each admission
                        // posts an Arrival at `ta`, so the outer loop would
                        // re-admit every same-cycle follower one iteration
                        // (and one event-queue probe) at a time anyway.
                        // Draining them here preserves that exact order
                        // while skipping the per-admission round trips.
                        if crate::sim_core::event_coalesce_enabled() {
                            while self.live.len() < self.slots {
                                match self.incoming.front() {
                                    Some(n) if n.t.max(self.engine.now()) == ta => {
                                        let n = self.incoming.pop_front().expect("peeked");
                                        self.admit_now(n);
                                    }
                                    _ => break,
                                }
                            }
                        }
                        continue;
                    }
                }
            } else if let Some(q) = self.incoming.front() {
                // All slots busy (or FIFO blocked): stage arrivals that
                // have "happened" by the engine frontier into the waiting
                // queue so reap() can backfill them in order; arrivals
                // beyond the cap are dropped at their own (simulated)
                // arrival instant.
                let staged = q.t <= self.engine.now().min(horizon);
                if staged {
                    let q = self.incoming.pop_front().expect("peeked");
                    if self.waiting.len() >= self.queue_cap {
                        self.drop_batch(q);
                    } else {
                        self.waiting.push_back(q);
                    }
                    continue;
                }
            }
            match self.engine.next_event_time() {
                Some(te) if te <= horizon => {
                    self.engine.step(&mut *self.sched, &mut self.obs);
                    self.reap();
                }
                _ => {
                    // No engine work inside the horizon; a queued arrival
                    // beyond the frontier may still be admissible.
                    if self.live.len() < self.slots
                        && self.waiting.is_empty()
                        && self.incoming.front().map_or(false, |q| q.t <= horizon)
                    {
                        let q = self.incoming.pop_front().expect("peeked");
                        self.admit_now(q);
                        continue;
                    }
                    break;
                }
            }
        }
    }

    /// Engine events processed (admissions + layers + preemptions) — the
    /// bench throughput numerator.
    pub fn events(&self) -> u64 {
        self.admitted_batches + self.obs.layers_completed + self.obs.preemptions
    }

    pub fn makespan(&self) -> u64 {
        self.obs.makespan
    }

    pub fn busy_pe_cycles(&self) -> u128 {
        self.obs.busy_pe_cycles
    }

    pub fn preemptions(&self) -> u64 {
        self.obs.preemptions
    }

    /// Nothing queued, nothing live — the stream has fully drained.
    pub fn drained(&self) -> bool {
        self.incoming.is_empty() && self.waiting.is_empty() && self.live.is_empty()
    }

    /// Final per-instance report (energy priced over this instance's own
    /// makespan via the shared estimator).
    pub fn report(&self) -> InstanceReport {
        let mut est = Estimator::new(self.energy_model.clone());
        est.record("fleet", &self.obs.activity);
        let energy = est.finish(self.obs.makespan);
        let denom = self.obs.makespan as f64 * self.pes as f64;
        InstanceReport {
            name: self.name.clone(),
            policy: self.policy_label.clone(),
            admitted_batches: self.admitted_batches,
            completed_batches: self.completed_batches,
            dropped_batches: self.dropped_batches,
            preemptions: self.obs.preemptions,
            makespan: self.obs.makespan,
            utilization: if denom > 0.0 { self.obs.busy_pe_cycles as f64 / denom } else { 0.0 },
            vector_layers: self.obs.vector_layers,
            energy_j: energy.total_j(),
            events: self.events(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::SchedulerConfig;
    use crate::fleet::{FleetPolicy, SloClass};
    use crate::workloads::models;

    fn assignment(t: u64, seq: u64) -> Assignment {
        let mut dnn = (models::by_name("NCF").unwrap().build)();
        dnn.name = format!("NCF#b{seq}");
        Assignment {
            instance: 0,
            t,
            dnn,
            batch: BatchInfo {
                class: SloClass::BestEffort,
                model: 0,
                members: vec![(t, None)],
                engine_deadline: None,
            },
        }
    }

    fn instance(slots: usize, queue_cap: usize) -> Instance {
        let cfg = InstanceConfig {
            name: "acc0".to_string(),
            sched: SchedulerConfig::default(),
            policy: FleetPolicy::Dynamic,
        };
        Instance::new(&cfg, slots, queue_cap)
    }

    #[test]
    fn streams_requests_through_bounded_slots() {
        let mut inst = instance(2, 64);
        for i in 0..6u64 {
            inst.deliver(assignment(i * 1_000, i));
        }
        inst.run_until(u64::MAX);
        assert!(inst.drained());
        assert_eq!(inst.admitted_batches, 6);
        assert_eq!(inst.completed_batches, 6);
        assert_eq!(inst.accum[SloClass::BestEffort.index()].completed, 6);
        assert_eq!(inst.dropped_batches, 0);
        assert!(inst.makespan() > 0);
        let r = inst.report();
        assert!(r.utilization > 0.0 && r.utilization <= 1.0);
        assert!(r.energy_j > 0.0);
    }

    #[test]
    fn horizon_waves_accumulate_like_one_big_run() {
        let run = |horizons: &[u64]| {
            let mut inst = instance(2, 64);
            for i in 0..8u64 {
                inst.deliver(assignment(i * 2_000, i));
            }
            for &h in horizons {
                inst.run_until(h);
            }
            inst.run_until(u64::MAX);
            (inst.completed_batches, inst.makespan(), inst.busy_pe_cycles())
        };
        assert_eq!(run(&[]), run(&[1_000, 5_000, 9_000, 100_000]));
    }

    #[test]
    fn queue_overflow_drops_with_members_counted() {
        let mut inst = instance(1, 2);
        // Deliver far more than slots+queue can hold at one instant.
        for i in 0..10u64 {
            inst.deliver(assignment(i, i));
        }
        inst.run_until(u64::MAX);
        assert!(inst.dropped_batches > 0);
        let acc = &inst.accum[SloClass::BestEffort.index()];
        assert_eq!(acc.completed + acc.dropped, 10);
        assert!(inst.drained());
    }
}
