//! Fleet-side accounting: streaming latency histograms, per-class SLO
//! tallies, per-instance observers, and the final [`FleetReport`].
//!
//! Everything here is O(1) per event and O(instances + buckets) in
//! memory — nothing grows with the request count, which is what lets
//! `mtsa fleet` stream millions of arrivals.

use std::collections::BTreeMap;

use crate::coordinator::DispatchRecord;
use crate::sim::activity::Activity;
use crate::sim::partitioned::Tile;
use crate::sim_core::Observer;
use crate::workloads::dnng::{DnnId, LayerId};

use super::SloClass;

/// Linear-then-geometric cycle histogram (4 fraction bits): exact below
/// 32 cycles, ≤ ~6% relative bucket width above, 976 buckets covering
/// all of `u64`.  Merging and recording are integer-only, so per-class
/// percentiles are deterministic and order-independent.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CycleHistogram {
    counts: Vec<u64>,
    n: u64,
}

/// 32 exact buckets + 59 octaves × 16 sub-buckets.
const LINEAR: usize = 32;
const SUB: usize = 16;
const NBUCKETS: usize = LINEAR + 59 * SUB;

impl Default for CycleHistogram {
    fn default() -> CycleHistogram {
        CycleHistogram { counts: vec![0; NBUCKETS], n: 0 }
    }
}

impl CycleHistogram {
    fn bucket_of(v: u64) -> usize {
        if v < LINEAR as u64 {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros() as usize; // >= 5
        let frac = ((v >> (msb - 4)) & 0xF) as usize;
        LINEAR + (msb - 5) * SUB + frac
    }

    /// Smallest value landing in bucket `b` — the value percentiles
    /// report (a conservative lower bound of the true order statistic).
    fn lower_bound(b: usize) -> u64 {
        if b < LINEAR {
            return b as u64;
        }
        let msb = 5 + (b - LINEAR) / SUB;
        let frac = ((b - LINEAR) % SUB) as u64;
        (1u64 << msb) + (frac << (msb - 4))
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket_of(v)] += 1;
        self.n += 1;
    }

    pub fn merge(&mut self, other: &CycleHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.n += other.n;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// The `p`-quantile (`0 < p <= 1`) as the lower bound of the bucket
    /// holding the rank-`ceil(p·n)` sample; 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.n == 0 {
            return 0;
        }
        let rank = ((p * self.n as f64).ceil() as u64).clamp(1, self.n);
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::lower_bound(b);
            }
        }
        Self::lower_bound(NBUCKETS - 1)
    }
}

/// Running per-class tallies, accumulated per instance then merged.
#[derive(Debug, Clone, Default)]
pub struct ClassAccum {
    pub completed: u64,
    pub dropped: u64,
    /// Completed requests that met their deadline (deadline-free classes
    /// count every completion).
    pub slo_ok: u64,
    pub latency: CycleHistogram,
    /// Σ cycles between arrival and first dispatch of the batch.
    pub queue_cycles: u128,
    /// Σ cycles between first dispatch and completion.
    pub service_cycles: u128,
}

impl ClassAccum {
    pub fn merge(&mut self, other: &ClassAccum) {
        self.completed += other.completed;
        self.dropped += other.dropped;
        self.slo_ok += other.slo_ok;
        self.latency.merge(&other.latency);
        self.queue_cycles += other.queue_cycles;
        self.service_cycles += other.service_cycles;
    }
}

/// Final per-class section of the fleet report.
#[derive(Debug, Clone)]
pub struct ClassReport {
    pub class: SloClass,
    pub share: f64,
    pub slack: Option<f64>,
    pub generated: u64,
    pub completed: u64,
    pub dropped: u64,
    pub slo_ok: u64,
    /// `slo_ok / generated` — drops count as misses, so attainment is
    /// judged against offered load, not survivors.
    pub attainment: f64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub mean_queue_cycles: f64,
    pub mean_service_cycles: f64,
}

/// Final per-instance section of the fleet report.
#[derive(Debug, Clone)]
pub struct InstanceReport {
    pub name: String,
    pub policy: String,
    pub admitted_batches: u64,
    pub completed_batches: u64,
    pub dropped_batches: u64,
    pub preemptions: u64,
    pub makespan: u64,
    /// busy-PE-cycles / (makespan × PEs) of this instance — array PEs
    /// only; lane segments are billed to `vector_layers` instead.
    pub utilization: f64,
    /// Layers served by this instance's vector engine (0 on array-only
    /// instances).  Programmatic surface only: the fleet table/JSON stay
    /// byte-identical, heterogeneous fleets read it via the library API.
    pub vector_layers: u64,
    pub energy_j: f64,
    /// Engine events this instance processed (admissions + layer
    /// completions + preemptions) — the bench throughput denominator.
    pub events: u64,
}

/// Everything `mtsa fleet` reports (rendered by
/// [`report::fleet_table`](crate::report::fleet_table) /
/// [`report::fleet_json`](crate::report::fleet_json)).
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub classes: Vec<ClassReport>,
    pub instances: Vec<InstanceReport>,
    pub generated: u64,
    pub completed: u64,
    pub dropped: u64,
    /// Batches dispatched by the router (each occupies one tenant slot).
    pub batches: u64,
    /// Latest completion cycle across the fleet.
    pub makespan: u64,
    /// busy-PE-cycles / (makespan × total PEs).
    pub utilization: f64,
    pub energy_j: f64,
    /// `energy_j / completed` — the cost-per-query figure.
    pub cost_j_per_query: f64,
    pub events: u64,
    pub seed: u64,
}

impl FleetReport {
    /// Conservation invariant: every generated request is accounted for
    /// exactly once (completed or dropped-with-reason) in its class.
    pub fn conserved(&self) -> bool {
        self.generated == self.completed + self.dropped
            && self
                .classes
                .iter()
                .all(|c| c.generated == c.completed + c.dropped)
    }
}

/// Streaming per-instance observer: first-dispatch/completion cycles per
/// live DNN (bounded by the slot count — entries are removed on
/// [`FleetObserver::take_done`]), plus order-independent integer totals.
#[derive(Debug, Default)]
pub struct FleetObserver {
    first_dispatch: BTreeMap<DnnId, u64>,
    done_at: BTreeMap<DnnId, u64>,
    pub dispatches: u64,
    pub layers_completed: u64,
    /// Layers that ran on the instance's vector engine (0 unless its
    /// config carries `[vector]` lanes).  Lane segments are kept out of
    /// [`FleetObserver::busy_pe_cycles`] and
    /// [`FleetObserver::activity`] so array utilization and the array
    /// energy bill stay array-only, mirroring
    /// [`RunMetrics`](crate::coordinator::metrics::RunMetrics).
    pub vector_layers: u64,
    pub preemptions: u64,
    pub wasted_refill_cycles: u64,
    pub busy_pe_cycles: u128,
    pub activity: Activity,
    pub makespan: u64,
}

impl FleetObserver {
    /// Consume a finished DNN's `(first_dispatch, completion)` cycles,
    /// clearing its entries so the recycled id starts clean.
    pub fn take_done(&mut self, dnn: DnnId) -> (u64, u64) {
        let done = self.done_at.remove(&dnn).unwrap_or(0);
        let first = self.first_dispatch.remove(&dnn).unwrap_or(done);
        (first, done)
    }
}

impl Observer for FleetObserver {
    fn on_dispatch(&mut self, t: u64, dnn: DnnId, _layer: LayerId, _tile: Tile) {
        self.dispatches += 1;
        self.first_dispatch.entry(dnn).or_insert(t);
    }

    fn on_layer_complete(&mut self, rec: &DispatchRecord) {
        self.layers_completed += 1;
        if rec.lanes.is_some() {
            self.vector_layers += 1;
        } else {
            self.busy_pe_cycles +=
                u128::from(rec.tile.pes()) * u128::from(rec.t_end - rec.t_start);
            self.activity.add(&rec.activity);
        }
        let d = self.done_at.entry(rec.dnn).or_insert(0);
        *d = (*d).max(rec.t_end);
        self.makespan = self.makespan.max(rec.t_end);
    }

    fn on_preempt(&mut self, rec: &DispatchRecord, _replayed_folds: u64, wasted_cycles: u64) {
        self.preemptions += 1;
        self.wasted_refill_cycles += wasted_cycles;
        self.busy_pe_cycles +=
            u128::from(rec.tile.pes()) * u128::from(rec.t_end - rec.t_start);
        self.activity.add(&rec.activity);
        self.makespan = self.makespan.max(rec.t_end);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_are_exact_low_and_tight_high() {
        // Exact below the linear cutoff.
        for v in 0..32 {
            assert_eq!(CycleHistogram::bucket_of(v), v as usize);
            assert_eq!(CycleHistogram::lower_bound(v as usize), v);
        }
        // Boundary values land on buckets whose lower bound is themselves.
        for v in [32u64, 33, 63, 64, 1 << 20, u64::MAX >> 1] {
            let b = CycleHistogram::bucket_of(v);
            let lo = CycleHistogram::lower_bound(b);
            assert!(lo <= v, "lower bound {lo} above {v}");
            // Bucket width is < 1/16 of the value's octave.
            assert!((v - lo) as f64 <= v as f64 / 16.0 + 1.0, "{v} -> {lo}");
        }
        assert!(CycleHistogram::bucket_of(u64::MAX) < NBUCKETS);
    }

    #[test]
    fn percentiles_track_known_distributions() {
        let mut h = CycleHistogram::default();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.percentile(0.50);
        let p99 = h.percentile(0.99);
        assert!((470..=500).contains(&p50), "p50 = {p50}");
        assert!((930..=990).contains(&p99), "p99 = {p99}");
        assert_eq!(h.percentile(1.0), h.percentile(0.9999));
        assert_eq!(CycleHistogram::default().percentile(0.5), 0);
    }

    #[test]
    fn histogram_merge_equals_combined_recording() {
        let mut a = CycleHistogram::default();
        let mut b = CycleHistogram::default();
        let mut both = CycleHistogram::default();
        for v in 0..500u64 {
            let x = v * v % 7919;
            if v % 2 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
            both.record(x);
        }
        a.merge(&b);
        assert_eq!(a, both);
        assert_eq!(a.percentile(0.95), both.percentile(0.95));
    }
}
