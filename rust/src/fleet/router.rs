//! The fleet front end: per-model batching queues and estimate-based
//! placement.
//!
//! The router is strictly single-threaded and processes arrivals in time
//! order; every decision (batch membership, close times, placement,
//! random-k draws) is a pure function of the arrival stream and the
//! router's own seeded RNG.  That is the determinism keystone — once the
//! router has fixed each instance's admission sequence, the instances can
//! be simulated on any number of worker threads without changing a byte
//! of the report.
//!
//! This generalizes the least-loaded assignment the multi-array
//! comparator performs *inside* one engine
//! ([`MultiArrayPolicy::on_arrival`](crate::coordinator::multi_array::MultiArrayPolicy))
//! to whole accelerators: instead of accumulated MACs per chip, the
//! router scores instances by an estimated completion horizon
//! (`busy_until`) priced from isolated layer timings on each instance's
//! actual geometry — so heterogeneous fleets are scored fairly.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::coordinator::scenario::deadline_cycle;
use crate::profiler::{isolated_cycles, ProfileStore};
use crate::sim::buffers::BufferConfig;
use crate::sim::dataflow::ArrayGeometry;
use crate::util::rng::Rng;
use crate::workloads::dnng::Dnn;

use super::{Placement, SloClass, SloSpec};

/// Per-member bookkeeping a batch carries to its instance: each member's
/// arrival cycle and (optional) absolute deadline.
#[derive(Debug, Clone)]
pub struct BatchInfo {
    pub class: SloClass,
    pub model: usize,
    /// `(arrival_cycle, deadline)` per member request.
    pub members: Vec<(u64, Option<u64>)>,
    /// Tightest member deadline — armed on the engine so the
    /// deadline-driven preemption trigger sees the batch.
    pub engine_deadline: Option<u64>,
}

/// A batch the router has dispatched: the batched DNN (member count
/// folded into every layer's batch dimension), when it was emitted, and
/// where it goes.
#[derive(Debug, Clone)]
pub struct Assignment {
    pub instance: usize,
    /// Emission cycle (close time) — admission time on the instance.
    pub t: u64,
    pub dnn: Dnn,
    pub batch: BatchInfo,
}

/// An open (still collecting) batch of one `(model, class)` pair.
#[derive(Debug)]
struct OpenBatch {
    close_at: u64,
    /// Member arrival cycles.
    members: Vec<u64>,
}

/// The fleet router: batching queues + placement state.
pub struct Router {
    templates: Vec<Dnn>,
    /// Per-instance `(geometry, buffers)` used to price isolated runs.
    arrays: Vec<(ArrayGeometry, BufferConfig)>,
    placement: Placement,
    random_k: usize,
    classes: [SloSpec; 3],
    rng: Rng,
    /// Estimated completion horizon per instance.
    busy_until: Vec<u64>,
    /// Model whose weights are resident per instance (last placed).
    warm: Vec<Option<usize>>,
    /// Open batches keyed `(model, class index)`.
    open: BTreeMap<(usize, usize), OpenBatch>,
    /// Monotone batch sequence number (names stay unique under
    /// slot recycling).
    batch_seq: u64,
    /// Isolated-cycles memo keyed `(model, batch_k, rows, cols)`.
    iso_cache: BTreeMap<(usize, u64, u64, u64), u64>,
    /// Offline profile tables: cache misses read the precomputed
    /// `iso_a + batch·iso_b` totals instead of re-summing layer timings.
    tables: Option<Arc<ProfileStore>>,
    /// Batches dispatched so far.
    pub batches: u64,
}

impl Router {
    pub fn new(
        templates: Vec<Dnn>,
        arrays: Vec<(ArrayGeometry, BufferConfig)>,
        placement: Placement,
        random_k: usize,
        classes: [SloSpec; 3],
        rng: Rng,
    ) -> Router {
        assert!(!templates.is_empty() && !arrays.is_empty());
        let n = arrays.len();
        Router {
            templates,
            arrays,
            placement,
            random_k: random_k.clamp(1, n),
            classes,
            rng,
            busy_until: vec![0; n],
            warm: vec![None; n],
            open: BTreeMap::new(),
            batch_seq: 0,
            iso_cache: BTreeMap::new(),
            tables: None,
            batches: 0,
        }
    }

    /// Consult profile tables for isolated-run totals.  The table total
    /// equals the closed-form loop exactly (pinned in
    /// [`crate::profiler::table`]'s tests), so routing bytes do not
    /// change — only the per-miss cost does.
    pub fn with_tables(mut self, tables: Arc<ProfileStore>) -> Router {
        self.tables = Some(tables);
        self
    }

    /// Isolated cycles of model `model` at batch multiplier `k` on
    /// instance `inst`'s geometry: Σ over layers of the baseline
    /// (full-array) timing — the same price the scenario tier uses for
    /// slack-relative deadlines.
    fn isolated(&mut self, model: usize, k: u64, inst: usize) -> u64 {
        let (geom, bufs) = self.arrays[inst];
        let key = (model, k, geom.rows, geom.cols);
        if let Some(&c) = self.iso_cache.get(&key) {
            return c;
        }
        // One pricing path for every miss: profiled totals when a table
        // covers this (model, geometry), the shared closed-form loop in
        // [`isolated_cycles`] otherwise.
        let cycles = self
            .tables
            .as_deref()
            .and_then(|s| s.totals(geom, &self.templates[model].name))
            .map(|(a, b)| a.saturating_add(b.saturating_mul(k)))
            .unwrap_or_else(|| isolated_cycles(geom, &bufs, &self.templates[model], k));
        self.iso_cache.insert(key, cycles);
        cycles
    }

    /// Estimated completion horizon if the batch were sent to `inst` now.
    fn score(&mut self, t: u64, model: usize, k: u64, inst: usize) -> u64 {
        let iso = self.isolated(model, k, inst);
        self.busy_until[inst].max(t).saturating_add(iso)
    }

    /// Least-loaded over an explicit candidate list (ties by index).
    fn least_loaded_of(&mut self, t: u64, model: usize, k: u64, cands: &[usize]) -> (u64, usize) {
        let mut best: Option<(u64, usize)> = None;
        for &i in cands {
            let s = (self.score(t, model, k, i), i);
            if best.map_or(true, |b| s < b) {
                best = Some(s);
            }
        }
        best.expect("non-empty candidate list")
    }

    fn place(&mut self, t: u64, model: usize, k: u64) -> usize {
        let n = self.arrays.len();
        let all: Vec<usize> = (0..n).collect();
        match self.placement {
            Placement::LeastLoaded => self.least_loaded_of(t, model, k, &all).1,
            Placement::RandomK => {
                let mut cands: Vec<usize> = Vec::with_capacity(self.random_k);
                while cands.len() < self.random_k {
                    let c = self.rng.gen_range(n as u64) as usize;
                    if !cands.contains(&c) {
                        cands.push(c);
                    }
                }
                self.least_loaded_of(t, model, k, &cands).1
            }
            Placement::Affinity => {
                let warm: Vec<usize> =
                    (0..n).filter(|&i| self.warm[i] == Some(model)).collect();
                let (cold_score, cold) = self.least_loaded_of(t, model, k, &all);
                if warm.is_empty() {
                    return cold;
                }
                let (warm_score, warm_best) = self.least_loaded_of(t, model, k, &warm);
                // A warm hit skips the weight reload; tolerate queueing
                // behind the warm instance up to one batch-service time.
                let tolerance = self.isolated(model, k, warm_best);
                if warm_score <= cold_score.saturating_add(tolerance) {
                    warm_best
                } else {
                    cold
                }
            }
        }
    }

    /// Close and dispatch one batch at cycle `t`.
    fn dispatch(
        &mut self,
        model: usize,
        class: SloClass,
        t: u64,
        arrivals: Vec<u64>,
        out: &mut Vec<Assignment>,
    ) {
        let k = arrivals.len() as u64;
        let inst = self.place(t, model, k);
        // Batched requests share one tenant slot: one DNN with every
        // layer's batch dimension scaled by the member count (the DAG
        // edges are untouched — only the feed streams widen).
        let mut dnn = self.templates[model].clone();
        if k > 1 {
            for l in &mut dnn.layers {
                l.shape.n *= k;
            }
        }
        dnn.name = format!("{}#b{}", dnn.name, self.batch_seq);
        self.batch_seq += 1;
        // Per-member deadline: the scenario tier's slack-relative rule,
        // priced at single-request isolation on the *chosen* instance.
        let spec = &self.classes[class.index()];
        let slack = spec.slack;
        let iso1 = self.isolated(model, 1, inst);
        let members: Vec<(u64, Option<u64>)> = arrivals
            .into_iter()
            .map(|a| (a, slack.map(|s| deadline_cycle(a, iso1, s))))
            .collect();
        let engine_deadline = members.iter().filter_map(|&(_, d)| d).min();
        let iso_k = self.isolated(model, k, inst);
        self.busy_until[inst] = self.busy_until[inst].max(t).saturating_add(iso_k);
        self.warm[inst] = Some(model);
        self.batches += 1;
        out.push(Assignment {
            instance: inst,
            t,
            dnn,
            batch: BatchInfo { class, model, members, engine_deadline },
        });
    }

    /// Close every open batch whose window expired by cycle `t`, in
    /// close-time order (ties by `(model, class)`), so emissions stay
    /// time-monotone per instance regardless of map iteration order.
    pub fn close_due(&mut self, t: u64, out: &mut Vec<Assignment>) {
        let mut due: Vec<(u64, usize, usize)> = self
            .open
            .iter()
            .filter(|(_, b)| b.close_at <= t)
            .map(|(&(m, c), b)| (b.close_at, m, c))
            .collect();
        due.sort_unstable();
        for (close_at, m, c) in due {
            let b = self.open.remove(&(m, c)).expect("due batch present");
            self.dispatch(m, SloClass::ALL[c], close_at, b.members, out);
        }
    }

    /// Offer one arrival to the router.  Expired windows close first (so
    /// emission times never run backwards), then the request joins or
    /// opens its `(model, class)` batch — full batches dispatch
    /// immediately, unbatched classes pass straight through.
    pub fn offer(&mut self, t: u64, model: usize, class: SloClass, out: &mut Vec<Assignment>) {
        self.close_due(t, out);
        let spec = &self.classes[class.index()];
        if spec.max_batch <= 1 {
            self.dispatch(model, class, t, vec![t], out);
            return;
        }
        let (max_batch, window) = (spec.max_batch, spec.window);
        let key = (model, class.index());
        let full = {
            let b = self
                .open
                .entry(key)
                .or_insert_with(|| OpenBatch {
                    close_at: t.saturating_add(window),
                    members: Vec::new(),
                });
            b.members.push(t);
            b.members.len() >= max_batch
        };
        if full {
            let b = self.open.remove(&key).expect("full batch present");
            self.dispatch(model, class, t, b.members, out);
        }
    }

    /// Flush every still-open batch after the stream ends (each at its
    /// scheduled close time, which is past the final arrival).
    pub fn finish(&mut self, out: &mut Vec<Assignment>) {
        self.close_due(u64::MAX, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::models;

    fn templates() -> Vec<Dnn> {
        vec![
            (models::by_name("NCF").unwrap().build)(),
            (models::by_name("MelodyLSTM").unwrap().build)(),
        ]
    }

    fn classes() -> [SloSpec; 3] {
        [
            SloSpec { share: 0.3, slack: Some(4.0), max_batch: 1, window: 0 },
            SloSpec { share: 0.5, slack: Some(12.0), max_batch: 3, window: 10_000 },
            SloSpec { share: 0.2, slack: None, max_batch: 4, window: 50_000 },
        ]
    }

    fn router(placement: Placement) -> Router {
        let geom = ArrayGeometry::new(128, 128);
        let arrays = vec![(geom, BufferConfig::default()); 4];
        Router::new(templates(), arrays, placement, 2, classes(), Rng::new(7))
    }

    #[test]
    fn unbatched_class_passes_straight_through_least_loaded() {
        let mut r = router(Placement::LeastLoaded);
        let mut out = Vec::new();
        for t in [0u64, 10, 20, 30] {
            r.offer(t, 0, SloClass::LatencyCritical, &mut out);
        }
        assert_eq!(out.len(), 4);
        // Equal instances, near-simultaneous equal requests: round-robin
        // by index because each placement bumps the chosen horizon.
        let insts: Vec<usize> = out.iter().map(|a| a.instance).collect();
        assert_eq!(insts, vec![0, 1, 2, 3]);
        for a in &out {
            assert_eq!(a.batch.members.len(), 1);
            assert!(a.batch.engine_deadline.is_some());
            assert_eq!(a.dnn.layers[0].shape.n, r.templates[0].layers[0].shape.n);
        }
    }

    #[test]
    fn full_batch_dispatches_immediately_and_scales_feed_rows() {
        let mut r = router(Placement::LeastLoaded);
        let mut out = Vec::new();
        r.offer(0, 1, SloClass::BestEffort, &mut out);
        r.offer(5, 1, SloClass::BestEffort, &mut out);
        assert!(out.is_empty(), "window still open");
        r.offer(9, 1, SloClass::BestEffort, &mut out);
        assert_eq!(out.len(), 1, "max_batch=3 reached");
        let a = &out[0];
        assert_eq!(a.t, 9);
        assert_eq!(a.batch.members.len(), 3);
        assert_eq!(a.dnn.layers[0].shape.n, 3 * r.templates[1].layers[0].shape.n);
        // Tightest member deadline is the earliest arrival's.
        let d0 = a.batch.members[0].1.unwrap();
        assert_eq!(a.batch.engine_deadline, Some(d0));
        assert!(a.dnn.name.starts_with("MelodyLSTM#b"));
    }

    #[test]
    fn window_expiry_closes_partial_batches_in_time_order() {
        let mut r = router(Placement::LeastLoaded);
        let mut out = Vec::new();
        r.offer(0, 0, SloClass::Batch, &mut out); // closes at 50_000
        r.offer(100, 1, SloClass::BestEffort, &mut out); // closes at 10_100
        assert!(out.is_empty());
        // An arrival far in the future flushes both, earliest close first.
        r.offer(60_000, 0, SloClass::LatencyCritical, &mut out);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].t, 10_100);
        assert_eq!(out[1].t, 50_000);
        assert_eq!(out[2].t, 60_000);
        let mut last_per_inst: std::collections::BTreeMap<usize, u64> = Default::default();
        for a in &out {
            let e = last_per_inst.entry(a.instance).or_insert(0);
            assert!(a.t >= *e, "per-instance admission times must be monotone");
            *e = a.t;
        }
        // Batch class carries no deadline.
        assert_eq!(out[1].batch.engine_deadline, None);
    }

    #[test]
    fn finish_flushes_every_open_batch() {
        let mut r = router(Placement::Affinity);
        let mut out = Vec::new();
        r.offer(0, 0, SloClass::Batch, &mut out);
        r.offer(1, 1, SloClass::BestEffort, &mut out);
        assert!(out.is_empty());
        r.finish(&mut out);
        assert_eq!(out.len(), 2);
        assert_eq!(r.batches, 2);
    }

    #[test]
    fn random_k_is_deterministic_per_seed() {
        let run = |seed| {
            let geom = ArrayGeometry::new(128, 128);
            let arrays = vec![(geom, BufferConfig::default()); 8];
            let mut r =
                Router::new(templates(), arrays, Placement::RandomK, 3, classes(), Rng::new(seed));
            let mut out = Vec::new();
            for t in 0..20u64 {
                r.offer(t * 1000, (t % 2) as usize, SloClass::LatencyCritical, &mut out);
            }
            out.iter().map(|a| a.instance).collect::<Vec<_>>()
        };
        assert_eq!(run(11), run(11), "same seed, same placements");
        assert_ne!(run(11), run(12), "different seed explores differently");
    }

    #[test]
    fn tables_price_isolated_runs_identically() {
        use crate::profiler::{ProfileStore, ProfileTable};
        let geom = ArrayGeometry::new(128, 128);
        let bufs = BufferConfig::default();
        let tabs: Vec<ProfileTable> = ["NCF", "MelodyLSTM"]
            .iter()
            .map(|n| ProfileTable::build(n, &(models::by_name(n).unwrap().build)(), geom, &bufs))
            .collect();
        let store = Arc::new(ProfileStore::from_tables("test", tabs));
        let drive = |mut r: Router| {
            let mut out = Vec::new();
            for t in 0..30u64 {
                r.offer(t * 2_000, (t % 2) as usize, SloClass::ALL[(t % 3) as usize], &mut out);
            }
            r.finish(&mut out);
            out.iter().map(|a| (a.instance, a.t, a.batch.engine_deadline)).collect::<Vec<_>>()
        };
        let plain = drive(router(Placement::Affinity));
        let tabled = drive(router(Placement::Affinity).with_tables(store));
        assert_eq!(plain, tabled, "table totals must not change a single routing decision");
    }

    #[test]
    fn affinity_prefers_warm_instance_within_tolerance() {
        let mut r = router(Placement::Affinity);
        let mut out = Vec::new();
        // First request warms some instance for model 0.
        r.offer(0, 0, SloClass::LatencyCritical, &mut out);
        let first = out[0].instance;
        // A prompt same-model follow-up sticks to the warm instance even
        // though idle cold instances exist.
        r.offer(10, 0, SloClass::LatencyCritical, &mut out);
        assert_eq!(out[1].instance, first, "warm reuse within tolerance");
        // A different model goes elsewhere (cold least-loaded).
        r.offer(20, 1, SloClass::LatencyCritical, &mut out);
        assert_ne!(out[2].instance, first);
    }
}
