//! The fleet run loop: streaming generation → routing → parallel
//! instance waves → merged report.
//!
//! Arrivals are generated in bounded chunks (peak memory is independent
//! of the request count).  Each chunk is routed single-threaded (all
//! randomness lives here), then every instance advances to the chunk's
//! last arrival cycle on a deterministic worker pool — the same
//! claim-by-atomic-index pattern as [`sweep::run_sweep`](crate::sweep::run_sweep).
//! Because routing never reads simulated state, the per-instance
//! admission sequences (and therefore every simulated byte) are
//! identical at any worker-thread count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::sim::dataflow::{
    timing_cache_enabled, timing_cache_snapshot, timing_cache_warm, TimingSnapshot,
};
use crate::util::rng::Rng;
use crate::workloads::generator::ArrivalStream;
use crate::workloads::models;

use super::instance::Instance;
use super::metrics::{ClassAccum, ClassReport, FleetReport};
use super::router::{Assignment, Router};
use super::{FleetConfig, SloClass, SloSpec};

/// Roll a request's SLO class from the configured shares (one RNG draw
/// per arrival, so the stream's draw order is fixed).
fn pick_class(classes: &[SloSpec; 3], rng: &mut Rng) -> SloClass {
    let total: f64 = classes.iter().map(|c| c.share).sum();
    let mut roll = rng.gen_f64() * total;
    for (i, c) in classes.iter().enumerate() {
        roll -= c.share;
        if roll < 0.0 {
            return SloClass::ALL[i];
        }
    }
    SloClass::Batch
}

/// Hand each routed batch to its instance (driver thread, in emission
/// order — per-instance delivery stays time-monotone).
fn deliver(instances: &[Mutex<Instance>], out: &mut Vec<Assignment>) {
    for a in out.drain(..) {
        instances[a.instance].lock().unwrap().deliver(a);
    }
}

/// Advance every instance to `horizon` on up to `threads` workers.
///
/// `memo` is the fleet-wide timing-memo relay: the worker pool is
/// respawned at every chunk barrier, so each wave's fresh OS threads
/// start with cold thread-local timing caches.  Workers re-warm from the
/// merged snapshot on entry and contribute their memo back on exit —
/// repeated (layer, tile, share) shapes stay cache hits across waves.
/// The memo is a pure-function cache, so the relay cannot change any
/// simulated byte.
fn run_wave(
    instances: &[Mutex<Instance>],
    horizon: u64,
    threads: usize,
    memo: &Mutex<TimingSnapshot>,
) {
    let workers = threads.clamp(1, instances.len());
    if workers == 1 {
        // Single worker = the driver thread itself, whose thread-local
        // memo already persists across waves — no relay needed.
        for inst in instances {
            inst.lock().unwrap().run_until(horizon);
        }
        return;
    }
    let share = timing_cache_enabled();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                if share {
                    timing_cache_warm(&memo.lock().unwrap());
                }
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= instances.len() {
                        break;
                    }
                    instances[i].lock().unwrap().run_until(horizon);
                }
                if share {
                    let snap = timing_cache_snapshot();
                    memo.lock().unwrap().merge(snap);
                }
            });
        }
    });
}

/// Run a fleet to completion and report.  `threads` only sets the worker
/// count for instance waves — the report is byte-identical for any value.
pub fn run_fleet(cfg: &FleetConfig, threads: usize) -> Result<FleetReport> {
    cfg.validate().map_err(|e| anyhow!("invalid fleet config: {e}"))?;

    // Resolve the mix's model templates once.
    let mut templates = Vec::with_capacity(cfg.mix.len());
    for i in 0..cfg.mix.len() {
        let name = cfg.mix.name(i);
        let entry = models::by_name(name)
            .ok_or_else(|| anyhow!("unknown model {name:?} in fleet mix"))?;
        templates.push((entry.build)());
    }

    // Independent RNG streams forked from the fleet seed in a fixed
    // order: arrival gaps, model/class picks, router candidate draws.
    let mut master = Rng::new(cfg.seed);
    let stream_rng = master.fork();
    let mut pick_rng = master.fork();
    let router_rng = master.fork();

    // Profile tables must cover the whole fleet before any simulation:
    // a partial store would silently fall back mid-run, so reject it
    // here with the missing (geometry, model) named.
    if let Some(store) = &cfg.tables {
        for ic in &cfg.instances {
            let geom = ic.sched.geom;
            if !store.has_geometry(geom) {
                bail!(
                    "fleet tables ({}) have no profile for instance {:?} geometry {}x{} \
                     — run `mtsa profile` for that geometry",
                    store.origin,
                    ic.name,
                    geom.rows,
                    geom.cols
                );
            }
            for i in 0..cfg.mix.len() {
                let name = cfg.mix.name(i);
                if store.totals(geom, name).is_none() {
                    bail!(
                        "fleet tables ({}) cover geometry {}x{} but not mix model {name:?} \
                         — run `mtsa profile` for that model",
                        store.origin,
                        geom.rows,
                        geom.cols
                    );
                }
            }
        }
    }

    let arrays = cfg.instances.iter().map(|ic| (ic.sched.geom, ic.sched.buffers)).collect();
    let mut router = Router::new(
        templates,
        arrays,
        cfg.placement,
        cfg.random_k,
        cfg.classes.clone(),
        router_rng,
    );
    if let Some(store) = &cfg.tables {
        router = router.with_tables(store.clone());
    }
    let instances: Vec<Mutex<Instance>> = cfg
        .instances
        .iter()
        .map(|ic| Mutex::new(Instance::new(ic, cfg.slots, cfg.queue_cap)))
        .collect();

    let mut stream =
        ArrivalStream::new(cfg.arrival.clone(), cfg.diurnal.clone(), stream_rng, cfg.requests);
    let timing_memo = Mutex::new(TimingSnapshot::default());
    let mut generated = [0u64; 3];
    let mut out: Vec<Assignment> = Vec::new();
    let chunk = cfg.chunk.max(1);
    loop {
        let mut last_t = 0u64;
        let mut got = 0usize;
        for t in stream.by_ref().take(chunk) {
            let model = cfg.mix.sample_index(&mut pick_rng);
            let class = pick_class(&cfg.classes, &mut pick_rng);
            generated[class.index()] += 1;
            router.offer(t, model, class, &mut out);
            last_t = t;
            got += 1;
        }
        if got == 0 {
            break;
        }
        // Close every window expiring inside this chunk so the next
        // chunk's emissions cannot land in an instance's past.
        router.close_due(last_t, &mut out);
        deliver(&instances, &mut out);
        run_wave(&instances, last_t, threads, &timing_memo);
    }
    router.finish(&mut out);
    deliver(&instances, &mut out);
    run_wave(&instances, u64::MAX, threads, &timing_memo);

    // Merge (in instance-index order — not that order matters: every
    // accumulator is integer-only).
    let insts: Vec<Instance> =
        instances.into_iter().map(|m| m.into_inner().unwrap()).collect();
    let mut class_accums: [ClassAccum; 3] = Default::default();
    let mut makespan = 0u64;
    let mut busy: u128 = 0;
    let mut energy_j = 0.0;
    let mut events = 0u64;
    let mut inst_reports = Vec::with_capacity(insts.len());
    for inst in &insts {
        if !inst.drained() {
            bail!("fleet instance {} finished with work in flight", inst.name);
        }
        for (acc, other) in class_accums.iter_mut().zip(&inst.accum) {
            acc.merge(other);
        }
        makespan = makespan.max(inst.makespan());
        busy += inst.busy_pe_cycles();
        let r = inst.report();
        energy_j += r.energy_j;
        events += r.events;
        inst_reports.push(r);
    }
    let total_pes: u128 = cfg
        .instances
        .iter()
        .map(|ic| u128::from(ic.sched.geom.rows) * u128::from(ic.sched.geom.cols))
        .sum();

    let classes: Vec<ClassReport> = SloClass::ALL
        .iter()
        .zip(&cfg.classes)
        .zip(&class_accums)
        .map(|((&class, spec), acc)| {
            let gen = generated[class.index()];
            ClassReport {
                class,
                share: spec.share,
                slack: spec.slack,
                generated: gen,
                completed: acc.completed,
                dropped: acc.dropped,
                slo_ok: acc.slo_ok,
                attainment: if gen > 0 { acc.slo_ok as f64 / gen as f64 } else { 1.0 },
                p50: acc.latency.percentile(0.50),
                p95: acc.latency.percentile(0.95),
                p99: acc.latency.percentile(0.99),
                mean_queue_cycles: if acc.completed > 0 {
                    acc.queue_cycles as f64 / acc.completed as f64
                } else {
                    0.0
                },
                mean_service_cycles: if acc.completed > 0 {
                    acc.service_cycles as f64 / acc.completed as f64
                } else {
                    0.0
                },
            }
        })
        .collect();

    let completed: u64 = classes.iter().map(|c| c.completed).sum();
    let dropped: u64 = classes.iter().map(|c| c.dropped).sum();
    let total_generated: u64 = generated.iter().sum();
    let report = FleetReport {
        classes,
        instances: inst_reports,
        generated: total_generated,
        completed,
        dropped,
        batches: router.batches,
        makespan,
        utilization: if makespan > 0 && total_pes > 0 {
            busy as f64 / (makespan as f64 * total_pes as f64)
        } else {
            0.0
        },
        energy_j,
        cost_j_per_query: if completed > 0 { energy_j / completed as f64 } else { 0.0 },
        events,
        seed: cfg.seed,
    };
    if !report.conserved() {
        bail!(
            "fleet conservation violated: generated {} != completed {} + dropped {}",
            report.generated,
            report.completed,
            report.dropped
        );
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::SchedulerConfig;
    use crate::fleet::{FleetPolicy, InstanceConfig, Placement};
    use crate::workloads::generator::{ArrivalProcess, Diurnal, ModelMix};

    fn small_cfg(requests: usize, seed: u64) -> FleetConfig {
        let sched = SchedulerConfig::default();
        FleetConfig {
            instances: FleetConfig::uniform(4, &sched, FleetPolicy::Dynamic),
            placement: Placement::LeastLoaded,
            random_k: 2,
            classes: FleetConfig::default_classes(30_000.0),
            slots: 4,
            queue_cap: 32,
            mix: ModelMix::new(&[("NCF", 2.0), ("MelodyLSTM", 1.0)]),
            arrival: ArrivalProcess::Poisson { mean_interarrival: 30_000.0 },
            diurnal: Some(Diurnal { period: 2_000_000.0, amplitude: 0.5, phase: 0.0 }),
            requests,
            seed,
            chunk: 64,
            tables: None,
        }
    }

    #[test]
    fn fleet_conserves_and_reports() {
        let r = run_fleet(&small_cfg(200, 42), 2).unwrap();
        assert!(r.conserved());
        assert_eq!(r.generated, 200);
        assert!(r.completed > 0);
        assert!(r.makespan > 0);
        assert!(r.utilization > 0.0 && r.utilization <= 1.0);
        assert!(r.energy_j > 0.0 && r.cost_j_per_query > 0.0);
        assert_eq!(r.instances.len(), 4);
        assert_eq!(r.classes.len(), 3);
        // Batching actually coalesces: fewer batches than requests once
        // the best-effort/batch classes see traffic.
        assert!(r.batches < r.generated);
    }

    #[test]
    fn chunk_size_does_not_change_results() {
        let base = run_fleet(&small_cfg(150, 7), 1).unwrap();
        for chunk in [1usize, 13, 1000] {
            let mut cfg = small_cfg(150, 7);
            cfg.chunk = chunk;
            let r = run_fleet(&cfg, 3).unwrap();
            assert_eq!(r.completed, base.completed, "chunk {chunk}");
            assert_eq!(r.dropped, base.dropped, "chunk {chunk}");
            assert_eq!(r.makespan, base.makespan, "chunk {chunk}");
            assert_eq!(r.batches, base.batches, "chunk {chunk}");
        }
    }

    fn mix_store(
        geoms: &[crate::sim::dataflow::ArrayGeometry],
    ) -> std::sync::Arc<crate::profiler::ProfileStore> {
        use crate::profiler::{ProfileStore, ProfileTable};
        let bufs = crate::sim::buffers::BufferConfig::default();
        let mut tables = Vec::new();
        for &geom in geoms {
            for name in ["NCF", "MelodyLSTM"] {
                let dnn = (models::by_name(name).unwrap().build)();
                tables.push(ProfileTable::build(name, &dnn, geom, &bufs));
            }
        }
        std::sync::Arc::new(ProfileStore::from_tables("test", tables))
    }

    #[test]
    fn tables_leave_every_fleet_byte_unchanged() {
        let base = run_fleet(&small_cfg(150, 7), 2).unwrap();
        let mut cfg = small_cfg(150, 7);
        cfg.tables = Some(mix_store(&[SchedulerConfig::default().geom]));
        let tabled = run_fleet(&cfg, 2).unwrap();
        assert_eq!(tabled.completed, base.completed);
        assert_eq!(tabled.dropped, base.dropped);
        assert_eq!(tabled.makespan, base.makespan);
        assert_eq!(tabled.batches, base.batches);
        assert_eq!(
            crate::report::fleet_json(&tabled).render(),
            crate::report::fleet_json(&base).render(),
            "table-priced routing must be byte-identical"
        );
    }

    #[test]
    fn tables_missing_coverage_fail_fast_and_name_the_gap() {
        // Wrong geometry: named per instance.
        let mut cfg = small_cfg(10, 1);
        cfg.tables = Some(mix_store(&[crate::sim::dataflow::ArrayGeometry::new(64, 64)]));
        let err = run_fleet(&cfg, 1).unwrap_err().to_string();
        assert!(err.contains("geometry 128x128"), "{err}");
        assert!(err.contains("mtsa profile"), "{err}");
        // Right geometry, missing mix model: named too.
        let mut cfg = small_cfg(10, 1);
        cfg.mix = ModelMix::new(&[("NCF", 1.0), ("AlexNet", 1.0)]);
        cfg.tables = Some(mix_store(&[SchedulerConfig::default().geom]));
        let err = run_fleet(&cfg, 1).unwrap_err().to_string();
        assert!(err.contains("AlexNet"), "{err}");
    }

    #[test]
    fn mixed_policies_run_side_by_side() {
        let sched = SchedulerConfig::default();
        let mut cfg = small_cfg(80, 3);
        cfg.instances = vec![
            InstanceConfig {
                name: "dyn".into(),
                sched: sched.clone(),
                policy: FleetPolicy::Dynamic,
            },
            InstanceConfig {
                name: "seq".into(),
                sched: sched.clone(),
                policy: FleetPolicy::Sequential,
            },
            InstanceConfig {
                name: "stat".into(),
                sched: sched.clone(),
                policy: FleetPolicy::Static,
            },
            InstanceConfig { name: "chips".into(), sched, policy: FleetPolicy::MultiArray(4) },
        ];
        let r = run_fleet(&cfg, 4).unwrap();
        assert!(r.conserved());
        assert_eq!(r.instances[3].policy, "multi-array:4");
    }
}
