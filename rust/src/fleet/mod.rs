//! Fleet-scale serving tier: route millions of requests across many
//! partitioned accelerators.
//!
//! One simulated accelerator (PRs 1–6) is a single [`Engine`] plus a
//! partitioning [`Scheduler`].  This module lifts that to a *cluster*: a
//! [`Router`](router::Router) with per-model batching queues fronts `N`
//! independent [`Instance`](instance::Instance)s, each wrapping its own
//! engine and any of the four shipped policies with its own geometry and
//! `[mem]` config.  Requests carry an SLO class
//! ([`SloClass`]) that maps onto the existing slack-relative deadlines
//! (and, through them, the deadline-driven preemption trigger).
//!
//! # Determinism
//!
//! The driver ([`driver::run_fleet`]) is built so the report is
//! byte-identical at any worker-thread count:
//!
//! * All randomness (arrival gaps, model picks, class rolls, random-k
//!   candidate draws) happens in the single-threaded router/generator
//!   front end, on [`Rng`](crate::util::rng::Rng) streams forked from the
//!   one fleet seed in a fixed order.
//! * Placement is *estimate-based*: the router tracks a per-instance
//!   `busy_until` horizon priced from isolated layer timings, never from
//!   simulated state.  Routing therefore depends only on the arrival
//!   stream — so the per-instance request sequences are fixed before any
//!   engine steps, and the instances can be simulated embarrassingly
//!   parallel (the sweep thread-pool pattern) with no cross-thread
//!   ordering to leak into the results.
//! * Arrivals stream through in bounded chunks — peak memory is set by
//!   the chunk size and the live-tenant caps, not the arrival count.
//!
//! [`Engine`]: crate::sim_core::Engine
//! [`Scheduler`]: crate::sim_core::Scheduler

pub mod driver;
pub mod instance;
pub mod metrics;
pub mod router;

pub use driver::run_fleet;
pub use metrics::{ClassReport, CycleHistogram, FleetReport, InstanceReport};

use crate::coordinator::baseline::SequentialBaseline;
use crate::coordinator::multi_array::{MultiArrayBank, MultiArrayPolicy};
use crate::coordinator::scheduler::SchedulerConfig;
use crate::coordinator::static_part::StaticPartitioning;
use crate::coordinator::DynamicScheduler;
use crate::sim_core::Scheduler;
use crate::util::UnknownTag;
use crate::workloads::generator::{ArrivalProcess, Diurnal, ModelMix};

/// Service-level objective class of a request — decides its deadline
/// slack and how aggressively the router batches it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SloClass {
    /// Interactive serving: tight slack, no batching.
    LatencyCritical,
    /// Default tier: moderate slack, small batches.
    BestEffort,
    /// Offline/bulk: no deadline, large batches.
    Batch,
}

impl SloClass {
    pub const ALL: [SloClass; 3] =
        [SloClass::LatencyCritical, SloClass::BestEffort, SloClass::Batch];
    pub const TAGS: [&'static str; 3] = ["latency-critical", "best-effort", "batch"];

    pub fn tag(&self) -> &'static str {
        Self::TAGS[self.index()]
    }

    /// Position in [`SloClass::ALL`] — the per-class array index used
    /// throughout the fleet accounting.
    pub fn index(&self) -> usize {
        match self {
            SloClass::LatencyCritical => 0,
            SloClass::BestEffort => 1,
            SloClass::Batch => 2,
        }
    }
}

impl std::str::FromStr for SloClass {
    type Err = UnknownTag;

    fn from_str(s: &str) -> Result<SloClass, UnknownTag> {
        SloClass::ALL.into_iter().find(|c| c.tag() == s).ok_or_else(|| UnknownTag {
            what: "SLO class",
            got: s.to_string(),
            valid: &SloClass::TAGS,
        })
    }
}

/// Router placement policy: which instance a (batched) request lands on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Minimize the estimated completion horizon across all instances.
    LeastLoaded,
    /// Prefer an instance whose weights for this model are already warm
    /// (last request it received was the same model), tolerating up to
    /// one extra batch-service of queueing before falling back cold.
    Affinity,
    /// Power-of-k-choices: least-loaded among `k` random candidates.
    RandomK,
}

impl Placement {
    pub const ALL: [Placement; 3] =
        [Placement::LeastLoaded, Placement::Affinity, Placement::RandomK];
    pub const TAGS: [&'static str; 3] = ["least-loaded", "affinity", "random-k"];

    pub fn tag(&self) -> &'static str {
        match self {
            Placement::LeastLoaded => "least-loaded",
            Placement::Affinity => "affinity",
            Placement::RandomK => "random-k",
        }
    }
}

impl std::str::FromStr for Placement {
    type Err = UnknownTag;

    fn from_str(s: &str) -> Result<Placement, UnknownTag> {
        Placement::ALL.into_iter().find(|p| p.tag() == s).ok_or_else(|| UnknownTag {
            what: "placement policy",
            got: s.to_string(),
            valid: &Placement::TAGS,
        })
    }
}

/// Which single-accelerator scheduling policy an instance runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetPolicy {
    /// The paper's dynamic partitioning (plus preemption if configured).
    Dynamic,
    /// Whole-array FIFO (the sequential baseline).
    Sequential,
    /// Fixed equal-width partitions.
    Static,
    /// `n` fixed chips at whole-DNN granularity.
    MultiArray(usize),
}

impl FleetPolicy {
    /// Display label (`multi-array` carries its chip count).
    pub fn label(&self) -> String {
        match self {
            FleetPolicy::Dynamic => "dynamic".to_string(),
            FleetPolicy::Sequential => "sequential".to_string(),
            FleetPolicy::Static => "static".to_string(),
            FleetPolicy::MultiArray(n) => format!("multi-array:{n}"),
        }
    }

    /// Instantiate the per-instance scheduler this policy names.
    pub fn build(&self, cfg: &SchedulerConfig) -> Box<dyn Scheduler + Send> {
        match self {
            FleetPolicy::Dynamic => Box::new(DynamicScheduler::new(cfg.clone())),
            FleetPolicy::Sequential => Box::new(SequentialBaseline::new(cfg.clone())),
            FleetPolicy::Static => Box::new(StaticPartitioning::new(cfg.clone())),
            FleetPolicy::MultiArray(n) => {
                Box::new(MultiArrayPolicy::new(&MultiArrayBank::split_of(cfg, *n)))
            }
        }
    }
}

impl std::str::FromStr for FleetPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<FleetPolicy, String> {
        match s {
            "dynamic" => return Ok(FleetPolicy::Dynamic),
            "sequential" => return Ok(FleetPolicy::Sequential),
            "static" => return Ok(FleetPolicy::Static),
            "multi-array" => return Ok(FleetPolicy::MultiArray(4)),
            _ => {}
        }
        if let Some(n) = s.strip_prefix("multi-array:") {
            let n: usize = n
                .parse()
                .map_err(|_| format!("multi-array chip count must be a number, got {s:?}"))?;
            if n == 0 {
                return Err("multi-array chip count must be >= 1".to_string());
            }
            return Ok(FleetPolicy::MultiArray(n));
        }
        Err(format!(
            "unknown fleet policy {s:?} (valid: dynamic|sequential|static|multi-array[:N])"
        ))
    }
}

/// Per-class serving policy: traffic share, deadline slack, and batching.
#[derive(Debug, Clone, PartialEq)]
pub struct SloSpec {
    /// Relative traffic share (normalized across the three classes).
    pub share: f64,
    /// Deadline = arrival + slack × isolated latency (single request on
    /// the chosen instance); `None` = no deadline (bulk work).
    pub slack: Option<f64>,
    /// Requests coalesced into one tenant slot (1 = no batching).
    pub max_batch: usize,
    /// Cycles an open batch waits for co-batchable arrivals before it is
    /// dispatched anyway.
    pub window: u64,
}

impl SloSpec {
    /// Validate one class spec (`tag` names it in errors).
    pub fn validate(&self, tag: &str) -> Result<(), String> {
        if !self.share.is_finite() || self.share < 0.0 {
            return Err(format!("[{tag}] share must be a finite number >= 0"));
        }
        if let Some(s) = self.slack {
            if !s.is_finite() || s <= 0.0 {
                return Err(format!("[{tag}] slack must be > 0 when set"));
            }
        }
        if self.max_batch == 0 {
            return Err(format!("[{tag}] max_batch must be >= 1"));
        }
        Ok(())
    }
}

/// One accelerator of the fleet: its display name, its full
/// single-accelerator config (geometry, buffers, `[mem]`, preemption…)
/// and the policy run on it.  Instances may be heterogeneous.
#[derive(Debug, Clone)]
pub struct InstanceConfig {
    pub name: String,
    pub sched: SchedulerConfig,
    pub policy: FleetPolicy,
}

/// The whole fleet-run description: instances, routing, traffic.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub instances: Vec<InstanceConfig>,
    pub placement: Placement,
    /// Candidate count for [`Placement::RandomK`] (clamped to the fleet).
    pub random_k: usize,
    /// Per-class policy, indexed by [`SloClass::index`].
    pub classes: [SloSpec; 3],
    /// Concurrent tenant slots per instance (live DNNs on one engine).
    pub slots: usize,
    /// Admission queue depth per instance; batches arriving beyond it are
    /// dropped (every member counted, reason `queue_full`).
    pub queue_cap: usize,
    /// Model mix sampled per request.
    pub mix: ModelMix,
    /// Arrival process of the aggregate request stream.
    pub arrival: ArrivalProcess,
    /// Day-length rate modulation over the stream (`None` = flat).
    pub diurnal: Option<Diurnal>,
    /// Total requests to generate.
    pub requests: usize,
    pub seed: u64,
    /// Arrivals generated per streaming chunk — bounds peak memory
    /// independent of `requests`.
    pub chunk: usize,
    /// Offline profile tables (`[fleet] tables = <dir>`): the router
    /// prices isolated-run horizons from table totals instead of
    /// re-summing layer timings.  Exactly equal by construction, so the
    /// report bytes do not change; the driver rejects stores missing an
    /// instance's geometry or a mix model up front.
    pub tables: Option<std::sync::Arc<crate::profiler::ProfileStore>>,
}

impl FleetConfig {
    /// Default SLO classes scaled to a mean interarrival gap:
    /// latency-critical (30%, tight slack, unbatched), best-effort (50%,
    /// loose slack, small batches), batch (20%, no deadline, big batches).
    pub fn default_classes(mean_interarrival: f64) -> [SloSpec; 3] {
        let gap = mean_interarrival.max(1.0);
        [
            SloSpec { share: 0.3, slack: Some(4.0), max_batch: 1, window: 0 },
            SloSpec { share: 0.5, slack: Some(12.0), max_batch: 4, window: (4.0 * gap) as u64 },
            SloSpec { share: 0.2, slack: None, max_batch: 16, window: (16.0 * gap) as u64 },
        ]
    }

    /// A homogeneous fleet of `n` instances running one policy.
    pub fn uniform(n: usize, sched: &SchedulerConfig, policy: FleetPolicy) -> Vec<InstanceConfig> {
        (0..n)
            .map(|i| InstanceConfig {
                name: format!("acc{i}"),
                sched: sched.clone(),
                policy,
            })
            .collect()
    }

    /// Reject configs the driver cannot run (empty fleet/mix, zero
    /// capacity, degenerate class table).
    pub fn validate(&self) -> Result<(), String> {
        if self.instances.is_empty() {
            return Err("fleet needs at least one instance".to_string());
        }
        if self.mix.is_empty() {
            return Err("fleet model mix is empty".to_string());
        }
        if self.requests == 0 {
            return Err("fleet requests must be >= 1".to_string());
        }
        if self.slots == 0 || self.queue_cap == 0 {
            return Err("fleet slots and queue_cap must be >= 1".to_string());
        }
        if self.chunk == 0 {
            return Err("fleet chunk must be >= 1".to_string());
        }
        let mut total = 0.0;
        for (c, spec) in SloClass::ALL.iter().zip(&self.classes) {
            spec.validate(c.tag())?;
            total += spec.share;
        }
        if total <= 0.0 {
            return Err("SLO class shares must sum to > 0".to_string());
        }
        if self.placement == Placement::RandomK && self.random_k == 0 {
            return Err("random-k placement needs k >= 1".to_string());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_round_trip() {
        for c in SloClass::ALL {
            assert_eq!(c.tag().parse::<SloClass>().unwrap(), c);
        }
        for p in Placement::ALL {
            assert_eq!(p.tag().parse::<Placement>().unwrap(), p);
        }
        assert!("interactive".parse::<SloClass>().is_err());
        assert!("round-robin".parse::<Placement>().is_err());
    }

    #[test]
    fn fleet_policy_parses_chip_counts() {
        assert_eq!("dynamic".parse::<FleetPolicy>().unwrap(), FleetPolicy::Dynamic);
        assert_eq!("multi-array".parse::<FleetPolicy>().unwrap(), FleetPolicy::MultiArray(4));
        assert_eq!("multi-array:2".parse::<FleetPolicy>().unwrap(), FleetPolicy::MultiArray(2));
        assert!("multi-array:0".parse::<FleetPolicy>().is_err());
        assert!("multi-array:x".parse::<FleetPolicy>().is_err());
        assert!("roundrobin".parse::<FleetPolicy>().is_err());
    }

    #[test]
    fn validate_rejects_degenerate_configs() {
        let sched = SchedulerConfig::default();
        let mut cfg = FleetConfig {
            instances: FleetConfig::uniform(2, &sched, FleetPolicy::Dynamic),
            placement: Placement::LeastLoaded,
            random_k: 2,
            classes: FleetConfig::default_classes(50_000.0),
            slots: 4,
            queue_cap: 16,
            mix: ModelMix::new(&[("NCF", 1.0)]),
            arrival: ArrivalProcess::Poisson { mean_interarrival: 50_000.0 },
            diurnal: None,
            requests: 100,
            seed: 1,
            chunk: 64,
            tables: None,
        };
        assert!(cfg.validate().is_ok());
        cfg.requests = 0;
        assert!(cfg.validate().is_err());
        cfg.requests = 100;
        cfg.instances.clear();
        assert!(cfg.validate().is_err());
        cfg.instances = FleetConfig::uniform(1, &sched, FleetPolicy::Dynamic);
        cfg.classes[0].share = -1.0;
        assert!(cfg.validate().is_err());
        cfg.classes[0].share = 0.0;
        cfg.classes[1].share = 0.0;
        cfg.classes[2].share = 0.0;
        assert!(cfg.validate().is_err());
    }
}
