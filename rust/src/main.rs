//! `mtsa` CLI — the leader entrypoint.
//!
//! See `mtsa help` (or `cli::commands::USAGE`) for subcommands.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(mtsa::cli::main_with(&argv));
}
