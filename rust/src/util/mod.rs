//! Small self-contained utility substrates.
//!
//! The build environment is offline with a fixed vendored crate set (no
//! `serde`, `rand`, `clap`, `criterion`, `proptest`), so the handful of
//! generic facilities the rest of the crate needs are implemented here and
//! tested in place:
//!
//! - [`json`] — minimal JSON parser for `artifacts/manifest.json`
//! - [`rng`] — xorshift* PRNG (deterministic, seedable)
//! - [`stats`] — summary statistics for benches and metrics
//! - [`tablefmt`] — aligned plain-text tables for bench/figure output
//! - [`prop`] — randomized property-test driver with seed reporting
//! - [`logging`] — leveled stderr logger

pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod tablefmt;

/// Parse error for string-tagged enums (`FeedModel`, `AllocPolicy`,
/// `ArrivalKind`, …): carries the rejected input and the full list of
/// valid tags, so every `FromStr` error names its alternatives.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UnknownTag {
    /// What was being parsed, e.g. `"feed model"`.
    pub what: &'static str,
    /// The rejected input.
    pub got: String,
    /// Every valid tag, in declaration order.
    pub valid: &'static [&'static str],
}

impl std::fmt::Display for UnknownTag {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "unknown {} {:?} (valid: {})", self.what, self.got, self.valid.join("|"))
    }
}

impl std::error::Error for UnknownTag {}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Round `n` up to the next multiple of `b`.
#[inline]
pub fn round_up(n: u64, b: u64) -> u64 {
    ceil_div(n, b) * b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
        assert_eq!(ceil_div(128, 32), 4);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }
}
