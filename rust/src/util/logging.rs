//! Leveled stderr logger.
//!
//! A tiny global logger: `MTSA_LOG=debug|info|warn|error` (default `info`).
//! Used by the coordinator service and the CLI; benches keep stdout clean
//! for the figure tables and log to stderr only.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

impl Level {
    fn from_env() -> Level {
        match std::env::var("MTSA_LOG").as_deref() {
            Ok("debug") => Level::Debug,
            Ok("warn") => Level::Warn,
            Ok("error") => Level::Error,
            _ => Level::Info,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
        }
    }
}

static CURRENT: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

/// Current threshold (lazily read from the environment).
pub fn level() -> Level {
    let raw = CURRENT.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let lvl = Level::from_env();
        CURRENT.store(lvl as u8, Ordering::Relaxed);
        return lvl;
    }
    match raw {
        0 => Level::Debug,
        1 => Level::Info,
        2 => Level::Warn,
        _ => Level::Error,
    }
}

/// Override the threshold programmatically (tests, CLI `--verbose`).
pub fn set_level(lvl: Level) {
    CURRENT.store(lvl as u8, Ordering::Relaxed);
}

/// Emit a record if `lvl` clears the threshold.
pub fn log(lvl: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if lvl >= level() {
        eprintln!("[{} {target}] {msg}", lvl.tag());
    }
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_are_ordered() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
        assert!(Level::Warn < Level::Error);
    }

    #[test]
    fn set_level_round_trips() {
        let prev = level();
        set_level(Level::Warn);
        assert_eq!(level(), Level::Warn);
        set_level(Level::Error);
        assert_eq!(level(), Level::Error);
        set_level(prev);
    }
}
