//! Summary statistics for benches and metrics (criterion replacement core).

/// Summary of a sample set.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn from_samples(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
        Some(Summary {
            n,
            mean,
            std_dev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
        })
    }

    /// Relative standard deviation (coefficient of variation).
    pub fn rsd(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std_dev / self.mean.abs()
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Number of `(completion, deadline)` pairs (absolute cycles) finishing
/// strictly after their deadline.  Finishing exactly at the deadline is a
/// hit (the SLA is "done by cycle D").  This is the single definition of
/// a deadline miss; everything else derives from it.
pub fn deadline_misses(pairs: &[(u64, u64)]) -> usize {
    pairs.iter().filter(|(done, deadline)| done > deadline).count()
}

/// Deadline-miss rate over `(completion, deadline)` pairs.  Empty input —
/// no request carried a deadline — counts as a perfect 0.0, not NaN.
pub fn deadline_miss_rate(pairs: &[(u64, u64)]) -> f64 {
    if pairs.is_empty() {
        return 0.0;
    }
    deadline_misses(pairs) as f64 / pairs.len() as f64
}

/// Format a duration in nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Format a count with SI suffixes (1.2 K, 3.4 M, ...).
pub fn fmt_si(x: f64) -> String {
    let (val, suffix) = if x.abs() >= 1e12 {
        (x / 1e12, " T")
    } else if x.abs() >= 1e9 {
        (x / 1e9, " G")
    } else if x.abs() >= 1e6 {
        (x / 1e6, " M")
    } else if x.abs() >= 1e3 {
        (x / 1e3, " K")
    } else {
        (x, "")
    };
    format!("{val:.2}{suffix}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_none() {
        assert!(Summary::from_samples(&[]).is_none());
    }

    #[test]
    fn single_sample() {
        let s = Summary::from_samples(&[5.0]).unwrap();
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.p50, 5.0);
        assert_eq!(s.p99, 5.0);
    }

    #[test]
    fn known_values() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std_dev - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile(&sorted, 0.0), 0.0);
        assert_eq!(percentile(&sorted, 0.5), 5.0);
        assert_eq!(percentile(&sorted, 1.0), 10.0);
    }

    #[test]
    fn ordering_invariant() {
        // p50 <= p95 <= p99 <= max for any sample.
        let samples: Vec<f64> = (0..101).map(|i| ((i * 37) % 101) as f64).collect();
        let s = Summary::from_samples(&samples).unwrap();
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn percentile_exact_on_sample_points() {
        // With n samples, q = i/(n-1) lands exactly on sorted[i].
        let sorted = [2.0, 4.0, 8.0, 16.0, 32.0];
        for (i, &v) in sorted.iter().enumerate() {
            assert_eq!(percentile(&sorted, i as f64 / 4.0), v);
        }
        // Quartile interpolation between points.
        assert_eq!(percentile(&sorted, 0.125), 3.0);
        assert_eq!(percentile(&sorted, 0.875), 24.0);
    }

    #[test]
    fn percentile_constant_sample() {
        let sorted = [7.0; 10];
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(percentile(&sorted, q), 7.0);
        }
    }

    #[test]
    fn summary_percentiles_match_percentile_fn() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::from_samples(&samples).unwrap();
        assert_eq!(s.p50, percentile(&samples, 0.50));
        assert_eq!(s.p95, percentile(&samples, 0.95));
        assert_eq!(s.p99, percentile(&samples, 0.99));
    }

    #[test]
    fn deadline_miss_rate_basics() {
        // No deadlines at all -> perfect.
        assert_eq!(deadline_miss_rate(&[]), 0.0);
        assert_eq!(deadline_misses(&[]), 0);
        assert_eq!(deadline_misses(&[(101, 100), (100, 100), (99, 100)]), 1);
        // Finishing exactly at the deadline is a hit.
        assert_eq!(deadline_miss_rate(&[(100, 100)]), 0.0);
        // One cycle over is a miss.
        assert_eq!(deadline_miss_rate(&[(101, 100)]), 1.0);
        // Mixed: 1 miss out of 4.
        let pairs = [(50, 100), (100, 100), (150, 100), (99, 100)];
        assert!((deadline_miss_rate(&pairs) - 0.25).abs() < 1e-12);
        // All misses.
        assert_eq!(deadline_miss_rate(&[(2, 1), (3, 1)]), 1.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(12.0), "12.0 ns");
        assert_eq!(fmt_ns(1_500.0), "1.50 µs");
        assert_eq!(fmt_ns(2_000_000.0), "2.00 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
        assert_eq!(fmt_si(950.0), "950.00");
        assert_eq!(fmt_si(1_200.0), "1.20 K");
        assert_eq!(fmt_si(3.4e9), "3.40 G");
    }
}
