//! Minimal JSON parser and writer — enough for `artifacts/manifest.json`,
//! config interchange, and the sweep reports.  Supports the full JSON
//! grammar except `\u` surrogate pairs beyond the BMP (sufficient for our
//! ASCII manifests); numbers parse to f64.
//!
//! [`Json::render`] is deterministic: objects are `BTreeMap`s (keys emit
//! sorted), and numbers use a fixed formatting rule — so two structurally
//! identical documents render byte-identically, which the sweep runner
//! relies on for its reproducibility contract (fixed seed ⇒ identical
//! report bytes, regardless of worker-thread count).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (rejects trailing garbage).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize to compact JSON text (deterministic; see module docs).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Deterministic number formatting: integral values within the f64-exact
/// range print without a fraction; everything else uses rust's shortest
/// round-trip repr (valid JSON: `0.25`, `1e300`, ...).  Non-finite values
/// have no JSON representation and emit `null`.
fn write_num(out: &mut String, n: f64) {
    use std::fmt::Write as _;
    if !n.is_finite() {
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n:?}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                use std::fmt::Write as _;
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("surrogate \\u escape"))?,
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn escapes() {
        assert_eq!(
            Json::parse(r#""a\nb\t\"c\" A""#).unwrap(),
            Json::Str("a\nb\t\"c\" A".into())
        );
    }

    #[test]
    fn nested_structure() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": true}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b"),
            Some(&Json::Bool(true))
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"k\" :\r [ ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"", "{\"a\"}", "{'a':1}", "tru", "+1", ""] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn u64_accessor() {
        assert_eq!(Json::parse("128").unwrap().as_u64(), Some(128));
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
    }

    #[test]
    fn render_round_trips() {
        for text in [
            "null",
            "true",
            "42",
            "-3.5",
            r#""hi there""#,
            r#"{"a":[1,2,{"b":true}],"c":null,"d":"x\ny"}"#,
            r#"[0.25,1,-7,"",{}]"#,
        ] {
            let v = Json::parse(text).unwrap();
            let rendered = v.render();
            assert_eq!(Json::parse(&rendered).unwrap(), v, "round trip of {text:?}");
        }
    }

    #[test]
    fn render_is_deterministic_and_sorted() {
        let a = Json::parse(r#"{"z": 1, "a": 2}"#).unwrap();
        let b = Json::parse(r#"{"a": 2, "z": 1}"#).unwrap();
        assert_eq!(a.render(), b.render());
        assert_eq!(a.render(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn render_number_forms() {
        assert_eq!(Json::Num(5.0).render(), "5");
        assert_eq!(Json::Num(-2.0).render(), "-2");
        assert_eq!(Json::Num(0.25).render(), "0.25");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        // Escapes survive a round trip.
        let s = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(Json::parse(&s.render()).unwrap(), s);
    }

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
          "schema": 1,
          "array": {"s": 128, "k": 128, "c": 128},
          "artifacts": [
            {"name": "pws_p1", "file": "pws_p1.hlo.txt",
             "inputs": [{"shape": [1, 128, 128], "dtype": "float32"}],
             "num_outputs": 1}
          ]
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("schema").unwrap().as_u64(), Some(1));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("pws_p1"));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.len(), 3);
    }
}
