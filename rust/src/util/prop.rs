//! Randomized property-test driver (proptest replacement).
//!
//! Usage:
//!
//! ```ignore
//! prop::check("partition widths sum to array width", 500, |rng| {
//!     let n = rng.gen_range_inclusive(1, 16);
//!     /* build a case from rng, return Err(msg) on violation */
//!     Ok(())
//! });
//! ```
//!
//! Each case gets a fresh child generator derived from a printed master
//! seed, so a failure report (`case #i, seed 0x...`) reproduces standalone.
//! Set `MTSA_PROP_SEED` to re-run a particular master seed and
//! `MTSA_PROP_CASES` to scale case counts up for soak runs.

use super::rng::Rng;

/// Master seed: env override or a fixed default (deterministic CI).
pub fn master_seed() -> u64 {
    match std::env::var("MTSA_PROP_SEED") {
        Ok(s) => parse_seed(&s).expect("MTSA_PROP_SEED must be a u64 (hex ok)"),
        Err(_) => 0xC0FFEE,
    }
}

fn parse_seed(s: &str) -> Option<u64> {
    if let Some(hex) = s.strip_prefix("0x") {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    }
}

/// Case-count multiplier from `MTSA_PROP_CASES` (default 1.0).
fn case_scale() -> f64 {
    std::env::var("MTSA_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

/// Run `cases` randomized checks of `prop`; panics with a reproducible
/// seed on the first violation.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let master = master_seed();
    let mut root = Rng::new(master);
    let scaled = ((cases as f64) * case_scale()).ceil() as usize;
    for i in 0..scaled {
        let child_seed = root.next_u64();
        let mut rng = Rng::new(child_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' violated at case #{i} \
                 (master seed {master:#x}, case seed {child_seed:#x}): {msg}"
            );
        }
    }
}

/// Assert-equal helper returning a property error instead of panicking,
/// so `check` can report the reproducing seed.
pub fn ensure_eq<T: PartialEq + std::fmt::Debug>(
    a: T,
    b: T,
    what: &str,
) -> Result<(), String> {
    if a == b {
        Ok(())
    } else {
        Err(format!("{what}: {a:?} != {b:?}"))
    }
}

/// Boolean property helper.
pub fn ensure(cond: bool, what: &str) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(what.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 100, |_rng| {
            count += 1;
            Ok(())
        });
        assert!(count >= 100);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' violated")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 10, |_rng| Err("nope".into()));
    }

    #[test]
    fn deterministic_case_streams() {
        let mut s1 = Vec::new();
        check("collect", 20, |rng| {
            s1.push(rng.next_u64());
            Ok(())
        });
        let mut s2 = Vec::new();
        check("collect", 20, |rng| {
            s2.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(s1, s2);
    }

    #[test]
    fn helpers() {
        assert!(ensure_eq(1, 1, "x").is_ok());
        assert!(ensure_eq(1, 2, "x").is_err());
        assert!(ensure(true, "y").is_ok());
        assert!(ensure(false, "y").is_err());
    }

    #[test]
    fn seed_parsing() {
        assert_eq!(parse_seed("123"), Some(123));
        assert_eq!(parse_seed("0xff"), Some(255));
        assert_eq!(parse_seed("zz"), None);
    }
}
