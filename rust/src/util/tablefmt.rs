//! Aligned plain-text tables — the output format of every bench/figure
//! harness, chosen so `cargo bench` output lines up with the paper's tables.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple column-aligned table builder.
#[derive(Debug, Clone)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given headers; all columns right-aligned
    /// except the first.
    pub fn new(headers: &[&str]) -> Table {
        let aligns = headers
            .iter()
            .enumerate()
            .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
            .collect();
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns,
            rows: Vec::new(),
        }
    }

    /// Override alignment per column.
    pub fn with_aligns(mut self, aligns: &[Align]) -> Table {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity {} != header arity {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: append a row of `&str`.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// Render with a header rule.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], aligns: &[Align]| {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                let cell = &cells[i];
                match aligns[i] {
                    Align::Left => {
                        line.push_str(cell);
                        if i + 1 < ncols {
                            line.push_str(&" ".repeat(widths[i] - cell.len()));
                        }
                    }
                    Align::Right => {
                        line.push_str(&" ".repeat(widths[i] - cell.len()));
                        line.push_str(cell);
                    }
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths, &self.aligns));
        out.push('\n');
        let rule_len = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(rule_len));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths, &self.aligns));
            out.push('\n');
        }
        out
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no data rows have been added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "cycles"]);
        t.row_str(&["alexnet", "123456"]);
        t.row_str(&["ncf", "99"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].ends_with("123456"));
        assert!(lines[3].ends_with("    99"));
        // All rows equal width for right-aligned last column.
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn empty_table_renders_headers() {
        let t = Table::new(&["x"]);
        assert!(t.is_empty());
        assert!(t.render().contains('x'));
    }
}
