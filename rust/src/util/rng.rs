//! Deterministic xorshift*-based PRNG.
//!
//! Every stochastic component in the repo (workload generators, property
//! tests, request arrival processes) takes an explicit [`Rng`] so runs are
//! reproducible from a single seed printed in the output.

/// xorshift64* generator with a splitmix64 seeding stage.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        // splitmix64 scrambles the seed so nearby seeds give unrelated streams.
        let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        Rng { state: z.max(1) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // Multiply-shift rejection-free mapping (Lemire); bias is < 2^-32
        // for the n used here, acceptable for simulation workloads.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn gen_range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.gen_range(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn gen_f32(&mut self) -> f32 {
        self.gen_f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(f64::MIN_POSITIVE);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`); for Poisson arrivals.
    pub fn gen_exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.gen_f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Bernoulli with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.gen_range(xs.len() as u64) as usize]
    }

    /// Derive an independent child generator (for parallel workers).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.gen_range(13) < 13);
        }
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut r = Rng::new(3);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[r.gen_range(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(5);
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let mean = (0..n).map(|_| r.gen_exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(23);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
