//! The paper's contribution: the dynamic resource-partitioning coordinator
//! (Algorithm 1, Fig. 5), expressed as policies over the shared
//! discrete-event engine ([`crate::sim_core`]).
//!
//! Every executor here implements the
//! [`Scheduler`](crate::sim_core::Scheduler) trait — decision-point hooks
//! plus `plan`/`exec` — and runs on [`Engine`](crate::sim_core::Engine);
//! the `run(&pool) -> RunMetrics` methods are thin wrappers over
//! `Engine::execute`.  See `docs/architecture.md`.
//!
//! - [`queue`] — the DNNG task queue: arrivals, per-DNN layer progress,
//!   ready-layer extraction (DAG predecessors honored).
//! - [`partition`] — the partition manager: rectangular tiles of the
//!   array (full-height column slices in the paper's `columns` mode),
//!   allocation (widest-free, best-fit 2D, or at an exact position),
//!   freeing, and adjacent-free rectangle merging.
//! - [`scheduler`] — the dynamic partitioning policy: the
//!   `Partition_Calculation` / `Task_Assignment` / partitioned-WS
//!   decisions of the paper.
//! - [`baseline`] — the single-tenant sequential baseline the paper
//!   compares against (whole array per layer, DNNs back-to-back).
//! - [`static_part`] — ablation: fixed equal partitions, no merging.
//! - [`multi_array`] — comparator: the §5 related-work alternative of
//!   allocating whole DNNs to separate chips (TPU-pod style).
//! - [`metrics`] — run metrics: makespan, per-DNN completion, utilization,
//!   per-tenant latency percentiles and deadline misses, the partition-size
//!   dispatch log behind Fig. 9(c)(d), energy hookup.  [`RunMetrics`]
//!   implements [`Observer`](crate::sim_core::Observer), so metrics are
//!   collected identically on every execution path.
//! - [`scenario`] — the arrival-driven scenario engine: instantiates
//!   request streams (Poisson / bursty / trace) over the zoo with per-DNN
//!   QoS deadlines, and scores runs against them (SLA view the paper's
//!   two static Table-1 mixes lack; cf. MoCA, arXiv 2305.05843).
//! - `service` — the multi-tenant serving loop that executes scheduler
//!   decisions on the PJRT runtime (real numerics; used by `e2e_serve`;
//!   behind the `pjrt` feature).

pub mod baseline;
pub mod metrics;
pub mod multi_array;
pub mod partition;
pub mod queue;
pub mod scenario;
pub mod scheduler;
#[cfg(feature = "pjrt")]
pub mod service;
pub mod static_part;

pub use metrics::{DispatchRecord, RunMetrics, TenantStats};
pub use partition::PartitionManager;
pub use scenario::{Scenario, ScenarioObserver, ScenarioSpec};
pub use scheduler::{
    plan_arena_enabled, plan_cache_enabled, DynamicScheduler, PartitionMode, PreemptMode,
    SchedulerConfig, UnknownTag,
};
