//! Multi-array comparator — the §5 related-work alternative: "multi
//! tenancy is performed by allocating different tenant DNNs to different
//! TPUs" (whole-chip granularity, no partitioning inside an array) — as a
//! [`Scheduler`] on the shared engine.
//!
//! Splits the same PE budget into `n` independent arrays; DNNs are
//! assigned to the least-loaded array on arrival (by assigned MACs,
//! through the [`Scheduler::on_arrival`] hook) and run there to
//! completion, each array executing its queue sequentially at full
//! (local) width.  Chips are modelled as fixed column ranges of the
//! pooled silicon, so the one engine and the one metrics pipeline serve
//! this comparator too.  The `ablations` bench compares this against
//! partitioning one big array — the paper's actual proposal — at equal
//! total PE count, isolating what intra-array partitioning buys over
//! chip-granularity scale-out.

use std::collections::BTreeMap;

use super::metrics::RunMetrics;
use super::queue::ReadyLayer;
use super::scheduler::SchedulerConfig;
use crate::sim::buffers::BufferConfig;
use crate::sim::dataflow::{baseline_layer_timing, ArrayGeometry};
use crate::sim::partitioned::Tile;
use crate::sim_core::{Allocation, Engine, LayerExec, Scheduler, SystemState};
use crate::workloads::dnng::{DnnId, LayerId, WorkloadPool};

/// A bank of `n` independent arrays (whole-DNN granularity).
#[derive(Debug, Clone)]
pub struct MultiArrayBank {
    /// Geometry of EACH array.
    pub geom_each: ArrayGeometry,
    pub num_arrays: usize,
    pub cfg: SchedulerConfig,
}

impl MultiArrayBank {
    /// Split a base config's array into `n` equal vertical chips
    /// (rows preserved, columns divided — the same silicon budget).
    pub fn split_of(cfg: &SchedulerConfig, n: usize) -> MultiArrayBank {
        assert!(n >= 1 && cfg.geom.cols as usize % n == 0, "cols must divide by n");
        let geom_each = ArrayGeometry::new(cfg.geom.rows, cfg.geom.cols / n as u64);
        MultiArrayBank { geom_each, num_arrays: n, cfg: cfg.clone() }
    }

    /// Run the pool: least-remaining-work assignment, per-array FIFO.
    pub fn run(&self, pool: &WorkloadPool) -> RunMetrics {
        Engine::execute(pool, self.cfg.geom, &mut MultiArrayPolicy::new(self))
    }
}

/// The per-run policy state of a [`MultiArrayBank`] (assignment table and
/// per-chip FIFOs are rebuilt fresh for every run).
#[derive(Debug, Clone)]
pub struct MultiArrayPolicy {
    geom_each: ArrayGeometry,
    num_arrays: usize,
    /// Buffer share scales with the chip fraction.
    bufs_each: BufferConfig,
    dram: Option<crate::sim::dram::DramConfig>,
    /// Shared memory hierarchy over the pooled silicon (chips contend
    /// for the one interface like partitions do).
    mem_spec: Option<crate::mem::MemSpec>,
    /// DNN → chip, filled on arrival.
    assignment: BTreeMap<DnnId, usize>,
    /// Per-chip queues in assignment (= arrival) order.
    fifo: Vec<Vec<DnnId>>,
    /// Accumulated assigned MACs per chip.
    load: Vec<u64>,
    /// MACs each live DNN contributed to its chip's load (so a recycled
    /// slot's contribution can be subtracted when it retires).
    macs: BTreeMap<DnnId, u64>,
    /// Recycled ready-layer scratch — see `SequentialBaseline::ready_buf`.
    ready_buf: Vec<ReadyLayer>,
}

impl MultiArrayPolicy {
    pub fn new(bank: &MultiArrayBank) -> MultiArrayPolicy {
        MultiArrayPolicy {
            geom_each: bank.geom_each,
            num_arrays: bank.num_arrays,
            bufs_each: bank.cfg.buffers.share(bank.geom_each.cols, bank.cfg.geom.cols),
            dram: bank.cfg.dram.clone(),
            mem_spec: bank.cfg.mem_spec(),
            assignment: BTreeMap::new(),
            fifo: vec![Vec::new(); bank.num_arrays],
            load: vec![0; bank.num_arrays],
            macs: BTreeMap::new(),
            ready_buf: Vec::new(),
        }
    }

    /// The column range chip `a` occupies in the pooled silicon
    /// (full-height: chips span every row).
    fn chip_tile(&self, a: usize) -> Tile {
        Tile::new(0, a as u64 * self.geom_each.cols, self.geom_each.rows, self.geom_each.cols)
    }
}

impl Scheduler for MultiArrayPolicy {
    fn name(&self) -> &'static str {
        "multi-array"
    }

    fn mem_spec(&self) -> Option<crate::mem::MemSpec> {
        self.mem_spec
    }

    /// Least-loaded assignment (by assigned MACs, then chip index) at the
    /// moment of arrival — arrival events are processed in `(cycle, dnn)`
    /// order, which is exactly the pool's `by_arrival` order.
    fn on_arrival(&mut self, s: &SystemState<'_>, dnn: DnnId) {
        if self.assignment.contains_key(&dnn) {
            return;
        }
        let a = (0..self.num_arrays).min_by_key(|&i| (self.load[i], i)).expect(">=1 array");
        let macs = s.pool.dnns[dnn].total_macs();
        self.load[a] += macs;
        self.assignment.insert(dnn, a);
        self.macs.insert(dnn, macs);
        self.fifo[a].push(dnn);
    }

    /// Slot recycling: forget the retired DNN so the id can be reassigned
    /// fresh (otherwise `on_arrival`'s dedup would pin the recycled id to
    /// the old chip and the stale MACs would skew least-loaded forever).
    fn on_dnn_retired(&mut self, dnn: DnnId) {
        if let Some(a) = self.assignment.remove(&dnn) {
            self.load[a] -= self.macs.remove(&dnn).unwrap_or(0);
            self.fifo[a].retain(|&d| d != dnn);
        }
    }

    fn plan(&mut self, s: &SystemState<'_>) -> Vec<Allocation> {
        let mut ready = std::mem::take(&mut self.ready_buf);
        s.queue.ready_into(s.now, &mut ready);
        if ready.is_empty() {
            self.ready_buf = ready;
            return Vec::new();
        }
        let mut out = Vec::new();
        for a in 0..self.num_arrays {
            let chip = self.chip_tile(a);
            if !s.partitions.is_free(chip) {
                continue; // this chip is mid-layer
            }
            // Strict FIFO: the first unfinished DNN owns the chip; later
            // assignees wait for it even if they are ready.
            let Some(&dnn) = self.fifo[a].iter().find(|&&d| !s.queue.dnn_done(d)) else {
                continue;
            };
            let Some(layer) = ready.iter().filter(|r| r.dnn == dnn).map(|r| r.layer).min() else {
                continue;
            };
            out.push(Allocation::array(dnn, layer, chip));
        }
        self.ready_buf = ready;
        out
    }

    fn exec(
        &self,
        s: &SystemState<'_>,
        dnn: DnnId,
        layer: LayerId,
        _tile: Tile,
        _coresident: u64,
    ) -> LayerExec {
        let gemm = s.pool.dnns[dnn].layers[layer].shape.gemm();
        let t = baseline_layer_timing(self.geom_each, gemm, &self.bufs_each);
        let cycles = match &self.dram {
            Some(d) => d.bound_cycles(t.cycles, &t.activity),
            None => t.cycles,
        };
        LayerExec { cycles, activity: t.activity }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::DynamicScheduler;
    use crate::workloads::dnng::{Dnn, Layer};
    use crate::workloads::models::heavy_pool;
    use crate::workloads::shapes::{LayerKind, LayerShape};

    #[test]
    fn one_array_equals_sequential_baseline() {
        let cfg = SchedulerConfig::default();
        let pool = heavy_pool();
        let bank = MultiArrayBank::split_of(&cfg, 1);
        let seq = super::super::baseline::SequentialBaseline::new(cfg).run(&pool);
        let multi = bank.run(&pool);
        assert_eq!(multi.makespan, seq.makespan);
    }

    #[test]
    fn balances_across_arrays() {
        let cfg = SchedulerConfig::default();
        let mk = |name: &str| {
            Dnn::chain(
                name,
                vec![Layer::new("l", LayerKind::Fc, LayerShape::fc(64, 256, 256))],
            )
        };
        let pool = WorkloadPool::new("t", vec![mk("a"), mk("b"), mk("c"), mk("d")]);
        let bank = MultiArrayBank::split_of(&cfg, 4);
        let m = bank.run(&pool);
        // Equal DNNs spread one per chip: all four start at cycle 0.
        assert!(m.dispatches.iter().all(|d| d.t_start == 0));
        let chips: std::collections::BTreeSet<u64> =
            m.dispatches.iter().map(|d| d.tile.col0).collect();
        assert_eq!(chips.len(), 4);
    }

    #[test]
    fn partitioned_single_array_beats_chip_granularity_on_heavy_pool() {
        // The paper's core architectural claim vs its related work: at
        // equal silicon, dynamically partitioning ONE array outperforms
        // four fixed quarter-width chips — chips strand capacity whenever
        // their queue drains or a wide-M layer folds onto 32 columns.
        let cfg = SchedulerConfig::default();
        let pool = heavy_pool();
        let partitioned = DynamicScheduler::new(cfg.clone()).run(&pool);
        let chips4 = MultiArrayBank::split_of(&cfg, 4).run(&pool);
        assert!(
            partitioned.makespan < chips4.makespan,
            "partitioned {} !< 4-chip {}",
            partitioned.makespan,
            chips4.makespan
        );
    }
}
