//! Multi-array comparator — the §5 related-work alternative: "multi
//! tenancy is performed by allocating different tenant DNNs to different
//! TPUs" (whole-chip granularity, no partitioning inside an array).
//!
//! Splits the same PE budget into `n` independent arrays; DNNs are
//! assigned to the least-loaded array on arrival (by remaining MACs) and
//! run there to completion, each array executing its queue sequentially
//! at full (local) width.  The `ablations` bench compares this against
//! partitioning one big array — the paper's actual proposal — at equal
//! total PE count, isolating what intra-array partitioning buys over
//! chip-granularity scale-out.

use super::metrics::{DispatchRecord, RunMetrics};
use super::scheduler::SchedulerConfig;
use crate::sim::dataflow::{baseline_layer_timing, ArrayGeometry};
use crate::sim::partitioned::PartitionSlice;
use crate::workloads::dnng::WorkloadPool;

/// A bank of `n` independent arrays (whole-DNN granularity).
#[derive(Debug, Clone)]
pub struct MultiArrayBank {
    /// Geometry of EACH array.
    pub geom_each: ArrayGeometry,
    pub num_arrays: usize,
    pub cfg: SchedulerConfig,
}

impl MultiArrayBank {
    /// Split a base config's array into `n` equal vertical chips
    /// (rows preserved, columns divided — the same silicon budget).
    pub fn split_of(cfg: &SchedulerConfig, n: usize) -> MultiArrayBank {
        assert!(n >= 1 && cfg.geom.cols as usize % n == 0, "cols must divide by n");
        let geom_each = ArrayGeometry::new(cfg.geom.rows, cfg.geom.cols / n as u64);
        MultiArrayBank { geom_each, num_arrays: n, cfg: cfg.clone() }
    }

    /// Run the pool: least-remaining-work assignment, per-array FIFO.
    pub fn run(&self, pool: &WorkloadPool) -> RunMetrics {
        // Buffer share scales with the chip fraction.
        let bufs = self.cfg.buffers.share(self.geom_each.cols, self.cfg.geom.cols);
        let mut metrics = RunMetrics::default();
        // (next-free-cycle, accumulated load) per array.
        let mut free_at = vec![0u64; self.num_arrays];
        let mut load = vec![0u64; self.num_arrays];

        for dnn_id in pool.by_arrival() {
            let dnn = &pool.dnns[dnn_id];
            // Least-loaded array (by assigned MACs, then index).
            let a = (0..self.num_arrays).min_by_key(|&i| (load[i], i)).unwrap();
            load[a] += dnn.total_macs();
            let mut now = free_at[a].max(dnn.arrival_cycles);
            for (li, layer) in dnn.layers.iter().enumerate() {
                let t = baseline_layer_timing(self.geom_each, layer.shape.gemm(), &bufs);
                let cycles = match &self.cfg.dram {
                    Some(d) => d.bound_cycles(t.cycles, &t.activity),
                    None => t.cycles,
                };
                metrics.record_dispatch(DispatchRecord {
                    dnn: dnn_id,
                    dnn_name: dnn.name.clone(),
                    layer: li,
                    layer_name: layer.name.clone(),
                    // Record the chip as a column range of the pooled silicon.
                    slice: PartitionSlice::new(
                        a as u64 * self.geom_each.cols,
                        self.geom_each.cols,
                    ),
                    t_start: now,
                    t_end: now + cycles,
                    activity: t.activity,
                });
                now += cycles;
            }
            free_at[a] = now;
        }
        metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::DynamicScheduler;
    use crate::workloads::dnng::{Dnn, Layer};
    use crate::workloads::models::heavy_pool;
    use crate::workloads::shapes::{LayerKind, LayerShape};

    #[test]
    fn one_array_equals_sequential_baseline() {
        let cfg = SchedulerConfig::default();
        let pool = heavy_pool();
        let bank = MultiArrayBank::split_of(&cfg, 1);
        let seq = super::super::baseline::SequentialBaseline::new(cfg).run(&pool);
        let multi = bank.run(&pool);
        assert_eq!(multi.makespan, seq.makespan);
    }

    #[test]
    fn balances_across_arrays() {
        let cfg = SchedulerConfig::default();
        let mk = |name: &str| {
            Dnn::chain(
                name,
                vec![Layer::new("l", LayerKind::Fc, LayerShape::fc(64, 256, 256))],
            )
        };
        let pool = WorkloadPool::new("t", vec![mk("a"), mk("b"), mk("c"), mk("d")]);
        let bank = MultiArrayBank::split_of(&cfg, 4);
        let m = bank.run(&pool);
        // Equal DNNs spread one per chip: all four start at cycle 0.
        assert!(m.dispatches.iter().all(|d| d.t_start == 0));
        let chips: std::collections::BTreeSet<u64> =
            m.dispatches.iter().map(|d| d.slice.col0).collect();
        assert_eq!(chips.len(), 4);
    }

    #[test]
    fn partitioned_single_array_beats_chip_granularity_on_heavy_pool() {
        // The paper's core architectural claim vs its related work: at
        // equal silicon, dynamically partitioning ONE array outperforms
        // four fixed quarter-width chips — chips strand capacity whenever
        // their queue drains or a wide-M layer folds onto 32 columns.
        let cfg = SchedulerConfig::default();
        let pool = heavy_pool();
        let partitioned = DynamicScheduler::new(cfg.clone()).run(&pool);
        let chips4 = MultiArrayBank::split_of(&cfg, 4).run(&pool);
        assert!(
            partitioned.makespan < chips4.makespan,
            "partitioned {} !< 4-chip {}",
            partitioned.makespan,
            chips4.makespan
        );
    }
}
