//! Ablation: static equal partitioning without merging.
//!
//! The array is divided into `n_dnns` equal vertical partitions up front;
//! DNN `i` is pinned to partition `i` for its whole lifetime.  No merging,
//! no reallocation — what a naive multi-tenant split would do.  The
//! `ablation_merging` bench compares this against the dynamic scheduler to
//! isolate the value of partition merging + Opr-sorted assignment.

use super::metrics::{DispatchRecord, RunMetrics};
use super::scheduler::SchedulerConfig;
use crate::sim::partitioned::{slice_layer_timing, FeedPolicy, PartitionSlice};
use crate::workloads::dnng::WorkloadPool;

/// Static equal-partition executor.
#[derive(Debug, Clone)]
pub struct StaticPartitioning {
    cfg: SchedulerConfig,
}

impl StaticPartitioning {
    pub fn new(cfg: SchedulerConfig) -> StaticPartitioning {
        StaticPartitioning { cfg }
    }

    /// Run the pool with one fixed partition per DNN.
    ///
    /// Panics if the pool has more DNNs than `cols / min_width` partitions
    /// can host.
    pub fn run(&self, pool: &WorkloadPool) -> RunMetrics {
        let cfg = &self.cfg;
        let n = pool.dnns.len() as u64;
        assert!(n >= 1);
        let width = (cfg.geom.cols / n).max(1);
        assert!(
            width >= cfg.min_width,
            "{} DNNs need width {width} < min {}",
            n,
            cfg.min_width
        );

        let mut metrics = RunMetrics::default();
        for (di, dnn) in pool.dnns.iter().enumerate() {
            let slice = PartitionSlice::new(di as u64 * width, width);
            let mut now = dnn.arrival_cycles;
            for (li, layer) in dnn.layers.iter().enumerate() {
                let t = slice_layer_timing(
                    cfg.geom,
                    layer.shape.gemm(),
                    slice,
                    FeedPolicy::Independent,
                    &cfg.buffers,
                );
                let cycles = match &cfg.dram {
                    Some(d) => d.bound_cycles(t.cycles, &t.activity),
                    None => t.cycles,
                };
                metrics.record_dispatch(DispatchRecord {
                    dnn: di,
                    dnn_name: dnn.name.clone(),
                    layer: li,
                    layer_name: layer.name.clone(),
                    slice,
                    t_start: now,
                    t_end: now + cycles,
                    activity: t.activity,
                });
                now += cycles;
            }
        }
        metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::DynamicScheduler;
    use crate::workloads::dnng::{Dnn, Layer};
    use crate::workloads::shapes::{LayerKind, LayerShape};

    fn pool(sizes: &[&[u64]]) -> WorkloadPool {
        let dnns = sizes
            .iter()
            .enumerate()
            .map(|(i, ms)| {
                let layers = ms
                    .iter()
                    .enumerate()
                    .map(|(j, &m)| {
                        Layer::new(&format!("l{j}"), LayerKind::Fc, LayerShape::fc(64, 128, m))
                    })
                    .collect();
                Dnn::chain(&format!("d{i}"), layers)
            })
            .collect();
        WorkloadPool::new("t", dnns)
    }

    #[test]
    fn partitions_are_fixed_and_disjoint() {
        let p = pool(&[&[128, 128], &[128], &[128, 128, 128], &[128]]);
        let m = StaticPartitioning::new(SchedulerConfig::default()).run(&p);
        for d in &m.dispatches {
            assert_eq!(d.slice.width, 32);
            assert_eq!(d.slice.col0, d.dnn as u64 * 32);
        }
    }

    #[test]
    fn dynamic_beats_static_on_skewed_pools() {
        // One long DNN + three tiny ones: the static split strands 3/4 of
        // the array idle while the long DNN grinds on 32 columns; the
        // dynamic scheduler lets it reclaim freed partitions.
        let p = pool(&[
            &[2048, 2048, 2048, 2048, 2048, 2048, 2048, 2048],
            &[64],
            &[64],
            &[64],
        ]);
        let stat = StaticPartitioning::new(SchedulerConfig::default()).run(&p);
        let dynm = DynamicScheduler::new(SchedulerConfig::default()).run(&p);
        assert!(
            dynm.makespan < stat.makespan,
            "dynamic {} vs static {}",
            dynm.makespan,
            stat.makespan
        );
    }

    #[test]
    #[should_panic(expected = "min")]
    fn too_many_tenants_rejected() {
        let sizes: Vec<&[u64]> = vec![&[8]; 20];
        let p = pool(&sizes);
        StaticPartitioning::new(SchedulerConfig::default()).run(&p);
    }
}
