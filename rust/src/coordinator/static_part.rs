//! Ablation: static equal partitioning without merging, as a
//! [`Scheduler`] on the shared engine.
//!
//! The array is divided into `n_dnns` equal vertical partitions up front;
//! DNN `i` is pinned to partition `i` for its whole lifetime.  No merging,
//! no reallocation — what a naive multi-tenant split would do.  The
//! `ablation_merging` bench compares this against the dynamic scheduler to
//! isolate the value of partition merging + Opr-sorted assignment.

use std::collections::BTreeMap;

use super::metrics::RunMetrics;
use super::queue::ReadyLayer;
use super::scheduler::SchedulerConfig;
use crate::sim::partitioned::{tile_layer_timing, FeedPolicy, Tile};
use crate::sim_core::{Allocation, Engine, LayerExec, Scheduler, SystemState};
use crate::workloads::dnng::{DnnId, LayerId, WorkloadPool};

/// Static equal-partition policy.
#[derive(Debug, Clone)]
pub struct StaticPartitioning {
    cfg: SchedulerConfig,
    /// Recycled ready-layer scratch — see `SequentialBaseline::ready_buf`.
    ready_buf: Vec<ReadyLayer>,
}

impl StaticPartitioning {
    pub fn new(cfg: SchedulerConfig) -> StaticPartitioning {
        StaticPartitioning { cfg, ready_buf: Vec::new() }
    }

    /// Each DNN's fixed partition width for `pool`.
    ///
    /// Panics if the pool has more DNNs than `cols / min_width`
    /// partitions can host — checked here (not just in [`Self::run`]) so
    /// the guard also fires when the policy is driven through the
    /// generic engine entry points (`Engine::execute`, `Scenario::run`).
    fn width_for(&self, pool: &WorkloadPool) -> u64 {
        let n = pool.dnns.len() as u64;
        assert!(n >= 1);
        let width = (self.cfg.geom.cols / n).max(1);
        assert!(
            width >= self.cfg.min_width,
            "{n} DNNs need width {width} < min {}",
            self.cfg.min_width
        );
        width
    }

    /// Run the pool with one fixed partition per DNN.
    ///
    /// Panics if the pool has more DNNs than `cols / min_width` partitions
    /// can host.
    pub fn run(&self, pool: &WorkloadPool) -> RunMetrics {
        self.width_for(pool); // capacity guard before the engine spins up
        Engine::execute(pool, self.cfg.geom, &mut self.clone())
    }
}

impl Scheduler for StaticPartitioning {
    fn name(&self) -> &'static str {
        "static"
    }

    fn mem_spec(&self) -> Option<crate::mem::MemSpec> {
        self.cfg.mem_spec()
    }

    fn plan(&mut self, s: &SystemState<'_>) -> Vec<Allocation> {
        let width = self.width_for(s.pool);
        // At most one layer per DNN (the lowest-index ready one), into
        // its pinned slice — which is free exactly when the DNN has no
        // layer in flight.
        let mut ready = std::mem::take(&mut self.ready_buf);
        s.queue.ready_into(s.now, &mut ready);
        let mut next: BTreeMap<DnnId, LayerId> = BTreeMap::new();
        for r in &ready {
            let e = next.entry(r.dnn).or_insert(r.layer);
            if r.layer < *e {
                *e = r.layer;
            }
        }
        self.ready_buf = ready;
        next.into_iter()
            .filter_map(|(dnn, layer)| {
                let tile = Tile::full_height(self.cfg.geom, dnn as u64 * width, width);
                s.partitions.is_free(tile).then_some(Allocation::array(dnn, layer, tile))
            })
            .collect()
    }

    fn exec(
        &self,
        s: &SystemState<'_>,
        dnn: DnnId,
        layer: LayerId,
        tile: Tile,
        _coresident: u64,
    ) -> LayerExec {
        let gemm = s.pool.dnns[dnn].layers[layer].shape.gemm();
        let t = tile_layer_timing(
            self.cfg.geom,
            gemm,
            tile,
            FeedPolicy::Independent,
            &self.cfg.buffers,
        );
        let cycles = match &self.cfg.dram {
            Some(d) => d.bound_cycles(t.cycles, &t.activity),
            None => t.cycles,
        };
        LayerExec { cycles, activity: t.activity }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::DynamicScheduler;
    use crate::workloads::dnng::{Dnn, Layer};
    use crate::workloads::shapes::{LayerKind, LayerShape};

    fn pool(sizes: &[&[u64]]) -> WorkloadPool {
        let dnns = sizes
            .iter()
            .enumerate()
            .map(|(i, ms)| {
                let layers = ms
                    .iter()
                    .enumerate()
                    .map(|(j, &m)| {
                        Layer::new(&format!("l{j}"), LayerKind::Fc, LayerShape::fc(64, 128, m))
                    })
                    .collect();
                Dnn::chain(&format!("d{i}"), layers)
            })
            .collect();
        WorkloadPool::new("t", dnns)
    }

    #[test]
    fn partitions_are_fixed_and_disjoint() {
        let p = pool(&[&[128, 128], &[128], &[128, 128, 128], &[128]]);
        let m = StaticPartitioning::new(SchedulerConfig::default()).run(&p);
        for d in &m.dispatches {
            assert_eq!(d.tile.cols, 32);
            assert_eq!(d.tile.col0, d.dnn as u64 * 32);
        }
    }

    #[test]
    fn dynamic_beats_static_on_skewed_pools() {
        // One long DNN + three tiny ones: the static split strands 3/4 of
        // the array idle while the long DNN grinds on 32 columns; the
        // dynamic scheduler lets it reclaim freed partitions.
        let p = pool(&[
            &[2048, 2048, 2048, 2048, 2048, 2048, 2048, 2048],
            &[64],
            &[64],
            &[64],
        ]);
        let stat = StaticPartitioning::new(SchedulerConfig::default()).run(&p);
        let dynm = DynamicScheduler::new(SchedulerConfig::default()).run(&p);
        assert!(
            dynm.makespan < stat.makespan,
            "dynamic {} vs static {}",
            dynm.makespan,
            stat.makespan
        );
    }

    #[test]
    #[should_panic(expected = "min")]
    fn too_many_tenants_rejected() {
        let sizes: Vec<&[u64]> = vec![&[8]; 20];
        let p = pool(&sizes);
        StaticPartitioning::new(SchedulerConfig::default()).run(&p);
    }
}
