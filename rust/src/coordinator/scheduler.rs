//! The dynamic resource-partitioning policy — Algorithm 1 (Fig. 5) — as a
//! [`Scheduler`] plugged into the shared event engine
//! ([`crate::sim_core::Engine`]):
//!
//! 1. The first ready layer on an idle array takes **all** PEs (Line 6).
//! 2. At every scheduling point (a completion or an arrival), the ready
//!    layers are sorted by `Opr` (Eq. 2) descending (`Task_Assignment`,
//!    Lines 20–27) and assigned heaviest-first to the widest free
//!    partitions.
//! 3. `Partition_Calculation` (Lines 15–19) sizes partitions as
//!    `cols / n_available` — rounded down to a power of two so widths land
//!    on the {16, 32, 64, 128} ladder of Fig. 9(c)(d) — clamped to
//!    `min_width` (default `cols/8 = 16`).
//! 4. Completed layers free their slice; adjacent free slices merge
//!    (§3.3), so a late straggler can reclaim the whole array.
//!
//! [`DynamicScheduler::plan`](crate::sim_core::Scheduler::plan) rehearses
//! the carving on a clone of the live
//! [`PartitionManager`](super::partition::PartitionManager) and returns
//! explicit column positions; the engine replays them with
//! `allocate_at`, so the placement is exactly what the rehearsal saw.
//! Layer execution time comes from the partitioned-WS analytic model
//! ([`crate::sim::partitioned`]), optionally DRAM-bandwidth-bounded.
//! `rust/tests/engine_parity.rs` pins this port bit-for-bit against the
//! pre-refactor fused batch loop.

use std::collections::{BTreeMap, BTreeSet};
use std::str::FromStr;
use std::sync::OnceLock;

use super::metrics::RunMetrics;
use super::partition::{AllocId, LaneManager, PartitionManager};
use super::queue::ReadyLayer;
use crate::mem::{MemConfig, MemSpec};
use crate::profiler::ProfileStore;
use crate::sim::activity::Activity;
use crate::sim::buffers::BufferConfig;
use crate::sim::dataflow::{
    layer_timing_vector, next_fold_boundary, vector_compute_cycles, ArrayGeometry, VectorUnit,
};
use crate::sim::dram::DramConfig;
use crate::sim::partitioned::{tile_layer_timing, FeedPolicy, LaneSpan, Tile};
use crate::sim_core::{
    Allocation, Checkpoint, Engine, LayerExec, RunningLayer, Scheduler, SystemState,
};
use crate::workloads::dnng::{DnnId, LayerId, WorkloadPool};
use crate::workloads::shapes::{GemmDims, OpClass};

pub use crate::util::UnknownTag;

/// Partition-shape selector: the paper's full-height column slices, or
/// rectangular 2D fission (Planaria-style; see `docs/fission.md`).
///
/// `columns` (the default) reproduces the pre-2D scheduler bit for bit —
/// every tile is full-height and the planner logic is unchanged.  `2d`
/// lets the dynamic policy also split rows, choosing row-split vs
/// column-split per decision point by minimizing the projected
/// fold-adjusted completion time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PartitionMode {
    /// Full-height column slices only (the paper's model; default).
    #[default]
    Columns,
    /// Rectangular tiles: rows and columns both divisible.
    TwoD,
}

impl PartitionMode {
    /// Every variant, in tag order.
    pub const ALL: [PartitionMode; 2] = [PartitionMode::Columns, PartitionMode::TwoD];
    /// The tags of [`PartitionMode::ALL`], in the same order.
    pub const TAGS: [&'static str; 2] = ["columns", "2d"];

    /// Stable config/CLI/report name (round-trips through [`FromStr`]).
    pub fn tag(self) -> &'static str {
        match self {
            PartitionMode::Columns => Self::TAGS[0],
            PartitionMode::TwoD => Self::TAGS[1],
        }
    }
}

impl FromStr for PartitionMode {
    type Err = UnknownTag;

    fn from_str(s: &str) -> Result<PartitionMode, UnknownTag> {
        PartitionMode::ALL.into_iter().find(|m| m.tag() == s).ok_or_else(|| UnknownTag {
            what: "partition mode",
            got: s.to_string(),
            valid: &PartitionMode::TAGS,
        })
    }
}

/// When the dynamic policy may preempt a *running* layer at its next
/// fold boundary (drain-and-reshape; see `docs/preemption.md`).
///
/// `off` (the default) reproduces the non-preemptive scheduler bit for
/// bit — arrivals only reclaim PEs at `LayerComplete` events, so a light
/// tenant can stall behind a wide tenant's long layer (head-of-line
/// blocking).  `arrival` arms a preemption check at every DNN arrival;
/// `deadline` additionally reacts to deadline verdicts, evicting tenants
/// that have already missed theirs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PreemptMode {
    /// Never interrupt a running layer (the paper's model; default).
    #[default]
    Off,
    /// Preempt when an arrival would otherwise starve behind a running
    /// tenant holding more than its recomputed equal share.
    Arrival,
    /// `arrival`, plus deadline awareness: replan at deadline events and
    /// evict first from tenants whose deadline has already passed unmet.
    Deadline,
}

impl PreemptMode {
    /// Every variant, in tag order.
    pub const ALL: [PreemptMode; 3] =
        [PreemptMode::Off, PreemptMode::Arrival, PreemptMode::Deadline];
    /// The tags of [`PreemptMode::ALL`], in the same order.
    pub const TAGS: [&'static str; 3] = ["off", "arrival", "deadline"];

    /// Stable config/CLI/report name (round-trips through [`FromStr`]).
    pub fn tag(self) -> &'static str {
        match self {
            PreemptMode::Off => Self::TAGS[0],
            PreemptMode::Arrival => Self::TAGS[1],
            PreemptMode::Deadline => Self::TAGS[2],
        }
    }
}

impl FromStr for PreemptMode {
    type Err = UnknownTag;

    fn from_str(s: &str) -> Result<PreemptMode, UnknownTag> {
        PreemptMode::ALL.into_iter().find(|m| m.tag() == s).ok_or_else(|| UnknownTag {
            what: "preempt mode",
            got: s.to_string(),
            valid: &PreemptMode::TAGS,
        })
    }
}

/// Feed-bus model selector for the scheduler (the per-dispatch slot/count
/// is filled in from live occupancy).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FeedModel {
    /// Paper model: partitions stream independently.
    #[default]
    Independent,
    /// Conservative physical model: row wires time-sliced among all
    /// co-resident partitions at dispatch time.
    Interleaved,
}

impl FeedModel {
    /// Every variant, in tag order.
    pub const ALL: [FeedModel; 2] = [FeedModel::Independent, FeedModel::Interleaved];
    /// The tags of [`FeedModel::ALL`], in the same order.
    pub const TAGS: [&'static str; 2] = ["independent", "interleaved"];

    /// Stable config/CLI/report name (round-trips through [`FromStr`]).
    pub fn tag(self) -> &'static str {
        match self {
            FeedModel::Independent => Self::TAGS[0],
            FeedModel::Interleaved => Self::TAGS[1],
        }
    }
}

impl FromStr for FeedModel {
    type Err = UnknownTag;

    fn from_str(s: &str) -> Result<FeedModel, UnknownTag> {
        FeedModel::ALL.into_iter().find(|m| m.tag() == s).ok_or_else(|| UnknownTag {
            what: "feed model",
            got: s.to_string(),
            valid: &FeedModel::TAGS,
        })
    }
}

/// Partition-width allocation policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AllocPolicy {
    /// `Task_Assignment` faithful: the heaviest ready layer takes the
    /// widest free slice up to its demand; lighter layers take what
    /// remains.  "Layers with higher dimensions are assigned to the
    /// partitions with higher resources" (§3.3).
    #[default]
    WidestToHeaviest,
    /// Literal `Partition_Calculation`: every ready layer gets
    /// `cols / n_available` (power-of-two ladder), regardless of demand.
    /// Kept as an ablation (`ablation_alloc_policy`).
    EqualShare,
    /// MoCA-style memory-aware variant of `WidestToHeaviest` (arXiv
    /// 2305.05843): reads the bandwidth arbiter's feedback
    /// ([`SystemState::mem`]) and *throttles* memory-bound tenants by
    /// never co-running two memory-bound layers — time-multiplexing a
    /// saturated interface beats processor-sharing it (both finish later
    /// than either alone).  Identical to `WidestToHeaviest` when the
    /// `[mem]` hierarchy is disabled.
    MemAware,
}

impl AllocPolicy {
    /// Every variant, in tag order.
    pub const ALL: [AllocPolicy; 3] =
        [AllocPolicy::WidestToHeaviest, AllocPolicy::EqualShare, AllocPolicy::MemAware];
    /// The tags of [`AllocPolicy::ALL`], in the same order.
    pub const TAGS: [&'static str; 3] = ["widest", "equal", "mem-aware"];

    /// Stable config/CLI/report name (round-trips through [`FromStr`]).
    pub fn tag(self) -> &'static str {
        match self {
            AllocPolicy::WidestToHeaviest => Self::TAGS[0],
            AllocPolicy::EqualShare => Self::TAGS[1],
            AllocPolicy::MemAware => Self::TAGS[2],
        }
    }
}

impl FromStr for AllocPolicy {
    type Err = UnknownTag;

    fn from_str(s: &str) -> Result<AllocPolicy, UnknownTag> {
        AllocPolicy::ALL.into_iter().find(|p| p.tag() == s).ok_or_else(|| UnknownTag {
            what: "allocation policy",
            got: s.to_string(),
            valid: &AllocPolicy::TAGS,
        })
    }
}

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    pub geom: ArrayGeometry,
    pub buffers: BufferConfig,
    /// Narrowest partition the scheduler will create.
    pub min_width: u64,
    /// Shortest tile the scheduler will create (2D mode only; `columns`
    /// mode always allocates full-height tiles).
    pub min_rows: u64,
    /// Column slices (paper) or rectangular 2D fission.
    pub partition_mode: PartitionMode,
    /// Fold-boundary preemption of running layers (`[partition] preempt`
    /// / `--preempt`); `off` keeps the non-preemptive scheduler exactly.
    pub preempt: PreemptMode,
    pub feed_model: FeedModel,
    pub alloc_policy: AllocPolicy,
    /// Patience: a layer dispatches only into a slice ≥ `demand /
    /// patience_divisor`; otherwise it waits for merges (unless nothing is
    /// running).  Folding a wide-M layer into a sliver multiplies its fold
    /// count, so impatience costs far more than waiting.
    pub patience_divisor: u64,
    /// Apply the *isolated* DRAM bandwidth bound to layer times
    /// (mutually exclusive with [`SchedulerConfig::mem`]).
    pub dram: Option<DramConfig>,
    /// Simulate the *shared* memory hierarchy (`[mem]` config section):
    /// cross-tenant bandwidth arbitration + banked buffer allocation on
    /// the engine.  Subsumes `dram`.
    pub mem: Option<MemConfig>,
    /// Offline profile tables (`[partition] tables = <dir>` /
    /// `mtsa profile`): the `2d` planner unions each layer's profiled
    /// exact-fit shapes with its online pow-2 height ladder, so it can
    /// fill non-pow-2 free rectangles the ladder must round down from.
    /// `None` (the default) keeps the ladder-only planner bit for bit.
    pub tables: Option<std::sync::Arc<ProfileStore>>,
    /// The machine's vector engine (`[vector]` config section): when
    /// set, the planner places memory-bound ready layers (low
    /// arithmetic intensity — see
    /// [`op_class`](crate::workloads::shapes::op_class)) on lane spans
    /// while compute-bound tenants keep the systolic array.  `None`
    /// (the default) keeps the array-only machine bit for bit.
    pub vector: Option<VectorUnit>,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        let geom = ArrayGeometry::new(128, 128);
        SchedulerConfig {
            geom,
            buffers: BufferConfig::default(),
            min_width: geom.cols / 8,
            min_rows: geom.rows / 8,
            partition_mode: PartitionMode::Columns,
            preempt: PreemptMode::Off,
            feed_model: FeedModel::Independent,
            alloc_policy: AllocPolicy::WidestToHeaviest,
            patience_divisor: 4,
            dram: None,
            mem: None,
            tables: None,
            vector: None,
        }
    }
}

impl SchedulerConfig {
    /// The [`MemSpec`] this config asks the engine to simulate (the
    /// shared `mem_spec` implementation of every shipped policy).
    ///
    /// Panics if both `dram` and `mem` are set: the isolated bound is
    /// already folded into `exec` cycles, so layering the shared
    /// hierarchy on top would double-count transfer time.  Enforced here
    /// — the one place every policy passes through — so the invariant
    /// holds for all of them, not just the dynamic scheduler.
    pub fn mem_spec(&self) -> Option<MemSpec> {
        assert!(
            self.dram.is_none() || self.mem.is_none(),
            "[dram] (isolated bound) and [mem] (shared hierarchy) are mutually exclusive"
        );
        self.mem.map(|cfg| MemSpec { cfg, geom: self.geom, buffers: self.buffers })
    }
}

/// Largest power of two ≤ `x` (x ≥ 1).
fn floor_pow2(x: u64) -> u64 {
    debug_assert!(x >= 1);
    1 << (63 - x.leading_zeros() as u64)
}

/// Smallest power of two ≥ `x` (x ≥ 1).
fn ceil_pow2(x: u64) -> u64 {
    debug_assert!(x >= 1);
    x.next_power_of_two()
}

/// Whether the dynamic policy memoizes its priced plan per
/// `(partition plan-key, ready-set signature)` so back-to-back decision
/// points that change neither the free rectangles nor the ready set
/// replay the previous plan instead of re-running the candidate search.
/// Opt out with `MTSA_NO_PLAN_CACHE` (any value).  Both modes produce
/// byte-identical plans — the memo key covers every input the search
/// reads — so the switch exists for A/B timing and bisecting.
pub fn plan_cache_enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| std::env::var_os("MTSA_NO_PLAN_CACHE").is_none())
}

/// Whether the dynamic policy recycles its planning scratch (ready
/// buffer, rehearsal manager, candidate and output vectors) across
/// decision points, making the steady-state plan path allocation-free.
/// Opt out with `MTSA_NO_PLAN_ARENA` (any value) to allocate fresh
/// buffers per call, as the pre-arena planner did; the buffers carry no
/// state between calls, so both modes are byte-identical.
pub fn plan_arena_enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| std::env::var_os("MTSA_NO_PLAN_ARENA").is_none())
}

/// Single-slot plan memo: the last computed plan and the signature of
/// the state it was computed from.  One slot is exactly the hot case —
/// consecutive decision points with an unchanged world (wake-ups,
/// deadline replans, patience waits) — and needs no eviction policy.
#[derive(Debug, Clone, Default)]
struct PlanMemo {
    valid: bool,
    sig: Vec<u64>,
    plan: Vec<Allocation>,
    hits: u64,
}

/// Recycled scratch buffers for the zero-allocation plan path.  Contents
/// are cleared (or overwritten via `clone_from`) before every use, so the
/// arena carries capacity between decision points, never state.
#[derive(Debug, Clone, Default)]
struct PlanArena {
    ready: Vec<ReadyLayer>,
    cand: Vec<Tile>,
    out: Vec<Vec<Allocation>>,
    pm: Option<PartitionManager>,
    sig: Vec<u64>,
}

/// The dynamic partitioning policy (with `preempt = off`, stateless
/// between decision points: every plan is a pure function of the
/// observable [`SystemState`] — the one cache below memoizes a
/// run-constant.  Preemption adds two small pieces of deterministic
/// state: the trigger latch and the missed-deadline set).
#[derive(Debug, Clone)]
pub struct DynamicScheduler {
    cfg: SchedulerConfig,
    /// Memo for [`intrinsically_bound`], keyed by GEMM shape `(sr, k,
    /// m)` — the estimate is a pure function of the shape and the fixed
    /// config, and `plan` re-evaluates it for every ready layer at every
    /// decision point (mem-aware policy only; empty otherwise).
    bound_cache: BTreeMap<(u64, u64, u64), bool>,
    /// Preemption trigger latch: set by the event hooks (arrivals; in
    /// deadline mode also missed deadlines), consumed by the next
    /// [`Scheduler::preempt`] decision point.  Bounds preemptions to at
    /// most one attempt per triggering event — no thrash, no livelock.
    preempt_armed: bool,
    /// Tenants whose deadline has already passed unmet (deadline mode's
    /// first-choice eviction victims).
    missed: BTreeSet<DnnId>,
    /// Plan memoization on (process default [`plan_cache_enabled`];
    /// per-instance override [`DynamicScheduler::with_plan_cache`]).
    use_cache: bool,
    /// Arena recycling on (process default [`plan_arena_enabled`];
    /// per-instance override [`DynamicScheduler::with_plan_arena`]).
    use_arena: bool,
    memo: PlanMemo,
    arena: PlanArena,
}

/// True when the layer would be memory-bound on a `width` slice even
/// with the *whole* interface to itself — transfer need (proportional
/// share estimate) beats compute need.  The `mem-aware` policy's
/// admission-time signal.  Deliberately *intrinsic*: observed stall
/// fractions measure sharing (a compute-bound victim co-running with a
/// memory hog stalls too), so classifying from them would serialize the
/// victim behind its aggressor.
fn intrinsically_bound(cfg: &SchedulerConfig, mem: &MemConfig, gemm: GemmDims, width: u64) -> bool {
    let width = width.clamp(1, cfg.geom.cols);
    let t = tile_layer_timing(
        cfg.geom,
        gemm,
        Tile::full_height(cfg.geom, 0, width),
        FeedPolicy::Independent,
        &cfg.buffers,
    );
    mem.dram.transfer_cycles(&t.activity) > t.cycles
}

impl DynamicScheduler {
    pub fn new(cfg: SchedulerConfig) -> DynamicScheduler {
        assert!(cfg.min_width >= 1 && cfg.min_width <= cfg.geom.cols);
        assert!(cfg.min_rows >= 1 && cfg.min_rows <= cfg.geom.rows);
        DynamicScheduler {
            cfg,
            bound_cache: BTreeMap::new(),
            preempt_armed: false,
            missed: BTreeSet::new(),
            use_cache: plan_cache_enabled(),
            use_arena: plan_arena_enabled(),
            memo: PlanMemo::default(),
            arena: PlanArena::default(),
        }
    }

    pub fn config(&self) -> &SchedulerConfig {
        &self.cfg
    }

    /// Toggle the plan memo for THIS instance, overriding the
    /// process-wide [`plan_cache_enabled`] default (in-process A/B tests
    /// can't re-latch the env flag).  Resets the memo so a toggle never
    /// replays stale state.
    pub fn with_plan_cache(mut self, on: bool) -> DynamicScheduler {
        self.use_cache = on;
        self.memo = PlanMemo::default();
        self
    }

    /// Toggle arena recycling for THIS instance, overriding the
    /// process-wide [`plan_arena_enabled`] default.
    pub fn with_plan_arena(mut self, on: bool) -> DynamicScheduler {
        self.use_arena = on;
        self
    }

    /// How many [`Scheduler::plan`] calls were answered from the memo
    /// (always 0 with the cache off).  [`DynamicScheduler::run`] clones
    /// the scheduler, so drive an [`Engine`] directly to observe this.
    pub fn plan_cache_hits(&self) -> u64 {
        self.memo.hits
    }

    /// Run a pool to completion on the shared engine; returns the full
    /// metrics.  Equivalent to
    /// [`Engine::execute`]`(pool, cfg.geom, &mut self.clone())`.
    pub fn run(&self, pool: &WorkloadPool) -> RunMetrics {
        Engine::execute(pool, self.cfg.geom, &mut self.clone())
    }
}

impl Scheduler for DynamicScheduler {
    fn name(&self) -> &'static str {
        "dynamic"
    }

    fn mem_spec(&self) -> Option<MemSpec> {
        self.cfg.mem_spec()
    }

    fn vector_spec(&self) -> Option<VectorUnit> {
        self.cfg.vector
    }

    fn on_arrival(&mut self, _s: &SystemState<'_>, _dnn: DnnId) {
        if self.cfg.preempt != PreemptMode::Off {
            self.preempt_armed = true;
        }
    }

    fn on_deadline(&mut self, _s: &SystemState<'_>, dnn: DnnId, met: bool) {
        if self.cfg.preempt == PreemptMode::Deadline && !met {
            self.missed.insert(dnn);
            self.preempt_armed = true;
        }
    }

    /// Deadline mode reacts to verdicts (eviction of missed tenants), so
    /// its reaction must take effect at deadline time.
    fn plan_on_deadline(&self) -> bool {
        self.cfg.preempt == PreemptMode::Deadline
    }

    /// Slot recycling: the id's miss verdict belongs to the retired
    /// tenant, not whoever is admitted under the id next.
    fn on_dnn_retired(&mut self, dnn: DnnId) {
        self.missed.remove(&dnn);
    }

    fn preempts(&self) -> bool {
        self.cfg.preempt != PreemptMode::Off
    }

    /// The preemption decision point: fires at most once per triggering
    /// event (the latch), and only when some ready layer is *starved* —
    /// its tenant has nothing running and the free space cannot give it
    /// even its patience floor.  The victim is the widest running tile
    /// above the recomputed `Partition_Calculation` equal share (in
    /// deadline mode, a tenant that already missed its deadline is taken
    /// first regardless of size); one victim per decision point keeps
    /// the reshape conservative.
    fn preempt(&mut self, s: &SystemState<'_>, running: &[RunningLayer]) -> Vec<AllocId> {
        if self.cfg.preempt == PreemptMode::Off || !self.preempt_armed {
            return Vec::new();
        }
        let ready = s.queue.ready_at(s.now);
        if ready.is_empty() {
            // Nobody is waiting (yet): keep the latch armed — the event
            // that set it may precede its starved arrival (e.g. a missed
            // deadline before the burst lands).
            return Vec::new();
        }
        self.preempt_armed = false;
        let cols = self.cfg.geom.cols;
        let widest = s.partitions.widest_free().map(|f| f.width).unwrap_or(0);
        let starved: Vec<DnnId> = ready
            .iter()
            .filter(|r| {
                if running.iter().any(|rl| rl.dnn == r.dnn) {
                    return false; // its tenant is already progressing
                }
                let gemm = self.gemm_remaining(s, r.dnn, r.layer);
                let demand = ceil_pow2(gemm.m).clamp(self.cfg.min_width, cols);
                let acceptable = (demand / self.cfg.patience_divisor).max(self.cfg.min_width);
                let usable = if widest == 0 { 0 } else { demand.min(floor_pow2(widest)) };
                usable < acceptable
            })
            .map(|r| r.dnn)
            .collect();
        if starved.is_empty() {
            return Vec::new();
        }
        // A layer already reshaped once is not reshaped again (its width
        // already reflects a contention decision; transient starvation
        // while earlier winners drain must not keep halving it).  A
        // starved strict-priority flight (no live completion prediction,
        // `t_end == u64::MAX`) is no victim either: its fold clock has
        // no finite dilation to locate a boundary on.
        let eligible =
            |rl: &&RunningLayer| s.k_done(rl.dnn, rl.layer) == 0 && rl.t_end != u64::MAX;
        if self.cfg.preempt == PreemptMode::Deadline {
            if let Some(victim) = running
                .iter()
                .filter(eligible)
                .filter(|rl| self.missed.contains(&rl.dnn) && !starved.contains(&rl.dnn))
                .max_by_key(|rl| (rl.tile.pes(), rl.t_end, rl.alloc))
            {
                return vec![victim.alloc];
            }
        }
        let n_avail = ready.len() as u64 + running.len() as u64;
        let target = floor_pow2((cols / n_avail).max(1)).clamp(self.cfg.min_width, cols);
        // Judge "above the equal share" in PEs, not column span — in 2D
        // mode a short-but-wide tile can hold far less than a full-height
        // slice of the same width (for full-height tiles the two tests
        // are identical, so columns-mode behavior is unchanged).
        let share_pes = target * self.cfg.geom.rows;
        running
            .iter()
            .filter(eligible)
            .filter(|rl| rl.tile.pes() > share_pes && rl.t_end > s.now)
            .max_by_key(|rl| (rl.tile.pes(), rl.t_end.saturating_sub(s.now), rl.alloc))
            .map(|rl| vec![rl.alloc])
            .unwrap_or_default()
    }

    /// Fold-boundary location for the engine: find the boundary on the
    /// independent-feed fold clock, then stretch it onto the segment's
    /// wall clock when contention (interleaved feed, DRAM bound, or a
    /// bandwidth rescale) priced the segment slower than the fold model
    /// — folds are assumed to dilate uniformly (see `docs/preemption.md`).
    fn checkpoint(
        &self,
        s: &SystemState<'_>,
        dnn: DnnId,
        layer: LayerId,
        tile: Tile,
        elapsed: u64,
        total: u64,
    ) -> Option<Checkpoint> {
        if self.cfg.preempt == PreemptMode::Off {
            return None;
        }
        let geom = self.cfg.geom;
        let gemm = self.gemm_remaining(s, dnn, layer);
        let ind = tile_layer_timing(geom, gemm, tile, FeedPolicy::Independent, &self.cfg.buffers);
        let c_ind = ind.cycles.max(1);
        let total = total.max(c_ind);
        // Floor into the fold clock (never credit an unfinished fold),
        // ceil back out (never schedule the drain before it can finish).
        // A just-dispatched victim (elapsed 0) drains at its FIRST fold
        // boundary, never at cycle zero.
        let elapsed_ind = ((elapsed as u128 * c_ind as u128) / total as u128) as u64;
        let fb = next_fold_boundary(geom, gemm, tile, elapsed_ind.max(1))?;
        let to_wall = |x: u64| ((x as u128 * total as u128).div_ceil(c_ind as u128)) as u64;
        let boundary = to_wall(fb.cycles).max(elapsed);
        let k_advance = fb.bands_done * tile.rows;
        let activity = if k_advance > 0 {
            let done = GemmDims { sr: gemm.sr, k: k_advance, m: gemm.m };
            tile_layer_timing(geom, done, tile, FeedPolicy::Independent, &self.cfg.buffers)
                .activity
        } else {
            Activity::default()
        };
        // Drain-and-reshape: keep the left half of the tile's width (the
        // pow-2 ladder's next rung down) so the remainder keeps running
        // and the freed right half hosts the starved arrival.  Below
        // `min_width` there is no rung left — evict to the ready set.
        let half = floor_pow2(tile.cols) / 2;
        let keep = (half >= self.cfg.min_width)
            .then(|| Tile::new(tile.row0, tile.col0, tile.rows, half));
        Some(Checkpoint {
            boundary,
            k_advance,
            replayed_folds: fb.replayed_folds,
            wasted_cycles: to_wall(fb.cycles) - to_wall(fb.band_prefix_cycles),
            activity,
            keep,
        })
    }

    /// `Partition_Calculation` + `Task_Assignment` over the ready set,
    /// rehearsed on a clone of the live partition tiling.  `columns` mode
    /// is the paper's Algorithm 1 verbatim; `2d` mode additionally
    /// considers row splits per decision point.
    ///
    /// Hot-path structure: the ready set is computed once into a
    /// recycled buffer, and — when the plan cache is on and `[mem]` is
    /// off — the priced plan is memoized against the partition
    /// [`plan_key`](PartitionManager::plan_key) plus a signature of
    /// everything else the search reads (ready identities, `Opr` order,
    /// remaining GEMMs, tables-on).  Decision points that change neither
    /// the free rectangles nor the ready set replay the memo instead of
    /// re-enumerating free-rects × ladder × table shapes.  `[mem]` runs
    /// never memoize: the arbiter's live feedback steers the mem-aware
    /// throttle without bumping the partition epoch, so the signature
    /// could not see it change.
    fn plan(&mut self, s: &SystemState<'_>) -> Vec<Allocation> {
        let mut ready = std::mem::take(&mut self.arena.ready);
        s.queue.ready_into(s.now, &mut ready);
        if ready.is_empty() {
            self.arena.ready = ready;
            return Vec::new();
        }

        let cacheable = self.use_cache && s.mem.is_none();
        if cacheable {
            let mut sig = std::mem::take(&mut self.arena.sig);
            sig.clear();
            let (nonce, epoch) = s.partitions.plan_key();
            sig.push(nonce);
            sig.push(epoch);
            // The lane pool is a second allocation input: its plan key
            // joins the signature so a memoized plan never replays lane
            // spans the pool no longer has free.  Absent (no extra
            // words) on array-only machines — the signature stays
            // byte-identical to the pre-heterogeneous one.
            if let Some(lm) = s.lanes {
                let (ln, le) = lm.plan_key();
                sig.push(ln);
                sig.push(le);
            }
            sig.push(self.cfg.tables.is_some() as u64);
            for r in &ready {
                let g = s.remaining_gemm(r.dnn, r.layer);
                sig.extend_from_slice(&[r.dnn as u64, r.layer as u64, r.opr, g.sr, g.k, g.m]);
            }
            if self.memo.valid && self.memo.sig == sig {
                self.memo.hits += 1;
                let mut out = self.take_out();
                out.extend_from_slice(&self.memo.plan);
                self.arena.sig = sig;
                self.arena.ready = ready;
                return out;
            }
            self.arena.sig = sig;
        }
        // Heterogeneous stage: memory-bound ready layers are carved
        // lane spans first (and drop out of `ready`), so the array
        // planner below shares PEs among the compute-bound layers only.
        let mut vector_allocs = Vec::new();
        if let Some(lm) = s.lanes {
            if self.cfg.vector.is_some() {
                plan_vector(s, lm, &mut ready, &mut vector_allocs);
            }
        }
        let mut out = if ready.is_empty() {
            // Everything went to the lanes: skip the array planner (its
            // equal-share divisor would see zero available layers).
            self.take_out()
        } else {
            match self.cfg.partition_mode {
                PartitionMode::Columns => self.plan_columns(s, &ready),
                PartitionMode::TwoD => self.plan_2d(s, &ready),
            }
        };
        out.extend_from_slice(&vector_allocs);
        if cacheable {
            // Adopt the just-built signature (the memo's old buffer
            // becomes the next call's scratch) and copy the plan.
            std::mem::swap(&mut self.memo.sig, &mut self.arena.sig);
            self.memo.plan.clear();
            self.memo.plan.extend_from_slice(&out);
            self.memo.valid = true;
        }
        self.arena.ready = ready;
        out
    }

    /// Arena recycling: a consumed plan vector returns to the pool
    /// (bounded — the engine hands back one per decision point).
    fn recycle_plan(&mut self, mut plan: Vec<Allocation>) {
        if self.use_arena && self.arena.out.len() < 4 {
            plan.clear();
            self.arena.out.push(plan);
        }
    }

    /// Cycles for one layer on `tile` with `coresident` live partitions;
    /// activity is feed-policy-invariant and always billed under the
    /// independent model.
    fn exec(
        &self,
        s: &SystemState<'_>,
        dnn: DnnId,
        layer: LayerId,
        tile: Tile,
        coresident: u64,
    ) -> LayerExec {
        let cfg = &self.cfg;
        let gemm = self.gemm_remaining(s, dnn, layer);
        let ind = tile_layer_timing(cfg.geom, gemm, tile, FeedPolicy::Independent, &cfg.buffers);
        let raw = match cfg.feed_model {
            FeedModel::Independent => ind.cycles,
            FeedModel::Interleaved => {
                // Row feed wires are shared only by tiles whose row bands
                // intersect: in columns mode that is every live partition
                // (the engine's `coresident`), in 2D mode count them —
                // vertically stacked tenants use disjoint wires.
                let p = match cfg.partition_mode {
                    PartitionMode::Columns => coresident.max(1),
                    PartitionMode::TwoD => (s
                        .partitions
                        .allocated_tiles_iter()
                        .filter(|t| t.overlaps_rows(&tile))
                        .count() as u64)
                        .max(1),
                };
                tile_layer_timing(
                    cfg.geom,
                    gemm,
                    tile,
                    FeedPolicy::Interleaved { coresident: p, slot: p.saturating_sub(1) },
                    &cfg.buffers,
                )
                .cycles
            }
        };
        let cycles = match &cfg.dram {
            Some(d) => d.bound_cycles(raw, &ind.activity),
            None => raw,
        };
        LayerExec { cycles, activity: ind.activity }
    }

    /// Cycles for one layer streamed through a lane span of the vector
    /// engine — the closed form of
    /// [`layer_timing_vector`](crate::sim::dataflow::layer_timing_vector).
    /// Under `[mem]` only the compute path is priced here; the engine
    /// admits the layer's ideal word stream to the bandwidth arbiter, so
    /// pricing the stream again would double-count transfer time.
    fn exec_vector(
        &self,
        s: &SystemState<'_>,
        dnn: DnnId,
        layer: LayerId,
        span: LaneSpan,
    ) -> LayerExec {
        let vu = self.cfg.vector.expect("exec_vector without a configured vector engine");
        let gemm = self.gemm_remaining(s, dnn, layer);
        let t = layer_timing_vector(&vu, span.lanes, gemm);
        if self.cfg.mem.is_some() {
            let cycles = vector_compute_cycles(&vu, span.lanes, gemm);
            return LayerExec { cycles, activity: t.activity };
        }
        let cycles = match &self.cfg.dram {
            Some(d) => d.bound_cycles(t.cycles, &t.activity),
            None => t.cycles,
        };
        LayerExec { cycles, activity: t.activity }
    }
}

/// The heterogeneous placement stage: carve lane spans for the
/// memory-bound ready layers (arithmetic intensity below the
/// [`crate::workloads::shapes::INTENSITY_THRESHOLD`]), rehearsed on a
/// clone of the live lane pool exactly like the array planner rehearses
/// its tiling.  Placed layers are removed from `ready`; a layer the pool
/// cannot host right now *stays* and competes for the array, so the
/// progress guarantee is unchanged.  Sizing follows
/// `Partition_Calculation`'s shape on the 1D pool: a lone memory-bound
/// layer on an idle pool takes every lane, otherwise each takes the
/// pow-2 equal share capped by the widest free span.
fn plan_vector(
    s: &SystemState<'_>,
    live: &LaneManager,
    ready: &mut Vec<ReadyLayer>,
    out: &mut Vec<Allocation>,
) {
    let memory_bound = |r: &ReadyLayer| {
        s.pool.dnns[r.dnn].layers[r.layer].op_class() == OpClass::MemoryBound
    };
    let mb = ready.iter().filter(|r| memory_bound(r)).count() as u64;
    if mb == 0 {
        return;
    }
    let mut lm = live.clone();
    let total = lm.lanes();
    let n_avail = mb + lm.allocated_count() as u64;
    let target = floor_pow2((total / n_avail).max(1));
    ready.retain(|r| {
        if !memory_bound(r) {
            return true;
        }
        // A lone memory-bound layer on an idle pool takes all lanes.
        let width = if lm.fully_free() && n_avail == 1 {
            total
        } else {
            let widest = lm.widest_free();
            if widest == 0 {
                return true; // pool exhausted: compete for the array
            }
            target.min(floor_pow2(widest))
        };
        match lm.allocate(width) {
            Some((_, span)) => {
                out.push(Allocation::vector(r.dnn, r.layer, span));
                false
            }
            None => true,
        }
    });
}

impl DynamicScheduler {
    /// The GEMM still to execute for `(dnn, layer)` — delegates to
    /// [`SystemState::remaining_gemm`], the one remainder-sizing formula
    /// the engine also prices DRAM traffic with.  Identical to the full
    /// shape — and bit-identical pricing — whenever preemption never
    /// fired.
    fn gemm_remaining(&self, s: &SystemState<'_>, dnn: DnnId, layer: LayerId) -> GemmDims {
        s.remaining_gemm(dnn, layer)
    }

    /// Memoized mem-aware admission signal for one layer shape (false
    /// whenever the policy is not `mem-aware` or `[mem]` is off).
    fn layer_bound(&mut self, gemm: GemmDims, width: u64) -> bool {
        let cfg = &self.cfg;
        if cfg.alloc_policy != AllocPolicy::MemAware {
            return false;
        }
        match &cfg.mem {
            Some(mem) => *self
                .bound_cache
                .entry((gemm.sr, gemm.k, gemm.m))
                .or_insert_with(|| intrinsically_bound(cfg, mem, gemm, width)),
            None => false,
        }
    }

    /// The paper's Algorithm 1 over full-height column slices — kept
    /// verbatim from the pre-2D scheduler (the `columns`-mode parity rail
    /// pinned by `rust/tests/engine_parity.rs`).  The caller
    /// ([`Scheduler::plan`]) computes `ready` (non-empty) once per
    /// decision point; the rehearsal manager and the output vector come
    /// from the recycled per-scheduler arena.
    fn plan_columns(&mut self, s: &SystemState<'_>, ready: &[ReadyLayer]) -> Vec<Allocation> {
        let mut pm = self.take_pm(s.partitions);
        let mut out = self.take_out();

        // Partition_Calculation (Lines 15-19): divide the array by the
        // number of available layers (running partitions keep their
        // slices), on the power-of-two ladder.
        let cols = self.cfg.geom.cols;
        let min_width = self.cfg.min_width;
        let alloc_policy = self.cfg.alloc_policy;
        let patience = self.cfg.patience_divisor;
        let n_avail = ready.len() as u64 + pm.allocated_count() as u64;
        let target = floor_pow2((cols / n_avail).max(1)).clamp(min_width, cols);

        let mut dispatched_any = false;
        // mem-aware throttle state: a memory-bound layer dispatched this
        // round counts like one already in flight.
        let mut bound_in_plan = false;
        for r in ready {
            // Width demand: a layer gains nothing beyond its GEMM column
            // count M (Task_Assignment's "layers with higher dimensions
            // to partitions with higher resources").  A preempted
            // remainder is priced on what it has left.
            let gemm = self.gemm_remaining(s, r.dnn, r.layer);
            let demand = ceil_pow2(gemm.m).clamp(min_width, cols);

            // MoCA-style throttle (mem-aware policy): a layer headed for
            // the DRAM wall is deferred while another memory-bound layer
            // is in flight — two saturated transfers processor-sharing
            // the interface both finish later than either alone, so
            // time-multiplexing them wins p95 latency AND residency
            // energy.  Never defers when nothing is running (progress).
            let bound = self.layer_bound(gemm, demand);
            if bound
                && (pm.allocated_count() > 0 || dispatched_any)
                && (bound_in_plan
                    || s.mem.is_some_and(|fb| fb.bound_inflight_excluding(r.dnn) > 0))
            {
                continue; // throttled: wait for the bound co-runner to drain
            }

            // First layer on a fully idle array: all PEs (Line 6).
            if pm.fully_free() && n_avail == 1 {
                let (_, tile) = pm.allocate(cols).expect("full array free");
                out.push(Allocation::array(r.dnn, r.layer, tile));
                dispatched_any = true;
                bound_in_plan |= bound;
                continue;
            }

            let widest = pm.widest_free().map(|s| s.width).unwrap_or(0);
            if widest < min_width {
                continue; // nothing usable free right now
            }
            let width = match alloc_policy {
                // Paper-literal Partition_Calculation: take the equal
                // share (capped by demand), no waiting.
                AllocPolicy::EqualShare => demand.min(target).min(floor_pow2(widest)),
                // Demand-aware: the heaviest ready layer takes the widest
                // free slice up to its demand.  Patience: a layer whose
                // demand cannot be reasonably met WAITS for merges
                // instead of exploding its fold count in a sliver —
                // unless nothing is running (progress guarantee: take the
                // best slice available).  The mem-aware policy carves
                // identically; its throttle already ran above.
                AllocPolicy::WidestToHeaviest | AllocPolicy::MemAware => {
                    let width = demand.min(floor_pow2(widest));
                    let acceptable = (demand / patience).max(min_width);
                    if width >= acceptable {
                        width
                    } else if pm.allocated_count() == 0 && !dispatched_any {
                        floor_pow2(widest)
                    } else {
                        continue; // wait for a completion to merge space
                    }
                }
            };
            let Some((_, tile)) = pm.allocate(width) else { continue };
            out.push(Allocation::array(r.dnn, r.layer, tile));
            dispatched_any = true;
            bound_in_plan |= bound;
        }
        self.give_pm(pm);
        out
    }

    /// 2D fission planning: for each ready layer (Opr order), evaluate
    /// candidate tiles — every free rectangle × the power-of-two height
    /// ladder at the layer's width demand — and take the one minimizing
    /// the projected fold-adjusted completion from the tile timing model.
    /// Ties prefer the smallest PE footprint, then the topmost/leftmost
    /// placement, so a shallow-K layer takes a short tile and leaves the
    /// rows below for a co-tenant (the packing win columns cannot get).
    /// Patience generalizes from widths to cycles: a candidate slower
    /// than `patience_divisor ×` the layer's unconstrained demand-shaped
    /// tile waits for merges instead (with the same progress guarantee).
    ///
    /// The allocation policies keep their columns-mode meaning: `equal`
    /// additionally caps the width demand at the `Partition_Calculation`
    /// equal share (`cols / n_available`, pow-2 ladder) and never waits
    /// on patience; `widest`/`mem-aware` carve demand-first.
    fn plan_2d(&mut self, s: &SystemState<'_>, ready: &[ReadyLayer]) -> Vec<Allocation> {
        let mut pm = self.take_pm(s.partitions);
        let mut out = self.take_out();
        let mut cand = std::mem::take(&mut self.arena.cand);
        let geom = self.cfg.geom;
        let buffers = self.cfg.buffers;
        let (min_width, min_rows) = (self.cfg.min_width, self.cfg.min_rows);
        let patience = self.cfg.patience_divisor;
        let alloc_policy = self.cfg.alloc_policy;
        let tables = self.cfg.tables.clone();
        let n_avail = ready.len() as u64 + pm.allocated_count() as u64;
        let target = floor_pow2((geom.cols / n_avail).max(1)).clamp(min_width, geom.cols);

        let mut dispatched_any = false;
        let mut bound_in_plan = false;
        for r in ready {
            let gemm = self.gemm_remaining(s, r.dnn, r.layer);
            // Demand: a layer gains nothing beyond M columns or K rows
            // (FK = ⌈K/h⌉ is already 1 at h = K), on the pow-2 ladder.
            let mut demand_w = ceil_pow2(gemm.m).clamp(min_width, geom.cols);
            if alloc_policy == AllocPolicy::EqualShare {
                demand_w = demand_w.min(target);
            }
            let demand_h = ceil_pow2(gemm.k).clamp(min_rows, geom.rows);

            // Same MoCA-style throttle as columns mode.
            let bound = self.layer_bound(gemm, demand_w);
            if bound
                && (pm.allocated_count() > 0 || dispatched_any)
                && (bound_in_plan
                    || s.mem.is_some_and(|fb| fb.bound_inflight_excluding(r.dnn) > 0))
            {
                continue;
            }

            // First layer on a fully idle array: all PEs (Line 6).
            if pm.fully_free() && n_avail == 1 {
                let (_, tile) = pm.allocate(geom.cols).expect("full array free");
                out.push(Allocation::array(r.dnn, r.layer, tile));
                dispatched_any = true;
                bound_in_plan |= bound;
                continue;
            }

            let mut best: Option<((u64, u64, u64, u64), Tile)> = None;
            for rect in pm.free_tiles_iter() {
                cand.clear();
                push_rect_candidates(
                    rect,
                    demand_w,
                    demand_h,
                    min_width,
                    min_rows,
                    tables.as_deref(),
                    geom,
                    gemm,
                    &mut cand,
                );
                for &tile in &cand {
                    let cycles =
                        tile_layer_timing(geom, gemm, tile, FeedPolicy::Independent, &buffers)
                            .cycles;
                    let key = (cycles, tile.pes(), tile.row0, tile.col0);
                    if best.map(|(bk, _)| key < bk).unwrap_or(true) {
                        best = Some((key, tile));
                    }
                }
            }
            let Some(((cycles, ..), want)) = best else { continue };

            // Patience in cycle space: the reference is the demand-shaped
            // tile at the array origin (no skew, no folding beyond the
            // layer's own shape).
            let ideal = Tile::new(0, 0, demand_h, demand_w);
            let ideal_cycles =
                tile_layer_timing(geom, gemm, ideal, FeedPolicy::Independent, &buffers).cycles;
            // Paper-literal equal share takes its slice without waiting,
            // exactly like the columns-mode EqualShare arm.
            if alloc_policy != AllocPolicy::EqualShare
                && cycles > patience.saturating_mul(ideal_cycles)
                && !(pm.allocated_count() == 0 && !dispatched_any)
            {
                continue; // wait for a completion to merge space
            }
            let Some((_, tile)) = pm.allocate_at(want) else { continue };
            out.push(Allocation::array(r.dnn, r.layer, tile));
            dispatched_any = true;
            bound_in_plan |= bound;
        }
        self.arena.cand = cand;
        self.give_pm(pm);
        out
    }

    /// A rehearsal manager primed from the live tiling: recycled from the
    /// arena (capacity reuse via `clone_from`) when arenas are on.
    fn take_pm(&mut self, live: &PartitionManager) -> PartitionManager {
        match self.arena.pm.take() {
            Some(mut pm) if self.use_arena => {
                pm.clone_from(live);
                pm
            }
            _ => live.clone(),
        }
    }

    fn give_pm(&mut self, pm: PartitionManager) {
        if self.use_arena {
            self.arena.pm = Some(pm);
        }
    }

    /// An empty allocation vector, recycled from the arena when one is
    /// pooled ([`Scheduler::recycle_plan`] returns them).
    fn take_out(&mut self) -> Vec<Allocation> {
        let mut v = self.arena.out.pop().unwrap_or_default();
        v.clear();
        v
    }
}

/// Enumerate one free rectangle's candidate tiles for a layer: the pow-2
/// height ladder at the layer's width demand, unioned with the layer's
/// profiled exact-fit shapes (when tables are on).  The union is deduped
/// on `(row0, col0, rows, cols)` — a profiled shape that coincides with a
/// ladder rung used to be enumerated and priced twice; since the planner
/// takes a strict minimum over `(cycles, pes, row0, col0)`, pricing a
/// duplicate can never change the chosen tile, only waste a timing call.
/// Ladder candidates precede table candidates, preserving the original
/// evaluation (and therefore tie-breaking) order exactly.
#[allow(clippy::too_many_arguments)]
fn push_rect_candidates(
    rect: Tile,
    demand_w: u64,
    demand_h: u64,
    min_width: u64,
    min_rows: u64,
    tables: Option<&ProfileStore>,
    geom: ArrayGeometry,
    gemm: GemmDims,
    out: &mut Vec<Tile>,
) {
    let w = demand_w.min(floor_pow2(rect.cols));
    if w < min_width {
        return;
    }
    let h0 = demand_h.min(floor_pow2(rect.rows));
    let mut h = h0;
    while h >= min_rows {
        out.push(Tile::new(rect.row0, rect.col0, h, w));
        if h == 1 {
            break;
        }
        h /= 2;
    }
    // Offline profile tables: union the layer's profiled exact-fit
    // shapes with the pow-2 ladder above.  Same pricing call, same best
    // key, so the plan can only improve; anything the table lacks
    // (preempted remnants hash to a different K) falls back to the
    // ladder.
    let Some(store) = tables else { return };
    for c in store.candidates(geom, gemm.k, gemm.m) {
        if c.rows < min_rows
            || c.cols < min_width
            || c.rows > rect.rows
            || c.cols > rect.cols
            || c.cols > demand_w
        {
            continue;
        }
        // Ladder-duplicate check: the rungs are exactly {h0 / 2^i ≥
        // min_rows} at width `w`, so membership is divisibility by a
        // power of two (exact even for non-pow-2 h0).
        if c.cols == w && c.rows <= h0 && h0 % c.rows == 0 && (h0 / c.rows).is_power_of_two() {
            continue;
        }
        out.push(Tile::new(rect.row0, rect.col0, c.rows, c.cols));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::baseline::SequentialBaseline;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use crate::workloads::dnng::{Dnn, Layer};
    use crate::workloads::generator::{random_pool, GeneratorCfg};
    use crate::workloads::shapes::{LayerKind, LayerShape};

    fn fc_dnn(name: &str, ms: &[u64], at: u64) -> Dnn {
        let layers = ms
            .iter()
            .enumerate()
            .map(|(i, &m)| {
                Layer::new(&format!("l{i}"), LayerKind::Fc, LayerShape::fc(64, 128, m))
            })
            .collect();
        Dnn::chain(name, layers).arriving_at(at)
    }

    #[test]
    fn floor_pow2_ladder() {
        assert_eq!(floor_pow2(128), 128);
        assert_eq!(floor_pow2(64), 64);
        assert_eq!(floor_pow2(42), 32);
        assert_eq!(floor_pow2(17), 16);
        assert_eq!(floor_pow2(1), 1);
    }

    #[test]
    fn tags_round_trip_through_fromstr() {
        for m in FeedModel::ALL {
            assert_eq!(m.tag().parse::<FeedModel>().unwrap(), m);
        }
        for p in AllocPolicy::ALL {
            assert_eq!(p.tag().parse::<AllocPolicy>().unwrap(), p);
        }
        for m in PartitionMode::ALL {
            assert_eq!(m.tag().parse::<PartitionMode>().unwrap(), m);
        }
        for p in PreemptMode::ALL {
            assert_eq!(p.tag().parse::<PreemptMode>().unwrap(), p);
        }
        assert_eq!(PreemptMode::default(), PreemptMode::Off);
        assert_eq!(SchedulerConfig::default().preempt, PreemptMode::Off);
        let e = "sometimes".parse::<PreemptMode>().unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("off") && msg.contains("arrival") && msg.contains("deadline"), "{msg}");
        // TAGS is exactly the tag() image, in order.
        assert_eq!(FeedModel::TAGS, [FeedModel::Independent.tag(), FeedModel::Interleaved.tag()]);
        assert_eq!(
            AllocPolicy::TAGS,
            [
                AllocPolicy::WidestToHeaviest.tag(),
                AllocPolicy::EqualShare.tag(),
                AllocPolicy::MemAware.tag()
            ]
        );
        assert_eq!(
            PartitionMode::TAGS,
            [PartitionMode::Columns.tag(), PartitionMode::TwoD.tag()]
        );
        // The default is the paper's columns mode.
        assert_eq!(PartitionMode::default(), PartitionMode::Columns);
        assert_eq!(SchedulerConfig::default().partition_mode, PartitionMode::Columns);
        let e = "diagonal".parse::<PartitionMode>().unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("columns") && msg.contains("2d"), "{msg}");
    }

    #[test]
    fn parse_errors_list_valid_tags() {
        let e = "psychic".parse::<FeedModel>().unwrap_err();
        assert_eq!(e.got, "psychic");
        let msg = e.to_string();
        assert!(msg.contains("independent") && msg.contains("interleaved"), "{msg}");
        let e = "greedy".parse::<AllocPolicy>().unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("widest") && msg.contains("equal") && msg.contains("mem-aware"), "{msg}");
    }

    #[test]
    fn single_dnn_first_layer_gets_full_array() {
        let pool = WorkloadPool::new("t", vec![fc_dnn("a", &[256, 128], 0)]);
        let m = DynamicScheduler::new(SchedulerConfig::default()).run(&pool);
        assert_eq!(m.dispatches[0].tile.cols, 128, "first layer uses all PEs");
        assert_eq!(m.partition_trace("a").len(), 2);
    }

    #[test]
    fn two_dnns_split_under_contention() {
        // Narrow-demand layers (m = 64): two can share the array.
        let pool = WorkloadPool::new(
            "t",
            vec![fc_dnn("a", &[64, 64, 64], 0), fc_dnn("b", &[64, 64], 0)],
        );
        let m = DynamicScheduler::new(SchedulerConfig::default()).run(&pool);
        // Two DNNs arrive together: Algorithm 1 splits immediately (the
        // full-array rule only applies to a lone available layer).
        let widths_a = m.partition_widths("a");
        let widths_b = m.partition_widths("b");
        assert!(
            widths_a.iter().chain(&widths_b).any(|&w| w < 128),
            "contention must produce sub-partitions: {widths_a:?} {widths_b:?}"
        );
        // Both DNNs make progress concurrently: b's first layer starts
        // before a's last layer ends.
        let a_last_end = m.dispatches.iter().filter(|d| d.dnn_name == "a").map(|d| d.t_end).max().unwrap();
        let b_first_start = m.dispatches.iter().filter(|d| d.dnn_name == "b").map(|d| d.t_start).min().unwrap();
        assert!(b_first_start < a_last_end);
    }

    #[test]
    fn all_layers_execute_exactly_once() {
        let pool = WorkloadPool::new(
            "t",
            vec![fc_dnn("a", &[100, 200, 300], 0), fc_dnn("b", &[400], 5000), fc_dnn("c", &[50, 60], 0)],
        );
        let m = DynamicScheduler::new(SchedulerConfig::default()).run(&pool);
        assert_eq!(m.dispatches.len(), 6);
        for d in &pool.dnns {
            let trace = m.partition_trace(&d.name);
            assert_eq!(trace.len(), d.layers.len(), "{}", d.name);
        }
    }

    #[test]
    fn chain_order_preserved() {
        let pool = WorkloadPool::new("t", vec![fc_dnn("a", &[64, 64, 64, 64], 0)]);
        let m = DynamicScheduler::new(SchedulerConfig::default()).run(&pool);
        let recs: Vec<_> = m.dispatches.iter().filter(|d| d.dnn_name == "a").collect();
        for w in recs.windows(2) {
            assert!(w[0].layer < w[1].layer);
            assert!(w[0].t_end <= w[1].t_start, "layer i+1 cannot start before i ends");
        }
    }

    #[test]
    fn arrival_times_respected() {
        let pool = WorkloadPool::new("t", vec![fc_dnn("late", &[64], 1_000_000)]);
        let m = DynamicScheduler::new(SchedulerConfig::default()).run(&pool);
        assert!(m.dispatches[0].t_start >= 1_000_000);
    }

    #[test]
    fn min_width_respected() {
        let mut dnns = Vec::new();
        for i in 0..20 {
            dnns.push(fc_dnn(&format!("d{i}"), &[64, 64], 0));
        }
        let pool = WorkloadPool::new("t", dnns);
        let cfg = SchedulerConfig { min_width: 16, ..Default::default() };
        let m = DynamicScheduler::new(cfg).run(&pool);
        assert!(m.dispatches.iter().all(|d| d.tile.cols >= 16));
    }

    #[test]
    fn partitioned_bounded_vs_sequential_on_random_pools() {
        // Makespan under dynamic partitioning is not a theorem — a pool of
        // wide-M layers gains nothing from splitting (WS throughput is
        // proportional to columns when M > width) — but the demand-aware
        // policy must keep the downside tightly bounded while winning on
        // average-completion latency is checked on the zoo pools in
        // rust/tests/paper_experiments.rs.
        prop::check("dynamic makespan <= 1.25x sequential", 15, |rng| {
            let cfg = GeneratorCfg {
                num_dnns: rng.gen_range_inclusive(2, 6) as usize,
                layers_min: 2,
                layers_max: 8,
                mean_interarrival: 0.0,
                dim_scale: 0.5 + rng.gen_f64(),
            };
            let pool = random_pool(rng, &cfg);
            let dyn_m = DynamicScheduler::new(SchedulerConfig::default()).run(&pool);
            let seq_m = SequentialBaseline::new(SchedulerConfig::default()).run(&pool);
            prop::ensure(
                dyn_m.makespan as f64 <= 1.25 * seq_m.makespan as f64,
                &format!("dynamic {} > 1.25x sequential {}", dyn_m.makespan, seq_m.makespan),
            )
        });
    }

    #[test]
    fn interleaved_model_never_faster() {
        let mut rng = Rng::new(31);
        let pool = random_pool(
            &mut rng,
            &GeneratorCfg { num_dnns: 4, layers_min: 2, layers_max: 6, ..Default::default() },
        );
        let ind = DynamicScheduler::new(SchedulerConfig::default()).run(&pool);
        let il = DynamicScheduler::new(SchedulerConfig {
            feed_model: FeedModel::Interleaved,
            ..Default::default()
        })
        .run(&pool);
        assert!(il.makespan >= ind.makespan);
    }

    #[test]
    fn deterministic_runs() {
        let mut rng = Rng::new(77);
        let pool = random_pool(&mut rng, &GeneratorCfg::default());
        let a = DynamicScheduler::new(SchedulerConfig::default()).run(&pool);
        let b = DynamicScheduler::new(SchedulerConfig::default()).run(&pool);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.dispatches.len(), b.dispatches.len());
        for (x, y) in a.dispatches.iter().zip(&b.dispatches) {
            assert_eq!(x, y);
        }
    }

    /// The canonical head-of-line mix: one heavy tenant holding the full
    /// array for a long multi-band layer, one light tenant arriving
    /// mid-layer.  Heavy layer: [4000, 1024] × [1024, 64] — 8 K-bands of
    /// 4319 cycles on the default 128×128 array (34552 cycles/layer).
    fn hol_pool(light_arrival: u64) -> WorkloadPool {
        let mk = |name: &str, sr: u64, k: u64, m: u64, n: usize, at: u64| {
            let layers = (0..n)
                .map(|i| Layer::new(&format!("l{i}"), LayerKind::Fc, LayerShape::fc(sr, k, m)))
                .collect();
            Dnn::chain(name, layers).arriving_at(at)
        };
        WorkloadPool::new(
            "hol",
            vec![mk("heavy", 4000, 1024, 64, 2, 0), mk("light", 256, 128, 32, 1, light_arrival)],
        )
    }

    #[test]
    fn preempt_off_is_bitwise_default() {
        let pool = hol_pool(3_000);
        let def = DynamicScheduler::new(SchedulerConfig::default()).run(&pool);
        let off = DynamicScheduler::new(SchedulerConfig {
            preempt: PreemptMode::Off,
            ..Default::default()
        })
        .run(&pool);
        assert_eq!(def.makespan, off.makespan);
        assert_eq!(def.dispatches, off.dispatches);
        assert_eq!(def.preemptions, 0);
        assert_eq!(def.wasted_refill_cycles, 0);
        // And the head-of-line block is real: the light tenant waits for
        // the heavy layer to drain whole.
        assert_eq!(def.start["light"], 34_552);
    }

    #[test]
    fn arrival_preemption_drains_at_the_fold_boundary() {
        // Mirror-validated pinned numbers (see docs/preemption.md): the
        // light arrival at 3000 preempts the heavy layer at its next
        // band boundary (4319); the remainder resumes on 64 columns and
        // — because m = 64 wastes nothing beyond that width — finishes
        // at exactly the uninterrupted 34552.  The light tenant starts
        // 30k cycles earlier; the heavy tenant loses nothing.
        let pool = hol_pool(3_000);
        let off = DynamicScheduler::new(SchedulerConfig::default()).run(&pool);
        let pre = DynamicScheduler::new(SchedulerConfig {
            preempt: PreemptMode::Arrival,
            ..Default::default()
        })
        .run(&pool);
        assert_eq!(pre.preemptions, 1);
        assert_eq!(pre.replayed_folds, 0, "fm = 1: band boundaries waste nothing");
        assert_eq!(pre.wasted_refill_cycles, 0);
        assert_eq!(pre.start["light"], 4_319, "light dispatches at the fold boundary");
        assert_eq!(pre.completion["light"], 4_319 + 607);
        assert_eq!(pre.completion["heavy"], off.completion["heavy"], "heavy loses nothing");
        assert_eq!(pre.dispatches.len(), pool.total_layers() + 1, "one extra segment record");
        // The preempted segment is visible in the partition trace:
        // 128-wide segment, then the 64-wide remainder.
        assert_eq!(pre.partition_trace("heavy")[..2], [128, 64]);
        // Work conservation: the heavy layer's MACs split exactly across
        // its two segments (1 band of 128 K-rows, then 896 remaining).
        let macs: u64 = pre
            .dispatches
            .iter()
            .filter(|d| d.dnn_name == "heavy" && d.layer == 0)
            .map(|d| d.activity.macs)
            .sum();
        assert_eq!(macs, 4000 * 1024 * 64);
        // Determinism: the preempting run reproduces itself.
        let again = DynamicScheduler::new(SchedulerConfig {
            preempt: PreemptMode::Arrival,
            ..Default::default()
        })
        .run(&pool);
        assert_eq!(pre.dispatches, again.dispatches);
    }

    #[test]
    fn preemption_requires_starvation() {
        // Free space for the arrival => no preemption: a and c hold
        // [0,32) and [32,64), the light dispatches straight into the
        // free right half and the armed trigger finds nobody starved.
        let mk = |name: &str, sr: u64, k: u64, m: u64, at: u64| {
            let layers = vec![Layer::new("l0", LayerKind::Fc, LayerShape::fc(sr, k, m))];
            Dnn::chain(name, layers).arriving_at(at)
        };
        let roomy = WorkloadPool::new(
            "roomy",
            vec![
                mk("a", 4000, 1024, 32, 0),
                mk("c", 4000, 1024, 32, 0),
                mk("light", 256, 128, 32, 3_000),
            ],
        );
        let pre = DynamicScheduler::new(SchedulerConfig {
            preempt: PreemptMode::Arrival,
            ..Default::default()
        })
        .run(&roomy);
        assert_eq!(pre.preemptions, 0, "nobody starved => nothing preempted");
        assert_eq!(pre.start["light"], 3_000, "the arrival dispatched immediately");

        // No free space => the starved arrival preempts the equal-width
        // tenant with the most remaining work (b, rightmost, ends later).
        let packed = WorkloadPool::new(
            "packed",
            vec![
                mk("a", 4000, 1024, 64, 0),
                mk("b", 4000, 1024, 64, 0),
                mk("light", 256, 128, 32, 3_000),
            ],
        );
        let pre = DynamicScheduler::new(SchedulerConfig {
            preempt: PreemptMode::Arrival,
            ..Default::default()
        })
        .run(&packed);
        assert_eq!(pre.preemptions, 1);
        // b runs on [64, 128): its band boundary is 128 + (4000 + 128 +
        // 64 + 64 - 1) = 4383; the segment record ends there.
        let seg = pre.dispatches.iter().find(|d| d.t_end == 4_383).unwrap();
        assert_eq!(seg.dnn_name, "b", "victim is the longest-remaining equal-width tile");
        assert_eq!(seg.tile.cols, 64, "segment billed on the pre-shrink tile");
        assert_eq!(pre.start["light"], 4_383, "light dispatches into the shrink's freed half");

        // Cascading reshape: a alone takes the whole array (Line 6), b's
        // arrival halves it at the first band boundary, and the light's
        // arrival halves b in turn — every arrival reclaims PEs without
        // ever waiting out a 34k-cycle layer.
        let cascade = WorkloadPool::new(
            "cascade",
            vec![
                mk("a", 4000, 1024, 64, 0),
                mk("b", 4000, 1024, 64, 10),
                mk("light", 256, 128, 32, 3_000),
            ],
        );
        let pre = DynamicScheduler::new(SchedulerConfig {
            preempt: PreemptMode::Arrival,
            ..Default::default()
        })
        .run(&cascade);
        assert_eq!(pre.preemptions, 2);
        assert!(pre.start["light"] < 10_000, "light must not wait out a whole heavy layer");
    }

    #[test]
    fn preemption_works_under_the_shared_memory_hierarchy() {
        // The drained segment's flight early-retires (banks + bandwidth
        // share released) and the remainder re-admits under the same
        // alloc id; MAC conservation and the record accounting must hold
        // exactly as in the isolated-DRAM case.
        let pool = hol_pool(3_000);
        let cfg = SchedulerConfig {
            preempt: PreemptMode::Arrival,
            mem: Some(crate::mem::MemConfig::default()),
            ..Default::default()
        };
        let m = DynamicScheduler::new(cfg).run(&pool);
        assert!(m.preemptions >= 1);
        assert_eq!(m.dispatches.len(), pool.total_layers() + m.preemptions as usize);
        // Every record (segments included) closed a mem flight.
        assert_eq!(m.mem_total.layers as usize, m.dispatches.len());
        let macs: u64 = m
            .dispatches
            .iter()
            .filter(|d| d.dnn_name == "heavy" && d.layer == 0)
            .map(|d| d.activity.macs)
            .sum();
        assert_eq!(macs, 4000 * 1024 * 64, "MAC conservation under [mem]");
        // Still a strict latency win for the light tenant.
        assert!(m.start["light"] < 10_000);
    }

    #[test]
    fn deadline_mode_evicts_missed_tenants_first() {
        use crate::sim_core::Engine;
        let mk = |name: &str, sr: u64, k: u64, m: u64, at: u64| {
            let layers = vec![Layer::new("l0", LayerKind::Fc, LayerShape::fc(sr, k, m))];
            Dnn::chain(name, layers).arriving_at(at)
        };
        let pool = WorkloadPool::new(
            "dl",
            vec![
                mk("h0", 4000, 1024, 64, 0),
                mk("h1", 4000, 1024, 64, 0),
                mk("light", 256, 128, 32, 3_000),
            ],
        );
        let run = |preempt: PreemptMode, deadlines: Vec<(usize, u64)>| {
            let mut sched = DynamicScheduler::new(SchedulerConfig {
                preempt,
                ..Default::default()
            });
            let mut m = RunMetrics::default();
            Engine::new(&pool, SchedulerConfig::default().geom)
                .with_deadlines(deadlines)
                .run(&mut sched, &mut m);
            m
        };
        // h0 misses its (absurd) deadline at cycle 100; when the light
        // tenant arrives starved, deadline mode evicts the missed h0 —
        // arrival mode would have picked h1 (equal width, later t_end).
        let dl = run(PreemptMode::Deadline, vec![(0, 100)]);
        assert_eq!(dl.preemptions, 1);
        let seg = dl.dispatches.iter().min_by_key(|d| d.t_end).unwrap();
        assert_eq!(seg.dnn_name, "h0", "missed tenant is evicted first");
        let ar = run(PreemptMode::Arrival, vec![(0, 100)]);
        assert_eq!(ar.preemptions, 1);
        let seg = ar.dispatches.iter().min_by_key(|d| d.t_end).unwrap();
        assert_eq!(seg.dnn_name, "h1", "arrival mode ignores the verdict");
    }

    fn tight_mem() -> crate::mem::MemConfig {
        crate::mem::MemConfig {
            dram: DramConfig { words_per_cycle: 1.0, burst_latency: 10 },
            arbitration: crate::mem::ArbitrationMode::FairShare,
            banks: 8,
        }
    }

    #[test]
    fn mem_aware_serializes_bound_tenants_and_wins_latency() {
        // Two identical strongly memory-bound single-layer tenants on a
        // starved 1 word/cycle interface.  Plain widest co-runs them at
        // half bandwidth each (both finish ~2T); mem-aware time-
        // multiplexes (T, then 2T) — strictly better mean completion at
        // (essentially) the same makespan, plus visible stall stats.
        let pool = WorkloadPool::new("t", vec![fc_dnn("a", &[64], 0), fc_dnn("b", &[64], 0)]);
        let widest_cfg = SchedulerConfig { mem: Some(tight_mem()), ..Default::default() };
        let aware_cfg = SchedulerConfig {
            alloc_policy: AllocPolicy::MemAware,
            mem: Some(tight_mem()),
            ..Default::default()
        };
        let widest = DynamicScheduler::new(widest_cfg).run(&pool);
        let aware = DynamicScheduler::new(aware_cfg).run(&pool);
        assert!(
            crate::report::mean_completion(&aware) <= 0.9 * crate::report::mean_completion(&widest),
            "mem-aware {:.0} should beat widest {:.0} on mean completion",
            crate::report::mean_completion(&aware),
            crate::report::mean_completion(&widest),
        );
        // Contention is visible in the per-tenant stats.
        assert_eq!(widest.mem.len(), 2);
        assert!(widest.mem_total.stall_cycles > 0, "starved interface must stall");
        assert!(widest.mem_total.achieved_words_per_cycle() <= 1.0 + 1e-9);
        assert!(aware.mem_total.stall_cycles < widest.mem_total.stall_cycles);
    }

    #[test]
    fn mem_aware_without_mem_matches_widest_bitwise() {
        let pool = WorkloadPool::new(
            "t",
            vec![fc_dnn("a", &[64, 64, 64], 0), fc_dnn("b", &[256, 64], 2_000)],
        );
        let widest = DynamicScheduler::new(SchedulerConfig::default()).run(&pool);
        let aware = DynamicScheduler::new(SchedulerConfig {
            alloc_policy: AllocPolicy::MemAware,
            ..Default::default()
        })
        .run(&pool);
        assert_eq!(widest.makespan, aware.makespan);
        assert_eq!(widest.dispatches, aware.dispatches);
        assert!(aware.mem.is_empty(), "no [mem] => no mem stats");
    }

    #[test]
    fn profile_tables_without_matching_shapes_change_nothing() {
        // A store that covers a *different* geometry contributes zero
        // candidates, so the 2d plan must stay bitwise identical to the
        // ladder-only plan (the `tables = None` byte-stability contract,
        // exercised through the union path rather than around it).
        use crate::profiler::{ProfileStore, ProfileTable};
        let pool = WorkloadPool::new(
            "t",
            vec![fc_dnn("a", &[64, 300, 64], 0), fc_dnn("b", &[256, 80], 1_500)],
        );
        let other_geom = ArrayGeometry::new(64, 64);
        let table =
            ProfileTable::build("a", &fc_dnn("a", &[64, 300, 64], 0), other_geom, &BufferConfig::default());
        let store = std::sync::Arc::new(ProfileStore::from_tables("test", vec![table]));
        let base_cfg = SchedulerConfig {
            partition_mode: PartitionMode::TwoD,
            ..Default::default()
        };
        let with_tables =
            SchedulerConfig { tables: Some(store), ..base_cfg.clone() };
        let plain = DynamicScheduler::new(base_cfg).run(&pool);
        let tabled = DynamicScheduler::new(with_tables).run(&pool);
        assert_eq!(plain.makespan, tabled.makespan);
        assert_eq!(plain.dispatches, tabled.dispatches);
    }

    #[test]
    fn profile_tables_beat_the_pow2_ladder_on_a_non_pow2_array() {
        // 96 array rows, K = 1152: the ladder rounds every free rectangle
        // down to 64 rows (FK = 18); the profiled exact-fit 96-row shape
        // reaches FK = 12.  Two equal-share tenants side by side, so the
        // full-array fast path never hides the ladder.
        use crate::profiler::{ProfileStore, ProfileTable};
        let geom = ArrayGeometry::new(96, 128);
        let mk = |name: &str| {
            let layers = (0..3)
                .map(|i| {
                    Layer::new(&format!("l{i}"), LayerKind::Fc, LayerShape::fc(2_000, 1_152, 384))
                })
                .collect();
            Dnn::chain(name, layers).arriving_at(0)
        };
        let pool = WorkloadPool::new("t", vec![mk("a"), mk("b")]);
        let bufs = BufferConfig::default();
        let table = ProfileTable::build("a", &mk("a"), geom, &bufs);
        let store = std::sync::Arc::new(ProfileStore::from_tables("test", vec![table]));
        let base_cfg = SchedulerConfig {
            geom,
            partition_mode: PartitionMode::TwoD,
            alloc_policy: AllocPolicy::EqualShare,
            ..Default::default()
        };
        let with_tables =
            SchedulerConfig { tables: Some(store), ..base_cfg.clone() };
        let ladder = DynamicScheduler::new(base_cfg).run(&pool);
        let tabled = DynamicScheduler::new(with_tables).run(&pool);
        assert!(
            tabled.makespan < ladder.makespan,
            "tables {} should beat ladder {}",
            tabled.makespan,
            ladder.makespan
        );
        // The win comes from a shape the pow-2 ladder cannot express.
        assert!(tabled.dispatches.iter().any(|d| d.tile.rows == 96), "{:?}", tabled.dispatches);
        assert!(ladder.dispatches.iter().all(|d| d.tile.rows.is_power_of_two()));
    }

    #[test]
    #[should_panic(expected = "mutually exclusive")]
    fn dram_and_mem_cannot_coexist() {
        // Enforced at the one place every policy passes through on its
        // way into the engine.
        let cfg = SchedulerConfig {
            dram: Some(DramConfig::default()),
            mem: Some(tight_mem()),
            ..Default::default()
        };
        let _ = cfg.mem_spec();
    }

    #[test]
    fn plan_cache_replays_identical_plans_and_counts_hits() {
        // Two plan calls over an unchanged world: the second must come
        // from the memo (hit counted) and be byte-identical to the first
        // — and to what the cache-off scheduler computes.
        use crate::coordinator::queue::TaskQueue;
        let pool = WorkloadPool::new("t", vec![fc_dnn("a", &[64, 64], 0), fc_dnn("b", &[64], 0)]);
        let queue = TaskQueue::new(&pool);
        let pm = PartitionManager::new(SchedulerConfig::default().geom);
        let progress = BTreeMap::new();
        let s = SystemState {
            now: 0,
            pool: &pool,
            queue: &queue,
            partitions: &pm,
            lanes: None,
            mem: None,
            progress: &progress,
        };
        let mut cached = DynamicScheduler::new(SchedulerConfig::default()).with_plan_cache(true);
        let p1 = cached.plan(&s);
        let p2 = cached.plan(&s);
        assert_eq!(p1, p2, "memo replay must be byte-identical");
        assert_eq!(cached.plan_cache_hits(), 1);
        let mut plain = DynamicScheduler::new(SchedulerConfig::default()).with_plan_cache(false);
        assert_eq!(plain.plan(&s), p1, "cache off computes the same plan");
        assert_eq!(plain.plan(&s), p1);
        assert_eq!(plain.plan_cache_hits(), 0);
    }

    #[test]
    fn plan_cache_and_arena_toggles_are_transparent() {
        // Full engine runs with every toggle combination must produce
        // identical dispatch streams in both partition modes.
        let mut rng = Rng::new(41);
        let pool = random_pool(
            &mut rng,
            &GeneratorCfg { num_dnns: 5, layers_min: 2, layers_max: 6, ..Default::default() },
        );
        for mode in PartitionMode::ALL {
            let cfg = SchedulerConfig { partition_mode: mode, ..Default::default() };
            let base = DynamicScheduler::new(cfg.clone())
                .with_plan_cache(false)
                .with_plan_arena(false)
                .run(&pool);
            let tuned = DynamicScheduler::new(cfg.clone())
                .with_plan_cache(true)
                .with_plan_arena(true)
                .run(&pool);
            let mixed = DynamicScheduler::new(cfg)
                .with_plan_cache(true)
                .with_plan_arena(false)
                .run(&pool);
            assert_eq!(base.dispatches, tuned.dispatches, "{mode:?}");
            assert_eq!(base.makespan, tuned.makespan, "{mode:?}");
            assert_eq!(base.dispatches, mixed.dispatches, "{mode:?}");
        }
    }

    #[test]
    fn rect_candidates_price_each_shape_once() {
        // Satellite: the ladder ∪ table union is deduped on
        // (row0, col0, rows, cols) — one price per distinct shape.
        use crate::profiler::{ProfileStore, ProfileTable};
        let geom = ArrayGeometry::new(128, 128);
        let bufs = BufferConfig::default();
        let dnn = fc_dnn("a", &[128], 0);
        let gemm = dnn.layers[0].shape.gemm();
        let table = ProfileTable::build("a", &dnn, geom, &bufs);
        let store = ProfileStore::from_tables("<memory>", vec![table]);
        let rect = Tile::full(geom);
        let (min_width, min_rows) = (16, 16);
        let demand_w = ceil_pow2(gemm.m).clamp(min_width, geom.cols);
        let demand_h = ceil_pow2(gemm.k).clamp(min_rows, geom.rows);
        let mut cand = Vec::new();
        push_rect_candidates(
            rect,
            demand_w,
            demand_h,
            min_width,
            min_rows,
            Some(&store),
            geom,
            gemm,
            &mut cand,
        );
        let mut seen = BTreeSet::new();
        for t in &cand {
            assert!(seen.insert((t.row0, t.col0, t.rows, t.cols)), "shape priced twice: {t:?}");
        }
        // And the dedupe is not vacuous: the raw ladder ∪ table union
        // enumerates the profiled full-width rungs twice.
        let w = demand_w.min(floor_pow2(rect.cols));
        assert!(w >= min_width);
        let mut ladder = 0u64;
        let mut h = demand_h.min(floor_pow2(rect.rows));
        while h >= min_rows {
            ladder += 1;
            if h == 1 {
                break;
            }
            h /= 2;
        }
        let tabled = store
            .candidates(geom, gemm.k, gemm.m)
            .iter()
            .filter(|c| {
                c.rows >= min_rows
                    && c.cols >= min_width
                    && c.rows <= rect.rows
                    && c.cols <= rect.cols
                    && c.cols <= demand_w
            })
            .count() as u64;
        assert!(
            (cand.len() as u64) < ladder + tabled,
            "deduped {} must shrink below ladder {ladder} + table {tabled}",
            cand.len()
        );
    }
}
