//! The partition manager — vertical slices of the PE array with
//! allocate / free / merge-adjacent-free semantics (paper §3.1–3.3).
//!
//! Invariants (checked in debug builds and by property tests):
//! - slices tile the array: disjoint, sorted, covering `[0, cols)`;
//! - free neighbours are always merged (canonical form), so the number of
//!   free slices is minimal;
//! - allocation carves from one free slice, leaving the remainder free.

use crate::sim::partitioned::PartitionSlice;

/// Allocation handle: index into the live allocation table.
pub type AllocId = usize;

#[derive(Debug, Clone, PartialEq, Eq)]
struct Region {
    slice: PartitionSlice,
    /// `None` = free; `Some(id)` = allocated.
    owner: Option<AllocId>,
}

/// Manages the vertical partitioning of an array `cols` wide.
#[derive(Debug, Clone)]
pub struct PartitionManager {
    cols: u64,
    regions: Vec<Region>,
    next_id: AllocId,
}

impl PartitionManager {
    pub fn new(cols: u64) -> PartitionManager {
        assert!(cols > 0);
        PartitionManager {
            cols,
            regions: vec![Region { slice: PartitionSlice::new(0, cols), owner: None }],
            next_id: 0,
        }
    }

    pub fn cols(&self) -> u64 {
        self.cols
    }

    /// Widths of free slices, descending.
    pub fn free_widths(&self) -> Vec<u64> {
        let mut w: Vec<u64> =
            self.regions.iter().filter(|r| r.owner.is_none()).map(|r| r.slice.width).collect();
        w.sort_unstable_by(|a, b| b.cmp(a));
        w
    }

    /// Total free columns.
    pub fn free_cols(&self) -> u64 {
        self.regions.iter().filter(|r| r.owner.is_none()).map(|r| r.slice.width).sum()
    }

    /// Number of live allocations.
    pub fn allocated_count(&self) -> usize {
        self.regions.iter().filter(|r| r.owner.is_some()).count()
    }

    /// Widest free slice, if any.
    pub fn widest_free(&self) -> Option<PartitionSlice> {
        self.regions
            .iter()
            .filter(|r| r.owner.is_none())
            .map(|r| r.slice)
            .max_by_key(|s| (s.width, u64::MAX - s.col0))
    }

    /// Allocate `width` columns from the widest free slice (carving from
    /// its left edge).  Returns the allocation id and slice, or `None` if
    /// no free slice is wide enough.
    pub fn allocate(&mut self, width: u64) -> Option<(AllocId, PartitionSlice)> {
        assert!(width > 0);
        let idx = self
            .regions
            .iter()
            .enumerate()
            .filter(|(_, r)| r.owner.is_none() && r.slice.width >= width)
            .max_by_key(|(_, r)| r.slice.width)
            .map(|(i, _)| i)?;

        let id = self.next_id;
        self.next_id += 1;
        let old = self.regions[idx].slice;
        let alloc = PartitionSlice::new(old.col0, width);
        if old.width == width {
            self.regions[idx].owner = Some(id);
        } else {
            self.regions[idx] = Region { slice: alloc, owner: Some(id) };
            self.regions.insert(
                idx + 1,
                Region { slice: PartitionSlice::new(old.col0 + width, old.width - width), owner: None },
            );
        }
        self.debug_check();
        Some((id, alloc))
    }

    /// Allocate the exact slice `want` (which must lie inside one free
    /// region), splitting off free remainders on either side.  This is
    /// how the engine applies a [`Scheduler`](crate::sim_core::Scheduler)
    /// plan: the policy proposes positions (possibly rehearsed on a
    /// clone), the manager enforces that they are actually free.
    pub fn allocate_at(&mut self, want: PartitionSlice) -> Option<(AllocId, PartitionSlice)> {
        let idx = self.regions.iter().position(|r| {
            r.owner.is_none() && r.slice.col0 <= want.col0 && want.end() <= r.slice.end()
        })?;
        let id = self.next_id;
        self.next_id += 1;
        let old = self.regions[idx].slice;
        self.regions.remove(idx);
        let mut at = idx;
        if want.col0 > old.col0 {
            let left = PartitionSlice::new(old.col0, want.col0 - old.col0);
            self.regions.insert(at, Region { slice: left, owner: None });
            at += 1;
        }
        self.regions.insert(at, Region { slice: want, owner: Some(id) });
        at += 1;
        if want.end() < old.end() {
            let right = PartitionSlice::new(want.end(), old.end() - want.end());
            self.regions.insert(at, Region { slice: right, owner: None });
        }
        self.debug_check();
        Some((id, want))
    }

    /// True when `slice` lies entirely inside one free region.
    pub fn is_free(&self, slice: PartitionSlice) -> bool {
        self.regions.iter().any(|r| {
            r.owner.is_none() && r.slice.col0 <= slice.col0 && slice.end() <= r.slice.end()
        })
    }

    /// Free an allocation, merging with adjacent free slices (paper:
    /// "these partitions may be merged if they are adjacent").
    pub fn free(&mut self, id: AllocId) -> PartitionSlice {
        let idx = self
            .regions
            .iter()
            .position(|r| r.owner == Some(id))
            .unwrap_or_else(|| panic!("free of unknown allocation {id}"));
        self.regions[idx].owner = None;
        // Merge right then left.
        if idx + 1 < self.regions.len() && self.regions[idx + 1].owner.is_none() {
            let right = self.regions.remove(idx + 1);
            self.regions[idx].slice = self.regions[idx].slice.merge(&right.slice);
        }
        let mut idx = idx;
        if idx > 0 && self.regions[idx - 1].owner.is_none() {
            let cur = self.regions.remove(idx);
            idx -= 1;
            self.regions[idx].slice = self.regions[idx].slice.merge(&cur.slice);
        }
        self.debug_check();
        self.regions[idx].slice
    }

    /// The slice of a live allocation.
    pub fn slice_of(&self, id: AllocId) -> Option<PartitionSlice> {
        self.regions.iter().find(|r| r.owner == Some(id)).map(|r| r.slice)
    }

    /// True when the whole array is one free slice.
    pub fn fully_free(&self) -> bool {
        self.regions.len() == 1 && self.regions[0].owner.is_none()
    }

    fn debug_check(&self) {
        debug_assert!(self.check_invariants().is_ok(), "{:?}", self.check_invariants());
    }

    /// Validate tiling + canonical-merge invariants (used by property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut expected_col = 0u64;
        let mut prev_free = false;
        for r in &self.regions {
            if r.slice.col0 != expected_col {
                return Err(format!("gap/overlap at col {expected_col}: {:?}", r.slice));
            }
            expected_col = r.slice.end();
            let is_free = r.owner.is_none();
            if is_free && prev_free {
                return Err(format!("unmerged adjacent free slices at {:?}", r.slice));
            }
            prev_free = is_free;
        }
        if expected_col != self.cols {
            return Err(format!("slices cover {expected_col} of {} cols", self.cols));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn starts_fully_free() {
        let pm = PartitionManager::new(128);
        assert!(pm.fully_free());
        assert_eq!(pm.free_cols(), 128);
        assert_eq!(pm.widest_free().unwrap().width, 128);
    }

    #[test]
    fn allocate_carves_left_edge() {
        let mut pm = PartitionManager::new(128);
        let (a, sa) = pm.allocate(32).unwrap();
        assert_eq!(sa, PartitionSlice::new(0, 32));
        let (_b, sb) = pm.allocate(64).unwrap();
        assert_eq!(sb, PartitionSlice::new(32, 64));
        assert_eq!(pm.free_cols(), 32);
        assert_eq!(pm.slice_of(a), Some(sa));
    }

    #[test]
    fn free_merges_adjacent() {
        let mut pm = PartitionManager::new(128);
        let (a, _) = pm.allocate(32).unwrap();
        let (b, _) = pm.allocate(32).unwrap();
        let (c, _) = pm.allocate(32).unwrap();
        // Free middle: no merge (neighbours busy).
        pm.free(b);
        assert_eq!(pm.free_widths(), vec![32, 32]);
        // Free left: merges with the freed middle.
        let merged = pm.free(a);
        assert_eq!(merged, PartitionSlice::new(0, 64));
        assert_eq!(pm.free_widths(), vec![64, 32]);
        // Free right: merges everything.
        pm.free(c);
        assert!(pm.fully_free());
    }

    #[test]
    fn allocation_failure_leaves_state_intact() {
        let mut pm = PartitionManager::new(64);
        let (_a, _) = pm.allocate(48).unwrap();
        assert!(pm.allocate(32).is_none());
        assert_eq!(pm.free_cols(), 16);
        assert!(pm.allocate(16).is_some());
    }

    #[test]
    #[should_panic(expected = "unknown allocation")]
    fn double_free_panics() {
        let mut pm = PartitionManager::new(64);
        let (a, _) = pm.allocate(16).unwrap();
        pm.free(a);
        pm.free(a);
    }

    #[test]
    fn allocate_at_splits_both_sides() {
        let mut pm = PartitionManager::new(128);
        assert!(pm.is_free(PartitionSlice::new(32, 64)));
        let (a, s) = pm.allocate_at(PartitionSlice::new(32, 64)).unwrap();
        assert_eq!(s, PartitionSlice::new(32, 64));
        assert_eq!(pm.free_widths(), vec![32, 32]);
        assert!(!pm.is_free(PartitionSlice::new(32, 64)));
        assert!(!pm.is_free(PartitionSlice::new(0, 64)), "straddles the allocation");
        assert!(pm.is_free(PartitionSlice::new(0, 32)));
        assert!(pm.is_free(PartitionSlice::new(96, 32)));
        // Overlapping request fails without disturbing state.
        assert!(pm.allocate_at(PartitionSlice::new(40, 8)).is_none());
        pm.free(a);
        assert!(pm.fully_free());
    }

    #[test]
    fn allocate_at_exact_region_and_edges() {
        let mut pm = PartitionManager::new(64);
        let (_a, _) = pm.allocate_at(PartitionSlice::new(0, 16)).unwrap();
        let (_b, _) = pm.allocate_at(PartitionSlice::new(48, 16)).unwrap();
        // Exactly the remaining middle region.
        let (_c, s) = pm.allocate_at(PartitionSlice::new(16, 32)).unwrap();
        assert_eq!(s, PartitionSlice::new(16, 32));
        assert_eq!(pm.free_cols(), 0);
        assert!(pm.allocate_at(PartitionSlice::new(0, 1)).is_none());
    }

    #[test]
    fn allocate_and_allocate_at_agree_on_left_carve() {
        // The dynamic policy rehearses with `allocate` on a clone and the
        // engine replays with `allocate_at`; both must produce the same
        // region layout.
        let mut a = PartitionManager::new(128);
        let mut b = PartitionManager::new(128);
        for w in [32u64, 64, 16] {
            let (_, sa) = a.allocate(w).unwrap();
            let (_, sb) = b.allocate_at(sa).unwrap();
            assert_eq!(sa, sb);
        }
        assert_eq!(a.free_widths(), b.free_widths());
        assert_eq!(a.widest_free(), b.widest_free());
    }

    #[test]
    fn random_alloc_free_preserves_invariants() {
        prop::check("partition manager invariants", 200, |rng| {
            let cols = *rng.choose(&[16u64, 64, 128, 256]);
            let mut pm = PartitionManager::new(cols);
            let mut live: Vec<AllocId> = Vec::new();
            for _ in 0..64 {
                if live.is_empty() || rng.gen_bool(0.55) {
                    let w = rng.gen_range_inclusive(1, cols / 2);
                    if let Some((id, s)) = pm.allocate(w) {
                        prop::ensure_eq(s.width, w, "allocated width")?;
                        live.push(id);
                    }
                } else {
                    let i = rng.gen_range(live.len() as u64) as usize;
                    pm.free(live.swap_remove(i));
                }
                pm.check_invariants()?;
                let alloc_cols: u64 =
                    live.iter().map(|&id| pm.slice_of(id).unwrap().width).sum();
                prop::ensure_eq(alloc_cols + pm.free_cols(), cols, "conservation")?;
            }
            for id in live {
                pm.free(id);
                pm.check_invariants()?;
            }
            prop::ensure(pm.fully_free(), "all freed -> fully free")
        });
    }
}
