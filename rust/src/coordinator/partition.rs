//! The partition manager — a 2D free-rectangle allocator over the PE
//! array, generalizing the paper's vertical column slices (§3.1–3.3) to
//! rectangular tiles (Planaria-style 2D fission; see `docs/fission.md`).
//!
//! Invariants (checked in debug builds and by property tests):
//! - regions tile the array: pairwise disjoint and covering every PE;
//! - no two free regions share a full edge (canonical form — any such
//!   pair would merge into one rectangle), so the free list is minimal
//!   under rectangle merging;
//! - allocation carves from one free region with a guillotine split
//!   (full-container-height strips left/right of the carved tile, then
//!   tile-width remainders above/below), leaving the remainders free.
//!
//! The rehearse/replay contract of the 1D manager is preserved: a policy
//! clones the manager, rehearses [`PartitionManager::allocate`] /
//! [`PartitionManager::allocate_at`] on the clone, and the engine replays
//! the returned tiles with `allocate_at` on the live manager — both paths
//! run the same split + merge code, so the replayed state is exactly what
//! the rehearsal saw.
//!
//! In `columns` mode every allocation is full-height, all regions stay
//! full-height rectangles, and the allocator degenerates bit-for-bit to
//! the original 1D slice manager (merging is only ever horizontal, and
//! the guillotine split leaves only left/right strips).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use crate::sim::dataflow::ArrayGeometry;
use crate::sim::partitioned::{LaneSpan, PartitionSlice, Tile};

/// Allocation handle: index into the live allocation table.
pub type AllocId = usize;

/// Process-global source of manager identities: every
/// [`PartitionManager::new`] draws a fresh nonce, and clones keep their
/// original's, so `(nonce, epoch)` names one concrete free-rectangle set
/// across a manager and all its rehearse clones without ever influencing
/// allocation behavior.
static PM_NONCE: AtomicU64 = AtomicU64::new(1);

/// Whether the sorted free-region index is consulted by the allocator
/// lookups ([`PartitionManager::allocate_tile`],
/// [`PartitionManager::allocate_at`], [`PartitionManager::is_free`]).
/// Opt out with `MTSA_NO_ALLOC_INDEX` (any value) to run the reference
/// linear scans — output is identical; the switch exists for A/B timing
/// and bisecting.  The index itself is always maintained (it is cheap and
/// rebuilt only when the region set changes).
pub fn alloc_index_enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| std::env::var_os("MTSA_NO_ALLOC_INDEX").is_none())
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct Region {
    tile: Tile,
    /// `None` = free; `Some(id)` = allocated.
    owner: Option<AllocId>,
}

/// Manages the rectangular partitioning of an `ArrayGeometry`.
#[derive(Debug)]
pub struct PartitionManager {
    geom: ArrayGeometry,
    /// Sorted by `(row0, col0)` — the deterministic scan order.
    regions: Vec<Region>,
    /// Indices of *free* regions, sorted by `(pes, row0, col0)` — the
    /// best-fit order.  First fit over this index equals the reference
    /// `min_by_key` scan because disjoint rectangles have distinct
    /// top-left corners, making the key unique.  Rebuilt whenever the
    /// region set changes (every mutation ends in [`Self::merge_free`]).
    free_index: Vec<usize>,
    next_id: AllocId,
    /// Identity of this manager lineage (shared by rehearse clones),
    /// drawn from [`PM_NONCE`].  Purely observational.
    nonce: u64,
    /// Bumped once per mutating call (allocate / allocate_at / free /
    /// shrink — every mutation path runs [`Self::merge_free`] exactly
    /// once).  `(nonce, epoch)` therefore uniquely names a free set:
    /// planners key memoized candidate searches on it.
    epoch: u64,
}

/// Clone is manual so `clone_from` can reuse the destination's existing
/// `regions`/`free_index` capacity — the rehearse path clones the live
/// manager on every plan call, and with the plan arena it clones into a
/// recycled scratch manager instead of allocating fresh vectors.
impl Clone for PartitionManager {
    fn clone(&self) -> PartitionManager {
        PartitionManager {
            geom: self.geom,
            regions: self.regions.clone(),
            free_index: self.free_index.clone(),
            next_id: self.next_id,
            nonce: self.nonce,
            epoch: self.epoch,
        }
    }

    fn clone_from(&mut self, src: &PartitionManager) {
        self.geom = src.geom;
        self.regions.clone_from(&src.regions);
        self.free_index.clone_from(&src.free_index);
        self.next_id = src.next_id;
        self.nonce = src.nonce;
        self.epoch = src.epoch;
    }
}

impl PartitionManager {
    pub fn new(geom: ArrayGeometry) -> PartitionManager {
        PartitionManager {
            geom,
            regions: vec![Region { tile: Tile::full(geom), owner: None }],
            free_index: vec![0],
            next_id: 0,
            nonce: PM_NONCE.fetch_add(1, Ordering::Relaxed),
            epoch: 0,
        }
    }

    /// `(nonce, epoch)` — a stable name for the current free-rectangle
    /// set.  The nonce identifies the manager lineage (rehearse clones
    /// share it), the epoch bumps on every mutation, so two equal keys
    /// within one lineage always mean an identical free set.
    pub fn plan_key(&self) -> (u64, u64) {
        (self.nonce, self.epoch)
    }

    pub fn geom(&self) -> ArrayGeometry {
        self.geom
    }

    pub fn cols(&self) -> u64 {
        self.geom.cols
    }

    fn sort_regions(&mut self) {
        self.regions.sort_unstable_by_key(|r| (r.tile.row0, r.tile.col0));
    }

    fn rebuild_free_index(&mut self) {
        self.free_index.clear();
        self.free_index.extend(
            self.regions.iter().enumerate().filter(|(_, r)| r.owner.is_none()).map(|(i, _)| i),
        );
        let regions = &self.regions;
        self.free_index.sort_unstable_by_key(|&i| {
            let t = regions[i].tile;
            (t.pes(), t.row0, t.col0)
        });
    }

    /// Widths of *full-height* free regions, descending — the
    /// columns-mode view (in that mode every free region is full-height).
    pub fn free_widths(&self) -> Vec<u64> {
        let mut w: Vec<u64> = self
            .regions
            .iter()
            .filter(|r| r.owner.is_none() && r.tile.is_full_height(self.geom))
            .map(|r| r.tile.cols)
            .collect();
        w.sort_unstable_by(|a, b| b.cmp(a));
        w
    }

    /// Total free PEs.
    pub fn free_pes(&self) -> u64 {
        self.regions.iter().filter(|r| r.owner.is_none()).map(|r| r.tile.pes()).sum()
    }

    /// Free column-equivalents: free PEs / array rows.  Exact whenever
    /// the free space is full-height — i.e. always in columns mode.
    pub fn free_cols(&self) -> u64 {
        self.free_pes() / self.geom.rows
    }

    /// Number of live allocations.
    pub fn allocated_count(&self) -> usize {
        self.regions.iter().filter(|r| r.owner.is_some()).count()
    }

    /// Free regions, in `(row0, col0)` order.
    pub fn free_tiles(&self) -> Vec<Tile> {
        self.free_tiles_iter().collect()
    }

    /// Allocation-free view of [`Self::free_tiles`], in the same
    /// `(row0, col0)` order — the planner hot path iterates this without
    /// materializing a vector.
    pub fn free_tiles_iter(&self) -> impl Iterator<Item = Tile> + '_ {
        self.regions.iter().filter(|r| r.owner.is_none()).map(|r| r.tile)
    }

    /// Live allocated tiles, in `(row0, col0)` order.
    pub fn allocated_tiles(&self) -> Vec<Tile> {
        self.allocated_tiles_iter().collect()
    }

    /// Allocation-free view of [`Self::allocated_tiles`].
    pub fn allocated_tiles_iter(&self) -> impl Iterator<Item = Tile> + '_ {
        self.regions.iter().filter(|r| r.owner.is_some()).map(|r| r.tile)
    }

    /// Widest free *full-height* slice, if any (leftmost on width ties —
    /// the same preference [`PartitionManager::allocate`] carves with).
    pub fn widest_free(&self) -> Option<PartitionSlice> {
        self.regions
            .iter()
            .filter(|r| r.owner.is_none() && r.tile.is_full_height(self.geom))
            .map(|r| PartitionSlice::new(r.tile.col0, r.tile.cols))
            .max_by_key(|s| (s.width, u64::MAX - s.col0))
    }

    /// Allocate `width` full-height columns from the widest free
    /// full-height region (carving from its left edge).  Ties on width go
    /// to the *leftmost* candidate — exactly the region
    /// [`PartitionManager::widest_free`] reports, so a policy that sizes
    /// against `widest_free` and then carves with `allocate` can never
    /// land in a different region.  Returns the allocation id and tile,
    /// or `None` if no free full-height region is wide enough.
    pub fn allocate(&mut self, width: u64) -> Option<(AllocId, Tile)> {
        assert!(width > 0);
        let best = self
            .regions
            .iter()
            .filter(|r| {
                r.owner.is_none() && r.tile.is_full_height(self.geom) && r.tile.cols >= width
            })
            .map(|r| r.tile)
            .max_by_key(|t| (t.cols, u64::MAX - t.col0))?;
        self.allocate_at(Tile::full_height(self.geom, best.col0, width))
    }

    /// Best-fit 2D allocation: a `rows × cols` tile at the top-left
    /// corner of the smallest free region that fits it (ties to the
    /// topmost, then leftmost region).  Returns `None` when no free
    /// region is tall and wide enough.
    pub fn allocate_tile(&mut self, rows: u64, cols: u64) -> Option<(AllocId, Tile)> {
        assert!(rows > 0 && cols > 0);
        let best = if alloc_index_enabled() {
            // First fit over the best-fit-sorted free index: the first
            // fitting entry *is* the `min_by_key` of the reference scan
            // (the index key is unique), found without visiting every
            // region or comparing keys.
            self.free_index
                .iter()
                .map(|&i| self.regions[i].tile)
                .find(|t| t.rows >= rows && t.cols >= cols)
        } else {
            self.regions
                .iter()
                .filter(|r| r.owner.is_none() && r.tile.rows >= rows && r.tile.cols >= cols)
                .map(|r| r.tile)
                .min_by_key(|t| (t.pes(), t.row0, t.col0))
        }?;
        self.allocate_at(Tile::new(best.row0, best.col0, rows, cols))
    }

    /// Allocate the exact tile `want` (which must lie inside one free
    /// region), guillotine-splitting the remainder: full-container-height
    /// strips left and right of `want`, then `want`-width remainders
    /// above and below.  This is how the engine applies a
    /// [`Scheduler`](crate::sim_core::Scheduler) plan: the policy
    /// proposes positions (possibly rehearsed on a clone), the manager
    /// enforces that they are actually free.
    pub fn allocate_at(&mut self, want: Tile) -> Option<(AllocId, Tile)> {
        // At most one region can contain `want` (regions are pairwise
        // disjoint), so scanning only the free index finds the same
        // region the reference full scan would.
        let idx = if alloc_index_enabled() {
            self.free_index.iter().copied().find(|&i| self.regions[i].tile.contains(&want))
        } else {
            self.regions.iter().position(|r| r.owner.is_none() && r.tile.contains(&want))
        }?;
        let id = self.next_id;
        self.next_id += 1;
        let old = self.regions[idx].tile;
        self.regions.remove(idx);
        if want.col0 > old.col0 {
            let left = Tile::new(old.row0, old.col0, old.rows, want.col0 - old.col0);
            self.regions.push(Region { tile: left, owner: None });
        }
        if want.col_end() < old.col_end() {
            let right =
                Tile::new(old.row0, want.col_end(), old.rows, old.col_end() - want.col_end());
            self.regions.push(Region { tile: right, owner: None });
        }
        if want.row0 > old.row0 {
            let above = Tile::new(old.row0, want.col0, want.row0 - old.row0, want.cols);
            self.regions.push(Region { tile: above, owner: None });
        }
        if want.row_end() < old.row_end() {
            let below =
                Tile::new(want.row_end(), want.col0, old.row_end() - want.row_end(), want.cols);
            self.regions.push(Region { tile: below, owner: None });
        }
        self.regions.push(Region { tile: want, owner: Some(id) });
        // A remainder can expose a full edge to a free region *outside*
        // the container (impossible in 1D, routine in 2D) — restore the
        // canonical form.  In columns mode this never fires: the old
        // invariant already guarantees the container's neighbours are
        // allocated.
        self.merge_free();
        self.debug_check();
        Some((id, want))
    }

    /// True when `tile` lies entirely inside one free region.
    ///
    /// Like the 1D manager, this is containment in a *single* region: an
    /// L-shaped free area covering `tile` across two rectangles reports
    /// `false` (canonical merging keeps such fragmentation minimal).
    pub fn is_free(&self, tile: Tile) -> bool {
        if alloc_index_enabled() {
            self.free_index.iter().any(|&i| self.regions[i].tile.contains(&tile))
        } else {
            self.regions.iter().any(|r| r.owner.is_none() && r.tile.contains(&tile))
        }
    }

    /// Free an allocation, merging free rectangles that share a full edge
    /// until none remain (paper §3.3: "these partitions may be merged if
    /// they are adjacent", extended to both axes).  Returns the free
    /// region that absorbed the tile.
    pub fn free(&mut self, id: AllocId) -> Tile {
        let idx = self
            .regions
            .iter()
            .position(|r| r.owner == Some(id))
            .unwrap_or_else(|| panic!("free of unknown allocation {id}"));
        let origin = self.regions[idx].tile;
        self.regions[idx].owner = None;
        self.merge_free();
        // Greedy pairwise merging cannot always re-fuse an *all-free*
        // tiling (pinwheel-shaped fixpoints exist in 2D); once no
        // allocation remains, the canonical form is simply the whole
        // array.  In columns mode this is a no-op: full-height regions
        // always merge back to one rectangle pairwise.
        if self.regions.len() > 1 && self.regions.iter().all(|r| r.owner.is_none()) {
            self.regions = vec![Region { tile: Tile::full(self.geom), owner: None }];
            self.rebuild_free_index();
        }
        self.debug_check();
        self.regions
            .iter()
            .find(|r| r.owner.is_none() && r.tile.contains(&origin))
            .map(|r| r.tile)
            .expect("freed tile must end up inside one free region")
    }

    /// Merge free regions sharing a full edge, to fixpoint, in
    /// deterministic `(row0, col0)` scan order.
    fn merge_free(&mut self) {
        // Every mutating entry point (allocate → allocate_at, allocate_at,
        // free, shrink) lands here exactly once, and failed allocations
        // return before any mutation — so the epoch counts mutations.
        // `free`'s all-free pinwheel reset below runs *within* the same
        // `free` call, after this bump: it is a deterministic function of
        // the post-merge state, so one epoch still names one free set.
        self.epoch += 1;
        // Sort once, outside the fixpoint loop: a merge replaces region
        // `i`'s tile with the merged rectangle — whose top-left corner is
        // exactly region `i`'s corner, because `j > i` in `(row0, col0)`
        // order and `merged_with` keeps the smaller corner — and removing
        // `j` leaves the tail sorted.  The list therefore *stays* sorted
        // through every merge, and each iteration scans the identical
        // order the per-iteration re-sort used to produce.
        self.sort_regions();
        loop {
            let mut found: Option<(usize, usize, Tile)> = None;
            'scan: for i in 0..self.regions.len() {
                if self.regions[i].owner.is_some() {
                    continue;
                }
                for j in (i + 1)..self.regions.len() {
                    if self.regions[j].owner.is_some() {
                        continue;
                    }
                    if let Some(t) = self.regions[i].tile.merged_with(&self.regions[j].tile) {
                        found = Some((i, j, t));
                        break 'scan;
                    }
                }
            }
            match found {
                Some((i, j, t)) => {
                    self.regions.remove(j); // j > i, so i stays valid
                    self.regions[i].tile = t;
                }
                None => break,
            }
        }
        self.rebuild_free_index();
    }

    /// Shrink a live allocation in place to `keep` (a sub-rectangle of
    /// its current tile), freeing the remainder with the same guillotine
    /// split [`PartitionManager::allocate_at`] carves with — the reshape
    /// primitive for preempting schedulers that narrow a running tenant
    /// at a fold boundary without fully draining it.  Returns the number
    /// of PEs released (0 when `keep` equals the current tile).
    ///
    /// Panics if `id` is unknown or `keep` is not contained in its tile —
    /// a policy bug, exactly like freeing an unknown allocation.
    pub fn shrink(&mut self, id: AllocId, keep: Tile) -> u64 {
        let idx = self
            .regions
            .iter()
            .position(|r| r.owner == Some(id))
            .unwrap_or_else(|| panic!("shrink of unknown allocation {id}"));
        let old = self.regions[idx].tile;
        assert!(
            old.contains(&keep),
            "shrink of allocation {id} to {keep:?} outside its tile {old:?}"
        );
        if keep == old {
            return 0;
        }
        self.regions[idx].tile = keep;
        if keep.col0 > old.col0 {
            let left = Tile::new(old.row0, old.col0, old.rows, keep.col0 - old.col0);
            self.regions.push(Region { tile: left, owner: None });
        }
        if keep.col_end() < old.col_end() {
            let right =
                Tile::new(old.row0, keep.col_end(), old.rows, old.col_end() - keep.col_end());
            self.regions.push(Region { tile: right, owner: None });
        }
        if keep.row0 > old.row0 {
            let above = Tile::new(old.row0, keep.col0, keep.row0 - old.row0, keep.cols);
            self.regions.push(Region { tile: above, owner: None });
        }
        if keep.row_end() < old.row_end() {
            let below =
                Tile::new(keep.row_end(), keep.col0, old.row_end() - keep.row_end(), keep.cols);
            self.regions.push(Region { tile: below, owner: None });
        }
        self.merge_free();
        self.debug_check();
        old.pes() - keep.pes()
    }

    /// The tile of a live allocation.
    pub fn tile_of(&self, id: AllocId) -> Option<Tile> {
        self.regions.iter().find(|r| r.owner == Some(id)).map(|r| r.tile)
    }

    /// True when the whole array is one free region.
    pub fn fully_free(&self) -> bool {
        self.regions.len() == 1 && self.regions[0].owner.is_none()
    }

    fn debug_check(&self) {
        debug_assert!(self.check_invariants().is_ok(), "{:?}", self.check_invariants());
    }

    /// Validate tiling + canonical-merge invariants (used by property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut area = 0u64;
        for (i, r) in self.regions.iter().enumerate() {
            if r.tile.row_end() > self.geom.rows || r.tile.col_end() > self.geom.cols {
                return Err(format!("tile out of bounds: {:?}", r.tile));
            }
            area += r.tile.pes();
            for s in &self.regions[i + 1..] {
                if r.tile.overlaps(&s.tile) {
                    return Err(format!("overlapping tiles {:?} and {:?}", r.tile, s.tile));
                }
                if r.owner.is_none()
                    && s.owner.is_none()
                    && r.tile.merged_with(&s.tile).is_some()
                {
                    return Err(format!(
                        "unmerged adjacent free tiles {:?} and {:?}",
                        r.tile, s.tile
                    ));
                }
            }
        }
        if area != self.geom.pes() {
            return Err(format!("tiles cover {area} of {} PEs", self.geom.pes()));
        }
        let mut want: Vec<usize> = self
            .regions
            .iter()
            .enumerate()
            .filter(|(_, r)| r.owner.is_none())
            .map(|(i, _)| i)
            .collect();
        want.sort_unstable_by_key(|&i| {
            let t = self.regions[i].tile;
            (t.pes(), t.row0, t.col0)
        });
        if self.free_index != want {
            return Err(format!("stale free index {:?}, want {want:?}", self.free_index));
        }
        Ok(())
    }
}

/// The vector-lane allocation pool: contiguous 1D lane spans, carved and
/// merged exactly like column slices.
///
/// Internally this *is* a [`PartitionManager`] over the degenerate
/// `1 × lanes` geometry — every allocation is "full height" by
/// construction, so the allocator runs the proven columns-mode code
/// (left-edge widest-fit carving, pairwise merge, epoch-on-mutation) and
/// the rehearse/replay + `(nonce, epoch)` plan-key contract that keeps
/// the plan cache sound carries over verbatim.  The wrapper only
/// translates between [`LaneSpan`]s and the 1-row [`Tile`]s the inner
/// manager stores, so lane handles can never be mistaken for array tiles.
#[derive(Debug, Clone)]
pub struct LaneManager {
    pm: PartitionManager,
}

impl LaneManager {
    pub fn new(lanes: u64) -> LaneManager {
        assert!(lanes > 0, "a lane pool needs at least one lane");
        LaneManager { pm: PartitionManager::new(ArrayGeometry::new(1, lanes)) }
    }

    /// Total lanes in the pool.
    pub fn lanes(&self) -> u64 {
        self.pm.cols()
    }

    /// `(nonce, epoch)` of the underlying free-set — see
    /// [`PartitionManager::plan_key`].  Plan memos hash this alongside
    /// the array pool's key so a lane mutation invalidates cached plans.
    pub fn plan_key(&self) -> (u64, u64) {
        self.pm.plan_key()
    }

    /// Free lanes in total (across all free spans).
    pub fn free_lanes(&self) -> u64 {
        self.pm.free_pes()
    }

    /// Width of the widest free span, 0 when the pool is exhausted.
    pub fn widest_free(&self) -> u64 {
        self.pm.widest_free().map_or(0, |s| s.width)
    }

    /// Live lane allocations.
    pub fn allocated_count(&self) -> usize {
        self.pm.allocated_count()
    }

    /// True when every lane is free (single free span).
    pub fn fully_free(&self) -> bool {
        self.pm.fully_free()
    }

    /// Allocate `width` lanes from the widest free span (leftmost on
    /// ties), like the columns-mode array allocator.
    pub fn allocate(&mut self, width: u64) -> Option<(AllocId, LaneSpan)> {
        let (id, tile) = self.pm.allocate(width)?;
        Some((id, LaneSpan::from_tile(tile)))
    }

    /// Replay an exact rehearsed span on the live pool.
    pub fn allocate_at(&mut self, span: LaneSpan) -> Option<(AllocId, LaneSpan)> {
        let (id, tile) = self.pm.allocate_at(span.as_tile())?;
        Some((id, LaneSpan::from_tile(tile)))
    }

    /// Release a lane allocation (panics on unknown ids, like the array
    /// pool).
    pub fn free(&mut self, id: AllocId) {
        self.pm.free(id);
    }

    /// The span of a live lane allocation.
    pub fn span_of(&self, id: AllocId) -> Option<LaneSpan> {
        self.pm.tile_of(id).map(LaneSpan::from_tile)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    const GEOM: ArrayGeometry = ArrayGeometry { rows: 128, cols: 128 };

    /// Full-height tile shorthand (the columns-mode shape).
    fn fh(col0: u64, width: u64) -> Tile {
        Tile::full_height(GEOM, col0, width)
    }

    #[test]
    fn plan_key_tracks_mutations_and_clone_lineage() {
        let mut pm = PartitionManager::new(GEOM);
        let (n0, e0) = pm.plan_key();
        // A failed allocation mutates nothing: the key must not move.
        assert!(pm.allocate(1024).is_none());
        assert_eq!(pm.plan_key(), (n0, e0));
        let (a, _) = pm.allocate(32).unwrap();
        assert_eq!(pm.plan_key(), (n0, e0 + 1));
        // Rehearse clones share the lineage nonce and replaying the same
        // mutation sequence lands both on the same key + free set.
        let mut clone = pm.clone();
        assert_eq!(clone.plan_key(), pm.plan_key());
        let (_, t) = clone.allocate(16).unwrap();
        pm.allocate_at(t).unwrap();
        assert_eq!(clone.plan_key(), pm.plan_key());
        assert_eq!(clone.free_tiles(), pm.free_tiles());
        pm.free(a);
        assert_ne!(pm.plan_key(), clone.plan_key());
        // Fresh managers are distinct lineages.
        assert_ne!(PartitionManager::new(GEOM).plan_key().0, n0);
        // clone_from reuses capacity but must copy the key too.
        let mut dst = PartitionManager::new(GEOM);
        dst.clone_from(&pm);
        assert_eq!(dst.plan_key(), pm.plan_key());
        assert_eq!(dst.free_tiles(), pm.free_tiles());
    }

    #[test]
    fn starts_fully_free() {
        let pm = PartitionManager::new(GEOM);
        assert!(pm.fully_free());
        assert_eq!(pm.free_cols(), 128);
        assert_eq!(pm.free_pes(), 128 * 128);
        assert_eq!(pm.widest_free().unwrap().width, 128);
    }

    #[test]
    fn allocate_carves_left_edge() {
        let mut pm = PartitionManager::new(GEOM);
        let (a, sa) = pm.allocate(32).unwrap();
        assert_eq!(sa, fh(0, 32));
        let (_b, sb) = pm.allocate(64).unwrap();
        assert_eq!(sb, fh(32, 64));
        assert_eq!(pm.free_cols(), 32);
        assert_eq!(pm.tile_of(a), Some(sa));
    }

    #[test]
    fn allocate_prefers_leftmost_on_width_ties() {
        // Regression for the 1D tie-break bug: with two equal-width free
        // regions, `allocate` must carve from the one `widest_free`
        // reports (the leftmost), not the rightmost.
        let mut pm = PartitionManager::new(GEOM);
        let (_a, _) = pm.allocate(32).unwrap(); // [0, 32)
        let (b, _) = pm.allocate(32).unwrap(); // [32, 64)
        let (_c, _) = pm.allocate(32).unwrap(); // [64, 96)
        pm.free(b); // free [32, 64) and [96, 128): two 32-wide regions
        assert_eq!(pm.free_widths(), vec![32, 32]);
        let reported = pm.widest_free().unwrap();
        assert_eq!(reported, PartitionSlice::new(32, 32), "widest_free prefers leftmost");
        let (_d, carved) = pm.allocate(32).unwrap();
        assert_eq!(
            carved,
            fh(reported.col0, 32),
            "allocate must carve the region widest_free reported"
        );
    }

    #[test]
    fn free_merges_adjacent() {
        let mut pm = PartitionManager::new(GEOM);
        let (a, _) = pm.allocate(32).unwrap();
        let (b, _) = pm.allocate(32).unwrap();
        let (c, _) = pm.allocate(32).unwrap();
        // Free middle: neighbours busy and the free right end [96,128)
        // is not adjacent — two separate free regions remain.
        pm.free(b);
        assert_eq!(pm.free_widths(), vec![32, 32]);
        // Free left: merges with the freed middle.
        let merged = pm.free(a);
        assert_eq!(merged, fh(0, 64));
        assert_eq!(pm.free_widths(), vec![64, 32]);
        // Free right: merges everything.
        pm.free(c);
        assert!(pm.fully_free());
    }

    #[test]
    fn allocation_failure_leaves_state_intact() {
        let geom = ArrayGeometry::new(128, 64);
        let mut pm = PartitionManager::new(geom);
        let (_a, _) = pm.allocate(48).unwrap();
        assert!(pm.allocate(32).is_none());
        assert_eq!(pm.free_cols(), 16);
        assert!(pm.allocate(16).is_some());
    }

    #[test]
    #[should_panic(expected = "unknown allocation")]
    fn double_free_panics() {
        let mut pm = PartitionManager::new(GEOM);
        let (a, _) = pm.allocate(16).unwrap();
        pm.free(a);
        pm.free(a);
    }

    #[test]
    fn allocate_at_splits_both_sides() {
        let mut pm = PartitionManager::new(GEOM);
        assert!(pm.is_free(fh(32, 64)));
        let (a, t) = pm.allocate_at(fh(32, 64)).unwrap();
        assert_eq!(t, fh(32, 64));
        assert_eq!(pm.free_widths(), vec![32, 32]);
        assert!(!pm.is_free(fh(32, 64)));
        assert!(!pm.is_free(fh(0, 64)), "straddles the allocation");
        assert!(pm.is_free(fh(0, 32)));
        assert!(pm.is_free(fh(96, 32)));
        // Overlapping request fails without disturbing state.
        assert!(pm.allocate_at(fh(40, 8)).is_none());
        pm.free(a);
        assert!(pm.fully_free());
    }

    #[test]
    fn allocate_at_exact_region_and_edges() {
        let geom = ArrayGeometry::new(128, 64);
        let mut pm = PartitionManager::new(geom);
        let (_a, _) = pm.allocate_at(Tile::full_height(geom, 0, 16)).unwrap();
        let (_b, _) = pm.allocate_at(Tile::full_height(geom, 48, 16)).unwrap();
        // Exactly the remaining middle region.
        let (_c, t) = pm.allocate_at(Tile::full_height(geom, 16, 32)).unwrap();
        assert_eq!(t, Tile::full_height(geom, 16, 32));
        assert_eq!(pm.free_cols(), 0);
        assert!(pm.allocate_at(Tile::full_height(geom, 0, 1)).is_none());
    }

    #[test]
    fn allocate_at_guillotine_splits_2d() {
        // Carve an interior tile: the container splits into left/right
        // full-height strips plus above/below remainders at tile width.
        let mut pm = PartitionManager::new(GEOM);
        let want = Tile::new(32, 16, 64, 96);
        let (a, t) = pm.allocate_at(want).unwrap();
        assert_eq!(t, want);
        let free = pm.free_tiles();
        assert_eq!(
            free,
            vec![
                Tile::new(0, 0, 128, 16),   // left strip
                Tile::new(0, 16, 32, 96),   // above
                Tile::new(0, 112, 128, 16), // right strip
                Tile::new(96, 16, 32, 96),  // below
            ]
        );
        assert_eq!(pm.free_pes() + want.pes(), GEOM.pes());
        // Freeing restores the single region.
        pm.free(a);
        assert!(pm.fully_free());
    }

    #[test]
    fn vertical_stacking_and_merge() {
        // Two half-height tiles stack in the same columns; freeing both
        // merges them back vertically, then into the whole array.
        let mut pm = PartitionManager::new(GEOM);
        let (a, ta) = pm.allocate_tile(64, 128).unwrap();
        assert_eq!(ta, Tile::new(0, 0, 64, 128));
        let (b, tb) = pm.allocate_tile(64, 128).unwrap();
        assert_eq!(tb, Tile::new(64, 0, 64, 128));
        assert_eq!(pm.free_pes(), 0);
        assert_eq!(pm.widest_free(), None, "no full-height region left");
        pm.free(a);
        assert_eq!(pm.free_tiles(), vec![Tile::new(0, 0, 64, 128)]);
        pm.free(b);
        assert!(pm.fully_free());
    }

    #[test]
    fn allocate_tile_best_fit_prefers_smallest_region() {
        let mut pm = PartitionManager::new(GEOM);
        // Carve a 32x32 corner so a small free region (32 x 96 above-right
        // strip pattern) exists alongside the big remainder.
        let (_a, _) = pm.allocate_at(Tile::new(0, 0, 32, 32)).unwrap();
        // Free regions now: right strip (128 x 96 at col 32) and below
        // (96 x 32 at row 32).
        assert_eq!(
            pm.free_tiles(),
            vec![Tile::new(0, 32, 128, 96), Tile::new(32, 0, 96, 32)]
        );
        // A 32x32 request fits both; best-fit picks the smaller region.
        let (_b, t) = pm.allocate_tile(32, 32).unwrap();
        assert_eq!(t, Tile::new(32, 0, 32, 32));
    }

    #[test]
    fn free_index_first_fit_matches_reference_best_fit() {
        // The indexed `allocate_tile` must pick the exact region the
        // reference `min_by_key` scan picks, across random region shapes.
        prop::check("alloc index parity", 120, |rng| {
            let geom = ArrayGeometry::new(64, 64);
            let mut pm = PartitionManager::new(geom);
            let mut live: Vec<AllocId> = Vec::new();
            for _ in 0..40 {
                if live.is_empty() || rng.gen_bool(0.6) {
                    let rows = rng.gen_range_inclusive(1, 48);
                    let cols = rng.gen_range_inclusive(1, 48);
                    let want = pm
                        .free_tiles()
                        .into_iter()
                        .filter(|t| t.rows >= rows && t.cols >= cols)
                        .min_by_key(|t| (t.pes(), t.row0, t.col0));
                    match (want, pm.allocate_tile(rows, cols)) {
                        (None, None) => {}
                        (Some(w), Some((id, t))) => {
                            prop::ensure_eq(
                                t,
                                Tile::new(w.row0, w.col0, rows, cols),
                                "carve corner",
                            )?;
                            live.push(id);
                        }
                        (w, g) => return Err(format!("fit disagreement: {w:?} vs {g:?}")),
                    }
                } else {
                    let i = rng.gen_range(live.len() as u64) as usize;
                    pm.free(live.swap_remove(i));
                }
                pm.check_invariants()?;
            }
            Ok(())
        });
    }

    #[test]
    fn is_free_respects_rows() {
        let mut pm = PartitionManager::new(GEOM);
        let (_a, _) = pm.allocate_tile(64, 64).unwrap(); // top-left quadrant
        assert!(!pm.is_free(Tile::new(0, 0, 64, 64)));
        assert!(!pm.is_free(fh(0, 64)), "column straddles the allocated quadrant");
        assert!(pm.is_free(Tile::new(64, 0, 64, 64)), "below the quadrant");
        assert!(pm.is_free(fh(64, 64)), "right half is full-height free");
    }

    #[test]
    fn allocate_and_allocate_at_agree_on_left_carve() {
        // The dynamic policy rehearses with `allocate` on a clone and the
        // engine replays with `allocate_at`; both must produce the same
        // region layout.
        let mut a = PartitionManager::new(GEOM);
        let mut b = PartitionManager::new(GEOM);
        for w in [32u64, 64, 16] {
            let (_, ta) = a.allocate(w).unwrap();
            let (_, tb) = b.allocate_at(ta).unwrap();
            assert_eq!(ta, tb);
        }
        assert_eq!(a.free_widths(), b.free_widths());
        assert_eq!(a.widest_free(), b.widest_free());
        assert_eq!(a.free_tiles(), b.free_tiles());
    }

    #[test]
    fn allocate_tile_and_allocate_at_agree() {
        // Same rehearse/replay contract for the 2D path.
        let mut a = PartitionManager::new(GEOM);
        let mut b = PartitionManager::new(GEOM);
        for (h, w) in [(64u64, 32u64), (64, 96), (64, 64), (16, 16)] {
            let (_, ta) = a.allocate_tile(h, w).unwrap();
            let (_, tb) = b.allocate_at(ta).unwrap();
            assert_eq!(ta, tb);
        }
        assert_eq!(a.free_tiles(), b.free_tiles());
    }

    #[test]
    fn shrink_frees_the_remainder_in_place() {
        let mut pm = PartitionManager::new(GEOM);
        let (a, t) = pm.allocate(128).unwrap();
        assert_eq!(t, fh(0, 128));
        // Narrow the running tenant to its left 64 columns: the right
        // half frees (and is immediately allocatable), the allocation id
        // stays live on the kept tile.
        let released = pm.shrink(a, fh(0, 64));
        assert_eq!(released, 64 * 128);
        assert_eq!(pm.tile_of(a), Some(fh(0, 64)));
        assert_eq!(pm.free_widths(), vec![64]);
        let (b, tb) = pm.allocate(32).unwrap();
        assert_eq!(tb, fh(64, 32));
        // Shrinking to the current tile is a no-op.
        assert_eq!(pm.shrink(a, fh(0, 64)), 0);
        // 2D shrink: keep the top-left quadrant of the kept slice.
        let released = pm.shrink(a, Tile::new(0, 0, 64, 64));
        assert_eq!(released, 64 * 64);
        pm.check_invariants().unwrap();
        // Freeing the survivors restores the whole array.
        pm.free(a);
        pm.free(b);
        assert!(pm.fully_free());
    }

    #[test]
    #[should_panic(expected = "outside its tile")]
    fn shrink_rejects_tiles_outside_the_allocation() {
        let mut pm = PartitionManager::new(GEOM);
        let (a, _) = pm.allocate(32).unwrap();
        pm.shrink(a, fh(16, 32));
    }

    #[test]
    fn random_shrink_preserves_invariants() {
        prop::check("shrink invariants", 100, |rng| {
            let geom = ArrayGeometry::new(64, 128);
            let mut pm = PartitionManager::new(geom);
            let mut live: Vec<AllocId> = Vec::new();
            for _ in 0..48 {
                let roll = rng.gen_range(3);
                if live.is_empty() || roll == 0 {
                    let w = rng.gen_range_inclusive(1, 48);
                    if let Some((id, _)) = pm.allocate(w) {
                        live.push(id);
                    }
                } else if roll == 1 {
                    let i = rng.gen_range(live.len() as u64) as usize;
                    pm.free(live.swap_remove(i));
                } else {
                    let i = rng.gen_range(live.len() as u64) as usize;
                    let old = pm.tile_of(live[i]).unwrap();
                    let rows = rng.gen_range_inclusive(1, old.rows);
                    let cols = rng.gen_range_inclusive(1, old.cols);
                    let row0 = old.row0 + rng.gen_range_inclusive(0, old.rows - rows);
                    let col0 = old.col0 + rng.gen_range_inclusive(0, old.cols - cols);
                    let keep = Tile::new(row0, col0, rows, cols);
                    let released = pm.shrink(live[i], keep);
                    prop::ensure_eq(released, old.pes() - keep.pes(), "released PEs")?;
                    prop::ensure_eq(pm.tile_of(live[i]), Some(keep), "kept tile")?;
                }
                pm.check_invariants()?;
                let alloc_pes: u64 = live.iter().map(|&id| pm.tile_of(id).unwrap().pes()).sum();
                prop::ensure_eq(alloc_pes + pm.free_pes(), geom.pes(), "PE conservation")?;
            }
            for id in live {
                pm.free(id);
            }
            prop::ensure(pm.fully_free(), "all freed -> fully free")
        });
    }

    #[test]
    fn lane_manager_carve_merge_and_plan_key() {
        let mut lm = LaneManager::new(256);
        assert_eq!(lm.lanes(), 256);
        assert!(lm.fully_free());
        assert_eq!(lm.widest_free(), 256);
        let (n0, e0) = lm.plan_key();
        let (a, sa) = lm.allocate(64).unwrap();
        assert_eq!(sa, LaneSpan::new(0, 64));
        assert_eq!(lm.plan_key(), (n0, e0 + 1));
        let (b, sb) = lm.allocate(128).unwrap();
        assert_eq!(sb, LaneSpan::new(64, 128));
        assert_eq!(lm.free_lanes(), 64);
        assert_eq!(lm.allocated_count(), 2);
        assert_eq!(lm.span_of(a), Some(sa));
        // Oversized request fails without mutating (epoch unchanged).
        let key = lm.plan_key();
        assert!(lm.allocate(65).is_none());
        assert_eq!(lm.plan_key(), key);
        lm.free(a);
        // [0, 64) freed; widest span is now the left gap + nothing merged
        // with the tail yet (b occupies the middle).
        assert_eq!(lm.widest_free(), 64);
        lm.free(b);
        assert!(lm.fully_free());
        assert_eq!(lm.free_lanes(), 256);
    }

    #[test]
    fn lane_manager_rehearse_replay_parity() {
        // The policy rehearses on a clone with `allocate`; the engine
        // replays the returned spans with `allocate_at` — both must land
        // on the identical free set and plan key (the PR 9 cache
        // contract, carried to the second pool).
        let mut live = LaneManager::new(128);
        let mut rehearsal = live.clone();
        for w in [32u64, 64, 16] {
            let (_, span) = rehearsal.allocate(w).unwrap();
            let (_, replayed) = live.allocate_at(span).unwrap();
            assert_eq!(span, replayed);
        }
        assert_eq!(live.plan_key(), rehearsal.plan_key());
        assert_eq!(live.free_lanes(), rehearsal.free_lanes());
        assert_eq!(live.widest_free(), rehearsal.widest_free());
    }

    #[test]
    fn random_alloc_free_preserves_invariants() {
        // Full-height (columns-mode) random workload — the original 1D
        // property suite, ported; the 2D variant lives in
        // rust/tests/scheduler_properties.rs.
        prop::check("partition manager invariants", 200, |rng| {
            let cols = *rng.choose(&[16u64, 64, 128, 256]);
            let geom = ArrayGeometry::new(64, cols);
            let mut pm = PartitionManager::new(geom);
            let mut live: Vec<AllocId> = Vec::new();
            for _ in 0..64 {
                if live.is_empty() || rng.gen_bool(0.55) {
                    let w = rng.gen_range_inclusive(1, cols / 2);
                    if let Some((id, t)) = pm.allocate(w) {
                        prop::ensure_eq(t.cols, w, "allocated width")?;
                        prop::ensure(t.is_full_height(geom), "allocate stays full height")?;
                        live.push(id);
                    }
                } else {
                    let i = rng.gen_range(live.len() as u64) as usize;
                    pm.free(live.swap_remove(i));
                }
                pm.check_invariants()?;
                let alloc_cols: u64 =
                    live.iter().map(|&id| pm.tile_of(id).unwrap().cols).sum();
                prop::ensure_eq(alloc_cols + pm.free_cols(), cols, "conservation")?;
            }
            for id in live {
                pm.free(id);
                pm.check_invariants()?;
            }
            prop::ensure(pm.fully_free(), "all freed -> fully free")
        });
    }
}
