//! Arrival-driven, SLA-aware multi-tenant scenarios — the serving-side
//! evaluation dimension the paper lacks.
//!
//! The paper (§4) evaluates exactly two static workload mixes (Table 1's
//! heavy and light groups), all DNNs submitted at t=0.  A deployed
//! multi-tenant accelerator instead sees a *stream* of requests with
//! per-tenant latency targets: MoCA (arXiv 2305.05843) drives multi-tenant
//! accelerators from per-tenant QoS/latency targets, and "No DNN Left
//! Behind" (arXiv 1901.06887) frames cloud DNN inference as an
//! arrival-driven, SLO-bound serving problem.  This module adds both
//! dimensions on top of the unchanged Algorithm-1 scheduler:
//!
//! - [`ScenarioSpec`] + [`Scenario::generate`] — instantiate `requests`
//!   DNN instances (round-robin over a template list, e.g. a Table-1
//!   group) with arrivals drawn from an
//!   [`ArrivalProcess`](crate::workloads::generator::ArrivalProcess)
//!   (batch / Poisson / bursty / fixed trace) and an optional per-request
//!   deadline;
//! - QoS deadlines are *slack-relative*: `deadline = arrival +
//!   slack × isolated_latency`, where isolated latency is the DNN's
//!   full-array sequential runtime on the same geometry.  A slack of 1.0
//!   means "as fast as having the whole chip to yourself"; 3.0 is a
//!   typical soft-real-time budget.  Relative deadlines make one knob
//!   meaningful across DNNs whose runtimes span three orders of magnitude
//!   (NCF vs ResNet-50).
//! - [`Scenario::run`] — execute the scenario on the shared
//!   discrete-event engine ([`crate::sim_core::Engine`]) under **any**
//!   [`Scheduler`] policy, with each request's deadline wired in as an
//!   engine [`Deadline`](crate::sim_core::Event::Deadline) event;
//! - [`Scenario::analyze`] — score any scheduler's [`RunMetrics`] against
//!   the scenario: per-tenant latency percentiles (p50/p95/p99) and
//!   deadline-miss rates ([`TenantStats`]).
//!
//! Everything is deterministic from `ScenarioSpec::seed`, which the sweep
//! runner ([`crate::sweep`]) relies on for byte-identical reports.

use std::collections::BTreeMap;

use super::metrics::{DispatchRecord, RunMetrics, TenantStats};
use super::scheduler::SchedulerConfig;
use crate::sim::dataflow::baseline_layer_timing;
use crate::sim_core::{Engine, Observer, Scheduler};
use crate::util::rng::Rng;
use crate::workloads::dnng::{Dnn, DnnId, WorkloadPool};
use crate::workloads::generator::ArrivalProcess;

/// `arrival + ceil(slack × isolated)`, computed exactly.
///
/// The former `(slack * isolated_cycles as f64).ceil() as u64` lost
/// precision once `isolated_cycles` crossed 2^53 (f64's integer range)
/// and could land anywhere near the wrap on overflow.  Here `slack` is
/// decomposed into its exact binary value `mant × 2^exp` (53-bit
/// mantissa), the product `isolated × mant` is taken in u128 (≤ 117
/// bits, never overflows) and the exponent is applied as a ceiling
/// shift — the result is the true `ceil(slack × isolated)` of the f64
/// slack at any cycle count, and every overflow path saturates (an
/// absurd slack degrades to "never misses", not to a bogus early
/// deadline).
pub(crate) fn deadline_cycle(arrival: u64, isolated_cycles: u64, slack: f64) -> u64 {
    if isolated_cycles == 0 || slack <= 0.0 {
        return arrival;
    }
    if !slack.is_finite() {
        return u64::MAX;
    }
    // Exact decomposition: slack = mant × 2^exp (mant < 2^53).
    let bits = slack.to_bits();
    let raw_exp = ((bits >> 52) & 0x7FF) as i64;
    let frac = bits & ((1u64 << 52) - 1);
    let (mant, exp) =
        if raw_exp == 0 { (frac, -1074i64) } else { (frac | (1u64 << 52), raw_exp - 1075) };
    let product = isolated_cycles as u128 * mant as u128;
    let cycles = if exp >= 0 {
        if exp >= 128 {
            u128::MAX
        } else {
            product.saturating_mul(1u128 << exp)
        }
    } else {
        let shift = (-exp) as u32;
        if shift >= 128 {
            1 // ceil of a positive value below one cycle
        } else {
            product.saturating_add((1u128 << shift) - 1) >> shift
        }
    };
    arrival.saturating_add(cycles.min(u64::MAX as u128) as u64)
}

/// One request of a generated scenario: a DNN instance with its arrival
/// and (optional) absolute deadline.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Unique instance name (`"<tenant>#<i>"`) — the key into
    /// [`RunMetrics::completion`].
    pub instance: String,
    /// Tenant = the template (zoo model) this instance was cloned from.
    pub tenant: String,
    pub arrival: u64,
    /// Absolute deadline cycle; `None` = best-effort.
    pub deadline: Option<u64>,
    /// Full-array sequential latency of this DNN on the scenario geometry
    /// (the basis of the slack-relative deadline).
    pub isolated_cycles: u64,
}

/// Declarative description of a scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub arrival: ArrivalProcess,
    /// Number of DNN instances to draw (round-robin over the templates).
    pub requests: usize,
    /// Seed for the arrival process.
    pub seed: u64,
    /// Deadline slack factor (`deadline = arrival + slack × isolated`);
    /// `None` = best-effort (no deadlines).
    pub qos_slack: Option<f64>,
}

impl Default for ScenarioSpec {
    fn default() -> Self {
        ScenarioSpec {
            name: "scenario".to_string(),
            arrival: ArrivalProcess::Batch,
            requests: 8,
            seed: 42,
            qos_slack: Some(3.0),
        }
    }
}

/// A fully-instantiated scenario: the pool to schedule plus the request
/// metadata to score the run against.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub pool: WorkloadPool,
    /// One entry per pool DNN, in pool order.
    pub requests: Vec<Request>,
}

/// Per-tenant + overall outcome of one scheduler run on a scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioOutcome {
    /// Sorted by tenant name.
    pub tenants: Vec<TenantStats>,
    /// All requests pooled (tenant `"*"`).
    pub overall: TenantStats,
}

impl ScenarioOutcome {
    /// Overall deadline-miss rate (0.0 when nothing carried a deadline).
    pub fn miss_rate(&self) -> f64 {
        self.overall.miss_rate()
    }
}

/// Engine observer for scenario runs: collects the ordinary
/// [`RunMetrics`] plus the *live* deadline verdicts the engine's
/// [`Deadline`](crate::sim_core::Event::Deadline) events report — the
/// online view a serving controller would act on, cross-checked against
/// the post-hoc [`Scenario::analyze`] accounting in the tests.
#[derive(Debug, Clone, Default)]
pub struct ScenarioObserver {
    pub metrics: RunMetrics,
    /// `(dnn index, deadline cycle, met)` in event order.
    pub deadline_events: Vec<(DnnId, u64, bool)>,
}

impl Observer for ScenarioObserver {
    fn on_layer_complete(&mut self, rec: &DispatchRecord) {
        // Delegate to the canonical RunMetrics observer impl so scenario
        // metrics can never drift from the other execution paths.
        Observer::on_layer_complete(&mut self.metrics, rec);
    }

    fn on_preempt(&mut self, rec: &DispatchRecord, replayed_folds: u64, wasted_cycles: u64) {
        Observer::on_preempt(&mut self.metrics, rec, replayed_folds, wasted_cycles);
    }

    fn on_deadline(&mut self, dnn: DnnId, t: u64, met: bool) {
        self.deadline_events.push((dnn, t, met));
    }

    fn on_mem(&mut self, _dnn: DnnId, tenant: &str, stats: &crate::mem::MemStats) {
        self.metrics.record_mem(tenant, stats);
    }
}

impl Scenario {
    /// Instantiate a scenario from DNN templates.
    ///
    /// `cfg` supplies the geometry/buffers used for the isolated-latency
    /// basis of the deadlines; it should match the config the scenario
    /// will be run under.
    pub fn generate(templates: &[Dnn], spec: &ScenarioSpec, cfg: &SchedulerConfig) -> Scenario {
        assert!(!templates.is_empty(), "scenario needs at least one template DNN");
        assert!(spec.requests > 0, "scenario needs at least one request");
        let mut rng = Rng::new(spec.seed);
        let arrivals = spec.arrival.sample(&mut rng, spec.requests);

        // Isolated (full-array sequential) latency once per template, not
        // per request — requests round-robin over the same templates.
        let isolated: Vec<u64> = templates
            .iter()
            .map(|t| {
                t.layers
                    .iter()
                    .map(|l| baseline_layer_timing(cfg.geom, l.shape.gemm(), &cfg.buffers).cycles)
                    .sum()
            })
            .collect();

        let mut dnns = Vec::with_capacity(spec.requests);
        let mut requests = Vec::with_capacity(spec.requests);
        for (i, &arrival) in arrivals.iter().enumerate() {
            let template = &templates[i % templates.len()];
            let instance = format!("{}#{i}", template.name);
            let isolated_cycles = isolated[i % templates.len()];
            let deadline =
                spec.qos_slack.map(|slack| deadline_cycle(arrival, isolated_cycles, slack));

            let mut dnn = template.clone();
            dnn.name = instance.clone();
            dnn.arrival_cycles = arrival;
            dnns.push(dnn);
            requests.push(Request {
                instance,
                tenant: template.name.clone(),
                arrival,
                deadline,
                isolated_cycles,
            });
        }
        Scenario { name: spec.name.clone(), pool: WorkloadPool::new(&spec.name, dnns), requests }
    }

    /// The `(dnn index, absolute deadline)` pairs to attach to an engine
    /// run (request `i` is pool DNN `i` by construction).
    pub fn deadlines(&self) -> Vec<(DnnId, u64)> {
        self.requests
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.deadline.map(|d| (i, d)))
            .collect()
    }

    /// Execute this scenario on the shared engine under `sched` (any
    /// [`Scheduler`] policy), with request deadlines wired in as engine
    /// events, and score the result.  `geom` is the array geometry the
    /// policy expects (`cfg.geom`).
    ///
    /// Returns the full [`ScenarioObserver`] — `observer.metrics` is the
    /// ordinary [`RunMetrics`], `observer.deadline_events` the live
    /// verdicts — plus the post-hoc [`ScenarioOutcome`].
    pub fn run(
        &self,
        sched: &mut dyn Scheduler,
        geom: crate::sim::dataflow::ArrayGeometry,
    ) -> (ScenarioObserver, ScenarioOutcome) {
        let mut obs = ScenarioObserver::default();
        Engine::new(&self.pool, geom).with_deadlines(self.deadlines()).run(sched, &mut obs);
        let outcome = self.analyze(&obs.metrics);
        debug_assert_eq!(
            obs.deadline_events.iter().filter(|&&(_, _, met)| !met).count(),
            outcome.overall.misses,
            "live deadline verdicts must agree with the post-hoc accounting"
        );
        (obs, outcome)
    }

    /// Score a finished run (any scheduler that produced `RunMetrics` over
    /// this scenario's pool) against the per-request deadlines.
    pub fn analyze(&self, metrics: &RunMetrics) -> ScenarioOutcome {
        let mut by_tenant: BTreeMap<&str, Vec<(u64, u64, Option<u64>)>> = BTreeMap::new();
        let mut all = Vec::with_capacity(self.requests.len());
        for r in &self.requests {
            let done = *metrics
                .completion
                .get(&r.instance)
                .unwrap_or_else(|| panic!("run has no completion for {}", r.instance));
            let tuple = (r.arrival, done, r.deadline);
            by_tenant.entry(&r.tenant).or_default().push(tuple);
            all.push(tuple);
        }
        ScenarioOutcome {
            tenants: by_tenant
                .iter()
                .map(|(tenant, reqs)| TenantStats::from_requests(tenant, reqs))
                .collect(),
            overall: TenantStats::from_requests("*", &all),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::baseline::SequentialBaseline;
    use crate::coordinator::scheduler::DynamicScheduler;
    use crate::workloads::dnng::Layer;
    use crate::workloads::shapes::{LayerKind, LayerShape};

    fn templates() -> Vec<Dnn> {
        let mk = |name: &str, m: u64, n_layers: usize| {
            let layers = (0..n_layers)
                .map(|i| Layer::new(&format!("l{i}"), LayerKind::Fc, LayerShape::fc(64, 128, m)))
                .collect();
            Dnn::chain(name, layers)
        };
        vec![mk("wide", 256, 3), mk("narrow", 32, 2)]
    }

    #[test]
    fn generate_round_robins_templates_with_unique_names() {
        let spec = ScenarioSpec {
            requests: 5,
            arrival: ArrivalProcess::Poisson { mean_interarrival: 10_000.0 },
            ..Default::default()
        };
        let sc = Scenario::generate(&templates(), &spec, &SchedulerConfig::default());
        assert_eq!(sc.pool.dnns.len(), 5);
        assert_eq!(sc.requests.len(), 5);
        let names: Vec<&str> = sc.requests.iter().map(|r| r.instance.as_str()).collect();
        assert_eq!(names, vec!["wide#0", "narrow#1", "wide#2", "narrow#3", "wide#4"]);
        assert_eq!(sc.requests[1].tenant, "narrow");
        // Pool arrival times mirror the request metadata.
        for (d, r) in sc.pool.dnns.iter().zip(&sc.requests) {
            assert_eq!(d.arrival_cycles, r.arrival);
            assert_eq!(d.name, r.instance);
        }
    }

    #[test]
    fn deadlines_scale_with_isolated_latency() {
        let spec = ScenarioSpec { requests: 2, qos_slack: Some(2.0), ..Default::default() };
        let sc = Scenario::generate(&templates(), &spec, &SchedulerConfig::default());
        for r in &sc.requests {
            assert!(r.isolated_cycles > 0);
            assert_eq!(r.deadline, Some(r.arrival + 2 * r.isolated_cycles));
        }
        // The wide template takes longer in isolation than the narrow one.
        assert!(sc.requests[0].isolated_cycles > sc.requests[1].isolated_cycles);
    }

    #[test]
    fn deadline_math_is_exact_and_saturating_at_extreme_cycle_counts() {
        // 2^60 + 3 isolated cycles: f64 math would round the product to a
        // multiple of 256 and miss the true deadline by up to ±128.
        let iso = (1u64 << 60) + 3;
        assert_eq!(deadline_cycle(0, iso, 2.0), 2 * iso);
        assert_eq!(deadline_cycle(5, iso, 1.0), 5 + iso);
        assert_eq!(deadline_cycle(0, iso, 1.5), iso + iso / 2 + 1, "ceil of an odd half");
        // Products and sums beyond u64 saturate instead of wrapping.
        assert_eq!(deadline_cycle(0, u64::MAX, 4.0), u64::MAX);
        assert_eq!(deadline_cycle(u64::MAX - 10, 100, 1.0), u64::MAX);
        assert_eq!(deadline_cycle(7, u64::MAX, f64::MAX), u64::MAX);
        // Small values keep the old ceil behavior exactly.
        assert_eq!(deadline_cycle(0, 3, 1.5), 5);
        assert_eq!(deadline_cycle(10, 543, 3.0), 10 + 1629);
        assert_eq!(deadline_cycle(0, 0, 3.0), 0);
    }

    #[test]
    fn best_effort_has_no_deadlines() {
        let spec = ScenarioSpec { requests: 3, qos_slack: None, ..Default::default() };
        let sc = Scenario::generate(&templates(), &spec, &SchedulerConfig::default());
        assert!(sc.requests.iter().all(|r| r.deadline.is_none()));
        let m = DynamicScheduler::new(SchedulerConfig::default()).run(&sc.pool);
        let outcome = sc.analyze(&m);
        assert_eq!(outcome.overall.deadlines, 0);
        assert_eq!(outcome.miss_rate(), 0.0);
    }

    #[test]
    fn analyze_groups_by_tenant() {
        let spec = ScenarioSpec {
            requests: 6,
            arrival: ArrivalProcess::Poisson { mean_interarrival: 5_000.0 },
            qos_slack: Some(4.0),
            ..Default::default()
        };
        let sc = Scenario::generate(&templates(), &spec, &SchedulerConfig::default());
        let m = DynamicScheduler::new(SchedulerConfig::default()).run(&sc.pool);
        let outcome = sc.analyze(&m);
        assert_eq!(outcome.tenants.len(), 2);
        assert_eq!(outcome.tenants[0].tenant, "narrow");
        assert_eq!(outcome.tenants[1].tenant, "wide");
        assert_eq!(outcome.tenants.iter().map(|t| t.requests).sum::<usize>(), 6);
        assert_eq!(outcome.overall.requests, 6);
        for t in &outcome.tenants {
            assert!(t.p50_latency > 0.0);
            assert!(t.p50_latency <= t.p99_latency);
            assert!((0.0..=1.0).contains(&t.miss_rate()));
        }
    }

    #[test]
    fn generous_slack_is_never_missed_in_isolation() {
        // A single request with generous slack must always meet its
        // deadline: it has the array to itself.
        let spec = ScenarioSpec {
            requests: 1,
            qos_slack: Some(1.5),
            arrival: ArrivalProcess::Batch,
            ..Default::default()
        };
        let sc = Scenario::generate(&templates(), &spec, &SchedulerConfig::default());
        for m in [
            DynamicScheduler::new(SchedulerConfig::default()).run(&sc.pool),
            SequentialBaseline::new(SchedulerConfig::default()).run(&sc.pool),
        ] {
            let outcome = sc.analyze(&m);
            assert_eq!(outcome.overall.misses, 0, "lone request missed its deadline");
        }
    }

    #[test]
    fn run_matches_manual_engine_drive() {
        // Scenario::run == running the pool yourself + analyze: one
        // engine, one metrics pipeline, no scenario-private time loop.
        let spec = ScenarioSpec {
            requests: 6,
            arrival: ArrivalProcess::Poisson { mean_interarrival: 8_000.0 },
            qos_slack: Some(2.0),
            ..Default::default()
        };
        let cfg = SchedulerConfig::default();
        let sc = Scenario::generate(&templates(), &spec, &cfg);
        let (obs, outcome) = sc.run(&mut DynamicScheduler::new(cfg.clone()), cfg.geom);
        let manual = DynamicScheduler::new(cfg.clone()).run(&sc.pool);
        assert_eq!(obs.metrics.makespan, manual.makespan);
        assert_eq!(obs.metrics.dispatches, manual.dispatches);
        assert_eq!(outcome, sc.analyze(&manual));
        // The one-call path surfaces the live verdicts: one per deadline.
        assert_eq!(obs.deadline_events.len(), outcome.overall.deadlines);
    }

    #[test]
    fn live_deadline_events_agree_with_analyze() {
        // Tight slack under contention forces some misses; the engine's
        // live Deadline events must report exactly the analyze() verdicts.
        let spec = ScenarioSpec {
            requests: 8,
            arrival: ArrivalProcess::Batch,
            qos_slack: Some(1.05),
            ..Default::default()
        };
        let cfg = SchedulerConfig::default();
        let sc = Scenario::generate(&templates(), &spec, &cfg);
        let mut obs = ScenarioObserver::default();
        crate::sim_core::Engine::new(&sc.pool, cfg.geom)
            .with_deadlines(sc.deadlines())
            .run(&mut SequentialBaseline::new(cfg.clone()), &mut obs);
        let outcome = sc.analyze(&obs.metrics);
        assert_eq!(obs.deadline_events.len(), outcome.overall.deadlines);
        let live_misses = obs.deadline_events.iter().filter(|&&(_, _, met)| !met).count();
        assert_eq!(live_misses, outcome.overall.misses);
        assert!(live_misses > 0, "a batch of 8 at slack 1.05 must miss sequentially");
    }

    #[test]
    fn deterministic_generation() {
        let spec = ScenarioSpec {
            requests: 10,
            arrival: ArrivalProcess::Bursty {
                burst_size: 3,
                within_gap: 500.0,
                between_gap: 40_000.0,
            },
            ..Default::default()
        };
        let a = Scenario::generate(&templates(), &spec, &SchedulerConfig::default());
        let b = Scenario::generate(&templates(), &spec, &SchedulerConfig::default());
        assert_eq!(a.requests, b.requests);
    }
}
