//! The DNNG task queue — arrivals and ready-layer tracking.
//!
//! A layer is *ready* when its DNN has arrived, all its DAG predecessors
//! have completed, and it is neither running nor completed.  For the
//! chain-topology networks of the zoo this reduces to "the next layer",
//! but the tracker honors arbitrary forward edges.

use crate::workloads::dnng::{DnnId, LayerId, WorkloadPool};

/// Execution state of one layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LayerState {
    Waiting,
    Running,
    Done,
}

/// A ready-to-run layer reference with its sort key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReadyLayer {
    pub dnn: DnnId,
    pub layer: LayerId,
    /// `Opr(l)` — Eq. 2, the paper's priority key.
    pub opr: u64,
}

/// Tracks the execution state of every layer in a pool.
///
/// Ready-set maintenance is incremental (indegree counting over the DAG
/// edges) — `ready_at` is called at every scheduling point and a full
/// layers×edges rescan dominated the scheduler's profile (see
/// EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct TaskQueue<'a> {
    pool: &'a WorkloadPool,
    state: Vec<Vec<LayerState>>,
    /// Unsatisfied-predecessor counts.
    indeg: Vec<Vec<usize>>,
    /// Successor adjacency (from the edge lists, built once).
    succs: Vec<Vec<Vec<LayerId>>>,
    /// Layers with indeg 0 that are still Waiting (arrival NOT yet
    /// checked — `ready_at` filters by the DNN arrival time).
    frontier: Vec<(DnnId, LayerId)>,
    remaining: usize,
}

impl<'a> TaskQueue<'a> {
    pub fn new(pool: &'a WorkloadPool) -> TaskQueue<'a> {
        let state: Vec<Vec<LayerState>> =
            pool.dnns.iter().map(|d| vec![LayerState::Waiting; d.layers.len()]).collect();
        let mut indeg: Vec<Vec<usize>> =
            pool.dnns.iter().map(|d| vec![0; d.layers.len()]).collect();
        let mut succs: Vec<Vec<Vec<LayerId>>> =
            pool.dnns.iter().map(|d| vec![Vec::new(); d.layers.len()]).collect();
        let mut frontier = Vec::new();
        for (di, dnn) in pool.dnns.iter().enumerate() {
            for &(f, t) in &dnn.edges {
                indeg[di][t] += 1;
                succs[di][f].push(t);
            }
            for li in 0..dnn.layers.len() {
                if indeg[di][li] == 0 {
                    frontier.push((di, li));
                }
            }
        }
        let remaining = pool.total_layers();
        TaskQueue { pool, state, indeg, succs, frontier, remaining }
    }

    /// Layers runnable at time `now`, sorted by `Opr` descending (the
    /// paper's `Task_Assignment` order; ties broken by (dnn, layer) for
    /// determinism).
    pub fn ready_at(&self, now: u64) -> Vec<ReadyLayer> {
        let mut ready: Vec<ReadyLayer> = self
            .frontier
            .iter()
            .filter(|&&(di, li)| {
                self.pool.dnns[di].arrival_cycles <= now
                    && self.state[di][li] == LayerState::Waiting
            })
            .map(|&(di, li)| ReadyLayer {
                dnn: di,
                layer: li,
                opr: self.pool.dnns[di].layers[li].shape.opr(),
            })
            .collect();
        ready.sort_by(|a, b| b.opr.cmp(&a.opr).then(a.dnn.cmp(&b.dnn)).then(a.layer.cmp(&b.layer)));
        ready
    }

    /// Earliest future arrival after `now`, if any (for event scheduling).
    pub fn next_arrival_after(&self, now: u64) -> Option<u64> {
        self.pool
            .dnns
            .iter()
            .enumerate()
            .filter(|(di, d)| {
                d.arrival_cycles > now
                    && self.state[*di].iter().any(|s| *s == LayerState::Waiting)
            })
            .map(|(_, d)| d.arrival_cycles)
            .min()
    }

    pub fn mark_running(&mut self, dnn: DnnId, layer: LayerId) {
        assert_eq!(self.state[dnn][layer], LayerState::Waiting, "double dispatch of {dnn}/{layer}");
        self.state[dnn][layer] = LayerState::Running;
        // Drop from the frontier (swap_remove keeps ready_at O(frontier)).
        if let Some(pos) = self.frontier.iter().position(|&(d, l)| d == dnn && l == layer) {
            self.frontier.swap_remove(pos);
        }
    }

    /// Return a running layer to the ready set — a fold-boundary
    /// preemption drained it mid-layer.  Progress (completed K-bands) is
    /// the engine's ledger, not the queue's: here the layer simply
    /// becomes dispatchable again, with its DAG state untouched.
    pub fn mark_preempted(&mut self, dnn: DnnId, layer: LayerId) {
        assert_eq!(
            self.state[dnn][layer],
            LayerState::Running,
            "preempting non-running {dnn}/{layer}"
        );
        self.state[dnn][layer] = LayerState::Waiting;
        self.frontier.push((dnn, layer));
    }

    pub fn mark_done(&mut self, dnn: DnnId, layer: LayerId) {
        assert_eq!(self.state[dnn][layer], LayerState::Running, "completing non-running {dnn}/{layer}");
        self.state[dnn][layer] = LayerState::Done;
        self.remaining -= 1;
        // Release successors whose last unsatisfied predecessor this was.
        for si in 0..self.succs[dnn][layer].len() {
            let succ = self.succs[dnn][layer][si];
            self.indeg[dnn][succ] -= 1;
            if self.indeg[dnn][succ] == 0 {
                debug_assert_eq!(self.state[dnn][succ], LayerState::Waiting);
                self.frontier.push((dnn, succ));
            }
        }
    }

    /// Layers not yet done.
    pub fn remaining(&self) -> usize {
        self.remaining
    }

    pub fn all_done(&self) -> bool {
        self.remaining == 0
    }

    /// True when every layer of `dnn` is done.
    pub fn dnn_done(&self, dnn: DnnId) -> bool {
        self.state[dnn].iter().all(|s| *s == LayerState::Done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::dnng::{Dnn, Layer};
    use crate::workloads::shapes::{LayerKind, LayerShape};

    fn pool() -> WorkloadPool {
        let mk = |name: &str, sizes: &[u64], at: u64| {
            let layers = sizes
                .iter()
                .enumerate()
                .map(|(i, &m)| Layer::new(&format!("l{i}"), LayerKind::Fc, LayerShape::fc(1, 64, m)))
                .collect();
            Dnn::chain(name, layers).arriving_at(at)
        };
        WorkloadPool::new("t", vec![mk("a", &[100, 50], 0), mk("b", &[200], 10)])
    }

    #[test]
    fn only_first_chain_layer_ready() {
        let p = pool();
        let q = TaskQueue::new(&p);
        let r = q.ready_at(0);
        assert_eq!(r.len(), 1);
        assert_eq!((r[0].dnn, r[0].layer), (0, 0));
    }

    #[test]
    fn arrival_gating() {
        let p = pool();
        let q = TaskQueue::new(&p);
        assert_eq!(q.ready_at(9).len(), 1);
        let r10 = q.ready_at(10);
        assert_eq!(r10.len(), 2);
        // Sorted by Opr desc: b/l0 (m=200) before a/l0 (m=100).
        assert_eq!((r10[0].dnn, r10[0].layer), (1, 0));
        assert_eq!(q.next_arrival_after(0), Some(10));
        assert_eq!(q.next_arrival_after(10), None);
    }

    #[test]
    fn chain_progression() {
        let p = pool();
        let mut q = TaskQueue::new(&p);
        q.mark_running(0, 0);
        assert!(q.ready_at(0).is_empty(), "layer 1 blocked by running layer 0");
        q.mark_done(0, 0);
        let r = q.ready_at(0);
        assert_eq!((r[0].dnn, r[0].layer), (0, 1));
        assert!(!q.dnn_done(0));
        q.mark_running(0, 1);
        q.mark_done(0, 1);
        assert!(q.dnn_done(0));
        assert_eq!(q.remaining(), 1);
        assert!(!q.all_done());
    }

    #[test]
    fn preempted_layer_returns_to_ready() {
        let p = pool();
        let mut q = TaskQueue::new(&p);
        q.mark_running(0, 0);
        assert!(q.ready_at(0).is_empty());
        q.mark_preempted(0, 0);
        let r = q.ready_at(0);
        assert_eq!((r[0].dnn, r[0].layer), (0, 0), "preempted layer is ready again");
        assert_eq!(q.remaining(), 3, "preemption completes nothing");
        // The resumed segment runs and retires normally.
        q.mark_running(0, 0);
        q.mark_done(0, 0);
        assert_eq!(q.ready_at(0)[0].layer, 1, "successor released once");
    }

    #[test]
    #[should_panic(expected = "preempting non-running")]
    fn preempting_waiting_layer_panics() {
        let p = pool();
        let mut q = TaskQueue::new(&p);
        q.mark_preempted(0, 0);
    }

    #[test]
    #[should_panic(expected = "double dispatch")]
    fn double_dispatch_panics() {
        let p = pool();
        let mut q = TaskQueue::new(&p);
        q.mark_running(0, 0);
        q.mark_running(0, 0);
    }

    #[test]
    fn dag_predecessors_honored() {
        // Diamond: 0 -> {1, 2} -> 3.
        let layers = (0..4)
            .map(|i| Layer::new(&format!("l{i}"), LayerKind::Fc, LayerShape::fc(1, 8, 8 + i)))
            .collect();
        let mut d = Dnn::chain("diamond", layers);
        d.edges = vec![(0, 1), (0, 2), (1, 3), (2, 3)];
        let p = WorkloadPool::new("t", vec![d]);
        let mut q = TaskQueue::new(&p);
        q.mark_running(0, 0);
        q.mark_done(0, 0);
        let r = q.ready_at(0);
        assert_eq!(r.len(), 2, "both branches ready");
        q.mark_running(0, 1);
        q.mark_done(0, 1);
        assert!(q.ready_at(0).iter().all(|r| r.layer != 3), "join blocked on branch 2");
        q.mark_running(0, 2);
        q.mark_done(0, 2);
        assert_eq!(q.ready_at(0)[0].layer, 3);
    }
}
