//! The DNNG task queue — arrivals and ready-layer tracking.
//!
//! A layer is *ready* when its DNN has arrived, all its DAG predecessors
//! have completed, and it is neither running nor completed.  For the
//! chain-topology networks of the zoo this reduces to "the next layer",
//! but the tracker honors arbitrary forward edges.

use crate::workloads::dnng::{Dnn, DnnId, LayerId, WorkloadPool};

/// Execution state of one layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LayerState {
    Waiting,
    Running,
    Done,
}

/// A ready-to-run layer reference with its sort key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReadyLayer {
    pub dnn: DnnId,
    pub layer: LayerId,
    /// `Opr(l)` — Eq. 2, the paper's priority key.
    pub opr: u64,
}

/// Tracks the execution state of every layer in a pool.
///
/// Ready-set maintenance is incremental (indegree counting over the DAG
/// edges) — `ready_at` is called at every scheduling point and a full
/// layers×edges rescan dominated the scheduler's profile (see
/// EXPERIMENTS.md §Perf).
///
/// The queue copies the two pool facts it consults per decision point
/// (arrival cycles and per-layer `Opr` keys) instead of borrowing the
/// pool, so the engine can own a *mutable* pool: the fleet tier admits
/// new DNNs and recycles finished slots at runtime
/// ([`TaskQueue::reset_slot`] / [`TaskQueue::push_slot`]) without a
/// self-referential borrow.
#[derive(Debug, Clone)]
pub struct TaskQueue {
    /// Per-DNN arrival cycle `A_t` (copied from the pool).
    arrival: Vec<u64>,
    /// Per-layer `Opr` sort keys (copied from the pool).
    opr: Vec<Vec<u64>>,
    state: Vec<Vec<LayerState>>,
    /// Unsatisfied-predecessor counts.
    indeg: Vec<Vec<usize>>,
    /// Successor adjacency (from the edge lists, built once per slot).
    succs: Vec<Vec<Vec<LayerId>>>,
    /// Layers with indeg 0 that are still Waiting (arrival NOT yet
    /// checked — `ready_at` filters by the DNN arrival time).
    frontier: Vec<(DnnId, LayerId)>,
    remaining: usize,
}

impl TaskQueue {
    pub fn new(pool: &WorkloadPool) -> TaskQueue {
        let mut q = TaskQueue {
            arrival: Vec::new(),
            opr: Vec::new(),
            state: Vec::new(),
            indeg: Vec::new(),
            succs: Vec::new(),
            frontier: Vec::new(),
            remaining: 0,
        };
        for d in &pool.dnns {
            q.push_slot(d);
        }
        q
    }

    /// Append a fresh DNN slot (the fleet tier's admission path when no
    /// freed slot is available for reuse); returns its id.
    pub fn push_slot(&mut self, d: &Dnn) -> DnnId {
        let dnn = self.state.len();
        self.arrival.push(d.arrival_cycles);
        self.opr.push(d.layers.iter().map(|l| l.shape.opr()).collect());
        self.state.push(vec![LayerState::Waiting; d.layers.len()]);
        let (indeg, succs) = Self::dag_of(d);
        for (li, &deg) in indeg.iter().enumerate() {
            if deg == 0 {
                self.frontier.push((dnn, li));
            }
        }
        self.indeg.push(indeg);
        self.succs.push(succs);
        self.remaining += d.layers.len();
        dnn
    }

    /// Reload a *fully completed* slot with a new DNN, reusing its id —
    /// the fleet tier's slot recycling (peak state stays bounded by the
    /// live-tenant cap, not the arrival count).  Panics if any layer of
    /// the slot is still waiting or running.
    pub fn reset_slot(&mut self, dnn: DnnId, d: &Dnn) {
        assert!(
            self.state[dnn].iter().all(|s| *s == LayerState::Done),
            "recycling slot {dnn} with live layers"
        );
        self.frontier.retain(|&(di, _)| di != dnn);
        self.arrival[dnn] = d.arrival_cycles;
        self.opr[dnn] = d.layers.iter().map(|l| l.shape.opr()).collect();
        self.state[dnn] = vec![LayerState::Waiting; d.layers.len()];
        let (indeg, succs) = Self::dag_of(d);
        for (li, &deg) in indeg.iter().enumerate() {
            if deg == 0 {
                self.frontier.push((dnn, li));
            }
        }
        self.indeg[dnn] = indeg;
        self.succs[dnn] = succs;
        self.remaining += d.layers.len();
    }

    fn dag_of(d: &Dnn) -> (Vec<usize>, Vec<Vec<LayerId>>) {
        let mut indeg = vec![0usize; d.layers.len()];
        let mut succs = vec![Vec::new(); d.layers.len()];
        for &(f, t) in &d.edges {
            indeg[t] += 1;
            succs[f].push(t);
        }
        (indeg, succs)
    }

    /// Layers runnable at time `now`, sorted by `Opr` descending (the
    /// paper's `Task_Assignment` order; ties broken by (dnn, layer) for
    /// determinism).
    pub fn ready_at(&self, now: u64) -> Vec<ReadyLayer> {
        let mut ready = Vec::new();
        self.ready_into(now, &mut ready);
        ready
    }

    /// [`Self::ready_at`] into a caller-recycled buffer (cleared first) —
    /// the planner hot path calls this at every scheduling point, so the
    /// recycled form avoids one heap allocation per decision.
    pub fn ready_into(&self, now: u64, out: &mut Vec<ReadyLayer>) {
        out.clear();
        out.extend(
            self.frontier
                .iter()
                .filter(|&&(di, li)| {
                    self.arrival[di] <= now && self.state[di][li] == LayerState::Waiting
                })
                .map(|&(di, li)| ReadyLayer { dnn: di, layer: li, opr: self.opr[di][li] }),
        );
        out.sort_by(|a, b| b.opr.cmp(&a.opr).then(a.dnn.cmp(&b.dnn)).then(a.layer.cmp(&b.layer)));
    }

    /// Earliest future arrival after `now`, if any (for event scheduling).
    pub fn next_arrival_after(&self, now: u64) -> Option<u64> {
        self.arrival
            .iter()
            .enumerate()
            .filter(|(di, &at)| {
                at > now && self.state[*di].iter().any(|s| *s == LayerState::Waiting)
            })
            .map(|(_, &at)| at)
            .min()
    }

    pub fn mark_running(&mut self, dnn: DnnId, layer: LayerId) {
        assert_eq!(self.state[dnn][layer], LayerState::Waiting, "double dispatch of {dnn}/{layer}");
        self.state[dnn][layer] = LayerState::Running;
        // Drop from the frontier (swap_remove keeps ready_at O(frontier)).
        if let Some(pos) = self.frontier.iter().position(|&(d, l)| d == dnn && l == layer) {
            self.frontier.swap_remove(pos);
        }
    }

    /// Return a running layer to the ready set — a fold-boundary
    /// preemption drained it mid-layer.  Progress (completed K-bands) is
    /// the engine's ledger, not the queue's: here the layer simply
    /// becomes dispatchable again, with its DAG state untouched.
    pub fn mark_preempted(&mut self, dnn: DnnId, layer: LayerId) {
        assert_eq!(
            self.state[dnn][layer],
            LayerState::Running,
            "preempting non-running {dnn}/{layer}"
        );
        self.state[dnn][layer] = LayerState::Waiting;
        self.frontier.push((dnn, layer));
    }

    pub fn mark_done(&mut self, dnn: DnnId, layer: LayerId) {
        assert_eq!(self.state[dnn][layer], LayerState::Running, "completing non-running {dnn}/{layer}");
        self.state[dnn][layer] = LayerState::Done;
        self.remaining -= 1;
        // Release successors whose last unsatisfied predecessor this was.
        for si in 0..self.succs[dnn][layer].len() {
            let succ = self.succs[dnn][layer][si];
            self.indeg[dnn][succ] -= 1;
            if self.indeg[dnn][succ] == 0 {
                debug_assert_eq!(self.state[dnn][succ], LayerState::Waiting);
                self.frontier.push((dnn, succ));
            }
        }
    }

    /// Layers not yet done.
    pub fn remaining(&self) -> usize {
        self.remaining
    }

    pub fn all_done(&self) -> bool {
        self.remaining == 0
    }

    /// True when every layer of `dnn` is done.
    pub fn dnn_done(&self, dnn: DnnId) -> bool {
        self.state[dnn].iter().all(|s| *s == LayerState::Done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::dnng::{Dnn, Layer};
    use crate::workloads::shapes::{LayerKind, LayerShape};

    fn pool() -> WorkloadPool {
        let mk = |name: &str, sizes: &[u64], at: u64| {
            let layers = sizes
                .iter()
                .enumerate()
                .map(|(i, &m)| Layer::new(&format!("l{i}"), LayerKind::Fc, LayerShape::fc(1, 64, m)))
                .collect();
            Dnn::chain(name, layers).arriving_at(at)
        };
        WorkloadPool::new("t", vec![mk("a", &[100, 50], 0), mk("b", &[200], 10)])
    }

    #[test]
    fn only_first_chain_layer_ready() {
        let p = pool();
        let q = TaskQueue::new(&p);
        let r = q.ready_at(0);
        assert_eq!(r.len(), 1);
        assert_eq!((r[0].dnn, r[0].layer), (0, 0));
    }

    #[test]
    fn arrival_gating() {
        let p = pool();
        let q = TaskQueue::new(&p);
        assert_eq!(q.ready_at(9).len(), 1);
        let r10 = q.ready_at(10);
        assert_eq!(r10.len(), 2);
        // Sorted by Opr desc: b/l0 (m=200) before a/l0 (m=100).
        assert_eq!((r10[0].dnn, r10[0].layer), (1, 0));
        assert_eq!(q.next_arrival_after(0), Some(10));
        assert_eq!(q.next_arrival_after(10), None);
    }

    #[test]
    fn chain_progression() {
        let p = pool();
        let mut q = TaskQueue::new(&p);
        q.mark_running(0, 0);
        assert!(q.ready_at(0).is_empty(), "layer 1 blocked by running layer 0");
        q.mark_done(0, 0);
        let r = q.ready_at(0);
        assert_eq!((r[0].dnn, r[0].layer), (0, 1));
        assert!(!q.dnn_done(0));
        q.mark_running(0, 1);
        q.mark_done(0, 1);
        assert!(q.dnn_done(0));
        assert_eq!(q.remaining(), 1);
        assert!(!q.all_done());
    }

    #[test]
    fn preempted_layer_returns_to_ready() {
        let p = pool();
        let mut q = TaskQueue::new(&p);
        q.mark_running(0, 0);
        assert!(q.ready_at(0).is_empty());
        q.mark_preempted(0, 0);
        let r = q.ready_at(0);
        assert_eq!((r[0].dnn, r[0].layer), (0, 0), "preempted layer is ready again");
        assert_eq!(q.remaining(), 3, "preemption completes nothing");
        // The resumed segment runs and retires normally.
        q.mark_running(0, 0);
        q.mark_done(0, 0);
        assert_eq!(q.ready_at(0)[0].layer, 1, "successor released once");
    }

    #[test]
    #[should_panic(expected = "preempting non-running")]
    fn preempting_waiting_layer_panics() {
        let p = pool();
        let mut q = TaskQueue::new(&p);
        q.mark_preempted(0, 0);
    }

    #[test]
    #[should_panic(expected = "double dispatch")]
    fn double_dispatch_panics() {
        let p = pool();
        let mut q = TaskQueue::new(&p);
        q.mark_running(0, 0);
        q.mark_running(0, 0);
    }

    #[test]
    fn dag_predecessors_honored() {
        // Diamond: 0 -> {1, 2} -> 3.
        let layers = (0..4)
            .map(|i| Layer::new(&format!("l{i}"), LayerKind::Fc, LayerShape::fc(1, 8, 8 + i)))
            .collect();
        let mut d = Dnn::chain("diamond", layers);
        d.edges = vec![(0, 1), (0, 2), (1, 3), (2, 3)];
        let p = WorkloadPool::new("t", vec![d]);
        let mut q = TaskQueue::new(&p);
        q.mark_running(0, 0);
        q.mark_done(0, 0);
        let r = q.ready_at(0);
        assert_eq!(r.len(), 2, "both branches ready");
        q.mark_running(0, 1);
        q.mark_done(0, 1);
        assert!(q.ready_at(0).iter().all(|r| r.layer != 3), "join blocked on branch 2");
        q.mark_running(0, 2);
        q.mark_done(0, 2);
        assert_eq!(q.ready_at(0)[0].layer, 3);
    }

    #[test]
    fn slot_recycling_round_trips() {
        let p = pool();
        let mut q = TaskQueue::new(&p);
        // Retire dnn 0 entirely, then reload its slot with a fresh
        // two-layer DNN arriving later.
        q.mark_running(0, 0);
        q.mark_done(0, 0);
        q.mark_running(0, 1);
        q.mark_done(0, 1);
        assert!(q.dnn_done(0));
        let fresh = Dnn::chain(
            "fresh",
            vec![
                Layer::new("l0", LayerKind::Fc, LayerShape::fc(1, 64, 300)),
                Layer::new("l1", LayerKind::Fc, LayerShape::fc(1, 64, 10)),
            ],
        )
        .arriving_at(500);
        q.reset_slot(0, &fresh);
        assert!(!q.dnn_done(0));
        assert_eq!(q.remaining(), 3, "1 (dnn b) + 2 reloaded");
        assert!(q.ready_at(499).iter().all(|r| r.dnn != 0), "not arrived yet");
        let r = q.ready_at(500);
        assert_eq!((r[0].dnn, r[0].layer, r[0].opr), (0, 0, 64 * 300));
        assert_eq!(q.next_arrival_after(10), Some(500));
        // The reloaded chain runs to completion normally.
        q.mark_running(0, 0);
        q.mark_done(0, 0);
        q.mark_running(0, 1);
        q.mark_done(0, 1);
        assert!(q.dnn_done(0));
    }

    #[test]
    fn push_slot_appends_new_dnn() {
        let p = pool();
        let mut q = TaskQueue::new(&p);
        let extra = Dnn::chain(
            "extra",
            vec![Layer::new("l0", LayerKind::Fc, LayerShape::fc(1, 64, 400))],
        )
        .arriving_at(20);
        let id = q.push_slot(&extra);
        assert_eq!(id, 2);
        assert_eq!(q.remaining(), 4);
        let r = q.ready_at(20);
        assert_eq!((r[0].dnn, r[0].opr), (2, 64 * 400), "heaviest new layer sorts first");
    }

    #[test]
    #[should_panic(expected = "recycling slot")]
    fn recycling_live_slot_panics() {
        let p = pool();
        let mut q = TaskQueue::new(&p);
        let d = p.dnns[0].clone();
        q.reset_slot(0, &d);
    }
}
