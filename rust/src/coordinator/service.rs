//! The multi-tenant serving loop — scheduler decisions executed for real
//! on the PJRT runtime.
//!
//! Tenants submit GEMM work (`y = x·w`, one layer tile); the service groups
//! pending requests into co-resident sets, packs them into the vertical
//! partitions of one physical array step (`runtime::packing`), executes the
//! AOT `pws_p{P}` artifact fold-by-fold (chaining partial sums through
//! `acc` exactly like the cycle model's K-folds), and returns each
//! tenant's slice.  This is the datapath a deployed multi-tenant
//! accelerator would run — Python is never involved.
//!
//! Threading: a [`ServiceHandle`] fronts a worker thread with mpsc
//! channels; the synchronous core ([`Service::serve_group`]) is separately
//! usable (and tested) without threads.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::runtime::packing::{pack_step, pick_variant, TenantTile};
use crate::runtime::{Engine, Tensor};
use crate::util::ceil_div;

/// One tenant GEMM request: `y[sr, m] = x[sr, k] · w[k, m]`.
#[derive(Debug, Clone)]
pub struct GemmRequest {
    pub tenant: usize,
    pub x: Tensor,
    pub w: Tensor,
}

/// A served response.
#[derive(Debug)]
pub struct GemmResponse {
    pub tenant: usize,
    pub y: Tensor,
    /// Wall-clock service latency (grouping + PJRT execution).
    pub latency: Duration,
}

/// Synchronous serving core over a PJRT engine.
pub struct Service {
    engine: Arc<Engine>,
    array_s: usize,
    array_k: usize,
    array_c: usize,
    variants: Vec<usize>,
}

impl Service {
    pub fn new(engine: Arc<Engine>) -> Service {
        let m = engine.manifest();
        let (array_s, array_k, array_c) = (m.array_s, m.array_k, m.array_c);
        let variants = m.pws_partition_counts();
        Service { engine, array_s, array_k, array_c, variants }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Serve one co-resident group of requests in a single partitioned
    /// array residency (multiple K-fold steps chained through `acc`).
    ///
    /// Constraints per request (one array residency): `sr ≤ S`, and all
    /// tenants' output widths must fit the array side by side (`Σ m ≤ C`).
    /// Wider/taller layers are tiled by the caller (see `e2e_serve`).
    pub fn serve_group(&self, reqs: &[GemmRequest]) -> Result<Vec<Tensor>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let num_p = pick_variant(&self.variants, reqs.len())
            .with_context(|| format!("no pws variant for {} tenants", reqs.len()))?;

        // Validate and compute the shared fold count.
        let mut max_k = 0usize;
        let mut total_m = 0usize;
        for (i, r) in reqs.iter().enumerate() {
            let (sr, k) = (r.x.shape()[0], r.x.shape()[1]);
            let (k2, m) = (r.w.shape()[0], r.w.shape()[1]);
            if k != k2 {
                bail!("request {i}: K mismatch {k} vs {k2}");
            }
            if sr > self.array_s {
                bail!("request {i}: sr {sr} > array S {}", self.array_s);
            }
            max_k = max_k.max(k);
            total_m += m;
        }
        if total_m > self.array_c {
            bail!("group output width {total_m} > array C {}", self.array_c);
        }

        let folds = ceil_div(max_k as u64, self.array_k as u64) as usize;
        let mut acc = Tensor::zeros(vec![self.array_s, self.array_c]);
        let mut last_step = None;
        for f in 0..folds {
            let k0 = f * self.array_k;
            // Build each tenant's tile for this K-fold (empty range -> zero
            // tile: the tenant simply passes its acc through).
            let tiles: Vec<TenantTile> = reqs
                .iter()
                .map(|r| {
                    let k_total = r.x.shape()[1];
                    let k1 = (k0 + self.array_k).min(k_total);
                    let kw = k1.saturating_sub(k0);
                    let sr = r.x.shape()[0];
                    let m = r.w.shape()[1];
                    // Row-contiguous slicing (hot path; see EXPERIMENTS.md §Perf).
                    let x = if kw == 0 {
                        Tensor::zeros(vec![sr, 1])
                    } else {
                        let mut t = Tensor::zeros(vec![sr, kw]);
                        for row in 0..sr {
                            t.data_mut()[row * kw..(row + 1) * kw].copy_from_slice(
                                &r.x.data()[row * k_total + k0..row * k_total + k1],
                            );
                        }
                        t
                    };
                    let w = if kw == 0 {
                        Tensor::zeros(vec![1, m])
                    } else {
                        // Rows k0..k1 of r.w are contiguous.
                        Tensor::new(vec![kw, m], r.w.data()[k0 * m..k1 * m].to_vec())
                    };
                    TenantTile { tenant: r.tenant, x, w }
                })
                .collect();
            let step = pack_step(&tiles, self.array_s, self.array_k, self.array_c, num_p)?;
            acc = self.engine.execute(
                &format!("pws_p{num_p}"),
                &[step.x.clone(), step.w.clone(), step.mask.clone(), acc],
            )?;
            last_step = Some(step);
        }

        let step = last_step.expect("at least one fold");
        Ok((0..reqs.len()).map(|i| step.unpack(&acc, i)).collect())
    }
}

/// Commands accepted by the worker thread.
enum Command {
    Submit(GemmRequest, mpsc::Sender<Result<GemmResponse>>),
    Shutdown,
}

/// Handle to a running service worker.
pub struct ServiceHandle {
    tx: mpsc::Sender<Command>,
    worker: Option<thread::JoinHandle<()>>,
}

impl ServiceHandle {
    /// Spawn the worker.  `group_window` is how long the batcher waits to
    /// accumulate co-resident tenants before serving a partial group —
    /// the dynamic-batching knob.
    pub fn spawn(service: Service, max_group: usize, group_window: Duration) -> ServiceHandle {
        let (tx, rx) = mpsc::channel::<Command>();
        let worker = thread::spawn(move || {
            let mut pending: Vec<(GemmRequest, mpsc::Sender<Result<GemmResponse>>, Instant)> =
                Vec::new();
            loop {
                // Block for the first request; then drain the window.
                let first = if pending.is_empty() {
                    match rx.recv() {
                        Ok(cmd) => Some(cmd),
                        Err(_) => break,
                    }
                } else {
                    match rx.recv_timeout(group_window) {
                        Ok(cmd) => Some(cmd),
                        Err(mpsc::RecvTimeoutError::Timeout) => None,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                };
                match first {
                    Some(Command::Submit(req, resp_tx)) => {
                        pending.push((req, resp_tx, Instant::now()));
                        if pending.len() < max_group {
                            continue; // keep batching within the window
                        }
                    }
                    Some(Command::Shutdown) => {
                        Self::flush(&service, &mut pending);
                        break;
                    }
                    None => {} // window expired -> serve what we have
                }
                Self::flush(&service, &mut pending);
            }
        });
        ServiceHandle { tx, worker: Some(worker) }
    }

    fn flush(
        service: &Service,
        pending: &mut Vec<(GemmRequest, mpsc::Sender<Result<GemmResponse>>, Instant)>,
    ) {
        if pending.is_empty() {
            return;
        }
        let group: Vec<_> = pending.drain(..).collect();
        let reqs: Vec<GemmRequest> = group.iter().map(|(r, _, _)| r.clone()).collect();
        match service.serve_group(&reqs) {
            Ok(results) => {
                for ((req, tx, t0), y) in group.into_iter().zip(results) {
                    let _ = tx.send(Ok(GemmResponse {
                        tenant: req.tenant,
                        y,
                        latency: t0.elapsed(),
                    }));
                }
            }
            Err(e) => {
                for (_, tx, _) in group {
                    let _ = tx.send(Err(anyhow::anyhow!("group failed: {e:#}")));
                }
            }
        }
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: GemmRequest) -> mpsc::Receiver<Result<GemmResponse>> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Command::Submit(req, tx)).expect("worker alive");
        rx
    }

    /// Drain and stop the worker.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Command::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for ServiceHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Command::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

// Tests needing artifacts live in rust/tests/service_e2e.rs.
