//! The single-tenant sequential baseline (paper §4.3, "baseline systolic
//! array with no partitioning") as a [`Scheduler`] on the shared engine.
//!
//! DNNs execute one at a time in arrival order; every layer gets the whole
//! array.  This is what the paper's Fig. 9(a)(b)(e)(f) bars labelled
//! "baseline" measure.  The policy is the simplest possible `plan`: if the
//! array is idle, the next layer of the earliest-arriving unfinished DNN
//! takes all columns; otherwise wait.

use super::metrics::RunMetrics;
use super::queue::ReadyLayer;
use crate::sim::dataflow::baseline_layer_timing;
use crate::sim::partitioned::Tile;
use crate::sim_core::{Allocation, Engine, LayerExec, Scheduler, SystemState};
use crate::workloads::dnng::{DnnId, LayerId, WorkloadPool};

use super::scheduler::SchedulerConfig;

/// Sequential single-tenant policy.
#[derive(Debug, Clone)]
pub struct SequentialBaseline {
    cfg: SchedulerConfig,
    /// Recycled ready-layer scratch: `plan` runs once per event batch, so
    /// the buffer keeps its high-water capacity instead of reallocating.
    ready_buf: Vec<ReadyLayer>,
}

impl SequentialBaseline {
    pub fn new(cfg: SchedulerConfig) -> SequentialBaseline {
        SequentialBaseline { cfg, ready_buf: Vec::new() }
    }

    /// Run the pool on the shared engine: DNNs in arrival order, layers
    /// in chain order, full array each.
    pub fn run(&self, pool: &WorkloadPool) -> RunMetrics {
        Engine::execute(pool, self.cfg.geom, &mut self.clone())
    }
}

impl Scheduler for SequentialBaseline {
    fn name(&self) -> &'static str {
        "sequential"
    }

    fn mem_spec(&self) -> Option<crate::mem::MemSpec> {
        self.cfg.mem_spec()
    }

    fn plan(&mut self, s: &SystemState<'_>) -> Vec<Allocation> {
        // Strictly one layer at a time: wait for the array to drain.
        if !s.partitions.fully_free() {
            return Vec::new();
        }
        let mut ready = std::mem::take(&mut self.ready_buf);
        s.queue.ready_into(s.now, &mut ready);
        if ready.is_empty() {
            self.ready_buf = ready;
            return Vec::new();
        }
        // The earliest-arriving unfinished DNN holds the array; later
        // arrivals wait even if they are ready first (no work conservation
        // across the arrival order — exactly the paper's baseline).
        // Min by (arrival, index) == the pool's stable `by_arrival` order,
        // without re-sorting at every scheduling event.
        let mut current: Option<(u64, usize)> = None;
        for (di, d) in s.pool.dnns.iter().enumerate() {
            if s.queue.dnn_done(di) {
                continue;
            }
            let key = (d.arrival_cycles, di);
            if current.map(|c| key < c).unwrap_or(true) {
                current = Some(key);
            }
        }
        let out = match current {
            Some((_, di)) => match ready.iter().filter(|r| r.dnn == di).map(|r| r.layer).min() {
                Some(layer) => {
                    vec![Allocation::array(di, layer, Tile::full(self.cfg.geom))]
                }
                // Current DNN not arrived yet: idle until its arrival.
                None => Vec::new(),
            },
            None => Vec::new(),
        };
        self.ready_buf = ready;
        out
    }

    fn exec(
        &self,
        s: &SystemState<'_>,
        dnn: DnnId,
        layer: LayerId,
        _tile: Tile,
        _coresident: u64,
    ) -> LayerExec {
        let gemm = s.pool.dnns[dnn].layers[layer].shape.gemm();
        let t = baseline_layer_timing(self.cfg.geom, gemm, &self.cfg.buffers);
        let cycles = match &self.cfg.dram {
            Some(d) => d.bound_cycles(t.cycles, &t.activity),
            None => t.cycles,
        };
        LayerExec { cycles, activity: t.activity }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::dnng::{Dnn, Layer};
    use crate::workloads::shapes::{LayerKind, LayerShape};

    fn pool() -> WorkloadPool {
        let mk = |name: &str, n: usize, at: u64| {
            let layers = (0..n)
                .map(|i| Layer::new(&format!("l{i}"), LayerKind::Fc, LayerShape::fc(32, 128, 128)))
                .collect();
            Dnn::chain(name, layers).arriving_at(at)
        };
        WorkloadPool::new("t", vec![mk("a", 2, 0), mk("b", 1, 0)])
    }

    #[test]
    fn strictly_sequential() {
        let m = SequentialBaseline::new(SchedulerConfig::default()).run(&pool());
        assert_eq!(m.dispatches.len(), 3);
        for w in m.dispatches.windows(2) {
            assert_eq!(w[0].t_end, w[1].t_start, "no overlap, no gap");
        }
        // Every layer used the full array.
        assert!(m.dispatches.iter().all(|d| d.tile.cols == 128));
    }

    #[test]
    fn completion_order_is_arrival_order() {
        let m = SequentialBaseline::new(SchedulerConfig::default()).run(&pool());
        assert!(m.completion["a"] < m.completion["b"]);
        assert_eq!(m.makespan, m.completion["b"]);
    }

    #[test]
    fn waits_for_late_arrivals() {
        let mk = |at| {
            let l = vec![Layer::new("l0", LayerKind::Fc, LayerShape::fc(1, 8, 8))];
            Dnn::chain("x", l).arriving_at(at)
        };
        let p = WorkloadPool::new("t", vec![mk(10_000)]);
        let m = SequentialBaseline::new(SchedulerConfig::default()).run(&p);
        assert!(m.dispatches[0].t_start >= 10_000);
    }
}
