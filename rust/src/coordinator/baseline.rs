//! The single-tenant sequential baseline (paper §4.3, "baseline systolic
//! array with no partitioning").
//!
//! DNNs execute one at a time in arrival order; every layer gets the whole
//! array.  This is what the paper's Fig. 9(a)(b)(e)(f) bars labelled
//! "baseline" measure.

use super::metrics::{DispatchRecord, RunMetrics};
use super::scheduler::SchedulerConfig;
use crate::sim::dataflow::baseline_layer_timing;
use crate::sim::partitioned::PartitionSlice;
use crate::workloads::dnng::WorkloadPool;

/// Sequential single-tenant executor.
#[derive(Debug, Clone)]
pub struct SequentialBaseline {
    cfg: SchedulerConfig,
}

impl SequentialBaseline {
    pub fn new(cfg: SchedulerConfig) -> SequentialBaseline {
        SequentialBaseline { cfg }
    }

    /// Run the pool: DNNs in arrival order, layers in chain order, full
    /// array each.
    pub fn run(&self, pool: &WorkloadPool) -> RunMetrics {
        let cfg = &self.cfg;
        let mut metrics = RunMetrics::default();
        let mut now = 0u64;
        for dnn_id in pool.by_arrival() {
            let dnn = &pool.dnns[dnn_id];
            now = now.max(dnn.arrival_cycles);
            for (li, layer) in dnn.layers.iter().enumerate() {
                let t = baseline_layer_timing(cfg.geom, layer.shape.gemm(), &cfg.buffers);
                let cycles = match &cfg.dram {
                    Some(d) => d.bound_cycles(t.cycles, &t.activity),
                    None => t.cycles,
                };
                metrics.record_dispatch(DispatchRecord {
                    dnn: dnn_id,
                    dnn_name: dnn.name.clone(),
                    layer: li,
                    layer_name: layer.name.clone(),
                    slice: PartitionSlice::full(cfg.geom),
                    t_start: now,
                    t_end: now + cycles,
                    activity: t.activity,
                });
                now += cycles;
            }
        }
        metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::dnng::{Dnn, Layer};
    use crate::workloads::shapes::{LayerKind, LayerShape};

    fn pool() -> WorkloadPool {
        let mk = |name: &str, n: usize, at: u64| {
            let layers = (0..n)
                .map(|i| Layer::new(&format!("l{i}"), LayerKind::Fc, LayerShape::fc(32, 128, 128)))
                .collect();
            Dnn::chain(name, layers).arriving_at(at)
        };
        WorkloadPool::new("t", vec![mk("a", 2, 0), mk("b", 1, 0)])
    }

    #[test]
    fn strictly_sequential() {
        let m = SequentialBaseline::new(SchedulerConfig::default()).run(&pool());
        assert_eq!(m.dispatches.len(), 3);
        for w in m.dispatches.windows(2) {
            assert_eq!(w[0].t_end, w[1].t_start, "no overlap, no gap");
        }
        // Every layer used the full array.
        assert!(m.dispatches.iter().all(|d| d.slice.width == 128));
    }

    #[test]
    fn completion_order_is_arrival_order() {
        let m = SequentialBaseline::new(SchedulerConfig::default()).run(&pool());
        assert!(m.completion["a"] < m.completion["b"]);
        assert_eq!(m.makespan, m.completion["b"]);
    }

    #[test]
    fn waits_for_late_arrivals() {
        let mk = |at| {
            let l = vec![Layer::new("l0", LayerKind::Fc, LayerShape::fc(1, 8, 8))];
            Dnn::chain("x", l).arriving_at(at)
        };
        let p = WorkloadPool::new("t", vec![mk(10_000)]);
        let m = SequentialBaseline::new(SchedulerConfig::default()).run(&p);
        assert!(m.dispatches[0].t_start >= 10_000);
    }
}
