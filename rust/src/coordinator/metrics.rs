//! Run metrics — everything the paper's Fig. 9 plots need, plus the
//! serving-side view the scenario engine adds: per-tenant latency
//! percentiles, deadline misses ([`TenantStats`]) and time-sliced array
//! occupancy ([`RunMetrics::occupancy_timeline`]).

use std::collections::BTreeMap;

use crate::mem::MemStats;
use crate::sim::activity::Activity;
use crate::sim::dataflow::ArrayGeometry;
use crate::sim::partitioned::{LaneSpan, Tile};
use crate::util::stats::{deadline_misses, Summary};
use crate::workloads::dnng::{DnnId, LayerId};

/// One layer dispatch — a row of the Fig. 9(c)(d) detail plots.
///
/// `tile` is full-height in `columns` mode; 2D fission also records the
/// row band.
#[derive(Debug, Clone, PartialEq)]
pub struct DispatchRecord {
    pub dnn: DnnId,
    pub dnn_name: String,
    pub layer: LayerId,
    pub layer_name: String,
    pub tile: Tile,
    /// `Some(span)` when the layer ran on the vector engine instead of
    /// the systolic array (`tile` is then the span's 1-row shadow).
    pub lanes: Option<LaneSpan>,
    pub t_start: u64,
    pub t_end: u64,
    pub activity: Activity,
}

impl DispatchRecord {
    pub fn duration(&self) -> u64 {
        self.t_end - self.t_start
    }
}

/// Metrics of one complete run (one pool × one scheduler).
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// Total cycles until the last layer drains.
    pub makespan: u64,
    /// Per-DNN completion cycle (name → cycle).
    pub completion: BTreeMap<String, u64>,
    /// Per-DNN start cycle (first layer dispatch).
    pub start: BTreeMap<String, u64>,
    /// Full dispatch log, in dispatch order.
    pub dispatches: Vec<DispatchRecord>,
    /// Aggregate activity (for the energy estimator).
    pub total_activity: Activity,
    /// Per-tenant memory-hierarchy stats (name → stats); empty unless the
    /// run had `[mem]` enabled.
    pub mem: BTreeMap<String, MemStats>,
    /// All tenants pooled ([`RunMetrics::mem`] summed).
    pub mem_total: MemStats,
    /// Fold-boundary preemptions taken (0 unless a preempting policy
    /// ran); each adds one extra segment record to `dispatches`.
    pub preemptions: u64,
    /// M-folds the preempted remainders replay (partial-band work
    /// discarded at the boundaries).
    pub replayed_folds: u64,
    /// Cycles spent on folds that were later replayed — the total
    /// refill overhead preemption paid for its latency wins.
    pub wasted_refill_cycles: u64,
    /// Layers that ran on the vector engine (0 unless lanes are on).
    pub vector_dispatches: u64,
    /// Aggregate activity of vector-engine layers, kept out of
    /// [`RunMetrics::total_activity`] so array utilization and the
    /// array's energy bill stay array-only.
    pub vector_activity: Activity,
}

impl RunMetrics {
    /// Record a preempted segment: the drained `[t_start, boundary)`
    /// window enters the dispatch log (occupancy/energy accounting see
    /// it like any other residency) and the preemption counters grow.
    /// The layer's *final* segment arrives later via
    /// [`RunMetrics::record_dispatch`] and wins the completion map's max.
    pub fn record_preempt(&mut self, rec: DispatchRecord, replayed_folds: u64, wasted_cycles: u64) {
        self.preemptions += 1;
        self.replayed_folds += replayed_folds;
        self.wasted_refill_cycles += wasted_cycles;
        self.record_dispatch(rec);
    }
    /// Accumulate one layer's memory-side record under its tenant.
    pub fn record_mem(&mut self, tenant: &str, stats: &MemStats) {
        self.mem.entry(tenant.to_string()).or_default().add(stats);
        self.mem_total.add(stats);
    }

    pub fn record_dispatch(&mut self, rec: DispatchRecord) {
        self.start.entry(rec.dnn_name.clone()).or_insert(rec.t_start);
        let done = self.completion.entry(rec.dnn_name.clone()).or_insert(0);
        *done = (*done).max(rec.t_end);
        self.makespan = self.makespan.max(rec.t_end);
        if rec.lanes.is_some() {
            self.vector_dispatches += 1;
            self.vector_activity.add(&rec.activity);
        } else {
            self.total_activity.add(&rec.activity);
        }
        self.dispatches.push(rec);
    }

    /// Average PE utilization over the makespan: MACs / (makespan × PEs).
    pub fn utilization(&self, geom: ArrayGeometry) -> f64 {
        if self.makespan == 0 {
            return 0.0;
        }
        self.total_activity.macs as f64 / (self.makespan as f64 * geom.pes() as f64)
    }

    /// Partition widths used by a DNN, in dispatch order (Fig. 9(c)(d)).
    pub fn partition_trace(&self, dnn_name: &str) -> Vec<u64> {
        self.dispatches
            .iter()
            .filter(|d| d.dnn_name == dnn_name)
            .map(|d| d.tile.cols)
            .collect()
    }

    /// Tile shapes `(rows, cols)` used by a DNN, in dispatch order — the
    /// 2D-fission counterpart of [`RunMetrics::partition_trace`].
    pub fn partition_shapes(&self, dnn_name: &str) -> Vec<(u64, u64)> {
        self.dispatches
            .iter()
            .filter(|d| d.dnn_name == dnn_name)
            .map(|d| (d.tile.rows, d.tile.cols))
            .collect()
    }

    /// Distinct partition widths a DNN used, sorted.
    pub fn partition_widths(&self, dnn_name: &str) -> Vec<u64> {
        let mut w = self.partition_trace(dnn_name);
        w.sort_unstable();
        w.dedup();
        w
    }

    /// Time-sliced array occupancy: the makespan is cut into `buckets`
    /// equal windows and each window reports the fraction of PE-cycles
    /// covered by a live partition (1.0 = the whole array allocated for the
    /// whole window).  This is the utilization *timeline* behind the
    /// paper's Fig. 9(c)(d) residency plots — the scalar
    /// [`RunMetrics::utilization`] is MAC-based and hides when the array
    /// sat idle waiting for arrivals.
    pub fn occupancy_timeline(&self, geom: ArrayGeometry, buckets: usize) -> Vec<f64> {
        assert!(buckets > 0);
        if self.makespan == 0 {
            return vec![0.0; buckets];
        }
        let span = self.makespan as f64;
        let window = span / buckets as f64;
        let mut busy = vec![0.0f64; buckets]; // column-equivalent-cycles per window
        for d in &self.dispatches {
            if d.lanes.is_some() {
                continue; // vector-engine residency is not array occupancy
            }
            // Column-equivalents of the tile (== its width for full-height
            // tiles — both divisions are exact, keeping columns-mode
            // output bit-identical to the pre-2D accounting).
            let width_equiv = d.tile.pes() as f64 / geom.rows as f64;
            // Buckets this dispatch can overlap (u128: cycles × buckets can
            // exceed u64 on long runs).
            let b0 = (d.t_start as u128 * buckets as u128 / self.makespan as u128) as usize;
            let b1 = ((d.t_end - 1) as u128 * buckets as u128 / self.makespan as u128) as usize;
            for (b, slot) in busy.iter_mut().enumerate().take(b1.min(buckets - 1) + 1).skip(b0) {
                let w0 = window * b as f64;
                let w1 = window * (b + 1) as f64;
                let overlap = (d.t_end as f64).min(w1) - (d.t_start as f64).max(w0);
                if overlap > 0.0 {
                    *slot += overlap * width_equiv;
                }
            }
        }
        busy.into_iter().map(|b| b / (window * geom.cols as f64)).collect()
    }
}

/// Per-tenant serving statistics over a set of requests — the SLA view the
/// scenario engine reports: request-latency percentiles (arrival →
/// last-layer completion) and deadline misses.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantStats {
    pub tenant: String,
    /// Requests aggregated into this row.
    pub requests: usize,
    pub mean_latency: f64,
    pub p50_latency: f64,
    pub p95_latency: f64,
    pub p99_latency: f64,
    pub max_latency: f64,
    /// Requests that carried a deadline.
    pub deadlines: usize,
    /// Requests finishing strictly after their deadline.
    pub misses: usize,
}

impl TenantStats {
    /// Aggregate `(arrival, completion, deadline)` request tuples (cycles;
    /// deadline absolute).  Empty input yields an all-zero row.
    pub fn from_requests(tenant: &str, reqs: &[(u64, u64, Option<u64>)]) -> TenantStats {
        let latencies: Vec<f64> =
            reqs.iter().map(|&(arrival, done, _)| (done.saturating_sub(arrival)) as f64).collect();
        let s = Summary::from_samples(&latencies);
        let pairs: Vec<(u64, u64)> =
            reqs.iter().filter_map(|&(_, done, dl)| dl.map(|dl| (done, dl))).collect();
        let misses = deadline_misses(&pairs);
        TenantStats {
            tenant: tenant.to_string(),
            requests: reqs.len(),
            mean_latency: s.as_ref().map_or(0.0, |s| s.mean),
            p50_latency: s.as_ref().map_or(0.0, |s| s.p50),
            p95_latency: s.as_ref().map_or(0.0, |s| s.p95),
            p99_latency: s.as_ref().map_or(0.0, |s| s.p99),
            max_latency: s.as_ref().map_or(0.0, |s| s.max),
            deadlines: pairs.len(),
            misses,
        }
    }

    /// Deadline-miss rate over the requests that carried a deadline
    /// (0.0 when none did).
    pub fn miss_rate(&self) -> f64 {
        if self.deadlines == 0 {
            0.0
        } else {
            self.misses as f64 / self.deadlines as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GEOM: ArrayGeometry = ArrayGeometry { rows: 128, cols: 128 };

    fn rec(dnn: &str, layer: LayerId, width: u64, t0: u64, t1: u64) -> DispatchRecord {
        rec_tile(dnn, layer, Tile::new(0, 0, 128, width), t0, t1)
    }

    fn rec_tile(dnn: &str, layer: LayerId, tile: Tile, t0: u64, t1: u64) -> DispatchRecord {
        DispatchRecord {
            dnn: 0,
            dnn_name: dnn.to_string(),
            layer,
            layer_name: format!("l{layer}"),
            tile,
            lanes: None,
            t_start: t0,
            t_end: t1,
            activity: Activity { macs: 100, ..Default::default() },
        }
    }

    #[test]
    fn completion_tracks_max_end() {
        let mut m = RunMetrics::default();
        m.record_dispatch(rec("a", 0, 128, 0, 50));
        m.record_dispatch(rec("a", 1, 64, 50, 80));
        m.record_dispatch(rec("b", 0, 64, 10, 95));
        assert_eq!(m.makespan, 95);
        assert_eq!(m.completion["a"], 80);
        assert_eq!(m.completion["b"], 95);
        assert_eq!(m.start["a"], 0);
        assert_eq!(m.start["b"], 10);
        assert_eq!(m.total_activity.macs, 300);
    }

    #[test]
    fn partition_traces() {
        let mut m = RunMetrics::default();
        m.record_dispatch(rec("a", 0, 128, 0, 10));
        m.record_dispatch(rec("a", 1, 32, 10, 20));
        m.record_dispatch(rec("a", 2, 32, 20, 30));
        assert_eq!(m.partition_trace("a"), vec![128, 32, 32]);
        assert_eq!(m.partition_widths("a"), vec![32, 128]);
        assert!(m.partition_trace("nope").is_empty());
    }

    #[test]
    fn utilization_formula() {
        let mut m = RunMetrics::default();
        m.record_dispatch(rec("a", 0, 128, 0, 100));
        let geom = ArrayGeometry::new(10, 10);
        assert!((m.utilization(geom) - 100.0 / (100.0 * 100.0)).abs() < 1e-12);
    }

    #[test]
    fn occupancy_timeline_full_and_half() {
        // One full-width dispatch over the whole makespan: every bucket 1.0.
        let mut m = RunMetrics::default();
        m.record_dispatch(rec("a", 0, 128, 0, 1000));
        let tl = m.occupancy_timeline(GEOM, 4);
        assert_eq!(tl.len(), 4);
        for v in &tl {
            assert!((v - 1.0).abs() < 1e-9, "{tl:?}");
        }

        // Half-width dispatch in the first half only.
        let mut m = RunMetrics::default();
        m.record_dispatch(rec("a", 0, 64, 0, 500));
        m.record_dispatch(rec("a", 1, 128, 500, 1000)); // sets makespan=1000
        let tl = m.occupancy_timeline(GEOM, 2);
        assert!((tl[0] - 0.5).abs() < 1e-9, "{tl:?}");
        assert!((tl[1] - 1.0).abs() < 1e-9, "{tl:?}");
    }

    #[test]
    fn occupancy_counts_tiles_by_pe_footprint() {
        // A half-height full-width tile covers half the array; stacking a
        // second one in the other row band fills it.
        let mut m = RunMetrics::default();
        m.record_dispatch(rec_tile("a", 0, Tile::new(0, 0, 64, 128), 0, 1000));
        let tl = m.occupancy_timeline(GEOM, 2);
        assert!((tl[0] - 0.5).abs() < 1e-9, "{tl:?}");
        m.record_dispatch(rec_tile("b", 0, Tile::new(64, 0, 64, 128), 0, 1000));
        let tl = m.occupancy_timeline(GEOM, 2);
        assert!((tl[0] - 1.0).abs() < 1e-9, "{tl:?}");
    }

    #[test]
    fn partition_shapes_record_row_bands() {
        let mut m = RunMetrics::default();
        m.record_dispatch(rec_tile("a", 0, Tile::new(0, 0, 64, 32), 0, 10));
        m.record_dispatch(rec_tile("a", 1, Tile::new(32, 16, 96, 64), 10, 20));
        assert_eq!(m.partition_shapes("a"), vec![(64, 32), (96, 64)]);
        assert_eq!(m.partition_trace("a"), vec![32, 64]);
    }

    #[test]
    fn occupancy_timeline_empty_run() {
        let m = RunMetrics::default();
        assert_eq!(m.occupancy_timeline(GEOM, 3), vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn tenant_stats_latency_and_misses() {
        // Three requests: latencies 100, 200, 700; two carry deadlines and
        // one of those misses.
        let reqs = vec![
            (0u64, 100u64, Some(150u64)),  // hit
            (50, 250, Some(200)),          // miss (done 250 > 200)
            (100, 800, None),              // best-effort
        ];
        let s = TenantStats::from_requests("t", &reqs);
        assert_eq!(s.requests, 3);
        assert_eq!(s.deadlines, 2);
        assert_eq!(s.misses, 1);
        assert!((s.miss_rate() - 0.5).abs() < 1e-12);
        assert!((s.mean_latency - (100.0 + 200.0 + 700.0) / 3.0).abs() < 1e-9);
        assert_eq!(s.p50_latency, 200.0);
        assert_eq!(s.max_latency, 700.0);
        assert!(s.p50_latency <= s.p95_latency && s.p95_latency <= s.p99_latency);
        // Cross-check against the canonical util::stats definition.
        let pairs = [(250u64, 200u64), (100, 150)];
        assert!((crate::util::stats::deadline_miss_rate(&pairs) - s.miss_rate()).abs() < 1e-12);
    }

    #[test]
    fn record_preempt_counts_segments_and_waste() {
        let mut m = RunMetrics::default();
        assert_eq!((m.preemptions, m.replayed_folds, m.wasted_refill_cycles), (0, 0, 0));
        // A segment drains at cycle 40, the remainder finishes at 100:
        // the completion map must reflect the FINAL segment.
        m.record_preempt(rec("a", 0, 128, 0, 40), 2, 15);
        m.record_dispatch(rec("a", 0, 64, 40, 100));
        assert_eq!(m.preemptions, 1);
        assert_eq!(m.replayed_folds, 2);
        assert_eq!(m.wasted_refill_cycles, 15);
        assert_eq!(m.completion["a"], 100);
        assert_eq!(m.start["a"], 0);
        assert_eq!(m.dispatches.len(), 2, "segment + final record");
        assert_eq!(m.partition_trace("a"), vec![128, 64], "reshape visible in the trace");
    }

    #[test]
    fn record_mem_accumulates_per_tenant_and_total() {
        let mut m = RunMetrics::default();
        let s1 = MemStats { layers: 1, stall_cycles: 10, busy_cycles: 100, xfer_words: 500, ..Default::default() };
        let s2 = MemStats { layers: 1, stall_cycles: 30, busy_cycles: 100, xfer_words: 700, ..Default::default() };
        m.record_mem("a", &s1);
        m.record_mem("a", &s2);
        m.record_mem("b", &s2);
        assert_eq!(m.mem.len(), 2);
        assert_eq!(m.mem["a"].stall_cycles, 40);
        assert_eq!(m.mem["a"].layers, 2);
        assert_eq!(m.mem_total.xfer_words, 1900);
        assert_eq!(m.mem_total.layers, 3);
    }

    #[test]
    fn vector_records_stay_out_of_array_accounting() {
        let mut m = RunMetrics::default();
        m.record_dispatch(rec("a", 0, 128, 0, 500));
        let mut v = rec_tile("b", 0, Tile::new(0, 0, 1, 256), 0, 1000);
        v.lanes = Some(LaneSpan::new(0, 256));
        m.record_dispatch(v);
        assert_eq!(m.vector_dispatches, 1);
        assert_eq!(m.vector_activity.macs, 100);
        assert_eq!(m.total_activity.macs, 100, "array bill excludes the lane record");
        assert_eq!(m.makespan, 1000, "but the lane record still sets the makespan");
        assert_eq!(m.completion["b"], 1000);
        // Occupancy stays array-only: the second half (lane-only) is idle.
        let tl = m.occupancy_timeline(GEOM, 2);
        assert!((tl[1] - 0.0).abs() < 1e-9, "{tl:?}");
    }

    #[test]
    fn tenant_stats_empty() {
        let s = TenantStats::from_requests("t", &[]);
        assert_eq!(s.requests, 0);
        assert_eq!(s.miss_rate(), 0.0);
        assert_eq!(s.p99_latency, 0.0);
    }
}
