//! Run metrics — everything the paper's Fig. 9 plots need.

use std::collections::BTreeMap;

use crate::sim::activity::Activity;
use crate::sim::dataflow::ArrayGeometry;
use crate::sim::partitioned::PartitionSlice;
use crate::workloads::dnng::{DnnId, LayerId};

/// One layer dispatch — a row of the Fig. 9(c)(d) detail plots.
#[derive(Debug, Clone, PartialEq)]
pub struct DispatchRecord {
    pub dnn: DnnId,
    pub dnn_name: String,
    pub layer: LayerId,
    pub layer_name: String,
    pub slice: PartitionSlice,
    pub t_start: u64,
    pub t_end: u64,
    pub activity: Activity,
}

impl DispatchRecord {
    pub fn duration(&self) -> u64 {
        self.t_end - self.t_start
    }
}

/// Metrics of one complete run (one pool × one scheduler).
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// Total cycles until the last layer drains.
    pub makespan: u64,
    /// Per-DNN completion cycle (name → cycle).
    pub completion: BTreeMap<String, u64>,
    /// Per-DNN start cycle (first layer dispatch).
    pub start: BTreeMap<String, u64>,
    /// Full dispatch log, in dispatch order.
    pub dispatches: Vec<DispatchRecord>,
    /// Aggregate activity (for the energy estimator).
    pub total_activity: Activity,
}

impl RunMetrics {
    pub fn record_dispatch(&mut self, rec: DispatchRecord) {
        self.start.entry(rec.dnn_name.clone()).or_insert(rec.t_start);
        let done = self.completion.entry(rec.dnn_name.clone()).or_insert(0);
        *done = (*done).max(rec.t_end);
        self.makespan = self.makespan.max(rec.t_end);
        self.total_activity.add(&rec.activity);
        self.dispatches.push(rec);
    }

    /// Average PE utilization over the makespan: MACs / (makespan × PEs).
    pub fn utilization(&self, geom: ArrayGeometry) -> f64 {
        if self.makespan == 0 {
            return 0.0;
        }
        self.total_activity.macs as f64 / (self.makespan as f64 * geom.pes() as f64)
    }

    /// Partition widths used by a DNN, in dispatch order (Fig. 9(c)(d)).
    pub fn partition_trace(&self, dnn_name: &str) -> Vec<u64> {
        self.dispatches
            .iter()
            .filter(|d| d.dnn_name == dnn_name)
            .map(|d| d.slice.width)
            .collect()
    }

    /// Distinct partition widths a DNN used, sorted.
    pub fn partition_widths(&self, dnn_name: &str) -> Vec<u64> {
        let mut w = self.partition_trace(dnn_name);
        w.sort_unstable();
        w.dedup();
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(dnn: &str, layer: LayerId, width: u64, t0: u64, t1: u64) -> DispatchRecord {
        DispatchRecord {
            dnn: 0,
            dnn_name: dnn.to_string(),
            layer,
            layer_name: format!("l{layer}"),
            slice: PartitionSlice::new(0, width),
            t_start: t0,
            t_end: t1,
            activity: Activity { macs: 100, ..Default::default() },
        }
    }

    #[test]
    fn completion_tracks_max_end() {
        let mut m = RunMetrics::default();
        m.record_dispatch(rec("a", 0, 128, 0, 50));
        m.record_dispatch(rec("a", 1, 64, 50, 80));
        m.record_dispatch(rec("b", 0, 64, 10, 95));
        assert_eq!(m.makespan, 95);
        assert_eq!(m.completion["a"], 80);
        assert_eq!(m.completion["b"], 95);
        assert_eq!(m.start["a"], 0);
        assert_eq!(m.start["b"], 10);
        assert_eq!(m.total_activity.macs, 300);
    }

    #[test]
    fn partition_traces() {
        let mut m = RunMetrics::default();
        m.record_dispatch(rec("a", 0, 128, 0, 10));
        m.record_dispatch(rec("a", 1, 32, 10, 20));
        m.record_dispatch(rec("a", 2, 32, 20, 30));
        assert_eq!(m.partition_trace("a"), vec![128, 32, 32]);
        assert_eq!(m.partition_widths("a"), vec![32, 128]);
        assert!(m.partition_trace("nope").is_empty());
    }

    #[test]
    fn utilization_formula() {
        let mut m = RunMetrics::default();
        m.record_dispatch(rec("a", 0, 128, 0, 100));
        let geom = ArrayGeometry::new(10, 10);
        assert!((m.utilization(geom) - 100.0 / (100.0 * 100.0)).abs() < 1e-12);
    }
}
