//! The discrete-event simulation core — ONE engine behind `mtsa run`, the
//! scenario engine and the sweep runner.
//!
//! Before this module existed, every executor (`DynamicScheduler`, the
//! sequential baseline, static partitioning, the multi-array comparator)
//! fused three concerns into one private batch loop: *policy* (who gets
//! which columns), *clock advancement* (when does the world change) and
//! *metrics accumulation* (what happened).  MoCA (arXiv 2305.05843) and
//! the systolic-vector scheduling exploration (arXiv 2206.03060) both show
//! that the interesting design space is policies plugged into a shared
//! event-driven core; this module adopts that shape:
//!
//! - [`Event`] — the event kinds a multi-tenant accelerator sees:
//!   DNN [`Event::Arrival`], [`Event::LayerComplete`], a fold-boundary
//!   [`Event::Preempt`] (a running layer drains mid-layer so an arrival
//!   can reclaim its PEs — see `docs/preemption.md`), a scheduled
//!   [`Event::Repartition`] wake-up, a QoS [`Event::Deadline`], and —
//!   when the shared memory hierarchy ([`crate::mem`]) is enabled — the
//!   engine-internal [`Event::MemRescale`] bandwidth-release point.
//!   Ordering is total and deterministic: `(time, kind, dnn, layer)`.
//! - [`Scheduler`] — the policy trait.  Decision-point hooks
//!   ([`Scheduler::on_arrival`], [`Scheduler::on_layer_complete`], …) let
//!   a policy maintain internal state; [`Scheduler::plan`] maps the
//!   observable [`SystemState`] to concrete [`Allocation`]s; and
//!   [`Scheduler::exec`] prices one layer on its
//!   [`Tile`](crate::sim::partitioned::Tile) (this is where
//!   [`tile_layer_timing`](crate::sim::partitioned::tile_layer_timing)
//!   feeds event durations).
//! - [`Observer`] — metrics collection, decoupled from both policy and
//!   clock.  [`RunMetrics`](crate::coordinator::metrics::RunMetrics)
//!   implements it directly, so every execution path collects metrics
//!   identically.
//! - [`Engine`] — owns the event queue, the
//!   [`TaskQueue`](crate::coordinator::queue::TaskQueue) (DAG-aware
//!   ready-layer tracking) and the
//!   [`PartitionManager`](crate::coordinator::partition::PartitionManager)
//!   (column tiling with merge-on-free), pops event batches, invokes the
//!   policy, and applies its allocations.
//!
//! All four legacy policies are ports onto this trait (see
//! [`crate::coordinator`]), and `rust/tests/engine_parity.rs` pins the
//! dynamic policy bit-for-bit against the pre-refactor batch loop.
//! `docs/architecture.md` is the narrative version of this design.

mod engine;
mod event;
mod observer;
pub mod queue;
mod scheduler;

pub use engine::{event_coalesce_enabled, obs_ring_enabled, Engine};
pub use event::Event;
pub use observer::Observer;
pub use scheduler::{Allocation, Checkpoint, LayerExec, RunningLayer, Scheduler, SystemState};
