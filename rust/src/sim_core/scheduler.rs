//! The `Scheduler` policy trait and the state view it decides over.

use crate::coordinator::partition::PartitionManager;
use crate::coordinator::queue::TaskQueue;
use crate::mem::{MemFeedback, MemSpec};
use crate::sim::activity::Activity;
use crate::sim::partitioned::Tile;
use crate::workloads::dnng::{DnnId, LayerId, WorkloadPool};

/// Read-only view of the world a policy decides over: the current cycle,
/// the workload pool, layer progress (ready set, per-DNN completion), the
/// live rectangle tiling, and — when the shared memory hierarchy is
/// enabled — the arbiter's per-tenant feedback.
///
/// A policy that needs to try out allocations before committing (the
/// dynamic policy's heaviest-first carving does) clones `partitions` and
/// rehearses on the clone; the engine then applies the returned
/// [`Allocation`]s to the real manager at the exact proposed positions.
pub struct SystemState<'e> {
    pub now: u64,
    pub pool: &'e WorkloadPool,
    pub queue: &'e TaskQueue<'e>,
    pub partitions: &'e PartitionManager,
    /// Live memory-system feedback (stall fractions, in-flight
    /// memory-bound layers); `None` when `[mem]` is disabled.
    pub mem: Option<&'e MemFeedback>,
}

/// One scheduling decision: run `(dnn, layer)` on `tile` starting now.
///
/// The tile must lie inside a currently-free region — the engine carves
/// it with [`PartitionManager::allocate_at`] and panics on overlap, so a
/// buggy policy fails loudly instead of silently double-booking PEs.
/// Columns-mode policies always propose full-height tiles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Allocation {
    pub dnn: DnnId,
    pub layer: LayerId,
    pub tile: Tile,
}

/// Execution price of one layer on one slice: how long the
/// [`LayerComplete`](super::Event::LayerComplete) event is scheduled out,
/// and the component activity billed to the energy model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerExec {
    pub cycles: u64,
    pub activity: Activity,
}

/// A partitioning policy plugged into the [`Engine`](super::Engine).
///
/// The engine calls, per event batch: the `on_*` hooks for each popped
/// event, then [`Scheduler::plan`] once over the settled state, then
/// [`Scheduler::exec`] for each returned allocation (in order, so a
/// policy can price later allocations against earlier co-residents), then
/// [`Scheduler::wake_after`].  All methods are deterministic functions of
/// their inputs plus the policy's own state — the engine adds no hidden
/// randomness, which is what keeps fixed-seed sweeps byte-identical
/// across thread counts.
pub trait Scheduler {
    /// Stable display name (report/CLI tag).
    fn name(&self) -> &'static str;

    /// The shared memory hierarchy this policy expects the engine to
    /// simulate (`None`, the default, keeps today's isolated DRAM
    /// pricing inside [`Scheduler::exec`]).  When `Some`, the engine
    /// instantiates a [`MemSystem`](crate::mem::MemSystem): layer DRAM
    /// traffic is re-priced under the banked buffer share, the interface
    /// is arbitrated among co-runners, and completions rescale as the
    /// co-runner set changes — so `exec` must return *compute* cycles
    /// only (a policy must not carry both `dram` and `mem` configs).
    /// Queried once per [`Engine::run`](super::Engine::run).
    fn mem_spec(&self) -> Option<MemSpec> {
        None
    }

    /// A DNN just arrived (its layers may now appear in the ready set).
    fn on_arrival(&mut self, _state: &SystemState<'_>, _dnn: DnnId) {}

    /// A layer just retired (its columns are already freed and merged).
    fn on_layer_complete(&mut self, _state: &SystemState<'_>, _dnn: DnnId, _layer: LayerId) {}

    /// A request's deadline just passed; `met` is whether it had finished.
    fn on_deadline(&mut self, _state: &SystemState<'_>, _dnn: DnnId, _met: bool) {}

    /// Opt in to a [`Scheduler::plan`] call after deadline events.
    ///
    /// Defaults to `false`: a deadline changes neither the ready set nor
    /// the tiling, so for a policy whose decisions are a pure function of
    /// [`SystemState`] (all four shipped policies) replanning there can
    /// only repeat the previous decision.  A *stateful* SLA-aware policy
    /// that reacts in [`Scheduler::on_deadline`] (boosting a tenant,
    /// releasing deferred work) returns `true` so its reaction takes
    /// effect at deadline time instead of at the next unrelated event.
    fn plan_on_deadline(&self) -> bool {
        false
    }

    /// A wake-up previously requested via [`Scheduler::wake_after`] fired.
    fn on_repartition(&mut self, _state: &SystemState<'_>) {}

    /// Map the current state to zero or more dispatches.  Returning an
    /// empty vector means "wait" — the engine will call again at the next
    /// event.
    fn plan(&mut self, state: &SystemState<'_>) -> Vec<Allocation>;

    /// Price one planned layer: cycles until completion and the activity
    /// to bill.  `coresident` counts live partitions *including* this one
    /// at dispatch (feeds the interleaved feed-bus model).
    fn exec(
        &self,
        state: &SystemState<'_>,
        dnn: DnnId,
        layer: LayerId,
        tile: Tile,
        coresident: u64,
    ) -> LayerExec;

    /// Request a [`Repartition`](super::Event::Repartition) wake-up this
    /// many cycles from now (`None` = none).  Called once after each
    /// plan/dispatch round.
    fn wake_after(&mut self, _state: &SystemState<'_>) -> Option<u64> {
        None
    }
}
