//! The `Scheduler` policy trait and the state view it decides over.

use std::collections::BTreeMap;

use crate::coordinator::partition::{AllocId, LaneManager, PartitionManager};
use crate::coordinator::queue::TaskQueue;
use crate::mem::{MemFeedback, MemSpec};
use crate::sim::activity::Activity;
use crate::sim::dataflow::VectorUnit;
use crate::sim::partitioned::{LaneSpan, Tile};
use crate::workloads::dnng::{DnnId, LayerId, WorkloadPool};
use crate::workloads::shapes::GemmDims;

/// Read-only view of the world a policy decides over: the current cycle,
/// the workload pool, layer progress (ready set, per-DNN completion), the
/// live rectangle tiling, and — when the shared memory hierarchy is
/// enabled — the arbiter's per-tenant feedback.
///
/// A policy that needs to try out allocations before committing (the
/// dynamic policy's heaviest-first carving does) clones `partitions` and
/// rehearses on the clone; the engine then applies the returned
/// [`Allocation`]s to the real manager at the exact proposed positions.
pub struct SystemState<'e> {
    pub now: u64,
    pub pool: &'e WorkloadPool,
    pub queue: &'e TaskQueue,
    pub partitions: &'e PartitionManager,
    /// Live memory-system feedback (stall fractions, in-flight
    /// memory-bound layers); `None` when `[mem]` is disabled.
    pub mem: Option<&'e MemFeedback>,
    /// The vector-lane pool; `None` unless the policy declared a vector
    /// engine via [`Scheduler::vector_spec`].  Policies rehearse lane
    /// carving on a clone exactly like `partitions`.
    pub lanes: Option<&'e LaneManager>,
    /// K rows already completed per `(dnn, layer)` by earlier preempted
    /// segments — empty unless a preempting policy ran.  A policy that
    /// supports preemption prices the *remaining* GEMM (`k -
    /// k_done`) in [`Scheduler::plan`]/[`Scheduler::exec`].
    pub progress: &'e BTreeMap<(DnnId, LayerId), u64>,
}

impl SystemState<'_> {
    /// K rows of `(dnn, layer)` completed by earlier preempted segments
    /// (0 for layers that were never preempted).
    pub fn k_done(&self, dnn: DnnId, layer: LayerId) -> u64 {
        self.progress.get(&(dnn, layer)).copied().unwrap_or(0)
    }

    /// The GEMM still to execute for `(dnn, layer)`: the full lowered
    /// shape minus the [`SystemState::k_done`] rows (clamped so at least
    /// one K row remains).  THE one formula for remainder sizing — the
    /// engine prices a remainder's DRAM traffic with it and a preempting
    /// policy must price its compute the same way, or words and cycles
    /// desynchronize.  Identical to the full shape when nothing was
    /// preempted.
    pub fn remaining_gemm(&self, dnn: DnnId, layer: LayerId) -> GemmDims {
        let mut gemm = self.pool.dnns[dnn].layers[layer].shape.gemm();
        gemm.k -= self.k_done(dnn, layer).min(gemm.k - 1);
        gemm
    }
}

/// An in-flight layer as the engine shows it to
/// [`Scheduler::preempt`]: where it runs and when it is scheduled to
/// finish (`t_end` is the currently live completion prediction).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunningLayer {
    pub alloc: AllocId,
    pub dnn: DnnId,
    pub layer: LayerId,
    pub tile: Tile,
    pub t_start: u64,
    /// Currently scheduled completion cycle (`u64::MAX` when a starved
    /// strict-priority transfer has no live prediction).
    pub t_end: u64,
}

/// A preemption checkpoint located by [`Scheduler::checkpoint`]: where
/// the running segment's next fold boundary falls and what the segment
/// will have completed when it drains there.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Checkpoint {
    /// Wall cycles from the segment's dispatch to the fold boundary.
    pub boundary: u64,
    /// K rows of the layer's GEMM this segment completes by the boundary
    /// (complete K-bands only); the engine credits them to
    /// [`SystemState::k_done`] and the remainder resumes from there.
    pub k_advance: u64,
    /// M-folds of the trailing partial band the remainder replays.
    pub replayed_folds: u64,
    /// Wall cycles the segment spent on folds that will be replayed —
    /// the preemption's wasted refill (reported per run).
    pub wasted_cycles: u64,
    /// Activity the segment actually completed (billed to its record;
    /// the replayed folds' traffic is re-billed by the remainder).
    pub activity: Activity,
    /// What happens to the remainder at the boundary.  `Some(keep)`:
    /// **shrink in place** — the layer keeps running, re-priced on
    /// `keep` (a sub-tile of its running tile), and only the rest of the
    /// tile frees; the policy never has to win the next plan to make
    /// progress.  `None`: **evict** — the whole tile frees and the
    /// remainder returns to the ready set carrying its progress.
    pub keep: Option<Tile>,
}

/// One scheduling decision: run `(dnn, layer)` on `tile` starting now.
///
/// The tile must lie inside a currently-free region — the engine carves
/// it with [`PartitionManager::allocate_at`] and panics on overlap, so a
/// buggy policy fails loudly instead of silently double-booking PEs.
/// Columns-mode policies always propose full-height tiles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Allocation {
    pub dnn: DnnId,
    pub layer: LayerId,
    pub tile: Tile,
    /// `Some(span)`: this dispatch targets the vector lanes, not the
    /// array — the engine carves `span` from the lane pool, prices it
    /// via [`Scheduler::exec_vector`], and `tile` is the span's 1-row
    /// shadow ([`LaneSpan::as_tile`]) kept for uniform records.  `None`:
    /// a normal array dispatch.
    pub lanes: Option<LaneSpan>,
}

impl Allocation {
    /// An array dispatch — the shape every pre-heterogeneous policy emits.
    pub fn array(dnn: DnnId, layer: LayerId, tile: Tile) -> Allocation {
        Allocation { dnn, layer, tile, lanes: None }
    }

    /// A vector-lane dispatch.
    pub fn vector(dnn: DnnId, layer: LayerId, span: LaneSpan) -> Allocation {
        Allocation { dnn, layer, tile: span.as_tile(), lanes: Some(span) }
    }
}

/// Execution price of one layer on one slice: how long the
/// [`LayerComplete`](super::Event::LayerComplete) event is scheduled out,
/// and the component activity billed to the energy model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerExec {
    pub cycles: u64,
    pub activity: Activity,
}

/// A partitioning policy plugged into the [`Engine`](super::Engine).
///
/// The engine calls, per event batch: the `on_*` hooks for each popped
/// event, then [`Scheduler::plan`] once over the settled state, then
/// [`Scheduler::exec`] for each returned allocation (in order, so a
/// policy can price later allocations against earlier co-residents), then
/// [`Scheduler::wake_after`].  All methods are deterministic functions of
/// their inputs plus the policy's own state — the engine adds no hidden
/// randomness, which is what keeps fixed-seed sweeps byte-identical
/// across thread counts.
pub trait Scheduler {
    /// Stable display name (report/CLI tag).
    fn name(&self) -> &'static str;

    /// The shared memory hierarchy this policy expects the engine to
    /// simulate (`None`, the default, keeps today's isolated DRAM
    /// pricing inside [`Scheduler::exec`]).  When `Some`, the engine
    /// instantiates a [`MemSystem`](crate::mem::MemSystem): layer DRAM
    /// traffic is re-priced under the banked buffer share, the interface
    /// is arbitrated among co-runners, and completions rescale as the
    /// co-runner set changes — so `exec` must return *compute* cycles
    /// only (a policy must not carry both `dram` and `mem` configs).
    /// Queried once per [`Engine::run`](super::Engine::run).
    fn mem_spec(&self) -> Option<MemSpec> {
        None
    }

    /// The vector engine this policy schedules onto (`None`, the
    /// default, is the pure-array machine — byte-identical to the
    /// pre-heterogeneous model).  When `Some`, the engine instantiates a
    /// [`LaneManager`] over its lanes as a second allocation pool and
    /// accepts [`Allocation::vector`] dispatches priced through
    /// [`Scheduler::exec_vector`].  Queried once per
    /// [`Engine::run`](super::Engine::run), like [`Scheduler::mem_spec`].
    fn vector_spec(&self) -> Option<VectorUnit> {
        None
    }

    /// A DNN just arrived (its layers may now appear in the ready set).
    fn on_arrival(&mut self, _state: &SystemState<'_>, _dnn: DnnId) {}

    /// A layer just retired (its columns are already freed and merged).
    fn on_layer_complete(&mut self, _state: &SystemState<'_>, _dnn: DnnId, _layer: LayerId) {}

    /// A request's deadline just passed; `met` is whether it had finished.
    fn on_deadline(&mut self, _state: &SystemState<'_>, _dnn: DnnId, _met: bool) {}

    /// Opt in to a [`Scheduler::plan`] call after deadline events.
    ///
    /// Defaults to `false`: a deadline changes neither the ready set nor
    /// the tiling, so for a policy whose decisions are a pure function of
    /// [`SystemState`] (all four shipped policies) replanning there can
    /// only repeat the previous decision.  A *stateful* SLA-aware policy
    /// that reacts in [`Scheduler::on_deadline`] (boosting a tenant,
    /// releasing deferred work) returns `true` so its reaction takes
    /// effect at deadline time instead of at the next unrelated event.
    fn plan_on_deadline(&self) -> bool {
        false
    }

    /// A wake-up previously requested via [`Scheduler::wake_after`] fired.
    fn on_repartition(&mut self, _state: &SystemState<'_>) {}

    /// A finished DNN's pool slot is being recycled (see
    /// [`Engine::release`](super::Engine::release)): the id WILL be
    /// reused for a future, unrelated admission, so a policy holding any
    /// per-DNN state keyed by id must drop this DNN's entries here.
    /// Default no-op — single-run policies never see a recycled id.
    fn on_dnn_retired(&mut self, _dnn: DnnId) {}

    /// Capability flag: does this policy ever call for preemptions?
    ///
    /// `false` (the default) lets the engine skip building the
    /// running-layer view entirely — non-preempting policies pay nothing
    /// for the machinery.  A policy overriding [`Scheduler::preempt`]
    /// must return `true` here (gate it on its own config, as the
    /// dynamic policy does with `preempt = off`).
    fn preempts(&self) -> bool {
        false
    }

    /// Nominate running layers to preempt at their next fold boundary.
    ///
    /// Called once per decision point, *after* [`Scheduler::plan`] has
    /// dispatched (so starvation is judged against what is actually left
    /// free), with every in-flight layer not already draining toward a
    /// preemption.  For each returned alloc the engine asks
    /// [`Scheduler::checkpoint`] for the boundary and posts a
    /// [`Preempt`](super::Event::Preempt) event there; at that cycle the
    /// segment drains, the completed K-bands are credited to
    /// [`SystemState::k_done`], and — per the checkpoint's `keep` — the
    /// layer either shrinks in place onto a sub-tile (the freed rest
    /// goes to the next plan) or is evicted back to the ready set.
    /// Requests whose boundary would not beat the layer's own completion
    /// are ignored.  Default: never preempt.
    fn preempt(&mut self, _state: &SystemState<'_>, _running: &[RunningLayer]) -> Vec<AllocId> {
        Vec::new()
    }

    /// Locate the next fold boundary of an in-flight layer segment.
    ///
    /// `elapsed` is wall cycles since the segment's dispatch and `total`
    /// its full priced duration (the [`Scheduler::exec`] cycles, possibly
    /// stretched by a bandwidth rescale).  A policy that preempts maps
    /// `elapsed` onto its fold clock (see
    /// [`next_fold_boundary`](crate::sim::dataflow::next_fold_boundary))
    /// and reports where the segment can drain and what it completes
    /// there.  Default `None`: the policy cannot be preempted and
    /// [`Scheduler::preempt`] requests are ignored.
    fn checkpoint(
        &self,
        _state: &SystemState<'_>,
        _dnn: DnnId,
        _layer: LayerId,
        _tile: Tile,
        _elapsed: u64,
        _total: u64,
    ) -> Option<Checkpoint> {
        None
    }

    /// Map the current state to zero or more dispatches.  Returning an
    /// empty vector means "wait" — the engine will call again at the next
    /// event.
    fn plan(&mut self, state: &SystemState<'_>) -> Vec<Allocation>;

    /// Return a consumed [`Scheduler::plan`] vector for reuse.  The
    /// engine calls this after replaying every allocation of a plan, so
    /// a policy keeping a scratch arena (the dynamic scheduler under
    /// `MTSA_NO_PLAN_ARENA`-off) can hand out recycled vectors from
    /// `plan` and take them back here — steady-state planning then
    /// performs no heap allocation.  Default: drop it.
    fn recycle_plan(&mut self, _plan: Vec<Allocation>) {}

    /// Price one planned layer: cycles until completion and the activity
    /// to bill.  `coresident` counts live partitions *including* this one
    /// at dispatch (feeds the interleaved feed-bus model).
    fn exec(
        &self,
        state: &SystemState<'_>,
        dnn: DnnId,
        layer: LayerId,
        tile: Tile,
        coresident: u64,
    ) -> LayerExec;

    /// Price one planned layer on a vector-lane span.  Under `[mem]` the
    /// same contract as [`Scheduler::exec`] applies: return *compute*
    /// cycles only (see
    /// [`vector_compute_cycles`](crate::sim::dataflow::vector_compute_cycles))
    /// and let the arbiter price the stream.  The default panics — only
    /// a policy that emits [`Allocation::vector`] dispatches (and thus
    /// declared a [`Scheduler::vector_spec`]) can ever be called here.
    fn exec_vector(
        &self,
        _state: &SystemState<'_>,
        _dnn: DnnId,
        _layer: LayerId,
        _span: LaneSpan,
    ) -> LayerExec {
        unimplemented!(
            "policy `{}` returned a lane allocation but does not implement exec_vector",
            self.name()
        )
    }

    /// Request a [`Repartition`](super::Event::Repartition) wake-up this
    /// many cycles from now (`None` = none).  Called once after each
    /// plan/dispatch round.
    fn wake_after(&mut self, _state: &SystemState<'_>) -> Option<u64> {
        None
    }
}
