//! The engine's pending-event queue — a calendar/bucket queue with a
//! binary-heap reference implementation.
//!
//! The engine pops events in batches: everything at the earliest pending
//! cycle, then one `plan`.  A [`BinaryHeap`] pays `O(log n)` sift per
//! push/pop and scatters same-cycle events through the tree; the
//! [`BucketQueue`] instead keeps one sorted bucket for the cycle being
//! drained plus a `BTreeMap` of future cycles, so a same-cycle batch pops
//! by bumping a head index and a push is usually an append.
//!
//! **Ordering contract** (pinned by `bucket_queue_matches_binary_heap` in
//! `rust/tests/engine_parity.rs`): events pop in [`Event`]'s total order
//! `(time, kind, dnn, layer)`, with *insertion order* (FIFO) breaking
//! exact key ties.  The pre-queue engine left equal-key order to
//! `BinaryHeap`'s arbitrary sift order, which was observationally safe
//! only because equal-key duplicates are stale husks (the engine's
//! staleness checks make all but one a no-op); both implementations here
//! are seq-stamped, so they agree with each other *exactly*, not just
//! observationally.
//!
//! Opt out with `MTSA_NO_BUCKET_QUEUE` (any value) to run the engine on
//! the reference heap — output is identical; the switch exists for A/B
//! timing and bisecting.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};
use std::sync::OnceLock;

use super::event::Event;

/// `(event, insertion seq)` — the seq stamp makes every entry's sort key
/// unique and equal-key pops FIFO.
type Entry = (Event, u64);

/// The queue the engine actually runs on: bucket by default, heap when
/// `MTSA_NO_BUCKET_QUEUE` is set.
#[derive(Debug)]
pub enum EventQueue {
    Bucket(BucketQueue),
    Heap(HeapQueue),
}

/// Whether the bucket queue is on (see the module doc for the opt-out).
pub fn bucket_queue_enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| std::env::var_os("MTSA_NO_BUCKET_QUEUE").is_none())
}

impl EventQueue {
    /// The implementation selected by the environment.
    pub fn new() -> EventQueue {
        if bucket_queue_enabled() {
            EventQueue::Bucket(BucketQueue::new())
        } else {
            EventQueue::Heap(HeapQueue::new())
        }
    }

    pub fn push(&mut self, ev: Event) {
        match self {
            EventQueue::Bucket(q) => q.push(ev),
            EventQueue::Heap(q) => q.push(ev),
        }
    }

    pub fn pop(&mut self) -> Option<Event> {
        match self {
            EventQueue::Bucket(q) => q.pop(),
            EventQueue::Heap(q) => q.pop(),
        }
    }

    /// Cycle of the earliest pending event.
    pub fn next_time(&self) -> Option<u64> {
        match self {
            EventQueue::Bucket(q) => q.next_time(),
            EventQueue::Heap(q) => q.next_time(),
        }
    }

    /// Pop *every* event pending at the earliest cycle, appending them to
    /// `out` in exactly [`Self::pop`] order, and return that cycle
    /// (`None` when the queue is empty).
    ///
    /// Equivalent to `pop`-ing while `next_time()` stays on the same
    /// cycle — but only if nothing is pushed between those pops: a push
    /// *at* the drained cycle (mem reposts, `MemRescale`) would have
    /// interleaved into the remainder in key order.  The engine therefore
    /// uses the coalesced drain only when the shared memory hierarchy is
    /// off (see [`event_coalesce_enabled`](super::Engine)).
    pub fn pop_batch_into(&mut self, out: &mut Vec<Event>) -> Option<u64> {
        match self {
            EventQueue::Bucket(q) => q.pop_batch_into(out),
            EventQueue::Heap(q) => q.pop_batch_into(out),
        }
    }
}

impl Default for EventQueue {
    fn default() -> EventQueue {
        EventQueue::new()
    }
}

/// Reference implementation: a seq-stamped binary heap.  `(Event, u64)`
/// tuples order lexicographically, so equal event keys pop in insertion
/// order — the exact contract the bucket queue is checked against.
#[derive(Debug, Default)]
pub struct HeapQueue {
    heap: BinaryHeap<Reverse<Entry>>,
    seq: u64,
}

impl HeapQueue {
    pub fn new() -> HeapQueue {
        HeapQueue::default()
    }

    pub fn push(&mut self, ev: Event) {
        self.heap.push(Reverse((ev, self.seq)));
        self.seq += 1;
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|Reverse((ev, _))| ev)
    }

    pub fn next_time(&self) -> Option<u64> {
        self.heap.peek().map(|Reverse((ev, _))| ev.time())
    }

    /// See [`EventQueue::pop_batch_into`].
    pub fn pop_batch_into(&mut self, out: &mut Vec<Event>) -> Option<u64> {
        let t = self.next_time()?;
        while self.next_time() == Some(t) {
            out.push(self.pop().expect("peeked event pops"));
        }
        Some(t)
    }
}

/// The calendar queue: one sorted bucket for the cycle currently being
/// drained (`current[head..]` is the undrained remainder) and a
/// time-indexed map of unsorted future buckets.
///
/// The engine's access pattern makes this fast:
/// - a push to a future cycle is a `BTreeMap` probe + `Vec` append (no
///   per-element sift);
/// - draining a same-cycle batch is a head-index bump per event;
/// - a push *at* the cycle being drained (preemptions armed mid-batch,
///   mem reposts) binary-searches only the undrained remainder, matching
///   the heap's pop-min-of-remaining semantics.
///
/// Future buckets are sorted once, when they become current — `O(b log b)`
/// per bucket instead of `O(b log n)` heap sifts.  Drained bucket vectors
/// are recycled through a free pool, so a steady-state run allocates
/// nothing per event.
#[derive(Debug, Default)]
pub struct BucketQueue {
    /// Events at `cur_time`; `current[head..]` is sorted ascending and
    /// not yet popped.
    current: Vec<Entry>,
    head: usize,
    cur_time: u64,
    /// Future buckets, unsorted until they become current.
    future: BTreeMap<u64, Vec<Entry>>,
    /// Recycled bucket storage.
    pool: Vec<Vec<Entry>>,
    seq: u64,
}

impl BucketQueue {
    pub fn new() -> BucketQueue {
        BucketQueue::default()
    }

    pub fn push(&mut self, ev: Event) {
        let entry = (ev, self.seq);
        self.seq += 1;
        let t = ev.time();
        if t == self.cur_time {
            // Same-cycle push while the bucket drains (or a reopen after
            // it fully drained): keep the undrained remainder sorted so
            // pops stay min-first.  Time never moves backwards, so the
            // current cycle can never also have a future bucket.
            let pos = self.current[self.head..].partition_point(|e| e <= &entry);
            self.current.insert(self.head + pos, entry);
            return;
        }
        let bucket = self.future.entry(t).or_insert_with(|| self.pool.pop().unwrap_or_default());
        bucket.push(entry);
    }

    pub fn pop(&mut self) -> Option<Event> {
        loop {
            if self.head < self.current.len() {
                let ev = self.current[self.head].0;
                self.head += 1;
                if self.head == self.current.len() {
                    self.current.clear();
                    self.head = 0;
                }
                return Some(ev);
            }
            // Advance to the earliest future bucket.
            let (t, mut bucket) = self.future.pop_first()?;
            bucket.sort_unstable();
            self.cur_time = t;
            self.head = 0;
            self.pool.push(std::mem::replace(&mut self.current, bucket));
        }
    }

    pub fn next_time(&self) -> Option<u64> {
        if self.head < self.current.len() {
            return Some(self.cur_time);
        }
        self.future.keys().next().copied()
    }

    /// See [`EventQueue::pop_batch_into`].  The bucket layout makes this
    /// the fast path the whole queue exists for: the undrained remainder
    /// of the current bucket *is* the same-cycle batch (future buckets
    /// are strictly later), so the drain is one `extend` — no per-event
    /// head bump, comparison, or map probe.
    pub fn pop_batch_into(&mut self, out: &mut Vec<Event>) -> Option<u64> {
        if self.head >= self.current.len() {
            let (t, mut bucket) = self.future.pop_first()?;
            bucket.sort_unstable();
            self.cur_time = t;
            self.head = 0;
            self.pool.push(std::mem::replace(&mut self.current, bucket));
        }
        out.extend(self.current[self.head..].iter().map(|&(ev, _)| ev));
        self.current.clear();
        self.head = 0;
        Some(self.cur_time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arr(t: u64, dnn: usize) -> Event {
        Event::Arrival { t, dnn }
    }

    fn drain(q: &mut BucketQueue) -> Vec<Event> {
        let mut out = Vec::new();
        while let Some(e) = q.pop() {
            out.push(e);
        }
        out
    }

    #[test]
    fn pops_in_event_order_across_buckets() {
        let mut q = BucketQueue::new();
        q.push(Event::Repartition { t: 30 });
        q.push(arr(10, 1));
        q.push(Event::Deadline { t: 20, dnn: 0 });
        q.push(arr(10, 0));
        q.push(Event::LayerComplete { t: 10, dnn: 0, layer: 0, alloc: 0 });
        assert_eq!(q.next_time(), Some(10));
        assert_eq!(
            drain(&mut q),
            vec![
                arr(10, 0),
                arr(10, 1),
                Event::LayerComplete { t: 10, dnn: 0, layer: 0, alloc: 0 },
                Event::Deadline { t: 20, dnn: 0 },
                Event::Repartition { t: 30 },
            ]
        );
        assert_eq!(q.next_time(), None);
    }

    #[test]
    fn equal_keys_pop_fifo() {
        // Duplicate events (same total-order key) must come back in
        // insertion order.  Track identity via interleaved distinct keys.
        let mut q = BucketQueue::new();
        let mut h = HeapQueue::new();
        let evs = [arr(5, 0), arr(5, 0), arr(5, 0), arr(5, 1), arr(5, 0)];
        for e in evs {
            q.push(e);
            h.push(e);
        }
        let want = vec![arr(5, 0), arr(5, 0), arr(5, 0), arr(5, 0), arr(5, 1)];
        assert_eq!(drain(&mut q), want);
        let mut hout = Vec::new();
        while let Some(e) = h.pop() {
            hout.push(e);
        }
        assert_eq!(hout, want);
    }

    #[test]
    fn same_cycle_push_mid_drain_pops_in_key_order() {
        // The engine arms preemptions and reposts completions while a
        // batch drains: a push at the cycle being drained must slot into
        // the undrained remainder in key order.
        let mut q = BucketQueue::new();
        q.push(arr(10, 0));
        q.push(Event::Repartition { t: 10 });
        assert_eq!(q.pop(), Some(arr(10, 0)));
        q.push(Event::Deadline { t: 10, dnn: 2 });
        assert_eq!(q.pop(), Some(Event::Deadline { t: 10, dnn: 2 }));
        assert_eq!(q.pop(), Some(Event::Repartition { t: 10 }));
        // Bucket fully drained, time unchanged: a same-cycle push reopens it.
        q.push(Event::MemRescale { t: 10 });
        assert_eq!(q.pop(), Some(Event::MemRescale { t: 10 }));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn reopened_cycle_beats_future_buckets() {
        let mut q = BucketQueue::new();
        q.push(arr(10, 0));
        q.push(arr(20, 1));
        assert_eq!(q.pop(), Some(arr(10, 0)));
        // t=10 drained; a push back at 10 must still pop before 20.
        q.push(Event::Preempt { t: 10, dnn: 0, layer: 0, alloc: 1 });
        assert_eq!(q.next_time(), Some(10));
        assert_eq!(q.pop(), Some(Event::Preempt { t: 10, dnn: 0, layer: 0, alloc: 1 }));
        assert_eq!(q.pop(), Some(arr(20, 1)));
    }

    #[test]
    fn initial_pushes_at_cycle_zero() {
        // cur_time starts at 0; t=0 pushes must work before any pop.
        let mut q = BucketQueue::new();
        q.push(arr(0, 1));
        q.push(arr(0, 0));
        q.push(arr(3, 2));
        assert_eq!(drain(&mut q), vec![arr(0, 0), arr(0, 1), arr(3, 2)]);
    }

    #[test]
    fn bucket_vectors_are_recycled() {
        let mut q = BucketQueue::new();
        for round in 0..4u64 {
            q.push(arr(10 * (round + 1), 0));
            q.push(arr(10 * (round + 1), 1));
            assert_eq!(q.pop(), Some(arr(10 * (round + 1), 0)));
            assert_eq!(q.pop(), Some(arr(10 * (round + 1), 1)));
        }
        assert!(!q.pool.is_empty(), "drained buckets return to the pool");
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn batch_pop_matches_pop_sequence() {
        // pop_batch_into must return exactly the events pop would have
        // returned while next_time() stayed put, in the same order —
        // for both implementations, including a mid-cycle resume (some
        // events of the cycle already popped singly).
        let evs = [
            arr(5, 1),
            arr(5, 0),
            Event::Deadline { t: 5, dnn: 0 },
            arr(5, 0),
            arr(9, 2),
            Event::Repartition { t: 9 },
        ];
        let mut b = BucketQueue::new();
        let mut h = HeapQueue::new();
        let mut reference = BucketQueue::new();
        for e in evs {
            b.push(e);
            h.push(e);
            reference.push(e);
        }
        // Reference: pop singly while the cycle holds.
        let mut want = Vec::new();
        let t0 = reference.next_time().unwrap();
        while reference.next_time() == Some(t0) {
            want.push(reference.pop().unwrap());
        }
        let mut got_b = Vec::new();
        assert_eq!(b.pop_batch_into(&mut got_b), Some(5));
        assert_eq!(got_b, want);
        let mut got_h = Vec::new();
        assert_eq!(h.pop_batch_into(&mut got_h), Some(5));
        assert_eq!(got_h, want);
        // Second batch: the t=9 pair, after popping one of them singly
        // (the engine's step may mix modes across cycles).
        assert_eq!(b.pop(), Some(arr(9, 2)));
        got_b.clear();
        assert_eq!(b.pop_batch_into(&mut got_b), Some(9));
        assert_eq!(got_b, vec![Event::Repartition { t: 9 }]);
        assert_eq!(b.pop_batch_into(&mut got_b), None, "drained queue");
        got_h.clear();
        assert_eq!(h.pop_batch_into(&mut got_h), Some(9));
        assert_eq!(got_h, vec![arr(9, 2), Event::Repartition { t: 9 }]);
    }

    #[test]
    fn batch_pop_preserves_fifo_on_equal_keys() {
        let mut q = BucketQueue::new();
        let mut h = HeapQueue::new();
        for e in [arr(5, 0), arr(5, 0), arr(5, 1), arr(5, 0)] {
            q.push(e);
            h.push(e);
        }
        let want = vec![arr(5, 0), arr(5, 0), arr(5, 0), arr(5, 1)];
        let mut got = Vec::new();
        q.pop_batch_into(&mut got);
        assert_eq!(got, want);
        got.clear();
        h.pop_batch_into(&mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn default_selection_is_bucket() {
        // The env opt-out is process-wide; in the test process it is not
        // set, so the engine runs on the bucket implementation.
        assert!(matches!(EventQueue::new(), EventQueue::Bucket(_)));
    }
}
